//! Quickstart: the public API in five minutes.
//!
//! 1. load the artifact manifest (`make artifacts` first),
//! 2. inspect the partitioned models and their exit points (paper Fig. 2),
//! 3. describe one MDI-Exit experiment and launch it through the `Run`
//!    builder on the discrete-event driver,
//! 4. read the report.
//!
//! The same builder drives both execution media: swap
//! `.driver(Driver::Des)` for `.driver(Driver::Realtime)` and the identical
//! `WorkerCore` decision logic runs on OS threads in wallclock time (see
//! `examples/edge_camera.rs`). Everything not supplied explicitly — model
//! metadata, engine, dataset — is derived from the manifest.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use mdi_exit::artifact::Manifest;
use mdi_exit::coordinator::{AdmissionMode, Driver, ExperimentConfig, Run};

fn main() -> Result<()> {
    // 1. Artifacts: everything the Python AOT pipeline produced.
    let manifest = Manifest::load(mdi_exit::artifacts_dir())?;
    println!("dataset: {} held-out samples", manifest.dataset.n);

    // 2. The partitioned models (paper Fig. 2: exit-point placement).
    for (name, info) in &manifest.models {
        println!("\nmodel {name} — {} tasks (exit points):", info.num_stages);
        for s in &info.stages {
            println!(
                "  τ_{}: {:>3?} -> {:>3?}  {:>7.2} ms  features on wire: {:>6} B",
                s.k, s.in_shape, s.out_shape, s.cost_ms, s.in_bytes
            );
        }
        println!("  accuracy if everything exited at k: {:?}", info.exit_accuracy);
        if let Some(ae) = &info.ae {
            println!("  autoencoder at exit 1: {} B -> {} B ({:.0}x)",
                     ae.raw_bytes, ae.code_bytes, ae.compression);
        }
    }

    // 3. One experiment: MobileNetV2-Lite on the 3-node mesh, fixed
    //    confidence threshold 0.9, Alg. 3 adapting the data rate. The
    //    config describes *what* to run; the builder picks up the model
    //    metadata, oracle engine, and sample store from the manifest.
    let mut cfg = ExperimentConfig::new(
        "mobilenetv2l",
        "3-node-mesh",
        AdmissionMode::AdaptiveRate { threshold: 0.9, initial_mu_s: 0.25 },
    );
    cfg.duration_s = 30.0; // virtual seconds — finishes in well under a wallclock second
    cfg.warmup_s = 10.0;
    cfg.compute_scale = 0.125; // model edge-class devices

    let mut report = Run::builder()
        .config(cfg)
        .manifest(&manifest)
        .driver(Driver::Des) // the default; Driver::Realtime uses threads
        .execute()?;

    // 4. The report.
    println!("\n== 3-node mesh, T_e = 0.9, Alg. 3 rate adaptation ==");
    println!("admitted rate   {:>8.1} Hz", report.admitted_rate_hz());
    println!("completed rate  {:>8.1} Hz", report.throughput_hz());
    println!("accuracy        {:>8.4}", report.accuracy());
    println!("latency p50/p95 {:>8.2} / {:.2} ms",
             report.latency.p50() * 1e3, report.latency.p95() * 1e3);
    println!("exit fractions  {:?}",
             report.exit_fractions().iter().map(|f| format!("{f:.2}")).collect::<Vec<_>>());
    println!("offloads        {:>8}", report.task_transfers);
    println!("bytes on wire   {:>8}", report.bytes_on_wire);
    Ok(())
}
