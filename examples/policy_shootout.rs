//! Scenario: three offload policies, one overloaded leaf, head to head.
//!
//! The decision-policy API (`mdi_exit::policy`) makes the paper's Alg. 2 a
//! *choice*: the same run config swaps `--offload-policy` between the
//! baseline one-hop scan, the deadline-aware slack comparison, and the
//! multi-hop region push. This example runs all three on a 5-node star
//! whose *only* source sits on leaf 1 — the hardest placement for one-hop
//! offloading, because the source's single neighbor is the hub: work can
//! only leave the leaf through it, and reaching the three idle leaves
//! takes a second hop the baseline policy cannot reason about.
//!
//! The source admits ~3x one worker's capacity with a tight class-0
//! latency budget, and the table shows what each policy does with the same
//! overload: completed throughput, accuracy, class-0 on-time rate, how
//! many workers actually computed, and what the (variable-size, charged by
//! encoded bytes) gossip cost.
//!
//! Entirely artifact-free (synthetic exit oracle): just
//! `cargo run --release --example policy_shootout`.

use anyhow::Result;

use mdi_exit::coordinator::{
    AdmissionMode, Driver, ExperimentConfig, ModelMeta, OffloadKind, Placement, Run,
    RunReport,
};
use mdi_exit::dataset::ExitTable;
use mdi_exit::runtime::sim_engine::SimEngine;

/// 8 samples x 3 exits: every fourth sample exits confidently at stage 1,
/// the rest ride to the heavy final stage. Predictions match the label.
fn oracle() -> (ExitTable, Vec<u8>) {
    let n = 8;
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
    for i in 0..n {
        if i % 4 == 0 {
            conf.extend([0.97f32, 0.99, 1.0]);
        } else {
            conf.extend([0.30f32, 0.50, 0.95]);
        }
        pred.extend([labels[i]; 3]);
    }
    (ExitTable::synthetic(n, 3, conf, pred), labels)
}

fn run_policy(offload: OffloadKind, labels: &[u8], engine: &SimEngine) -> Result<RunReport> {
    // Stage-3-heavy pipeline: 1 ms + 1 ms + 6 ms — one worker sustains
    // ~160 Hz of this stream; the leaf source admits 450 Hz.
    let meta = ModelMeta::synthetic(vec![0.001, 0.001, 0.006], vec![12288, 8192, 4096]);
    let mut cfg = ExperimentConfig::new(
        "policy-shootout",
        "star-5",
        AdmissionMode::Fixed { rate_hz: 450.0, threshold: 0.9 },
    );
    cfg.duration_s = 20.0;
    cfg.warmup_s = 2.0;
    cfg.placement = Placement::single(1);
    // Small T_O keeps the output queue short — the regime where queue-
    // length gates stall and wait/deadline reasoning pays (see the
    // `ablation_policy` bench for the asserted version of this story).
    cfg.t_o = 2;
    cfg.sched = cfg.sched.with_classes(2);
    cfg.sched.class_deadline_s = vec![0.5, 10.0];
    cfg.policy.offload = offload;
    Run::builder()
        .config(cfg)
        .model(meta)
        .engine(engine)
        .labels(labels)
        .driver(Driver::Des)
        .execute()
}

fn main() -> Result<()> {
    let (table, labels) = oracle();
    let engine = SimEngine::from_table(table, false);

    println!(
        "policy_shootout: 5-node star, single source on leaf 1 @ 450 Hz\n\
         (all work leaves through the hub; leaves 2-4 idle unless a policy\n\
         finds them)\n"
    );
    println!(
        "{:<16} {:>10} {:>10} {:>12} {:>8} {:>12}",
        "policy", "tput(Hz)", "accuracy", "c0 on-time", "workers", "gossip B"
    );

    let mut results = Vec::new();
    for (kind, name) in [
        (OffloadKind::Alg2, "baseline (alg2)"),
        (OffloadKind::DeadlineAware, "deadline-aware"),
        (OffloadKind::MultiHop, "multi-hop"),
    ] {
        let r = run_policy(kind, &labels, &engine)?;
        let busy = r.per_worker.iter().filter(|w| w.processed > 0).count();
        println!(
            "{name:<16} {:>10.1} {:>10.4} {:>12.3} {:>8} {:>12}",
            r.throughput_hz(),
            r.accuracy(),
            r.per_class[0].on_time_rate(),
            busy,
            r.gossip_bytes()
        );
        results.push((name, r));
    }

    // The properties this example demonstrates, asserted so it doubles as
    // a smoke test.
    for (name, r) in &results {
        anyhow::ensure!(r.completed > 0, "{name}: nothing completed");
        anyhow::ensure!(
            (r.accuracy() - 1.0).abs() < 1e-9,
            "{name}: oracle predicts the label at every exit"
        );
        let by_class: u64 = r.per_class.iter().map(|c| c.completed).sum();
        anyhow::ensure!(by_class == r.completed, "{name}: class counters conserve");
    }
    // Multi-hop is the only policy that can *reason* about the far leaves;
    // it must put compute on more workers than the one-hop baseline sees.
    let busy = |r: &RunReport| r.per_worker.iter().filter(|w| w.processed > 0).count();
    let (_, base) = &results[0];
    let (_, multi) = &results[2];
    anyhow::ensure!(
        busy(multi) >= busy(base),
        "multi-hop must reach at least as many workers as the baseline"
    );
    anyhow::ensure!(
        multi.gossip_bytes() > base.gossip_bytes(),
        "the region table rides the gossip and is charged by encoded size"
    );
    println!("\nmulti-hop busy workers: {} (baseline: {})", busy(multi), busy(base));
    Ok(())
}
