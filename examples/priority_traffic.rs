//! Scenario: two traffic classes share an overloaded edge mesh —
//! interactive requests (class 0, latency-critical) and bulk analytics
//! (class 1, best-effort). Priority-Aware MDI (arXiv 2412.12371) shows
//! that class-aware queueing at each worker decides which traffic meets
//! its deadline under overload; this example reproduces that effect with
//! the `sched` subsystem on the paper's MobileNetV2 pipeline.
//!
//! Four runs on the same seed and workload:
//!   * FIFO            — both classes share one queue (the paper's system);
//!   * StrictPriority  — interactive traffic jumps the bulk backlog;
//!   * EDF + drop-late — per-class deadline budgets; hopelessly late bulk
//!                       work is aged out instead of wasting compute;
//!   * StrictPriority + coalesce=stage-class — offloads drain same-stage,
//!     same-class runs into one `net::Envelope`, so batches travel the
//!     network (per-worker `envelopes_sent` / `coalesced_tasks` /
//!     `wire_bytes_saved` counters surface the wire economy).
//!
//! Run: `cargo run --release --example priority_traffic`

use anyhow::Result;

use mdi_exit::artifact::Manifest;
use mdi_exit::coordinator::{AdmissionMode, ExperimentConfig, Run, RunReport};
use mdi_exit::sched::{CoalesceMode, DisciplineKind};

fn main() -> Result<()> {
    let manifest = Manifest::load(mdi_exit::artifacts_dir())?;
    let run = |cfg: ExperimentConfig| -> Result<RunReport> {
        Run::builder().config(cfg).manifest(&manifest).execute()
    };

    // 1.5x the mesh's sustainable rate: the backlog has to land somewhere,
    // and the queue discipline decides on whom.
    let mut base = ExperimentConfig::new(
        "mobilenetv2l",
        "5-node-mesh",
        AdmissionMode::Fixed { rate_hz: 630.0, threshold: 0.9 },
    );
    base.duration_s = 60.0;
    base.warmup_s = 10.0;
    base.compute_scale = 0.125;
    base.sched = base.sched.with_classes(2);
    // Interactive budget 150 ms, bulk budget 5 s (EDF deadline stamps).
    base.sched.class_deadline_s = vec![0.15, 5.0];

    println!(
        "priority_traffic: 5-node mesh @ 630 Hz (overloaded), MobileNetV2-Lite,\n\
         class 0 = interactive (every other admission), class 1 = bulk\n"
    );
    println!(
        "{:<22} {:>9} {:>11} {:>11} {:>9} {:>9} {:>10} {:>9}",
        "discipline", "tput(Hz)", "c0 p95(ms)", "c1 p95(ms)", "dropped", "envel.", "coalesced",
        "B saved"
    );

    let print_run = |name: &str, mut r: RunReport| -> (f64, f64) {
        let (c0, c1) = {
            let [a, b] = &mut r.per_class[..] else { panic!("two classes") };
            (a.latency.p95(), b.latency.p95())
        };
        println!(
            "{name:<22} {:>9.1} {:>11.2} {:>11.2} {:>9} {:>9} {:>10} {:>9}",
            r.throughput_hz(),
            c0 * 1e3,
            c1 * 1e3,
            r.dropped,
            r.envelopes_sent(),
            r.coalesced_tasks(),
            r.wire_bytes_saved()
        );
        (c0, c1)
    };

    let fifo = run(base.clone())?;
    let (fifo_c0, _) = print_run("fifo", fifo);

    let mut prio = base.clone();
    prio.sched.discipline = DisciplineKind::StrictPriority;
    let (prio_c0, prio_c1) = print_run("strict-priority", run(prio)?);

    let mut edf = base.clone();
    edf.sched.discipline = DisciplineKind::Edf { drop_late: true };
    let edf_report = run(edf)?;
    let edf_dropped = edf_report.dropped;
    print_run("edf + drop-late", edf_report);

    // Same priority run, but offloads coalesce same-stage/same-class runs
    // into shared wire envelopes (class isolation preserved end to end).
    let mut prio_co = base.clone();
    prio_co.sched.discipline = DisciplineKind::StrictPriority;
    prio_co.sched.coalesce = CoalesceMode::StageClass;
    prio_co.sched.coalesce_max = 8;
    let co_report = run(prio_co)?;
    let (co_envelopes, co_tasks, co_saved) =
        (co_report.envelopes_sent(), co_report.coalesced_tasks(), co_report.wire_bytes_saved());
    print_run("priority + coalesce", co_report);

    println!(
        "\nUnder overload FIFO spreads the backlog over everyone; strict\n\
         priority keeps the interactive class fast at the bulk class's\n\
         expense; EDF additionally sheds bulk work that already missed its\n\
         budget instead of computing worthless results. With stage-class\n\
         coalescing the same priority traffic crossed the mesh in\n\
         {co_envelopes} envelopes ({co_tasks} tasks rode along, {co_saved} B\n\
         of framing saved) — batches travel the network, not just the engine."
    );
    anyhow::ensure!(
        prio_c0 < fifo_c0,
        "priority must beat FIFO for class 0: {prio_c0} vs {fifo_c0}"
    );
    anyhow::ensure!(
        prio_c0 < prio_c1,
        "priority must separate the classes: {prio_c0} vs {prio_c1}"
    );
    anyhow::ensure!(edf_dropped > 0, "overloaded EDF with drop-late should shed late work");
    Ok(())
}
