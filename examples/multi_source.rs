//! Scenario: two ingest points shard one edge cluster.
//!
//! The paper's testbed has a single source one hop from every worker. Real
//! edge deployments rarely look like that: several cameras (or gateways)
//! admit data into a shared pool of compute, and results must find their
//! way back to whichever ingest point owns them — possibly across several
//! hops. The `routing` module makes that a config choice: a `Placement`
//! declares the sources, and the next-hop table carries every result and
//! re-homed task back to its admitting source.
//!
//! Here two sources sit on *leaves* of a 5-node star (nodes 1 and 2), so
//! every cross-leaf offload and every result from a foreign leaf crosses
//! the hub — 2 hops. The model's final stage is deliberately heavy, which
//! pushes continuing work off the source leaves, through the hub, onto the
//! idle leaves 3 and 4; their results then relay back through the hub. The
//! run prints per-source throughput/accuracy and the hub's relay counter,
//! which is pure routing work that did not exist before this API.
//!
//! Entirely artifact-free (synthetic exit oracle): just
//! `cargo run --release --example multi_source`.

use anyhow::Result;

use mdi_exit::coordinator::{
    AdmissionMode, Driver, ExperimentConfig, ModelMeta, Placement, Run,
};
use mdi_exit::dataset::ExitTable;
use mdi_exit::runtime::sim_engine::SimEngine;

/// 8 samples x 3 exits: every fourth sample exits confidently at stage 1,
/// the rest ride to the final stage. Predictions always match the label.
fn oracle() -> (ExitTable, Vec<u8>) {
    let n = 8;
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
    for i in 0..n {
        if i % 4 == 0 {
            conf.extend([0.97f32, 0.99, 1.0]);
        } else {
            conf.extend([0.30f32, 0.50, 0.95]);
        }
        pred.extend([labels[i]; 3]);
    }
    (ExitTable::synthetic(n, 3, conf, pred), labels)
}

fn main() -> Result<()> {
    let (table, labels) = oracle();
    let engine = SimEngine::from_table(table, false);
    // Stage-3-heavy pipeline: 1 ms + 1 ms + 6 ms. One worker sustains
    // ~160 Hz of this stream, so two 300 Hz sources must shed stage-3
    // work across the star.
    let meta = ModelMeta::synthetic(vec![0.001, 0.001, 0.006], vec![12288, 8192, 4096]);

    let mut cfg = ExperimentConfig::new(
        "multi-source-demo",
        "star-5",
        AdmissionMode::Fixed { rate_hz: 300.0, threshold: 0.9 },
    );
    cfg.duration_s = 30.0;
    cfg.warmup_s = 5.0;
    cfg.placement = Placement::multi(&[1, 2]);

    println!(
        "multi_source: 5-node star, sources on leaves 1 and 2 @ 300 Hz each\n\
         (hub = node 0; every cross-leaf task and foreign result crosses it)\n"
    );

    let mut report = Run::builder()
        .config(cfg)
        .model(meta)
        .engine(&engine)
        .labels(&labels)
        .driver(Driver::Des)
        .execute()?;

    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "source", "admitted", "completed", "tput(Hz)", "accuracy", "p95(ms)"
    );
    for s in report.per_source.iter_mut() {
        println!(
            "node {:<5} {:>10} {:>10} {:>10.1} {:>10.4} {:>10.2}",
            s.node,
            s.admitted,
            s.completed,
            s.completed as f64 / report.duration_s,
            s.accuracy(),
            s.latency.p95() * 1e3
        );
    }
    println!(
        "\ntotals: {:.1} Hz, accuracy {:.4}, {} task transfers, {} B on wire",
        report.throughput_hz(),
        report.accuracy(),
        report.task_transfers,
        report.bytes_on_wire
    );
    println!(
        "hub relays (results/re-homes forwarded for other nodes): {}",
        report.per_worker[0].relayed
    );

    // The properties this example demonstrates, asserted so it doubles as
    // a smoke test: both sources are served, every result went home
    // correctly, and the hub really relayed foreign-leaf results.
    for s in &report.per_source {
        anyhow::ensure!(s.completed > 0, "source {} got nothing back", s.node);
        anyhow::ensure!(
            (s.accuracy() - 1.0).abs() < 1e-9,
            "oracle predicts the label at every exit"
        );
    }
    anyhow::ensure!(
        report.per_worker[0].relayed > 0,
        "leaf sources imply relay work at the hub"
    );
    Ok(())
}
