//! Scenario: a traffic spike hits the source (paper §IV.B scenario ii).
//!
//! Poisson arrivals step through rising mean rates; Algorithm 4 adapts the
//! early-exit threshold so *all* traffic is admitted, trading accuracy for
//! throughput. Prints the threshold/queue trace per rate — the mechanism
//! behind the paper's Figs 5–6.
//!
//! Run: `cargo run --release --example overload_adaptation -- [--model resnetl --use-ae]`

use anyhow::Result;

use mdi_exit::artifact::Manifest;
use mdi_exit::cli::Args;
use mdi_exit::coordinator::{AdmissionMode, ExperimentConfig, Run};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let model = args.str_or("model", "mobilenetv2l").to_string();
    let use_ae = args.bool_or("use-ae", false)?;
    let topology = args.str_or("topology", "3-node-mesh").to_string();

    let manifest = Manifest::load(mdi_exit::artifacts_dir())?;
    println!("overload_adaptation: {model} on {topology} (Alg. 4, Poisson arrivals)");
    println!(
        "\n{:>10} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "rate(Hz)", "final T_e", "accuracy", "tput(Hz)", "p95 lat(ms)", "exit@1"
    );

    for rate in [10.0, 25.0, 50.0, 100.0, 200.0, 400.0] {
        let mut cfg = ExperimentConfig::new(
            &model,
            &topology,
            AdmissionMode::AdaptiveThreshold {
                rate_hz: rate,
                initial_t_e: 0.9,
                t_e_min: 0.05,
            },
        );
        cfg.use_ae = use_ae;
        cfg.duration_s = 45.0;
        cfg.warmup_s = 15.0;
        cfg.compute_scale = 0.125;
        let mut r = Run::builder().config(cfg).manifest(&manifest).execute()?;
        println!(
            "{:>10.0} {:>10.3} {:>10.4} {:>10.1} {:>12.2} {:>10.2}",
            rate,
            r.final_t_e.unwrap_or(f64::NAN),
            r.accuracy(),
            r.throughput_hz(),
            r.latency.p95() * 1e3,
            r.exit_fractions().first().copied().unwrap_or(0.0),
        );
    }

    println!(
        "\nExpected shape (paper Figs 5–6): as the rate grows, T_e falls, more\n\
         samples exit at point 1, and accuracy degrades gracefully instead of\n\
         queues growing without bound."
    );
    Ok(())
}
