//! Scenario: workers join and leave mid-run (paper §III: "a dynamic edge
//! computing setup where workers join and leave the system anytime").
//!
//! A 5-node mesh loses two workers during a sustained load, then one
//! returns. Queued and in-flight tasks re-home to the source (no data
//! loss); the run shows throughput dip and recovery plus the re-homing
//! counters.
//!
//! Run: `cargo run --release --example churn_resilience`

use anyhow::Result;

use mdi_exit::artifact::Manifest;
use mdi_exit::coordinator::{AdmissionMode, ExperimentConfig, Run, RunReport};
use mdi_exit::simnet::ChurnEvent;

fn main() -> Result<()> {
    let manifest = Manifest::load(mdi_exit::artifacts_dir())?;
    let run = |cfg: ExperimentConfig| -> Result<RunReport> {
        Run::builder().config(cfg).manifest(&manifest).execute()
    };

    let mut base = ExperimentConfig::new(
        "mobilenetv2l",
        "5-node-mesh",
        AdmissionMode::Fixed { rate_hz: 420.0, threshold: 0.9 },
    );
    base.duration_s = 60.0;
    base.warmup_s = 10.0;
    base.compute_scale = 0.125;

    println!("churn_resilience: 5-node mesh @ 420 Hz fixed (near the τ1 capacity ceiling), MobileNetV2-Lite\n");
    println!("{:<28} {:>10} {:>10} {:>10} {:>10}",
             "scenario", "tput(Hz)", "accuracy", "p95(ms)", "rehomed");

    // Stable reference run.
    let mut stable = run(base.clone())?;
    println!("{:<28} {:>10.1} {:>10.4} {:>10.2} {:>10}",
             "stable (no churn)", stable.throughput_hz(), stable.accuracy(),
             stable.latency.p95() * 1e3, stable.rehomed);

    // Two workers leave at t=20s/25s; one rejoins at t=45s.
    let mut churny = base.clone();
    churny.churn = vec![
        ChurnEvent { at_s: 20.0, worker: 3, join: false },
        ChurnEvent { at_s: 25.0, worker: 4, join: false },
        ChurnEvent { at_s: 45.0, worker: 3, join: true },
    ];
    let mut r = run(churny)?;
    println!("{:<28} {:>10.1} {:>10.4} {:>10.2} {:>10}",
             "leave@20s,25s join@45s", r.throughput_hz(), r.accuracy(),
             r.latency.p95() * 1e3, r.rehomed);

    // Source-only survival: everyone else leaves.
    let mut worst = base.clone();
    worst.churn = (1..5)
        .map(|w| ChurnEvent { at_s: 15.0 + w as f64, worker: w, join: false })
        .collect();
    let mut w = run(worst)?;
    println!("{:<28} {:>10.1} {:>10.4} {:>10.2} {:>10}",
             "all non-source leave", w.throughput_hz(), w.accuracy(),
             w.latency.p95() * 1e3, w.rehomed);

    println!(
        "\nInvariant: tasks queued on a leaving worker re-home to the source\n\
         (rehomed > 0) instead of disappearing; the system degrades to the\n\
         Local baseline rather than failing."
    );
    anyhow::ensure!(r.rehomed > 0, "churn run should have re-homed tasks");
    Ok(())
}
