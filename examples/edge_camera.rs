//! End-to-end validation driver (DESIGN.md §5): the full three-layer stack
//! on a real workload.
//!
//! A simulated edge camera (worker 0) admits held-out test images under the
//! paper's Alg. 3 rate adaptation. Every worker is a real OS thread driven
//! by the same `WorkerCore` the DES benches exercise — here through
//! `Run::builder().driver(Driver::Realtime)` — with tasks moving between
//! threads over the delay-enforcing simnet transport. With the `pjrt`
//! feature the per-worker engine is the compiled HLO stages on PJRT (the
//! Pallas kernels lowered by `python/compile/aot.py`, zero Python);
//! otherwise it falls back to oracle replay with wallclock cost emulation.
//!
//! Reports admitted/completed rate, accuracy, per-exit histogram, and
//! latency percentiles; recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example edge_camera -- [--topology 3-node-mesh]
//!       [--seconds 20] [--threshold 0.9] [--model mobilenetv2l]`

use anyhow::{Context, Result};

use mdi_exit::artifact::Manifest;
use mdi_exit::cli::Args;
use mdi_exit::coordinator::{AdmissionMode, Driver, ExperimentConfig, Run};
use mdi_exit::runtime::InferenceEngine;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let topology = args.str_or("topology", "3-node-mesh").to_string();
    let seconds = args.f64_or("seconds", 20.0)?;
    let threshold = args.f64_or("threshold", 0.9)? as f32;
    let model = args.str_or("model", "mobilenetv2l").to_string();

    let manifest = Manifest::load(mdi_exit::artifacts_dir())?;
    let info = manifest.model(&model)?;

    let mut cfg = ExperimentConfig::new(
        &model,
        &topology,
        AdmissionMode::AdaptiveRate { threshold, initial_mu_s: 0.10 },
    );
    cfg.duration_s = seconds;
    cfg.warmup_s = (seconds * 0.25).min(5.0);
    cfg.adapt.sleep_s = 0.25;

    println!("edge_camera: {model} on {topology}, T_e = {threshold}, {seconds}s wallclock");
    println!("building {} stages per worker...", info.num_stages);
    let manifest_ref = &manifest;
    let model_name = model.clone();
    let factory = move |worker: usize| -> Result<Box<dyn InferenceEngine>> {
        let t0 = std::time::Instant::now();
        let eng = mdi_exit::runtime::default_engine(manifest_ref, &model_name, false)
            .with_context(|| format!("worker {worker}"))?;
        eprintln!("  worker {worker}: {} stages ready in {:.2}s",
                  eng.num_stages(), t0.elapsed().as_secs_f64());
        Ok(eng)
    };

    let mut r = Run::builder()
        .config(cfg.clone())
        .manifest(&manifest)
        .engine_factory(factory)
        .driver(Driver::Realtime)
        .execute()?;

    println!("\n== end-to-end results (measured window: {:.1}s) ==", cfg.duration_s);
    println!("admitted        {:>8}  ({:.1} Hz)", r.admitted, r.admitted_rate_hz());
    println!("completed       {:>8}  ({:.1} Hz)", r.completed, r.throughput_hz());
    println!("accuracy        {:>8.4}", r.accuracy());
    println!("latency p50     {:>8.2} ms", r.latency.p50() * 1e3);
    println!("latency p95     {:>8.2} ms", r.latency.p95() * 1e3);
    println!("latency p99     {:>8.2} ms", r.latency.p99() * 1e3);
    println!("exit histogram  {:?}", r.exit_histogram);
    if let Some(mu) = r.final_mu_s {
        println!("final μ         {:>8.4} s  ({:.1} Hz steady-state)", mu, 1.0 / mu);
    }
    for (i, w) in r.per_worker.iter().enumerate() {
        println!(
            "worker {i}: processed {:>6}  exits {:>6}  offloaded {:>5}  received {:>5}  busy {:>6.2}s",
            w.processed, w.exits, w.offloaded_out, w.received, w.busy_s
        );
    }
    anyhow::ensure!(r.completed > 0, "no results completed — system misconfigured");
    Ok(())
}
