"""AOT pipeline invariants: HLO emission, binary formats, manifest, caching.

These tests do not retrain: they exercise the pipeline's pure pieces with
random parameters (fast) and, when artifacts/ already exists, validate the
shipped manifest (the contract the Rust side consumes).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot as A
from compile import data as D
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)
ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_to_hlo_text_smoke(tmp_path):
    """A stage lowers to parseable-looking HLO text with a tuple root."""
    params = M.init_params("mobilenetv2l", KEY)
    out = tmp_path / "stage1.hlo.txt"
    size = A.emit_stage_hlo("mobilenetv2l", params, 1, str(out))
    text = out.read_text()
    assert size == len(text) > 1000
    assert "HloModule" in text
    assert "f32[16,16,24]" in text  # stage-1 feature output shape
    assert "f32[10]" in text        # probs output


def test_exits_bin_roundtrip(tmp_path):
    conf = np.random.rand(16, 3).astype(np.float32)
    pred = np.random.randint(0, 10, (16, 3)).astype(np.uint8)
    p = tmp_path / "exits.bin"
    A.write_exits_bin(str(p), conf, pred)
    raw = p.read_bytes()
    hdr = np.frombuffer(raw[:16], np.uint32)
    assert hdr[0] == A.EXITS_MAGIC
    assert (hdr[2], hdr[3]) == (16, 3)
    got_conf = np.frombuffer(raw[16:16 + 16 * 3 * 4], np.float32).reshape(16, 3)
    got_pred = np.frombuffer(raw[16 + 16 * 3 * 4:], np.uint8).reshape(16, 3)
    np.testing.assert_allclose(got_conf, conf)
    np.testing.assert_array_equal(got_pred, pred)


def test_dataset_bin_roundtrip(tmp_path):
    tpl = D.class_templates(jax.random.PRNGKey(1))
    ds = D.make_dataset(jax.random.PRNGKey(2), 8, tpl)
    p = tmp_path / "dataset.bin"
    D.write_dataset_bin(str(p), ds)
    raw = p.read_bytes()
    hdr = np.frombuffer(raw[:24], np.uint32)
    assert hdr[0] == D.DATASET_MAGIC
    assert hdr[2] == 8
    n, h, w, c = 8, D.IMG_H, D.IMG_W, D.IMG_C
    assert len(raw) == 24 + n * h * w * c + n + 4 * n


def test_param_cache_roundtrip(tmp_path):
    params = M.init_params("resnetl", KEY)
    p = tmp_path / "params.npz"
    A.save_params(str(p), params)
    loaded = A.load_params(str(p))

    flat_a = dict(A._flatten(params))
    flat_b = dict(A._flatten(loaded))
    assert set(flat_a) == set(flat_b)
    for k in flat_a:
        np.testing.assert_array_equal(flat_a[k], flat_b[k])


def test_exit_rates_partition():
    conf = np.array([[0.95, 0.2], [0.3, 0.99], [0.1, 0.2]], np.float32)
    rates = A.exit_rates(conf, [0.9])
    r = rates["0.9"]
    # sample0 exits at 1; samples 1,2 absorb at final
    # exit_rates rounds to 4 decimals for the manifest
    assert r == [pytest.approx(1 / 3, abs=1e-3), pytest.approx(2 / 3, abs=1e-3)]
    assert pytest.approx(sum(r), abs=1e-3) == 1.0


def test_exit_rates_threshold_monotonicity():
    rng = np.random.RandomState(0)
    conf = rng.rand(512, 4).astype(np.float32)
    rates = A.exit_rates(conf, [0.3, 0.6, 0.9])
    # higher threshold → fewer exit-1 exits
    assert rates["0.3"][0] >= rates["0.6"][0] >= rates["0.9"][0]
    for key in rates:
        assert pytest.approx(sum(rates[key]), abs=1e-3) == 1.0


def test_vmem_audit_under_budget():
    for name in M.model_names():
        for row in A.vmem_audit(name):
            for key, v in row.items():
                if key.endswith("_bytes"):
                    assert v < 16 * 1024 * 1024, f"{name} {row}"


def test_canonical_templates_match_training_derivation():
    """Train/test distribution equality — the bug class this guards against
    produced 2% 'accuracy' in an early build."""
    tpl_a = A.canonical_templates()
    ktpl = jax.random.split(jax.random.PRNGKey(A.SEED), 3)[0]
    tpl_b = D.class_templates(ktpl)
    np.testing.assert_array_equal(np.asarray(tpl_a), np.asarray(tpl_b))


# ---------------------------------------------------------------------------
# Shipped-artifact validation (skipped until `make artifacts` has run)
# ---------------------------------------------------------------------------

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)


@needs_artifacts
def test_shipped_manifest_consistent():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["dataset"]["n"] >= 1024
    for name, entry in man["models"].items():
        stages = entry["stages"]
        assert len(stages) == entry["num_stages"]
        for a, b in zip(stages, stages[1:]):
            assert a["out_shape"] == b["in_shape"], name
        for s in stages:
            assert os.path.exists(os.path.join(ART, s["hlo"])), s["hlo"]
            assert s["cost_ms"] > 0
        assert os.path.exists(os.path.join(ART, entry["exits_bin"]))
        # final exit must be the most accurate (deepest classifier)
        acc = entry["exit_accuracy"]
        assert acc[-1] == max(acc)
        assert acc[-1] > 0.9, f"{name} final accuracy {acc[-1]} too low"


@needs_artifacts
def test_shipped_exit_confidences_monotone_enough():
    """Deeper exits should be (weakly) more confident on average."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, entry in man["models"].items():
        mc = entry["mean_confidence"]
        assert mc[-1] >= mc[0] - 0.05, f"{name}: {mc}"


@needs_artifacts
def test_shipped_ae_claims():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    ae = man["models"]["resnetl"]["ae"]
    assert ae["compression"] >= 64
    # Paper: up to 2.2% accuracy cost. Our Lite trunk is far shallower than
    # ResNet-50, so the exit directly after the AE pays more (reconstruction
    # error has fewer layers to wash out) — but the *final* exit must match
    # the paper's ≤~2% claim.
    assert abs(ae["acc_drop"][-1]) < 0.03
    assert max(abs(d) for d in ae["acc_drop"]) < 0.2
    for key in ("enc_hlo", "dec_hlo"):
        assert os.path.exists(os.path.join(ART, ae[key]))
