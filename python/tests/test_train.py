"""Training substrate tests: the in-tree Adam, the multi-exit loss, and the
evaluation helpers (fast — no full model training here)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M
from compile import train as T

jax.config.update("jax_platform_name", "cpu")


def test_adam_converges_on_quadratic():
    """min ||x - c||^2 — Adam must reach the optimum."""
    c = jnp.array([1.5, -2.0, 0.5])
    params = {"x": jnp.zeros(3)}
    opt = T.adam_init(params)
    loss_fn = lambda p: jnp.sum((p["x"] - c) ** 2)
    for _ in range(400):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = T.adam_update(params, grads, opt, lr=0.05)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(c), atol=1e-2)


def test_adam_bias_correction_first_step():
    """First step with bias correction moves by ~lr regardless of grad scale."""
    params = {"x": jnp.zeros(1)}
    opt = T.adam_init(params)
    grads = {"x": jnp.array([1e-3])}
    new, _ = T.adam_update(params, grads, opt, lr=0.1)
    assert abs(float(new["x"][0]) + 0.1) < 1e-3  # moved ≈ -lr


def test_ce_loss_matches_manual():
    logits = jnp.array([[2.0, 0.0, -1.0], [0.0, 0.0, 0.0]])
    y = jnp.array([0, 2])
    got = float(T._ce(logits, y))
    p0 = np.exp(2.0) / (np.exp(2.0) + 1 + np.exp(-1.0))
    want = (-np.log(p0) - np.log(1 / 3)) / 2
    assert abs(got - want) < 1e-5


def test_multi_exit_loss_weights_all_exits():
    """Zeroing one exit's contribution must change the loss — every exit is
    in the objective."""
    params = M.init_params("resnetl", jax.random.PRNGKey(0))
    tpl = D.class_templates(jax.random.PRNGKey(1))
    ds = D.make_dataset(jax.random.PRNGKey(2), 8, tpl)
    full = float(T.multi_exit_loss("resnetl", params, ds.images, ds.labels))
    assert np.isfinite(full) and full > 0
    # He-init without normalization gives large logit variance, so the CE
    # starts well above ln(10) — just bound it sanely.
    assert np.log(10) / 2 < full < 50.0


def test_one_train_step_reduces_loss():
    params = M.init_params("mobilenetv2l", jax.random.PRNGKey(0))
    opt = T.adam_init(params)
    tpl = D.class_templates(jax.random.PRNGKey(1))
    ds = D.make_dataset(jax.random.PRNGKey(2), 32, tpl)
    l0 = float(T.multi_exit_loss("mobilenetv2l", params, ds.images, ds.labels))
    # several steps on the same batch must overfit it
    for _ in range(10):
        params, opt, loss = T._train_step("mobilenetv2l", params, opt,
                                          ds.images, ds.labels, jnp.float32(3e-3))
    l1 = float(loss)
    assert l1 < l0, f"loss did not decrease: {l0} -> {l1}"


def test_eval_exits_shapes_and_ranges():
    params = M.init_params("resnetl", jax.random.PRNGKey(0))
    tpl = D.class_templates(jax.random.PRNGKey(1))
    ds = D.make_dataset(jax.random.PRNGKey(2), 32, tpl)
    conf, pred, acc = T.eval_exits("resnetl", params, ds, batch=16)
    assert conf.shape == (32, 3) and pred.shape == (32, 3)
    assert np.all((np.asarray(conf) > 0) & (np.asarray(conf) <= 1.0 + 1e-6))
    assert np.all((np.asarray(pred) >= 0) & (np.asarray(pred) < 10))
    assert acc.shape == (3,)


def test_eval_exits_ae_changes_downstream_only():
    """With an AE at exit 1, exit-1 records are unchanged but deeper exits
    see reconstructed features."""
    params = M.init_params("resnetl", jax.random.PRNGKey(0))
    ae = M.init_ae_params(jax.random.PRNGKey(5))
    tpl = D.class_templates(jax.random.PRNGKey(1))
    ds = D.make_dataset(jax.random.PRNGKey(2), 16, tpl)
    conf_a, _, _ = T.eval_exits("resnetl", params, ds, batch=16)
    conf_b, _, _ = T.eval_exits("resnetl", params, ds, ae=ae, batch=16)
    np.testing.assert_allclose(np.asarray(conf_a[:, 0]), np.asarray(conf_b[:, 0]),
                               rtol=1e-6)
    # untrained AE mangles features: deep confidences must differ
    assert not np.allclose(np.asarray(conf_a[:, 1]), np.asarray(conf_b[:, 1]))
