"""L2 correctness: stage partitioning, backend agreement, autoencoder."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module", params=M.model_names())
def model_and_params(request):
    name = request.param
    return name, M.init_params(name, KEY)


def test_stage_shapes_chain(model_and_params):
    """out_shape of τ_k == in_shape of τ_{k+1}; probs is always [10]."""
    name, params = model_and_params
    x = jax.random.normal(KEY, M.INPUT_SHAPE)
    feat = x
    for k in range(1, M.num_stages(name) + 1):
        assert feat.shape == M.stage_input_shape(name, k)
        feat, probs = M.stage_apply(name, params, k, feat)
        assert feat.shape == M.stage_output_shape(name, k)
        assert probs.shape == (M.NUM_CLASSES,)


def test_stage_composition_equals_monolith(model_and_params):
    """Chaining stage_apply == forward_all_logits (the partition is exact)."""
    name, params = model_and_params
    x = jax.random.normal(jax.random.PRNGKey(3), M.INPUT_SHAPE)
    logits = M.forward_all_logits(name, params, x)
    feat = x
    for k in range(1, M.num_stages(name) + 1):
        feat, probs = M.stage_apply(name, params, k, feat)
        # softmax(logits) == stage probs
        want = jax.nn.softmax(logits[k - 1])
        np.testing.assert_allclose(np.asarray(probs), np.asarray(want),
                                   rtol=2e-5, atol=1e-6)


def test_backends_agree(model_and_params):
    """ref (training) and pallas (AOT) backends produce identical stages."""
    name, params = model_and_params
    x = jax.random.normal(jax.random.PRNGKey(5), M.INPUT_SHAPE)
    feat_r, feat_p = x, x
    for k in range(1, M.num_stages(name) + 1):
        feat_r, probs_r = M.stage_apply(name, params, k, feat_r, backend="ref")
        feat_p, probs_p = M.stage_apply(name, params, k, feat_p, backend="pallas")
        np.testing.assert_allclose(np.asarray(feat_p), np.asarray(feat_r),
                                   rtol=5e-5, atol=5e-5)
        np.testing.assert_allclose(np.asarray(probs_p), np.asarray(probs_r),
                                   rtol=5e-5, atol=1e-6)


def test_probs_are_probabilities(model_and_params):
    name, params = model_and_params
    x = jax.random.normal(jax.random.PRNGKey(7), M.INPUT_SHAPE) * 3.0
    feat = x
    for k in range(1, M.num_stages(name) + 1):
        feat, probs = M.stage_apply(name, params, k, feat)
        p = np.asarray(probs)
        assert abs(p.sum() - 1.0) < 1e-5
        assert (p >= 0).all()
        conf = p.max()
        assert 1.0 / M.NUM_CLASSES - 1e-6 <= conf <= 1.0 + 1e-6


def test_exit_counts_match_paper_fig2():
    """Paper Fig. 2: 5 exits for MobileNetV2, 3 for ResNet."""
    assert M.num_stages("mobilenetv2l") == 5
    assert M.num_stages("resnetl") == 3


def test_unknown_model_rejected():
    with pytest.raises(ValueError):
        M.init_params("vgg", KEY)
    with pytest.raises(ValueError):
        M.num_stages("vgg")
    with pytest.raises(ValueError):
        M.get_ops("tensorflow")


def test_autoencoder_roundtrip_shapes():
    ae = M.init_ae_params(KEY)
    f = jax.random.normal(KEY, (32, 32, 32))
    z = M.ae_encode(ae, f)
    assert z.shape == M.AE_CODE_SHAPE
    r = M.ae_decode(ae, z)
    assert r.shape == (32, 32, 32)
    # compression ratio claim (raw/code = 128x)
    assert f.size * 4 // (z.size * 4) == 128


def test_autoencoder_backends_agree():
    ae = M.init_ae_params(jax.random.PRNGKey(2))
    f = jax.random.normal(jax.random.PRNGKey(4), (32, 32, 32))
    z_r = M.ae_encode(ae, f, backend="ref")
    z_p = M.ae_encode(ae, f, backend="pallas")
    np.testing.assert_allclose(np.asarray(z_p), np.asarray(z_r), rtol=5e-5, atol=5e-5)
    r_r = M.ae_decode(ae, z_r, backend="ref")
    r_p = M.ae_decode(ae, z_r, backend="pallas")
    np.testing.assert_allclose(np.asarray(r_p), np.asarray(r_r), rtol=5e-5, atol=5e-5)


def test_residual_connection_active():
    """Inverted-residual skip fires when stride=1 and cin==cout: zeroed
    weights must give identity (plus bias terms = 0)."""
    p = M._init_invres(KEY, 16, 16, 4)
    p = jax.tree_util.tree_map(jnp.zeros_like, p)
    ops = M.get_ops("ref")
    x = jax.random.normal(KEY, (8, 8, 16))
    out = M._invres_block(ops, p, x, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)


def test_dataset_difficulty_drives_noise():
    """Easy samples must be closer to their template than hard ones."""
    tpl = D.class_templates(jax.random.PRNGKey(1))
    ds = D.make_dataset(jax.random.PRNGKey(2), 2048, tpl)
    d = np.asarray(ds.difficulty)
    assert ((0.0 <= d) & (d <= 1.0)).all()
    # noise grows with difficulty: correlate per-sample std-from-template
    imgs = np.asarray(ds.images)
    labels = np.asarray(ds.labels)
    # Per-sample SNR = amp/sig = (1.1-0.9d)/(0.12+0.55d) must fall
    # monotonically in d — the property that makes early exits fire on easy
    # samples only.
    snr = (1.1 - 0.9 * d) / (0.12 + 0.55 * d)
    order = np.argsort(d)
    assert (np.diff(snr[order]) <= 1e-9).all()
    # Total image power is signal-dominated, so it *falls* as the signal
    # fades with difficulty: strong negative correlation confirms the knob
    # reaches the pixels.
    power = np.asarray([imgs[i].std() for i in range(256)])
    corr = np.corrcoef(d[:256], power)[0, 1]
    assert corr < -0.5, f"difficulty knob not reflected in pixels: {corr}"
    # labels span all classes
    assert set(labels.tolist()) == set(range(10))


def test_dataset_quantization_roundtrip():
    tpl = D.class_templates(jax.random.PRNGKey(1))
    ds = D.make_dataset(jax.random.PRNGKey(2), 64, tpl)
    q = D.quantize_u8(ds.images)
    back = D.dequantize_u8(q)
    # quantization step is 8/255 ≈ 0.0314 → max error half a step
    assert np.abs(back - np.asarray(ds.images)).max() <= 8.0 / 255.0 / 2 + 1e-6
