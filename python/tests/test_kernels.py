"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

This is the core signal that makes the training(ref)/AOT(pallas) backend
swap sound: hypothesis sweeps shapes, strides and value ranges and asserts
allclose between `kernels.conv/head` and `kernels.ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as K
from compile.kernels import head as H
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        np.asarray(K.matmul_pallas(x, w)), np.asarray(R.matmul_ref(x, w)), **TOL
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([32, 128, 256]),
    bm=st.sampled_from([16, 32, 128]),
    bn=st.sampled_from([16, 64, 128]),
)
def test_matmul_block_shapes_do_not_change_result(m, bm, bn):
    x = rand(7, (m, 36))
    w = rand(8, (36, 128))
    base = np.asarray(R.matmul_ref(x, w))
    out = np.asarray(K.matmul_pallas(x, w, block_m=bm, block_n=bn))
    np.testing.assert_allclose(out, base, **TOL)


def test_matmul_rejects_contraction_mismatch():
    with pytest.raises(AssertionError):
        K.matmul_pallas(jnp.zeros((2, 3)), jnp.zeros((4, 2)))


def test_matmul_accumulates_in_f32():
    # large-k accumulation should not collapse: compare vs float64 numpy
    x = rand(3, (8, 512), scale=0.5)
    w = rand(4, (512, 8), scale=0.5)
    exact = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
    out = np.asarray(K.matmul_pallas(x, w))
    np.testing.assert_allclose(out, exact, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# conv2d (im2col + MXU matmul)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(4, 20),
    w=st.integers(4, 20),
    cin=st.sampled_from([1, 3, 8, 17]),
    cout=st.sampled_from([1, 4, 10]),
    stride=st.sampled_from([1, 2]),
    kh=st.sampled_from([1, 3]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_matches_ref(h, w, cin, cout, stride, kh, seed):
    x = rand(seed, (h, w, cin))
    f = rand(seed + 1, (kh, kh, cin, cout))
    out = K.conv2d_pallas(x, f, stride)
    refv = R.conv2d_ref(x, f, stride)
    assert out.shape == refv.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(refv), **TOL)


def test_conv2d_matches_lax_conv():
    # cross-check the ref itself against lax.conv_general_dilated
    x = rand(11, (16, 16, 8))
    f = rand(12, (3, 3, 8, 12))
    ours = R.conv2d_ref(x, f, 1)
    lax_out = jax.lax.conv_general_dilated(
        x[None], f, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )[0]
    np.testing.assert_allclose(np.asarray(ours), np.asarray(lax_out), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(4, 24),
    stride=st.sampled_from([1, 2]),
)
def test_conv2d_output_shape(h, stride):
    x = jnp.zeros((h, h, 3))
    f = jnp.zeros((3, 3, 3, 5))
    oh = (h + stride - 1) // stride
    assert K.conv2d_pallas(x, f, stride).shape == (oh, oh, 5)


# ---------------------------------------------------------------------------
# depthwise 3x3
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(3, 20),
    c=st.sampled_from([1, 2, 8, 24, 33]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_depthwise_matches_ref(h, c, stride, seed):
    x = rand(seed, (h, h, c))
    f = rand(seed + 1, (3, 3, c))
    out = K.depthwise3x3_pallas(x, f, stride)
    refv = R.depthwise3x3_ref(x, f, stride)
    assert out.shape == refv.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(refv), **TOL)


@settings(max_examples=8, deadline=None)
@given(bc=st.sampled_from([1, 4, 16, 128]))
def test_depthwise_channel_blocking_invariant(bc):
    x = rand(5, (10, 10, 32))
    f = rand(6, (3, 3, 32))
    base = np.asarray(R.depthwise3x3_ref(x, f, 1))
    out = np.asarray(K.depthwise3x3_pallas(x, f, 1, block_c=bc))
    np.testing.assert_allclose(out, base, **TOL)


def test_depthwise_identity_filter():
    # center-tap filter = identity
    x = rand(9, (8, 8, 4))
    f = jnp.zeros((3, 3, 4)).at[1, 1, :].set(1.0)
    np.testing.assert_allclose(
        np.asarray(K.depthwise3x3_pallas(x, f, 1)), np.asarray(x), **TOL
    )


# ---------------------------------------------------------------------------
# pointwise / dense / head
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 16),
    cin=st.sampled_from([1, 8, 24]),
    cout=st.sampled_from([1, 16, 96]),
    seed=st.integers(0, 2**16),
)
def test_pointwise_matches_ref(h, cin, cout, seed):
    x = rand(seed, (h, h, cin))
    w = rand(seed + 1, (cin, cout))
    np.testing.assert_allclose(
        np.asarray(K.pointwise_pallas(x, w)), np.asarray(R.pointwise_ref(x, w)), **TOL
    )


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(1, 128),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**16),
)
def test_dense_matches_ref(k, n, seed):
    x = rand(seed, (k,))
    w = rand(seed + 1, (k, n))
    b = rand(seed + 2, (n,))
    np.testing.assert_allclose(
        np.asarray(H.dense_pallas(x, w, b)), np.asarray(R.dense_ref(x, w, b)), **TOL
    )


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(1, 16),
    c=st.sampled_from([1, 8, 24, 128]),
    scale=st.sampled_from([0.1, 1.0, 20.0]),  # large logits: softmax stability
    seed=st.integers(0, 2**16),
)
def test_head_matches_ref(h, c, scale, seed):
    x = rand(seed, (h, h, c), scale)
    w = rand(seed + 1, (c, 10), scale)
    b = rand(seed + 2, (10,))
    out = np.asarray(H.head_pallas(x, w, b))
    refv = np.asarray(R.head_ref(x, w, b))
    np.testing.assert_allclose(out, refv, rtol=2e-5, atol=1e-6)
    # eq. (1): a probability vector
    assert abs(out.sum() - 1.0) < 1e-5
    assert (out >= 0).all()


def test_head_confidence_bounds():
    # eq. (2): confidence = max prob is in [1/v, 1]
    x = rand(1, (4, 4, 8))
    w = rand(2, (8, 10))
    b = jnp.zeros((10,))
    conf = float(jnp.max(H.head_pallas(x, w, b)))
    assert 0.1 - 1e-6 <= conf <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# VMEM audit helpers (the L1 perf contract of DESIGN.md §8)
# ---------------------------------------------------------------------------

def test_vmem_footprints_under_budget():
    budget = 16 * 1024 * 1024
    # worst shapes in either model
    assert K.vmem_footprint_matmul(32 * 32, 9 * 128, 128) < budget
    assert K.vmem_footprint_depthwise(32, 32, 384) < budget
    from compile.kernels.head import vmem_footprint_head
    assert vmem_footprint_head(32, 32, 128, 10) < budget


def test_pick_block_divides():
    for dim in [1, 7, 32, 100, 256, 1000]:
        for target in [1, 16, 128]:
            b = K._pick_block(dim, target)
            assert dim % b == 0 and 1 <= b <= max(dim, target)


def test_mxu_efficiency_bounds_and_alignment():
    # perfectly aligned shapes reach 1.0
    assert K.mxu_efficiency(8, 128, 128) == 1.0
    assert K.mxu_efficiency(256, 256, 128) == 1.0
    # misaligned shapes pay padding
    assert K.mxu_efficiency(1, 1, 1) == pytest.approx(1 / (8 * 128 * 128))
    for m, k, n in [(100, 27, 16), (1024, 216, 24), (64, 864, 96)]:
        e = K.mxu_efficiency(m, k, n)
        assert 0.0 < e <= 1.0
