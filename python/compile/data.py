"""Synthetic CIFAR-like dataset with a controlled difficulty spectrum.

Substitution (DESIGN.md §1): the paper uses CIFAR-10 test images. Early-exit
dynamics depend on *confidence heterogeneity* — some inputs are easy enough
for exit 1, some need the full depth ("network overthinking", paper §I).
We reproduce that property by construction:

* each of the 10 classes is a fixed smooth template (low-frequency pattern
  upsampled from an 8x8 seed),
* each sample mixes its class template with Gaussian noise according to a
  per-sample difficulty d ∈ [0,1]: easy samples (low d) are high-SNR and
  classifiable by shallow exits; hard samples (high d) need depth or are
  never classified correctly,
* a random spatial roll adds pose variation so exits cannot memorise pixels.

The difficulty value is recorded per sample and shipped in dataset.bin so
the Rust side can stratify metrics by difficulty.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

IMG_H, IMG_W, IMG_C = 32, 32, 3
NUM_CLASSES = 10
_EASY_FRAC = 0.6  # fraction of samples drawn from the easy difficulty band


@dataclasses.dataclass
class Dataset:
    images: jax.Array      # [n, 32, 32, 3] f32
    labels: jax.Array      # [n] i32
    difficulty: jax.Array  # [n] f32 in [0, 1]


def class_templates(key: jax.Array) -> jax.Array:
    """[10, 32, 32, 3] smooth unit-std class patterns."""
    seeds = jax.random.normal(key, (NUM_CLASSES, 8, 8, IMG_C))
    t = jax.image.resize(seeds, (NUM_CLASSES, IMG_H, IMG_W, IMG_C), "cubic")
    t = t - jnp.mean(t, axis=(1, 2, 3), keepdims=True)
    t = t / (jnp.std(t, axis=(1, 2, 3), keepdims=True) + 1e-8)
    return t.astype(jnp.float32)


def _sample_difficulty(key: jax.Array, n: int) -> jax.Array:
    """Bimodal difficulty: 60% easy U(0, .45), 40% hard U(.45, 1)."""
    k1, k2, k3 = jax.random.split(key, 3)
    easy = jax.random.uniform(k1, (n,), minval=0.0, maxval=0.45)
    hard = jax.random.uniform(k2, (n,), minval=0.45, maxval=1.0)
    pick = jax.random.uniform(k3, (n,)) < _EASY_FRAC
    return jnp.where(pick, easy, hard)


def make_dataset(key: jax.Array, n: int, templates: jax.Array) -> Dataset:
    """Draw n labelled samples from the synthetic distribution."""
    ky, kd, kn, kr = jax.random.split(key, 4)
    labels = jax.random.randint(ky, (n,), 0, NUM_CLASSES)
    diff = _sample_difficulty(kd, n)
    noise = jax.random.normal(kn, (n, IMG_H, IMG_W, IMG_C))
    signal = templates[labels]                       # [n, 32, 32, 3]
    amp = (1.1 - 0.9 * diff)[:, None, None, None]    # signal fades with d
    sig = (0.12 + 0.55 * diff)[:, None, None, None]  # noise grows with d
    imgs = signal * amp + noise * sig
    # pose variation: independent per-sample circular shifts in [-3, 3]
    shifts = jax.random.randint(kr, (n, 2), -3, 4)

    def roll(img, sh):
        return jnp.roll(img, shift=(sh[0], sh[1]), axis=(0, 1))

    imgs = jax.vmap(roll)(imgs, shifts)
    imgs = jnp.clip(imgs, -4.0, 4.0).astype(jnp.float32)
    return Dataset(images=imgs, labels=labels.astype(jnp.int32),
                   difficulty=diff.astype(jnp.float32))


def quantize_u8(images: jax.Array) -> np.ndarray:
    """f32 [-4,4] -> u8 for dataset.bin (Rust dequantizes: x/255*8-4)."""
    q = jnp.clip((images + 4.0) / 8.0 * 255.0, 0.0, 255.0)
    return np.asarray(jnp.round(q), dtype=np.uint8)


def dequantize_u8(q: np.ndarray) -> np.ndarray:
    """Inverse of quantize_u8 — must match rust/src/dataset exactly."""
    return q.astype(np.float32) / 255.0 * 8.0 - 4.0


DATASET_MAGIC = 0x4D444945  # "MDIE"


def write_dataset_bin(path: str, ds: Dataset) -> None:
    """Serialize the held-out test set for the Rust source worker.

    Layout (little-endian):
      u32 magic | u32 version=1 | u32 n | u32 h | u32 w | u32 c
      n*h*w*c   u8 quantized pixels
      n         u8 labels
      n         f32 difficulty
    """
    imgs = quantize_u8(ds.images)
    n, h, w, c = imgs.shape
    with open(path, "wb") as f:
        hdr = np.array([DATASET_MAGIC, 1, n, h, w, c], dtype=np.uint32)
        f.write(hdr.tobytes())
        f.write(imgs.tobytes())
        f.write(np.asarray(ds.labels, dtype=np.uint8).tobytes())
        f.write(np.asarray(ds.difficulty, dtype=np.float32).tobytes())
