"""Build-time training for the multi-exit models (L2).

The paper assumes pre-trained MobileNetV2/ResNet-50 with internal
classifiers (BranchyNet-style).  We have no model zoo in this image, so we
train the Lite variants here, once, at `make artifacts` time; parameters are
cached under artifacts/cache/ so rebuilds are no-ops.

Joint multi-exit objective (BranchyNet [4] / Shallow-Deep [3]):
    L = Σ_k w_k · CE(exit_k logits, y)
with mildly increasing weights so deep exits dominate but shallow exits
still learn usable classifiers.

The autoencoder (paper §V) is trained *after* the trunk, frozen-feature
reconstruction (MSE on stage-1 features), which mirrors the paper's
post-hoc insertion of the AE at ResNet's first exit boundary.

No optax in this image: Adam is implemented inline on pytrees.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from . import data as D
from . import model as M

EXIT_WEIGHTS = {
    "mobilenetv2l": jnp.array([0.6, 0.7, 0.8, 0.9, 1.0]),
    "resnetl": jnp.array([0.7, 0.85, 1.0]),
}


# ---------------------------------------------------------------------------
# Adam on pytrees (optax substitute — offline image, DESIGN.md §1)
# ---------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=2e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) /
        (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------

def _ce(logits, y):
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    return jnp.mean(lse - jnp.take_along_axis(logits, y[:, None], 1)[:, 0])


def multi_exit_loss(name, params, xb, yb):
    logits = jax.vmap(lambda x: M.forward_all_logits(name, params, x))(xb)
    w = EXIT_WEIGHTS[name]
    losses = jnp.stack([_ce(lg, yb) for lg in logits])
    return jnp.sum(w * losses) / jnp.sum(w)


@functools.partial(jax.jit, static_argnums=0)
def _train_step(name, params, opt, xb, yb, lr):
    loss, grads = jax.value_and_grad(lambda p: multi_exit_loss(name, p, xb, yb))(params)
    params, opt = adam_update(params, grads, opt, lr=lr)
    return params, opt, loss


def train_model(name: str, key: jax.Array, steps: int = 500,
                batch: int = 128, lr: float = 2e-3, log=print,
                templates: jax.Array | None = None) -> dict:
    """Train a multi-exit model on the synthetic distribution; return params.

    `templates` defaults to the canonical derivation (class_templates of the
    first split of `key`) — aot.py derives the *same* templates for the
    held-out test set, so train and test share one distribution.
    """
    ktpl, kinit, kdata = jax.random.split(key, 3)
    if templates is None:
        templates = D.class_templates(ktpl)
    params = M.init_params(name, kinit)
    opt = adam_init(params)
    t0 = time.time()
    for step in range(steps):
        kdata, kb = jax.random.split(kdata)
        ds = D.make_dataset(kb, batch, templates)
        # cosine decay keeps late exits from oscillating once shallow heads saturate
        cur_lr = lr * 0.5 * (1 + jnp.cos(jnp.pi * step / steps))
        params, opt, loss = _train_step(name, params, opt, ds.images,
                                        ds.labels, cur_lr)
        if step % 100 == 0 or step == steps - 1:
            log(f"[train {name}] step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")
    return params


# ---------------------------------------------------------------------------
# Autoencoder training (frozen trunk features)
# ---------------------------------------------------------------------------

@jax.jit
def _ae_step(ae, opt, feats, lr):
    def loss_fn(p):
        rec = jax.vmap(lambda f: M.ae_decode(p, M.ae_encode(p, f)))(feats)
        return jnp.mean((rec - feats) ** 2)
    loss, grads = jax.value_and_grad(loss_fn)(ae)
    ae, opt = adam_update(ae, grads, opt, lr=lr)
    return ae, opt, loss


def train_autoencoder(params_resnet: dict, key: jax.Array, steps: int = 300,
                      batch: int = 64, lr: float = 2e-3, log=print,
                      templates: jax.Array | None = None) -> dict:
    """Train the stage-1-boundary AE on frozen ResNet-Lite features.

    Pass the same `templates` the trunk was trained on so the AE sees the
    deployment feature distribution.
    """
    ktpl, kinit, kdata = jax.random.split(key, 3)
    if templates is None:
        templates = D.class_templates(ktpl)
    ae = M.init_ae_params(kinit)
    opt = adam_init(ae)
    stage1 = jax.jit(jax.vmap(
        lambda x: M.stage_apply("resnetl", params_resnet, 1, x)[0]))
    t0 = time.time()
    for step in range(steps):
        kdata, kb = jax.random.split(kdata)
        ds = D.make_dataset(kb, batch, templates)
        feats = stage1(ds.images)
        ae, opt, loss = _ae_step(ae, opt, feats, lr)
        if step % 100 == 0 or step == steps - 1:
            log(f"[train ae] step {step:4d} mse {float(loss):.5f} "
                f"({time.time() - t0:.1f}s)")
    return ae


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

def eval_exits(name: str, params: dict, ds: D.Dataset, ae: dict | None = None,
               batch: int = 256):
    """Per-sample, per-exit (confidence, prediction) tables + accuracies.

    Runs the staged forward on the held-out set. When `ae` is given (resnetl)
    stage 2 consumes decode(encode(stage-1 features)) so the recorded deep-exit
    behaviour includes the AE's reconstruction error, exactly like the wire
    path in the Rust runtime.  Returns (conf [n,K], pred [n,K], acc [K]).
    """
    ks = M.num_stages(name)

    @jax.jit
    def batch_eval(xb):
        def one(x):
            feat = x
            confs, preds = [], []
            for k in range(1, ks + 1):
                feat, probs = M.stage_apply(name, params, k, feat)
                confs.append(jnp.max(probs))
                preds.append(jnp.argmax(probs))
                if ae is not None and k == 1:
                    feat = M.ae_decode(ae, M.ae_encode(ae, feat))
            return jnp.stack(confs), jnp.stack(preds)
        return jax.vmap(one)(xb)

    n = ds.images.shape[0]
    confs, preds = [], []
    for i in range(0, n, batch):
        c, p = batch_eval(ds.images[i:i + batch])
        confs.append(c)
        preds.append(p)
    conf = jnp.concatenate(confs)         # [n, K]
    pred = jnp.concatenate(preds)         # [n, K]
    acc = jnp.mean(pred == ds.labels[:, None], axis=0)  # [K]
    return conf, pred, acc
