"""L2: multi-exit CNN models, partitioned at the paper's exit points.

Two model families mirror the paper's Fig. 2:

* **mobilenetv2l** — MobileNetV2-style inverted-residual trunk with **5 exit
  points** (the paper puts 5 exits in MobileNetV2),
* **resnetl** — ResNet-style residual trunk with **3 exit points** plus a
  bottleneck **autoencoder** at the first exit boundary (the paper adds an
  AE after ResNet-50's first exit to shrink the 3.2 MB feature vector).

Both are "Lite" variants scaled for the CPU testbed (DESIGN.md §1); the
partition structure (task k = layers between exit k-1 and exit k, paper
§III "Model Partitioning") is exactly the paper's.

Everything is functional: params are nested dicts of arrays; stage_apply
computes task τ_k.  `backend="ref"` uses the pure-jnp oracles (training,
differentiable); `backend="pallas"` uses the L1 Pallas kernels (AOT
lowering).  test_model.py asserts the two backends agree and that chained
stages equal the monolithic forward.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import conv as kconv
from .kernels import head as khead
from .kernels import ref as kref

NUM_CLASSES = 10
INPUT_SHAPE = (32, 32, 3)


@dataclasses.dataclass(frozen=True)
class Ops:
    """Backend dispatch table (ref oracles vs Pallas kernels)."""
    conv2d: Callable
    pointwise: Callable
    depthwise: Callable
    head: Callable


def get_ops(backend: str) -> Ops:
    if backend == "ref":
        return Ops(conv2d=kref.conv2d_ref, pointwise=kref.pointwise_ref,
                   depthwise=kref.depthwise3x3_ref, head=kref.head_ref)
    if backend == "pallas":
        return Ops(conv2d=kconv.conv2d_pallas, pointwise=kconv.pointwise_pallas,
                   depthwise=kconv.depthwise3x3_pallas, head=khead.head_pallas)
    raise ValueError(f"unknown backend {backend!r}")


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def _init_conv(key, kh, kw, cin, cout):
    kw_, kb_ = jax.random.split(key)
    return {"w": _he(kw_, (kh, kw, cin, cout), kh * kw * cin),
            "b": jnp.zeros((cout,), jnp.float32)}


def _init_pw(key, cin, cout):
    return {"w": _he(key, (cin, cout), cin), "b": jnp.zeros((cout,), jnp.float32)}


def _init_dw(key, c):
    return {"w": _he(key, (3, 3, c), 9), "b": jnp.zeros((c,), jnp.float32)}


def _init_head(key, c):
    return {"w": _he(key, (c, NUM_CLASSES), c),
            "b": jnp.zeros((NUM_CLASSES,), jnp.float32)}


def _init_invres(key, cin, cout, expand):
    k1, k2, k3 = jax.random.split(key, 3)
    mid = cin * expand
    return {"pw1": _init_pw(k1, cin, mid), "dw": _init_dw(k2, mid),
            "pw2": _init_pw(k3, mid, cout)}


def _init_basic(key, cin, cout, stride):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"c1": _init_conv(k1, 3, 3, cin, cout), "c2": _init_conv(k2, 3, 3, cout, cout)}
    if stride != 1 or cin != cout:
        p["sc"] = _init_conv(k3, 1, 1, cin, cout)
    return p


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _conv_block(ops: Ops, p, x, stride=1, act=kref.relu):
    return act(ops.conv2d(x, p["w"], stride) + p["b"])


def _invres_block(ops: Ops, p, x, stride):
    """MobileNetV2 inverted residual: expand 1x1 -> depthwise 3x3 -> project 1x1."""
    h = kref.relu6(ops.pointwise(x, p["pw1"]["w"]) + p["pw1"]["b"])
    h = kref.relu6(ops.depthwise(h, p["dw"]["w"], stride) + p["dw"]["b"])
    h = ops.pointwise(h, p["pw2"]["w"]) + p["pw2"]["b"]  # linear bottleneck
    if stride == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    return h


def _basic_block(ops: Ops, p, x, stride):
    """ResNet basic block with projection shortcut when shape changes."""
    h = kref.relu(ops.conv2d(x, p["c1"]["w"], stride) + p["c1"]["b"])
    h = ops.conv2d(h, p["c2"]["w"], 1) + p["c2"]["b"]
    sc = x if "sc" not in p else ops.conv2d(x, p["sc"]["w"], stride) + p["sc"]["b"]
    return kref.relu(h + sc)


def _head_logits(p, x):
    """Training-path head (GAP -> dense, no softmax; CE wants logits)."""
    gap = jnp.mean(x, axis=(0, 1))
    return kref.dense_ref(gap, p["w"], p["b"])


# ---------------------------------------------------------------------------
# Model definitions
# ---------------------------------------------------------------------------
# Stage layout tables: (stage builder, exit-head input channels, feature shape
# entering the stage). Stage k implements task τ_k of the paper.

MOBILENET_STAGES = [
    # (name, feature shape INTO the stage)
    ("m1", (32, 32, 3)),
    ("m2", (16, 16, 24)),
    ("m3", (16, 16, 32)),
    ("m4", (8, 8, 48)),
    ("m5", (8, 8, 64)),
]
MOBILENET_OUT = [(16, 16, 24), (16, 16, 32), (8, 8, 48), (8, 8, 64), (4, 4, 128)]

RESNET_STAGES = [
    ("r1", (32, 32, 3)),
    ("r2", (32, 32, 32)),
    ("r3", (16, 16, 64)),
]
RESNET_OUT = [(32, 32, 32), (16, 16, 64), (8, 8, 128)]

AE_CODE_SHAPE = (8, 8, 4)  # 1 KiB f32 code vs 128 KiB raw stage-1 features


def model_names():
    return ["mobilenetv2l", "resnetl"]


def num_stages(name: str) -> int:
    try:
        return {"mobilenetv2l": 5, "resnetl": 3}[name]
    except KeyError:
        raise ValueError(f"unknown model {name!r}") from None


def stage_input_shape(name: str, k: int):
    """Shape of the feature tensor entering stage k (1-based)."""
    tbl = MOBILENET_STAGES if name == "mobilenetv2l" else RESNET_STAGES
    return tbl[k - 1][1]


def stage_output_shape(name: str, k: int):
    tbl = MOBILENET_OUT if name == "mobilenetv2l" else RESNET_OUT
    return tbl[k - 1]


def init_params(name: str, key: jax.Array) -> dict:
    ks = jax.random.split(key, 24)
    if name == "mobilenetv2l":
        return {
            "s1": {"stem": _init_conv(ks[0], 3, 3, 3, 16),
                   "b1": _init_invres(ks[1], 16, 24, 4),
                   "head": _init_head(ks[2], 24)},
            "s2": {"b1": _init_invres(ks[3], 24, 32, 4),
                   "head": _init_head(ks[4], 32)},
            "s3": {"b1": _init_invres(ks[5], 32, 48, 4),
                   "head": _init_head(ks[6], 48)},
            "s4": {"b1": _init_invres(ks[7], 48, 64, 4),
                   "head": _init_head(ks[8], 64)},
            "s5": {"b1": _init_invres(ks[9], 64, 96, 4),
                   "pw": _init_pw(ks[10], 96, 128),
                   "head": _init_head(ks[11], 128)},
        }
    if name == "resnetl":
        return {
            "s1": {"stem": _init_conv(ks[0], 3, 3, 3, 32),
                   "b1": _init_basic(ks[1], 32, 32, 1),
                   "head": _init_head(ks[3], 32)},
            "s2": {"b1": _init_basic(ks[4], 32, 32, 1),
                   "b2": _init_basic(ks[5], 32, 64, 2),
                   "head": _init_head(ks[6], 64)},
            "s3": {"b1": _init_basic(ks[7], 64, 64, 1),
                   "b2": _init_basic(ks[8], 64, 128, 2),
                   "b3": _init_basic(ks[9], 128, 128, 1),
                   "head": _init_head(ks[10], 128)},
        }
    raise ValueError(f"unknown model {name!r}")


# ---------------------------------------------------------------------------
# Stage application (task τ_k): features in -> (features out, exit output)
# ---------------------------------------------------------------------------

def _stage_trunk(name: str, params: dict, k: int, x: jax.Array, ops: Ops):
    s = params[f"s{k}"]
    if name == "mobilenetv2l":
        if k == 1:
            # stride-2 stem: keeps task 1 (which can never be offloaded —
            # the source must run it) comparable in cost to later tasks,
            # matching the paper's balanced exit placement (footnote 1).
            h = _conv_block(ops, s["stem"], x, 2, kref.relu6)
            return _invres_block(ops, s["b1"], h, 1)
        if k == 2:
            return _invres_block(ops, s["b1"], x, 1)
        if k == 3:
            return _invres_block(ops, s["b1"], x, 2)
        if k == 4:
            return _invres_block(ops, s["b1"], x, 1)
        if k == 5:
            h = _invres_block(ops, s["b1"], x, 2)
            return kref.relu6(ops.pointwise(h, s["pw"]["w"]) + s["pw"]["b"])
    if name == "resnetl":
        if k == 1:
            h = _conv_block(ops, s["stem"], x, 1, kref.relu)
            return _basic_block(ops, s["b1"], h, 1)
        if k == 2:
            h = _basic_block(ops, s["b1"], x, 1)
            return _basic_block(ops, s["b2"], h, 2)
        h = _basic_block(ops, s["b1"], x, 1)
        h = _basic_block(ops, s["b2"], h, 2)
        return _basic_block(ops, s["b3"], h, 1)
    raise ValueError(f"bad model/stage {name}/{k}")


def stage_apply(name: str, params: dict, k: int, x: jax.Array,
                backend: str = "ref"):
    """Task τ_k: [H,W,C] features -> (next features, exit-k probabilities).

    This is exactly what a worker executes in Algorithm 1 line 3-4: process
    the layers of task k, then feed the exit classifier.  The probabilities
    (eq. (1)) come back alongside the features; the Rust worker takes
    max(probs) as the confidence level C_k(d) (eq. (2)).
    """
    ops = get_ops(backend)
    feat = _stage_trunk(name, params, k, x, ops)
    probs = ops.head(feat, params[f"s{k}"]["head"]["w"], params[f"s{k}"]["head"]["b"])
    return feat, probs


def stage_logits(name: str, params: dict, k: int, x: jax.Array):
    """Training path: trunk + head logits (ref backend, differentiable)."""
    ops = get_ops("ref")
    feat = _stage_trunk(name, params, k, x, ops)
    return feat, _head_logits(params[f"s{k}"]["head"], feat)


def forward_all_logits(name: str, params: dict, x: jax.Array):
    """Monolithic forward returning every exit's logits (for the joint loss)."""
    logits = []
    feat = x
    for k in range(1, num_stages(name) + 1):
        feat, lg = stage_logits(name, params, k, feat)
        logits.append(lg)
    return logits


# ---------------------------------------------------------------------------
# Autoencoder at the ResNet stage-1 boundary (paper §V)
# ---------------------------------------------------------------------------

def init_ae_params(key: jax.Array) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "enc1": _init_conv(k1, 3, 3, 32, 8),   # 32x32x32 -> 16x16x8 (s2)
        "enc2": _init_conv(k2, 3, 3, 8, 4),    # -> 8x8x4 code (1 KiB)
        "dec1": _init_conv(k3, 3, 3, 4, 16),   # 8x8 -> upsample -> 16x16
        "dec2": _init_conv(k4, 3, 3, 16, 32),  # 16x16 -> upsample -> 32x32
    }


def ae_encode(p: dict, x: jax.Array, backend: str = "ref") -> jax.Array:
    """[32,32,32] stage-1 features -> [8,8,4] code. Two conv+ReLU (paper §V)."""
    ops = get_ops(backend)
    h = kref.relu(ops.conv2d(x, p["enc1"]["w"], 2) + p["enc1"]["b"])
    return kref.relu(ops.conv2d(h, p["enc2"]["w"], 2) + p["enc2"]["b"])


def _upsample2(x: jax.Array) -> jax.Array:
    return jnp.repeat(jnp.repeat(x, 2, axis=0), 2, axis=1)


def ae_decode(p: dict, z: jax.Array, backend: str = "ref") -> jax.Array:
    """[8,8,4] code -> [32,32,32] reconstructed stage-1 features."""
    ops = get_ops(backend)
    h = kref.relu(ops.conv2d(_upsample2(z), p["dec1"]["w"], 1) + p["dec1"]["b"])
    return ops.conv2d(_upsample2(h), p["dec2"]["w"], 1) + p["dec2"]["b"]
