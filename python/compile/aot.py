"""AOT pipeline: train -> partition -> lower to HLO text -> emit artifacts.

This is the single build-time entry point (`make artifacts`).  It produces
everything the Rust runtime consumes; after it runs, Python is never needed
again (DESIGN.md: Python is never on the request path).

Outputs under --out (default ../artifacts):
  manifest.json                     index of everything below
  dataset.bin                       held-out test set (source worker input)
  <model>/stage<k>.hlo.txt          task τ_k as HLO text: feat -> (feat', probs)
  resnetl/ae_enc.hlo.txt, ae_dec.hlo.txt
  exits_<model>.bin                 per-sample per-exit (confidence, prediction)
  exits_resnetl_ae.bin              same, with the AE on the stage-1 boundary
  cache/params_<model>.npz          trained parameters (makes rebuilds no-ops)

Interchange format is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import train as T
from .kernels import conv as kconv
from .kernels import head as khead

SEED = 20240710          # fixed: artifacts are reproducible bit-for-bit
TEST_N = 4096
EXITS_MAGIC = 0x4D444958  # "MDIX"
CONF_THRESHOLDS = [0.5, 0.6, 0.7, 0.8, 0.9, 0.95]


# ---------------------------------------------------------------------------
# HLO text emission
# ---------------------------------------------------------------------------

def lower_to_hlo_text(fn, *arg_specs) -> str:
    """jit(fn).lower(specs) -> stablehlo -> XlaComputation -> HLO text.

    Two print options are load-bearing (found the hard way; the Rust side
    cross-checks exact predictions in rust/tests/integration_xla.rs):

    * ``print_large_constants=True`` — the default printer elides big
      weight constants as ``{...}``, which XLA's text *parser* silently
      zero-fills: every trained parameter would become 0 on the Rust side.
    * ``print_metadata=False`` — jax emits ``source_end_line`` metadata that
      xla_extension 0.5.1's parser rejects outright.
    """
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def emit_stage_hlo(name: str, params: dict, k: int, out_path: str) -> int:
    """Lower task τ_k (Pallas backend) to HLO text; returns file size."""
    in_shape = M.stage_input_shape(name, k)
    spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)

    def stage(x):
        feat, probs = M.stage_apply(name, params, k, x, backend="pallas")
        return feat, probs

    text = lower_to_hlo_text(stage, spec)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)


def emit_ae_hlo(ae: dict, out_dir: str) -> dict:
    enc_spec = jax.ShapeDtypeStruct((32, 32, 32), jnp.float32)
    dec_spec = jax.ShapeDtypeStruct(M.AE_CODE_SHAPE, jnp.float32)
    enc_path = os.path.join(out_dir, "ae_enc.hlo.txt")
    dec_path = os.path.join(out_dir, "ae_dec.hlo.txt")
    with open(enc_path, "w") as f:
        f.write(lower_to_hlo_text(
            lambda x: (M.ae_encode(ae, x, backend="pallas"),), enc_spec))
    with open(dec_path, "w") as f:
        f.write(lower_to_hlo_text(
            lambda z: (M.ae_decode(ae, z, backend="pallas"),), dec_spec))
    return {"enc_hlo": "resnetl/ae_enc.hlo.txt",
            "dec_hlo": "resnetl/ae_dec.hlo.txt"}


# ---------------------------------------------------------------------------
# Parameter cache
# ---------------------------------------------------------------------------

def _flatten(d: dict, prefix=""):
    for key, val in d.items():
        path = f"{prefix}/{key}" if prefix else key
        if isinstance(val, dict):
            yield from _flatten(val, path)
        else:
            yield path, np.asarray(val)


def _unflatten(flat: dict) -> dict:
    out: dict = {}
    for path, val in flat.items():
        parts = path.split("/")
        node = out
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = jnp.asarray(val)
    return out


def save_params(path: str, params: dict) -> None:
    np.savez(path, **dict(_flatten(params)))


def load_params(path: str) -> dict:
    with np.load(path) as z:
        return _unflatten({key: z[key] for key in z.files})


# ---------------------------------------------------------------------------
# Measurements for the manifest
# ---------------------------------------------------------------------------

def measure_stage_cost_ms(name: str, params: dict, k: int, iters=30) -> float:
    """Median wallclock of the compiled (Pallas-backend) stage, batch 1.

    This is what the Rust runtime will pay per task on this machine; simnet
    divides it by per-worker speed factors to recreate Jetson heterogeneity.
    """
    fn = jax.jit(lambda x: M.stage_apply(name, params, k, x, backend="pallas"))
    x = jnp.zeros(M.stage_input_shape(name, k), jnp.float32)
    jax.block_until_ready(fn(x))  # compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e3)


def measure_fn_cost_ms(fn, x, iters=30) -> float:
    jfn = jax.jit(fn)
    jax.block_until_ready(jfn(x))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e3)


def write_exits_bin(path: str, conf: np.ndarray, pred: np.ndarray) -> None:
    """Per-sample per-exit oracle table for the Rust SimEngine.

    Layout: u32 magic | u32 version=1 | u32 n | u32 K
            n*K f32 confidence (row-major, sample-major)
            n*K u8  predicted class
    """
    n, k = conf.shape
    with open(path, "wb") as f:
        f.write(np.array([EXITS_MAGIC, 1, n, k], dtype=np.uint32).tobytes())
        f.write(conf.astype(np.float32).tobytes())
        f.write(pred.astype(np.uint8).tobytes())


def exit_rates(conf: np.ndarray, thresholds) -> dict:
    """Fraction of samples that would exit at each point per threshold
    (first exit whose confidence clears T_e; last exit absorbs the rest)."""
    n, k = conf.shape
    out = {}
    for t in thresholds:
        taken = np.zeros(k)
        remaining = np.ones(n, dtype=bool)
        for j in range(k - 1):
            hit = remaining & (conf[:, j] > t)
            taken[j] = hit.sum()
            remaining &= ~hit
        taken[k - 1] = remaining.sum()
        out[str(t)] = (taken / n).round(4).tolist()
    return out


def vmem_audit(name: str) -> list:
    """Static L1 perf audit: worst-case VMEM bytes + MXU utilization
    estimates per stage (DESIGN.md §8 / EXPERIMENTS.md §Perf)."""
    rows = []
    for k in range(1, M.num_stages(name) + 1):
        h, w, c = M.stage_output_shape(name, k)
        rows.append({
            "stage": k,
            "head_vmem_bytes": khead.vmem_footprint_head(h, w, c, M.NUM_CLASSES),
            "matmul_vmem_bytes": kconv.vmem_footprint_matmul(h * w, 9 * c, c),
            "depthwise_vmem_bytes": kconv.vmem_footprint_depthwise(h, w, c),
            # main conv contraction of the stage, as the MXU sees it
            "mxu_efficiency": round(kconv.mxu_efficiency(h * w, 9 * c, c), 4),
        })
    return rows


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def canonical_templates():
    """The one template set shared by training, the AE, and the test set.

    Must match train.train_model's internal derivation (first split of the
    training key) so cached parameters remain valid across rebuilds.
    """
    ktpl = jax.random.split(jax.random.PRNGKey(SEED), 3)[0]
    return D.class_templates(ktpl)


def build_model(name: str, out_dir: str, cache_dir: str, steps: int,
                ds_test: D.Dataset, templates, force: bool, log=print) -> dict:
    cache = os.path.join(cache_dir, f"params_{name}.npz")
    if os.path.exists(cache) and not force:
        log(f"[aot] {name}: cached params {cache}")
        params = load_params(cache)
    else:
        params = T.train_model(name, jax.random.PRNGKey(SEED), steps=steps,
                               log=log, templates=templates)
        save_params(cache, params)

    model_dir = os.path.join(out_dir, name)
    os.makedirs(model_dir, exist_ok=True)

    stages = []
    for k in range(1, M.num_stages(name) + 1):
        hlo_rel = f"{name}/stage{k}.hlo.txt"
        size = emit_stage_hlo(name, params, k, os.path.join(out_dir, hlo_rel))
        in_shape = M.stage_input_shape(name, k)
        out_shape = M.stage_output_shape(name, k)
        cost = measure_stage_cost_ms(name, params, k)
        stages.append({
            "k": k,
            "in_shape": list(in_shape),
            "out_shape": list(out_shape),
            "probs_dim": M.NUM_CLASSES,
            "hlo": hlo_rel,
            "hlo_text_bytes": size,
            "cost_ms": round(cost, 4),
            "in_bytes": 4 * int(np.prod(in_shape)),
            "out_bytes": 4 * int(np.prod(out_shape)),
        })
        log(f"[aot] {name} stage {k}: {size} chars, {cost:.2f} ms")

    conf, pred, acc = T.eval_exits(name, params, ds_test)
    conf, pred = np.asarray(conf), np.asarray(pred)
    exits_rel = f"exits_{name}.bin"
    write_exits_bin(os.path.join(out_dir, exits_rel), conf, pred)

    entry = {
        "num_stages": M.num_stages(name),
        "stages": stages,
        "exits_bin": exits_rel,
        "exit_accuracy": np.asarray(acc).round(4).tolist(),
        "mean_confidence": conf.mean(axis=0).round(4).tolist(),
        "exit_rate_at": exit_rates(conf, CONF_THRESHOLDS),
        "vmem_audit": vmem_audit(name),
        "ae": None,
    }
    log(f"[aot] {name}: per-exit accuracy {entry['exit_accuracy']}")
    return entry, params


def build_autoencoder(params_resnet: dict, out_dir: str, cache_dir: str,
                      steps: int, ds_test: D.Dataset, templates, base_acc,
                      force: bool, log=print) -> dict:
    cache = os.path.join(cache_dir, "params_ae.npz")
    if os.path.exists(cache) and not force:
        log(f"[aot] ae: cached params {cache}")
        ae = load_params(cache)
    else:
        ae = T.train_autoencoder(params_resnet, jax.random.PRNGKey(SEED + 1),
                                 steps=steps, log=log, templates=templates)
        save_params(cache, ae)

    entry = emit_ae_hlo(ae, os.path.join(out_dir, "resnetl"))

    conf, pred, acc = T.eval_exits("resnetl", params_resnet, ds_test, ae=ae)
    conf, pred = np.asarray(conf), np.asarray(pred)
    write_exits_bin(os.path.join(out_dir, "exits_resnetl_ae.bin"), conf, pred)

    raw_bytes = 4 * 32 * 32 * 32
    code_bytes = 4 * int(np.prod(M.AE_CODE_SHAPE))
    acc_drop = [round(float(b - a), 4) for a, b in zip(np.asarray(acc), base_acc)]
    enc_cost = measure_fn_cost_ms(
        lambda x: M.ae_encode(ae, x, backend="pallas"),
        jnp.zeros((32, 32, 32), jnp.float32))
    dec_cost = measure_fn_cost_ms(
        lambda z: M.ae_decode(ae, z, backend="pallas"),
        jnp.zeros(M.AE_CODE_SHAPE, jnp.float32))
    entry.update({
        "code_shape": list(M.AE_CODE_SHAPE),
        "code_bytes": code_bytes,
        "raw_bytes": raw_bytes,
        "compression": round(raw_bytes / code_bytes, 2),
        "exit_accuracy_ae": np.asarray(acc).round(4).tolist(),
        "acc_drop": acc_drop,
        "enc_cost_ms": round(enc_cost, 4),
        "dec_cost_ms": round(dec_cost, 4),
        "exits_bin_ae": "exits_resnetl_ae.bin",
    })
    log(f"[aot] ae: {raw_bytes}B -> {code_bytes}B "
        f"({entry['compression']}x), acc drop {acc_drop}")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--ae-steps", type=int, default=300)
    ap.add_argument("--test-n", type=int, default=TEST_N)
    ap.add_argument("--force", action="store_true",
                    help="retrain even if cached params exist")
    args = ap.parse_args()

    out_dir = args.out
    cache_dir = os.path.join(out_dir, "cache")
    os.makedirs(cache_dir, exist_ok=True)

    t_start = time.time()
    templates = canonical_templates()
    # held-out test set: seed disjoint from every training batch stream
    ds_test = D.make_dataset(jax.random.PRNGKey(SEED + 999), args.test_n,
                             templates)
    D.write_dataset_bin(os.path.join(out_dir, "dataset.bin"), ds_test)
    # Evaluate on the quantize->dequantize roundtrip of the images — the
    # exact tensors the Rust source worker reconstructs from dataset.bin —
    # so the exit-oracle tables match the PJRT runtime bit-for-bit
    # (rust/tests/integration_xla.rs asserts prediction equality).
    ds_test = D.Dataset(
        images=jnp.asarray(D.dequantize_u8(D.quantize_u8(ds_test.images))),
        labels=ds_test.labels,
        difficulty=ds_test.difficulty,
    )
    print(f"[aot] dataset.bin: {args.test_n} samples")

    manifest = {
        "version": 1,
        "seed": SEED,
        "dataset": {"file": "dataset.bin", "n": args.test_n,
                    "h": D.IMG_H, "w": D.IMG_W, "c": D.IMG_C,
                    "num_classes": D.NUM_CLASSES},
        "models": {},
    }

    mnet_entry, _ = build_model("mobilenetv2l", out_dir, cache_dir,
                                args.steps, ds_test, templates, args.force)
    manifest["models"]["mobilenetv2l"] = mnet_entry

    rnet_entry, rparams = build_model("resnetl", out_dir, cache_dir,
                                      args.steps, ds_test, templates, args.force)
    rnet_entry["ae"] = build_autoencoder(
        rparams, out_dir, cache_dir, args.ae_steps, ds_test, templates,
        rnet_entry["exit_accuracy"], args.force)
    manifest["models"]["resnetl"] = rnet_entry

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest.json written; total {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
