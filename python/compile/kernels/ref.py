"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels in
`conv.py` / `head.py` match these implementations to float tolerance.

They are also the *training-time* implementations: training runs the ref
path (plain jnp/lax, differentiable, fast to trace), and the AOT stage
lowering swaps in the Pallas kernels (`backend="pallas"` in model.py).
The kernel-vs-ref tests are what make that swap sound.

All functions operate on single images (no batch dim); training vmaps them.
Layout is HWC / HWIO throughout (TPU-friendly, channels minor).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """[m, k] @ [k, n] -> [m, n] in float32 accumulation."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def extract_patches(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """im2col: [H, W, C] -> [OH*OW, kh*kw*C] with SAME-style explicit padding.

    Patch extraction is shared verbatim by the ref conv and the Pallas conv
    (the Pallas kernel is the matmul contraction; im2col is the layout
    transform that makes the MXU do convolution). Padding is symmetric
    (kh//2, kw//2), so OH = ceil(H/stride).
    """
    h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((ph, ph), (pw, pw), (0, 0)))
    oh = (h + stride - 1) // stride
    ow = (w + stride - 1) // stride
    cols = []
    for i in range(kh):
        for j in range(kw):
            # lax.slice (not python strided indexing): python step-slicing
            # can lower to gather ops that XLA 0.5.1's HLO-text round-trip
            # mis-executes; lax.slice stays a plain strided Slice op.
            sl = jax.lax.slice(
                xp,
                (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, c),
                (stride, stride, 1),
            )
            cols.append(sl)
    patches = jnp.concatenate(cols, axis=-1)  # [OH, OW, kh*kw*C]
    return patches.reshape(oh * ow, kh * kw * c)


def conv2d_ref(x: jax.Array, f: jax.Array, stride: int = 1) -> jax.Array:
    """[H, W, Cin] * [KH, KW, Cin, Cout] -> [OH, OW, Cout], SAME padding.

    Implemented as im2col + matmul so ref and Pallas share the exact same
    reduction order (important for bit-level comparability of the sweep).
    """
    kh, kw, cin, cout = f.shape
    h, w, _ = x.shape
    oh = (h + stride - 1) // stride
    ow = (w + stride - 1) // stride
    patches = extract_patches(x, kh, kw, stride)          # [OH*OW, kh*kw*Cin]
    fm = f.reshape(kh * kw * cin, cout)                   # [kh*kw*Cin, Cout]
    return matmul_ref(patches, fm).reshape(oh, ow, cout)


def pointwise_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """1x1 convolution: [H, W, Cin] * [Cin, Cout] -> [H, W, Cout]."""
    h, ww, cin = x.shape
    return matmul_ref(x.reshape(h * ww, cin), w).reshape(h, ww, -1)


def depthwise3x3_ref(x: jax.Array, f: jax.Array, stride: int = 1) -> jax.Array:
    """Depthwise 3x3: [H, W, C] * [3, 3, C] -> [OH, OW, C], SAME padding."""
    h, w, c = x.shape
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    oh = (h + stride - 1) // stride
    ow = (w + stride - 1) // stride
    acc = jnp.zeros((oh, ow, c), jnp.float32)
    for i in range(3):
        for j in range(3):
            sl = jax.lax.slice(
                xp,
                (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, c),
                (stride, stride, 1),
            )
            acc = acc + sl.astype(jnp.float32) * f[i, j, :].astype(jnp.float32)
    return acc


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """[k] @ [k, n] + [n] -> [n]."""
    return matmul_ref(x[None, :], w)[0] + b.astype(jnp.float32)


def head_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fused exit head: GAP -> dense -> softmax.

    [H, W, C] -> [v] class probabilities (eq. (1) of the paper; the
    confidence level eq. (2) is max over this vector, taken by the Rust
    worker).  Softmax is the numerically-stable shifted form.
    """
    gap = jnp.mean(x.astype(jnp.float32), axis=(0, 1))     # [C]
    logits = dense_ref(gap, w, b)                           # [v]
    z = logits - jnp.max(logits)
    e = jnp.exp(z)
    return e / jnp.sum(e)


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def relu6(x: jax.Array) -> jax.Array:
    return jnp.clip(x, 0.0, 6.0)
