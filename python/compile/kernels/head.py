"""Pallas kernel for the early-exit head (L1).

The exit head is the piece the paper's Algorithm 1 invokes at every exit
point k: feature map -> classifier -> softmax (eq. (1)).  It runs once per
task per worker, so it is fused into a single kernel: global-average-pool
reduction, the (1×C)·(C×v) classifier matvec, and a numerically-stable
softmax, all without the GAP vector ever leaving VMEM.

The whole operand set (feature map ≤ 32·32·128 f32 = 512 KiB, classifier
≤ 128×10) fits in VMEM, so the grid is a single step; on larger models the
H dimension would be gridded with a scratch accumulator.

Oracle: `ref.head_ref`; dense oracle: `ref.dense_ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _head_kernel(x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]                                   # [H, W, C] in VMEM
    gap = jnp.mean(x, axis=(0, 1))                   # VPU reduction -> [C]
    logits = jax.lax.dot_general(                    # MXU matvec -> [v]
        gap[None, :], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[0] + b_ref[...]
    z = logits - jnp.max(logits)                     # stable softmax (eq. 1)
    e = jnp.exp(z)
    o_ref[...] = e / jnp.sum(e)


def head_pallas(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fused GAP->dense->softmax: [H,W,C] -> [v] class probabilities."""
    h, ww, c = x.shape
    c2, v = w.shape
    assert c == c2, f"feature/classifier mismatch {c} vs {c2}"
    return pl.pallas_call(
        _head_kernel,
        out_shape=jax.ShapeDtypeStruct((v,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32))


def _dense_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        x_ref[...][None, :], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[0] + b_ref[...]


def dense_pallas(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """[k] @ [k, n] + [n] -> [n] (single MXU matvec step)."""
    k = x.shape[0]
    k2, n = w.shape
    assert k == k2
    return pl.pallas_call(
        _dense_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32))


def vmem_footprint_head(h: int, w: int, c: int, v: int) -> int:
    """Bytes of VMEM the single-step head kernel holds (f32)."""
    return 4 * (h * w * c + c * v + v + v)
