"""Pallas kernels for the convolution hot-spots (L1 of the stack).

Hardware adaptation (DESIGN.md §2): the paper runs MobileNetV2/ResNet-50 on
Jetson CUDA cores. We do not port CUDA threadblocks; we restate the compute
for the TPU model Pallas exposes:

* Convolution is **im2col patches × filter matrix** so the contraction runs
  on the MXU systolic array.  The BlockSpec tiles the patch matrix into
  VMEM-resident (block_m × K) · (K × block_n) tiles; K (= kh·kw·Cin, at most
  a few hundred here) is kept un-tiled, which bounds VMEM per grid step at
  `(block_m·K + K·block_n + block_m·block_n) · 4B` — ≤ ~1 MiB for every
  shape in this repo, far under the ~16 MiB VMEM budget, leaving headroom
  for the pipeline's double buffering.
* Depthwise conv is bandwidth-bound: the kernel holds the full padded halo
  block in VMEM and accumulates the 9 taps as strided vector multiplies
  (VPU work, no MXU).  The grid tiles channels so wide layers stream.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO through the Pallas interpreter.
Correctness vs `ref.py` is asserted by `python/tests/test_kernels.py`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref

# The CPU interpreter executes the grid serially in Python-traced HLO, so we
# fall back to a single grid step when the whole operand set is small enough
# to "fit in VMEM" anyway.  On a real TPU these thresholds would instead pick
# the pipelined multi-step grid.
_VMEM_BUDGET_BYTES = 4 * 1024 * 1024  # conservative half of a TPUv4 core


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (MXU-friendly when possible)."""
    if dim <= target:
        return dim
    for b in range(target, 0, -1):
        if dim % b == 0:
            return b
    return dim


# ---------------------------------------------------------------------------
# Tiled matmul — the MXU contraction used by conv2d / pointwise / dense.
# ---------------------------------------------------------------------------

def _matmul_kernel(x_ref, w_ref, o_ref):
    # One (block_m, K) x (K, block_n) MXU tile per grid step. float32
    # accumulate (preferred_element_type pins the MXU accumulator width).
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def matmul_pallas(x: jax.Array, w: jax.Array,
                  block_m: int = 128, block_n: int = 128) -> jax.Array:
    """[m, k] @ [k, n] -> [m, n] via a 2-D grid of MXU tiles.

    K is not tiled (see module docstring); block_m/block_n are clamped to
    divisors of m/n so BlockSpecs tile exactly. Oracle: `ref.matmul_ref`.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32))


# ---------------------------------------------------------------------------
# conv2d = im2col (layout transform, fuses into the surrounding HLO) + matmul
# ---------------------------------------------------------------------------

def conv2d_pallas(x: jax.Array, f: jax.Array, stride: int = 1,
                  block_m: int = 128, block_n: int = 128) -> jax.Array:
    """SAME conv [H,W,Cin] * [KH,KW,Cin,Cout] -> [OH,OW,Cout] on the MXU.

    Patch extraction is the shared `ref.extract_patches` (identical
    reduction order as the oracle); the contraction is `matmul_pallas`.
    """
    kh, kw, cin, cout = f.shape
    h, w, _ = x.shape
    oh = (h + stride - 1) // stride
    ow = (w + stride - 1) // stride
    patches = _ref.extract_patches(x, kh, kw, stride)
    fm = f.reshape(kh * kw * cin, cout)
    out = matmul_pallas(patches, fm, block_m=block_m, block_n=block_n)
    return out.reshape(oh, ow, cout)


def pointwise_pallas(x: jax.Array, w: jax.Array,
                     block_m: int = 128, block_n: int = 128) -> jax.Array:
    """1x1 conv [H,W,Cin] * [Cin,Cout] -> [H,W,Cout]: pure MXU matmul."""
    h, ww, cin = x.shape
    out = matmul_pallas(x.reshape(h * ww, cin), w,
                        block_m=block_m, block_n=block_n)
    return out.reshape(h, ww, -1)


# ---------------------------------------------------------------------------
# Depthwise 3x3 — VPU kernel over a VMEM-resident halo block.
# ---------------------------------------------------------------------------

def _depthwise_kernel(xp_ref, f_ref, o_ref, *, stride: int, oh: int, ow: int):
    # xp_ref: [H+2, W+2, Cblk] padded halo; f_ref: [3, 3, Cblk].
    # 9 strided multiply-accumulates on the VPU; the halo never leaves VMEM.
    xp = xp_ref[...]
    f = f_ref[...]
    acc = jnp.zeros((oh, ow, xp.shape[-1]), jnp.float32)
    for i in range(3):
        for j in range(3):
            sl = jax.lax.slice(
                xp,
                (i, j, 0),
                (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, xp.shape[-1]),
                (stride, stride, 1),
            )
            acc = acc + sl * f[i, j, :]
    o_ref[...] = acc


def depthwise3x3_pallas(x: jax.Array, f: jax.Array, stride: int = 1,
                        block_c: int = 128) -> jax.Array:
    """Depthwise SAME 3x3 conv, channel-tiled grid.

    Padding happens in the caller graph (fuses with the producer); each grid
    step sees a [H+2, W+2, block_c] halo slab. Oracle: `ref.depthwise3x3_ref`.
    """
    h, w, c = x.shape
    oh = (h + stride - 1) // stride
    ow = (w + stride - 1) // stride
    bc = _pick_block(c, block_c)
    xp = jnp.pad(x.astype(jnp.float32), ((1, 1), (1, 1), (0, 0)))
    kern = functools.partial(_depthwise_kernel, stride=stride, oh=oh, ow=ow)
    return pl.pallas_call(
        kern,
        grid=(c // bc,),
        in_specs=[
            pl.BlockSpec((h + 2, w + 2, bc), lambda i: (0, 0, i)),
            pl.BlockSpec((3, 3, bc), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((oh, ow, bc), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, c), jnp.float32),
        interpret=True,
    )(xp, f.astype(jnp.float32))


def vmem_footprint_matmul(m: int, k: int, n: int,
                          block_m: int = 128, block_n: int = 128) -> int:
    """Bytes of VMEM one grid step of `matmul_pallas` holds (f32).

    Used by the build-time perf audit (aot.py) and DESIGN.md §8 numbers.
    """
    bm, bn = _pick_block(m, block_m), _pick_block(n, block_n)
    return 4 * (bm * k + k * bn + bm * bn)


def vmem_footprint_depthwise(h: int, w: int, c: int, stride: int = 1,
                             block_c: int = 128) -> int:
    """Bytes of VMEM one grid step of `depthwise3x3_pallas` holds (f32)."""
    bc = _pick_block(c, block_c)
    oh = (h + stride - 1) // stride
    ow = (w + stride - 1) // stride
    return 4 * ((h + 2) * (w + 2) * bc + 9 * bc + oh * ow * bc)


def mxu_efficiency(m: int, k: int, n: int) -> float:
    """Estimated MXU utilization of an (m,k)x(k,n) f32 contraction.

    The 128x128 systolic array consumes (8,128)-tiled f32 operands; work
    issued is the padded volume, useful work is m*k*n. This is the L1
    perf-audit number DESIGN.md §8 and EXPERIMENTS.md §Perf report
    (interpret-mode wallclock is not a TPU proxy, so utilization is
    estimated structurally from the shapes the BlockSpecs produce).
    """
    def pad(d: int, t: int) -> int:
        return ((d + t - 1) // t) * t

    issued = pad(m, 8) * pad(k, 128) * pad(n, 128)
    return (m * k * n) / issued
