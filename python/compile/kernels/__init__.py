"""L1 Pallas kernels + pure-jnp oracles for the MDI-Exit model stages.

Public surface:
  conv.matmul_pallas / conv2d_pallas / pointwise_pallas / depthwise3x3_pallas
  head.head_pallas / head.dense_pallas
  ref.*_ref oracles (also the training-time implementations)
"""

from . import conv, head, ref  # noqa: F401
