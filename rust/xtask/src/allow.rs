//! The vetted-exception list (`rust/xtask/lint.allow`).
//!
//! Format, one entry per line (`#` comments, blanks ignored):
//!
//! ```text
//! rule | path-suffix | line-must-contain | reason
//! ```
//!
//! An entry suppresses a finding when the rule id matches, the file path
//! ends with the suffix, and the *original* line text contains the
//! substring (string contents are blanked in cleaned text, so entries
//! match on what the file says — typically the expect message). Entries
//! that suppress nothing are stale and reported as errors, so the list
//! can only shrink as code improves.

use crate::rules::Finding;

#[derive(Debug)]
pub struct Entry {
    pub rule: String,
    pub path_suffix: String,
    pub contains: String,
    pub lineno: usize,
}

/// Parse the allowlist. Malformed lines are hard errors (a typo must not
/// silently stop suppressing).
pub fn parse(text: &str) -> Result<Vec<Entry>, String> {
    let mut entries = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split('|').map(str::trim).collect();
        if parts.len() != 4 {
            return Err(format!(
                "lint.allow:{}: malformed entry (want `rule | path | contains | reason`)",
                idx + 1
            ));
        }
        if parts[..3].iter().any(|p| p.is_empty()) {
            return Err(format!(
                "lint.allow:{}: rule, path, and contains must be non-empty",
                idx + 1
            ));
        }
        entries.push(Entry {
            rule: parts[0].to_string(),
            path_suffix: parts[1].to_string(),
            contains: parts[2].to_string(),
            lineno: idx + 1,
        });
    }
    Ok(entries)
}

/// Split findings into (kept, stale-entry messages). Every entry must
/// suppress at least one finding or it is reported as stale.
pub fn apply(findings: Vec<Finding>, entries: &[Entry]) -> (Vec<Finding>, Vec<String>) {
    let mut used = vec![false; entries.len()];
    let mut kept = Vec::new();
    for f in findings {
        let mut suppressed = false;
        for (i, e) in entries.iter().enumerate() {
            if e.rule == f.rule
                && f.path.ends_with(&e.path_suffix)
                && f.orig_line.contains(&e.contains)
            {
                used[i] = true;
                suppressed = true;
            }
        }
        if !suppressed {
            kept.push(f);
        }
    }
    let stale = entries
        .iter()
        .zip(&used)
        .filter(|(_, &u)| !u)
        .map(|(e, _)| {
            format!(
                "lint.allow:{}: stale entry ({} | {} | {}) suppresses nothing — remove it",
                e.lineno, e.rule, e.path_suffix, e.contains
            )
        })
        .collect();
    (kept, stale)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, path: &str, orig_line: &str) -> Finding {
        Finding {
            rule,
            path: path.to_string(),
            line: 1,
            msg: String::new(),
            orig_line: orig_line.to_string(),
        }
    }

    #[test]
    fn parses_and_skips_comments() {
        let text = "# header\n\nrule-a | foo/bar.rs | needle | because\n";
        let es = parse(text).unwrap();
        assert_eq!(es.len(), 1);
        assert_eq!(es[0].rule, "rule-a");
        assert_eq!(es[0].path_suffix, "foo/bar.rs");
        assert_eq!(es[0].contains, "needle");
        assert_eq!(es[0].lineno, 3);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse("only | three | parts\n").is_err());
        assert!(parse(" | x | y | z\n").is_err());
    }

    #[test]
    fn suppresses_matching_and_reports_stale() {
        let entries = parse(
            "panic-budget | coordinator/run.rs | resolved above | invariant\n\
             clock-purity | simnet/transport.rs | Instant | fabric\n",
        )
        .unwrap();
        let findings = vec![
            finding("panic-budget", "src/coordinator/run.rs", "x.expect(\"resolved above\")"),
            finding("panic-budget", "src/coordinator/run.rs", "y.unwrap()"),
        ];
        let (kept, stale) = apply(findings, &entries);
        // The expect is suppressed, the unwrap survives, the unused clock
        // entry is stale.
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].orig_line, "y.unwrap()");
        assert_eq!(stale.len(), 1);
        assert!(stale[0].contains("simnet/transport.rs"), "{}", stale[0]);
        // Rule must match, not just path+substring.
        let entries = parse("clock-purity | coordinator/run.rs | unwrap | x\n").unwrap();
        let (kept, _) =
            apply(vec![finding("panic-budget", "src/coordinator/run.rs", "y.unwrap()")], &entries);
        assert_eq!(kept.len(), 1);
    }
}
