//! Repo automation tasks. Today: `cargo xtask lint`.
//!
//! The lint enforces the crate's written contracts as deny-by-default
//! diagnostics with `file:line` output (see `rust/CONTRACTS.md` for the
//! rule catalogue and `lint.allow` for the vetted exceptions). It is a
//! zero-dependency token scanner — the offline build image cannot fetch
//! `syn`, and every contract here is expressible as identifier/call-site
//! patterns over comment- and string-stripped source.
//!
//! Exit codes: 0 clean, 1 findings or stale allowlist entries, 2 usage /
//! I/O errors.

mod allow;
mod rules;
mod scan;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(),
        Some(other) => {
            eprintln!("xtask: unknown task {other:?} (available: lint)");
            ExitCode::from(2)
        }
        None => {
            eprintln!("usage: cargo xtask lint");
            ExitCode::from(2)
        }
    }
}

fn lint() -> ExitCode {
    // xtask lives at rust/xtask; the tree under check is rust/src.
    let xtask_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let rust_dir = match xtask_dir.parent() {
        Some(p) => p,
        None => {
            eprintln!("xtask: cannot locate the rust/ directory");
            return ExitCode::from(2);
        }
    };
    let src_dir = rust_dir.join("src");

    let mut files = Vec::new();
    if let Err(e) = collect_rs_files(&src_dir, &mut files) {
        eprintln!("xtask: walking {}: {e}", src_dir.display());
        return ExitCode::from(2);
    }
    files.sort();

    let mut findings = Vec::new();
    let mut rng_cleaned: Option<Vec<u8>> = None;
    for path in &files {
        let orig = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("xtask: reading {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        let cleaned = scan::clean(&orig);
        let mask = scan::test_mask(&cleaned);
        let rel = rel_path(rust_dir, path);
        if rel.ends_with("util/rng.rs") {
            rng_cleaned = Some(cleaned.clone());
        }
        rules::run_all(&rel, &orig, &cleaned, &mask, &mut findings);
    }
    match rng_cleaned {
        Some(cleaned) => rules::check_registry(&cleaned, &mut findings),
        None => findings.push(rules::Finding {
            rule: "rng-streams",
            path: "src/util/rng.rs".to_string(),
            line: 1,
            msg: "util/rng.rs not found — the stream registry is gone".to_string(),
            orig_line: String::new(),
        }),
    }

    let allow_path = xtask_dir.join("lint.allow");
    let entries = match std::fs::read_to_string(&allow_path) {
        Ok(text) => match allow::parse(&text) {
            Ok(es) => es,
            Err(e) => {
                eprintln!("xtask: {e}");
                return ExitCode::from(2);
            }
        },
        Err(e) => {
            eprintln!("xtask: reading {}: {e}", allow_path.display());
            return ExitCode::from(2);
        }
    };

    let (kept, stale) = allow::apply(findings, &entries);
    for f in &kept {
        println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.msg);
    }
    for s in &stale {
        println!("{s}");
    }
    if kept.is_empty() && stale.is_empty() {
        println!(
            "xtask lint: clean ({} files, {} vetted exceptions)",
            files.len(),
            entries.len()
        );
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "xtask lint: {} finding(s), {} stale allowlist entr{} — see rust/CONTRACTS.md",
            kept.len(),
            stale.len(),
            if stale.len() == 1 { "y" } else { "ies" }
        );
        ExitCode::FAILURE
    }
}

/// Recursively collect `.rs` files under `dir`.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if entry.file_type()?.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Forward-slashed path relative to `rust/` (diagnostics read
/// `src/coordinator/worker.rs:376: …` regardless of platform).
fn rel_path(rust_dir: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(rust_dir).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}
