//! Byte-level source scanning: comment/string stripping and test masking.
//!
//! The lint does not parse Rust — it runs textual rules over a *cleaned*
//! copy of each file in which comments and literal contents are blanked
//! out (offsets and newlines preserved, so positions map 1:1 back to the
//! original), plus a mask marking `#[cfg(test)]` / `#[test]` item bodies.
//! This is deliberately dependency-free: the offline build image cannot
//! fetch `syn`, and the contracts being checked are all expressible as
//! identifier/call-site patterns.

#[inline]
pub fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// First occurrence of `needle` in `haystack[from..]`, as an absolute index.
pub fn find(haystack: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || from > haystack.len() {
        return None;
    }
    haystack[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}

fn blank(out: &mut [u8], a: usize, b: usize) {
    let hi = b.min(out.len());
    for slot in out.iter_mut().take(hi).skip(a) {
        if *slot != b'\n' {
            *slot = b' ';
        }
    }
}

/// Blank comments (fully, delimiters included) and string/char literal
/// contents (keeping the quotes), preserving byte offsets and newlines.
/// Handles nested block comments, raw strings (`r"…"`, `r#"…"#`), byte
/// strings, escapes, and the char-literal/lifetime ambiguity.
pub fn clean(src: &[u8]) -> Vec<u8> {
    let mut out = src.to_vec();
    let n = src.len();
    let mut i = 0;
    while i < n {
        let c = src[i];
        let nxt = if i + 1 < n { src[i + 1] } else { 0 };
        if c == b'/' && nxt == b'/' {
            let j = find(src, b"\n", i).unwrap_or(n);
            blank(&mut out, i, j);
            i = j;
        } else if c == b'/' && nxt == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if src[j..].starts_with(b"/*") {
                    depth += 1;
                    j += 2;
                } else if src[j..].starts_with(b"*/") {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'r' && (nxt == b'"' || nxt == b'#') && (i == 0 || !is_ident(src[i - 1]))
        {
            // Raw string: r"…" or r#"…"# (any number of hashes).
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && src[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && src[j] == b'"' {
                let mut close = vec![b'#'; hashes + 1];
                close[0] = b'"';
                let k = match find(src, &close, j + 1) {
                    Some(k) => k + close.len(),
                    None => n,
                };
                blank(&mut out, j + 1, (k - 1).saturating_sub(hashes));
                i = k;
            } else {
                i += 1; // `r#` that wasn't a raw string (raw identifier)
            }
        } else if c == b'b' && nxt == b'"' && (i == 0 || !is_ident(src[i - 1])) {
            i += 1; // byte string: handled as a plain string next iteration
        } else if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if src[j] == b'\\' {
                    j += 2;
                } else if src[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            let j = j.min(n);
            blank(&mut out, i + 1, j.saturating_sub(1));
            i = j;
        } else if c == b'\'' {
            if nxt == b'\\' {
                // Escaped char literal: '\n', '\u{41}', '\x7f', …
                let mut j = i + 3;
                while j < n && src[j] != b'\'' {
                    j += 1;
                }
                j = (j + 1).min(n);
                blank(&mut out, i + 1, j.saturating_sub(1));
                i = j;
            } else if i + 2 < n && src[i + 2] == b'\'' && nxt != b'\'' {
                // Plain char literal 'x'.
                blank(&mut out, i + 1, i + 2);
                i += 3;
            } else {
                i += 1; // lifetime
            }
        } else {
            i += 1;
        }
    }
    out
}

/// Mark the byte ranges of `#[cfg(test)]` / `#[test]` items: from the
/// attribute to the matching close brace of the first `{` after it.
/// Operates on cleaned text so braces in strings/comments don't confuse
/// the matcher.
pub fn test_mask(cleaned: &[u8]) -> Vec<bool> {
    let n = cleaned.len();
    let mut mask = vec![false; n];
    for pat in [b"#[cfg(test)]".as_slice(), b"#[test]".as_slice()] {
        let mut start = 0;
        while let Some(a) = find(cleaned, pat, start) {
            start = a + 1;
            let Some(open) = find(cleaned, b"{", a + pat.len()) else {
                continue;
            };
            let mut depth = 1usize;
            let mut j = open + 1;
            while j < n && depth > 0 {
                match cleaned[j] {
                    b'{' => depth += 1,
                    b'}' => depth -= 1,
                    _ => {}
                }
                j += 1;
            }
            for slot in mask.iter_mut().take(j).skip(a) {
                *slot = true;
            }
        }
    }
    mask
}

/// 1-indexed line number of byte position `pos`.
pub fn line_of(src: &[u8], pos: usize) -> usize {
    src[..pos.min(src.len())].iter().filter(|&&b| b == b'\n').count() + 1
}

/// Full text of the line containing `pos`.
pub fn line_text(src: &[u8], pos: usize) -> String {
    let pos = pos.min(src.len());
    let a = src[..pos].iter().rposition(|&b| b == b'\n').map(|p| p + 1).unwrap_or(0);
    let b = find(src, b"\n", pos).unwrap_or(src.len());
    String::from_utf8_lossy(&src[a..b]).into_owned()
}

/// Whole-word occurrences of `word` (identifier-boundary on both sides).
pub fn word_hits(cleaned: &[u8], word: &[u8]) -> Vec<usize> {
    let mut hits = Vec::new();
    let mut start = 0;
    while let Some(a) = find(cleaned, word, start) {
        start = a + 1;
        let before_ok = a == 0 || !is_ident(cleaned[a - 1]);
        let after = a + word.len();
        let after_ok = after >= cleaned.len() || !is_ident(cleaned[after]);
        if before_ok && after_ok {
            hits.push(a);
        }
    }
    hits
}

/// Balanced-paren argument text starting at the `(` at `open_paren`;
/// returns (args, index of the closing paren).
pub fn call_args(cleaned: &[u8], open_paren: usize) -> (Vec<u8>, usize) {
    let n = cleaned.len();
    let mut depth = 0usize;
    let mut j = open_paren;
    while j < n {
        match cleaned[j] {
            b'(' => depth += 1,
            b')' => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return (cleaned[open_paren + 1..j].to_vec(), j);
                }
            }
            _ => {}
        }
        j += 1;
    }
    (cleaned[(open_paren + 1).min(n)..].to_vec(), n.saturating_sub(1))
}

/// Is `pos` on a `use` / `pub use` line? (Re-exports of charged constants
/// are fine; only arithmetic/usage is charged.)
pub fn is_use_line(cleaned: &[u8], pos: usize) -> bool {
    let t = line_text(cleaned, pos);
    let t = t.trim_start();
    t.starts_with("use ") || t.starts_with("pub use ")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(bytes: &[u8]) -> String {
        String::from_utf8_lossy(bytes).into_owned()
    }

    #[test]
    fn comments_are_fully_blanked() {
        let c = clean(b"let x = 1; // Instant::now()\nlet y = 2;");
        assert!(!s(&c).contains("Instant"));
        assert!(s(&c).contains("let y = 2;"));
        let c = clean(b"/* outer /* nested Instant */ still comment */ let z = 3;");
        assert!(!s(&c).contains("Instant"));
        assert!(s(&c).contains("let z = 3;"));
    }

    #[test]
    fn string_contents_blanked_quotes_kept() {
        let c = clean(br#"let m = "Instant::now inside"; let k = 1;"#);
        let cs = s(&c);
        assert!(!cs.contains("Instant"));
        assert!(cs.contains('"'));
        assert!(cs.contains("let k = 1;"));
        // Escaped quotes don't end the literal early.
        let c = clean(br#"let m = "a\"Instant\"b"; let k = 1;"#);
        assert!(!s(&c).contains("Instant"));
        // Raw strings too.
        let c = clean(br###"let m = r#"Instant "quoted" body"#; after"###);
        let cs = s(&c);
        assert!(!cs.contains("Instant"), "{cs}");
        assert!(cs.contains("after"));
    }

    #[test]
    fn char_literals_blanked_lifetimes_kept() {
        let c = clean(b"let a = 'Z'; fn f<'a>(x: &'a str) {} let q = '\\n';");
        let cs = s(&c);
        assert!(cs.contains("<'a>"), "lifetime untouched: {cs}");
        assert!(cs.contains("&'a str"));
        assert!(!cs.contains('Z'), "char literal contents blanked: {cs}");
        assert!(!cs.contains("\\n"), "escaped literal blanked: {cs}");
    }

    #[test]
    fn offsets_and_newlines_survive() {
        let src = b"a\n\"two\nlines\"\nb // c\nd";
        let c = clean(src);
        assert_eq!(c.len(), src.len());
        assert_eq!(
            c.iter().filter(|&&b| b == b'\n').count(),
            src.iter().filter(|&&b| b == b'\n').count()
        );
    }

    #[test]
    fn test_mask_covers_test_items_only() {
        let src = b"fn real() { x(); }\n#[cfg(test)]\nmod tests {\n fn t() { y(); }\n}\nfn after() {}";
        let cleaned = clean(src);
        let mask = test_mask(&cleaned);
        let y = find(src, b"y();", 0).unwrap();
        let x = find(src, b"x();", 0).unwrap();
        let after = find(src, b"after", 0).unwrap();
        assert!(mask[y]);
        assert!(!mask[x]);
        assert!(!mask[after]);
    }

    #[test]
    fn word_hits_respects_boundaries() {
        let src = b"rng rngs my_rng (rng) rng.next";
        let hits = word_hits(src, b"rng");
        assert_eq!(hits.len(), 3, "{hits:?}");
    }

    #[test]
    fn call_args_balances_nesting() {
        let src = b"f(a, g(b, c), d) rest";
        let open = find(src, b"(", 0).unwrap();
        let (args, close) = call_args(src, open);
        assert_eq!(s(&args), "a, g(b, c), d");
        assert_eq!(src[close], b')');
        assert_eq!(close, src.len() - 6);
    }

    #[test]
    fn line_helpers() {
        let src = b"one\ntwo three\nfour";
        let pos = find(src, b"three", 0).unwrap();
        assert_eq!(line_of(src, pos), 2);
        assert_eq!(line_text(src, pos), "two three");
        assert!(is_use_line(b"  use crate::net::RESULT_BYTES;", 10));
        assert!(is_use_line(b"pub use crate::net::Envelope;", 10));
        assert!(!is_use_line(b"let x = RESULT_BYTES;", 10));
    }
}
