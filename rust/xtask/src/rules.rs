//! The five machine-checked contracts (see ../CONTRACTS.md for the
//! rationale behind each rule and how to add an allowlist entry):
//!
//! * `rng-streams` — every `Pcg64::new` / `.fork` call site names a
//!   constant from `util::rng::streams`; the registry's reservations must
//!   be pairwise disjoint.
//! * `clock-purity` — no `Instant` / `SystemTime` outside
//!   `coordinator/rt.rs`, `util/logging.rs`, `coordinator/clock.rs`.
//! * `wire-charge` — envelope byte-size identifiers only appear in `net/`
//!   and the driver choke points; no arithmetic on `encoded_bytes()`
//!   outside `net/`; no owned payload copies (`into_data()`,
//!   `.data().to_vec()`) outside `tensor/`, `runtime/`, `net/` —
//!   activations travel the queues and the wire as shared-buffer views.
//! * `telemetry-purity` — no RNG or clock identifiers inside
//!   `telemetry/` (recorders observe; they never perturb).
//! * `panic-budget` — no `unwrap`/`expect`/`panic!`-family in non-test
//!   code under `cluster/`, `coordinator/`, `net/`, `policy/`, `sched/`.
//!
//! Rules operate on cleaned text + test mask from [`crate::scan`] and
//! report against the original line text so allowlist entries can match
//! expect messages.

use crate::scan;

/// One diagnostic. `orig_line` is the untouched source line (cleaned text
/// blanks string contents, and allowlist entries match on e.g. the expect
/// message).
#[derive(Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
    pub orig_line: String,
}

fn emit(
    out: &mut Vec<Finding>,
    rule: &'static str,
    path: &str,
    orig: &[u8],
    cleaned: &[u8],
    pos: usize,
    msg: String,
) {
    out.push(Finding {
        rule,
        path: path.to_string(),
        line: scan::line_of(cleaned, pos),
        msg,
        orig_line: scan::line_text(orig, pos),
    });
}

/// Run every per-file rule.
pub fn run_all(path: &str, orig: &[u8], cleaned: &[u8], mask: &[bool], out: &mut Vec<Finding>) {
    rng_streams(path, orig, cleaned, mask, out);
    clock_purity(path, orig, cleaned, mask, out);
    wire_charge(path, orig, cleaned, mask, out);
    payload_copy(path, orig, cleaned, mask, out);
    telemetry_purity(path, orig, cleaned, mask, out);
    panic_budget(path, orig, cleaned, mask, out);
}

// ---------------------------------------------------------------------------
// rng-streams
// ---------------------------------------------------------------------------

/// `Pcg64::new(seed, stream)` / `rng.fork(stream)` call sites must take
/// the stream from the central registry — the argument text has to
/// mention `streams::`. The registry file itself is exempt (it defines
/// the constants and the generator).
pub fn rng_streams(
    path: &str,
    orig: &[u8],
    cleaned: &[u8],
    mask: &[bool],
    out: &mut Vec<Finding>,
) {
    if path.ends_with("util/rng.rs") {
        return;
    }
    for name in [b"Pcg64::new".as_slice(), b".fork".as_slice()] {
        let mut start = 0;
        while let Some(a) = scan::find(cleaned, name, start) {
            start = a + 1;
            if name == b".fork" {
                // Only `.fork(` — not `.forked` or a field access.
                match cleaned.get(a + name.len()) {
                    Some(b'(') => {}
                    _ => continue,
                }
            }
            if mask[a] {
                continue;
            }
            let Some(p) = scan::find(cleaned, b"(", a + name.len()) else {
                continue;
            };
            let (args, _) = scan::call_args(cleaned, p);
            if scan::find(&args, b"streams::", 0).is_none() {
                let shown = String::from_utf8_lossy(name).into_owned();
                emit(
                    out,
                    "rng-streams",
                    path,
                    orig,
                    cleaned,
                    a,
                    format!(
                        "{shown} stream argument must come from util::rng::streams \
                         (magic-number streams break the reservation registry)"
                    ),
                );
            }
        }
    }
}

/// Parse the `pub mod streams` registry out of `util/rng.rs` (cleaned
/// text) and check the declared reservations are pairwise disjoint:
/// `FOO_BASE` spans `[FOO_BASE, FOO_BASE + FOO_SPAN)` and needs its
/// `FOO_SPAN` sibling; every other constant reserves exactly one id.
pub fn check_registry(rng_cleaned: &[u8], out: &mut Vec<Finding>) {
    const PATH: &str = "src/util/rng.rs";
    let missing = |out: &mut Vec<Finding>, msg: &str| {
        out.push(Finding {
            rule: "rng-streams",
            path: PATH.to_string(),
            line: 1,
            msg: msg.to_string(),
            orig_line: String::new(),
        });
    };
    let Some(m) = scan::find(rng_cleaned, b"pub mod streams", 0) else {
        missing(out, "missing `pub mod streams` registry");
        return;
    };
    let Some(open) = scan::find(rng_cleaned, b"{", m) else {
        missing(out, "malformed `pub mod streams` registry");
        return;
    };
    let mut depth = 1usize;
    let mut j = open + 1;
    while j < rng_cleaned.len() && depth > 0 {
        match rng_cleaned[j] {
            b'{' => depth += 1,
            b'}' => depth -= 1,
            _ => {}
        }
        j += 1;
    }
    let body = &rng_cleaned[open + 1..j.saturating_sub(1)];

    // Collect `pub const NAME: u64 = <int literal>;` declarations.
    let mut consts: Vec<(String, u64, usize)> = Vec::new();
    let mut start = 0;
    while let Some(a) = scan::find(body, b"pub const ", start) {
        start = a + 1;
        let mut k = a + b"pub const ".len();
        let name_start = k;
        while k < body.len() && scan::is_ident(body[k]) {
            k += 1;
        }
        let name = String::from_utf8_lossy(&body[name_start..k]).into_owned();
        let Some(eq) = scan::find(body, b"=", k) else { continue };
        let Some(semi) = scan::find(body, b";", eq) else { continue };
        let lit: String = String::from_utf8_lossy(&body[eq + 1..semi])
            .chars()
            .filter(|c| c.is_ascii_digit())
            .collect();
        let Ok(value) = lit.parse::<u64>() else {
            missing(out, &format!("registry constant {name} is not an integer literal"));
            continue;
        };
        consts.push((name, value, scan::line_of(rng_cleaned, open + 1 + a)));
    }

    // Build reservations: (name, base, span, line).
    let span_of = |base_name: &str| -> Option<u64> {
        let span_name = format!("{}_SPAN", base_name.strip_suffix("_BASE")?);
        consts.iter().find(|(n, _, _)| *n == span_name).map(|(_, v, _)| *v)
    };
    let mut ranges: Vec<(String, u64, u64, usize)> = Vec::new();
    for (name, value, line) in &consts {
        if name.ends_with("_SPAN") {
            continue;
        }
        if name.ends_with("_BASE") {
            match span_of(name) {
                Some(span) if span > 0 => ranges.push((name.clone(), *value, span, *line)),
                Some(_) => out.push(Finding {
                    rule: "rng-streams",
                    path: PATH.to_string(),
                    line: *line,
                    msg: format!("registry range {name} has zero span"),
                    orig_line: String::new(),
                }),
                None => out.push(Finding {
                    rule: "rng-streams",
                    path: PATH.to_string(),
                    line: *line,
                    msg: format!(
                        "registry range {name} has no sibling {}_SPAN",
                        name.trim_end_matches("_BASE")
                    ),
                    orig_line: String::new(),
                }),
            }
        } else {
            ranges.push((name.clone(), *value, 1, *line));
        }
    }

    // Pairwise disjointness.
    for (i, (na, a, sa, line)) in ranges.iter().enumerate() {
        for (nb, b, sb, _) in &ranges[i + 1..] {
            if *a < *b + *sb && *b < *a + *sa {
                out.push(Finding {
                    rule: "rng-streams",
                    path: PATH.to_string(),
                    line: *line,
                    msg: format!("stream reservations {na} and {nb} overlap"),
                    orig_line: String::new(),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// clock-purity
// ---------------------------------------------------------------------------

const CLOCK_ALLOWED: [&str; 3] =
    ["coordinator/rt.rs", "util/logging.rs", "coordinator/clock.rs"];

/// `Instant` / `SystemTime` may only appear where wallclock access is the
/// module's job. Everything the clock-agnostic `WorkerCore` can reach
/// receives `now` as a value instead.
pub fn clock_purity(
    path: &str,
    orig: &[u8],
    cleaned: &[u8],
    mask: &[bool],
    out: &mut Vec<Finding>,
) {
    if CLOCK_ALLOWED.iter().any(|p| path.ends_with(p)) {
        return;
    }
    for word in [b"Instant".as_slice(), b"SystemTime".as_slice()] {
        for a in scan::word_hits(cleaned, word) {
            if !mask[a] {
                let shown = String::from_utf8_lossy(word).into_owned();
                emit(
                    out,
                    "clock-purity",
                    path,
                    orig,
                    cleaned,
                    a,
                    format!(
                        "{shown} outside rt.rs / logging.rs / clock.rs \
                         (cores receive `now` as a value; drivers own clocks)"
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// wire-charge
// ---------------------------------------------------------------------------

const WIRE_IDENTS: [&[u8]; 8] = [
    b"encoded_bytes",
    b"unbatched_bytes",
    b"task_wire_bytes",
    b"task_item_bytes",
    b"note_wire_recharge",
    b"ENVELOPE_HEADER_BYTES",
    b"RESULT_BYTES",
    b"RESULT_ITEM_BYTES",
];

/// Driver files that may *call* the charging API (but still may not do
/// arithmetic on `encoded_bytes()` — only `net/` composes byte math).
const WIRE_ALLOWED: [&str; 4] =
    ["coordinator/worker.rs", "coordinator/sim.rs", "coordinator/rt.rs", "policy/summary.rs"];

/// Byte-charging identifiers stay inside `net/` plus the driver choke
/// points; `use` re-exports are exempt; arithmetic directly on an
/// `encoded_bytes()` call outside `net/` is flagged even in allowed files
/// (composite charges belong next to the wire format).
pub fn wire_charge(
    path: &str,
    orig: &[u8],
    cleaned: &[u8],
    mask: &[bool],
    out: &mut Vec<Finding>,
) {
    let in_net = path.contains("/net/") || path.ends_with("net/mod.rs");
    let in_allowed = in_net || WIRE_ALLOWED.iter().any(|p| path.ends_with(p));
    for word in WIRE_IDENTS {
        for a in scan::word_hits(cleaned, word) {
            if mask[a] || scan::is_use_line(cleaned, a) {
                continue;
            }
            if !in_allowed {
                let shown = String::from_utf8_lossy(word).into_owned();
                emit(
                    out,
                    "wire-charge",
                    path,
                    orig,
                    cleaned,
                    a,
                    format!(
                        "byte-charging identifier {shown} outside net/ and the driver \
                         choke points (all wire charging flows through net::Envelope)"
                    ),
                );
            } else if !in_net && word == b"encoded_bytes" {
                // Arithmetic adjacency on the call's result.
                let mut flagged = false;
                if cleaned.get(a + word.len()) == Some(&b'(') {
                    let (_, close) = scan::call_args(cleaned, a + word.len());
                    let mut k = close + 1;
                    while k < cleaned.len() && cleaned[k].is_ascii_whitespace() {
                        k += 1;
                    }
                    let ch = cleaned.get(k).copied().unwrap_or(b' ');
                    let arrow = ch == b'-' && cleaned.get(k + 1) == Some(&b'>');
                    if matches!(ch, b'+' | b'*' | b'%' | b'/') || (ch == b'-' && !arrow) {
                        flagged = true;
                    }
                }
                if !flagged {
                    // Walk left across the receiver (`env.`, `self.x.`) and
                    // whitespace to the token before the whole expression.
                    let mut b = a;
                    while b > 0
                        && (scan::is_ident(cleaned[b - 1])
                            || cleaned[b - 1] == b'.'
                            || cleaned[b - 1].is_ascii_whitespace())
                    {
                        b -= 1;
                    }
                    if b > 0 && matches!(cleaned[b - 1], b'+' | b'-' | b'*' | b'/' | b'%') {
                        flagged = true;
                    }
                }
                if flagged {
                    emit(
                        out,
                        "wire-charge",
                        path,
                        orig,
                        cleaned,
                        a,
                        "arithmetic on encoded_bytes() outside net/ (derive composite \
                         charges inside the wire module)"
                            .to_string(),
                    );
                }
            }
        }
    }
}

/// Call patterns that materialize an owned copy of a tensor payload.
/// `into_data` gets identifier-boundary matching; the method chain is
/// matched literally (same line, no interior spaces — the idiomatic
/// spelling both escape hatches document).
const COPY_PATTERNS: [&[u8]; 2] = [b"into_data", b".data().to_vec()"];

/// Directories that may materialize owned payload copies: the tensor
/// module (defines the escape hatches), engines under `runtime/`
/// (marshalling activations across an FFI boundary is their job), and
/// the wire codec.
const COPY_ALLOWED: [&str; 3] = ["/tensor/", "/runtime/", "/net/"];

/// Everything between admission and the wire moves `Tensor` views
/// (refcount bumps), never owned `Vec<f32>` copies — that is what the
/// zero-copy hot path is made of. A payload copy outside the allowed
/// modules silently reintroduces the pre-zero-copy cost without
/// changing any observable byte accounting, so only a lint can catch it.
pub fn payload_copy(
    path: &str,
    orig: &[u8],
    cleaned: &[u8],
    mask: &[bool],
    out: &mut Vec<Finding>,
) {
    if COPY_ALLOWED.iter().any(|d| path.contains(d)) {
        return;
    }
    for pat in COPY_PATTERNS {
        let hits: Vec<usize> = if pat == b"into_data" {
            scan::word_hits(cleaned, pat)
        } else {
            let mut hs = Vec::new();
            let mut start = 0;
            while let Some(a) = scan::find(cleaned, pat, start) {
                hs.push(a);
                start = a + 1;
            }
            hs
        };
        for a in hits {
            if mask[a] || scan::is_use_line(cleaned, a) {
                continue;
            }
            let shown = String::from_utf8_lossy(pat).into_owned();
            emit(
                out,
                "wire-charge",
                path,
                orig,
                cleaned,
                a,
                format!(
                    "owned payload copy ({shown}) outside tensor/, runtime/, net/ \
                     (activations travel as shared-buffer views; copying here \
                     silently reintroduces the pre-zero-copy hot path)"
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// telemetry-purity
// ---------------------------------------------------------------------------

const TELEMETRY_DENY: [&[u8]; 4] = [b"Pcg64", b"rng", b"Instant", b"SystemTime"];

/// Recorders observe the event flow; they never draw randomness or read
/// clocks (stamps arrive as values). Any RNG/clock identifier in
/// `telemetry/` non-test code breaks the "zero perturbation" guarantee
/// that keeps DES runs bit-for-bit identical with telemetry on.
pub fn telemetry_purity(
    path: &str,
    orig: &[u8],
    cleaned: &[u8],
    mask: &[bool],
    out: &mut Vec<Finding>,
) {
    if !path.contains("/telemetry/") && !path.ends_with("telemetry/mod.rs") {
        return;
    }
    for word in TELEMETRY_DENY {
        for a in scan::word_hits(cleaned, word) {
            if !mask[a] {
                let shown = String::from_utf8_lossy(word).into_owned();
                emit(
                    out,
                    "telemetry-purity",
                    path,
                    orig,
                    cleaned,
                    a,
                    format!(
                        "{shown} inside telemetry (recorders are read-only: no RNG, \
                         no clocks — stamps arrive as values)"
                    ),
                );
            }
        }
    }
    if let Some(a) = scan::find(cleaned, b"static mut", 0) {
        if !mask[a] {
            emit(
                out,
                "telemetry-purity",
                path,
                orig,
                cleaned,
                a,
                "static mut inside telemetry".to_string(),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// panic-budget
// ---------------------------------------------------------------------------

const PANIC_DIRS: [&str; 5] =
    ["/cluster/", "/coordinator/", "/net/", "/policy/", "/sched/"];
const PANIC_PATTERNS: [&[u8]; 6] =
    [b".unwrap()", b".expect(", b"panic!", b"unreachable!", b"todo!", b"unimplemented!"];

/// `unwrap`/`expect`/`panic!`-family is forbidden in non-test code of the
/// decision-critical subsystems; vetted invariants live in the allowlist
/// with their justification.
pub fn panic_budget(
    path: &str,
    orig: &[u8],
    cleaned: &[u8],
    mask: &[bool],
    out: &mut Vec<Finding>,
) {
    if !PANIC_DIRS.iter().any(|d| path.contains(d)) {
        return;
    }
    for pat in PANIC_PATTERNS {
        let mut start = 0;
        while let Some(a) = scan::find(cleaned, pat, start) {
            start = a + 1;
            if mask[a] {
                continue;
            }
            // Macro names need a left identifier boundary (`derive_panic!`
            // is not `panic!`).
            if pat.ends_with(b"!") && a > 0 && scan::is_ident(cleaned[a - 1]) {
                continue;
            }
            let shown = String::from_utf8_lossy(pat).into_owned();
            emit(
                out,
                "panic-budget",
                path,
                orig,
                cleaned,
                a,
                format!(
                    "{shown} in non-test code (panic budget: convert to a typed error \
                     or add a vetted lint.allow entry)"
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Fixture-based negative tests: each rule must catch a seeded violation.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> Vec<Finding> {
        let orig = src.as_bytes();
        let cleaned = scan::clean(orig);
        let mask = scan::test_mask(&cleaned);
        let mut out = Vec::new();
        run_all(path, orig, &cleaned, &mask, &mut out);
        out
    }

    fn rules_of(fs: &[Finding]) -> Vec<&'static str> {
        fs.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn rng_rule_catches_magic_stream() {
        let bad = "fn f(seed: u64) { let r = Pcg64::new(seed, 1234); }";
        let fs = run("src/workload/mod.rs", bad);
        assert_eq!(rules_of(&fs), ["rng-streams"], "{fs:?}");
        assert_eq!(fs[0].line, 1);

        let good = "fn f(seed: u64) { let r = Pcg64::new(seed, streams::DES_LINK_JITTER); }";
        assert!(run("src/workload/mod.rs", good).is_empty());

        let bad_fork = "fn f(r: &mut Pcg64) { let c = r.fork(3); }";
        assert_eq!(rules_of(&run("src/simnet/mod.rs", bad_fork)), ["rng-streams"]);

        // Test code is exempt, and the registry file itself is exempt.
        let in_test = "#[cfg(test)]\nmod tests { fn t() { let r = Pcg64::new(1, 0); } }";
        assert!(run("src/workload/mod.rs", in_test).is_empty());
        assert!(run("src/util/rng.rs", bad).is_empty());
    }

    #[test]
    fn clock_rule_catches_wallclock_outside_drivers() {
        let bad = "fn f() { let t = Instant::now(); }";
        let fs = run("src/coordinator/worker.rs", bad);
        assert_eq!(rules_of(&fs), ["clock-purity"], "{fs:?}");

        // Allowed files and test code pass; string/comment mentions pass.
        assert!(run("src/coordinator/rt.rs", bad).is_empty());
        assert!(run("src/coordinator/clock.rs", bad).is_empty());
        assert!(run("src/util/logging.rs", bad).is_empty());
        let in_test = "#[test]\nfn t() { let t = Instant::now(); }";
        assert!(run("src/coordinator/worker.rs", in_test).is_empty());
        let in_str = "fn f() { let s = \"Instant::now\"; } // Instant";
        assert!(run("src/coordinator/worker.rs", in_str).is_empty());
    }

    #[test]
    fn wire_rule_catches_stray_byte_charging() {
        let bad = "fn f(e: &Envelope) -> usize { e.encoded_bytes() }";
        let fs = run("src/sched/batch.rs", bad);
        assert_eq!(rules_of(&fs), ["wire-charge"], "{fs:?}");

        // In net/ it's the contract itself.
        assert!(run("src/net/mod.rs", bad).is_empty());
        // Driver choke points may call it...
        assert!(run("src/coordinator/worker.rs", bad).is_empty());
        // ...but not do arithmetic on it.
        let arith = "fn f(e: &Envelope) -> usize { e.encoded_bytes() + 4 }";
        assert_eq!(rules_of(&run("src/coordinator/worker.rs", arith)), ["wire-charge"]);
        let arith_left = "fn f(e: &Envelope) -> usize { 4 + e.encoded_bytes() }";
        assert_eq!(rules_of(&run("src/coordinator/sim.rs", arith_left)), ["wire-charge"]);
        // `->` after the call is a return type, not subtraction.
        let method = "fn g(e: &Envelope) { let f = |x: usize| e.encoded_bytes() -> usize; }";
        assert!(run("src/coordinator/worker.rs", method).is_empty());
        // Re-export lines are exempt everywhere.
        let reexport = "pub use crate::net::{Envelope, ENVELOPE_HEADER_BYTES, RESULT_BYTES};";
        assert!(run("src/coordinator/mod.rs", reexport).is_empty());
    }

    #[test]
    fn wire_rule_catches_payload_copies_outside_the_wire() {
        let copy = "fn f(t: &Tensor) -> Vec<f32> { t.data().to_vec() }";
        let fs = run("src/coordinator/worker.rs", copy);
        assert_eq!(rules_of(&fs), ["wire-charge"], "{fs:?}");
        assert!(fs[0].msg.contains("payload copy"), "{}", fs[0].msg);

        let consume = "fn f(t: Tensor) -> Vec<f32> { t.into_data() }";
        assert_eq!(rules_of(&run("src/policy/mod.rs", consume)), ["wire-charge"]);

        // The escape hatches' home, engines, and the wire codec may copy.
        assert!(run("src/tensor/mod.rs", copy).is_empty());
        assert!(run("src/runtime/sim_engine.rs", consume).is_empty());
        assert!(run("src/net/wire.rs", copy).is_empty());

        // Test code, use lines, and unrelated identifiers are exempt.
        let in_test = "#[cfg(test)]\nmod tests { fn t(x: &Tensor) { x.data().to_vec(); } }";
        assert!(run("src/coordinator/worker.rs", in_test).is_empty());
        let reexport = "pub use crate::tensor::into_data;";
        assert!(run("src/coordinator/mod.rs", reexport).is_empty());
        let other_ident = "fn f() { let turn_into_database = 1; }";
        assert!(run("src/coordinator/worker.rs", other_ident).is_empty());
    }

    #[test]
    fn telemetry_rule_catches_rng_and_clock() {
        let bad = "fn f(rng: &mut Pcg64) { rng.next_u64(); }";
        let fs = run("src/telemetry/mod.rs", bad);
        assert!(
            fs.iter().all(|f| f.rule == "telemetry-purity") && !fs.is_empty(),
            "{fs:?}"
        );
        let clocky = "fn f() { let t = Instant::now(); }";
        assert!(!run("src/telemetry/metrics.rs", clocky).is_empty());
        // Other modules are out of scope for this rule; telemetry test
        // code is exempt.
        assert!(run("src/routing/mod.rs", bad)
            .iter()
            .all(|f| f.rule != "telemetry-purity"));
        let in_test = "#[cfg(test)]\nmod tests { fn t(rng: &mut Pcg64) {} }";
        assert!(run("src/telemetry/mod.rs", in_test).is_empty());
    }

    #[test]
    fn panic_rule_catches_unwraps_in_covered_dirs() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let fs = run("src/policy/alg2.rs", bad);
        assert_eq!(rules_of(&fs), ["panic-budget"], "{fs:?}");
        for pat_src in [
            "fn f(x: Option<u32>) -> u32 { x.expect(\"set\") }",
            "fn f() { panic!(\"boom\"); }",
            "fn f() { unreachable!() }",
            "fn f() { todo!() }",
        ] {
            assert_eq!(rules_of(&run("src/net/mod.rs", pat_src)), ["panic-budget"], "{pat_src}");
        }
        // The elastic control plane is decision-critical too.
        assert_eq!(rules_of(&run("src/cluster/health.rs", bad)), ["panic-budget"], "{bad}");
        // Out-of-scope dirs and test code are exempt.
        assert!(run("src/simnet/transport.rs", bad).is_empty());
        let in_test = "#[cfg(test)]\nmod tests { fn t() { Some(1).unwrap(); } }";
        assert!(run("src/sched/batch.rs", in_test).is_empty());
    }

    #[test]
    fn registry_check_catches_overlaps_and_missing_spans() {
        let good = b"pub mod streams {\n\
            pub const A_BASE: u64 = 100;\n\
            pub const A_SPAN: u64 = 900;\n\
            pub const B: u64 = 1000;\n\
        }";
        let mut out = Vec::new();
        check_registry(good, &mut out);
        assert!(out.is_empty(), "{out:?}");

        // B = 999 falls inside [100, 1000).
        let overlap = b"pub mod streams {\n\
            pub const A_BASE: u64 = 100;\n\
            pub const A_SPAN: u64 = 900;\n\
            pub const B: u64 = 999;\n\
        }";
        let mut out = Vec::new();
        check_registry(overlap, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].msg.contains("overlap"), "{}", out[0].msg);

        let no_span = b"pub mod streams {\n\
            pub const A_BASE: u64 = 100;\n\
        }";
        let mut out = Vec::new();
        check_registry(no_span, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].msg.contains("A_SPAN"), "{}", out[0].msg);

        let mut out = Vec::new();
        check_registry(b"fn nothing_here() {}", &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].msg.contains("missing"), "{}", out[0].msg);
    }
}
