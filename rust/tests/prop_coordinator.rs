//! Property tests on the coordinator invariants (DESIGN.md §7), using the
//! in-tree mini-framework (`testkit::prop` — offline proptest substitute).

use mdi_exit::coordinator::task::Task;
use mdi_exit::coordinator::{AdmissionMode, Driver, ExperimentConfig, ModelMeta, Run};
use mdi_exit::dataset::ExitTable;
use mdi_exit::policy::{
    self, AdaptConfig, BaselineExit, BaselineOffload, ExitCtx, ExitDecision, ExitPolicy,
    NeighborSummary, NeighborView, OffloadCtx, OffloadKind, OffloadPolicy, OffloadRule,
    RateController, ThresholdController,
};
use mdi_exit::runtime::sim_engine::SimEngine;
use mdi_exit::testkit::prop::{F64In, Gen, Prop, UsizeIn, Verdict};
use mdi_exit::util::rng::Pcg64;

/// Composite generator for Alg. 1 inputs.
struct Alg1Case;
impl Gen for Alg1Case {
    type Out = (f32, f32, bool, usize, usize, usize);
    fn sample(&self, rng: &mut Pcg64) -> Self::Out {
        (
            rng.range_f64(0.0, 1.0) as f32,
            rng.range_f64(0.0, 1.0) as f32,
            rng.chance(0.2),
            rng.below(100) as usize,
            rng.below(100) as usize,
            rng.below(80) as usize,
        )
    }
}

#[test]
fn prop_alg1_decision_table() {
    Prop::new("alg1 complete decision table").cases(2000).run(
        &Alg1Case,
        |&(conf, th, is_final, i_len, o_len, t_o)| {
            let d = policy::alg1_decide(conf, th, is_final, i_len, o_len, t_o);
            let want = if is_final || conf > th {
                ExitDecision::Exit
            } else if i_len == 0 || o_len > t_o {
                ExitDecision::ContinueLocal
            } else {
                ExitDecision::ContinueOffload
            };
            Verdict::check(d == want, || {
                format!("({conf},{th},{is_final},{i_len},{o_len},{t_o}) -> {d:?}, want {want:?}")
            })
        },
    );
}

struct Alg2Case;
impl Gen for Alg2Case {
    type Out = (usize, usize, f64, NeighborView, u64);
    fn sample(&self, rng: &mut Pcg64) -> Self::Out {
        (
            rng.below(60) as usize,
            rng.below(60) as usize,
            rng.range_f64(1e-4, 0.05),
            NeighborView {
                input_len: rng.below(60) as usize,
                gamma_s: rng.range_f64(1e-4, 0.05),
                d_nm_s: rng.range_f64(0.0, 0.05),
            },
            rng.next_u64(),
        )
    }
}

#[test]
fn prop_alg2_gate_is_strict() {
    // Whatever the delays, O_n <= I_m must never offload (paper line 2/4).
    Prop::new("alg2 queue gate").cases(2000).run(
        &Alg2Case,
        |&(o_len, i_len, gamma, view, seed)| {
            if o_len > view.input_len {
                return Verdict::Pass; // gate open: either branch is legal
            }
            let mut rng = Pcg64::new(seed, 9);
            let went = policy::alg2_should_offload(o_len, i_len, gamma, &view, &mut rng);
            Verdict::check(!went, || {
                format!("offloaded with O_n={o_len} <= I_m={}", view.input_len)
            })
        },
    );
}

#[test]
fn prop_alg2_deterministic_branch_always_fires() {
    // When local wait strictly exceeds remote wait and the gate is open,
    // Alg. 2 must offload with probability 1 (line 3).
    Prop::new("alg2 deterministic branch").cases(2000).run(
        &Alg2Case,
        |&(o_len, i_len, gamma, view, seed)| {
            let local = i_len as f64 * gamma;
            let remote = view.d_nm_s + view.input_len as f64 * view.gamma_s;
            if o_len <= view.input_len || local <= remote {
                return Verdict::Pass;
            }
            let mut rng = Pcg64::new(seed, 9);
            let went = policy::alg2_should_offload(o_len, i_len, gamma, &view, &mut rng);
            Verdict::check(went, || {
                format!("local {local} > remote {remote} but did not offload")
            })
        },
    );
}

#[test]
fn prop_rate_controller_bounded_under_any_inputs() {
    Prop::new("alg3 mu bounded").cases(200).run(
        &mdi_exit::testkit::prop::VecOf(UsizeIn(0, 500), 64),
        |qs| {
            let mut rc = RateController::new(AdaptConfig::default(), 0.5);
            for &q in qs {
                let mu = rc.update(q);
                if !(1e-4..=60.0).contains(&mu) || !mu.is_finite() {
                    return Verdict::Fail(format!("mu escaped bounds: {mu}"));
                }
            }
            Verdict::Pass
        },
    );
}

#[test]
fn prop_threshold_controller_bounded() {
    Prop::new("alg4 t_e in [t_min, 1]").cases(200).run(
        &mdi_exit::testkit::prop::VecOf(UsizeIn(0, 500), 64),
        |qs| {
            let mut tc = ThresholdController::new(AdaptConfig::default(), 0.8, 0.05);
            for &q in qs {
                let te = tc.update(q);
                if !(0.05..=1.0).contains(&te) {
                    return Verdict::Fail(format!("t_e escaped bounds: {te}"));
                }
            }
            Verdict::Pass
        },
    );
}

#[test]
fn prop_alg3_direction_matches_occupancy() {
    // μ must decrease when queues are under T_Q1 and increase above T_Q2.
    Prop::new("alg3 monotone response").cases(500).run(&UsizeIn(0, 200), |&q| {
        let cfg = AdaptConfig::default();
        let mut rc = RateController::new(cfg, 1.0);
        let mu0 = rc.mu_s();
        let mu1 = rc.update(q);
        let ok = if q < cfg.t_q1 {
            mu1 < mu0
        } else if q > cfg.t_q2 {
            mu1 > mu0
        } else if q > cfg.t_q1 && q < cfg.t_q2 {
            mu1 < mu0
        } else {
            (mu1 - mu0).abs() < 1e-12
        };
        Verdict::check(ok, || format!("q={q}: mu {mu0} -> {mu1}"))
    });
}

// ---------------------------------------------------------------------------
// The policy seam: Baseline is bit-for-bit the pre-refactor functions
// ---------------------------------------------------------------------------

/// A random worker decision state: queue lengths, Γ_n, a neighbor set with
/// random gossiped views, and an RNG seed.
struct SeamCase;
impl Gen for SeamCase {
    #[allow(clippy::type_complexity)]
    type Out = (usize, usize, f64, Vec<(usize, NeighborSummary)>, u64, usize);
    fn sample(&self, rng: &mut Pcg64) -> Self::Out {
        let n_neighbors = rng.below(5) as usize;
        let candidates = (0..n_neighbors)
            .map(|i| {
                let mut s = NeighborSummary::base(
                    rng.below(60) as usize,
                    rng.range_f64(1e-4, 0.05),
                    0.9,
                );
                s.d_nm_s = rng.range_f64(0.0, 0.05);
                (i + 1, s)
            })
            .collect();
        (
            rng.below(60) as usize,        // output_len
            rng.below(60) as usize,        // input_len
            rng.range_f64(1e-4, 0.05),     // gamma
            candidates,
            rng.next_u64(),                // decision-RNG seed
            rng.below(4) as usize,         // rule index
        )
    }
}

/// The pre-refactor offload scan, straight-line: shuffle the neighbor ids,
/// walk them in shuffled order, first acceptance by the pure rule wins.
/// This is literally the loop `WorkerCore::try_offload` used to inline.
fn reference_scan(
    rule: OffloadRule,
    output_len: usize,
    input_len: usize,
    gamma: f64,
    candidates: &[(usize, NeighborSummary)],
    rng: &mut Pcg64,
) -> Option<usize> {
    let mut scan: Vec<usize> = candidates.iter().map(|(m, _)| *m).collect();
    rng.shuffle(&mut scan);
    for &m in &scan {
        let view = candidates.iter().find(|(c, _)| *c == m).expect("candidate").1.view();
        if policy::offload_decide(rule, output_len, input_len, gamma, &view, rng) {
            return Some(m);
        }
    }
    None
}

#[test]
fn prop_baseline_offload_is_bit_for_bit_the_seed_scan() {
    let rules = [
        OffloadRule::Alg2,
        OffloadRule::Deterministic,
        OffloadRule::QueueOnly,
        OffloadRule::RoundRobin,
    ];
    Prop::new("BaselineOffload == pre-refactor scan (incl. RNG stream)").cases(2000).run(
        &SeamCase,
        |(output_len, input_len, gamma, candidates, seed, ri)| {
            let rule = rules[*ri];
            // Two RNGs cloned from the same state: the policy must consume
            // the stream exactly as the inlined scan did, so a *sequence*
            // of decisions stays aligned too.
            let mut rng_policy = Pcg64::new(*seed, 1000);
            let mut rng_ref = Pcg64::new(*seed, 1000);
            let task = Task::initial(1, 0, None, 0.0);
            let mut p = BaselineOffload::new(rule);
            for round in 0..3 {
                let ctx = OffloadCtx {
                    now: round as f64,
                    task: &task,
                    input_len: *input_len,
                    output_len: *output_len,
                    gamma_s: *gamma,
                    candidates,
                    next_hop: &[],
                };
                let got = p.choose(&ctx, &mut rng_policy);
                let want = reference_scan(
                    rule,
                    *output_len,
                    *input_len,
                    *gamma,
                    candidates,
                    &mut rng_ref,
                );
                if got != want {
                    return Verdict::Fail(format!(
                        "{rule:?} round {round}: policy {got:?} != reference {want:?} \
                         (O_n={output_len}, I_n={input_len}, {} candidates)",
                        candidates.len()
                    ));
                }
            }
            Verdict::Pass
        },
    );
}

#[test]
fn prop_baseline_exit_is_bit_for_bit_alg1() {
    Prop::new("BaselineExit == alg1_decide").cases(2000).run(
        &Alg1Case,
        |&(conf, th, is_final, i_len, o_len, t_o)| {
            let got = BaselineExit.decide(&ExitCtx {
                confidence: conf,
                threshold: th,
                is_final,
                input_len: i_len,
                output_len: o_len,
                t_o,
                now: 0.0,
                class: 0,
                deadline: 1.0,
            });
            let want = policy::alg1_decide(conf, th, is_final, i_len, o_len, t_o);
            Verdict::check(got == want, || format!("{got:?} != {want:?}"))
        },
    );
}

// ---------------------------------------------------------------------------
// Whole-system invariants under randomized configurations
// ---------------------------------------------------------------------------

struct SysCase;
impl Gen for SysCase {
    type Out = (usize, f64, f32, u64, usize);
    fn sample(&self, rng: &mut Pcg64) -> Self::Out {
        (
            rng.below(5) as usize,                 // topology index
            rng.range_f64(20.0, 400.0),            // rate
            rng.range_f64(0.3, 0.99) as f32,       // threshold
            rng.next_u64(),                        // seed
            rng.below(3) as usize,                 // policy index
        )
    }
}

fn synthetic_engine(n: usize) -> (SimEngine, Vec<u8>) {
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
    let mut rng = Pcg64::new(99, 0);
    for &l in &labels {
        let c1 = rng.range_f64(0.2, 1.0) as f32;
        let c2 = (c1 + 0.2).min(1.0);
        let c3 = 1.0f32;
        conf.extend([c1, c2, c3]);
        // earlier exits sometimes wrong
        let p1 = if c1 > 0.6 { l } else { (l + 1) % 10 };
        pred.extend([p1, l, l]);
    }
    (SimEngine::from_table(ExitTable::synthetic(n, 3, conf, pred), false), labels)
}

#[test]
fn prop_simulation_conservation_and_sanity() {
    let topos = ["local", "2-node", "3-node-mesh", "3-node-circular", "5-node-mesh"];
    let policies = [OffloadKind::Alg2, OffloadKind::Deterministic, OffloadKind::QueueOnly];
    let (engine, labels) = synthetic_engine(256);
    Prop::new("simulation invariants").cases(40).run(
        &SysCase,
        |&(ti, rate, threshold, seed, pi)| {
            let mut cfg = ExperimentConfig::new(
                "prop",
                topos[ti],
                AdmissionMode::Fixed { rate_hz: rate, threshold },
            );
            cfg.policy.offload = policies[pi];
            cfg.duration_s = 10.0;
            cfg.warmup_s = 0.0;
            cfg.seed = seed;
            let meta =
                ModelMeta::synthetic(vec![0.002, 0.002, 0.002], vec![12288, 8192, 4096]);
            let r = match Run::builder()
                .config(cfg)
                .model(meta)
                .engine(&engine)
                .labels(&labels)
                .driver(Driver::Des)
                .execute()
            {
                Ok(r) => r,
                Err(e) => return Verdict::Fail(format!("run failed: {e:#}")),
            };
            // results never exceed admissions
            if r.completed > r.admitted {
                return Verdict::Fail(format!(
                    "completed {} > admitted {}",
                    r.completed, r.admitted
                ));
            }
            // exit histogram accounts for every completion
            let hist_sum: u64 = r.exit_histogram.iter().sum();
            if hist_sum != r.completed {
                return Verdict::Fail(format!(
                    "exit histogram {hist_sum} != completed {}",
                    r.completed
                ));
            }
            if !(0.0..=1.0).contains(&r.accuracy()) {
                return Verdict::Fail(format!("accuracy {}", r.accuracy()));
            }
            // per-worker processing also conserves: every completion was
            // processed at least once
            let processed: u64 = r.per_worker.iter().map(|w| w.processed).sum();
            if processed < r.completed {
                return Verdict::Fail(format!(
                    "processed {processed} < completed {}",
                    r.completed
                ));
            }
            Verdict::Pass
        },
    );
}

#[test]
fn prop_no_ee_exits_only_at_final() {
    let (engine, labels) = synthetic_engine(128);
    Prop::new("no-EE final-exit only").cases(20).run(&F64In(30.0, 200.0), |&rate| {
        let mut cfg = ExperimentConfig::new(
            "prop",
            "3-node-mesh",
            AdmissionMode::Fixed { rate_hz: rate, threshold: 0.5 },
        );
        cfg.no_early_exit = true;
        cfg.duration_s = 8.0;
        cfg.warmup_s = 0.0;
        let meta = ModelMeta::synthetic(vec![0.002, 0.002, 0.002], vec![12288, 8192, 4096]);
        let r = Run::builder()
            .config(cfg)
            .model(meta)
            .engine(&engine)
            .labels(&labels)
            .execute()
            .unwrap();
        let early: u64 = r.exit_histogram[..2].iter().sum();
        Verdict::check(early == 0, || format!("early exits under no-EE: {:?}", r.exit_histogram))
    });
}
