//! Integration: the realtime threaded driver (wallclock, simnet transport)
//! with the oracle engine, driven through the `Run` builder — fast enough
//! for CI, same code path as the XLA-backed examples.

use anyhow::Result;

use mdi_exit::artifact::Manifest;
use mdi_exit::coordinator::{AdmissionMode, Driver, ExperimentConfig, ModelMeta, Run, RunReport};
use mdi_exit::dataset::Dataset;
use mdi_exit::runtime::sim_engine::SimEngine;
use mdi_exit::runtime::InferenceEngine;

fn setup() -> Option<(Manifest, Dataset)> {
    let manifest = match Manifest::load(mdi_exit::artifacts_dir()) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("artifacts missing; skipping");
            return None;
        }
    };
    let ds = Dataset::load(manifest.path(&manifest.dataset.file)).expect("dataset");
    Some((manifest, ds))
}

fn run(topology: &str, admission: AdmissionMode, seconds: f64) -> Option<RunReport> {
    let (manifest, ds) = setup()?;
    let info = manifest.model("mobilenetv2l").unwrap();
    let meta = ModelMeta::from_manifest(info);
    let mut cfg = ExperimentConfig::new("mobilenetv2l", topology, admission);
    cfg.duration_s = seconds;
    cfg.warmup_s = 0.5;
    cfg.adapt.sleep_s = 0.2;
    let mref = &manifest;
    let costs: Vec<f64> = info.stages.iter().map(|s| s.cost_ms / 1e3).collect();
    let factory = move |_w: usize| -> Result<Box<dyn InferenceEngine>> {
        // oracle engine + wallclock compute emulation at the manifest costs
        let eng = SimEngine::load(mref, "mobilenetv2l", false)?
            .with_costs(costs.clone(), 1.0);
        Ok(Box::new(eng) as Box<dyn InferenceEngine>)
    };
    let report = Run::builder()
        .config(cfg)
        .model(meta)
        .engine_factory(factory)
        .dataset(&ds)
        .driver(Driver::Realtime)
        .execute()
        .expect("realtime run");
    Some(report)
}

#[test]
fn realtime_local_completes_with_high_accuracy() {
    let Some(r) = run("local", AdmissionMode::Fixed { rate_hz: 200.0, threshold: 0.9 }, 2.0)
    else {
        return;
    };
    assert!(r.completed > 100, "completed {}", r.completed);
    assert!(r.accuracy() > 0.8, "accuracy {}", r.accuracy());
    let hist: u64 = r.exit_histogram.iter().sum();
    assert_eq!(hist, r.completed);
}

#[test]
fn realtime_mesh_distributes_work() {
    let Some(r) =
        run("3-node-mesh", AdmissionMode::Fixed { rate_hz: 3000.0, threshold: 0.95 }, 3.0)
    else {
        return;
    };
    assert!(r.completed > 500, "completed {}", r.completed);
    // overloaded source must have offloaded to both neighbors
    assert!(
        r.per_worker[0].offloaded_out > 0,
        "no offloading happened: {:?}",
        r.per_worker.iter().map(|w| w.processed).collect::<Vec<_>>()
    );
    let remote: u64 = r.per_worker[1..].iter().map(|w| w.processed).sum();
    assert!(remote > 0, "neighbors never processed tasks");
}

#[test]
fn realtime_rate_adaptation_settles() {
    let Some(r) = run(
        "2-node",
        AdmissionMode::AdaptiveRate { threshold: 0.9, initial_mu_s: 0.1 },
        3.0,
    ) else {
        return;
    };
    assert!(r.completed > 50, "completed {}", r.completed);
    let mu = r.final_mu_s.expect("controller state");
    assert!((1e-4..60.0).contains(&mu));
}

#[test]
fn realtime_default_factory_comes_from_manifest() {
    // No explicit engine factory: the builder falls back to oracle replay
    // with cost emulation derived from the manifest.
    let Some((manifest, _ds)) = setup() else { return };
    let mut cfg = ExperimentConfig::new(
        "mobilenetv2l",
        "local",
        AdmissionMode::Fixed { rate_hz: 100.0, threshold: 0.9 },
    );
    cfg.duration_s = 1.5;
    cfg.warmup_s = 0.25;
    let r = Run::builder()
        .config(cfg)
        .manifest(&manifest)
        .driver(Driver::Realtime)
        .execute()
        .expect("realtime run");
    assert!(r.completed > 20, "completed {}", r.completed);
}
