//! Telemetry integration: both drivers run the same `WorkerCore` with a
//! recorder installed, so the traces they emit must (a) be structurally
//! valid Chrome trace-event JSON, (b) reproduce the run's report
//! aggregates from the metrics timeline, (c) be bit-identical across DES
//! reruns on the same seed — and never perturb the run itself — and
//! (d) tell the same per-task story on both drivers.
//!
//! Entirely engine- and artifact-free, like `cross_driver.rs`: a
//! synthetic oracle table drives both runs through the `Run` builder.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::Result;

use mdi_exit::coordinator::{
    AdmissionMode, Driver, ExperimentConfig, ModelMeta, Run, RunReport,
};
use mdi_exit::dataset::{Dataset, ExitTable};
use mdi_exit::runtime::sim_engine::SimEngine;
use mdi_exit::runtime::InferenceEngine;
use mdi_exit::simnet::ChurnEvent;
use mdi_exit::telemetry::{
    validate_chrome_trace, SpanKind, TelemetryData, TelemetryEvent,
};
use mdi_exit::util::json::Json;

/// Realtime runs busy-spin one thread per worker; serialize them so they
/// don't starve each other on small CI runners (same idiom as
/// `cross_driver.rs`).
static WALLCLOCK: Mutex<()> = Mutex::new(());

fn serialized() -> std::sync::MutexGuard<'static, ()> {
    WALLCLOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// 8 samples x 2 exits: even samples confident at exit 1, odd samples
/// only at exit 2 — a deterministic 50/50 exit split.
fn oracle() -> (ExitTable, Vec<u8>) {
    let n = 8;
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
    for i in 0..n {
        if i % 2 == 0 {
            conf.extend([0.97f32, 0.99]);
        } else {
            conf.extend([0.30f32, 0.95]);
        }
        pred.extend([labels[i], labels[i]]);
    }
    (ExitTable::synthetic(n, 2, conf, pred), labels)
}

fn meta() -> ModelMeta {
    ModelMeta::synthetic(vec![0.002, 0.003], vec![12288, 8192])
}

/// Stage-3-heavy costs on a 3-exit oracle: overloading a line pushes
/// continuing work multiple hops out, so traces carry task, result-relay,
/// and gossip wire legs.
const COSTS3: [f64; 3] = [0.001, 0.001, 0.006];

fn oracle3() -> (ExitTable, Vec<u8>) {
    let n = 8;
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
    for i in 0..n {
        if i % 4 == 0 {
            conf.extend([0.97f32, 0.99, 1.0]);
        } else {
            conf.extend([0.30f32, 0.50, 0.95]);
        }
        pred.extend([labels[i]; 3]);
    }
    (ExitTable::synthetic(n, 3, conf, pred), labels)
}

fn meta3() -> ModelMeta {
    ModelMeta::synthetic(COSTS3.to_vec(), vec![12288, 8192, 4096])
}

fn cfg(topology: &str, rate_hz: f64, seconds: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(
        "tiny",
        topology,
        AdmissionMode::Fixed { rate_hz, threshold: 0.9 },
    );
    cfg.duration_s = seconds;
    cfg.warmup_s = 0.5;
    cfg.seed = 7;
    cfg
}

fn traced(mut cfg: ExperimentConfig) -> ExperimentConfig {
    cfg.telemetry.spans = true;
    cfg.telemetry.metrics = true;
    cfg.telemetry.interval_s = 0.5;
    cfg
}

fn run_des(cfg: ExperimentConfig, labels: &[u8]) -> RunReport {
    let (table, _) = oracle();
    let engine = SimEngine::from_table(table, false);
    Run::builder()
        .config(cfg)
        .model(meta())
        .engine(&engine)
        .labels(labels)
        .driver(Driver::Des)
        .execute()
        .expect("DES run")
}

fn run_rt(cfg: ExperimentConfig, labels: &[u8]) -> RunReport {
    let ds = Dataset::synthetic(labels.len(), 2, 2, 3, labels.to_vec());
    let m = meta();
    let costs = m.stage_cost_s.clone();
    let factory = move |_w: usize| -> Result<Box<dyn InferenceEngine>> {
        let (table, _) = oracle();
        let eng = SimEngine::from_table(table, false).with_costs(costs.clone(), 1.0);
        Ok(Box::new(eng) as Box<dyn InferenceEngine>)
    };
    Run::builder()
        .config(cfg)
        .model(m)
        .engine_factory(factory)
        .dataset(&ds)
        .driver(Driver::Realtime)
        .execute()
        .expect("realtime run")
}

fn run_des3(cfg: ExperimentConfig, labels: &[u8]) -> RunReport {
    let (table, _) = oracle3();
    let engine = SimEngine::from_table(table, false);
    Run::builder()
        .config(cfg)
        .model(meta3())
        .engine(&engine)
        .labels(labels)
        .driver(Driver::Des)
        .execute()
        .expect("DES run")
}

/// Per-task span-kind sequences, in recording order (task 0 = envelopes
/// that are not task-scoped: results, gossip).
fn signatures(data: &TelemetryData) -> BTreeMap<u64, Vec<SpanKind>> {
    let mut by_task: BTreeMap<u64, Vec<SpanKind>> = BTreeMap::new();
    for s in &data.spans {
        if s.task != 0 {
            by_task.entry(s.task).or_default().push(s.kind);
        }
    }
    by_task
}

#[test]
fn traced_des_line4_emits_perfetto_valid_chrome_trace() {
    let (_, labels) = oracle3();
    // Overloaded line-4 on the stage-3-heavy model: offloads, multi-hop
    // result relays, and gossip all hit the wire, so the trace must carry
    // every span family the exporter knows.
    let r = run_des3(traced(cfg("line-4", 900.0, 6.0)), &labels);
    let data = r.telemetry.as_ref().expect("traced run returns telemetry");
    assert!(!data.spans.is_empty(), "no spans collected");

    let trace = data.chrome_trace();
    let n = validate_chrome_trace(&trace).expect("schema-valid Chrome trace");
    assert_eq!(n, data.spans.len(), "one complete event per span");
    // Survives its own serializer: what `--trace` writes is what Perfetto
    // loads.
    let parsed = Json::parse(&trace.to_string()).expect("serialized trace parses");
    assert_eq!(validate_chrome_trace(&parsed), Ok(n), "valid after round-trip");

    use SpanKind::*;
    for kind in [Admit, QueueWait, Compute, Exit, Continue, WireTask, WireResult, WireGossip]
    {
        assert!(
            data.spans.iter().any(|s| s.kind == kind),
            "trace is missing {kind:?} spans"
        );
    }
    // Wire legs live on the sender's process and name their receiver.
    for s in &data.spans {
        assert!(s.t1 >= s.t0, "span {:?} runs backwards", s.kind);
        match s.kind {
            WireTask | WireResult | WireRehome | WireGossip => {
                assert_ne!(s.peer, usize::MAX, "wire span without a peer");
                assert_ne!(s.peer, s.worker, "wire span to self");
            }
            _ => assert_eq!(s.peer, usize::MAX, "{:?} span with a peer", s.kind),
        }
    }
}

#[test]
fn metrics_timeline_folds_to_des_report_aggregates() {
    let (_, labels) = oracle3();
    let r = run_des3(traced(cfg("line-4", 700.0, 6.0)), &labels);
    let data = r.telemetry.as_ref().expect("traced run returns telemetry");
    assert!(!data.metrics.is_empty(), "no metrics rows sampled");

    // The acceptance identity: fold each worker's final row and land
    // exactly on the report's aggregates — same counters, same warmup
    // window, same closing sample at the horizon.
    assert_eq!(
        data.folded_totals(),
        (r.admitted, r.completed, r.bytes_on_wire),
        "folded metrics diverge from the run report"
    );

    // Every worker sampled on the cadence (warmup + 6 s at 0.5 s/sample,
    // plus the closing row).
    for w in 0..4 {
        let rows = data.metrics.iter().filter(|m| m.worker == w).count();
        assert!(rows >= 10, "worker {w} sampled only {rows} rows");
    }

    // The JSONL export parses line by line and is ordered by (t_s, worker).
    let jsonl = data.metrics_jsonl();
    let mut prev = (f64::NEG_INFINITY, 0usize);
    let mut rows = 0;
    for line in jsonl.lines() {
        let v = Json::parse(line).expect("every JSONL line parses");
        if v.get("kind").as_str() != Some("metrics") {
            continue;
        }
        rows += 1;
        let key = (
            v.get("t_s").as_f64().expect("t_s"),
            v.get("worker").as_i64().expect("worker") as usize,
        );
        assert!(key >= prev, "rows out of order: {key:?} after {prev:?}");
        prev = key;
    }
    assert_eq!(rows, data.metrics.len(), "JSONL row count");
}

#[test]
fn metrics_identity_holds_on_the_realtime_driver() {
    let _g = serialized();
    let (_, labels) = oracle();
    let mut c = cfg("3-node-mesh", 300.0, 2.5);
    c.telemetry.metrics = true;
    c.telemetry.interval_s = 0.25;
    let r = run_rt(c, &labels);
    let data = r.telemetry.as_ref().expect("metrics run returns telemetry");
    assert!(!data.metrics.is_empty(), "no metrics rows sampled");

    let (admitted, completed, wire_bytes) = data.folded_totals();
    // Admissions are stamped with their *scheduled* time on both the
    // tally and the recorder, and wire bytes mirror the same core
    // counter, so these two are exact even on wallclock.
    assert_eq!(admitted, r.admitted, "admitted diverged");
    assert_eq!(wire_bytes, r.bytes_on_wire, "wire bytes diverged");
    // Completions are clocked twice a few microseconds apart (core
    // handler vs driver bookkeeping); allow the warmup boundary to split
    // at most a couple of them.
    assert!(
        (completed as i64 - r.completed as i64).abs() <= 2,
        "completed diverged: folded {completed} vs report {}",
        r.completed
    );
}

#[test]
fn des_trace_is_deterministic_and_does_not_perturb_the_run() {
    let (_, labels) = oracle();
    // Same seed, same config: the DES records the identical span and
    // metrics sequence, timestamps bit-for-bit.
    let a = run_des(traced(cfg("line-4", 400.0, 6.0)), &labels);
    let b = run_des(traced(cfg("line-4", 400.0, 6.0)), &labels);
    let (da, db) = (
        a.telemetry.as_ref().expect("telemetry"),
        b.telemetry.as_ref().expect("telemetry"),
    );
    assert!(!da.spans.is_empty() && !da.metrics.is_empty());
    assert_eq!(da.spans, db.spans, "span sequences diverged on the same seed");
    assert_eq!(da.metrics, db.metrics, "metrics rows diverged on the same seed");
    assert_eq!(da.dumps, db.dumps, "flight dumps diverged on the same seed");

    // And recording never feeds back: a recorder-free run on the same
    // seed reports the same system, bit for bit.
    let off = run_des(cfg("line-4", 400.0, 6.0), &labels);
    assert!(off.telemetry.is_none(), "untraced run must carry no telemetry");
    assert_eq!(off.admitted, a.admitted);
    assert_eq!(off.completed, a.completed);
    assert_eq!(off.bytes_on_wire, a.bytes_on_wire);
    assert_eq!(off.exit_histogram, a.exit_histogram);
    // The legacy controller/queue timeline is cut from the same snapshot
    // and must not move either.
    assert_eq!(off.trace.len(), a.trace.len());
    for (x, y) in off.trace.iter().zip(&a.trace) {
        assert_eq!(x.t_s, y.t_s);
        assert_eq!(x.control, y.control);
        assert_eq!(x.source_queue, y.source_queue);
    }
}

#[test]
fn des_and_realtime_tell_the_same_per_task_story() {
    let _g = serialized();
    let (_, labels) = oracle();
    // Light load on a single node: every task's life is fully local, so
    // both drivers must produce exactly the same per-task span shapes —
    // admitted tasks that exit at 1, admitted tasks that continue, and
    // the continuation successors that exit at 2.
    let spans_only = |mut c: ExperimentConfig| {
        c.telemetry.spans = true;
        c
    };
    let des = run_des(spans_only(cfg("local", 100.0, 5.0)), &labels);
    let rt = run_rt(spans_only(cfg("local", 100.0, 2.5)), &labels);

    const ADMIT_EXIT: &[SpanKind] =
        &[SpanKind::Admit, SpanKind::QueueWait, SpanKind::Compute, SpanKind::Exit];
    const ADMIT_CONT: &[SpanKind] =
        &[SpanKind::Admit, SpanKind::QueueWait, SpanKind::Compute, SpanKind::Continue];
    const SUCC_EXIT: &[SpanKind] =
        &[SpanKind::QueueWait, SpanKind::Compute, SpanKind::Exit];

    for (name, r) in [("DES", &des), ("realtime", &rt)] {
        let sigs = signatures(r.telemetry.as_ref().expect(name));
        let mut exit1 = 0;
        let mut continued = 0;
        let mut succ = 0;
        for sig in sigs.values() {
            let sig = sig.as_slice();
            match sig.last() {
                // A finished task: its shape must be one of the two
                // canonical local stories, on either driver.
                Some(SpanKind::Exit) => {
                    assert!(
                        sig == ADMIT_EXIT || sig == SUCC_EXIT,
                        "{name}: unexpected completed-task shape {sig:?}"
                    );
                    if sig == ADMIT_EXIT {
                        exit1 += 1;
                    } else {
                        succ += 1;
                    }
                }
                Some(SpanKind::Continue) => {
                    assert_eq!(sig, ADMIT_CONT, "{name}: unexpected continue shape");
                    continued += 1;
                }
                // Tasks truncated by the horizon mid-flight (realtime
                // admits until the last instant) are legal prefixes.
                _ => {}
            }
        }
        assert!(exit1 >= 20, "{name}: only {exit1} exit-at-1 tasks traced");
        assert!(continued >= 20, "{name}: only {continued} continuing tasks traced");
        assert!(succ >= 20, "{name}: only {succ} successor tasks traced");
        // Every successor stems from a continue decision.
        assert!(succ <= continued, "{name}: {succ} successors from {continued} continues");
    }
}

#[test]
fn flight_recorder_dumps_the_events_preceding_a_churn_rehome() {
    let (_, labels) = oracle();
    // Worker 1 leaves mid-run while holding queued work (2-node at ~3x
    // the pair's capacity): its recorder must snapshot the flight ring at
    // the re-home anomaly.
    let mut c = cfg("2-node", 900.0, 4.0);
    c.warmup_s = 0.0;
    c.churn = vec![ChurnEvent { at_s: 1.0, worker: 1, join: false }];
    c.telemetry.spans = true;
    let r = run_des(c, &labels);
    assert!(r.rehomed > 0, "churn produced no re-homing");

    let data = r.telemetry.as_ref().expect("traced run returns telemetry");
    let dump = data
        .dumps
        .iter()
        .find(|d| d.reason.contains("churn-rehome"))
        .expect("churn re-home must dump the flight ring");
    assert_eq!(dump.worker, 1, "the leaving worker owns the dump");
    assert!(!dump.events.is_empty(), "dump carries no context");
    // The anomaly itself closes the ring; everything before it is the
    // context leading up to the incident.
    assert!(
        matches!(dump.events.last(), Some(TelemetryEvent::ChurnRehome { .. })),
        "ring must end with the anomaly event"
    );
    assert!(
        data.metrics_jsonl().contains("churn-rehome"),
        "JSONL export must carry the dump"
    );
}

/// CI artifact hook: when `MDI_TELEMETRY_ARTIFACTS` names a directory,
/// write the traced line-4 run's Chrome trace and metrics JSONL there for
/// upload (no-op otherwise, so local `cargo test` stays read-only).
#[test]
fn emit_ci_artifacts_when_requested() -> Result<()> {
    let Some(dir) = std::env::var_os("MDI_TELEMETRY_ARTIFACTS") else {
        return Ok(());
    };
    let dir = std::path::PathBuf::from(dir);
    std::fs::create_dir_all(&dir)?;
    let (_, labels) = oracle3();
    let r = run_des3(traced(cfg("line-4", 700.0, 6.0)), &labels);
    let data = r.telemetry.expect("traced run returns telemetry");
    validate_chrome_trace(&data.chrome_trace())
        .map_err(|e| anyhow::anyhow!("invalid trace artifact: {e}"))?;
    std::fs::write(dir.join("trace.json"), data.chrome_trace().to_string())?;
    std::fs::write(dir.join("metrics.jsonl"), data.metrics_jsonl())?;
    Ok(())
}
