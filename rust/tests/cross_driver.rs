//! Cross-driver equivalence: the DES and realtime drivers execute the
//! *same* `WorkerCore`, so on the same seed, topology, and oracle table
//! they must report consistent behaviour — exit split, accuracy, offload
//! activity — even though one runs in virtual time and the other on OS
//! threads with real link delays.
//!
//! Entirely engine- and artifact-free: a synthetic oracle table drives
//! both runs through the `Run` builder.

use std::sync::Mutex;

use anyhow::Result;

use mdi_exit::coordinator::{
    AdmissionMode, AeMeta, Driver, ExperimentConfig, Mode, ModelMeta, OffloadKind, Placement,
    Run, RunReport, ENVELOPE_HEADER_BYTES,
};
use mdi_exit::dataset::{Dataset, ExitTable};
use mdi_exit::runtime::sim_engine::SimEngine;
use mdi_exit::runtime::InferenceEngine;
use mdi_exit::sched::{BatchPolicy, CoalesceMode, DisciplineKind};
use mdi_exit::testkit::TensorEngine;
use mdi_exit::workload::ArrivalSpec;

/// The realtime runs busy-spin one thread per worker for cost emulation;
/// running the three tests concurrently starves them of cores on small CI
/// runners and flakes the throughput assertions. Serialize them.
static WALLCLOCK: Mutex<()> = Mutex::new(());

fn serialized() -> std::sync::MutexGuard<'static, ()> {
    WALLCLOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// 8 samples x 2 exits: even samples confident at exit 1 (correct), odd
/// samples only at exit 2 — a deterministic 50/50 exit split.
fn oracle() -> (ExitTable, Vec<u8>) {
    let n = 8;
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
    for i in 0..n {
        if i % 2 == 0 {
            conf.extend([0.97f32, 0.99]);
            pred.extend([labels[i], labels[i]]);
        } else {
            conf.extend([0.30f32, 0.95]);
            pred.extend([labels[i], labels[i]]);
        }
    }
    (ExitTable::synthetic(n, 2, conf, pred), labels)
}

fn meta() -> ModelMeta {
    ModelMeta::synthetic(vec![0.002, 0.003], vec![12288, 8192])
}

/// 8 samples x 3 exits for the multi-hop legs: every fourth sample exits
/// at 1, the rest ride to the final stage. A 2-stage model can never push
/// work past one hop (only final-stage tasks are offloaded, and they spawn
/// no successors), so multi-hop traffic needs a mid-pipeline stage.
fn oracle3() -> (ExitTable, Vec<u8>) {
    let n = 8;
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
    for i in 0..n {
        if i % 4 == 0 {
            conf.extend([0.97f32, 0.99, 1.0]);
        } else {
            conf.extend([0.30f32, 0.50, 0.95]);
        }
        pred.extend([labels[i]; 3]);
    }
    (ExitTable::synthetic(n, 3, conf, pred), labels)
}

/// Stage-3-heavy costs: the final stage is the bottleneck, so continuing
/// work piles up and spills multiple hops down the line.
const COSTS3: [f64; 3] = [0.001, 0.001, 0.006];

fn meta3() -> ModelMeta {
    ModelMeta::synthetic(COSTS3.to_vec(), vec![12288, 8192, 4096])
}

fn run_des3(cfg: ExperimentConfig, labels: &[u8]) -> RunReport {
    let (table, _) = oracle3();
    let engine = SimEngine::from_table(table, false);
    Run::builder()
        .config(cfg)
        .model(meta3())
        .engine(&engine)
        .labels(labels)
        .driver(Driver::Des)
        .execute()
        .expect("DES run")
}

fn run_rt3(cfg: ExperimentConfig, labels: &[u8]) -> RunReport {
    let ds = Dataset::synthetic(labels.len(), 2, 2, 3, labels.to_vec());
    let factory = move |_w: usize| -> Result<Box<dyn InferenceEngine>> {
        let (table, _) = oracle3();
        let eng = SimEngine::from_table(table, false).with_costs(COSTS3.to_vec(), 1.0);
        Ok(Box::new(eng) as Box<dyn InferenceEngine>)
    };
    Run::builder()
        .config(cfg)
        .model(meta3())
        .engine_factory(factory)
        .dataset(&ds)
        .driver(Driver::Realtime)
        .execute()
        .expect("realtime run")
}

fn cfg(topology: &str, rate_hz: f64, seconds: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(
        "tiny",
        topology,
        AdmissionMode::Fixed { rate_hz, threshold: 0.9 },
    );
    cfg.duration_s = seconds;
    cfg.warmup_s = 0.5;
    cfg.seed = 7;
    cfg
}

fn run_des(cfg: ExperimentConfig, labels: &[u8]) -> RunReport {
    let (table, _) = oracle();
    let engine = SimEngine::from_table(table, false);
    Run::builder()
        .config(cfg)
        .model(meta())
        .engine(&engine)
        .labels(labels)
        .driver(Driver::Des)
        .execute()
        .expect("DES run")
}

fn run_rt(cfg: ExperimentConfig, labels: &[u8]) -> RunReport {
    let ds = Dataset::synthetic(labels.len(), 2, 2, 3, labels.to_vec());
    let m = meta();
    let costs = m.stage_cost_s.clone();
    let factory = move |_w: usize| -> Result<Box<dyn InferenceEngine>> {
        let (table, _) = oracle();
        // Wallclock cost emulation at the same per-stage costs the DES
        // charges in virtual time.
        let eng = SimEngine::from_table(table, false).with_costs(costs.clone(), 1.0);
        Ok(Box::new(eng) as Box<dyn InferenceEngine>)
    };
    Run::builder()
        .config(cfg)
        .model(m)
        .engine_factory(factory)
        .dataset(&ds)
        .driver(Driver::Realtime)
        .execute()
        .expect("realtime run")
}

#[test]
fn des_and_realtime_agree_on_exit_split_and_accuracy() {
    let _g = serialized();
    let (_, labels) = oracle();
    // Under-loaded single node: both drivers must complete nearly all
    // admissions with the oracle's deterministic 50/50 exit split.
    let des = run_des(cfg("local", 100.0, 5.0), &labels);
    let rt = run_rt(cfg("local", 100.0, 2.5), &labels);

    assert!(des.completed > 300, "DES completed {}", des.completed);
    assert!(rt.completed > 100, "realtime completed {}", rt.completed);

    let (fd, fr) = (des.exit_fractions(), rt.exit_fractions());
    assert!(
        (fd[0] - fr[0]).abs() < 0.10,
        "exit-1 fraction diverged: DES {fd:?} vs realtime {fr:?}"
    );
    assert!((fd[0] - 0.5).abs() < 0.05, "DES split {fd:?}");
    assert!((fr[0] - 0.5).abs() < 0.05, "realtime split {fr:?}");

    // The oracle predicts the true label at every exit: accuracy 1.0 on
    // both drivers, bit-for-bit.
    assert!((des.accuracy() - 1.0).abs() < 1e-9, "DES accuracy {}", des.accuracy());
    assert!((rt.accuracy() - 1.0).abs() < 1e-9, "realtime accuracy {}", rt.accuracy());
}

#[test]
fn des_and_realtime_agree_on_offload_behaviour() {
    let _g = serialized();
    let (_, labels) = oracle();
    // Overload a 3-node mesh far past one node's capacity (~285 Hz for
    // these costs): both drivers must push work to the neighbors through
    // the same Alg. 2 in the shared core.
    let des = run_des(cfg("3-node-mesh", 900.0, 6.0), &labels);
    let rt = run_rt(cfg("3-node-mesh", 900.0, 3.0), &labels);

    for (name, r) in [("DES", &des), ("realtime", &rt)] {
        assert!(
            r.per_worker[0].offloaded_out > 0,
            "{name}: overloaded source never offloaded"
        );
        let remote: u64 = r.per_worker[1..].iter().map(|w| w.processed).sum();
        assert!(remote > 0, "{name}: neighbors never processed tasks");
        assert!(r.completed > 0, "{name}: nothing completed");
    }

    // Offload intensity is medium-dependent (virtual vs real link delays),
    // but both must offload a nontrivial share of processed work.
    for (name, r) in [("DES", &des), ("realtime", &rt)] {
        let processed: u64 = r.per_worker.iter().map(|w| w.processed).sum();
        let offloaded: u64 = r.per_worker.iter().map(|w| w.offloaded_out).sum();
        assert!(
            offloaded as f64 >= 0.02 * processed as f64,
            "{name}: offloads {offloaded} vs processed {processed}"
        );
    }
}

#[test]
fn des_and_realtime_agree_on_per_class_exit_splits_under_strict_priority() {
    let _g = serialized();
    let (_, labels) = oracle();
    // Two classes stamped round-robin over the rotating 8-sample store
    // couple deterministically: class 0 ↔ even samples (always exit 1),
    // class 1 ↔ odd samples (always exit 2). Both drivers must report that
    // exact per-class split through the StrictPriority discipline.
    let sched = |mut cfg: ExperimentConfig| {
        cfg.sched = cfg.sched.with_classes(2);
        cfg.sched.discipline = DisciplineKind::StrictPriority;
        cfg
    };
    let des = run_des(sched(cfg("local", 100.0, 5.0)), &labels);
    let rt = run_rt(sched(cfg("local", 100.0, 2.5)), &labels);

    for (name, r) in [("DES", &des), ("realtime", &rt)] {
        assert_eq!(r.per_class.len(), 2, "{name}");
        let by_class: u64 = r.per_class.iter().map(|c| c.completed).sum();
        assert_eq!(by_class, r.completed, "{name}: class counters must conserve");
        assert!(r.per_class[0].completed > 50, "{name}: class 0 {:?}", r.per_class[0]);
        assert!(r.per_class[1].completed > 50, "{name}: class 1 {:?}", r.per_class[1]);
        let f0 = r.per_class[0].exit_fractions();
        let f1 = r.per_class[1].exit_fractions();
        assert!((f0[0] - 1.0).abs() < 1e-9, "{name}: class 0 exits at 1: {f0:?}");
        assert!((f1[1] - 1.0).abs() < 1e-9, "{name}: class 1 exits at 2: {f1:?}");
        assert_eq!(r.dropped, 0, "{name}: strict priority never drops");
    }
}

#[test]
fn realtime_ddi_round_robins_whole_images() {
    let _g = serialized();
    let (_, labels) = oracle();
    // Mirror `ddi_source_round_robins_whole_images` on the realtime
    // driver: the source round-robins whole images across the mesh, every
    // worker runs the full model, and nothing exits early.
    let mut c = cfg("3-node-mesh", 150.0, 2.5);
    c.mode = Mode::Ddi;
    let r = run_rt(c, &labels);

    assert!(r.completed > 50, "completed {}", r.completed);
    let f = r.exit_fractions();
    assert_eq!(f[0], 0.0, "DDI never exits early: {f:?}");
    // Round-robin reached both neighbors with whole-image payloads.
    for w in 1..3 {
        assert!(
            r.per_worker[w].processed > 0,
            "worker {w} never processed: {:?}",
            r.per_worker.iter().map(|w| w.processed).collect::<Vec<_>>()
        );
    }
    assert!(
        r.per_worker[0].offloaded_out > 0,
        "the DDI source pushes whole images to its neighbors"
    );
    // The oracle's final exit predicts the true label.
    assert!((r.accuracy() - 1.0).abs() < 1e-9, "accuracy {}", r.accuracy());
}

#[test]
fn results_cross_two_hops_on_both_drivers() {
    let _g = serialized();
    let (_, labels) = oracle3();
    // Single source at one end of a 4-node line, overloaded far past the
    // source's own capacity on a stage-3-heavy model: mid-line workers
    // push continuing stage-3 work further out, so exits happen two-plus
    // hops from the source and their results must relay back through
    // worker 1. This is the regression test for the old one-hop delivery
    // assumption (and its DES-only two-hop fallback).
    let des = run_des3(cfg("line-4", 900.0, 6.0), &labels);
    let rt = run_rt3(cfg("line-4", 900.0, 3.0), &labels);

    for (name, r) in [("DES", &des), ("realtime", &rt)] {
        let far_exits: u64 = r.per_worker[2..].iter().map(|w| w.exits).sum();
        assert!(far_exits > 0, "{name}: no exits two-plus hops out");
        assert!(
            r.per_worker[1].relayed > 0,
            "{name}: far results must relay through worker 1 (relayed = {:?})",
            r.per_worker.iter().map(|w| w.relayed).collect::<Vec<_>>()
        );
        assert!(r.completed > 0, "{name}: nothing completed");
        // Multi-hop delivery loses nothing: everything the exit counters
        // saw is either home or still in flight at the horizon (small
        // slack for exits straddling the warmup boundary).
        let exits: u64 = r.per_worker.iter().map(|w| w.exits).sum();
        assert!(
            exits + 50 >= r.completed,
            "{name}: completed {} far exceeds recorded exits {exits}",
            r.completed
        );
    }
}

#[test]
fn des_and_realtime_agree_per_source_on_two_source_line() {
    let _g = serialized();
    let (_, labels) = oracle();
    // Two sources at the ends of the line, each under-loaded: both drivers
    // must deliver every source its own results with the oracle's
    // deterministic 50/50 split, per source.
    let two_src = |mut c: ExperimentConfig| {
        c.placement = Placement::multi(&[0, 3]);
        c
    };
    let des = run_des(two_src(cfg("line-4", 80.0, 5.0)), &labels);
    let rt = run_rt(two_src(cfg("line-4", 80.0, 2.5)), &labels);

    for (name, r) in [("DES", &des), ("realtime", &rt)] {
        assert_eq!(r.per_source.len(), 2, "{name}");
        let by_source: u64 = r.per_source.iter().map(|s| s.completed).sum();
        assert_eq!(by_source, r.completed, "{name}: per-source counters conserve");
        for s in &r.per_source {
            assert!(s.completed > 50, "{name}: source {} starved: {s:?}", s.node);
            assert!(
                s.admitted as f64 - s.completed as f64 <= 0.2 * s.admitted as f64,
                "{name}: source {} admitted {} but completed {}",
                s.node,
                s.admitted,
                s.completed
            );
            let f = s.exit_fractions();
            assert!((f[0] - 0.5).abs() < 0.10, "{name}: source {} split {f:?}", s.node);
        }
    }
    // The two drivers agree per source, not just in aggregate.
    for i in 0..2 {
        let (fd, fr) = (des.per_source[i].exit_fractions(), rt.per_source[i].exit_fractions());
        assert!(
            (fd[0] - fr[0]).abs() < 0.10,
            "source {i} exit split diverged: DES {fd:?} vs realtime {fr:?}"
        );
    }
    // Completed counts agree once normalized by each run's window length.
    for i in 0..2 {
        let d_rate = des.per_source[i].completed as f64 / des.duration_s;
        let r_rate = rt.per_source[i].completed as f64 / rt.duration_s;
        assert!(
            (d_rate - r_rate).abs() < 0.25 * d_rate.max(1.0),
            "source {i} completion rate diverged: DES {d_rate:.1} Hz vs realtime {r_rate:.1} Hz"
        );
    }
}

#[test]
fn des_and_realtime_agree_with_deadline_aware_on_line4() {
    let _g = serialized();
    let (_, labels) = oracle3();
    // DeadlineAware offloading on a 4-node line, overloaded ~2.5x past a
    // single worker's capacity on the stage-3-heavy model: the source
    // cannot make its deadlines locally, so the policy must push work out
    // — and both drivers must agree on the resulting behaviour, since the
    // policy is deterministic (it never draws from the RNG).
    let dl = |mut c: ExperimentConfig| {
        c.policy.offload = OffloadKind::DeadlineAware;
        c.sched = c.sched.with_classes(2);
        c.sched.class_deadline_s = vec![0.25, 2.0];
        c
    };
    let des = run_des3(dl(cfg("line-4", 400.0, 6.0)), &labels);
    let rt = run_rt3(dl(cfg("line-4", 400.0, 3.0)), &labels);

    for (name, r) in [("DES", &des), ("realtime", &rt)] {
        assert!(r.completed > 100, "{name}: completed {}", r.completed);
        assert!(
            r.per_worker[0].offloaded_out > 0,
            "{name}: overloaded source never offloaded under DeadlineAware"
        );
        let remote: u64 = r.per_worker[1..].iter().map(|w| w.processed).sum();
        assert!(remote > 0, "{name}: neighbors never processed tasks");
        // Per-class counters (including the new on-time tally) conserve.
        assert_eq!(r.per_class.len(), 2, "{name}");
        let by_class: u64 = r.per_class.iter().map(|c| c.completed).sum();
        assert_eq!(by_class, r.completed, "{name}: class counters conserve");
        for (i, c) in r.per_class.iter().enumerate() {
            assert!(
                c.on_time <= c.completed,
                "{name}: class {i} on_time {} > completed {}",
                c.on_time,
                c.completed
            );
        }
        // The offload-target histogram agrees with the offload counter.
        let targeted: u64 = r.per_worker[0].offload_targets.iter().sum();
        assert_eq!(targeted, r.per_worker[0].offloaded_out, "{name}: target histogram");
        // Deadline-aware summaries (2 classes + slack) cost more than the
        // 32-byte base gossip, and the charge is accounted on both drivers.
        assert!(r.gossip_bytes() > 0, "{name}: gossip bytes uncharged");
    }

    // The two drivers agree on the exit split (loose: the realtime leg
    // runs short windows on shared CI cores).
    let (fd, fr) = (des.exit_fractions(), rt.exit_fractions());
    assert!(
        (fd[0] - fr[0]).abs() < 0.15,
        "exit-1 fraction diverged: DES {fd:?} vs realtime {fr:?}"
    );
}

#[test]
fn wire_accounting_is_equivalent_across_drivers_with_and_without_coalescing() {
    let _g = serialized();
    let (_, labels) = oracle3();
    // Both drivers charge every envelope through the ONE shared
    // `net::Envelope::encoded_bytes` function and count it in the core, so
    // a fixed set of accounting identities must hold EXACTLY on both —
    // with coalescing off (the seed wire) and on. An overloaded line-4 on
    // the stage-3-heavy model produces real offload + result-relay + gossip
    // traffic to check them against.
    let wired = |mut c: ExperimentConfig, mode: CoalesceMode| {
        c.sched.batch = BatchPolicy::batched(8);
        c.sched.coalesce = mode;
        c.sched.coalesce_max = 8;
        c
    };
    for mode in [CoalesceMode::Off, CoalesceMode::Stage] {
        let des = run_des3(wired(cfg("line-4", 700.0, 6.0), mode), &labels);
        let rt = run_rt3(wired(cfg("line-4", 700.0, 3.0), mode), &labels);
        for (name, r) in [("DES", &des), ("realtime", &rt)] {
            // Real traffic flowed on this driver.
            assert!(r.task_transfers > 0, "{name} {mode:?}: no task envelopes");
            assert!(r.gossip_bytes() > 0, "{name} {mode:?}: gossip uncharged");
            // Identity 1: the run totals ARE the per-worker envelope sums
            // (one charging function, no driver-private byte path).
            let wire: u64 = r.per_worker.iter().map(|w| w.wire_bytes).sum();
            let envs: u64 = r.per_worker.iter().map(|w| w.envelopes_sent).sum();
            assert_eq!(r.bytes_on_wire, wire, "{name} {mode:?}");
            assert_eq!(r.task_transfers, envs, "{name} {mode:?}");
            // Identity 2: payload totals include the gossip charge.
            assert!(r.bytes_on_wire >= r.gossip_bytes(), "{name} {mode:?}");
            // Identity 3: gossip is whole 32-byte base summaries (the
            // baseline policy annotates nothing).
            for (i, w) in r.per_worker.iter().enumerate() {
                assert_eq!(
                    w.gossip_bytes % 32,
                    0,
                    "{name} {mode:?}: worker {i} gossip not whole summaries"
                );
            }
            let offloaded: u64 = r.per_worker.iter().map(|w| w.offloaded_out).sum();
            match mode {
                CoalesceMode::Off => {
                    // Seed wire, bit for bit: one task per envelope, no
                    // sharing, no savings.
                    assert_eq!(envs, offloaded, "{name}: off must be per-task");
                    assert_eq!(r.coalesced_tasks(), 0, "{name}");
                    assert_eq!(r.wire_bytes_saved(), 0, "{name}");
                }
                _ => {
                    // Every item sharing an envelope saves exactly one
                    // 32-byte frame — on both drivers, by construction.
                    assert_eq!(
                        r.wire_bytes_saved(),
                        ENVELOPE_HEADER_BYTES as u64 * r.coalesced_tasks(),
                        "{name}: saved bytes must be frames shed"
                    );
                    assert!(
                        envs <= offloaded,
                        "{name}: coalescing cannot send more envelopes than tasks"
                    );
                }
            }
        }
        // The DES leg is virtual-time-deterministic: under this overload
        // the batched engine dumps same-stage runs into the output queue,
        // so coalescing must actually coalesce.
        if mode == CoalesceMode::Stage {
            assert!(
                des.coalesced_tasks() > 0,
                "DES: stage coalescing never shared an envelope"
            );
            assert!(
                des.envelopes_sent() < des.per_worker.iter().map(|w| w.offloaded_out).sum(),
                "DES: envelope count must drop below per-task offloads"
            );
        }
    }
}

#[test]
fn des_and_realtime_agree_under_poisson_arrivals_on_grid() {
    let _g = serialized();
    let (_, labels) = oracle();
    // Poisson arrivals on a generated 9-node grid: both drivers draw the
    // interarrival gaps from the same dedicated Pcg64 stream
    // (`ARRIVAL_STREAM_BASE + source`, seeded from the run seed), so they
    // admit the *same sample path* — the realtime leg merely truncates it
    // at its wallclock horizon. Rates and exit splits must line up.
    let poisson = |mut c: ExperimentConfig| {
        c.workload.arrival = ArrivalSpec::Poisson;
        c
    };
    let des = run_des(poisson(cfg("grid-3x3", 100.0, 5.0)), &labels);
    let rt = run_rt(poisson(cfg("grid-3x3", 100.0, 2.0)), &labels);

    assert!(des.completed > 300, "DES completed {}", des.completed);
    assert!(rt.completed > 100, "realtime completed {}", rt.completed);

    let (da, ra) = (des.admitted_rate_hz(), rt.admitted_rate_hz());
    assert!((da - 100.0).abs() < 12.0, "DES Poisson rate {da:.1} Hz");
    assert!((ra - 100.0).abs() < 18.0, "realtime Poisson rate {ra:.1} Hz");
    assert!(
        (da - ra).abs() < 0.20 * da,
        "admission rates diverged: DES {da:.1} Hz vs realtime {ra:.1} Hz"
    );

    let (fd, fr) = (des.exit_fractions(), rt.exit_fractions());
    assert!(
        (fd[0] - fr[0]).abs() < 0.10,
        "exit-1 fraction diverged: DES {fd:?} vs realtime {fr:?}"
    );
    assert!((des.accuracy() - 1.0).abs() < 1e-9, "DES accuracy {}", des.accuracy());
    assert!((rt.accuracy() - 1.0).abs() < 1e-9, "realtime accuracy {}", rt.accuracy());
}

#[test]
fn realtime_drains_flash_crowd_bursts_without_loop_rate_capping() {
    let _g = serialized();
    let (_, labels) = oracle();
    // A 10x flash crowd concentrates ~rate·ramp_s·(peak_mult − 1) extra
    // admissions into one second. The realtime admission loop must drain
    // the whole scheduled backlog every poll (admitting at the *scheduled*
    // timestamps), or the burst gets clipped to the driver's poll rate and
    // the total falls far short of the DES reference.
    let flash = |mut c: ExperimentConfig| {
        c.workload.arrival =
            ArrivalSpec::FlashCrowd { peak_mult: 10.0, at_s: 1.0, ramp_s: 0.5 };
        c.warmup_s = 0.0;
        c
    };
    let des = run_des(flash(cfg("3-node-mesh", 150.0, 3.0)), &labels);
    let rt = run_rt(flash(cfg("3-node-mesh", 150.0, 3.0)), &labels);

    // Expected ≈ 150·3 (steady) + 150·0.5·9 (burst triangle) ≈ 1125.
    let expect = 150.0 * 3.0 + 150.0 * 0.5 * 9.0;
    assert!(
        (des.admitted as f64 - expect).abs() < 0.15 * expect,
        "DES admitted {} (expected ≈ {expect:.0})",
        des.admitted
    );
    // The burst actually happened: far more than the steady-state total.
    assert!(des.admitted as f64 > 1.5 * 150.0 * 3.0, "DES admitted {}", des.admitted);
    // And the realtime driver kept up with it.
    assert!(
        (rt.admitted as f64 - des.admitted as f64).abs() < 0.10 * des.admitted as f64,
        "realtime clipped the burst: admitted {} vs DES {}",
        rt.admitted,
        des.admitted
    );
    assert!(des.completed > 0 && rt.completed > 0);
}

#[test]
fn realtime_churn_rehomes_like_des() {
    use mdi_exit::simnet::ChurnEvent;
    let _g = serialized();
    let (_, labels) = oracle();
    // Worker 1 leaves mid-run while holding queued work (2-node at 3x the
    // pair's capacity): both drivers must re-home instead of losing tasks.
    let churn = vec![ChurnEvent { at_s: 1.0, worker: 1, join: false }];

    let mut c = cfg("2-node", 900.0, 4.0);
    c.warmup_s = 0.0;
    c.churn = churn.clone();
    let des = run_des(c, &labels);

    let mut c = cfg("2-node", 900.0, 2.5);
    c.warmup_s = 0.0;
    c.churn = churn;
    let rt = run_rt(c, &labels);

    assert!(des.rehomed > 0, "DES: no re-homing on churn");
    assert!(rt.rehomed > 0, "realtime: no re-homing on churn (rehomed = 0)");
    assert!(des.completed > 0 && rt.completed > 0);
}

#[test]
fn cluster_relayers_around_a_midpath_leave_on_both_drivers() {
    use mdi_exit::simnet::ChurnEvent;
    let _g = serialized();
    let (_, labels) = oracle3();
    // Elastic control plane ON, worker 1 (a mid-path relay on the grid,
    // adjacent to the corner source) leaves at t = 1 s while the
    // stage-3-heavy overload keeps continuing work and results flowing
    // through it. Both drivers must re-home its queued tasks, rebuild
    // routing around the hole (the grid offers alternate paths), and keep
    // delivering every completion to the admitting source. Load-driven
    // scaling is neutralized (thresholds no sane occupancy can cross, and
    // `min_workers` at the full fleet blocks retirements) so the autoscaler
    // cannot respawn the leaver or park idle nodes — the test isolates the
    // churn -> re-home -> re-layer path.
    let cl = |mut c: ExperimentConfig| {
        c.cluster.enabled = true;
        c.cluster.scale_up_occupancy = 1e18;
        c.cluster.min_workers = 9;
        c.warmup_s = 0.0;
        c.churn = vec![ChurnEvent { at_s: 1.0, worker: 1, join: false }];
        c
    };
    let des = run_des3(cl(cfg("grid-3x3", 700.0, 6.0)), &labels);
    let rt = run_rt3(cl(cfg("grid-3x3", 700.0, 3.0)), &labels);

    for (name, r) in [("DES", &des), ("realtime", &rt)] {
        assert!(r.completed > 100, "{name}: completed {}", r.completed);
        assert!(r.rehomed > 0, "{name}: the leaver's queued tasks must re-home");
        // Work continued on the surviving fleet past the leaver.
        let remote: u64 = r.per_worker[2..].iter().map(|w| w.processed).sum();
        assert!(remote > 0, "{name}: survivors never processed tasks");
        // Nothing lost or duplicated across the re-layout: every completion
        // the run counted landed at a source's per-source row.
        let by_source: u64 = r.per_source.iter().map(|s| s.completed).sum();
        assert_eq!(by_source, r.completed, "{name}: per-source counters conserve");
        // The cost integral bills the live fleet: 9 nodes for 1 s, 8 after.
        let expect = 9.0 + 8.0 * (r.duration_s - 1.0);
        assert!(
            (r.worker_seconds - expect).abs() < 1e-6,
            "{name}: worker_seconds {} (expected {expect})",
            r.worker_seconds
        );
    }

    // The two drivers agree on behaviour, not just survival.
    let (fd, fr) = (des.exit_fractions(), rt.exit_fractions());
    assert!(
        (fd[0] - fr[0]).abs() < 0.15,
        "exit-1 fraction diverged: DES {fd:?} vs realtime {fr:?}"
    );
}

// ---- autoencoder wire legs (real tensors through the zero-copy path) ------

fn meta_ae() -> ModelMeta {
    let mut m = meta();
    m.ae = Some(AeMeta { enc_cost_s: 0.001, dec_cost_s: 0.001, code_bytes: 2048 });
    m
}

fn tensor_engine() -> TensorEngine {
    let (table, _) = oracle();
    TensorEngine::new(table, 16, 4)
}

/// DES run over real feature tensors: the dataset supplies stage-1 image
/// views and the [`TensorEngine`] materializes inter-stage tensors, so the
/// sender-side AE step is physical (batched forward + per-item fallback),
/// not the oracle's virtual bookkeeping.
fn run_des_tensor(cfg: ExperimentConfig, ds: &Dataset, engine: &TensorEngine) -> RunReport {
    Run::builder()
        .config(cfg)
        .model(meta_ae())
        .engine(engine)
        .dataset(ds)
        .driver(Driver::Des)
        .execute()
        .expect("DES run")
}

/// Round-robin offloading pushes every continuing stage-2 task to a
/// neighbor regardless of load — the decision is queue-independent, so the
/// AE and raw runs offload the same work and their byte totals compare.
fn rr(mut c: ExperimentConfig, use_ae: bool) -> ExperimentConfig {
    c.policy.offload = OffloadKind::RoundRobin;
    c.use_ae = use_ae;
    c
}

#[test]
fn des_ae_fail_all_is_byte_identical_to_a_raw_run() {
    let _g = serialized();
    let (_, labels) = oracle();
    let ds = Dataset::synthetic(labels.len(), 2, 2, 3, labels.to_vec());
    // Every encode declines: zero encoder forwards are priced, zero decode
    // costs are charged (only `encoded` tasks pay them), and every payload
    // ships raw after the sender-side `note_wire_recharge` reconciliation —
    // so the run must be indistinguishable from `use_ae = false`, event for
    // event and byte for byte.
    let declining = tensor_engine().declining_all();
    let ae = run_des_tensor(rr(cfg("3-node-mesh", 150.0, 5.0), true), &ds, &declining);
    let plain = tensor_engine();
    let raw = run_des_tensor(rr(cfg("3-node-mesh", 150.0, 5.0), false), &ds, &plain);

    assert!(ae.task_transfers > 100, "no offload traffic to compare");
    assert_eq!(ae.bytes_on_wire, raw.bytes_on_wire, "recharge must land on raw bytes");
    assert_eq!(ae.task_transfers, raw.task_transfers);
    assert_eq!(ae.completed, raw.completed);
    assert_eq!(ae.exit_fractions(), raw.exit_fractions());
    // The charging identity survives the recharge path: run totals are
    // still exactly the per-worker envelope sums.
    let wire: u64 = ae.per_worker.iter().map(|w| w.wire_bytes).sum();
    assert_eq!(ae.bytes_on_wire, wire);
}

#[test]
fn des_ae_codes_cut_wire_bytes_without_hurting_accuracy() {
    let _g = serialized();
    let (_, labels) = oracle();
    let ds = Dataset::synthetic(labels.len(), 2, 2, 3, labels.to_vec());
    let eng = tensor_engine();
    let ae = run_des_tensor(rr(cfg("3-node-mesh", 150.0, 5.0), true), &ds, &eng);
    let plain = tensor_engine();
    let raw = run_des_tensor(rr(cfg("3-node-mesh", 150.0, 5.0), false), &ds, &plain);

    assert!(ae.task_transfers > 100, "round-robin must push stage-2 work out");
    assert!(eng.batch_forwards() > 0, "the physical encoder actually ran");
    assert_eq!(eng.single_encodes(), 0, "sends ride the batched forward, not per-item encodes");
    // Stage-2 codes (2048 B) replace raw activations (8192 B) on every
    // offload; results are unchanged, so non-gossip bytes collapse to
    // roughly a quarter.
    let task_bytes = |r: &RunReport| r.bytes_on_wire - r.gossip_bytes();
    assert!(
        (task_bytes(&ae) as f64) < 0.55 * task_bytes(&raw) as f64,
        "AE {} bytes vs raw {} bytes",
        task_bytes(&ae),
        task_bytes(&raw)
    );
    // Decode feeds the oracle replay untouched: accuracy survives coding.
    assert!((ae.accuracy() - 1.0).abs() < 1e-9, "accuracy {}", ae.accuracy());
    let wire: u64 = ae.per_worker.iter().map(|w| w.wire_bytes).sum();
    assert_eq!(ae.bytes_on_wire, wire, "charging identity holds with AE codes");
}

#[test]
fn realtime_ae_codes_and_recharges_account_like_des() {
    let _g = serialized();
    let (_, labels) = oracle();
    let ds = Dataset::synthetic(labels.len(), 2, 2, 3, labels.to_vec());
    let rt_ae = |decline: bool| {
        let factory = move |_w: usize| -> Result<Box<dyn InferenceEngine>> {
            let (table, _) = oracle();
            let eng = TensorEngine::new(table, 16, 4);
            let eng = if decline { eng.declining_all() } else { eng };
            Ok(Box::new(eng) as Box<dyn InferenceEngine>)
        };
        Run::builder()
            .config(rr(cfg("3-node-mesh", 150.0, 2.5), true))
            .model(meta_ae())
            .engine_factory(factory)
            .dataset(&ds)
            .driver(Driver::Realtime)
            .execute()
            .expect("realtime run")
    };
    let coded = rt_ae(false);
    let declined = rt_ae(true);

    for (name, r) in [("coded", &coded), ("declined", &declined)] {
        assert!(r.task_transfers > 50, "{name}: no offload traffic");
        assert!((r.accuracy() - 1.0).abs() < 1e-9, "{name}: accuracy {}", r.accuracy());
        // Same identity the DES legs assert: one charging function, no
        // driver-private byte path — including the realtime recharge.
        let wire: u64 = r.per_worker.iter().map(|w| w.wire_bytes).sum();
        assert_eq!(r.bytes_on_wire, wire, "{name}: charging identity");
    }
    // Wallclock jitter moves the *counts*, never the per-envelope sizes:
    // a coded stage-2 envelope carries 2048 B against the declined run's
    // recharged 8192 B raw activation.
    let per_env =
        |r: &RunReport| (r.bytes_on_wire - r.gossip_bytes()) as f64 / r.task_transfers as f64;
    assert!(
        per_env(&coded) < 0.55 * per_env(&declined),
        "coded {:.0} B/envelope vs declined {:.0} B/envelope",
        per_env(&coded),
        per_env(&declined)
    );
}
