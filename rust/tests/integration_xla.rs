//! Integration over the *real* runtime: AOT HLO artifacts compiled and
//! executed on PJRT, cross-checked against the Python-side oracle tables.
//! Requires the `pjrt` cargo feature (the default build carries no XLA
//! toolchain); the whole file compiles away without it.
#![cfg(feature = "pjrt")]
//!
//! This is the proof that the three layers compose: the Pallas kernels (L1)
//! inside the JAX stage functions (L2) lowered to HLO text, loaded and
//! driven by the Rust coordinator (L3), reproduce exactly the confidences
//! and predictions the Python evaluation recorded at build time.

use mdi_exit::artifact::Manifest;
use mdi_exit::coordinator::{AdmissionMode, ExperimentConfig, ModelMeta, SampleStore, Simulation};
use mdi_exit::dataset::{Dataset, ExitTable};
use mdi_exit::runtime::xla_engine::XlaEngine;
use mdi_exit::runtime::InferenceEngine;

fn setup(model: &str, with_ae: bool) -> Option<(Manifest, XlaEngine, Dataset, ExitTable)> {
    let manifest = match Manifest::load(mdi_exit::artifacts_dir()) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("artifacts missing; skipping");
            return None;
        }
    };
    let engine = XlaEngine::load(&manifest, model, with_ae).expect("compile stages");
    let ds = Dataset::load(manifest.path(&manifest.dataset.file)).expect("dataset");
    let info = manifest.model(model).unwrap();
    let table = ExitTable::load(manifest.path(&info.exits_bin)).expect("exit table");
    Some((manifest, engine, ds, table))
}

/// Chain every stage on PJRT for `sample`, returning (conf, pred) per exit.
fn run_chain(engine: &XlaEngine, ds: &Dataset, sample: usize) -> Vec<(f32, u8)> {
    let mut feats = Some(ds.image(sample));
    let mut out = Vec::new();
    for k in 1..=engine.num_stages() {
        let o = engine.run_stage(k, sample, feats.as_ref()).expect("stage");
        out.push((o.confidence, o.prediction));
        feats = o.features;
    }
    out
}

#[test]
fn xla_stages_match_python_oracle_mobilenet() {
    let Some((_m, engine, ds, table)) = setup("mobilenetv2l", false) else { return };
    for sample in [0usize, 1, 17, 255, 1023] {
        let got = run_chain(&engine, &ds, sample);
        for (k, (conf, pred)) in got.iter().enumerate() {
            let want_conf = table.confidence(sample, k);
            let want_pred = table.prediction(sample, k);
            assert_eq!(*pred, want_pred, "sample {sample} exit {k}: prediction mismatch");
            assert!(
                (conf - want_conf).abs() < 2e-2,
                "sample {sample} exit {k}: conf {conf} vs oracle {want_conf}"
            );
        }
    }
}

#[test]
fn xla_stages_match_python_oracle_resnet() {
    let Some((_m, engine, ds, table)) = setup("resnetl", false) else { return };
    for sample in [2usize, 42, 511] {
        let got = run_chain(&engine, &ds, sample);
        for (k, (conf, pred)) in got.iter().enumerate() {
            assert_eq!(*pred, table.prediction(sample, k), "sample {sample} exit {k}");
            assert!((conf - table.confidence(sample, k)).abs() < 2e-2);
        }
    }
}

#[test]
fn xla_accuracy_on_subset_matches_manifest() {
    let Some((m, engine, ds, _)) = setup("mobilenetv2l", false) else { return };
    let info = m.model("mobilenetv2l").unwrap();
    let n = 200;
    let mut correct = 0;
    for s in 0..n {
        let chain = run_chain(&engine, &ds, s);
        let (_, pred) = chain.last().unwrap();
        if *pred == ds.label(s) {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    let manifest_acc = *info.exit_accuracy.last().unwrap();
    assert!(
        (acc - manifest_acc).abs() < 0.08,
        "subset accuracy {acc} vs manifest {manifest_acc}"
    );
}

#[test]
fn xla_autoencoder_roundtrip_preserves_deep_exits() {
    let Some((_m, engine, ds, _)) = setup("resnetl", true) else { return };
    assert!(engine.has_autoencoder());
    let sample = 7;
    // stage 1 → encode → decode → stage 2 must still classify like the
    // AE-aware oracle (exits_resnetl_ae.bin)
    let o1 = engine.run_stage(1, sample, Some(&ds.image(sample))).unwrap();
    let feats = o1.features.unwrap();
    let code = engine.encode(&feats).unwrap().expect("code");
    assert_eq!(code.numel() * 4, 1024, "code must be 1 KiB");
    let dec = engine.decode(&code).unwrap().expect("decoded");
    assert_eq!(dec.shape(), feats.shape());
    let o2 = engine.run_stage(2, sample, Some(&dec)).unwrap();
    let ae_table = ExitTable::load(
        Manifest::load(mdi_exit::artifacts_dir())
            .unwrap()
            .path("exits_resnetl_ae.bin"),
    )
    .unwrap();
    assert_eq!(o2.prediction, ae_table.prediction(sample, 1));
    assert!((o2.confidence - ae_table.confidence(sample, 1)).abs() < 2e-2);
}

#[test]
fn des_driver_runs_on_real_engine() {
    // The same Simulation used by benches, but pushing real tensors through
    // PJRT — proving the DES and the runtime compose.
    let Some((m, engine, ds, _)) = setup("mobilenetv2l", false) else { return };
    let info = m.model("mobilenetv2l").unwrap();
    let mut cfg = ExperimentConfig::new(
        "mobilenetv2l",
        "2-node",
        AdmissionMode::Fixed { rate_hz: 40.0, threshold: 0.9 },
    );
    cfg.duration_s = 3.0; // virtual seconds, but compute is real now
    cfg.warmup_s = 0.5;
    let meta = ModelMeta::from_manifest(info);
    let store = SampleStore { labels: &ds.labels, images: Some(&ds) };
    let r = Simulation::new(cfg, &engine, meta, store).unwrap().run().unwrap();
    assert!(r.completed > 20, "completed {}", r.completed);
    assert!(r.accuracy() > 0.5, "accuracy {}", r.accuracy());
    let hist_sum: u64 = r.exit_histogram.iter().sum();
    assert_eq!(hist_sum, r.completed);
}
