//! Integration: the full DES system on the *real* trained-model artifacts
//! (oracle engine). Skips silently when `make artifacts` has not run.
//!
//! These tests assert the qualitative shapes of the paper's evaluation —
//! the same claims EXPERIMENTS.md records quantitatively.

use mdi_exit::artifact::Manifest;
use mdi_exit::coordinator::{AdmissionMode, ExperimentConfig, Mode, Run, RunReport};
use mdi_exit::experiments::{self, SweepOpts};

fn manifest() -> Option<Manifest> {
    match Manifest::load(mdi_exit::artifacts_dir()) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("artifacts missing; skipping integration test");
            None
        }
    }
}

fn run_from_artifacts(cfg: ExperimentConfig, manifest: &Manifest) -> anyhow::Result<RunReport> {
    Run::builder().config(cfg).manifest(manifest).execute()
}

fn quick() -> SweepOpts {
    SweepOpts { duration_s: 20.0, warmup_s: 8.0, seed: 7, compute_scale: 0.125 }
}

fn rate_cfg(model: &str, topo: &str, threshold: f32) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(
        model,
        topo,
        AdmissionMode::AdaptiveRate { threshold, initial_mu_s: 0.25 },
    );
    cfg.duration_s = quick().duration_s;
    cfg.warmup_s = quick().warmup_s;
    cfg.compute_scale = quick().compute_scale;
    cfg
}

#[test]
fn early_exit_beats_no_ee_in_admitted_rate() {
    let Some(m) = manifest() else { return };
    for model in ["mobilenetv2l", "resnetl"] {
        let ee = run_from_artifacts(rate_cfg(model, "local", 0.9), &m).unwrap();
        let mut no_ee = rate_cfg(model, "local", 0.9);
        no_ee.no_early_exit = true;
        let no_ee = run_from_artifacts(no_ee, &m).unwrap();
        assert!(
            ee.throughput_hz() > no_ee.throughput_hz(),
            "{model}: EE {:.1} Hz should beat No-EE {:.1} Hz",
            ee.throughput_hz(),
            no_ee.throughput_hz()
        );
    }
}

#[test]
fn more_nodes_admit_more_data() {
    // Paper §V: "when the number of nodes increases ... MDI-Exit achieves a
    // higher data arrival rate". The gains are modest for MobileNet (small
    // features, cheap stages — the paper's own Fig. 3 shows the same), so
    // assert monotone growth with a 10% margin rather than a 2x jump.
    let Some(m) = manifest() else { return };
    let local = run_from_artifacts(rate_cfg("mobilenetv2l", "local", 0.9), &m).unwrap();
    let mesh3 = run_from_artifacts(rate_cfg("mobilenetv2l", "3-node-mesh", 0.9), &m).unwrap();
    let mesh5 = run_from_artifacts(rate_cfg("mobilenetv2l", "5-node-mesh", 0.9), &m).unwrap();
    assert!(
        mesh3.throughput_hz() > local.throughput_hz() * 1.1,
        "3-node mesh {:.1} Hz should beat local {:.1} Hz",
        mesh3.throughput_hz(),
        local.throughput_hz()
    );
    assert!(
        mesh5.throughput_hz() > mesh3.throughput_hz(),
        "5-node mesh {:.1} Hz should beat 3-node {:.1} Hz",
        mesh5.throughput_hz(),
        mesh3.throughput_hz()
    );
}

#[test]
fn lower_threshold_trades_accuracy_for_rate() {
    let Some(m) = manifest() else { return };
    let lo = run_from_artifacts(rate_cfg("mobilenetv2l", "local", 0.5), &m).unwrap();
    let hi = run_from_artifacts(rate_cfg("mobilenetv2l", "local", 0.95), &m).unwrap();
    assert!(
        lo.throughput_hz() >= hi.throughput_hz(),
        "T=0.5 rate {:.1} should be >= T=0.95 rate {:.1}",
        lo.throughput_hz(),
        hi.throughput_hz()
    );
    assert!(
        hi.accuracy() >= lo.accuracy() - 0.02,
        "higher threshold should not lose accuracy: {:.3} vs {:.3}",
        hi.accuracy(),
        lo.accuracy()
    );
}

#[test]
fn threshold_adaptation_admits_all_traffic_with_graceful_accuracy() {
    let Some(m) = manifest() else { return };
    let mut accs = Vec::new();
    for rate in [20.0, 320.0] {
        let mut cfg = ExperimentConfig::new(
            "mobilenetv2l",
            "3-node-mesh",
            AdmissionMode::AdaptiveThreshold { rate_hz: rate, initial_t_e: 0.9, t_e_min: 0.05 },
        );
        cfg.duration_s = 25.0;
        cfg.warmup_s = 10.0;
        cfg.compute_scale = 0.125;
        let r = run_from_artifacts(cfg, &m).unwrap();
        // all traffic admitted: completions keep up within 15%
        assert!(
            r.completed as f64 >= 0.85 * r.admitted as f64,
            "rate {rate}: completed {} vs admitted {}",
            r.completed,
            r.admitted
        );
        accs.push(r.accuracy());
    }
    // accuracy degrades with rate but stays above chance
    assert!(accs[1] <= accs[0] + 0.02, "accuracy did not degrade: {accs:?}");
    assert!(accs[1] > 0.3, "accuracy collapsed: {accs:?}");
}

#[test]
fn autoencoder_rescues_resnet_on_5_node_mesh() {
    let Some(m) = manifest() else { return };
    let mut raw_acc = Vec::new();
    let mut ae_acc = Vec::new();
    for &use_ae in &[false, true] {
        for &rate in &[20.0] {
            let mut cfg = ExperimentConfig::new(
                "resnetl",
                "5-node-mesh",
                AdmissionMode::AdaptiveThreshold {
                    rate_hz: rate,
                    initial_t_e: 0.9,
                    t_e_min: 0.05,
                },
            );
            cfg.use_ae = use_ae;
            cfg.link = mdi_exit::experiments::resnet_link();
            cfg.duration_s = 25.0;
            cfg.warmup_s = 10.0;
            cfg.compute_scale = 0.125;
            let r = run_from_artifacts(cfg, &m).unwrap();
            if use_ae {
                ae_acc.push(r.accuracy());
            } else {
                raw_acc.push(r.accuracy());
            }
        }
    }
    // Paper Fig. 6 claim: with the AE the mesh holds accuracy at high rate.
    assert!(
        ae_acc[0] > raw_acc[0] - 0.02,
        "AE should not be worse under load: ae {ae_acc:?} vs raw {raw_acc:?}"
    );
}

#[test]
fn ddi_pays_more_bytes_than_mdi() {
    let Some(m) = manifest() else { return };
    let mk = |mode| {
        let mut cfg = ExperimentConfig::new(
            "mobilenetv2l",
            "3-node-mesh",
            AdmissionMode::Fixed { rate_hz: 60.0, threshold: 0.9 },
        );
        cfg.mode = mode;
        cfg.duration_s = 20.0;
        cfg.warmup_s = 5.0;
        cfg.compute_scale = 0.125;
        cfg
    };
    let ddi = run_from_artifacts(mk(Mode::Ddi), &m).unwrap();
    let mdi = run_from_artifacts(mk(Mode::MdiExit), &m).unwrap();
    let ddi_bps = ddi.bytes_on_wire as f64 / ddi.completed.max(1) as f64;
    let mdi_bps = mdi.bytes_on_wire as f64 / mdi.completed.max(1) as f64;
    assert!(
        ddi_bps > mdi_bps,
        "DDI bytes/sample {ddi_bps:.0} should exceed MDI-Exit {mdi_bps:.0}"
    );
}

#[test]
fn fig_runners_produce_full_grids() {
    let Some(m) = manifest() else { return };
    let opts = SweepOpts { duration_s: 6.0, warmup_s: 2.0, seed: 7, compute_scale: 0.125 };
    let rows = experiments::fig3(&m, opts).unwrap();
    // 5 topologies x 6 thresholds + 3 No-EE reference points
    assert_eq!(rows.len(), 5 * 6 + 3);
    assert!(rows.iter().all(|r| r.rate_hz.is_finite() && (0.0..=1.0).contains(&r.accuracy)));
    let rows = experiments::fig5(&m, opts).unwrap();
    assert_eq!(rows.len(), 5 * 6);
}
