//! Bench: regenerate Fig. 4 — ResNet, fixed confidence threshold, Alg. 3
//! adapts the data arrival rate (same protocol as Fig. 3, heavier model).

use mdi_exit::artifact::Manifest;
use mdi_exit::experiments as exp;
use mdi_exit::testkit::bench::BenchSuite;

fn main() {
    let manifest = match Manifest::load(mdi_exit::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping fig4 bench (artifacts missing): {e:#}");
            return;
        }
    };
    let opts = exp::SweepOpts::full();
    let mut suite = BenchSuite::new("fig4 sweep wallclock").warmup(0).iters(1);
    let mut rows = Vec::new();
    suite.bench("fig4: 5 topologies x 6 thresholds + No-EE refs", || {
        rows = exp::fig4(&manifest, opts).expect("fig4 sweep");
    });
    suite.report();
    exp::print_rows(
        "Fig. 4 — ResNet50: achieved data rate, fixed confidence threshold",
        "T_e",
        &rows,
    );
}
