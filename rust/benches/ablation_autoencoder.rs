//! Ablation: autoencoder on/off at the ResNet stage-1 boundary on the
//! 5-node mesh (paper §V: the AE turns the worst topology into the best,
//! at ≤2.2% exit-1 accuracy cost).

use mdi_exit::artifact::Manifest;
use mdi_exit::experiments as exp;

fn main() {
    let manifest = match Manifest::load(mdi_exit::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping ablation (artifacts missing): {e:#}");
            return;
        }
    };
    let rows = exp::ablation_autoencoder(&manifest, exp::SweepOpts::full())
        .expect("ablation sweep");
    exp::print_rows("abl-ae — ResNet 5-node mesh, AE vs raw features", "rate", &rows);
    if let Some(ae) = &manifest.model("resnetl").expect("resnetl").ae {
        println!(
            "\nmanifest: AE compresses {} B -> {} B ({}x); per-exit accuracy drop {:?}",
            ae.raw_bytes, ae.code_bytes, ae.compression, ae.acc_drop
        );
    }
}
