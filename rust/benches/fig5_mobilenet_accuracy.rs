//! Bench: regenerate Fig. 5 — MobileNetV2, Poisson arrivals at fixed mean
//! rate, Alg. 4 adapts the early-exit threshold (accuracy degrades
//! gracefully with load).
//!
//! Expected shape (paper): accuracy falls as rate rises; multi-node setups
//! hold accuracy longer; 3-Node-Mesh beats 5-Node-Mesh at high rates
//! because raw-feature transmission saturates the shared medium.

use mdi_exit::artifact::Manifest;
use mdi_exit::experiments as exp;
use mdi_exit::testkit::bench::BenchSuite;

fn main() {
    let manifest = match Manifest::load(mdi_exit::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping fig5 bench (artifacts missing): {e:#}");
            return;
        }
    };
    let opts = exp::SweepOpts::full();
    let mut suite = BenchSuite::new("fig5 sweep wallclock").warmup(0).iters(1);
    let mut rows = Vec::new();
    suite.bench("fig5: 5 topologies x 6 rates", || {
        rows = exp::fig5(&manifest, opts).expect("fig5 sweep");
    });
    suite.report();
    exp::print_rows(
        "Fig. 5 — MobileNetV2: accuracy vs Poisson arrival rate",
        "rate",
        &rows,
    );
}
