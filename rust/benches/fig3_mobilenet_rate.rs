//! Bench: regenerate Fig. 3 — MobileNetV2, early-exit confidence threshold
//! fixed, Alg. 3 adapts the data arrival rate. Prints the achieved data
//! rate per topology/threshold (the paper's y-axis) and wall-clock timing
//! of the sweep itself.
//!
//! Expected shape (paper): EE > No-EE everywhere; rate grows with node
//! count; lower thresholds admit more data at lower accuracy.

use mdi_exit::artifact::Manifest;
use mdi_exit::experiments as exp;
use mdi_exit::testkit::bench::BenchSuite;

fn main() {
    let manifest = match Manifest::load(mdi_exit::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping fig3 bench (artifacts missing): {e:#}");
            return;
        }
    };
    let opts = exp::SweepOpts::full();
    let mut suite = BenchSuite::new("fig3 sweep wallclock").warmup(0).iters(1);
    let mut rows = Vec::new();
    suite.bench("fig3: 5 topologies x 6 thresholds + No-EE refs", || {
        rows = exp::fig3(&manifest, opts).expect("fig3 sweep");
    });
    suite.report();
    exp::print_rows(
        "Fig. 3 — MobileNetV2: achieved data rate, fixed confidence threshold",
        "T_e",
        &rows,
    );
}
