//! Elastic-fleet ablation: the autoscaled control plane vs every fixed
//! fleet size on the same flash-crowd ramp, DES driver (virtual-time
//! deterministic, so every claim is asserted tight and CI fails on a
//! control-plane regression, not just a drifting BENCH history):
//!
//! * cost x latency: the autoscaled fleet must beat EVERY fixed size K in
//!   `worker_seconds x p95 latency` — under-provisioned fleets melt on
//!   latency during the flash, over-provisioned fleets burn worker-seconds
//!   all run for capacity they use for a few seconds;
//! * conservation: scale events never lose or duplicate in-flight tasks —
//!   per-source admitted/completed sum exactly to the run totals and the
//!   end-of-run tail is bounded;
//! * determinism: the full autoscaled run (health jitter, scale decisions,
//!   re-layering) is bit-for-bit reproducible across repeats.
//!
//! Topology is star-5 with the controller source on the hub: every other
//! node is one gossip hop away, so the controller's occupancy view covers
//! the whole fleet (gossip is neighbor-only and parked nodes are silent).
//! The workload rides the per-source mixes: the hub source takes a flash
//! crowd, the leaf source steady Poisson — one `[workload.sources.N]`
//! override, one shared default.
//!
//! Every fleet config lands in `BENCH_cluster.json` (worker-seconds, p95,
//! cost x latency, scale counts) as a machine-readable history.
//! `MDI_BENCH_QUICK=1` shrinks the window for CI.

use mdi_exit::coordinator::{
    AdmissionMode, Driver, ExperimentConfig, ModelMeta, Placement, Run, RunReport,
};
use mdi_exit::dataset::ExitTable;
use mdi_exit::runtime::sim_engine::SimEngine;
use mdi_exit::util::json::{obj, Json};
use mdi_exit::workload::ArrivalSpec;

/// Stage-3-heavy costs: the final stage dominates, so continuing work
/// spreads across the fleet instead of pinning to the admitting node.
const COSTS3: [f64; 3] = [0.001, 0.001, 0.006];

/// Flash ramp in ABSOLUTE seconds (not a fraction of the window): the
/// autoscaler's reaction time is absolute too, so scaling the ramp down
/// with the quick window would change what is being measured.
const RAMP_S: f64 = 2.0;

/// 8 samples x 3 exits: every fourth sample exits at 1, the rest ride to
/// the heavy final stage. Predictions always match the label.
fn oracle3() -> (ExitTable, Vec<u8>) {
    let n = 8;
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
    for i in 0..n {
        if i % 4 == 0 {
            conf.extend([0.97f32, 0.99, 1.0]);
        } else {
            conf.extend([0.30f32, 0.50, 0.95]);
        }
        pred.extend([labels[i]; 3]);
    }
    (ExitTable::synthetic(n, 3, conf, pred), labels)
}

fn meta3() -> ModelMeta {
    ModelMeta::synthetic(COSTS3.to_vec(), vec![12288, 8192, 4096])
}

/// Star-5, sources on the hub (0, controller) and one leaf (4). The hub
/// source takes the flash crowd; the leaf stays steady Poisson via the
/// shared default — the per-source workload-mix machinery under load.
fn base_cfg(seconds: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(
        "cluster-ablation",
        "star-5",
        AdmissionMode::Fixed { rate_hz: 60.0, threshold: 0.9 },
    );
    cfg.placement = Placement::multi(&[0, 4]);
    cfg.duration_s = seconds;
    cfg.warmup_s = 0.0;
    cfg.seed = 7;
    cfg.gossip_interval_s = 0.1;
    cfg.workload.arrival = ArrivalSpec::Poisson;
    cfg.workload.sources = vec![(
        0,
        ArrivalSpec::FlashCrowd { peak_mult: 8.0, at_s: 0.4 * seconds, ramp_s: RAMP_S },
    )];
    cfg
}

/// The elastic fleet: boots at the 2 sources, may wake any of the 3 leaves.
fn autoscaled(seconds: f64) -> ExperimentConfig {
    let mut cfg = base_cfg(seconds);
    cfg.cluster.enabled = true;
    cfg.cluster.initial_workers = Some(2);
    cfg.cluster.min_workers = 2;
    cfg.cluster.check_interval_s = 0.2;
    cfg.cluster.cooldown_s = 0.4;
    cfg.cluster.scale_up_occupancy = 1.0;
    cfg.cluster.scale_down_occupancy = 0.3;
    cfg
}

/// A fixed fleet of exactly `k` nodes: the control plane runs (same code
/// path, same health checking) but `min = max = k` pins the size.
fn fixed(seconds: f64, k: usize) -> ExperimentConfig {
    let mut cfg = base_cfg(seconds);
    cfg.cluster.enabled = true;
    cfg.cluster.initial_workers = Some(k);
    cfg.cluster.min_workers = k;
    cfg.cluster.max_workers = k;
    cfg
}

fn run_des(cfg: ExperimentConfig) -> RunReport {
    let (table, labels) = oracle3();
    let engine = SimEngine::from_table(table, false);
    Run::builder()
        .config(cfg)
        .model(meta3())
        .engine(&engine)
        .labels(&labels)
        .driver(Driver::Des)
        .execute()
        .expect("DES run")
}

fn row(name: &str, r: &mut RunReport) -> (f64, Json) {
    let p95 = r.latency.p95();
    let score = r.worker_seconds * p95;
    println!(
        "{name:<12} {:>8} {:>8} {:>12.1} {:>10.2} {:>14.3} {:>6} {:>6}",
        r.admitted,
        r.completed,
        r.worker_seconds,
        p95 * 1e3,
        score,
        r.scale_ups,
        r.scale_downs
    );
    let json = obj(vec![
        ("fleet", name.into()),
        ("admitted", (r.admitted as i64).into()),
        ("completed", (r.completed as i64).into()),
        ("worker_seconds", r.worker_seconds.into()),
        ("p95_s", p95.into()),
        ("cost_x_latency", score.into()),
        ("scale_ups", (r.scale_ups as i64).into()),
        ("scale_downs", (r.scale_downs as i64).into()),
    ]);
    (score, json)
}

/// Per-source conservation across re-layering: every admitted/completed
/// task is accounted to exactly one source, and the unfinished tail at the
/// horizon is bounded (nothing got lost in a scale event).
fn assert_conserves(name: &str, r: &RunReport) {
    let adm: u64 = r.per_source.iter().map(|s| s.admitted).sum();
    let com: u64 = r.per_source.iter().map(|s| s.completed).sum();
    assert_eq!(adm, r.admitted, "{name}: per-source admissions conserve");
    assert_eq!(com, r.completed, "{name}: per-source completions conserve");
    assert!(
        r.admitted - r.completed < 300,
        "{name}: admitted {} vs completed {} — tasks lost across scale events?",
        r.admitted,
        r.completed
    );
}

fn main() {
    let quick = std::env::var_os("MDI_BENCH_QUICK").is_some();
    let seconds = if quick { 20.0 } else { 60.0 };

    println!("== bench: elastic fleet vs fixed sizes (star-5, flash crowd on the hub) ==");
    println!(
        "{:<12} {:>8} {:>8} {:>12} {:>10} {:>14} {:>6} {:>6}",
        "fleet", "admitted", "completed", "worker-sec", "p95(ms)", "cost x p95", "ups", "downs"
    );

    let mut rows: Vec<Json> = Vec::new();

    let mut auto = run_des(autoscaled(seconds));
    let (auto_score, auto_json) = row("autoscaled", &mut auto);
    rows.push(auto_json);
    assert_conserves("autoscaled", &auto);
    // The fleet actually breathed: grew for the flash, parked afterwards.
    // (>= 2 not 3: four nodes already cover the 540 Hz peak, so whether
    // the fifth ever wakes depends on transient backlog.)
    assert!(auto.scale_ups >= 2, "flash must wake the parked leaves: {}", auto.scale_ups);
    assert!(auto.scale_downs >= 2, "decay must park them again: {}", auto.scale_downs);
    assert!(
        auto.worker_seconds > 2.0 * seconds + 1.0 && auto.worker_seconds < 5.0 * seconds - 1.0,
        "elastic cost must sit strictly between the 2-node floor and the full fleet: {}",
        auto.worker_seconds
    );

    for k in 2..=5usize {
        let mut r = run_des(fixed(seconds, k));
        let name = format!("fixed-{k}");
        let (score, json) = row(&name, &mut r);
        rows.push(json);
        assert_conserves(&name, &r);
        // A pinned fleet bills exactly k x duration and never scales.
        assert_eq!(r.scale_ups + r.scale_downs, 0, "{name}: pinned fleet scaled");
        assert!(
            (r.worker_seconds - k as f64 * seconds).abs() < 1e-6,
            "{name}: worker_seconds {} != {k} x {seconds}",
            r.worker_seconds
        );
        assert!(
            auto_score < score,
            "autoscaled must beat every fixed fleet on cost x latency: \
             autoscaled {auto_score:.3} vs {name} {score:.3}"
        );
    }

    // -- determinism: the whole control loop replays bit-for-bit ----------
    let mut again = run_des(autoscaled(seconds));
    assert_eq!(again.admitted, auto.admitted, "admissions diverged across repeats");
    assert_eq!(again.completed, auto.completed, "completions diverged across repeats");
    assert_eq!(again.scale_ups, auto.scale_ups, "scale-ups diverged across repeats");
    assert_eq!(again.scale_downs, auto.scale_downs, "scale-downs diverged across repeats");
    assert_eq!(again.bytes_on_wire, auto.bytes_on_wire, "wire bytes diverged across repeats");
    assert_eq!(
        again.worker_seconds.to_bits(),
        auto.worker_seconds.to_bits(),
        "worker-seconds diverged across repeats"
    );
    assert_eq!(
        again.latency.p95().to_bits(),
        auto.latency.p95().to_bits(),
        "p95 diverged across repeats"
    );
    println!("  -> determinism: repeat run identical (bit-for-bit)");

    let doc = obj(vec![
        ("bench", "cluster".into()),
        ("quick", quick.into()),
        (
            "workload",
            obj(vec![
                ("topology", "star-5".into()),
                ("seconds", seconds.into()),
                ("rate_hz", 60.0.into()),
                ("flash_peak_mult", 8.0.into()),
                ("flash_ramp_s", RAMP_S.into()),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_cluster.json", doc.to_string()).expect("write BENCH_cluster.json");
    println!("wrote BENCH_cluster.json");
}
