//! Hot-path microbenchmarks (the L3 perf targets of DESIGN.md §8):
//!
//! * task hand-off: queue push/pop + Alg. 1 decision        (< 5 µs)
//! * Alg. 2 scan against 4 neighbor views                    (< 5 µs)
//! * DES event throughput on a saturated 5-node mesh         (Mevents/s)
//! * XLA stage execution, when artifacts are present         (per-stage ms)

use mdi_exit::policy::{self, NeighborView, OffloadRule};
use mdi_exit::coordinator::queues::TaskQueue;
use mdi_exit::coordinator::task::Task;
use mdi_exit::coordinator::{AdmissionMode, Driver, ExperimentConfig, ModelMeta, Run};
use mdi_exit::dataset::ExitTable;
use mdi_exit::runtime::sim_engine::SimEngine;
use mdi_exit::runtime::InferenceEngine;
use mdi_exit::testkit::bench::{fmt_dur, BenchSuite};
use mdi_exit::util::rng::Pcg64;

fn bench_queues(suite: &mut BenchSuite) {
    let mut q = TaskQueue::new();
    let mut id = 0u64;
    suite.bench_micro("queue push+pop + alg1 decision", 10_000, || {
        id += 1;
        q.push(Task::initial(id, (id % 4096) as usize, None, 0.0));
        let t = q.pop().unwrap();
        let d = policy::alg1_decide(0.7, 0.9, false, 3, t.stage, 50);
        std::hint::black_box(d);
    });
}

fn bench_offload_scan(suite: &mut BenchSuite) {
    let mut rng = Pcg64::new(1, 0);
    let views: Vec<NeighborView> = (0..4)
        .map(|i| NeighborView {
            input_len: i,
            gamma_s: 0.004 + i as f64 * 1e-3,
            d_nm_s: 0.006,
        })
        .collect();
    suite.bench_micro("alg2 scan over 4 neighbors", 10_000, || {
        for v in &views {
            let d = policy::offload_decide(OffloadRule::Alg2, 6, 3, 0.005, v, &mut rng);
            std::hint::black_box(d);
        }
    });
}

fn bench_des_throughput(suite: &mut BenchSuite) {
    // synthetic 3-stage model, saturated 5-node mesh, 60 virtual seconds
    let n = 512;
    let mut conf = Vec::with_capacity(n * 3);
    let mut pred = Vec::with_capacity(n * 3);
    let labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
    for i in 0..n {
        conf.extend([0.6f32, 0.85, 0.99]);
        pred.extend([labels[i]; 3]);
    }
    let engine = SimEngine::from_table(ExitTable::synthetic(n, 3, conf, pred), false);
    let meta = ModelMeta::synthetic(vec![0.002, 0.002, 0.003], vec![12288, 24576, 16384]);
    let mut completed = 0u64;
    let r = suite.bench("DES: 5-node mesh, 60 virtual s @ 400 Hz", || {
        let mut cfg = ExperimentConfig::new(
            "bench",
            "5-node-mesh",
            AdmissionMode::Fixed { rate_hz: 400.0, threshold: 0.9 },
        );
        cfg.duration_s = 60.0;
        cfg.warmup_s = 5.0;
        let report = Run::builder()
            .config(cfg)
            .model(meta.clone())
            .engine(&engine)
            .labels(&labels)
            .driver(Driver::Des)
            .execute()
            .unwrap();
        completed = report.completed;
    });
    let virt_per_wall = 65.0 / r.mean_s;
    println!(
        "  -> {completed} tasks completed / run; {virt_per_wall:.0}x faster than realtime"
    );
}

fn bench_xla_stage(suite: &mut BenchSuite) {
    let Ok(manifest) = mdi_exit::artifact::Manifest::load(mdi_exit::artifacts_dir()) else {
        println!("(artifacts missing — skipping stage bench)");
        return;
    };
    // PJRT-compiled stages under the `pjrt` feature; oracle replay with
    // cost emulation otherwise — either way the per-stage wallclock below
    // is comparable against the manifest's measured cost.
    let Ok(engine) = mdi_exit::runtime::default_engine(&manifest, "mobilenetv2l", false)
    else {
        println!("(engine unavailable — skipping)");
        return;
    };
    let ds = mdi_exit::dataset::Dataset::load(
        manifest.path(&manifest.dataset.file),
    )
    .expect("dataset");
    let img = ds.image(0);
    let r = suite
        .bench("stage 1 (mobilenetv2l) execute", || {
            let out = engine.run_stage(1, 0, Some(&img)).expect("stage");
            std::hint::black_box(out.confidence);
        })
        .clone();
    if cfg!(feature = "pjrt") {
        let manifest_cost =
            manifest.model("mobilenetv2l").unwrap().stages[0].cost_ms / 1e3;
        println!(
            "  -> manifest cost {} vs measured {}",
            fmt_dur(manifest_cost),
            fmt_dur(r.mean_s)
        );
    } else {
        // Without PJRT the engine spin-waits the manifest cost, so comparing
        // against it would be circular — just report the measurement.
        println!("  -> measured {} (oracle cost emulation; build with --features pjrt for real stage timing)",
                 fmt_dur(r.mean_s));
    }
}

fn main() {
    // CI smoke mode: a handful of iterations so scheduling/hot-path
    // regressions fail fast without burning runner minutes.
    let quick = std::env::var_os("MDI_BENCH_QUICK").is_some();
    let (warmup, iters) = if quick { (1, 3) } else { (2, 12) };
    let mut suite = BenchSuite::new("L3 hot paths").warmup(warmup).iters(iters);
    bench_queues(&mut suite);
    bench_offload_scan(&mut suite);
    bench_des_throughput(&mut suite);
    bench_xla_stage(&mut suite);
    suite.report();
}
