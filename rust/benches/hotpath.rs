//! Hot-path benchmarks (the L3 perf targets of DESIGN.md §8), now a
//! trajectory: results land in `BENCH_hotpath.json` so successive runs
//! are comparable.
//!
//! * task hand-off: queue push/pop + Alg. 1 decision        (< 5 µs)
//! * Alg. 2 scan against 4 neighbor views                    (< 5 µs)
//! * the trait seams next to those free functions: the
//!   `QueueDiscipline` objects (`SchedConfig::build_queue`) and the
//!   `OffloadPolicy` objects (`PolicyConfig::build_offload`), including
//!   the `AdaptiveCoalesce` run-sizing wrapper
//! * the full `WorkerCore` offload path, owned-`Vec` payloads vs
//!   shared-buffer views — tasks/s, allocs/task, bytes/task (asserted:
//!   the zero-copy wire must hold its speedup)
//! * adaptive vs fixed-size coalescing, ablated across traffic regimes
//!   on the DES (asserted: adaptive wins at least one)
//! * DES event throughput on a saturated 5-node mesh         (Mevents/s)
//! * XLA stage execution, when artifacts are present         (per-stage ms)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use mdi_exit::coordinator::queues::TaskQueue;
use mdi_exit::coordinator::task::Task;
use mdi_exit::coordinator::{
    Action, AdmissionMode, Driver, ExperimentConfig, ModelMeta, Run, TaskOrigin, WorkerCore,
};
use mdi_exit::dataset::ExitTable;
use mdi_exit::policy::{
    self, AdaptiveCoalesce, NeighborSummary, NeighborView, OffloadCtx, OffloadKind,
    OffloadPolicy, OffloadRule,
};
use mdi_exit::runtime::sim_engine::SimEngine;
use mdi_exit::runtime::{InferenceEngine, StageOutput};
use mdi_exit::sched::{BatchPolicy, CoalesceMode, DisciplineKind, SchedConfig};
use mdi_exit::simnet::{LinkSpec, Topology};
use mdi_exit::tensor::{Tensor, TensorBuf};
use mdi_exit::testkit::bench::{fmt_dur, BenchResult, BenchSuite};
use mdi_exit::util::json::{obj, Json};
use mdi_exit::util::rng::Pcg64;
use mdi_exit::workload::ArrivalSpec;

// ---------------------------------------------------------------------------
// Counting allocator: allocs/task and bytes/task for the offload-path leg.
// Bench-binary only — the library itself stays `forbid(unsafe_code)`.
// ---------------------------------------------------------------------------

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_snapshot() -> (u64, u64) {
    (ALLOCS.load(Ordering::Relaxed), ALLOC_BYTES.load(Ordering::Relaxed))
}

// ---------------------------------------------------------------------------
// Legacy microbenches (free-function hot paths)
// ---------------------------------------------------------------------------

fn bench_queues(suite: &mut BenchSuite) {
    let mut q = TaskQueue::new();
    let mut id = 0u64;
    suite.bench_micro("queue push+pop + alg1 decision", 10_000, || {
        id += 1;
        q.push(Task::initial(id, (id % 4096) as usize, None, 0.0));
        let t = q.pop().unwrap();
        let d = policy::alg1_decide(0.7, 0.9, false, 3, t.stage, 50);
        std::hint::black_box(d);
    });
}

fn bench_offload_scan(suite: &mut BenchSuite) {
    let mut rng = Pcg64::new(1, 0);
    let views: Vec<NeighborView> = (0..4)
        .map(|i| NeighborView {
            input_len: i,
            gamma_s: 0.004 + i as f64 * 1e-3,
            d_nm_s: 0.006,
        })
        .collect();
    suite.bench_micro("alg2 scan over 4 neighbors", 10_000, || {
        for v in &views {
            let d = policy::offload_decide(OffloadRule::Alg2, 6, 3, 0.005, v, &mut rng);
            std::hint::black_box(d);
        }
    });
}

// ---------------------------------------------------------------------------
// Trait-seam microbenches: the policy/discipline objects that replaced the
// free functions must cost the same order of magnitude.
// ---------------------------------------------------------------------------

fn push_seam_row(rows: &mut Vec<Json>, r: BenchResult) {
    rows.push(obj(vec![
        ("name", r.name.into()),
        ("mean_s", r.mean_s.into()),
        ("p50_s", r.p50_s.into()),
        ("p95_s", r.p95_s.into()),
    ]));
}

fn bench_discipline_seam(suite: &mut BenchSuite, rows: &mut Vec<Json>) {
    for (name, kind) in [
        ("fifo", DisciplineKind::Fifo),
        ("edf", DisciplineKind::Edf { drop_late: false }),
    ] {
        let sched = SchedConfig { discipline: kind, ..SchedConfig::default() };
        let mut q = sched.build_queue(0.0);
        let mut id = 0u64;
        let r = suite
            .bench_micro(&format!("discipline seam ({name}): push + pop_next"), 10_000, || {
                id += 1;
                let mut t = Task::initial(id, (id % 4096) as usize, None, 0.0);
                t.deadline = 1.0 + (id % 97) as f64 * 1e-3;
                q.push(t);
                let popped = q.pop_next(0.0).unwrap();
                std::hint::black_box(popped.id);
            })
            .clone();
        push_seam_row(rows, r);
    }
}

fn bench_offload_policy_seam(suite: &mut BenchSuite, rows: &mut Vec<Json>) {
    let candidates: Vec<(usize, NeighborSummary)> = (1..5)
        .map(|m| {
            let mut s = NeighborSummary::base(m, 0.004 + m as f64 * 1e-3, 0.9);
            s.d_nm_s = 0.004 + m as f64 * 5e-4;
            (m, s)
        })
        .collect();
    let next_hop: Vec<Option<usize>> = vec![None, Some(1), Some(2), Some(3), Some(4)];
    let task = Task::initial(1, 0, None, 0.0);
    let mut rng = Pcg64::new(1, 1);
    let policy_cfg = ExperimentConfig::new(
        "bench",
        "5-node-mesh",
        AdmissionMode::Fixed { rate_hz: 1.0, threshold: 0.9 },
    )
    .policy;

    let mut alg2 = policy_cfg.build_offload(0, 5);
    let r = suite
        .bench_micro("offload seam (alg2 object): choose over 4 neighbors", 10_000, || {
            let ctx = OffloadCtx {
                now: 0.0,
                task: &task,
                input_len: 3,
                output_len: 6,
                gamma_s: 0.005,
                candidates: &candidates,
                next_hop: &next_hop,
            };
            std::hint::black_box(alg2.choose(&ctx, &mut rng));
        })
        .clone();
    push_seam_row(rows, r);

    let mut adaptive = AdaptiveCoalesce::new(policy_cfg.build_offload(0, 5));
    let r = suite
        .bench_micro("offload seam (adaptive wrap): choose_coalesced + take", 10_000, || {
            let ctx = OffloadCtx {
                now: 0.0,
                task: &task,
                input_len: 3,
                output_len: 6,
                gamma_s: 0.005,
                candidates: &candidates,
                next_hop: &next_hop,
            };
            if let Some(target) = adaptive.choose_coalesced(&ctx, 8, &mut rng) {
                std::hint::black_box(adaptive.coalesce_take(&ctx, target, 8));
            }
        })
        .clone();
    push_seam_row(rows, r);
}

// ---------------------------------------------------------------------------
// The tentpole leg: the full WorkerCore offload path, owned-Vec payloads
// (the pre-zero-copy wire) vs shared-buffer views. Same admissions, same
// envelopes — the only difference is whether every queue boundary copies
// the activation or bumps a refcount.
// ---------------------------------------------------------------------------

/// f32 elements per activation: 128 KiB payloads, the regime where the
/// owned wire's copies dominate the hand-off cost.
const FEAT: usize = 32_768;
/// Distinct prototype activations, all views into ONE backing buffer.
const PROTOS: usize = 16;
/// Admissions per drive round (one compute batch forms behind a single).
const ROUND: usize = 8;

struct PathLeg {
    tasks_per_s: f64,
    allocs_per_task: f64,
    bytes_per_task: f64,
    shipped: usize,
    envelopes: usize,
}

fn offload_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(
        "bench",
        "5-node-mesh",
        AdmissionMode::Fixed { rate_hz: 1000.0, threshold: 0.99 },
    );
    cfg.warmup_s = 0.0;
    cfg.policy.offload = OffloadKind::RoundRobin;
    cfg.sched.batch = BatchPolicy::batched(ROUND);
    cfg.sched.coalesce = CoalesceMode::Stage;
    cfg.sched.coalesce_max = ROUND;
    cfg
}

fn proto_pool() -> Vec<Tensor> {
    let mut data = Vec::with_capacity(FEAT * PROTOS);
    for i in 0..FEAT * PROTOS {
        data.push((i % 251) as f32 * 0.01 - 1.0);
    }
    let buf = TensorBuf::from_vec(data);
    (0..PROTOS).map(|i| Tensor::view(buf.clone(), i * FEAT, vec![FEAT])).collect()
}

/// The pre-PR payload behaviour: materialize an owned copy of the
/// activation at the queue boundary.
fn owned_copy(p: &Tensor) -> Tensor {
    let mut data = Vec::with_capacity(p.numel());
    data.extend_from_slice(p.data());
    Tensor::new(vec![p.numel()], data)
}

/// Drive a source core through `rounds` admit → compute → offload cycles
/// and count the tasks crossing the wire. Payloads are either owned
/// copies (`owned = true`) or refcounted views of the prototype pool.
fn drive_offload_path(owned: bool, rounds: usize, protos: &[Tensor]) -> (usize, usize) {
    let cfg = offload_cfg();
    let meta = ModelMeta::synthetic(vec![0.002, 0.003], vec![FEAT * 4, FEAT * 4]);
    let topo = Topology::named("5-node-mesh", LinkSpec::wifi()).expect("topology");
    let mut w = WorkerCore::new(0, &cfg, meta, &topo, PROTOS);
    let mut now = 0.0f64;
    let mut id = 0u64;
    let (mut shipped, mut envelopes) = (0usize, 0usize);
    let mut pending: Vec<Action> = Vec::new();
    for _ in 0..rounds {
        for _ in 0..ROUND {
            let p = &protos[(id as usize) % PROTOS];
            let feat = if owned { owned_copy(p) } else { p.clone() };
            let task = Task::initial(id, (id as usize) % PROTOS, Some(feat), now);
            id += 1;
            pending.extend(w.on_task(now, task, TaskOrigin::Admitted));
        }
        while let Some(action) = pending.pop() {
            match action {
                Action::StartCompute { batch, est_cost_s } => {
                    now += est_cost_s.max(1e-6);
                    let results: Vec<(StageOutput, usize)> = batch
                        .iter()
                        .map(|t| {
                            let features = (t.stage < 2).then(|| {
                                let p = &protos[t.sample % PROTOS];
                                if owned { owned_copy(p) } else { p.clone() }
                            });
                            // Low confidence: every task continues to
                            // stage 2 and rides the offload path.
                            (StageOutput { features, confidence: 0.05, prediction: 0 }, t.stage)
                        })
                        .collect();
                    pending.extend(w.on_compute_done(now, batch, results, est_cost_s));
                }
                Action::Send { env, .. } => {
                    if let Some(tasks) = env.task_batch() {
                        shipped += tasks.len();
                        envelopes += 1;
                    }
                    std::hint::black_box(&env);
                }
                _ => {}
            }
        }
        now += 0.001;
    }
    (shipped, envelopes)
}

fn measure_leg(owned: bool, rounds: usize, protos: &[Tensor]) -> PathLeg {
    drive_offload_path(owned, rounds / 10 + 1, protos); // warmup
    let (a0, b0) = alloc_snapshot();
    let t0 = Instant::now();
    let (shipped, envelopes) = drive_offload_path(owned, rounds, protos);
    let dt = t0.elapsed().as_secs_f64().max(1e-9);
    let (a1, b1) = alloc_snapshot();
    let tasks = (rounds * ROUND) as f64;
    PathLeg {
        tasks_per_s: tasks / dt,
        allocs_per_task: (a1 - a0) as f64 / tasks,
        bytes_per_task: (b1 - b0) as f64 / tasks,
        shipped,
        envelopes,
    }
}

fn bench_offload_path(quick: bool) -> Json {
    let protos = proto_pool();
    let rounds = if quick { 300 } else { 2000 };
    let tasks = rounds * ROUND;
    let owned = measure_leg(true, rounds, &protos);
    let shared = measure_leg(false, rounds, &protos);
    assert_eq!(owned.shipped, tasks, "every admitted task crosses the wire once");
    assert_eq!(shared.shipped, tasks, "every admitted task crosses the wire once");
    assert_eq!(owned.envelopes, shared.envelopes, "legs coalesce identically");

    let speedup = shared.tasks_per_s / owned.tasks_per_s;
    println!(
        "  owned:  {:>10.0} tasks/s  {:>6.1} allocs/task  {:>10.0} bytes/task",
        owned.tasks_per_s, owned.allocs_per_task, owned.bytes_per_task
    );
    println!(
        "  shared: {:>10.0} tasks/s  {:>6.1} allocs/task  {:>10.0} bytes/task",
        shared.tasks_per_s, shared.allocs_per_task, shared.bytes_per_task
    );
    println!("  -> {speedup:.2}x tasks/s from the zero-copy wire ({tasks} tasks/leg)");

    // The quick (CI smoke) floor is deliberately loose — shared runners
    // jitter — while the full run must hold the PR's 2x claim.
    let floor = if quick { 1.3 } else { 2.0 };
    assert!(
        speedup >= floor,
        "zero-copy offload path regressed: {speedup:.2}x under the {floor}x floor \
         (owned {:.0} vs shared {:.0} tasks/s)",
        owned.tasks_per_s,
        shared.tasks_per_s
    );
    // Two owned copies per task (admission + stage output) vs two
    // refcount bumps: at least one full payload of allocated bytes and
    // both Vec allocations must separate the legs.
    assert!(
        owned.bytes_per_task - shared.bytes_per_task >= (FEAT * 4) as f64,
        "owned leg should allocate at least one payload copy more per task \
         (owned {:.0} vs shared {:.0} bytes/task)",
        owned.bytes_per_task,
        shared.bytes_per_task
    );
    assert!(
        owned.allocs_per_task - shared.allocs_per_task >= 1.5,
        "owned leg should pay ~2 payload allocations more per task \
         (owned {:.1} vs shared {:.1} allocs/task)",
        owned.allocs_per_task,
        shared.allocs_per_task
    );

    obj(vec![
        ("tasks", tasks.into()),
        ("feat_elems", FEAT.into()),
        ("envelopes", owned.envelopes.into()),
        ("owned_tasks_per_s", owned.tasks_per_s.into()),
        ("shared_tasks_per_s", shared.tasks_per_s.into()),
        ("speedup", speedup.into()),
        ("speedup_floor", floor.into()),
        ("owned_allocs_per_task", owned.allocs_per_task.into()),
        ("shared_allocs_per_task", shared.allocs_per_task.into()),
        ("owned_bytes_per_task", owned.bytes_per_task.into()),
        ("shared_bytes_per_task", shared.bytes_per_task.into()),
    ])
}

// ---------------------------------------------------------------------------
// Adaptive-coalescing ablation: fixed coalesce_max runs vs contention-sized
// runs, across an idle-bursty regime (head-of-line latency dominates: the
// adaptive wire ships singles/short runs) and a saturated one (contention
// slots dominate: both drain full runs). DES — deterministic per seed.
// ---------------------------------------------------------------------------

fn coalesce_ablation(quick: bool) -> Json {
    let n = 256;
    let mut conf = Vec::with_capacity(n * 2);
    let mut pred = Vec::with_capacity(n * 2);
    let labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
    for i in 0..n {
        conf.extend([0.6f32, 0.99]);
        pred.extend([labels[i]; 2]);
    }
    let engine = SimEngine::from_table(ExitTable::synthetic(n, 2, conf, pred), false);
    // Big stage-2 activations (64 KiB): per-task serialization dominates
    // base latency, so an 8-task envelope costs ~8x the wire time of the
    // first of 8 pipelined singles — the head-of-line regime the adaptive
    // policy is for.
    let meta = ModelMeta::synthetic(vec![0.002, 0.003], vec![12288, 65536]);

    // Bursts of 8 admissions every 250 ms on an otherwise idle link.
    let burst = ArrivalSpec::Trace {
        dts: {
            let mut dts = vec![0.25];
            dts.extend([1e-4; 7]);
            dts
        },
    };
    let regimes: [(&str, &str, f64, f64, Option<ArrivalSpec>); 2] = [
        ("idle-bursty", "2-node", 32.0, if quick { 12.0 } else { 40.0 }, Some(burst)),
        ("saturated", "3-node-mesh", 400.0, if quick { 4.0 } else { 8.0 }, None),
    ];

    let mut rows = Vec::new();
    let mut wins = 0usize;
    for (name, topology, rate_hz, secs, arrival) in regimes {
        let run = |mode: CoalesceMode| {
            let mut cfg = ExperimentConfig::new(
                "bench",
                topology,
                AdmissionMode::Fixed { rate_hz, threshold: 0.9 },
            );
            cfg.duration_s = secs;
            cfg.warmup_s = 1.0;
            cfg.policy.offload = OffloadKind::RoundRobin;
            cfg.sched.batch = BatchPolicy::batched(8);
            cfg.sched.coalesce = mode;
            cfg.sched.coalesce_max = 8;
            if let Some(a) = arrival.clone() {
                cfg.workload.arrival = a;
            }
            Run::builder()
                .config(cfg)
                .model(meta.clone())
                .engine(&engine)
                .labels(&labels)
                .driver(Driver::Des)
                .execute()
                .unwrap()
        };
        let fixed = run(CoalesceMode::Stage);
        let adaptive = run(CoalesceMode::Adaptive);
        assert!(
            fixed.completed > 0 && adaptive.completed > 0,
            "ablation regime {name} completed no work"
        );
        let (f_mean, a_mean) = (fixed.latency.mean(), adaptive.latency.mean());
        if a_mean < f_mean {
            wins += 1;
        }
        println!(
            "  {name}: mean latency fixed {} vs adaptive {} (coalesced {} vs {} tasks)",
            fmt_dur(f_mean),
            fmt_dur(a_mean),
            fixed.coalesced_tasks(),
            adaptive.coalesced_tasks(),
        );
        rows.push(obj(vec![
            ("regime", name.into()),
            ("completed_fixed", (fixed.completed as f64).into()),
            ("completed_adaptive", (adaptive.completed as f64).into()),
            ("fixed_latency_mean_s", f_mean.into()),
            ("adaptive_latency_mean_s", a_mean.into()),
            ("fixed_coalesced_tasks", (fixed.coalesced_tasks() as f64).into()),
            ("adaptive_coalesced_tasks", (adaptive.coalesced_tasks() as f64).into()),
            ("fixed_bytes_on_wire", (fixed.bytes_on_wire as f64).into()),
            ("adaptive_bytes_on_wire", (adaptive.bytes_on_wire as f64).into()),
            ("adaptive_wins", (a_mean < f_mean).into()),
        ]));
    }
    assert!(
        wins >= 1,
        "adaptive coalescing must beat the fixed coalesce_max run on at least one regime"
    );
    Json::Arr(rows)
}

// ---------------------------------------------------------------------------
// Legacy macro legs
// ---------------------------------------------------------------------------

fn bench_des_throughput(suite: &mut BenchSuite) {
    // synthetic 3-stage model, saturated 5-node mesh, 60 virtual seconds
    let n = 512;
    let mut conf = Vec::with_capacity(n * 3);
    let mut pred = Vec::with_capacity(n * 3);
    let labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
    for i in 0..n {
        conf.extend([0.6f32, 0.85, 0.99]);
        pred.extend([labels[i]; 3]);
    }
    let engine = SimEngine::from_table(ExitTable::synthetic(n, 3, conf, pred), false);
    let meta = ModelMeta::synthetic(vec![0.002, 0.002, 0.003], vec![12288, 24576, 16384]);
    let mut completed = 0u64;
    let r = suite.bench("DES: 5-node mesh, 60 virtual s @ 400 Hz", || {
        let mut cfg = ExperimentConfig::new(
            "bench",
            "5-node-mesh",
            AdmissionMode::Fixed { rate_hz: 400.0, threshold: 0.9 },
        );
        cfg.duration_s = 60.0;
        cfg.warmup_s = 5.0;
        let report = Run::builder()
            .config(cfg)
            .model(meta.clone())
            .engine(&engine)
            .labels(&labels)
            .driver(Driver::Des)
            .execute()
            .unwrap();
        completed = report.completed;
    });
    let virt_per_wall = 65.0 / r.mean_s;
    println!(
        "  -> {completed} tasks completed / run; {virt_per_wall:.0}x faster than realtime"
    );
}

fn bench_xla_stage(suite: &mut BenchSuite) {
    let Ok(manifest) = mdi_exit::artifact::Manifest::load(mdi_exit::artifacts_dir()) else {
        println!("(artifacts missing — skipping stage bench)");
        return;
    };
    // PJRT-compiled stages under the `pjrt` feature; oracle replay with
    // cost emulation otherwise — either way the per-stage wallclock below
    // is comparable against the manifest's measured cost.
    let Ok(engine) = mdi_exit::runtime::default_engine(&manifest, "mobilenetv2l", false)
    else {
        println!("(engine unavailable — skipping)");
        return;
    };
    let ds = mdi_exit::dataset::Dataset::load(
        manifest.path(&manifest.dataset.file),
    )
    .expect("dataset");
    let img = ds.image(0);
    let r = suite
        .bench("stage 1 (mobilenetv2l) execute", || {
            let out = engine.run_stage(1, 0, Some(&img)).expect("stage");
            std::hint::black_box(out.confidence);
        })
        .clone();
    if cfg!(feature = "pjrt") {
        let manifest_cost =
            manifest.model("mobilenetv2l").unwrap().stages[0].cost_ms / 1e3;
        println!(
            "  -> manifest cost {} vs measured {}",
            fmt_dur(manifest_cost),
            fmt_dur(r.mean_s)
        );
    } else {
        // Without PJRT the engine spin-waits the manifest cost, so comparing
        // against it would be circular — just report the measurement.
        println!("  -> measured {} (oracle cost emulation; build with --features pjrt for real stage timing)",
                 fmt_dur(r.mean_s));
    }
}

fn main() {
    // CI smoke mode: a handful of iterations so scheduling/hot-path
    // regressions fail fast without burning runner minutes.
    let quick = std::env::var_os("MDI_BENCH_QUICK").is_some();
    let (warmup, iters) = if quick { (1, 3) } else { (2, 12) };
    let mut suite = BenchSuite::new("L3 hot paths").warmup(warmup).iters(iters);
    let mut seam_rows = Vec::new();
    bench_queues(&mut suite);
    bench_offload_scan(&mut suite);
    bench_discipline_seam(&mut suite, &mut seam_rows);
    bench_offload_policy_seam(&mut suite, &mut seam_rows);

    println!("zero-copy offload path (owned Vec payloads vs shared-buffer views):");
    let offload_path = bench_offload_path(quick);

    println!("adaptive coalescing ablation (fixed run vs contention-sized run):");
    let ablation = coalesce_ablation(quick);

    bench_des_throughput(&mut suite);
    bench_xla_stage(&mut suite);
    suite.report();

    let doc = obj(vec![
        ("bench", "hotpath".into()),
        ("quick", quick.into()),
        ("offload_path", offload_path),
        ("seams", Json::Arr(seam_rows)),
        ("coalesce_ablation", ablation),
    ]);
    std::fs::write("BENCH_hotpath.json", doc.to_string()).expect("write BENCH_hotpath.json");
    println!("\nwrote BENCH_hotpath.json");
}
