//! Ablation: sensitivity to the output-queue threshold T_O (Alg. 1 line 8)
//! plus the DDI-vs-MDI comparison motivating the paper's §I.

use mdi_exit::artifact::Manifest;
use mdi_exit::experiments as exp;

fn main() {
    let manifest = match Manifest::load(mdi_exit::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping ablation (artifacts missing): {e:#}");
            return;
        }
    };
    let opts = exp::SweepOpts::full();
    let rows = exp::ablation_thresholds(&manifest, opts).expect("T_O sweep");
    exp::print_rows("abl-queue — T_O sensitivity (Alg. 1)", "T_O", &rows);
    let rows = exp::ddi_comparison(&manifest, opts).expect("ddi sweep");
    exp::print_rows("DDI vs MDI-Exit (MobileNet, 3-node mesh)", "rate", &rows);
}
