//! Scheduling ablation: FIFO vs StrictPriority vs EDF vs batched FIFO on
//! the same seed and workload, on both drivers.
//!
//! Two claims are asserted (so CI fails on a scheduling regression, not
//! just a drifting BENCH history):
//!
//! * batching ≥ 1.5× completed throughput over unbatched FIFO on the DES
//!   driver (and a conservative ≥ 1.3× on the realtime driver);
//! * StrictPriority gives class 0 a lower p95 latency than FIFO gives the
//!   same traffic under overload, on *both* drivers.
//!
//! Entirely artifact-free: a synthetic oracle drives both drivers through
//! the `Run` builder. `MDI_BENCH_QUICK=1` shrinks the windows for CI.

use anyhow::Result;

use mdi_exit::coordinator::{
    AdmissionMode, Driver, ExperimentConfig, ModelMeta, Placement, Run, RunReport,
};
use mdi_exit::dataset::{Dataset, ExitTable};
use mdi_exit::runtime::sim_engine::SimEngine;
use mdi_exit::runtime::InferenceEngine;
use mdi_exit::sched::{BatchPolicy, CoalesceMode, DisciplineKind};
use mdi_exit::simnet::LinkSpec;

/// Stage costs shared by every run: 2 ms + 3 ms, speed 1.0.
const COSTS: [f64; 2] = [0.002, 0.003];

/// `n` samples × 2 exits; every `confident_of`-th sample needs stage 2,
/// the rest exit at 1. Predictions always match the label.
fn oracle(n: usize, confident_of: usize) -> (ExitTable, Vec<u8>) {
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
    for (i, &l) in labels.iter().enumerate() {
        if i % confident_of == confident_of - 1 {
            conf.extend([0.30f32, 0.95]);
        } else {
            conf.extend([0.97f32, 0.99]);
        }
        pred.extend([l, l]);
    }
    (ExitTable::synthetic(n, 2, conf, pred), labels)
}

fn meta() -> ModelMeta {
    ModelMeta::synthetic(COSTS.to_vec(), vec![12288, 8192])
}

fn base_cfg(rate_hz: f64, seconds: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(
        "sched-ablation",
        "local",
        AdmissionMode::Fixed { rate_hz, threshold: 0.9 },
    );
    cfg.duration_s = seconds;
    cfg.warmup_s = 0.5;
    cfg.seed = 7;
    cfg
}

fn run_des(cfg: ExperimentConfig, n: usize, confident_of: usize) -> RunReport {
    let (table, labels) = oracle(n, confident_of);
    let engine = SimEngine::from_table(table, false);
    Run::builder()
        .config(cfg)
        .model(meta())
        .engine(&engine)
        .labels(&labels)
        .driver(Driver::Des)
        .execute()
        .expect("DES run")
}

fn run_rt(cfg: ExperimentConfig, n: usize, confident_of: usize) -> RunReport {
    let (_, labels) = oracle(n, confident_of);
    let ds = Dataset::synthetic(n, 2, 2, 3, labels);
    let factory = move |_w: usize| -> Result<Box<dyn InferenceEngine>> {
        let (table, _) = oracle(n, confident_of);
        let eng = SimEngine::from_table(table, false).with_costs(COSTS.to_vec(), 1.0);
        Ok(Box::new(eng) as Box<dyn InferenceEngine>)
    };
    Run::builder()
        .config(cfg)
        .model(meta())
        .engine_factory(factory)
        .dataset(&ds)
        .driver(Driver::Realtime)
        .execute()
        .expect("realtime run")
}

fn row(name: &str, driver: &str, r: &mut RunReport) {
    let (c0, c1) = if r.per_class.len() > 1 {
        let [a, b] = &mut r.per_class[..] else { unreachable!() };
        (a.latency.p95() * 1e3, b.latency.p95() * 1e3)
    } else {
        (f64::NAN, f64::NAN)
    };
    println!(
        "{name:<26} {driver:<9} {:>10.1} {:>10.2} {:>10.2} {:>10.2} {:>8}",
        r.throughput_hz(),
        r.latency.p95() * 1e3,
        c0,
        c1,
        r.dropped
    );
}

fn main() {
    let quick = std::env::var_os("MDI_BENCH_QUICK").is_some();
    let (des_s, rt_s) = if quick { (6.0, 1.2) } else { (30.0, 3.0) };
    // The DES legs are virtual-time-deterministic, so their margins are
    // tight everywhere; the realtime legs run short windows on shared CI
    // cores, so quick mode loosens their margins to avoid jitter flakes
    // while still catching real regressions.
    let (rt_gain_floor, rt_prio_factor) = if quick { (1.15, 0.8) } else { (1.3, 0.5) };

    println!("== bench: sched ablation (same seed, 2-stage synthetic model) ==");
    println!(
        "{:<26} {:<9} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "scenario", "driver", "tput(Hz)", "p95(ms)", "c0p95(ms)", "c1p95(ms)", "dropped"
    );

    // -- batching: overload one worker, 7/8 of traffic exits at stage 1 ---
    let overload = 2500.0;
    let mut fifo_des = run_des(base_cfg(overload, des_s), 16, 8);
    let mut cfg = base_cfg(overload, des_s);
    cfg.sched.batch = BatchPolicy::batched(8);
    let mut batch_des = run_des(cfg, 16, 8);
    row("fifo (unbatched)", "DES", &mut fifo_des);
    row("fifo + batch 8", "DES", &mut batch_des);

    let mut fifo_rt = run_rt(base_cfg(overload, rt_s), 16, 8);
    let mut cfg = base_cfg(overload, rt_s);
    cfg.sched.batch = BatchPolicy::batched(8);
    let mut batch_rt = run_rt(cfg, 16, 8);
    row("fifo (unbatched)", "realtime", &mut fifo_rt);
    row("fifo + batch 8", "realtime", &mut batch_rt);

    let gain_des = batch_des.completed as f64 / fifo_des.completed.max(1) as f64;
    let gain_rt = batch_rt.completed as f64 / fifo_rt.completed.max(1) as f64;
    println!("  -> batching gain: DES {gain_des:.2}x, realtime {gain_rt:.2}x");
    assert!(gain_des >= 1.5, "DES batching gain {gain_des:.2}x < 1.5x");
    assert!(
        gain_rt >= rt_gain_floor,
        "realtime batching gain {gain_rt:.2}x < {rt_gain_floor}x"
    );

    // -- priority classes: class 0 fits capacity, class 1 overloads it ----
    // 480 Hz round-robin over two classes on the 50/50 oracle: class 0 is
    // the even (exit-1, 2 ms) samples at 240 Hz — within the worker's
    // capacity — while class 1 needs both stages and backs up behind it.
    let classes = |mut cfg: ExperimentConfig, d: DisciplineKind| {
        cfg.sched = cfg.sched.with_classes(2);
        cfg.sched.discipline = d;
        cfg
    };
    let mut fifo_des = run_des(classes(base_cfg(480.0, des_s), DisciplineKind::Fifo), 8, 2);
    let mut prio_des =
        run_des(classes(base_cfg(480.0, des_s), DisciplineKind::StrictPriority), 8, 2);
    row("fifo, 2 classes", "DES", &mut fifo_des);
    row("strict-priority", "DES", &mut prio_des);

    let mut fifo_rt = run_rt(classes(base_cfg(480.0, rt_s), DisciplineKind::Fifo), 8, 2);
    let mut prio_rt =
        run_rt(classes(base_cfg(480.0, rt_s), DisciplineKind::StrictPriority), 8, 2);
    row("fifo, 2 classes", "realtime", &mut fifo_rt);
    row("strict-priority", "realtime", &mut prio_rt);

    for (driver, factor, fifo, prio) in [
        ("DES", 0.5, &mut fifo_des, &mut prio_des),
        ("realtime", rt_prio_factor, &mut fifo_rt, &mut prio_rt),
    ] {
        let fifo_c0 = fifo.per_class[0].latency.p95();
        let prio_c0 = prio.per_class[0].latency.p95();
        println!(
            "  -> {driver}: class-0 p95 {:.2} ms (fifo) vs {:.2} ms (priority)",
            fifo_c0 * 1e3,
            prio_c0 * 1e3
        );
        assert!(
            prio_c0 < factor * fifo_c0,
            "{driver}: priority class-0 p95 {prio_c0} not below {factor} x FIFO {fifo_c0}"
        );
    }

    // -- EDF with per-class budgets: late bulk traffic is aged out --------
    let mut cfg = classes(base_cfg(480.0, des_s), DisciplineKind::Edf { drop_late: true });
    cfg.sched.class_deadline_s = vec![0.05, 2.0];
    let mut edf_des = run_des(cfg, 8, 2);
    row("edf (50ms/2s, drop)", "DES", &mut edf_des);
    let by_class: u64 = edf_des.per_class.iter().map(|c| c.completed).sum();
    assert_eq!(by_class, edf_des.completed, "per-class counters conserve");

    // -- multi-hop routing: 2 sources on a 4-node line --------------------
    // FIFO again, but on a multi-hop topology with admission split across
    // both ends of the line and a stage-3-heavy 3-stage model (a 2-stage
    // model cannot push work past one hop): continuing work spills toward
    // the middle, and every far exit relays its result back hop by hop.
    // Routing overhead (relay work, multi-hop latency) lands in this
    // bench's trajectory instead of hiding in a one-hop testbed, and the
    // per-source totals are asserted so a routing regression fails CI.
    let line = |mut cfg: ExperimentConfig| {
        cfg.topology = "line-4".into();
        cfg.placement = Placement::multi(&[0, 3]);
        cfg
    };
    let mut line_des = run_des3(line(base_cfg(400.0, des_s)));
    row("2-src line-4 (fifo)", "DES", &mut line_des);
    let mut line_rt = run_rt3(line(base_cfg(400.0, rt_s)));
    row("2-src line-4 (fifo)", "realtime", &mut line_rt);

    for (driver, r) in [("DES", &line_des), ("realtime", &line_rt)] {
        let by_source: u64 = r.per_source.iter().map(|s| s.completed).sum();
        assert_eq!(by_source, r.completed, "{driver}: per-source counters conserve");
        for s in &r.per_source {
            assert!(s.completed > 0, "{driver}: source {} starved", s.node);
        }
    }
    // The DES leg is virtual-time-deterministic: multi-hop delivery must
    // actually happen (results relayed through intermediate workers).
    let relays: u64 = line_des.per_worker.iter().map(|w| w.relayed).sum();
    assert!(relays > 0, "multi-hop line run produced no relays");
    println!("  -> line-4 relays (DES): {relays}, per-source completed: {:?}",
             line_des.per_source.iter().map(|s| s.completed).collect::<Vec<_>>());

    // -- cross-worker batch coalescing: batches travel the network --------
    // A star-5 hub source on an expensive shared medium (high per-message
    // base latency, strong contention), with a small T_O, engine batching,
    // and Alg. 3 adapting the admitted rate to what the system sustains.
    // The hub's batched completions dump same-stage runs into the output
    // queue; per-task wiring pays base latency + a contention slot + a
    // D_nm charge per task, which Alg. 2 weighs against the bounded local
    // backlog the controller maintains — so the per-task wire throttles
    // how much overload the leaves can absorb, and the admitted (hence
    // completed) rate settles lower. `coalesce = stage` ships each run as
    // ONE net::Envelope (one frame, one contention slot, amortized D_nm),
    // so the same decision loop keeps the leaves fed. The DES legs are
    // virtual-time-deterministic, so both claims are asserted.
    let star = |mut cfg: ExperimentConfig, mode: CoalesceMode| {
        cfg.topology = "star-5".into();
        cfg.admission =
            AdmissionMode::AdaptiveRate { threshold: 0.9, initial_mu_s: 1.0 / 600.0 };
        cfg.adapt.sleep_s = 0.1; // settle the controller within the window
        cfg.warmup_s = 2.0;
        cfg.t_o = 8; // small T_O: offload staging stays shallow
        cfg.medium_contention = 4.0; // the shared medium is the bottleneck
        cfg.link = LinkSpec {
            bandwidth_bps: 12.5e6,
            base_latency_s: 0.04, // per-message cost coalescing amortizes
            jitter_s: 0.002,
        };
        cfg.sched.batch = BatchPolicy::batched(8);
        cfg.sched.coalesce = mode;
        cfg.sched.coalesce_max = 8;
        cfg
    };
    let mut per_task = run_des3(star(base_cfg(600.0, des_s), CoalesceMode::Off));
    let mut coalesced = run_des3(star(base_cfg(600.0, des_s), CoalesceMode::Stage));
    row("star-5 off (per-task)", "DES", &mut per_task);
    row("star-5 coalesce=stage", "DES", &mut coalesced);
    let gain = coalesced.completed as f64 / per_task.completed.max(1) as f64;
    println!(
        "  -> coalescing gain: {gain:.2}x; envelopes {} -> {} ({} tasks coalesced, {} B saved)",
        per_task.envelopes_sent(),
        coalesced.envelopes_sent(),
        coalesced.coalesced_tasks(),
        coalesced.wire_bytes_saved()
    );
    // Short quick-mode windows carry a larger in-flight tail, so the floor
    // is looser there; the full run demands a clear win.
    let gain_floor = if quick { 1.02 } else { 1.05 };
    assert!(
        gain >= gain_floor,
        "coalesced offload must beat per-task offload on DES throughput: \
         {gain:.2}x < {gain_floor}x"
    );
    // Envelope economy: per task offloaded, the coalesced run must need
    // strictly fewer envelopes than the per-task wire's one-per-task (the
    // absolute counts are not comparable — the coalesced run also moves
    // more work).
    let off_tasks = |r: &RunReport| -> u64 {
        r.per_worker.iter().map(|w| w.offloaded_out).sum::<u64>().max(1)
    };
    let per_task_ratio = per_task.envelopes_sent() as f64 / off_tasks(&per_task) as f64;
    let coalesced_ratio = coalesced.envelopes_sent() as f64 / off_tasks(&coalesced) as f64;
    assert!(
        (per_task_ratio - 1.0).abs() < 1e-9,
        "per-task wire must send exactly one envelope per task: {per_task_ratio}"
    );
    assert!(
        coalesced_ratio < 1.0,
        "coalescing must cut envelopes per offloaded task: {coalesced_ratio}"
    );
    assert!(coalesced.coalesced_tasks() > 0, "no run ever shared an envelope");
}

/// 8 samples x 3 exits for the multi-hop leg: every fourth sample exits
/// at 1, the rest ride to the heavy final stage.
fn oracle3(n: usize) -> (mdi_exit::dataset::ExitTable, Vec<u8>) {
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
    for (i, &l) in labels.iter().enumerate() {
        if i % 4 == 0 {
            conf.extend([0.97f32, 0.99, 1.0]);
        } else {
            conf.extend([0.30f32, 0.50, 0.95]);
        }
        pred.extend([l; 3]);
    }
    (mdi_exit::dataset::ExitTable::synthetic(n, 3, conf, pred), labels)
}

/// Stage-3-heavy costs for the multi-hop leg.
const COSTS3: [f64; 3] = [0.001, 0.001, 0.006];

fn meta3() -> ModelMeta {
    ModelMeta::synthetic(COSTS3.to_vec(), vec![12288, 8192, 4096])
}

fn run_des3(cfg: ExperimentConfig) -> RunReport {
    let (table, labels) = oracle3(8);
    let engine = SimEngine::from_table(table, false);
    Run::builder()
        .config(cfg)
        .model(meta3())
        .engine(&engine)
        .labels(&labels)
        .driver(Driver::Des)
        .execute()
        .expect("DES run")
}

fn run_rt3(cfg: ExperimentConfig) -> RunReport {
    let (_, labels) = oracle3(8);
    let ds = Dataset::synthetic(8, 2, 2, 3, labels);
    let factory = move |_w: usize| -> Result<Box<dyn InferenceEngine>> {
        let (table, _) = oracle3(8);
        let eng = SimEngine::from_table(table, false).with_costs(COSTS3.to_vec(), 1.0);
        Ok(Box::new(eng) as Box<dyn InferenceEngine>)
    };
    Run::builder()
        .config(cfg)
        .model(meta3())
        .engine_factory(factory)
        .dataset(&ds)
        .driver(Driver::Realtime)
        .execute()
        .expect("realtime run")
}
