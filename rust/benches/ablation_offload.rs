//! Ablation: the paper's Alg. 2 offloading policy vs its deterministic-only
//! variant (no probabilistic branch), a queue-size-only policy, and blind
//! round-robin — justifying the design choices of §IV.A.

use mdi_exit::artifact::Manifest;
use mdi_exit::experiments as exp;

fn main() {
    let manifest = match Manifest::load(mdi_exit::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping ablation (artifacts missing): {e:#}");
            return;
        }
    };
    let rows =
        exp::ablation_offload(&manifest, exp::SweepOpts::full()).expect("ablation sweep");
    exp::print_rows("abl-offload — offloading policies, MobileNet 3-node mesh", "rate", &rows);
}
