//! Bench: regenerate Fig. 6 — ResNet with the stage-1 autoencoder, Poisson
//! arrivals at fixed mean rate, Alg. 4 adapts the threshold.
//!
//! Expected shape (paper): with the AE compressing the 128 KiB stage-1
//! features to 1 KiB codes, the 5-Node-Mesh becomes the best topology and
//! accuracy degrades only slightly with rate.

use mdi_exit::artifact::Manifest;
use mdi_exit::experiments as exp;
use mdi_exit::testkit::bench::BenchSuite;

fn main() {
    let manifest = match Manifest::load(mdi_exit::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping fig6 bench (artifacts missing): {e:#}");
            return;
        }
    };
    let opts = exp::SweepOpts::full();
    let mut suite = BenchSuite::new("fig6 sweep wallclock").warmup(0).iters(1);
    let mut rows = Vec::new();
    suite.bench("fig6: 5 topologies x 6 rates (AE on)", || {
        rows = exp::fig6(&manifest, opts).expect("fig6 sweep");
    });
    suite.report();
    exp::print_rows(
        "Fig. 6 — ResNet50 + autoencoder: accuracy vs Poisson arrival rate",
        "rate",
        &rows,
    );
}
