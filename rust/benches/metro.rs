//! Metro-scale simulation benchmark: generated topologies × arrival
//! models on the DES fast path, plus a calendar-vs-binary-heap event-queue
//! microbenchmark.
//!
//! Three claims are asserted (so CI fails on a fast-path regression, not
//! just a drifting history):
//!
//! * the calendar event queue beats the seed's `BinaryHeap` by the mode's
//!   floor (≥ 2.0× in the full run, ≥ 1.3× under `MDI_BENCH_QUICK=1`) on a
//!   hold-model schedule with a deep pending set — both kinds must also
//!   agree on the pop sequence, checksummed;
//! * telemetry is zero-cost when off: a run with the no-op recorder
//!   installed (every hook branch taken, events constructed and
//!   discarded) stays within 2% of the recorder-free baseline (10% under
//!   `MDI_BENCH_QUICK=1`);
//! * (full mode) a 1000-node random-geometric Poisson run completes at
//!   least one million simulated events in under 60 s of wallclock.
//!
//! Every sweep row lands in `BENCH_metro.json` (simulated events,
//! wallclock, events/s, completed tasks/s, peak event-queue depth) next to
//! the queue microbenchmark numbers, as a machine-readable history of the
//! metro fast path.

use std::time::Instant;

use mdi_exit::coordinator::{
    AdmissionMode, Driver, EventQueue, ExperimentConfig, ModelMeta, Placement, QueueKind, Run,
    RunReport,
};
use mdi_exit::dataset::ExitTable;
use mdi_exit::runtime::sim_engine::SimEngine;
use mdi_exit::util::json::{obj, Json};
use mdi_exit::util::rng::Pcg64;
use mdi_exit::workload::ArrivalSpec;

/// Stage costs shared by every run: 2 ms + 3 ms, speed 1.0.
const COSTS: [f64; 2] = [0.002, 0.003];

/// 8 samples × 2 exits: even samples exit at 1, odd ride to 2; predictions
/// always match the label (a deterministic 50/50 split).
fn oracle() -> (ExitTable, Vec<u8>) {
    let n = 8;
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
    for i in 0..n {
        if i % 2 == 0 {
            conf.extend([0.97f32, 0.99]);
        } else {
            conf.extend([0.30f32, 0.95]);
        }
        pred.extend([labels[i], labels[i]]);
    }
    (ExitTable::synthetic(n, 2, conf, pred), labels)
}

fn meta() -> ModelMeta {
    ModelMeta::synthetic(COSTS.to_vec(), vec![12288, 8192])
}

fn metro_cfg(
    topology: &str,
    sources: &[usize],
    arrival: ArrivalSpec,
    rate_hz: f64,
    seconds: f64,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(
        "metro",
        topology,
        AdmissionMode::Fixed { rate_hz, threshold: 0.9 },
    );
    cfg.duration_s = seconds;
    cfg.warmup_s = 1.0;
    cfg.gossip_interval_s = 0.25;
    cfg.workload.arrival = arrival;
    cfg.placement = Placement::multi(sources);
    cfg.seed = 7;
    cfg
}

fn run_des(cfg: ExperimentConfig) -> RunReport {
    let (table, labels) = oracle();
    let engine = SimEngine::from_table(table, false);
    Run::builder()
        .config(cfg)
        .model(meta())
        .engine(&engine)
        .labels(&labels)
        .driver(Driver::Des)
        .execute()
        .expect("DES run")
}

/// Classic hold model: prefill `pending` events, then `ops` rounds of
/// pop-one / push-its-successor a mean-1 s hold later. The interarrival
/// draws are precomputed and shared so both queue kinds execute the exact
/// same schedule — the returned checksum must therefore agree bit for bit.
fn queue_hold(kind: QueueKind, pending: usize, ops: usize, dts: &[f64]) -> (f64, u64) {
    let mask = dts.len() - 1;
    let mut q: EventQueue<u64> = EventQueue::new(kind);
    for i in 0..pending as u64 {
        q.push(dts[(i as usize) & mask], i);
    }
    let mut t = 0.0f64;
    let mut acc = 0u64;
    for i in 0..ops {
        let (now, ev) = q.pop().expect("hold model never empties");
        t = now;
        acc = acc.wrapping_add(ev).rotate_left(7);
        q.push(t + dts[(pending + i) & mask], (pending + i) as u64);
    }
    std::hint::black_box(t);
    (t, acc)
}

fn time_queue(
    kind: QueueKind,
    pending: usize,
    ops: usize,
    iters: u32,
    dts: &[f64],
) -> (f64, u64) {
    let mut best = f64::INFINITY;
    let mut check = 0u64;
    for _ in 0..iters {
        let t0 = Instant::now();
        let (_, acc) = queue_hold(kind, pending, ops, dts);
        best = best.min(t0.elapsed().as_secs_f64());
        check = acc;
    }
    (best, check)
}

fn main() {
    let quick = std::env::var_os("MDI_BENCH_QUICK").is_some();

    // -- DES fast path: calendar wheel vs the seed's binary heap ----------
    // Min-of-iters timing; the quick floor is loose because CI runners are
    // noisy, the full floor is the acceptance bar for the fast path.
    let (pending, ops, iters, floor) =
        if quick { (30_000, 120_000, 3, 1.3) } else { (100_000, 400_000, 5, 2.0) };
    let mut rng = Pcg64::new(42, 0);
    let dts: Vec<f64> = (0..1usize << 16).map(|_| rng.exponential(1.0)).collect();
    let (t_base, c_base) = time_queue(QueueKind::Baseline, pending, ops, iters, &dts);
    let (t_cal, c_cal) = time_queue(QueueKind::Calendar, pending, ops, iters, &dts);
    assert_eq!(c_base, c_cal, "queue kinds diverged on an identical schedule");
    let speedup = t_base / t_cal;
    println!("== bench: metro ==");
    println!(
        "event queue, hold model ({pending} pending, {ops} ops): \
         heap {:.1} ms, calendar {:.1} ms -> {speedup:.2}x",
        t_base * 1e3,
        t_cal * 1e3
    );
    assert!(
        speedup >= floor,
        "calendar queue speedup {speedup:.2}x below the {floor}x floor \
         (heap {t_base:.4}s vs calendar {t_cal:.4}s)"
    );

    // -- sweep: generated topologies × arrival models ---------------------
    let (rate_hz, seconds, every) = if quick { (30.0, 6.0, 12) } else { (40.0, 20.0, 10) };
    let topos: &[(&str, usize)] = if quick {
        &[("grid-4x4", 16), ("random-geometric-120-0.15", 120), ("scale-free-120", 120)]
    } else {
        &[("grid-10x10", 100), ("random-geometric-300-0.1", 300), ("scale-free-300", 300)]
    };
    let arrivals: Vec<(&str, ArrivalSpec)> = vec![
        ("legacy", ArrivalSpec::Legacy),
        ("poisson", ArrivalSpec::Poisson),
        (
            "flash-crowd",
            ArrivalSpec::FlashCrowd { peak_mult: 4.0, at_s: seconds * 0.4, ramp_s: 1.0 },
        ),
    ];

    println!(
        "{:<28} {:<12} {:>8} {:>10} {:>9} {:>12} {:>11} {:>10}",
        "topology", "arrival", "sources", "events", "wall(s)", "events/s", "tasks/s", "peakq"
    );
    let mut rows: Vec<Json> = Vec::new();
    for &(topo, n) in topos {
        let sources: Vec<usize> = (0..n).step_by(every.min(n)).collect();
        for (aname, spec) in &arrivals {
            let cfg = metro_cfg(topo, &sources, spec.clone(), rate_hz, seconds);
            let t0 = Instant::now();
            let r = run_des(cfg);
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            println!(
                "{topo:<28} {aname:<12} {:>8} {:>10} {:>9.2} {:>12.0} {:>11.0} {:>10}",
                sources.len(),
                r.sim_events,
                wall,
                r.sim_events as f64 / wall,
                r.completed as f64 / wall,
                r.peak_event_queue
            );
            assert!(r.completed > 0, "{topo}/{aname}: nothing completed");
            assert!(r.peak_event_queue > 0, "{topo}/{aname}: peak queue untracked");
            rows.push(obj(vec![
                ("topology", topo.into()),
                ("arrival", (*aname).into()),
                ("nodes", n.into()),
                ("sources", sources.len().into()),
                ("sim_events", (r.sim_events as i64).into()),
                ("wallclock_s", wall.into()),
                ("events_per_s", (r.sim_events as f64 / wall).into()),
                ("completed", (r.completed as i64).into()),
                ("tasks_per_s", (r.completed as f64 / wall).into()),
                ("peak_event_queue", r.peak_event_queue.into()),
            ]));
        }
    }

    // -- zero-cost-when-off: telemetry's no-op recorder -------------------
    // The telemetry contract (see `mdi_exit::telemetry`): with a
    // `NoopRecorder` installed every hook still takes its `is_some()`
    // branch and constructs its event, but the payload work is zero — so
    // the metro fast path must stay within 2% of the recorder-free
    // baseline (quick mode loosens the ceiling for noisy CI runners).
    let (tel_topo, tel_nodes, tel_secs, tel_iters, tel_ceiling) =
        if quick { ("grid-4x4", 16, 6.0, 3, 1.10) } else { ("grid-10x10", 100, 10.0, 5, 1.02) };
    let tel_sources: Vec<usize> = (0..tel_nodes).step_by(every.min(tel_nodes)).collect();
    let time_runs = |noop: bool, iters: u32| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..iters {
            let mut cfg =
                metro_cfg(tel_topo, &tel_sources, ArrivalSpec::Legacy, rate_hz, tel_secs);
            cfg.telemetry.noop = noop;
            let t0 = Instant::now();
            let r = run_des(cfg);
            best = best.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(r.completed);
            if let Some(d) = &r.telemetry {
                assert!(d.is_empty(), "the no-op recorder must collect nothing");
            }
        }
        best
    };
    let t_off = time_runs(false, tel_iters);
    let t_noop = time_runs(true, tel_iters);
    let overhead = t_noop / t_off;
    println!(
        "telemetry no-op overhead ({tel_topo}, {tel_secs}s): off {:.1} ms, \
         noop {:.1} ms -> {overhead:.3}x",
        t_off * 1e3,
        t_noop * 1e3
    );
    assert!(
        overhead <= tel_ceiling,
        "no-op telemetry overhead {overhead:.3}x breaks the {tel_ceiling}x \
         zero-cost-when-off ceiling (off {t_off:.4}s vs noop {t_noop:.4}s)"
    );

    // -- flagship (full mode): 1000-node metro run ------------------------
    // The acceptance bar: ≥ 1M simulated events in < 60 s of wallclock on
    // a 1000-node random-geometric graph under Poisson arrivals.
    if !quick {
        let sources: Vec<usize> = (0..1000).step_by(10).collect();
        let cfg = metro_cfg(
            "random-geometric-1000-0.06",
            &sources,
            ArrivalSpec::Poisson,
            40.0,
            30.0,
        );
        let t0 = Instant::now();
        let r = run_des(cfg);
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<28} {:<12} {:>8} {:>10} {:>9.2} {:>12.0} {:>11.0} {:>10}",
            "random-geometric-1000-0.06",
            "poisson",
            sources.len(),
            r.sim_events,
            wall,
            r.sim_events as f64 / wall,
            r.completed as f64 / wall,
            r.peak_event_queue
        );
        assert!(
            r.sim_events >= 1_000_000,
            "metro flagship simulated only {} events",
            r.sim_events
        );
        assert!(wall < 60.0, "metro flagship took {wall:.1}s (budget 60s)");
        assert!(r.completed > 10_000, "metro flagship completed {}", r.completed);
        rows.push(obj(vec![
            ("topology", "random-geometric-1000-0.06".into()),
            ("arrival", "poisson".into()),
            ("nodes", 1000usize.into()),
            ("sources", sources.len().into()),
            ("sim_events", (r.sim_events as i64).into()),
            ("wallclock_s", wall.into()),
            ("events_per_s", (r.sim_events as f64 / wall).into()),
            ("completed", (r.completed as i64).into()),
            ("tasks_per_s", (r.completed as f64 / wall).into()),
            ("peak_event_queue", r.peak_event_queue.into()),
        ]));
    }

    let doc = obj(vec![
        ("bench", "metro".into()),
        ("quick", quick.into()),
        (
            "queue",
            obj(vec![
                ("pending", pending.into()),
                ("ops", ops.into()),
                ("baseline_min_s", t_base.into()),
                ("calendar_min_s", t_cal.into()),
                ("speedup", speedup.into()),
                ("floor", floor.into()),
            ]),
        ),
        (
            "telemetry_noop",
            obj(vec![
                ("topology", tel_topo.into()),
                ("seconds", tel_secs.into()),
                ("baseline_min_s", t_off.into()),
                ("noop_min_s", t_noop.into()),
                ("overhead", overhead.into()),
                ("ceiling", tel_ceiling.into()),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_metro.json", doc.to_string()).expect("write BENCH_metro.json");
    println!("wrote BENCH_metro.json");
}
