//! Decision-policy ablation: Baseline Alg. 2 vs DeadlineAware vs MultiHop
//! on the `2-ring-bridge` topology, same seed and workload.
//!
//! The scenario is the regime the ROADMAP follow-ons named: a single
//! source (ring A) overloaded ~3x past one worker's capacity while ring B
//! idles behind the bridge, with a *small* output threshold T_O. Small T_O
//! exposes the structural weakness of Alg. 2's `O_n > I_m` gate: the
//! output queue O_n is capped near T_O by Alg. 1, so the gate slams shut
//! as soon as every neighbor holds a handful of tasks — while the real
//! overload piles up in the *input* queue, invisible to the gate. Policies
//! that reason about waits and deadlines (DeadlineAware) or about remote
//! backlog through the next-hop table (MultiHop) keep draining.
//!
//! Two claims are asserted (so CI fails on a policy regression, not just a
//! drifting BENCH history):
//!
//! * **DeadlineAware beats Baseline on class-0 on-time completion** under
//!   overload (by a wide margin: the baseline's gate strands the backlog
//!   at the source, so its class-0 results blow their 0.5 s budget);
//! * **MultiHop shrinks the worker-occupancy spread** (max - min peak
//!   input queue): pushing toward the idle remote ring flattens the load
//!   the one-hop scan cannot reach.
//!
//! Entirely artifact-free; DES driver only, so every number is
//! virtual-time-deterministic. `MDI_BENCH_QUICK=1` shrinks the window.

use mdi_exit::coordinator::{
    AdmissionMode, Driver, ExperimentConfig, ModelMeta, OffloadKind, Run, RunReport,
};
use mdi_exit::dataset::ExitTable;
use mdi_exit::runtime::sim_engine::SimEngine;
use mdi_exit::sched::DisciplineKind;

/// Stage-3-heavy 3-stage model: 3/4 of the stream rides to the 6 ms final
/// stage, so continuing work dominates and must spread to survive.
const COSTS3: [f64; 3] = [0.001, 0.001, 0.006];

/// 8 samples x 3 exits: every fourth sample exits at 1, the rest at 3.
fn oracle3(n: usize) -> (ExitTable, Vec<u8>) {
    let mut conf = Vec::new();
    let mut pred = Vec::new();
    let labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
    for (i, &l) in labels.iter().enumerate() {
        if i % 4 == 0 {
            conf.extend([0.97f32, 0.99, 1.0]);
        } else {
            conf.extend([0.30f32, 0.50, 0.95]);
        }
        pred.extend([l; 3]);
    }
    (ExitTable::synthetic(n, 3, conf, pred), labels)
}

fn meta3() -> ModelMeta {
    ModelMeta::synthetic(COSTS3.to_vec(), vec![12288, 8192, 4096])
}

fn run_policy(offload: OffloadKind, seconds: f64) -> RunReport {
    let mut cfg = ExperimentConfig::new(
        "policy-ablation",
        "2-ring-bridge",
        AdmissionMode::Fixed { rate_hz: 500.0, threshold: 0.9 },
    );
    cfg.duration_s = seconds;
    cfg.warmup_s = 1.0;
    cfg.seed = 7;
    // Small T_O: Alg. 1 keeps the output queue short, which is exactly
    // where Alg. 2's queue-length gate breaks down (see module docs).
    cfg.t_o = 2;
    cfg.policy.offload = offload;
    // Two traffic classes, class 0 on a 0.5 s budget, EDF service on every
    // run — the queue discipline is held constant so the *offload* policy
    // is the only variable, and deadline-ordered service is the regime the
    // deadline-aware wait estimate (classes <= ours queue ahead) models.
    cfg.sched = cfg.sched.with_classes(2);
    cfg.sched.discipline = DisciplineKind::Edf { drop_late: false };
    cfg.sched.class_deadline_s = vec![0.5, 10.0];
    let (table, labels) = oracle3(8);
    let engine = SimEngine::from_table(table, false);
    Run::builder()
        .config(cfg)
        .model(meta3())
        .engine(&engine)
        .labels(&labels)
        .driver(Driver::Des)
        .execute()
        .expect("DES run")
}

/// Max - min peak input occupancy across workers: how unevenly the load
/// sat on the topology.
fn occupancy_spread(r: &RunReport) -> usize {
    let peaks: Vec<usize> = r.per_worker.iter().map(|w| w.peak_input).collect();
    peaks.iter().max().unwrap() - peaks.iter().min().unwrap()
}

fn row(name: &str, r: &RunReport) {
    let c0 = r.per_class[0].on_time_rate();
    let ring_b: u64 = r.per_worker[3..].iter().map(|w| w.processed).sum();
    println!(
        "{name:<16} {:>10.1} {:>12.3} {:>10} {:>10} {:>12}",
        r.throughput_hz(),
        c0,
        occupancy_spread(r),
        ring_b,
        r.gossip_bytes()
    );
}

fn main() {
    let quick = std::env::var_os("MDI_BENCH_QUICK").is_some();
    let seconds = if quick { 8.0 } else { 20.0 };

    println!("== bench: offload-policy ablation (2-ring-bridge, 500 Hz, T_O = 2) ==");
    println!(
        "{:<16} {:>10} {:>12} {:>10} {:>10} {:>12}",
        "policy", "tput(Hz)", "c0 on-time", "spread", "ringB proc", "gossip B"
    );

    let base = run_policy(OffloadKind::Alg2, seconds);
    let dl = run_policy(OffloadKind::DeadlineAware, seconds);
    let multi = run_policy(OffloadKind::MultiHop, seconds);
    row("baseline (alg2)", &base);
    row("deadline-aware", &dl);
    row("multi-hop", &multi);

    // -- claim 1: deadline-aware rescues class-0 on-time completion -------
    let base_c0 = base.per_class[0].on_time_rate();
    let dl_c0 = dl.per_class[0].on_time_rate();
    println!("  -> class-0 on-time rate: baseline {base_c0:.3} vs deadline-aware {dl_c0:.3}");
    assert!(
        dl_c0 >= base_c0 + 0.10,
        "DeadlineAware class-0 on-time rate {dl_c0:.3} must clearly beat baseline {base_c0:.3}"
    );
    // It must also *complete* more class-0 work on time in absolute terms,
    // not just win a ratio over a smaller completion count.
    assert!(
        dl.per_class[0].on_time > base.per_class[0].on_time,
        "DeadlineAware on-time completions {} vs baseline {}",
        dl.per_class[0].on_time,
        base.per_class[0].on_time
    );

    // -- claim 2: multi-hop flattens the occupancy spread -----------------
    let (base_spread, multi_spread) = (occupancy_spread(&base), occupancy_spread(&multi));
    println!("  -> occupancy spread: baseline {base_spread} vs multi-hop {multi_spread}");
    assert!(
        (multi_spread as f64) <= 0.7 * base_spread as f64,
        "MultiHop spread {multi_spread} must undercut baseline {base_spread}"
    );
    let ring_b: u64 = multi.per_worker[3..].iter().map(|w| w.processed).sum();
    assert!(ring_b > 0, "multi-hop never reached the idle ring");

    // Gossip wire accounting: the richer summaries must actually be
    // charged — deadline-aware (slack + 2 classes) and multi-hop (region
    // table) summaries cost more than the 32-byte baseline gossip.
    assert!(dl.gossip_bytes() > base.gossip_bytes(), "annotated gossip must cost more");
    assert!(multi.gossip_bytes() > base.gossip_bytes(), "region gossip must cost more");

    // Sanity on every run: per-class counters conserve.
    for (name, r) in [("baseline", &base), ("deadline", &dl), ("multi-hop", &multi)] {
        let by_class: u64 = r.per_class.iter().map(|c| c.completed).sum();
        assert_eq!(by_class, r.completed, "{name}: per-class counters conserve");
    }
}
