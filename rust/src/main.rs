//! `mdi-exit` — CLI launcher for the MDI-Exit system.
//!
//! Subcommands:
//!   info                         inspect the artifact manifest
//!   run [--config f.toml] [...]  one experiment on the DES driver
//!   serve [...]                  realtime threaded run on the PJRT engine
//!   fig3|fig4|fig5|fig6          reproduce a paper figure
//!   ablations                    run the ablation suite
//!
//! Common flags: --artifacts DIR (or MDI_ARTIFACTS), --quick, --seed N.

use anyhow::{bail, Context, Result};

use mdi_exit::artifact::Manifest;
use mdi_exit::cli::Args;
use mdi_exit::coordinator::{AdmissionMode, Driver, ExperimentConfig, PolicyConfig, Run};
use mdi_exit::experiments as exp;
use mdi_exit::sched::DisciplineKind;
use mdi_exit::util::toml::Config as Toml;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    // `--log LEVEL` overrides the MDI_LOG env filter for this invocation.
    if args.has("log") {
        let name = args.str_or("log", "info");
        match mdi_exit::util::logging::Level::parse(name) {
            Some(level) => mdi_exit::util::logging::set_level(level),
            None => bail!("--log {name:?} (trace|debug|info|warn|error)"),
        }
    }
    let artifacts = args.str_or("artifacts", "artifacts").to_string();
    match args.subcommand() {
        None | Some("help") => {
            print_help();
            Ok(())
        }
        Some("info") => cmd_info(&artifacts),
        Some("run") => cmd_run(&args, &artifacts),
        Some("serve") => cmd_serve(&args, &artifacts),
        Some(fig @ ("fig3" | "fig4" | "fig5" | "fig6")) => cmd_fig(fig, &args, &artifacts),
        Some("ablations") => cmd_ablations(&args, &artifacts),
        Some(other) => bail!("unknown subcommand {other:?} (try `mdi-exit help`)"),
    }
}

fn print_help() {
    println!(
        "mdi-exit — Early-Exit meets Model-Distributed Inference (reproduction)\n\n\
         USAGE: mdi-exit <subcommand> [flags]\n\n\
         SUBCOMMANDS\n\
           info        print the artifact manifest summary\n\
           run         one DES experiment     (--config cfg.toml | --model --topology ...)\n\
           serve       realtime threaded run (PJRT stages with --features pjrt,\n\
                       oracle replay with cost emulation otherwise)\n\
           fig3..fig6  reproduce the paper's figures (DES sweeps)\n\
           ablations   autoencoder / offload-policy / T_O ablations\n\n\
         COMMON FLAGS\n\
           --artifacts DIR   artifact directory (default: artifacts)\n\
           --quick           short sweeps (for smoke runs)\n\
           --seed N          RNG seed (default 7)\n\
           --log LEVEL       stderr log level: trace|debug|info|warn|error\n\n\
         TELEMETRY FLAGS (run + serve)\n\
           --trace [FILE]    record per-task spans; write Chrome trace-event\n\
                             JSON (default trace.json; open in Perfetto)\n\
           --metrics [FILE]  sample per-worker time-series; write JSONL\n\
                             (default metrics.jsonl; includes flight dumps)\n\
           --metrics-interval S  sampling cadence in seconds (default 0.25)\n\n\
         RUN FLAGS\n\
           --config FILE     TOML experiment config (see configs/)\n\
           --model M --topology T --threshold X --rate HZ --duration S\n\
           --sources 0,3     admitting nodes (default 0); results and\n\
                             re-homes route multi-hop back to each source\n\
           --adaptive-rate | --adaptive-threshold   admission mode\n\
           --use-ae --no-ee  feature toggles\n\
           --exit-policy P   alg1 (default) | local-only\n\
           --offload-policy P  alg2 (default) | deterministic | queue-only |\n\
                             round-robin | deadline-aware | multi-hop\n\
           --sched D         queue discipline: fifo (default) | priority | edf | drr\n\
           --classes N       traffic classes, stamped round-robin at admission\n\
           --class-deadline S  per-class latency budget (EDF deadline stamp)\n\
           --quantum Q       DRR service quantum (one weight for all classes)\n\
           --drop-late       EDF: discard tasks whose deadline passed\n\
           --batch N         max same-stage tasks per batched engine call\n\
           --coalesce M      cross-worker batch coalescing: off (default) |\n\
                             stage | stage-class | adaptive — offloads drain\n\
                             same-stage runs into one wire envelope (adaptive\n\
                             sizes the run from measured link contention)\n\
           --coalesce-max N  cap on tasks per coalesced envelope (default 8)\n\
           --arrival A       workload arrival model at the sources:\n\
                             legacy (default) | constant | poisson |\n\
                             flash-crowd | diurnal | trace:FILE\n\
           --arrival-source \"N:SPEC,...\"  per-source arrival overrides,\n\
                             e.g. \"0:poisson,3:flash-crowd\" (others keep\n\
                             --arrival)\n\
           --cluster         elastic fleet control plane: heartbeats,\n\
                             health-driven failover, occupancy autoscaling\n\
                             with live re-layering (see [cluster] in TOML)\n\
           --cluster-min N --cluster-max N   fleet size bounds\n\
           --cluster-initial N   nodes active at t=0 (sources + lowest ids)\n\
           --cluster-cooldown S --cluster-interval S   scaling cadence\n\
           --piggyback       ride gossip summaries on outbound task/result\n\
                             envelopes headed to the same neighbor\n\
           --timeline [FILE] controller/queue timeline JSON (was --trace)\n\
           --json            print the full RunReport as JSON"
    );
}

fn cmd_info(artifacts: &str) -> Result<()> {
    let m = Manifest::load(artifacts)?;
    println!("artifacts: {}", m.dir.display());
    println!("dataset: {} samples, {}x{}x{}, {} classes",
             m.dataset.n, m.dataset.h, m.dataset.w, m.dataset.c, m.dataset.num_classes);
    for (name, info) in &m.models {
        println!("\nmodel {name}: {} stages", info.num_stages);
        for s in &info.stages {
            println!(
                "  stage {}: {:?} -> {:?}  cost {:.2} ms  in {} B  ({})",
                s.k, s.in_shape, s.out_shape, s.cost_ms, s.in_bytes, s.hlo
            );
        }
        println!("  exit accuracy: {:?}", info.exit_accuracy);
        println!("  mean confidence: {:?}", info.mean_confidence);
        if let Some(ae) = &info.ae {
            println!(
                "  autoencoder: {} B -> {} B ({}x), acc drop {:?}",
                ae.raw_bytes, ae.code_bytes, ae.compression, ae.acc_drop
            );
        }
    }
    Ok(())
}

/// Fold the telemetry CLI flags into a config (after TOML or flag
/// construction — the CLI wins over the `[telemetry]` section).
fn apply_telemetry_flags(cfg: &mut ExperimentConfig, args: &Args) -> Result<()> {
    if args.has("trace") {
        cfg.telemetry.spans = true;
    }
    if args.has("metrics") {
        cfg.telemetry.metrics = true;
    }
    cfg.telemetry.interval_s = args.f64_or("metrics-interval", cfg.telemetry.interval_s)?;
    cfg.telemetry.validate().map_err(|e| anyhow::anyhow!("telemetry: {e}"))?;
    Ok(())
}

/// A flag used as `--key PATH` or bare `--key` (default path).
fn path_flag<'a>(args: &'a Args, key: &str, default: &'a str) -> &'a str {
    match args.str_or(key, default) {
        "true" => default,
        p => p,
    }
}

/// Export the run's telemetry per the `--trace` / `--metrics` flags:
/// Chrome trace-event JSON (load at <https://ui.perfetto.dev>) and the
/// metrics time-series as JSONL.
fn export_telemetry(
    report: &mut mdi_exit::coordinator::RunReport,
    args: &Args,
) -> Result<()> {
    if !args.has("trace") && !args.has("metrics") {
        return Ok(());
    }
    let data = report.telemetry.take().unwrap_or_default();
    if args.has("trace") {
        let path = path_flag(args, "trace", "trace.json");
        std::fs::write(path, data.chrome_trace().to_string())
            .with_context(|| format!("writing trace {path}"))?;
        println!(
            "chrome trace written to {path} ({} spans; open in https://ui.perfetto.dev)",
            data.spans.len()
        );
    }
    if args.has("metrics") {
        let path = path_flag(args, "metrics", "metrics.jsonl");
        std::fs::write(path, data.metrics_jsonl())
            .with_context(|| format!("writing metrics {path}"))?;
        println!(
            "metrics written to {path} ({} rows, {} flight dumps)",
            data.metrics.len(),
            data.dumps.len()
        );
    }
    Ok(())
}

fn build_config(args: &Args) -> Result<ExperimentConfig> {
    if args.has("config") {
        let path = args.str_or("config", "");
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let toml = Toml::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let mut cfg = ExperimentConfig::from_toml(&toml)?;
        apply_telemetry_flags(&mut cfg, args)?;
        return Ok(cfg);
    }
    let model = args.str_or("model", "mobilenetv2l");
    let topology = args.str_or("topology", "3-node-mesh");
    let threshold = args.f64_or("threshold", 0.9)? as f32;
    let rate = args.f64_or("rate", 25.0)?;
    let admission = if args.bool_or("adaptive-rate", false)? {
        AdmissionMode::AdaptiveRate { threshold, initial_mu_s: 0.25 }
    } else if args.bool_or("adaptive-threshold", false)? {
        AdmissionMode::AdaptiveThreshold { rate_hz: rate, initial_t_e: threshold, t_e_min: 0.05 }
    } else {
        AdmissionMode::Fixed { rate_hz: rate, threshold }
    };
    let mut cfg = ExperimentConfig::new(model, topology, admission);
    cfg.use_ae = args.bool_or("use-ae", false)?;
    cfg.no_early_exit = args.bool_or("no-ee", false)?;
    cfg.duration_s = args.f64_or("duration", 30.0)?;
    cfg.warmup_s = args.f64_or("warmup", 5.0)?;
    cfg.compute_scale = args.f64_or("compute-scale", 0.125)?;
    // Scheduling subsystem: discipline, traffic classes, batching.
    let classes = args.usize_or("classes", 1)?;
    if !(1..=255).contains(&classes) {
        bail!("--classes {classes} outside 1..=255");
    }
    cfg.sched = cfg.sched.with_classes(classes as u8);
    cfg.sched.discipline = match args.str_or("sched", "fifo") {
        "fifo" => DisciplineKind::Fifo,
        "priority" => DisciplineKind::StrictPriority,
        "edf" => DisciplineKind::Edf { drop_late: args.bool_or("drop-late", false)? },
        "drr" | "weighted-fair" => DisciplineKind::WeightedFair,
        other => bail!("unknown --sched {other:?} (fifo|priority|edf|drr)"),
    };
    let deadline = args.f64_or("class-deadline", 0.0)?;
    if deadline > 0.0 {
        cfg.sched.class_deadline_s = vec![deadline; classes];
    }
    let quantum = args.f64_or("quantum", 0.0)?;
    if quantum > 0.0 {
        cfg.sched.class_quantum = vec![quantum; classes];
    }
    cfg.sched.batch.max_batch = args.usize_or("batch", 1)?;
    // Cross-worker batch coalescing (net::Envelope): how offloads share
    // wire envelopes.
    cfg.sched.coalesce = mdi_exit::sched::CoalesceMode::parse(args.str_or("coalesce", "off"))
        .map_err(|e| anyhow::anyhow!("--coalesce: {e}"))?;
    cfg.sched.coalesce_max = args.usize_or("coalesce-max", cfg.sched.coalesce_max)?;
    // Decision policies (crate::policy): which Alg. 1/2 variants run.
    cfg.policy.exit = PolicyConfig::parse_exit(args.str_or("exit-policy", "alg1"))?;
    cfg.policy.offload = PolicyConfig::parse_offload(args.str_or("offload-policy", "alg2"))?;
    // Placement: comma-separated source nodes, e.g. --sources 0,3.
    let sources = args.str_or("sources", "");
    if !sources.is_empty() {
        let nodes: Vec<usize> = sources
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("--sources: bad node id {s:?}"))
            })
            .collect::<Result<_>>()?;
        cfg.placement = mdi_exit::routing::Placement::multi(&nodes);
    }
    // Workload subsystem: arrival model at the sources (default `legacy`
    // keeps the seed's pacing bit for bit).
    cfg.workload.arrival = mdi_exit::workload::ArrivalSpec::parse_cli(args.str_or("arrival", "legacy"))
        .map_err(|e| anyhow::anyhow!("--arrival: {e}"))?;
    // Per-source mixes: --arrival-source "3:flash-crowd,5:poisson" gives the
    // listed sources their own model (the rest keep --arrival). One flag
    // carries every pair — repeated flags overwrite each other.
    let mixes = args.str_or("arrival-source", "");
    if !mixes.is_empty() {
        let mut sources = Vec::new();
        for pair in mixes.split(',') {
            let (node, spec) = pair.split_once(':').ok_or_else(|| {
                anyhow::anyhow!("--arrival-source: expected N:SPEC, got {pair:?}")
            })?;
            let node: usize = node
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--arrival-source: bad node id {node:?}"))?;
            let spec = mdi_exit::workload::ArrivalSpec::parse_cli(spec)
                .map_err(|e| anyhow::anyhow!("--arrival-source: {e}"))?;
            sources.push((node, spec));
        }
        sources.sort_by_key(|(n, _)| *n);
        cfg.workload.sources = sources;
    }
    // Elastic fleet control plane (crate::cluster): --cluster flips it on;
    // unset knobs keep the [cluster]-section defaults.
    cfg.cluster.enabled = args.bool_or("cluster", false)?;
    if cfg.cluster.enabled {
        cfg.cluster.min_workers = args.usize_or("cluster-min", cfg.cluster.min_workers)?;
        cfg.cluster.max_workers = args.usize_or("cluster-max", cfg.cluster.max_workers)?;
        cfg.cluster.cooldown_s = args.f64_or("cluster-cooldown", cfg.cluster.cooldown_s)?;
        cfg.cluster.check_interval_s =
            args.f64_or("cluster-interval", cfg.cluster.check_interval_s)?;
        if args.has("cluster-initial") {
            cfg.cluster.initial_workers = Some(args.usize_or("cluster-initial", 1)?);
        }
    }
    cfg.gossip_piggyback = args.bool_or("piggyback", false)?;
    cfg.seed = args.u64_or("seed", 7)?;
    apply_telemetry_flags(&mut cfg, args)?;
    Ok(cfg)
}

fn cmd_run(args: &Args, artifacts: &str) -> Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let cfg = build_config(args)?;
    let label = format!("{} on {}", cfg.model, cfg.topology);
    let mut report = Run::builder().config(cfg).manifest(&manifest).execute()?;
    export_telemetry(&mut report, args)?;
    if args.has("timeline") {
        // controller/queue timeline for plotting (t, control value, queue)
        let path = path_flag(args, "timeline", "timeline.json");
        let pts: Vec<mdi_exit::util::json::Json> = report
            .trace
            .iter()
            .map(|p| {
                mdi_exit::util::json::obj(vec![
                    ("t_s", p.t_s.into()),
                    ("control", p.control.into()),
                    ("source_queue", p.source_queue.into()),
                ])
            })
            .collect();
        std::fs::write(path, mdi_exit::util::json::Json::Arr(pts).to_string())
            .with_context(|| format!("writing timeline {path}"))?;
        println!("timeline written to {path} ({} points)", report.trace.len());
    }
    if args.bool_or("json", false)? {
        println!("{}", report.to_json().to_string());
    } else {
        println!("run: {label}");
        println!("  admitted      {:>10}  ({:.2} Hz)", report.admitted, report.admitted_rate_hz());
        println!("  completed     {:>10}  ({:.2} Hz)", report.completed, report.throughput_hz());
        println!("  accuracy      {:>10.4}", report.accuracy());
        println!("  latency p50   {:>10.2} ms", report.latency.p50() * 1e3);
        println!("  latency p95   {:>10.2} ms", report.latency.p95() * 1e3);
        println!("  exit fractions {:?}",
                 report.exit_fractions().iter().map(|f| (f * 100.0).round() / 100.0)
                       .collect::<Vec<_>>());
        println!("  bytes on wire {:>10}", report.bytes_on_wire);
        if report.coalesced_tasks() > 0 {
            println!(
                "  envelopes     {:>10}  (+{} coalesced tasks, {} B saved)",
                report.envelopes_sent(),
                report.coalesced_tasks(),
                report.wire_bytes_saved()
            );
        }
        if report.per_class.len() > 1 || report.dropped > 0 {
            for (c, cs) in report.per_class.iter_mut().enumerate() {
                println!(
                    "  class {c}: completed {:>8}  p95 {:>8.2} ms  on-time {:>6.3}  dropped {:>6}",
                    cs.completed,
                    cs.latency.p95() * 1e3,
                    cs.on_time_rate(),
                    cs.dropped
                );
            }
        }
        if report.per_source.len() > 1 {
            for ss in report.per_source.iter_mut() {
                println!(
                    "  source @{}: admitted {:>8}  completed {:>8}  acc {:>6.4}  p95 {:>8.2} ms",
                    ss.node,
                    ss.admitted,
                    ss.completed,
                    ss.accuracy(),
                    ss.latency.p95() * 1e3
                );
            }
        }
        if let Some(mu) = report.final_mu_s {
            println!("  final mu      {:>10.4} s ({:.2} Hz)", mu, 1.0 / mu);
        }
        if let Some(te) = report.final_t_e {
            println!("  final T_e     {:>10.4}", te);
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args, artifacts: &str) -> Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let mut cfg = build_config(args)?;
    cfg.duration_s = args.f64_or("duration", 10.0)?;
    cfg.warmup_s = args.f64_or("warmup", 2.0)?;
    let info = manifest.model(&cfg.model)?;
    let use_ae = cfg.use_ae;
    let model = cfg.model.clone();
    let manifest_ref = &manifest;
    println!("building {} stages per worker...", info.num_stages);
    let factory = move |worker: usize| -> Result<Box<dyn mdi_exit::runtime::InferenceEngine>> {
        mdi_exit::runtime::default_engine(manifest_ref, &model, use_ae)
            .with_context(|| format!("worker {worker} engine"))
    };
    let mut report = Run::builder()
        .config(cfg.clone())
        .manifest(&manifest)
        .engine_factory(factory)
        .driver(Driver::Realtime)
        .execute()?;
    export_telemetry(&mut report, args)?;
    println!("realtime run: {} on {}", cfg.model, cfg.topology);
    println!("  completed  {:>8}  ({:.2} Hz)", report.completed, report.throughput_hz());
    println!("  accuracy   {:>8.4}", report.accuracy());
    println!("  latency p50 {:>7.2} ms  p95 {:>7.2} ms",
             report.latency.p50() * 1e3, report.latency.p95() * 1e3);
    println!("  exit fractions {:?}", report.exit_fractions());
    Ok(())
}

fn cmd_fig(which: &str, args: &Args, artifacts: &str) -> Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let opts = if args.bool_or("quick", false)? {
        exp::SweepOpts::quick()
    } else {
        exp::SweepOpts::full()
    };
    let (rows, title, xlabel) = match which {
        "fig3" => (exp::fig3(&manifest, opts)?, "Fig. 3 — MobileNetV2, fixed threshold", "T_e"),
        "fig4" => (exp::fig4(&manifest, opts)?, "Fig. 4 — ResNet, fixed threshold", "T_e"),
        "fig5" => (exp::fig5(&manifest, opts)?, "Fig. 5 — MobileNetV2, Poisson arrivals", "rate"),
        "fig6" => (exp::fig6(&manifest, opts)?, "Fig. 6 — ResNet + AE, Poisson arrivals", "rate"),
        _ => unreachable!(),
    };
    exp::print_rows(title, xlabel, &rows);
    Ok(())
}

fn cmd_ablations(args: &Args, artifacts: &str) -> Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let opts = if args.bool_or("quick", false)? {
        exp::SweepOpts::quick()
    } else {
        exp::SweepOpts::full()
    };
    exp::print_rows("abl-ae — autoencoder on/off (ResNet, 5-node mesh)", "rate",
                    &exp::ablation_autoencoder(&manifest, opts)?);
    exp::print_rows("abl-offload — offloading policies (MobileNet, 3-node mesh)", "rate",
                    &exp::ablation_offload(&manifest, opts)?);
    exp::print_rows("abl-queue — T_O sensitivity", "T_O",
                    &exp::ablation_thresholds(&manifest, opts)?);
    exp::print_rows("DDI vs MDI-Exit", "rate", &exp::ddi_comparison(&manifest, opts)?);
    Ok(())
}
