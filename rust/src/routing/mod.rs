//! Topology-aware routing and source placement — the surface that
//! generalizes the paper's "one source, one hop away" testbed.
//!
//! The paper's Algs 1–4 never say *where* results go or *who* admits data;
//! the testbed just happens to put the single source one hop from every
//! worker. This module makes both choices explicit and first-class:
//!
//! * [`RoutingTable`] — all-pairs next-hop table computed by shortest path
//!   over the [`Topology`](crate::simnet::Topology)'s link weights (mean
//!   link delay for a reference payload, so a half-bandwidth ring link
//!   really is "longer" than a full-rate one). Drivers and the
//!   [`WorkerCore`](crate::coordinator::WorkerCore) consult it to move
//!   results, re-homed tasks, and gossip-adopted thresholds across
//!   arbitrary multi-hop graphs.
//! * [`Placement`] — which nodes admit data (one or many sources) and at
//!   what per-source rate share. The default, a single source at node 0,
//!   reproduces the paper's setup exactly.
//! * [`Role`] — what the placement means for one worker: whether it is a
//!   source, and which source is its *home* (the nearest one by routing
//!   distance — the worker adopts that source's adapted T_e).
//!
//! ## The next-hop contract
//!
//! `next_hop(from, to)` returns the **one-hop neighbor of `from`** that is
//! the first step of a shortest `from → to` path, or `None` when `to` is
//! unreachable or equals `from`. Three properties callers rely on:
//!
//! 1. **Progress**: following next hops strictly decreases the remaining
//!    shortest-path cost, so a relayed message reaches `to` in at most
//!    `n - 1` forwards — no loops, ever.
//! 2. **Determinism**: equal-cost ties resolve identically on every
//!    build (Dijkstra settles nodes in ascending-id order on ties and
//!    only relaxes on strict improvement), so both drivers and repeated
//!    runs route the same. On *unweighted* ties this picks the lowest
//!    first hop; on weighted graphs the tie goes to the path whose
//!    intermediate nodes settle first.
//! 3. **Locality**: the returned hop is always a direct neighbor, so every
//!    transport (virtual link delay, threaded `DelayNet`) can carry the
//!    send without knowing anything about the rest of the route.
//!
//! ## Re-routing under churn
//!
//! The static table ([`RoutingTable::build`]) is computed once per run;
//! by itself, churn does not re-route — a leaving worker stops
//! *computing*, but its radio keeps forwarding (the fabric's no-data-loss
//! guarantee). When the elastic control plane (`crate::cluster`) is on,
//! drivers instead rebuild the table on every join/leave with
//! [`RoutingTable::build_active`]: new traffic avoids inactive *relays*,
//! while the in-flight forwarding rules keep the old guarantee —
//! a departed node still forwards what it holds (its own row stays
//! routable), and any destination stranded behind dead relays falls back
//! to its static route rather than blackholing. One flapping node can
//! therefore never strand an in-flight result, with or without the
//! control plane.

use anyhow::{bail, Result};

use crate::simnet::{ChurnEvent, Topology};

/// Reference payload for link weights: one MTU-ish frame. Routing mostly
/// carries small result/re-home messages, so what matters is the *relative*
/// cost of links (a half-rate bridge vs. a clean mesh edge), not the exact
/// serialization time of any one payload.
const REF_BYTES: usize = 1500;

// ---------------------------------------------------------------------------
// RoutingTable
// ---------------------------------------------------------------------------

/// All-pairs shortest-path next hops over a topology's link weights.
#[derive(Debug, Clone)]
pub struct RoutingTable {
    n: usize,
    /// `next[from][to]` = first hop of a shortest path, `None` if
    /// unreachable or `from == to`.
    next: Vec<Vec<Option<usize>>>,
    /// `dist[from][to]` = shortest-path cost (`INFINITY` if unreachable).
    dist: Vec<Vec<f64>>,
}

impl RoutingTable {
    /// Compute the table with heap Dijkstra from every node. The weighted
    /// adjacency is extracted from the dense link matrix once and shared by
    /// all `n` runs, so building stays O(n·(E log n)) — the difference
    /// between milliseconds and minutes on the 1000-node generated graphs.
    pub fn build(topo: &Topology) -> RoutingTable {
        let n = topo.n;
        let adj: Vec<Vec<(usize, f64)>> = (0..n)
            .map(|u| {
                topo.neighbors(u)
                    .into_iter()
                    .map(|v| {
                        let w = topo.link(u, v).expect("neighbor has a link");
                        (v, w.mean_delay_s(REF_BYTES))
                    })
                    .collect()
            })
            .collect();
        let mut next = vec![vec![None; n]; n];
        let mut dist = vec![vec![f64::INFINITY; n]; n];
        for from in 0..n {
            let (d, first) = dijkstra(&adj, from);
            dist[from] = d;
            next[from] = first;
        }
        RoutingTable { n, next, dist }
    }

    /// Churn-aware variant: shortest paths that only *relay* through
    /// active nodes. The rules (see the module docs):
    ///
    /// * an inactive node never forwards **new** traffic — edges out of
    ///   inactive nodes are not relaxed, except out of the path's origin
    ///   (a departed worker must still drain what it already holds);
    /// * inactive nodes remain valid **destinations** (one terminal hop
    ///   onto a parked radio is allowed; it just never extends a path);
    /// * pairs left unreachable by the gating fall back to the static
    ///   table's route, so re-routing can only improve — never sever —
    ///   connectivity.
    ///
    /// The mixture stays loop-free: a gated route never relays through a
    /// node whose own gated route is missing (no active path through it
    /// exists either), so a fallback hop always lands on a node that makes
    /// static-route progress or resumes a gated route.
    pub fn build_active(topo: &Topology, active: &[bool]) -> RoutingTable {
        let full = RoutingTable::build(topo);
        if active.len() != topo.n || active.iter().all(|&a| a) {
            return full;
        }
        let n = topo.n;
        let adj: Vec<Vec<(usize, f64)>> = (0..n)
            .map(|u| {
                topo.neighbors(u)
                    .into_iter()
                    .filter_map(|v| {
                        topo.link(u, v).map(|w| (v, w.mean_delay_s(REF_BYTES)))
                    })
                    .collect()
            })
            .collect();
        let mut next = vec![vec![None; n]; n];
        let mut dist = vec![vec![f64::INFINITY; n]; n];
        for from in 0..n {
            let (d, first) = dijkstra_gated(&adj, from, active);
            dist[from] = d;
            next[from] = first;
        }
        // Fallback merge: any pair the gating disconnected keeps its
        // static route (dead radios keep forwarding in-flight traffic).
        for from in 0..n {
            for to in 0..n {
                if !dist[from][to].is_finite() && full.dist[from][to].is_finite() {
                    dist[from][to] = full.dist[from][to];
                    next[from][to] = full.next[from][to];
                }
            }
        }
        RoutingTable { n, next, dist }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// First hop of a shortest `from → to` path (see the module docs for
    /// the contract).
    pub fn next_hop(&self, from: usize, to: usize) -> Option<usize> {
        self.next[from][to]
    }

    /// This node's full next-hop row (`row[to]`), for cores that only ever
    /// route from themselves.
    pub fn row(&self, from: usize) -> Vec<Option<usize>> {
        self.next[from].clone()
    }

    /// Shortest-path cost, `None` if unreachable.
    pub fn distance(&self, from: usize, to: usize) -> Option<f64> {
        let d = self.dist[from][to];
        d.is_finite().then_some(d)
    }

    /// Hop count of the shortest path (0 for `from == to`), `None` if
    /// unreachable.
    pub fn hops(&self, from: usize, to: usize) -> Option<usize> {
        if from == to {
            return Some(0);
        }
        let mut at = from;
        let mut count = 0;
        while at != to {
            at = self.next[at][to]?;
            count += 1;
            debug_assert!(count <= self.n, "next-hop walk must terminate");
        }
        Some(count)
    }
}

/// Min-heap key: pops ascending (distance, node id), so equal-distance
/// ties settle toward the lowest node id — the same order the original
/// linear-scan `min_by(dist.total_cmp.then(id.cmp))` produced.
struct HeapKey {
    d: f64,
    u: usize,
}

impl PartialEq for HeapKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for HeapKey {}
impl PartialOrd for HeapKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want min-(d, u) pops.
        other.d.total_cmp(&self.d).then(other.u.cmp(&self.u))
    }
}

/// Heap Dijkstra from `src` over mean link delays, with lazy deletion
/// (stale heap entries are skipped on pop). Settle order breaks distance
/// ties toward the lowest node id and relaxation is strict-improvement
/// only, which makes equal-cost routing deterministic across drivers and
/// runs (and lowest-first-hop on unweighted ties) — identical, route for
/// route, to the linear-scan implementation it replaced.
fn dijkstra(adj: &[Vec<(usize, f64)>], src: usize) -> (Vec<f64>, Vec<Option<usize>>) {
    let n = adj.len();
    let mut dist = vec![f64::INFINITY; n];
    let mut first = vec![None; n];
    let mut done = vec![false; n];
    let mut heap = std::collections::BinaryHeap::new();
    dist[src] = 0.0;
    heap.push(HeapKey { d: 0.0, u: src });
    while let Some(HeapKey { d, u }) = heap.pop() {
        if done[u] || d > dist[u] {
            continue;
        }
        done[u] = true;
        for &(v, w) in &adj[u] {
            let nd = d + w;
            if nd < dist[v] {
                dist[v] = nd;
                // The first hop out of src toward v: src's own neighbor.
                first[v] = if u == src { Some(v) } else { first[u] };
                heap.push(HeapKey { d: nd, u: v });
            }
        }
    }
    (dist, first)
}

/// Dijkstra with relay gating: edges are only relaxed out of `src` itself
/// and out of active nodes, so inactive nodes terminate — never extend —
/// paths. Tie-breaking matches [`dijkstra`] exactly (ascending `(d, id)`
/// settle order, strict-improvement relaxation), so on an all-active
/// fleet the two produce identical tables.
fn dijkstra_gated(
    adj: &[Vec<(usize, f64)>],
    src: usize,
    active: &[bool],
) -> (Vec<f64>, Vec<Option<usize>>) {
    let n = adj.len();
    let mut dist = vec![f64::INFINITY; n];
    let mut first = vec![None; n];
    let mut done = vec![false; n];
    let mut heap = std::collections::BinaryHeap::new();
    dist[src] = 0.0;
    heap.push(HeapKey { d: 0.0, u: src });
    while let Some(HeapKey { d, u }) = heap.pop() {
        if done[u] || d > dist[u] {
            continue;
        }
        done[u] = true;
        if u != src && !active.get(u).copied().unwrap_or(true) {
            continue; // parked radio: terminal hop only
        }
        for &(v, w) in &adj[u] {
            let nd = d + w;
            if nd < dist[v] {
                dist[v] = nd;
                first[v] = if u == src { Some(v) } else { first[u] };
                heap.push(HeapKey { d: nd, u: v });
            }
        }
    }
    (dist, first)
}

// ---------------------------------------------------------------------------
// Placement
// ---------------------------------------------------------------------------

/// One admitting node and its share of the configured admission rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SourceSpec {
    pub node: usize,
    /// Multiplier on the config's admission pacing: this source's
    /// inter-arrival times are divided by `rate_share`, so a share of 2.0
    /// admits twice as fast and 0.5 half as fast as the configured rate.
    pub rate_share: f64,
}

/// Which nodes admit data. The default — a single source at node 0 with
/// share 1.0 — is exactly the paper's (and the seed code's) setup.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub sources: Vec<SourceSpec>,
}

impl Default for Placement {
    fn default() -> Placement {
        Placement::single(0)
    }
}

impl Placement {
    /// One source at `node`, full rate.
    pub fn single(node: usize) -> Placement {
        Placement { sources: vec![SourceSpec { node, rate_share: 1.0 }] }
    }

    /// Several sources, each admitting at the full configured rate.
    pub fn multi(nodes: &[usize]) -> Placement {
        Placement {
            sources: nodes.iter().map(|&node| SourceSpec { node, rate_share: 1.0 }).collect(),
        }
    }

    pub fn is_source(&self, node: usize) -> bool {
        self.sources.iter().any(|s| s.node == node)
    }

    /// Source nodes in declaration order (report ordering follows it).
    pub fn source_nodes(&self) -> Vec<usize> {
        self.sources.iter().map(|s| s.node).collect()
    }

    /// Rate share of `node` (1.0 for non-sources, which never admit).
    pub fn rate_share(&self, node: usize) -> f64 {
        self.sources.iter().find(|s| s.node == node).map(|s| s.rate_share).unwrap_or(1.0)
    }

    /// The source `node` belongs to: itself if it is one, otherwise the
    /// reachable source with the smallest routing distance (ties toward
    /// the lowest node id). Falls back to the first declared source when
    /// nothing is reachable (an isolated worker never sees traffic anyway).
    pub fn home_source(&self, node: usize, routing: &RoutingTable) -> usize {
        if self.is_source(node) {
            return node;
        }
        self.sources
            .iter()
            .filter_map(|s| routing.distance(node, s.node).map(|d| (d, s.node)))
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
            .map(|(_, n)| n)
            .unwrap_or_else(|| self.sources.first().map(|s| s.node).unwrap_or(0))
    }

    /// Structural validation against a topology of `n` nodes and its churn
    /// schedule. Sources must exist, be unique, be in range, and carry
    /// positive shares. A source *may* appear in the churn schedule — the
    /// elastic control plane retires source nodes after failover — as long
    /// as at least one source never leaves, so admission always has
    /// surviving coverage. A schedule that would churn out every source is
    /// rejected (nothing would admit, and every orphaned lineage would have
    /// nowhere to re-home).
    pub fn validate(&self, n: usize, churn: &[ChurnEvent]) -> Result<()> {
        if self.sources.is_empty() {
            bail!("placement declares no sources");
        }
        for (i, s) in self.sources.iter().enumerate() {
            if s.node >= n {
                bail!("placement source {} out of range (topology has {} nodes)", s.node, n);
            }
            if !s.rate_share.is_finite() || s.rate_share <= 0.0 {
                bail!("placement source {}: rate_share must be positive", s.node);
            }
            if self.sources[..i].iter().any(|p| p.node == s.node) {
                bail!("placement source {} declared twice", s.node);
            }
        }
        let covering = self
            .sources
            .iter()
            .filter(|s| !churn.iter().any(|e| e.worker == s.node && !e.join))
            .count();
        if covering == 0 && churn.iter().any(|e| self.is_source(e.worker) && !e.join) {
            bail!(
                "churn schedule retires every source ({:?}) — at least one source \
                 must stay up to cover admission",
                self.source_nodes()
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Role
// ---------------------------------------------------------------------------

/// What a placement means for one worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Role {
    /// This worker admits data (runs admission pacing and, per the
    /// configured mode, an Alg. 3/4 controller).
    pub is_source: bool,
    /// The source this worker answers to: itself for sources; otherwise
    /// the nearest source by routing distance. Non-sources adopt their
    /// home source's adapted T_e as it propagates hop by hop through
    /// gossip.
    pub home_source: usize,
}

impl Role {
    pub fn of(node: usize, placement: &Placement, routing: &RoutingTable) -> Role {
        Role {
            is_source: placement.is_source(node),
            home_source: placement.home_source(node, routing),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::LinkSpec;

    fn topo(name: &str) -> Topology {
        Topology::named(name, LinkSpec::wifi()).unwrap()
    }

    #[test]
    fn line_next_hops_walk_the_chain() {
        let rt = RoutingTable::build(&topo("line-4"));
        assert_eq!(rt.next_hop(0, 3), Some(1));
        assert_eq!(rt.next_hop(1, 3), Some(2));
        assert_eq!(rt.next_hop(3, 0), Some(2));
        assert_eq!(rt.next_hop(2, 0), Some(1));
        assert_eq!(rt.next_hop(1, 1), None, "no hop to yourself");
        assert_eq!(rt.hops(0, 3), Some(3));
        assert_eq!(rt.hops(3, 1), Some(2));
        assert_eq!(rt.hops(2, 2), Some(0));
    }

    #[test]
    fn mesh_routes_are_direct() {
        let rt = RoutingTable::build(&topo("5-node-mesh"));
        for a in 0..5 {
            for b in 0..5 {
                if a != b {
                    assert_eq!(rt.next_hop(a, b), Some(b), "mesh is one hop");
                    assert_eq!(rt.hops(a, b), Some(1));
                }
            }
        }
    }

    #[test]
    fn star_routes_via_hub() {
        let rt = RoutingTable::build(&topo("star-5"));
        // Leaves reach each other through the hub (node 0).
        assert_eq!(rt.next_hop(1, 4), Some(0));
        assert_eq!(rt.next_hop(4, 1), Some(0));
        assert_eq!(rt.hops(1, 4), Some(2));
        assert_eq!(rt.next_hop(0, 3), Some(3));
    }

    #[test]
    fn bridge_routes_cross_the_bridge() {
        let rt = RoutingTable::build(&topo("2-ring-bridge"));
        // Ring A = {0,1,2}, ring B = {3,4,5}, bridge 2–3.
        assert_eq!(rt.next_hop(0, 4), Some(2), "toward the bridge");
        assert_eq!(rt.next_hop(2, 4), Some(3));
        assert_eq!(rt.hops(0, 4), Some(3));
        assert_eq!(rt.hops(5, 1), Some(3), "5 → 3 → 2 → 1 (ring B is a triangle)");
    }

    #[test]
    fn unreachable_and_isolated_nodes() {
        let t = Topology::empty("iso", 3); // no links
        let rt = RoutingTable::build(&t);
        assert_eq!(rt.next_hop(0, 2), None);
        assert_eq!(rt.distance(0, 2), None);
        assert_eq!(rt.hops(0, 2), None);
        assert_eq!(rt.hops(1, 1), Some(0));
    }

    #[test]
    fn weighted_ties_break_deterministically() {
        // Equal-cost two-hop paths 0-1-3 and 0-2-3: the route must pick
        // the lowest first hop, every time.
        let mut t = Topology::empty("diamond", 4);
        let l = LinkSpec::wifi();
        t.connect(0, 1, l);
        t.connect(0, 2, l);
        t.connect(1, 3, l);
        t.connect(2, 3, l);
        let rt = RoutingTable::build(&t);
        assert_eq!(rt.next_hop(0, 3), Some(1));
        assert_eq!(rt.next_hop(3, 0), Some(1));
    }

    #[test]
    fn slow_links_are_routed_around() {
        // 0-1 direct but at a crawl; 0-2-1 fast: shortest path takes the
        // detour, so "next hop" is weight-aware, not hop-count BFS.
        let mut t = Topology::empty("detour", 3);
        let fast = LinkSpec { bandwidth_bps: 100e6, base_latency_s: 1e-3, jitter_s: 0.0 };
        let slow = LinkSpec { bandwidth_bps: 1e4, base_latency_s: 0.5, jitter_s: 0.0 };
        t.connect(0, 1, slow);
        t.connect(0, 2, fast);
        t.connect(2, 1, fast);
        let rt = RoutingTable::build(&t);
        assert_eq!(rt.next_hop(0, 1), Some(2));
        assert_eq!(rt.hops(0, 1), Some(2));
    }

    #[test]
    fn placement_roles_and_homes() {
        let t = topo("line-4");
        let rt = RoutingTable::build(&t);
        let p = Placement::multi(&[0, 3]);
        assert!(p.is_source(0) && p.is_source(3));
        assert!(!p.is_source(1));
        // Workers split between the two ends of the line.
        assert_eq!(p.home_source(0, &rt), 0);
        assert_eq!(p.home_source(1, &rt), 0);
        assert_eq!(p.home_source(2, &rt), 3);
        assert_eq!(p.home_source(3, &rt), 3);
        let r1 = Role::of(1, &p, &rt);
        assert!(!r1.is_source);
        assert_eq!(r1.home_source, 0);
        let r3 = Role::of(3, &p, &rt);
        assert!(r3.is_source);
        assert_eq!(r3.home_source, 3);
    }

    #[test]
    fn equidistant_home_breaks_toward_lowest_source() {
        let t = topo("line-4");
        let rt = RoutingTable::build(&t);
        // Sources at both neighbors of node 1: equal distance, home = 0.
        let p = Placement::multi(&[2, 0]);
        assert_eq!(p.home_source(1, &rt), 0);
    }

    #[test]
    fn placement_validation() {
        let churn_3 = vec![ChurnEvent { at_s: 1.0, worker: 3, join: false }];
        assert!(Placement::multi(&[0, 3]).validate(4, &[]).is_ok());
        assert!(Placement { sources: vec![] }.validate(4, &[]).is_err());
        assert!(Placement::multi(&[0, 4]).validate(4, &[]).is_err(), "out of range");
        assert!(Placement::multi(&[0, 0]).validate(4, &[]).is_err(), "duplicate");
        assert!(
            Placement { sources: vec![SourceSpec { node: 0, rate_share: 0.0 }] }
                .validate(4, &[])
                .is_err(),
            "zero share"
        );
        // A source may churn out as long as another source stays up to
        // cover admission (the control plane retires sources after
        // failover); a schedule that retires *every* source is rejected.
        assert!(
            Placement::multi(&[0, 3]).validate(4, &churn_3).is_ok(),
            "source 3 may retire: source 0 covers"
        );
        assert!(Placement::single(0).validate(4, &churn_3).is_ok());
        let churn_0 = vec![ChurnEvent { at_s: 1.0, worker: 0, join: false }];
        assert!(Placement::single(0).validate(4, &churn_0).is_err(), "no covering source");
        let churn_both = vec![
            ChurnEvent { at_s: 1.0, worker: 0, join: false },
            ChurnEvent { at_s: 2.0, worker: 3, join: false },
        ];
        assert!(
            Placement::multi(&[0, 3]).validate(4, &churn_both).is_err(),
            "all sources retire"
        );
    }

    #[test]
    fn build_active_avoids_parked_relays() {
        // Line 0-1-2-3 with node 1 parked: 0 can no longer relay through
        // 1... but the line has no detour, so the static fallback keeps
        // 0 → 3 routable through 1's still-forwarding radio.
        let t = topo("line-4");
        let mut active = vec![true; 4];
        active[1] = false;
        let rt = RoutingTable::build_active(&t, &active);
        assert_eq!(rt.next_hop(0, 3), Some(1), "no detour: static fallback");
        // The parked node itself still drains what it holds.
        assert_eq!(rt.next_hop(1, 3), Some(2));
        assert_eq!(rt.next_hop(1, 0), Some(0));
        // Terminal hops onto the parked radio stay valid.
        assert_eq!(rt.next_hop(0, 1), Some(1));

        // Diamond 0-1-3 / 0-2-3 with 1 parked: traffic takes the detour.
        let mut d = Topology::empty("diamond", 4);
        let l = LinkSpec::wifi();
        d.connect(0, 1, l);
        d.connect(0, 2, l);
        d.connect(1, 3, l);
        d.connect(2, 3, l);
        let mut active = vec![true; 4];
        active[1] = false;
        let rt = RoutingTable::build_active(&d, &active);
        assert_eq!(rt.next_hop(0, 3), Some(2), "re-routed around the parked relay");
        assert_eq!(rt.next_hop(3, 0), Some(2));
        assert_eq!(rt.next_hop(1, 3), Some(3), "parked node still forwards out");

        // All-active must reproduce the static table bit for bit.
        let full = RoutingTable::build(&d);
        let all = RoutingTable::build_active(&d, &vec![true; 4]);
        for a in 0..4 {
            for b in 0..4 {
                assert_eq!(all.next_hop(a, b), full.next_hop(a, b));
            }
        }
    }

    #[test]
    fn default_placement_is_the_paper_setup() {
        let p = Placement::default();
        assert_eq!(p.source_nodes(), vec![0]);
        assert!((p.rate_share(0) - 1.0).abs() < 1e-12);
    }
}
