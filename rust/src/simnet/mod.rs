//! Simulated edge network (testbed substitute, DESIGN.md §1).
//!
//! The paper's testbed is NVIDIA Jetson Nanos on WiFi in four topologies.
//! The algorithms consume only (a) per-task compute delay Γ_n, (b) link
//! transfer delay D_nm, and (c) queue sizes — so the substitution models
//! exactly those: per-worker compute-speed factors and per-link
//! bandwidth/latency/jitter, plus a churn schedule for the paper's
//! "workers join and leave the system anytime" dynamics.
//!
//! The same specs drive both execution modes: the discrete-event driver
//! turns them into virtual-time delays; the realtime transport
//! (`transport.rs`) turns them into actual sleeps on delivery threads.

pub mod transport;

use crate::util::rng::{streams, Pcg64};

/// A directed link n -> m with WiFi-like characteristics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// Sustained throughput, bytes per second.
    pub bandwidth_bps: f64,
    /// Propagation + protocol base latency, seconds.
    pub base_latency_s: f64,
    /// Lognormal-ish jitter magnitude, seconds (0 = deterministic link).
    pub jitter_s: f64,
}

impl LinkSpec {
    /// Default WiFi-class link: ~12.5 MB/s effective (100 Mbps 802.11n),
    /// 2 ms base, 1 ms jitter — the regime of the paper's testbed.
    pub fn wifi() -> LinkSpec {
        LinkSpec { bandwidth_bps: 12.5e6, base_latency_s: 2.0e-3, jitter_s: 1.0e-3 }
    }

    /// Transfer delay for a payload (paper's D_nm for one task), sampled.
    pub fn delay_s(&self, bytes: usize, rng: &mut Pcg64) -> f64 {
        let jitter = if self.jitter_s > 0.0 {
            rng.exponential(self.jitter_s)
        } else {
            0.0
        };
        self.base_latency_s + bytes as f64 / self.bandwidth_bps + jitter
    }

    /// Deterministic mean delay (for estimator sanity checks).
    pub fn mean_delay_s(&self, bytes: usize) -> f64 {
        self.base_latency_s + bytes as f64 / self.bandwidth_bps + self.jitter_s
    }
}

/// A worker's compute character: scale factor over the manifest's measured
/// stage costs (1.0 = build machine; <1 slower, >1 faster). Heterogeneity
/// across workers recreates the paper's mixed edge devices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerSpec {
    pub speed: f64,
}

impl Default for WorkerSpec {
    fn default() -> Self {
        WorkerSpec { speed: 1.0 }
    }
}

/// A worker joining or leaving mid-run (paper §III: "workers join and
/// leave the system anytime"). A source may leave as long as at least one
/// covering source survives the whole schedule — enforced by
/// `routing::Placement::validate`, which knows where the sources are.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    pub at_s: f64,
    pub worker: usize,
    pub join: bool,
}

/// Network description: adjacency with per-link specs + per-worker specs.
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub n: usize,
    /// links[n][m] = Some(spec) iff n and m are one-hop neighbors.
    links: Vec<Vec<Option<LinkSpec>>>,
    pub workers: Vec<WorkerSpec>,
    pub churn: Vec<ChurnEvent>,
}

impl Topology {
    pub fn empty(name: &str, n: usize) -> Topology {
        Topology {
            name: name.to_string(),
            n,
            links: vec![vec![None; n]; n],
            workers: vec![WorkerSpec::default(); n],
            churn: Vec::new(),
        }
    }

    pub fn connect(&mut self, a: usize, b: usize, spec: LinkSpec) {
        assert!(a != b && a < self.n && b < self.n, "bad link {a}-{b}");
        self.links[a][b] = Some(spec);
        self.links[b][a] = Some(spec);
    }

    pub fn link(&self, from: usize, to: usize) -> Option<&LinkSpec> {
        self.links[from][to].as_ref()
    }

    /// One-hop neighbor ids of `n` (the candidate offload targets of Alg. 2).
    pub fn neighbors(&self, n: usize) -> Vec<usize> {
        (0..self.n).filter(|&m| self.links[n][m].is_some()).collect()
    }

    pub fn is_connected_pair(&self, a: usize, b: usize) -> bool {
        self.links[a][b].is_some()
    }

    /// The paper's four testbed topologies (§V) plus three multi-hop
    /// graphs that exercise the routing layer. Sources are declared by the
    /// run's `Placement` (default: node 0, the paper's setup).
    ///
    /// * `"local"`          — 1 node, no links (the Local baselines)
    /// * `"2-node"`         — source + 1 worker
    /// * `"3-node-mesh"`    — 3 fully connected
    /// * `"3-node-circular"`— 3 in a ring (identical to mesh at n=3 as a
    ///   graph, but with *half-bandwidth* links modelling the shared ring)
    /// * `"5-node-mesh"`    — 5 fully connected
    /// * `"line-4"`         — 0–1–2–3 chain (up to 3 hops end to end)
    /// * `"star-5"`         — hub 0 with leaves 1–4 (leaf↔leaf is 2 hops)
    /// * `"2-ring-bridge"`  — triangles {0,1,2} and {3,4,5} joined by a
    ///   single half-bandwidth 2–3 bridge (up to 4 hops across)
    ///
    /// Three parametric *generator families* extend the same namespace to
    /// metro scale (see [`Topology::named_seeded`] for the seeding
    /// contract; `named` builds them with seed 0):
    ///
    /// * `"grid-NxM"`               — N rows × M columns, 4-neighbor mesh
    ///   (node id = row·M + col), e.g. `grid-3x3`, `grid-25x40`
    /// * `"random-geometric-N-R"`   — N points uniform on the unit square,
    ///   linked within radius R, then minimally repaired to be connected,
    ///   e.g. `random-geometric-200-0.12`
    /// * `"scale-free-N"`           — Barabási–Albert preferential
    ///   attachment (m = 2 links per new node from a seed triangle),
    ///   e.g. `scale-free-500`
    pub fn named(name: &str, link: LinkSpec) -> Option<Topology> {
        Self::named_seeded(name, link, 0)
    }

    /// [`Topology::named`] with an explicit seed for the generator
    /// families (fixed names ignore it).
    ///
    /// Determinism contract: generated graphs are a pure function of
    /// `(name, seed)` — random-geometric draws from PCG stream 4242,
    /// scale-free from 4343, both disjoint from every runtime stream — so
    /// the two drivers, handed the same config, build the identical graph,
    /// and a stored experiment config replays its exact topology. All
    /// generated graphs are connected by construction (the geometric
    /// family repairs disconnected components by bridging closest
    /// cross-component pairs, deterministically).
    pub fn named_seeded(name: &str, link: LinkSpec, seed: u64) -> Option<Topology> {
        let mut t = match name {
            "local" => Topology::empty(name, 1),
            "2-node" => {
                let mut t = Topology::empty(name, 2);
                t.connect(0, 1, link);
                t
            }
            "3-node-mesh" => {
                let mut t = Topology::empty(name, 3);
                for a in 0..3 {
                    for b in (a + 1)..3 {
                        t.connect(a, b, link);
                    }
                }
                t
            }
            "3-node-circular" => {
                // a ring of 3 is graph-identical to the mesh; the circular
                // testbed differs in that each radio shares the medium with
                // both ring neighbors — modelled as half-rate links.
                let ring = LinkSpec { bandwidth_bps: link.bandwidth_bps * 0.5, ..link };
                let mut t = Topology::empty(name, 3);
                t.connect(0, 1, ring);
                t.connect(1, 2, ring);
                t.connect(2, 0, ring);
                t
            }
            "5-node-mesh" => {
                let mut t = Topology::empty(name, 5);
                for a in 0..5 {
                    for b in (a + 1)..5 {
                        t.connect(a, b, link);
                    }
                }
                t
            }
            "line-4" => {
                let mut t = Topology::empty(name, 4);
                for a in 0..3 {
                    t.connect(a, a + 1, link);
                }
                t
            }
            "star-5" => {
                let mut t = Topology::empty(name, 5);
                for leaf in 1..5 {
                    t.connect(0, leaf, link);
                }
                t
            }
            "2-ring-bridge" => {
                // Two triangles joined by a single half-rate bridge: the
                // bridge is the routing bottleneck every cross-ring result
                // and re-home must traverse.
                let bridge = LinkSpec { bandwidth_bps: link.bandwidth_bps * 0.5, ..link };
                let mut t = Topology::empty(name, 6);
                for ring in [[0, 1, 2], [3, 4, 5]] {
                    t.connect(ring[0], ring[1], link);
                    t.connect(ring[1], ring[2], link);
                    t.connect(ring[2], ring[0], link);
                }
                t.connect(2, 3, bridge);
                t
            }
            _ => Self::generate(name, link, seed)?,
        };
        // Mild heterogeneity: non-source workers alternate 0.85x / 1.1x of
        // the source's speed (the paper's devices are nominally identical
        // Jetsons but effectively heterogeneous under thermal throttling).
        for i in 1..t.n {
            t.workers[i].speed = if i % 2 == 0 { 1.1 } else { 0.85 };
        }
        Some(t)
    }

    /// Largest node count the generator families accept: the adjacency
    /// matrix is dense, so memory is quadratic (4096² ≈ 0.5 GB of links).
    pub const MAX_GENERATED_NODES: usize = 4096;

    /// Parse-and-build for the parametric families. `None` when the name
    /// doesn't match any family or the parameters are out of range.
    fn generate(name: &str, link: LinkSpec, seed: u64) -> Option<Topology> {
        if let Some(dims) = name.strip_prefix("grid-") {
            let (rows, cols) = dims.split_once('x')?;
            let (rows, cols): (usize, usize) = (rows.parse().ok()?, cols.parse().ok()?);
            if rows == 0 || cols == 0 || rows * cols > Self::MAX_GENERATED_NODES {
                return None;
            }
            return Some(Self::grid(name, rows, cols, link));
        }
        if let Some(params) = name.strip_prefix("random-geometric-") {
            let (n, r) = params.split_once('-')?;
            let (n, r): (usize, f64) = (n.parse().ok()?, r.parse().ok()?);
            if n == 0 || n > Self::MAX_GENERATED_NODES || !r.is_finite() || r <= 0.0 {
                return None;
            }
            return Some(Self::random_geometric(name, n, r, link, seed));
        }
        if let Some(n) = name.strip_prefix("scale-free-") {
            let n: usize = n.parse().ok()?;
            if n < 3 || n > Self::MAX_GENERATED_NODES {
                return None;
            }
            return Some(Self::scale_free(name, n, link, seed));
        }
        None
    }

    fn grid(name: &str, rows: usize, cols: usize, link: LinkSpec) -> Topology {
        let mut t = Topology::empty(name, rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                let id = r * cols + c;
                if c + 1 < cols {
                    t.connect(id, id + 1, link);
                }
                if r + 1 < rows {
                    t.connect(id, id + cols, link);
                }
            }
        }
        t
    }

    fn random_geometric(name: &str, n: usize, radius: f64, link: LinkSpec, seed: u64) -> Topology {
        let mut rng = Pcg64::new(seed, streams::TOPO_GEOMETRIC);
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64(), rng.f64())).collect();
        let d2 = |a: usize, b: usize| {
            let (dx, dy) = (pts[a].0 - pts[b].0, pts[a].1 - pts[b].1);
            dx * dx + dy * dy
        };
        let mut t = Topology::empty(name, n);
        let r2 = radius * radius;
        for a in 0..n {
            for b in (a + 1)..n {
                if d2(a, b) <= r2 {
                    t.connect(a, b, link);
                }
            }
        }
        // Repair: while disconnected, bridge the globally closest
        // cross-component pair. The strict `<` scan in ascending (a, b)
        // order makes tie-breaks — and thus the repaired graph —
        // deterministic.
        let mut comp = t.components();
        while comp.iter().any(|&c| c != comp[0]) {
            let (mut best, mut best_d2) = ((0, 0), f64::INFINITY);
            for a in 0..n {
                for b in (a + 1)..n {
                    if comp[a] != comp[b] && d2(a, b) < best_d2 {
                        best_d2 = d2(a, b);
                        best = (a, b);
                    }
                }
            }
            t.connect(best.0, best.1, link);
            let (keep, merge) = (comp[best.0], comp[best.1]);
            for c in comp.iter_mut() {
                if *c == merge {
                    *c = keep;
                }
            }
        }
        debug_assert!(t.is_fully_connected());
        t
    }

    fn scale_free(name: &str, n: usize, link: LinkSpec, seed: u64) -> Topology {
        let mut rng = Pcg64::new(seed, streams::TOPO_SCALE_FREE);
        let mut t = Topology::empty(name, n);
        // Seed triangle, then each new node attaches m=2 links, targets
        // drawn proportionally to degree by sampling the edge-endpoint
        // multiset.
        t.connect(0, 1, link);
        t.connect(1, 2, link);
        t.connect(2, 0, link);
        let mut endpoints: Vec<usize> = vec![0, 1, 1, 2, 2, 0];
        for v in 3..n {
            let first = endpoints[rng.below(endpoints.len() as u64) as usize];
            let mut second = endpoints[rng.below(endpoints.len() as u64) as usize];
            let mut tries = 0;
            while second == first && tries < 32 {
                second = endpoints[rng.below(endpoints.len() as u64) as usize];
                tries += 1;
            }
            if second == first {
                // Degenerate multiset (can't happen past the seed triangle,
                // but keep the fallback total): lowest other node id.
                second = if first == 0 { 1 } else { 0 };
            }
            for u in [first, second] {
                t.connect(v, u, link);
                endpoints.push(v);
                endpoints.push(u);
            }
        }
        debug_assert!(t.is_fully_connected());
        t
    }

    /// Connected-component label per node (BFS), ignoring link direction.
    fn components(&self) -> Vec<usize> {
        let mut comp = vec![usize::MAX; self.n];
        let mut queue = Vec::new();
        for start in 0..self.n {
            if comp[start] != usize::MAX {
                continue;
            }
            comp[start] = start;
            queue.push(start);
            while let Some(u) = queue.pop() {
                for m in 0..self.n {
                    if self.links[u][m].is_some() && comp[m] == usize::MAX {
                        comp[m] = start;
                        queue.push(m);
                    }
                }
            }
        }
        comp
    }

    /// Whether every node can reach every other (the structural invariant
    /// the generator families guarantee; `local` trivially satisfies it).
    pub fn is_fully_connected(&self) -> bool {
        let comp = self.components();
        comp.iter().all(|&c| c == comp[0])
    }

    /// Total undirected edge count.
    pub fn edge_count(&self) -> usize {
        let mut edges = 0;
        for a in 0..self.n {
            for b in (a + 1)..self.n {
                if self.links[a][b].is_some() {
                    edges += 1;
                }
            }
        }
        edges
    }

    pub fn all_names() -> &'static [&'static str] {
        &[
            "local",
            "2-node",
            "3-node-mesh",
            "3-node-circular",
            "5-node-mesh",
            "line-4",
            "star-5",
            "2-ring-bridge",
        ]
    }

    /// Attach a churn schedule. Which nodes may churn is a *placement*
    /// question (admission must stay covered by at least one source) and is
    /// validated by `routing::Placement::validate`, where the source set
    /// lives.
    pub fn with_churn(mut self, churn: Vec<ChurnEvent>) -> Topology {
        for e in &churn {
            assert!(e.worker < self.n, "churn worker {} out of range", e.worker);
        }
        self.churn = churn;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_math() {
        let l = LinkSpec { bandwidth_bps: 1.0e6, base_latency_s: 0.002, jitter_s: 0.0 };
        let mut rng = Pcg64::new(1, 0);
        // 1 MB over 1 MB/s + 2 ms
        assert!((l.delay_s(1_000_000, &mut rng) - 1.002).abs() < 1e-9);
        assert!((l.mean_delay_s(500_000) - 0.502).abs() < 1e-9);
    }

    #[test]
    fn jitter_is_nonnegative_and_variable() {
        let l = LinkSpec { bandwidth_bps: 1.0e6, base_latency_s: 0.001, jitter_s: 0.005 };
        let mut rng = Pcg64::new(2, 0);
        let d1 = l.delay_s(1000, &mut rng);
        let d2 = l.delay_s(1000, &mut rng);
        assert!(d1 >= 0.002 && d2 >= 0.002);
        assert_ne!(d1, d2);
    }

    #[test]
    fn named_topologies() {
        let wifi = LinkSpec::wifi();
        let t = Topology::named("local", wifi).unwrap();
        assert_eq!((t.n, t.neighbors(0).len()), (1, 0));

        let t = Topology::named("2-node", wifi).unwrap();
        assert_eq!(t.neighbors(0), vec![1]);

        let t = Topology::named("3-node-mesh", wifi).unwrap();
        assert_eq!(t.neighbors(0), vec![1, 2]);
        assert_eq!(t.neighbors(2), vec![0, 1]);

        let t = Topology::named("5-node-mesh", wifi).unwrap();
        for n in 0..5 {
            assert_eq!(t.neighbors(n).len(), 4);
        }
        assert!(Topology::named("7-node-star", wifi).is_none());
    }

    #[test]
    fn circular_halves_bandwidth() {
        let wifi = LinkSpec::wifi();
        let mesh = Topology::named("3-node-mesh", wifi).unwrap();
        let circ = Topology::named("3-node-circular", wifi).unwrap();
        let bm = mesh.link(0, 1).unwrap().bandwidth_bps;
        let bc = circ.link(0, 1).unwrap().bandwidth_bps;
        assert!((bc - bm * 0.5).abs() < 1e-9);
    }

    #[test]
    fn links_are_symmetric() {
        let t = Topology::named("3-node-mesh", LinkSpec::wifi()).unwrap();
        for a in 0..3 {
            for b in 0..3 {
                assert_eq!(t.link(a, b).is_some(), t.link(b, a).is_some());
            }
        }
    }

    #[test]
    fn multi_hop_topologies() {
        let wifi = LinkSpec::wifi();
        let t = Topology::named("line-4", wifi).unwrap();
        assert_eq!(t.n, 4);
        assert_eq!(t.neighbors(0), vec![1]);
        assert_eq!(t.neighbors(1), vec![0, 2]);
        assert_eq!(t.neighbors(3), vec![2]);
        assert!(!t.is_connected_pair(0, 3), "ends of the line are multi-hop");

        let t = Topology::named("star-5", wifi).unwrap();
        assert_eq!(t.neighbors(0), vec![1, 2, 3, 4]);
        for leaf in 1..5 {
            assert_eq!(t.neighbors(leaf), vec![0], "leaves see only the hub");
        }

        let t = Topology::named("2-ring-bridge", wifi).unwrap();
        assert_eq!(t.n, 6);
        assert_eq!(t.neighbors(2), vec![0, 1, 3]);
        let bridge = t.link(2, 3).unwrap().bandwidth_bps;
        assert!((bridge - wifi.bandwidth_bps * 0.5).abs() < 1e-9, "bridge is half-rate");
        assert!(!t.is_connected_pair(0, 5));
    }

    fn edge_set(t: &Topology) -> Vec<(usize, usize)> {
        let mut es = Vec::new();
        for a in 0..t.n {
            for b in (a + 1)..t.n {
                if t.is_connected_pair(a, b) {
                    es.push((a, b));
                }
            }
        }
        es
    }

    #[test]
    fn grid_generator_shape() {
        let wifi = LinkSpec::wifi();
        let t = Topology::named("grid-3x4", wifi).unwrap();
        assert_eq!(t.n, 12);
        // N(M-1) + M(N-1) = 3·3 + 4·2 = 17 edges.
        assert_eq!(t.edge_count(), 17);
        assert!(t.is_fully_connected());
        // Interior node 5 (row 1, col 1) has all four neighbors.
        assert_eq!(t.neighbors(5), vec![1, 4, 6, 9]);
        // Corners have two.
        assert_eq!(t.neighbors(0), vec![1, 4]);
        assert_eq!(t.neighbors(11), vec![7, 10]);
        // grid-3x3 exists for the cross-driver tests.
        assert_eq!(Topology::named("grid-3x3", wifi).unwrap().n, 9);
        // Bad shapes are rejected, not panicked on.
        assert!(Topology::named("grid-0x4", wifi).is_none());
        assert!(Topology::named("grid-3by4", wifi).is_none());
        assert!(Topology::named("grid-9999x9999", wifi).is_none());
    }

    #[test]
    fn random_geometric_is_connected_and_seed_deterministic() {
        let wifi = LinkSpec::wifi();
        let a = Topology::named_seeded("random-geometric-80-0.12", wifi, 7).unwrap();
        let b = Topology::named_seeded("random-geometric-80-0.12", wifi, 7).unwrap();
        let c = Topology::named_seeded("random-geometric-80-0.12", wifi, 8).unwrap();
        assert_eq!(a.n, 80);
        assert!(a.is_fully_connected(), "repair bridges every component");
        assert_eq!(edge_set(&a), edge_set(&b), "same seed, same graph");
        assert_ne!(edge_set(&a), edge_set(&c), "different seed, different graph");
        // Sparse radius still yields a connected graph via repair.
        let sparse = Topology::named_seeded("random-geometric-40-0.01", wifi, 3).unwrap();
        assert!(sparse.is_fully_connected());
        assert!(sparse.edge_count() >= sparse.n - 1);
        assert!(Topology::named("random-geometric-40-0", wifi).is_none());
        assert!(Topology::named("random-geometric-40", wifi).is_none());
    }

    #[test]
    fn scale_free_degree_and_determinism() {
        let wifi = LinkSpec::wifi();
        let a = Topology::named_seeded("scale-free-120", wifi, 7).unwrap();
        let b = Topology::named_seeded("scale-free-120", wifi, 7).unwrap();
        let c = Topology::named_seeded("scale-free-120", wifi, 9).unwrap();
        assert_eq!(a.n, 120);
        // Seed triangle (3 edges) + 2 per attached node.
        assert_eq!(a.edge_count(), 3 + 2 * (120 - 3));
        assert!(a.is_fully_connected());
        assert_eq!(edge_set(&a), edge_set(&b));
        assert_ne!(edge_set(&a), edge_set(&c));
        // Preferential attachment concentrates degree: some hub has far
        // more links than the minimum degree of 2.
        let max_deg = (0..a.n).map(|v| a.neighbors(v).len()).max().unwrap();
        assert!(max_deg >= 8, "expected a hub, max degree {max_deg}");
        assert!(Topology::named("scale-free-2", wifi).is_none());
    }

    #[test]
    fn named_defaults_to_seed_zero_and_heterogeneity_applies() {
        let wifi = LinkSpec::wifi();
        let a = Topology::named("scale-free-30", wifi).unwrap();
        let b = Topology::named_seeded("scale-free-30", wifi, 0).unwrap();
        assert_eq!(edge_set(&a), edge_set(&b));
        // The alternating speed profile covers generated nodes too.
        assert!((a.workers[1].speed - 0.85).abs() < 1e-12);
        assert!((a.workers[2].speed - 1.1).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn churn_bounds_checked() {
        let t = Topology::named("2-node", LinkSpec::wifi()).unwrap();
        let _ = t.with_churn(vec![ChurnEvent { at_s: 1.0, worker: 7, join: false }]);
    }
}
