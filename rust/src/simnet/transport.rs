//! Realtime transport: delivers messages between worker threads with the
//! link delays the topology prescribes (actual sleeps, not virtual time).
//!
//! One scheduler thread owns a due-time heap; endpoints stamp each message
//! with `now + link.delay_s(bytes)` and the scheduler releases it to the
//! destination's mailbox when the deadline passes. This gives the threaded
//! driver (examples, XLA engine) the same D_nm semantics the discrete-event
//! driver computes in virtual time.

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::Topology;
use crate::util::rng::Pcg64;

struct Scheduled<T> {
    due: Instant,
    seq: u64,
    to: usize,
    from: usize,
    msg: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // min-heap by (due, seq) via reverse
        other.due.cmp(&self.due).then(other.seq.cmp(&self.seq))
    }
}

enum Ctl<T> {
    Send(Scheduled<T>),
    Shutdown,
}

/// The network fabric: build once, take one endpoint per worker.
pub struct DelayNet<T: Send + 'static> {
    ctl: Sender<Ctl<T>>,
    mailboxes: Vec<Option<Receiver<Delivery<T>>>>,
    topology: Arc<Topology>,
    seq: Arc<Mutex<u64>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// A delivered message with its origin.
#[derive(Debug)]
pub struct Delivery<T> {
    pub from: usize,
    pub msg: T,
}

/// Per-worker sending/receiving handle.
pub struct Endpoint<T: Send + 'static> {
    pub id: usize,
    rx: Receiver<Delivery<T>>,
    ctl: Sender<Ctl<T>>,
    topology: Arc<Topology>,
    rng: Mutex<Pcg64>,
    seq: Arc<Mutex<u64>>,
}

impl<T: Send + 'static> DelayNet<T> {
    pub fn new(topology: Arc<Topology>, _seed: u64) -> DelayNet<T> {
        let (ctl_tx, ctl_rx) = channel::<Ctl<T>>();
        let mut mailboxes = Vec::with_capacity(topology.n);
        let mut deliver_txs = Vec::with_capacity(topology.n);
        for _ in 0..topology.n {
            let (tx, rx) = channel::<Delivery<T>>();
            deliver_txs.push(tx);
            mailboxes.push(Some(rx));
        }
        let handle = std::thread::Builder::new()
            .name("simnet-sched".into())
            .spawn(move || scheduler_loop(ctl_rx, deliver_txs))
            .expect("spawn scheduler");
        DelayNet {
            ctl: ctl_tx,
            mailboxes,
            topology,
            seq: Arc::new(Mutex::new(0)),
            handle: Some(handle),
        }
    }

    /// Take worker `id`'s endpoint (once).
    pub fn endpoint(&mut self, id: usize, seed: u64) -> Endpoint<T> {
        let rx = self.mailboxes[id].take().expect("endpoint already taken");
        Endpoint {
            id,
            rx,
            ctl: self.ctl.clone(),
            topology: self.topology.clone(),
            rng: Mutex::new(Pcg64::new(seed, id as u64 + 100)),
            seq: self.seq.clone(),
        }
    }
}

impl<T: Send + 'static> Drop for DelayNet<T> {
    fn drop(&mut self) {
        let _ = self.ctl.send(Ctl::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn scheduler_loop<T>(ctl: Receiver<Ctl<T>>, deliver: Vec<Sender<Delivery<T>>>) {
    let mut heap: BinaryHeap<Scheduled<T>> = BinaryHeap::new();
    loop {
        // Wait for the next control message or the next due delivery.
        let timeout = heap
            .peek()
            .map(|s| s.due.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_secs(3600));
        match ctl.recv_timeout(timeout) {
            Ok(Ctl::Send(s)) => heap.push(s),
            Ok(Ctl::Shutdown) => return,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return,
        }
        let now = Instant::now();
        while let Some(top) = heap.peek() {
            if top.due > now {
                break;
            }
            let s = heap.pop().unwrap();
            // Destination may have shut down (churn / end of run): drop.
            let _ = deliver[s.to].send(Delivery { from: s.from, msg: s.msg });
        }
    }
}

impl<T: Send + 'static> Endpoint<T> {
    /// Send `msg` of `bytes` to one-hop neighbor `to`; the fabric delivers
    /// it after the sampled link delay. Errors if `to` is not a neighbor
    /// (Alg. 2 only ever offloads one hop).
    pub fn send(&self, to: usize, msg: T, bytes: usize) -> Result<f64> {
        let Some(link) = self.topology.link(self.id, to) else {
            bail!("worker {} has no link to {}", self.id, to);
        };
        let delay = link.delay_s(bytes, &mut self.rng.lock().unwrap());
        let seq = {
            let mut s = self.seq.lock().unwrap();
            *s += 1;
            *s
        };
        self.ctl
            .send(Ctl::Send(Scheduled {
                due: Instant::now() + Duration::from_secs_f64(delay),
                seq,
                to,
                from: self.id,
                msg,
            }))
            .map_err(|_| anyhow::anyhow!("network fabric shut down"))?;
        Ok(delay)
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Delivery<T>> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<Delivery<T>> {
        self.rx.try_recv().ok()
    }

    pub fn neighbors(&self) -> Vec<usize> {
        self.topology.neighbors(self.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::LinkSpec;

    fn fast_link() -> LinkSpec {
        LinkSpec { bandwidth_bps: 1.0e9, base_latency_s: 0.005, jitter_s: 0.0 }
    }

    #[test]
    fn delivers_with_delay() {
        let mut topo = Topology::empty("t", 2);
        topo.connect(0, 1, fast_link());
        let mut net: DelayNet<u32> = DelayNet::new(Arc::new(topo), 7);
        let a = net.endpoint(0, 1);
        let b = net.endpoint(1, 1);
        let t0 = Instant::now();
        let d = a.send(1, 42, 1000).unwrap();
        assert!(d >= 0.005);
        let got = b.recv_timeout(Duration::from_secs(2)).expect("delivery");
        let elapsed = t0.elapsed().as_secs_f64();
        assert_eq!(got.msg, 42);
        assert_eq!(got.from, 0);
        assert!(elapsed >= 0.004, "arrived too early: {elapsed}");
    }

    #[test]
    fn rejects_non_neighbor() {
        let topo = Topology::empty("t", 3); // no links at all
        let mut net: DelayNet<u32> = DelayNet::new(Arc::new(topo), 7);
        let a = net.endpoint(0, 1);
        assert!(a.send(2, 1, 10).is_err());
    }

    #[test]
    fn ordering_respects_due_times() {
        // A big slow message sent first must arrive after a later fast one.
        let mut topo = Topology::empty("t", 2);
        topo.connect(0, 1, LinkSpec { bandwidth_bps: 1.0e4, base_latency_s: 0.0, jitter_s: 0.0 });
        let mut net: DelayNet<&'static str> = DelayNet::new(Arc::new(topo), 7);
        let a = net.endpoint(0, 1);
        let b = net.endpoint(1, 1);
        a.send(1, "slow", 1500).unwrap(); // 150 ms
        a.send(1, "fast", 10).unwrap(); // 1 ms
        let first = b.recv_timeout(Duration::from_secs(2)).unwrap();
        let second = b.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(first.msg, "fast");
        assert_eq!(second.msg, "slow");
    }

    #[test]
    fn try_recv_nonblocking() {
        let mut topo = Topology::empty("t", 2);
        topo.connect(0, 1, fast_link());
        let mut net: DelayNet<u8> = DelayNet::new(Arc::new(topo), 7);
        let _a = net.endpoint(0, 1);
        let b = net.endpoint(1, 1);
        assert!(b.try_recv().is_none());
    }
}
