//! Realtime transport: delivers messages between worker threads with the
//! link delays the topology prescribes (actual sleeps, not virtual time).
//!
//! One scheduler thread owns a due-time heap; endpoints stamp each message
//! with `now + link.delay_s(bytes)` and the scheduler releases it to the
//! destination's mailbox when the deadline passes. This gives the threaded
//! driver (examples, XLA engine) the same D_nm semantics the discrete-event
//! driver computes in virtual time — including the two knobs the DES
//! driver already modelled:
//!
//! * **Seeded jitter** — the fabric owns the run seed; every endpoint's
//!   delay-jitter RNG derives from it
//!   (`(seed, `[`streams::RT_LINK_JITTER_BASE`]` + worker_id)`), so
//!   realtime link delays are reproducible per config seed.
//! * **Shared-medium contention** — the effective bandwidth of a send is
//!   divided by `1 + medium_contention × in-flight transfers`, mirroring
//!   the DES driver's WiFi model: concurrent transfers slow each other
//!   down, and a coalesced envelope occupies ONE contention slot where
//!   per-task wiring occupied k. In-flight = messages accepted by the
//!   fabric and not yet delivered, sampled at send time (the sender's own
//!   message is not counted against itself, exactly like the DES
//!   driver's `active_transfers`).

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::Topology;
use crate::util::rng::{streams, Pcg64};

struct Scheduled<T> {
    due: Instant,
    seq: u64,
    to: usize,
    from: usize,
    msg: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // min-heap by (due, seq) via reverse
        other.due.cmp(&self.due).then(other.seq.cmp(&self.seq))
    }
}

enum Ctl<T> {
    Send(Scheduled<T>),
    Shutdown,
}

/// The network fabric: build once, take one endpoint per worker.
pub struct DelayNet<T: Send + 'static> {
    ctl: Sender<Ctl<T>>,
    mailboxes: Vec<Option<Receiver<Delivery<T>>>>,
    topology: Arc<Topology>,
    seed: u64,
    medium_contention: f64,
    seq: Arc<Mutex<u64>>,
    /// Transfers accepted by the fabric and not yet delivered (the
    /// contention signal; decremented by the scheduler on delivery).
    in_flight: Arc<AtomicUsize>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// A delivered message with its origin.
#[derive(Debug)]
pub struct Delivery<T> {
    pub from: usize,
    pub msg: T,
}

/// Per-worker sending/receiving handle.
pub struct Endpoint<T: Send + 'static> {
    pub id: usize,
    rx: Receiver<Delivery<T>>,
    ctl: Sender<Ctl<T>>,
    topology: Arc<Topology>,
    medium_contention: f64,
    rng: Mutex<Pcg64>,
    seq: Arc<Mutex<u64>>,
    in_flight: Arc<AtomicUsize>,
}

impl<T: Send + 'static> DelayNet<T> {
    /// Build the fabric. `seed` feeds every endpoint's delay-jitter RNG
    /// (stream `(seed, `[`streams::RT_LINK_JITTER_BASE`]` + worker_id)`),
    /// so two runs on the same config seed sample identical link jitter;
    /// `medium_contention` is the run's shared-medium factor (0 =
    /// independent switched links).
    pub fn new(topology: Arc<Topology>, seed: u64, medium_contention: f64) -> DelayNet<T> {
        let (ctl_tx, ctl_rx) = channel::<Ctl<T>>();
        let mut mailboxes = Vec::with_capacity(topology.n);
        let mut deliver_txs = Vec::with_capacity(topology.n);
        for _ in 0..topology.n {
            let (tx, rx) = channel::<Delivery<T>>();
            deliver_txs.push(tx);
            mailboxes.push(Some(rx));
        }
        let in_flight = Arc::new(AtomicUsize::new(0));
        let sched_in_flight = in_flight.clone();
        let handle = std::thread::Builder::new()
            .name("simnet-sched".into())
            .spawn(move || scheduler_loop(ctl_rx, deliver_txs, sched_in_flight))
            .expect("spawn scheduler");
        DelayNet {
            ctl: ctl_tx,
            mailboxes,
            topology,
            seed,
            medium_contention,
            seq: Arc::new(Mutex::new(0)),
            in_flight,
            handle: Some(handle),
        }
    }

    /// Take worker `id`'s endpoint (once). The endpoint's jitter RNG is
    /// derived from the fabric's run seed — there is no per-endpoint seed
    /// to get wrong.
    pub fn endpoint(&mut self, id: usize) -> Endpoint<T> {
        let rx = self.mailboxes[id].take().expect("endpoint already taken");
        Endpoint {
            id,
            rx,
            ctl: self.ctl.clone(),
            topology: self.topology.clone(),
            medium_contention: self.medium_contention,
            rng: Mutex::new(Pcg64::new(self.seed, streams::RT_LINK_JITTER_BASE + id as u64)),
            seq: self.seq.clone(),
            in_flight: self.in_flight.clone(),
        }
    }
}

impl<T: Send + 'static> Drop for DelayNet<T> {
    fn drop(&mut self) {
        let _ = self.ctl.send(Ctl::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn scheduler_loop<T>(
    ctl: Receiver<Ctl<T>>,
    deliver: Vec<Sender<Delivery<T>>>,
    in_flight: Arc<AtomicUsize>,
) {
    let mut heap: BinaryHeap<Scheduled<T>> = BinaryHeap::new();
    loop {
        // Wait for the next control message or the next due delivery.
        let timeout = heap
            .peek()
            .map(|s| s.due.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_secs(3600));
        match ctl.recv_timeout(timeout) {
            Ok(Ctl::Send(s)) => heap.push(s),
            Ok(Ctl::Shutdown) => return,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return,
        }
        let now = Instant::now();
        while let Some(top) = heap.peek() {
            if top.due > now {
                break;
            }
            let s = heap.pop().unwrap();
            // The transfer stops occupying the shared medium on delivery.
            in_flight.fetch_sub(1, AtomicOrdering::Relaxed);
            // Destination may have shut down (churn / end of run): drop.
            let _ = deliver[s.to].send(Delivery { from: s.from, msg: s.msg });
        }
    }
}

impl<T: Send + 'static> Endpoint<T> {
    /// Send `msg` of `bytes` to one-hop neighbor `to`; the fabric delivers
    /// it after the sampled link delay, with the effective bandwidth
    /// divided by `1 + medium_contention × in-flight transfers` (the DES
    /// contention model). Errors if `to` is not a neighbor (Alg. 2 only
    /// ever offloads one hop).
    pub fn send(&self, to: usize, msg: T, bytes: usize) -> Result<f64> {
        let Some(link) = self.topology.link(self.id, to) else {
            bail!("worker {} has no link to {}", self.id, to);
        };
        let concurrent = self.in_flight.load(AtomicOrdering::Relaxed);
        let slow = 1.0 + self.medium_contention * concurrent as f64;
        let mut eff = *link;
        eff.bandwidth_bps = link.bandwidth_bps / slow;
        let delay = eff.delay_s(bytes, &mut self.rng.lock().unwrap());
        let seq = {
            let mut s = self.seq.lock().unwrap();
            *s += 1;
            *s
        };
        self.in_flight.fetch_add(1, AtomicOrdering::Relaxed);
        if self
            .ctl
            .send(Ctl::Send(Scheduled {
                due: Instant::now() + Duration::from_secs_f64(delay),
                seq,
                to,
                from: self.id,
                msg,
            }))
            .is_err()
        {
            // The fabric already shut down: the message never occupied it.
            self.in_flight.fetch_sub(1, AtomicOrdering::Relaxed);
            bail!("network fabric shut down");
        }
        Ok(delay)
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Delivery<T>> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<Delivery<T>> {
        self.rx.try_recv().ok()
    }

    pub fn neighbors(&self) -> Vec<usize> {
        self.topology.neighbors(self.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::LinkSpec;

    fn fast_link() -> LinkSpec {
        LinkSpec { bandwidth_bps: 1.0e9, base_latency_s: 0.005, jitter_s: 0.0 }
    }

    #[test]
    fn delivers_with_delay() {
        let mut topo = Topology::empty("t", 2);
        topo.connect(0, 1, fast_link());
        let mut net: DelayNet<u32> = DelayNet::new(Arc::new(topo), 7, 0.0);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let t0 = Instant::now();
        let d = a.send(1, 42, 1000).unwrap();
        assert!(d >= 0.005);
        let got = b.recv_timeout(Duration::from_secs(2)).expect("delivery");
        let elapsed = t0.elapsed().as_secs_f64();
        assert_eq!(got.msg, 42);
        assert_eq!(got.from, 0);
        assert!(elapsed >= 0.004, "arrived too early: {elapsed}");
    }

    #[test]
    fn rejects_non_neighbor() {
        let topo = Topology::empty("t", 3); // no links at all
        let mut net: DelayNet<u32> = DelayNet::new(Arc::new(topo), 7, 0.0);
        let a = net.endpoint(0);
        assert!(a.send(2, 1, 10).is_err());
    }

    #[test]
    fn ordering_respects_due_times() {
        // A big slow message sent first must arrive after a later fast one.
        let mut topo = Topology::empty("t", 2);
        topo.connect(0, 1, LinkSpec { bandwidth_bps: 1.0e4, base_latency_s: 0.0, jitter_s: 0.0 });
        let mut net: DelayNet<&'static str> = DelayNet::new(Arc::new(topo), 7, 0.0);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, "slow", 1500).unwrap(); // 150 ms
        a.send(1, "fast", 10).unwrap(); // 1 ms
        let first = b.recv_timeout(Duration::from_secs(2)).unwrap();
        let second = b.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(first.msg, "fast");
        assert_eq!(second.msg, "slow");
    }

    #[test]
    fn try_recv_nonblocking() {
        let mut topo = Topology::empty("t", 2);
        topo.connect(0, 1, fast_link());
        let mut net: DelayNet<u8> = DelayNet::new(Arc::new(topo), 7, 0.0);
        let _a = net.endpoint(0);
        let b = net.endpoint(1);
        assert!(b.try_recv().is_none());
    }

    #[test]
    fn jitter_is_reproducible_per_fabric_seed() {
        let jittery =
            LinkSpec { bandwidth_bps: 1.0e6, base_latency_s: 0.001, jitter_s: 0.004 };
        let delays = |seed: u64| -> Vec<f64> {
            let mut topo = Topology::empty("t", 2);
            topo.connect(0, 1, jittery);
            let mut net: DelayNet<u8> = DelayNet::new(Arc::new(topo), seed, 0.0);
            let a = net.endpoint(0);
            let b = net.endpoint(1);
            let ds: Vec<f64> = (0..4).map(|_| a.send(1, 0, 100).unwrap()).collect();
            // Drain so in-flight bookkeeping settles before the fabric
            // drops.
            for _ in 0..4 {
                let _ = b.recv_timeout(Duration::from_secs(2));
            }
            ds
        };
        let first = delays(7);
        assert_eq!(first, delays(7), "same seed, same jitter sequence");
        assert_ne!(first, delays(8), "different seed, different jitter");
    }

    #[test]
    fn concurrent_senders_preserve_fabric_invariants() {
        // The fabric's shared state — `seq: Arc<Mutex<u64>>` (global send
        // order) and `in_flight: Arc<AtomicUsize>` (contention signal) —
        // is hammered from many sender threads at once. This is the test
        // the CI ThreadSanitizer lane exercises: TSan sees every
        // interleaving's accesses; the assertions below check the
        // invariants that must survive them all:
        //   * seq ends exactly at the total number of sends (no lost or
        //     duplicated increments under the mutex), and
        //   * in_flight returns to 0 once every delivery has drained (every
        //     fetch_add has exactly one matching fetch_sub).
        const N: usize = 4;
        const PER_LINK: usize = 50;
        let mut topo = Topology::empty("t", N);
        for i in 0..N {
            for j in (i + 1)..N {
                topo.connect(i, j, fast_link());
            }
        }
        let mut net: DelayNet<usize> = DelayNet::new(Arc::new(topo), 7, 1.0);
        let endpoints: Vec<Endpoint<usize>> = (0..N).map(|i| net.endpoint(i)).collect();
        let seq = net.seq.clone();
        let in_flight = net.in_flight.clone();

        std::thread::scope(|scope| {
            for ep in endpoints {
                let in_flight = in_flight.clone();
                scope.spawn(move || {
                    // Interleave sends to every neighbor with drains of our
                    // own mailbox so mailbox channels never back up.
                    for round in 0..PER_LINK {
                        for to in 0..N {
                            if to != ep.id {
                                ep.send(to, round, 200).expect("send on full mesh");
                            }
                        }
                        while ep.try_recv().is_some() {}
                    }
                    // Drain the rest of our (N-1) * PER_LINK deliveries.
                    let mut got = 0usize;
                    let deadline = Instant::now() + Duration::from_secs(10);
                    while Instant::now() < deadline {
                        match ep.recv_timeout(Duration::from_millis(50)) {
                            Some(_) => got += 1,
                            None if in_flight.load(AtomicOrdering::Relaxed) == 0 => break,
                            None => {}
                        }
                    }
                    got
                });
            }
        });

        let total = (N * (N - 1) * PER_LINK) as u64;
        assert_eq!(*seq.lock().unwrap(), total, "every send took one seq slot");
        // Every accepted transfer was delivered (or the mailbox drained):
        // the contention counter must settle back to zero.
        let mut flight = usize::MAX;
        for _ in 0..100 {
            flight = in_flight.load(AtomicOrdering::Relaxed);
            if flight == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(flight, 0, "in-flight counter settles to zero");
    }

    #[test]
    fn contention_scales_delay_with_in_flight_transfers() {
        // 10 KB at 50 KB/s = 200 ms of serialization — a window wide
        // enough that the back-to-back sends below cannot be outrun by an
        // early delivery even on a heavily preempted CI runner. With
        // contention 1.0 and one transfer already in flight, the second
        // send sees half the bandwidth -> 400 ms; a third sees a third
        // -> 600 ms.
        let slow = LinkSpec { bandwidth_bps: 50.0e3, base_latency_s: 0.0, jitter_s: 0.0 };
        let mut topo = Topology::empty("t", 2);
        topo.connect(0, 1, slow);
        let mut net: DelayNet<u8> = DelayNet::new(Arc::new(topo), 7, 1.0);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let d1 = a.send(1, 0, 10_000).unwrap();
        let d2 = a.send(1, 1, 10_000).unwrap();
        let d3 = a.send(1, 2, 10_000).unwrap();
        assert!((d1 - 0.2).abs() < 1e-9, "first transfer uncontended: {d1}");
        assert!((d2 - 0.4).abs() < 1e-9, "second halves the bandwidth: {d2}");
        assert!((d3 - 0.6).abs() < 1e-9, "third divides it by three: {d3}");
        // After everything delivers, the medium frees up again.
        for _ in 0..3 {
            let _ = b.recv_timeout(Duration::from_secs(5));
        }
        // Delivery decrements may race the next send by a scheduler tick;
        // poll briefly for the medium to clear.
        let mut d4 = f64::MAX;
        for _ in 0..50 {
            std::thread::sleep(Duration::from_millis(2));
            d4 = a.send(1, 3, 10_000).unwrap();
            let _ = b.recv_timeout(Duration::from_secs(5));
            if (d4 - 0.2).abs() < 1e-9 {
                break;
            }
        }
        assert!((d4 - 0.2).abs() < 1e-9, "medium clears after delivery: {d4}");
    }
}
