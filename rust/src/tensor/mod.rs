//! Dense f32 tensors for the request path.
//!
//! Deliberately minimal: the heavy math lives in the AOT-compiled HLO; the
//! coordinator only moves feature tensors between queues, links, and the
//! runtime. Kept free of `xla` types so coordinator tests never need PJRT
//! (the Literal conversions live in `runtime::xla_engine`).

use std::fmt;

/// A dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match {} elements",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Serialized size on a simulated link (f32 payload).
    pub fn wire_bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Index of the largest element (class prediction from a probs vector).
    pub fn argmax(&self) -> usize {
        let mut best = 0;
        for (i, &v) in self.data.iter().enumerate() {
            if v > self.data[best] {
                best = i;
            }
        }
        best
    }

    /// Largest element — the paper's confidence level C_k(d), eq. (2).
    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshaped(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.wire_bytes(), 24);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn zeros_and_scalar() {
        let z = Tensor::zeros(vec![4, 4, 3]);
        assert_eq!(z.numel(), 48);
        assert!(z.data().iter().all(|&v| v == 0.0));
        assert_eq!(Tensor::scalar(2.5).data(), &[2.5]);
    }

    #[test]
    fn argmax_and_max() {
        let t = Tensor::new(vec![5], vec![0.1, 0.7, 0.05, 0.1, 0.05]);
        assert_eq!(t.argmax(), 1);
        assert!((t.max() - 0.7).abs() < 1e-9);
    }

    #[test]
    fn argmax_ties_take_first() {
        let t = Tensor::new(vec![3], vec![0.5, 0.5, 0.2]);
        assert_eq!(t.argmax(), 0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).reshaped(vec![4]);
        assert_eq!(t.shape(), &[4]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0]);
    }
}
