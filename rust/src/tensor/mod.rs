//! Dense f32 tensors for the request path.
//!
//! Deliberately minimal: the heavy math lives in the AOT-compiled HLO; the
//! coordinator only moves feature tensors between queues, links, and the
//! runtime. Kept free of `xla` types so coordinator tests never need PJRT
//! (the Literal conversions live in `runtime::xla_engine`).
//!
//! # Buffer aliasing (the zero-copy contract)
//!
//! A [`Tensor`] is an *offset/len view* over a shared, immutable
//! [`TensorBuf`] (an `Arc<Vec<f32>>`). Cloning a tensor bumps a refcount;
//! it never copies activation data. This is what lets the coordinator
//! enqueue, offload, re-home, and relay tasks — and let `net::Envelope`
//! encode/decode — without materializing payload bytes per hop:
//!
//! * many tensors may alias one buffer (e.g. every view decoded from one
//!   received wire allocation, or every image view over the dataset's
//!   pre-dequantized store);
//! * buffers are write-once: mutation goes through [`Tensor::data_mut`],
//!   which copies-on-write iff the buffer is shared or the view is
//!   partial, so aliasing views can never observe each other's writes;
//! * code outside `tensor/`, `runtime/`, and `net/` must not materialize
//!   payloads (`into_data()`, `.data().to_vec()`) — the `wire-charge`
//!   xtask rule flags reintroduced copies on the task path.

use std::fmt;
use std::sync::Arc;

/// A shared, immutable f32 buffer. Cheap to clone (refcount bump); many
/// [`Tensor`] views may alias one buffer.
#[derive(Clone)]
pub struct TensorBuf {
    data: Arc<Vec<f32>>,
}

impl TensorBuf {
    pub fn from_vec(data: Vec<f32>) -> TensorBuf {
        TensorBuf { data: Arc::new(data) }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Do `a` and `b` share one allocation?
    pub fn ptr_eq(a: &TensorBuf, b: &TensorBuf) -> bool {
        Arc::ptr_eq(&a.data, &b.data)
    }
}

impl fmt::Debug for TensorBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TensorBuf[{} elems, rc={}]", self.data.len(), Arc::strong_count(&self.data))
    }
}

/// A dense row-major f32 tensor: a shaped offset/len view over a shared
/// [`TensorBuf`]. `Clone` is a refcount bump, never a data copy.
#[derive(Clone)]
pub struct Tensor {
    shape: Vec<usize>,
    buf: TensorBuf,
    offset: usize,
    len: usize,
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match {} elements",
            data.len()
        );
        let len = data.len();
        Tensor { shape, buf: TensorBuf::from_vec(data), offset: 0, len }
    }

    /// A view of `buf[offset..offset + shape.product()]` — no copy.
    pub fn view(buf: TensorBuf, offset: usize, shape: Vec<usize>) -> Tensor {
        let len = shape.iter().product::<usize>();
        assert!(
            offset + len <= buf.len(),
            "view [{offset}, {offset}+{len}) out of buffer ({} elems)",
            buf.len()
        );
        Tensor { shape, buf, offset, len }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, buf: TensorBuf::from_vec(vec![0.0; n]), offset: 0, len: n }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], buf: TensorBuf::from_vec(vec![v]), offset: 0, len: 1 }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.len
    }

    /// Serialized size on a simulated link (f32 payload).
    pub fn wire_bytes(&self) -> usize {
        self.len * 4
    }

    pub fn data(&self) -> &[f32] {
        &self.buf.as_slice()[self.offset..self.offset + self.len]
    }

    /// The backing buffer this view aliases (refcount bump to clone).
    pub fn buf(&self) -> &TensorBuf {
        &self.buf
    }

    /// Does this tensor alias the same allocation as `other`?
    pub fn aliases(&self, other: &Tensor) -> bool {
        TensorBuf::ptr_eq(&self.buf, &other.buf)
    }

    /// Mutable access, copy-on-write: if the backing buffer is shared (or
    /// this view covers only part of it), the view's elements are first
    /// copied into a fresh exclusive buffer so aliasing views never observe
    /// the writes.
    pub fn data_mut(&mut self) -> &mut [f32] {
        let exclusive = self.offset == 0
            && self.len == self.buf.len()
            && Arc::strong_count(&self.buf.data) == 1;
        if !exclusive {
            let owned: Vec<f32> = self.data().to_vec();
            self.buf = TensorBuf::from_vec(owned);
            self.offset = 0;
        }
        let data = Arc::get_mut(&mut self.buf.data)
            .expect("buffer is exclusive after copy-on-write");
        &mut data[..]
    }

    /// Extract the element data, copying only if the buffer is shared or
    /// the view is partial.
    pub fn into_data(self) -> Vec<f32> {
        if self.offset == 0 && self.len == self.buf.len() {
            match Arc::try_unwrap(self.buf.data) {
                Ok(v) => v,
                Err(arc) => arc.as_slice().to_vec(),
            }
        } else {
            self.data().to_vec()
        }
    }

    /// Index of the largest element (class prediction from a probs vector).
    pub fn argmax(&self) -> usize {
        let data = self.data();
        let mut best = 0;
        for (i, &v) in data.iter().enumerate() {
            if v > data[best] {
                best = i;
            }
        }
        best
    }

    /// Largest element — the paper's confidence level C_k(d), eq. (2).
    pub fn max(&self) -> f32 {
        self.data().iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshaped(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.len);
        self.shape = shape;
        self
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.wire_bytes(), 24);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn zeros_and_scalar() {
        let z = Tensor::zeros(vec![4, 4, 3]);
        assert_eq!(z.numel(), 48);
        assert!(z.data().iter().all(|&v| v == 0.0));
        assert_eq!(Tensor::scalar(2.5).data(), &[2.5]);
    }

    #[test]
    fn argmax_and_max() {
        let t = Tensor::new(vec![5], vec![0.1, 0.7, 0.05, 0.1, 0.05]);
        assert_eq!(t.argmax(), 1);
        assert!((t.max() - 0.7).abs() < 1e-9);
    }

    #[test]
    fn argmax_ties_take_first() {
        let t = Tensor::new(vec![3], vec![0.5, 0.5, 0.2]);
        assert_eq!(t.argmax(), 0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).reshaped(vec![4]);
        assert_eq!(t.shape(), &[4]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn clone_aliases_same_buffer() {
        let t = Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]);
        let c = t.clone();
        assert!(t.aliases(&c), "clone must share the allocation");
        assert_eq!(t, c);
    }

    #[test]
    fn views_share_one_buffer() {
        let buf = TensorBuf::from_vec(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let a = Tensor::view(buf.clone(), 0, vec![3]);
        let b = Tensor::view(buf.clone(), 3, vec![3]);
        assert_eq!(a.data(), &[0.0, 1.0, 2.0]);
        assert_eq!(b.data(), &[3.0, 4.0, 5.0]);
        assert!(a.aliases(&b));
        assert_eq!(a.wire_bytes(), 12);
    }

    #[test]
    #[should_panic(expected = "out of buffer")]
    fn view_out_of_range_panics() {
        let buf = TensorBuf::from_vec(vec![0.0; 4]);
        Tensor::view(buf, 2, vec![3]);
    }

    #[test]
    fn data_mut_copies_on_write_when_shared() {
        let t = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let mut c = t.clone();
        c.data_mut()[0] = 9.0;
        assert_eq!(t.data(), &[1.0, 2.0, 3.0], "alias must not see the write");
        assert_eq!(c.data(), &[9.0, 2.0, 3.0]);
        assert!(!t.aliases(&c), "write must have detached the buffer");
    }

    #[test]
    fn data_mut_in_place_when_exclusive() {
        let mut t = Tensor::new(vec![2], vec![1.0, 2.0]);
        t.data_mut()[1] = 7.0;
        assert_eq!(t.data(), &[1.0, 7.0]);
    }

    #[test]
    fn into_data_on_partial_view_copies_view_only() {
        let buf = TensorBuf::from_vec(vec![0.0, 1.0, 2.0, 3.0]);
        let v = Tensor::view(buf, 1, vec![2]);
        assert_eq!(v.into_data(), vec![1.0, 2.0]);
    }
}
