//! Command-line argument parsing (clap substitute — offline image).
//!
//! Flag grammar: `--key value`, `--key=value`, boolean `--flag`, plus
//! positional arguments. Subcommand dispatch lives in `main.rs`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments: positionals + flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding `argv[0]`).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` ends flag parsing
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else if let Some(v) =
                    it.next_if(|n| !n.starts_with("--"))
                {
                    out.flags.insert(body.to_string(), v);
                    out.present.push(body.to_string());
                } else {
                    // boolean flag
                    out.flags.insert(body.to_string(), "true".to_string());
                    out.present.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(String::as_str).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: bad number {v:?}")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: bad integer {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: bad integer {v:?}")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => match v.as_str() {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                other => bail!("--{key}: bad bool {other:?}"),
            },
        }
    }

    /// First positional (subcommand) if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }

    /// Reject unknown flags (call after reading all expected ones).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in &self.present {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["run", "--model", "resnetl", "--use-ae", "--rate=25.5"]);
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.str_or("model", ""), "resnetl");
        assert!(a.bool_or("use-ae", false).unwrap());
        assert!((a.f64_or("rate", 0.0).unwrap() - 25.5).abs() < 1e-12);
        assert_eq!(a.usize_or("missing", 3).unwrap(), 3);
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = parse(&["--verbose", "--out", "x.json"]);
        assert!(a.bool_or("verbose", false).unwrap());
        assert_eq!(a.str_or("out", ""), "x.json");
    }

    #[test]
    fn double_dash_ends_flags() {
        let a = parse(&["--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.usize_or("n", 0).is_err());
        assert!(a.bool_or("n", false).is_err());
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = parse(&["--weird", "1"]);
        assert!(a.ensure_known(&["model"]).is_err());
        assert!(a.ensure_known(&["weird"]).is_ok());
    }
}
