//! Loaders for the binary artifacts the Python AOT pipeline ships:
//!
//! * `dataset.bin` — the held-out test set the source worker admits
//!   (quantized images + labels + per-sample difficulty),
//! * `exits_*.bin` — the per-sample, per-exit oracle table (confidence and
//!   prediction at every exit point), used by `runtime::SimEngine` to replay
//!   the *exact* trained-model exit behaviour without paying XLA compute in
//!   the figure benches.
//!
//! Formats are defined in `python/compile/data.py` / `aot.py`; magics and
//! layouts must stay in sync.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{Tensor, TensorBuf};

pub const DATASET_MAGIC: u32 = 0x4D44_4945; // "MDIE"
pub const EXITS_MAGIC: u32 = 0x4D44_4958; // "MDIX"

/// The held-out labelled image set.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Dequantized pixels, n*h*w*c, row-major, shared: `image(i)` hands
    /// out zero-copy views into this one buffer, so admission never
    /// allocates or copies per task.
    features: TensorBuf,
    pub labels: Vec<u8>,
    pub difficulty: Vec<f32>,
}

/// Invert `python/compile/data.py::quantize_u8` exactly: x = q/255 * 8 - 4.
fn dequantize(pixels: &[u8]) -> TensorBuf {
    TensorBuf::from_vec(pixels.iter().map(|&q| q as f32 / 255.0 * 8.0 - 4.0).collect())
}

fn read_u32s(buf: &[u8], n: usize) -> Result<Vec<u32>> {
    if buf.len() < n * 4 {
        bail!("truncated header");
    }
    Ok((0..n)
        .map(|i| u32::from_le_bytes(buf[i * 4..i * 4 + 4].try_into().unwrap()))
        .collect())
}

impl Dataset {
    pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
        let path = path.as_ref();
        let buf = std::fs::read(path)
            .with_context(|| format!("reading dataset {}", path.display()))?;
        let hdr = read_u32s(&buf, 6)?;
        if hdr[0] != DATASET_MAGIC {
            bail!("bad dataset magic {:#x}", hdr[0]);
        }
        if hdr[1] != 1 {
            bail!("unsupported dataset version {}", hdr[1]);
        }
        let (n, h, w, c) = (hdr[2] as usize, hdr[3] as usize, hdr[4] as usize, hdr[5] as usize);
        let px = n * h * w * c;
        let expect = 24 + px + n + n * 4;
        if buf.len() != expect {
            bail!("dataset size {} != expected {}", buf.len(), expect);
        }
        let features = dequantize(&buf[24..24 + px]);
        let labels = buf[24 + px..24 + px + n].to_vec();
        let difficulty = buf[24 + px + n..]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Ok(Dataset { n, h, w, c, features, labels, difficulty })
    }

    /// Image `i` as the f32 tensor the stage-1 HLO expects: a zero-copy
    /// view into the dataset's shared, pre-dequantized feature buffer.
    pub fn image(&self, i: usize) -> Tensor {
        assert!(i < self.n, "image index {i} out of range {}", self.n);
        let sz = self.h * self.w * self.c;
        Tensor::view(self.features.clone(), i * sz, vec![self.h, self.w, self.c])
    }

    pub fn label(&self, i: usize) -> u8 {
        self.labels[i]
    }

    /// Build an in-memory dataset (tests / synthetic workloads): `n`
    /// labelled `h`×`w`×`c` images with deterministic pixel fill.
    pub fn synthetic(n: usize, h: usize, w: usize, c: usize, labels: Vec<u8>) -> Dataset {
        assert_eq!(labels.len(), n);
        let pixels: Vec<u8> = (0..n * h * w * c).map(|i| (i % 256) as u8).collect();
        let difficulty = (0..n).map(|i| i as f32 / n.max(1) as f32).collect();
        Dataset { n, h, w, c, features: dequantize(&pixels), labels, difficulty }
    }
}

/// Per-sample, per-exit oracle table: what the trained model would produce
/// at every exit point for every test sample.
#[derive(Debug, Clone)]
pub struct ExitTable {
    pub n: usize,
    pub num_exits: usize,
    conf: Vec<f32>,
    pred: Vec<u8>,
}

impl ExitTable {
    pub fn load(path: impl AsRef<Path>) -> Result<ExitTable> {
        let path = path.as_ref();
        let buf = std::fs::read(path)
            .with_context(|| format!("reading exit table {}", path.display()))?;
        let hdr = read_u32s(&buf, 4)?;
        if hdr[0] != EXITS_MAGIC {
            bail!("bad exits magic {:#x}", hdr[0]);
        }
        if hdr[1] != 1 {
            bail!("unsupported exits version {}", hdr[1]);
        }
        let (n, k) = (hdr[2] as usize, hdr[3] as usize);
        let expect = 16 + n * k * 4 + n * k;
        if buf.len() != expect {
            bail!("exits size {} != expected {}", buf.len(), expect);
        }
        let conf = buf[16..16 + n * k * 4]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        let pred = buf[16 + n * k * 4..].to_vec();
        Ok(ExitTable { n, num_exits: k, conf, pred })
    }

    /// Confidence C_k(d) the trained model reports at exit k (0-based) for
    /// sample `i`.
    pub fn confidence(&self, i: usize, k: usize) -> f32 {
        self.conf[i * self.num_exits + k]
    }

    /// Class prediction at exit k (0-based) for sample `i`.
    pub fn prediction(&self, i: usize, k: usize) -> u8 {
        self.pred[i * self.num_exits + k]
    }

    /// Build an in-memory table (tests / synthetic setups).
    pub fn synthetic(n: usize, num_exits: usize, conf: Vec<f32>, pred: Vec<u8>) -> ExitTable {
        assert_eq!(conf.len(), n * num_exits);
        assert_eq!(pred.len(), n * num_exits);
        ExitTable { n, num_exits, conf, pred }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mdi-ds-{}-{}", std::process::id(), name))
    }

    fn write_dataset(path: &Path, n: usize, h: usize, w: usize, c: usize) {
        let mut f = std::fs::File::create(path).unwrap();
        for v in [DATASET_MAGIC, 1, n as u32, h as u32, w as u32, c as u32] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        let px: Vec<u8> = (0..n * h * w * c).map(|i| (i % 256) as u8).collect();
        f.write_all(&px).unwrap();
        let labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
        f.write_all(&labels).unwrap();
        for i in 0..n {
            f.write_all(&(i as f32 / n as f32).to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn dataset_roundtrip_and_dequantize() {
        let p = tmpfile("ok.bin");
        write_dataset(&p, 4, 2, 2, 3);
        let ds = Dataset::load(&p).unwrap();
        assert_eq!((ds.n, ds.h, ds.w, ds.c), (4, 2, 2, 3));
        assert_eq!(ds.label(3), 3);
        let img = ds.image(0);
        assert_eq!(img.shape(), &[2, 2, 3]);
        // pixel value 0 -> -4.0; pixel 255 -> +4.0
        assert!((img.data()[0] - (-4.0)).abs() < 1e-6);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn images_are_views_over_one_shared_buffer() {
        let ds = Dataset::synthetic(4, 2, 2, 3, vec![0, 1, 2, 3]);
        let a = ds.image(0);
        let b = ds.image(3);
        assert!(a.aliases(&b), "images must alias the dataset store");
        assert_eq!(a.numel(), 12);
        // pixel value 0 -> -4.0 under the exact dequantize transform
        assert!((a.data()[0] - (-4.0)).abs() < 1e-6);
    }

    #[test]
    fn dataset_rejects_bad_magic_and_truncation() {
        let p = tmpfile("bad.bin");
        std::fs::write(&p, [0u8; 24]).unwrap();
        assert!(Dataset::load(&p).is_err());
        write_dataset(&p, 4, 2, 2, 3);
        let mut buf = std::fs::read(&p).unwrap();
        buf.truncate(buf.len() - 1);
        std::fs::write(&p, &buf).unwrap();
        assert!(Dataset::load(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn exit_table_roundtrip() {
        let p = tmpfile("exits.bin");
        let (n, k) = (3, 2);
        let mut f = std::fs::File::create(&p).unwrap();
        for v in [EXITS_MAGIC, 1, n as u32, k as u32] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        let confs = [0.5f32, 0.9, 0.4, 0.8, 0.3, 0.7];
        for c in confs {
            f.write_all(&c.to_le_bytes()).unwrap();
        }
        f.write_all(&[1u8, 1, 2, 3, 4, 4]).unwrap();
        drop(f);
        let t = ExitTable::load(&p).unwrap();
        assert_eq!((t.n, t.num_exits), (3, 2));
        assert!((t.confidence(1, 1) - 0.8).abs() < 1e-6);
        assert_eq!(t.prediction(2, 0), 4);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn synthetic_table() {
        let t = ExitTable::synthetic(2, 2, vec![0.1, 0.2, 0.3, 0.4], vec![0, 1, 2, 3]);
        assert!((t.confidence(1, 0) - 0.3).abs() < 1e-6);
        assert_eq!(t.prediction(0, 1), 1);
    }
}
