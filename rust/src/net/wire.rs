//! The physical envelope codec: zero-copy serialize / one-allocation
//! deserialize for [`Envelope`].
//!
//! Two size notions coexist in `net/` and must not be confused:
//!
//! * [`Envelope::encoded_bytes`] is the *charging contract* — the paper's
//!   metadata-driven cost model (`stage_in_bytes` / AE `code_bytes` per
//!   item plus shared framing). It is what both drivers bill the medium
//!   and what every counter records, and it is independent of how — or
//!   whether — an envelope is ever rendered to physical bytes.
//! * [`WireFrame`] is the *physical layout* — what would actually cross a
//!   socket. Its length ([`WireFrame::byte_len`]) tracks the real f32
//!   payload, which the simulation deliberately abstracts away.
//!
//! Changing this codec can therefore never change a simulated byte charge.
//!
//! ## Zero-copy discipline
//!
//! [`encode`] builds a [`WireFrame`]: a fixed 32-byte stack header, a
//! small item-metadata vector, and a list of payload *segments* that are
//! refcount-clones of the tasks' shared [`TensorBuf`]s
//! (`crate::tensor`) — activation data is never copied to stage a send.
//! [`WireFrame::to_bytes`] is the single place payload bytes are
//! materialized (the physical transmit). [`decode`] parses the header and
//! metadata, gathers *all* payload floats into ONE allocation, and hands
//! every reconstructed task a [`Tensor::view`] into that one buffer — a
//! k-task batch costs one allocation on receive, not k.
//!
//! The receiver-local `NeighborSummary::d_nm_s` field never travels the
//! wire (see `policy::summary`); decoded summaries carry `0.0` until the
//! receiver's estimator fills it, exactly like every other gossip arrival.

use crate::coordinator::task::{InferenceResult, Task};
use crate::policy::{NeighborSummary, RegionLoad};
use crate::tensor::{Tensor, TensorBuf};

use super::{Envelope, ENVELOPE_HEADER_BYTES};

/// Leading magic of every physical frame ("MW" little-endian).
const WIRE_MAGIC: u16 = 0x574D;
/// Physical layout version.
const WIRE_VERSION: u8 = 1;

/// Header flag: a piggybacked gossip summary trails the item metadata.
const FLAG_PIGGYBACK: u8 = 0x80;
const KIND_TASKS: u8 = 0;
const KIND_RESULTS: u8 = 1;
const KIND_REHOME: u8 = 2;
const KIND_STATE: u8 = 3;
const KIND_MASK: u8 = 0x0f;

/// Physical-codec failure: every malformed input is an error, never a
/// panic (`net/` sits inside the panic budget — see rust/CONTRACTS.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// Input ended before the structure it promised.
    Truncated,
    /// Leading magic was not a wire frame.
    BadMagic,
    /// Unknown layout version.
    BadVersion(u8),
    /// Unknown envelope kind tag.
    BadKind(u8),
    /// Structurally invalid frame (reason names the field).
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "wire frame truncated"),
            WireError::BadMagic => write!(f, "bad wire magic"),
            WireError::BadVersion(v) => write!(f, "unsupported wire version {v}"),
            WireError::BadKind(k) => write!(f, "unknown envelope kind {k}"),
            WireError::Malformed(what) => write!(f, "malformed wire frame: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

/// A staged, zero-copy physical frame: stack header + item metadata +
/// refcounted payload segments. Build with [`encode`]; materialize with
/// [`WireFrame::to_bytes`].
#[derive(Debug)]
pub struct WireFrame {
    header: [u8; ENVELOPE_HEADER_BYTES],
    meta: Vec<u8>,
    /// Payload tensors in item order — refcount clones aliasing the
    /// senders' buffers, never copies.
    segments: Vec<Tensor>,
    payload_elems: usize,
}

impl WireFrame {
    /// Physical length of the serialized frame in bytes.
    pub fn byte_len(&self) -> usize {
        ENVELOPE_HEADER_BYTES + self.meta.len() + self.payload_elems * 4
    }

    /// The payload segments this frame borrows (diagnostics/tests: each
    /// aliases its source tensor's buffer).
    pub fn segments(&self) -> &[Tensor] {
        &self.segments
    }

    /// Materialize the frame for a physical medium — the one place
    /// payload floats are rendered to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.byte_len());
        out.extend_from_slice(&self.header);
        out.extend_from_slice(&self.meta);
        for seg in &self.segments {
            for v in seg.data() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Writers (little-endian throughout)

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_task(meta: &mut Vec<u8>, segments: &mut Vec<Tensor>, t: &Task) -> Result<(), WireError> {
    put_u64(meta, t.id);
    put_u64(meta, t.sample as u64);
    put_u32(meta, u32::try_from(t.stage).map_err(|_| WireError::Malformed("stage"))?);
    put_u32(meta, u32::try_from(t.source).map_err(|_| WireError::Malformed("source"))?);
    put_f64(meta, t.admitted_at);
    put_f64(meta, t.deadline);
    put_u32(meta, t.hops);
    meta.push(t.class);
    meta.push(t.encoded as u8);
    match &t.features {
        Some(f) => {
            let ndims =
                u8::try_from(f.shape().len()).map_err(|_| WireError::Malformed("ndims"))?;
            meta.push(1);
            meta.push(ndims);
            for &d in f.shape() {
                put_u32(meta, u32::try_from(d).map_err(|_| WireError::Malformed("dim"))?);
            }
            segments.push(f.clone()); // refcount bump — the zero-copy borrow
        }
        None => {
            meta.push(0);
            meta.push(0);
        }
    }
    Ok(())
}

fn put_result(meta: &mut Vec<u8>, r: &InferenceResult) -> Result<(), WireError> {
    put_u64(meta, r.sample as u64);
    put_u32(meta, u32::try_from(r.exit_point).map_err(|_| WireError::Malformed("exit_point"))?);
    put_u32(meta, u32::try_from(r.exited_on).map_err(|_| WireError::Malformed("exited_on"))?);
    put_u32(meta, u32::try_from(r.source).map_err(|_| WireError::Malformed("source"))?);
    meta.push(r.prediction);
    meta.push(r.class);
    put_u16(meta, 0); // pad
    put_f32(meta, r.confidence);
    put_f64(meta, r.admitted_at);
    put_f64(meta, r.deadline);
    Ok(())
}

fn put_summary(meta: &mut Vec<u8>, s: &NeighborSummary) -> Result<(), WireError> {
    // d_nm_s is receiver-local by contract and deliberately absent.
    put_u64(meta, s.input_len as u64);
    put_f64(meta, s.gamma_s);
    put_f32(meta, s.t_e);
    let n_class =
        u16::try_from(s.per_class_input.len()).map_err(|_| WireError::Malformed("classes"))?;
    let n_region = u16::try_from(s.region.len()).map_err(|_| WireError::Malformed("region"))?;
    put_u16(meta, n_class);
    put_u16(meta, n_region);
    meta.push(s.min_slack_s.is_some() as u8);
    meta.push(s.beat.is_some() as u8);
    for &c in &s.per_class_input {
        put_u32(meta, c);
    }
    if let Some(slack) = s.min_slack_s {
        put_f64(meta, slack);
    }
    if let Some(beat) = s.beat {
        put_u64(meta, beat);
    }
    for r in &s.region {
        put_u32(meta, u32::try_from(r.node).map_err(|_| WireError::Malformed("region node"))?);
        put_u32(
            meta,
            u32::try_from(r.input_len).map_err(|_| WireError::Malformed("region load"))?,
        );
        meta.push(r.hops);
    }
    Ok(())
}

/// Stage `env` for the wire: headers and metadata are written out, payload
/// tensors are *borrowed* (refcount clones) — no activation data moves.
pub fn encode(env: &Envelope) -> Result<WireFrame, WireError> {
    let (kind, flags, payload, summary) = match env {
        Envelope::TaskBatch(_) => (KIND_TASKS, 0u8, env, None),
        Envelope::Result(_) => (KIND_RESULTS, 0, env, None),
        Envelope::Rehome(_) => (KIND_REHOME, 0, env, None),
        Envelope::State(s) => (KIND_STATE, 0, env, Some(s)),
        Envelope::Piggybacked(inner, s) => {
            let kind = match inner.as_ref() {
                Envelope::TaskBatch(_) => KIND_TASKS,
                Envelope::Result(_) => KIND_RESULTS,
                Envelope::Rehome(_) => KIND_REHOME,
                // Never nested / never wrapping gossip, by contract.
                Envelope::State(_) | Envelope::Piggybacked(..) => {
                    return Err(WireError::Malformed("piggyback wraps a payload envelope"))
                }
            };
            (kind, FLAG_PIGGYBACK, inner.as_ref(), Some(s))
        }
    };

    let mut meta = Vec::new();
    let mut segments = Vec::new();
    let items: u32 = match payload {
        Envelope::TaskBatch(ts) | Envelope::Rehome(ts) => {
            for t in ts {
                put_task(&mut meta, &mut segments, t)?;
            }
            u32::try_from(ts.len()).map_err(|_| WireError::Malformed("items"))?
        }
        Envelope::Result(rs) => {
            for r in rs {
                put_result(&mut meta, r)?;
            }
            u32::try_from(rs.len()).map_err(|_| WireError::Malformed("items"))?
        }
        Envelope::State(_) => 1,
        // `payload` above is never `Piggybacked` (matched out), but the
        // compiler cannot see that; treat it as malformed rather than
        // panic.
        Envelope::Piggybacked(..) => return Err(WireError::Malformed("nested piggyback")),
    };
    if let Some(s) = summary {
        put_summary(&mut meta, s)?;
    }

    let payload_elems: usize = segments.iter().map(|t| t.numel()).sum();
    let mut header = [0u8; ENVELOPE_HEADER_BYTES];
    header[0..2].copy_from_slice(&WIRE_MAGIC.to_le_bytes());
    header[2] = WIRE_VERSION;
    header[3] = kind | flags;
    header[4..8].copy_from_slice(&items.to_le_bytes());
    header[8..12].copy_from_slice(
        &u32::try_from(payload_elems).map_err(|_| WireError::Malformed("payload"))?.to_le_bytes(),
    );
    // bytes 12..32 reserved (routing ids live here on a real medium)
    Ok(WireFrame { header, meta, segments, payload_elems })
}

// ---------------------------------------------------------------------------
// Reader

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated)?;
        let s = self.buf.get(self.pos..end).ok_or(WireError::Truncated)?;
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        b.try_into().map(u16::from_le_bytes).map_err(|_| WireError::Truncated)
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        b.try_into().map(u32::from_le_bytes).map_err(|_| WireError::Truncated)
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        b.try_into().map(u64::from_le_bytes).map_err(|_| WireError::Truncated)
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        let b = self.take(4)?;
        b.try_into().map(f32::from_le_bytes).map_err(|_| WireError::Truncated)
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        let b = self.take(8)?;
        b.try_into().map(f64::from_le_bytes).map_err(|_| WireError::Truncated)
    }

    fn remaining(&self) -> usize {
        self.buf.len().saturating_sub(self.pos)
    }
}

/// Task metadata plus the pending view description (shape + element
/// count) to be resolved once the shared payload buffer exists.
struct TaskMeta {
    task: Task,
    shape: Option<Vec<usize>>,
}

fn get_task(r: &mut Reader<'_>) -> Result<TaskMeta, WireError> {
    let id = r.u64()?;
    let sample = r.u64()? as usize;
    let stage = r.u32()? as usize;
    let source = r.u32()? as usize;
    let admitted_at = r.f64()?;
    let deadline = r.f64()?;
    let hops = r.u32()?;
    let class = r.u8()?;
    let encoded = r.u8()? != 0;
    let has_features = r.u8()? != 0;
    let ndims = r.u8()? as usize;
    let shape = if has_features {
        let mut shape = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            shape.push(r.u32()? as usize);
        }
        Some(shape)
    } else {
        if ndims != 0 {
            return Err(WireError::Malformed("dims without features"));
        }
        None
    };
    if stage == 0 {
        return Err(WireError::Malformed("stage is 1-based"));
    }
    let task = Task {
        id,
        sample,
        stage,
        source,
        features: None, // view attached after the payload buffer is read
        encoded,
        admitted_at,
        hops,
        class,
        deadline,
    };
    Ok(TaskMeta { task, shape })
}

fn get_result(r: &mut Reader<'_>) -> Result<InferenceResult, WireError> {
    let sample = r.u64()? as usize;
    let exit_point = r.u32()? as usize;
    let exited_on = r.u32()? as usize;
    let source = r.u32()? as usize;
    let prediction = r.u8()?;
    let class = r.u8()?;
    let _pad = r.u16()?;
    let confidence = r.f32()?;
    let admitted_at = r.f64()?;
    let deadline = r.f64()?;
    Ok(InferenceResult {
        sample,
        exit_point,
        prediction,
        confidence,
        admitted_at,
        deadline,
        exited_on,
        source,
        class,
    })
}

fn get_summary(r: &mut Reader<'_>) -> Result<NeighborSummary, WireError> {
    let input_len = r.u64()? as usize;
    let gamma_s = r.f64()?;
    let t_e = r.f32()?;
    let n_class = r.u16()? as usize;
    let n_region = r.u16()? as usize;
    let has_slack = r.u8()? != 0;
    let has_beat = r.u8()? != 0;
    let mut per_class_input = Vec::with_capacity(n_class.min(1024));
    for _ in 0..n_class {
        per_class_input.push(r.u32()?);
    }
    let min_slack_s = if has_slack { Some(r.f64()?) } else { None };
    let beat = if has_beat { Some(r.u64()?) } else { None };
    let mut region = Vec::with_capacity(n_region.min(1024));
    for _ in 0..n_region {
        let node = r.u32()? as usize;
        let load = r.u32()? as usize;
        let hops = r.u8()?;
        region.push(RegionLoad { node, input_len: load, hops });
    }
    Ok(NeighborSummary {
        input_len,
        gamma_s,
        t_e,
        d_nm_s: 0.0, // receiver-local; the estimator fills it on arrival
        per_class_input,
        min_slack_s,
        region,
        beat,
    })
}

/// Attach payload views to the decoded tasks: every task with features
/// gets a [`Tensor::view`] into the ONE shared buffer, in item order.
fn attach_views(metas: Vec<TaskMeta>, buf: &TensorBuf) -> Result<Vec<Task>, WireError> {
    let mut tasks = Vec::with_capacity(metas.len());
    let mut offset = 0usize;
    for m in metas {
        let mut task = m.task;
        if let Some(shape) = m.shape {
            let len: usize = shape.iter().product();
            let end = offset.checked_add(len).ok_or(WireError::Malformed("payload overflow"))?;
            if end > buf.len() {
                return Err(WireError::Malformed("payload shorter than views"));
            }
            task.features = Some(Tensor::view(buf.clone(), offset, shape));
            offset = end;
        }
        tasks.push(task);
    }
    if offset != buf.len() {
        return Err(WireError::Malformed("payload longer than views"));
    }
    Ok(tasks)
}

/// Decode a physical frame. All payload floats land in ONE allocation;
/// every reconstructed feature tensor is a view into it.
pub fn decode(bytes: &[u8]) -> Result<Envelope, WireError> {
    let mut r = Reader::new(bytes);
    let header = r.take(ENVELOPE_HEADER_BYTES)?;
    let magic = u16::from_le_bytes([header[0], header[1]]);
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic);
    }
    if header[2] != WIRE_VERSION {
        return Err(WireError::BadVersion(header[2]));
    }
    let kind = header[3] & KIND_MASK;
    let piggyback = header[3] & FLAG_PIGGYBACK != 0;
    let items = u32::from_le_bytes([header[4], header[5], header[6], header[7]]) as usize;
    let payload_elems =
        u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;

    let payload = match kind {
        KIND_TASKS | KIND_REHOME => {
            // Capacity is a hint, clamped: a corrupt count must not
            // reserve unbounded memory before parsing fails.
            let mut metas = Vec::with_capacity(items.min(1024));
            for _ in 0..items {
                metas.push(get_task(&mut r)?);
            }
            let summary = if piggyback { Some(get_summary(&mut r)?) } else { None };
            // ONE allocation for the whole batch's activations.
            if r.remaining() != payload_elems * 4 {
                return Err(WireError::Truncated);
            }
            let mut data = Vec::with_capacity(payload_elems);
            for _ in 0..payload_elems {
                data.push(r.f32()?);
            }
            let buf = TensorBuf::from_vec(data);
            let tasks = attach_views(metas, &buf)?;
            let inner = if kind == KIND_TASKS {
                Envelope::TaskBatch(tasks)
            } else {
                Envelope::Rehome(tasks)
            };
            return Ok(match summary {
                Some(s) => Envelope::Piggybacked(Box::new(inner), s),
                None => inner,
            });
        }
        KIND_RESULTS => {
            let mut rs = Vec::with_capacity(items.min(1024));
            for _ in 0..items {
                rs.push(get_result(&mut r)?);
            }
            let summary = if piggyback { Some(get_summary(&mut r)?) } else { None };
            let inner = Envelope::Result(rs);
            match summary {
                Some(s) => Envelope::Piggybacked(Box::new(inner), s),
                None => inner,
            }
        }
        KIND_STATE => {
            if piggyback {
                return Err(WireError::Malformed("gossip cannot piggyback on gossip"));
            }
            Envelope::State(get_summary(&mut r)?)
        }
        k => return Err(WireError::BadKind(k)),
    };
    if payload_elems != 0 || r.remaining() != 0 {
        return Err(WireError::Malformed("trailing bytes"));
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::ModelMeta;
    use crate::util::rng::{streams, Pcg64};

    fn meta2() -> ModelMeta {
        ModelMeta::synthetic(vec![0.002, 0.003], vec![12288, 8192])
    }

    fn task(id: u64, stage: usize, features: Option<Tensor>) -> Task {
        Task {
            stage,
            class: (id % 3) as u8,
            deadline: if id % 2 == 0 { f64::INFINITY } else { 1.5 + id as f64 },
            hops: id as u32 % 4,
            source: (id % 5) as usize,
            encoded: false,
            ..Task::initial(id, id as usize * 7, features, 0.125 * id as f64)
        }
    }

    fn tensor(rng: &mut Pcg64, n: usize) -> Tensor {
        Tensor::new(vec![n], (0..n).map(|_| rng.f64() as f32).collect())
    }

    fn summary_rich() -> NeighborSummary {
        let mut s = NeighborSummary::base(9, 0.013, 0.85);
        s.per_class_input = vec![4, 5];
        s.min_slack_s = Some(-0.25);
        s.region = vec![
            RegionLoad { node: 3, input_len: 2, hops: 1 },
            RegionLoad { node: 7, input_len: 0, hops: 2 },
        ];
        s.beat = Some(41);
        s
    }

    fn assert_task_eq(a: &Task, b: &Task) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.sample, b.sample);
        assert_eq!(a.stage, b.stage);
        assert_eq!(a.source, b.source);
        assert_eq!(a.encoded, b.encoded);
        assert_eq!(a.admitted_at.to_bits(), b.admitted_at.to_bits());
        assert_eq!(a.hops, b.hops);
        assert_eq!(a.class, b.class);
        assert_eq!(a.deadline.to_bits(), b.deadline.to_bits());
        match (&a.features, &b.features) {
            (None, None) => {}
            (Some(x), Some(y)) => assert_eq!(x, y, "task {} features", a.id),
            _ => panic!("task {}: feature presence diverged", a.id),
        }
    }

    fn assert_env_eq(a: &Envelope, b: &Envelope) {
        match (a, b) {
            (Envelope::TaskBatch(x), Envelope::TaskBatch(y))
            | (Envelope::Rehome(x), Envelope::Rehome(y)) => {
                assert_eq!(x.len(), y.len());
                for (t, u) in x.iter().zip(y) {
                    assert_task_eq(t, u);
                }
            }
            (Envelope::Result(x), Envelope::Result(y)) => assert_eq!(x, y),
            (Envelope::State(x), Envelope::State(y)) => assert_eq!(x, y),
            (Envelope::Piggybacked(xi, xs), Envelope::Piggybacked(yi, ys)) => {
                assert_env_eq(xi, yi);
                assert_eq!(xs, ys);
            }
            _ => panic!("envelope kind diverged"),
        }
    }

    /// Roundtrip + re-encode byte identity + unchanged simulated charge.
    fn roundtrip(env: &Envelope) -> Envelope {
        let m = meta2();
        let charge_before = env.encoded_bytes(&m);
        let frame = encode(env).expect("encode");
        let bytes = frame.to_bytes();
        assert_eq!(bytes.len(), frame.byte_len());
        let back = decode(&bytes).expect("decode");
        assert_eq!(
            back.encoded_bytes(&m),
            charge_before,
            "physical codec must not perturb the simulated charge"
        );
        let bytes2 = encode(&back).expect("re-encode").to_bytes();
        assert_eq!(bytes, bytes2, "re-encoded frame must be byte-identical");
        back
    }

    #[test]
    fn task_batch_roundtrips_with_mixed_payloads() {
        let mut rng = Pcg64::new(7, streams::PROP_CASES);
        let env = Envelope::TaskBatch(vec![
            task(1, 2, Some(tensor(&mut rng, 6))),
            task(2, 2, None), // oracle/DES path: no materialized features
            task(3, 2, Some(tensor(&mut rng, 10))),
        ]);
        let back = roundtrip(&env);
        assert_env_eq(&env, &back);
        // All decoded views share ONE received allocation.
        if let Envelope::TaskBatch(ts) = &back {
            let views: Vec<&Tensor> = ts.iter().filter_map(|t| t.features.as_ref()).collect();
            assert_eq!(views.len(), 2);
            assert!(views[0].aliases(views[1]), "views must share the receive buffer");
        } else {
            panic!("kind changed");
        }
    }

    #[test]
    fn encode_borrows_payload_buffers() {
        let mut rng = Pcg64::new(8, streams::PROP_CASES);
        let t = task(4, 1, Some(tensor(&mut rng, 12)));
        let src = t.features.clone().expect("features");
        let env = Envelope::TaskBatch(vec![t]);
        let frame = encode(&env).expect("encode");
        assert_eq!(frame.segments().len(), 1);
        assert!(
            frame.segments()[0].aliases(&src),
            "staging a send must borrow, not copy, the activation buffer"
        );
    }

    #[test]
    fn encoded_flag_and_rehome_roundtrip() {
        let mut rng = Pcg64::new(9, streams::PROP_CASES);
        let mut t = task(5, 2, Some(tensor(&mut rng, 4)));
        t.encoded = true;
        let env = Envelope::Rehome(vec![t, task(6, 2, None)]);
        let back = roundtrip(&env);
        assert_env_eq(&env, &back);
    }

    #[test]
    fn result_batch_roundtrips() {
        let r1 = InferenceResult {
            sample: 3,
            exit_point: 1,
            prediction: 7,
            confidence: 0.91,
            admitted_at: 0.5,
            deadline: f64::INFINITY,
            exited_on: 2,
            source: 0,
            class: 1,
        };
        let r2 = InferenceResult { sample: 4, exit_point: 2, deadline: 2.25, ..r1 };
        let env = Envelope::Result(vec![r1, r2]);
        assert_env_eq(&env, &roundtrip(&env));
    }

    #[test]
    fn state_roundtrips_except_receiver_local_delay() {
        let mut s = summary_rich();
        s.d_nm_s = 0.375; // must NOT travel
        let env = Envelope::State(s.clone());
        let back = roundtrip(&env);
        if let Envelope::State(got) = back {
            assert_eq!(got.d_nm_s, 0.0, "d_nm_s is receiver-local");
            let mut expect = s;
            expect.d_nm_s = 0.0;
            assert_eq!(got, expect);
        } else {
            panic!("kind changed");
        }
    }

    #[test]
    fn piggybacked_roundtrips() {
        let mut rng = Pcg64::new(10, streams::PROP_CASES);
        let inner = Envelope::TaskBatch(vec![
            task(7, 1, Some(tensor(&mut rng, 5))),
            task(8, 1, Some(tensor(&mut rng, 5))),
        ]);
        let mut s = summary_rich();
        s.d_nm_s = 0.0;
        let env = Envelope::Piggybacked(Box::new(inner), s);
        assert_env_eq(&env, &roundtrip(&env));
    }

    #[test]
    fn nested_or_state_piggyback_is_rejected() {
        let s = NeighborSummary::base(1, 0.01, 0.9);
        let env = Envelope::Piggybacked(
            Box::new(Envelope::State(NeighborSummary::base(2, 0.01, 0.9))),
            s.clone(),
        );
        assert!(encode(&env).is_err());
        let env = Envelope::Piggybacked(
            Box::new(Envelope::Piggybacked(
                Box::new(Envelope::Result(vec![])),
                s.clone(),
            )),
            s,
        );
        assert!(encode(&env).is_err());
    }

    #[test]
    fn malformed_inputs_error_instead_of_panicking() {
        assert!(matches!(decode(&[]), Err(WireError::Truncated)));
        let mut rng = Pcg64::new(11, streams::PROP_CASES);
        let env = Envelope::TaskBatch(vec![task(9, 1, Some(tensor(&mut rng, 8)))]);
        let good = encode(&env).expect("encode").to_bytes();
        // Truncations at every prefix length must error, never panic.
        for cut in 0..good.len() {
            assert!(decode(&good[..cut]).is_err(), "prefix {cut} decoded");
        }
        // Bad magic / version / kind.
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(matches!(decode(&bad), Err(WireError::BadMagic)));
        let mut bad = good.clone();
        bad[2] = 99;
        assert!(matches!(decode(&bad), Err(WireError::BadVersion(99))));
        let mut bad = good.clone();
        bad[3] = 9;
        assert!(matches!(decode(&bad), Err(WireError::BadKind(9))));
        // Trailing garbage is rejected.
        let mut bad = good;
        bad.push(0);
        assert!(decode(&bad).is_err());
    }

    /// Seeded mini-fuzz: random envelopes roundtrip byte-identically.
    /// Sizes stay tiny so the Miri `net::` lane interprets this quickly.
    #[test]
    fn random_envelopes_roundtrip_byte_identically() {
        let mut rng = Pcg64::new(13, streams::PROP_CASES);
        for case in 0..12u64 {
            let k = 1 + (rng.next_u64() % 3) as usize;
            let tasks: Vec<Task> = (0..k)
                .map(|i| {
                    let id = case * 10 + i as u64;
                    let feats = if rng.next_u64() % 4 == 0 {
                        None
                    } else {
                        Some(tensor(&mut rng, 1 + (rng.next_u64() % 6) as usize))
                    };
                    let mut t = task(id, 1 + (id % 3) as usize, feats);
                    t.encoded = rng.next_u64() % 5 == 0;
                    t
                })
                .collect();
            let env = if case % 3 == 0 {
                Envelope::Piggybacked(Box::new(Envelope::TaskBatch(tasks)), summary_rich())
            } else if case % 3 == 1 {
                Envelope::Rehome(tasks)
            } else {
                Envelope::TaskBatch(tasks)
            };
            assert_env_eq(&env, &roundtrip(&env));
        }
    }
}
