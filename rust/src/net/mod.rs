//! The unified wire layer: every message between workers is a typed
//! [`Envelope`], and **batches are first-class on the wire**.
//!
//! Before this module the two drivers each kept a private mirror of the
//! core's payload enum (`worker::Payload`, the DES driver's `Msg`, the
//! realtime driver's `NetMsg`) and every hop moved exactly one task — so
//! the engine-side batching of [`crate::sched::BatchPolicy`] was undone at
//! the first offload: a batch formed on worker n crossed the link as k
//! separate messages, each paying its own base latency, jitter draw,
//! contention slot, and per-message framing. DEFER (PAPERS.md) identifies
//! precisely this per-task communication cost as the MDI bottleneck.
//! [`Envelope`] closes it: a same-stage run of tasks travels as ONE
//! `TaskBatch`, results and churn re-homes headed to the same source share
//! an envelope per relay leg, and both drivers charge the link with the
//! same [`Envelope::encoded_bytes`] — one charging function, two media.
//!
//! ## Encoding / charging contract
//!
//! Every envelope charge includes one fixed [`ENVELOPE_HEADER_BYTES`]
//! frame (routing ids, kind tag, item count) plus the per-item payload:
//!
//! * `TaskBatch` / `Rehome` — each task contributes its feature tensor
//!   entering the stage ([`task_wire_bytes`]: `stage_in_bytes[stage-1]`,
//!   or the AE code size when the payload is encoded), *minus* the frame
//!   a lone message would have carried. A singleton therefore charges
//!   exactly what the seed charged for one task (`task_wire_bytes`), and
//!   a batch of k sheds `(k-1) ×` [`ENVELOPE_HEADER_BYTES`] — the wire
//!   analogue of the engine's amortized dispatch.
//! * `Result` — [`RESULT_BYTES`] for a singleton (the seed's classifier
//!   output + header), `header + k × (RESULT_BYTES - header)` for k.
//! * `State` — the gossiped summary's own
//!   [`crate::policy::NeighborSummary::encoded_bytes`] (its base encoding
//!   already frames the message; gossip is never batched).
//!
//! Both drivers MUST obtain the wire charge from [`Envelope::encoded_bytes`]
//! *after* any autoencoder step (an encode failure flips `task.encoded`
//! back and the same call then charges the raw tensor) — the DES driver
//! feeds it to the virtual link-delay model, the realtime transport frames
//! the delivery delay with it, and [`crate::coordinator::WorkerCore`]
//! counts the identical number into the per-worker `wire_bytes` /
//! `wire_bytes_saved` counters when it emits the send. When an encode
//! falls back to raw, the driver reconciles the core's emit-time count
//! through `WorkerCore::note_wire_recharge`, so the counters always equal
//! what the medium was charged. There is no other byte-sizing code path —
//! and `cargo xtask lint` (rule `wire-charge`, see `rust/CONTRACTS.md`)
//! rejects arithmetic on these sizes outside `net/`, so the cost model
//! cannot silently fork from the codec.
//!
//! ## Batch invariants
//!
//! * A `TaskBatch` is same-stage by construction (the engine runs one
//!   batched forward per stage) and sorted in *admission order*
//!   (`admitted_at`, ties by id), so a receiver merging it through its
//!   [`crate::sched::QueueDiscipline::push`] sees the arrivals in the
//!   order the sources admitted them — EDF/DRR/StrictPriority accounting
//!   is indistinguishable from the tasks having arrived one by one.
//! * `Result` and `Rehome` envelopes are same-source by construction:
//!   every item shares one destination, so relays forward the envelope
//!   intact and each multi-hop leg is charged once per envelope, not once
//!   per item.
//! * `coalesce = off` (the default) puts exactly one item in every task /
//!   result / re-home envelope, reproducing the seed's per-task wire
//!   behaviour bit for bit — same message count, same byte charges, same
//!   RNG draws.
//!
//! ## Zero-copy payloads (the buffer-aliasing contract)
//!
//! Feature tensors inside an envelope are offset/len *views* over shared,
//! refcounted [`crate::tensor::TensorBuf`]s: putting a task on the wire,
//! relaying it, re-homing it, or cloning a batch never copies activation
//! data — only headers and refcounts move. The physical codec in
//! [`wire`] upholds the same discipline ([`wire::encode`] borrows the
//! senders' buffers; [`wire::decode`] reconstructs every view over ONE
//! received allocation) and is deliberately independent of
//! [`Envelope::encoded_bytes`]: the *simulated charge* is metadata-driven
//! (`stage_in_bytes` / AE `code_bytes`) and stays bit-for-bit identical
//! to the seed no matter how payloads are represented in memory.

pub mod wire;

use crate::coordinator::task::{InferenceResult, Task};
use crate::coordinator::worker::ModelMeta;
use crate::policy::NeighborSummary;

/// Fixed per-envelope framing: sender/destination ids, kind tag, item
/// count, per-item offsets. This is the cost coalescing amortizes — each
/// item beyond the first rides an existing frame.
pub const ENVELOPE_HEADER_BYTES: usize = 32;

/// Wire size of a lone exit-result message (classifier output + framing),
/// unchanged from the seed.
pub const RESULT_BYTES: usize = 64;

/// Payload bytes of one result inside a shared frame.
const RESULT_ITEM_BYTES: usize = RESULT_BYTES - ENVELOPE_HEADER_BYTES;

/// Wire size of task τ_k travelling alone: the feature tensor entering
/// stage k (or the autoencoder code when the payload is encoded), framing
/// included — byte-identical to the seed's per-task charge.
pub fn task_wire_bytes(meta: &ModelMeta, task: &Task) -> usize {
    if task.encoded {
        return meta.ae.as_ref().map(|ae| ae.code_bytes).unwrap_or(0);
    }
    meta.stage_in_bytes[task.stage - 1]
}

/// One task's contribution to a shared frame (its lone-message size minus
/// the frame it no longer needs; saturating so degenerate tiny payloads —
/// e.g. an extreme AE code — never underflow).
fn task_item_bytes(meta: &ModelMeta, task: &Task) -> usize {
    task_wire_bytes(meta, task).saturating_sub(ENVELOPE_HEADER_BYTES)
}

/// What travels between workers — on both drivers, through one type.
///
/// See the module docs for the charging contract and batch invariants.
#[derive(Debug)]
pub enum Envelope {
    /// One or more *same-stage* tasks offloaded to a neighbor, in
    /// admission order. Size 1 unless the run coalesces
    /// ([`crate::sched::SchedConfig::coalesce`]).
    TaskBatch(Vec<Task>),
    /// Completed inference results in transit toward their (shared)
    /// admitting source, relayed hop by hop.
    Result(Vec<InferenceResult>),
    /// Churn-displaced tasks in transit back to their (shared) admitting
    /// source, relayed hop by hop.
    Rehome(Vec<Task>),
    /// A gossiped neighbor summary (never batched; charged by its own
    /// encoded size).
    State(NeighborSummary),
    /// A payload envelope with a gossip summary riding its frame
    /// (`cfg.gossip_piggyback`): the summary was headed to the same
    /// neighbor anyway, so it shares the existing header instead of paying
    /// for a dedicated `State` message. Charged as the inner envelope plus
    /// the summary's encoding minus the one header they now share. Never
    /// nested; the inner envelope is never itself `State` or `Piggybacked`.
    Piggybacked(Box<Envelope>, NeighborSummary),
}

impl Envelope {
    /// Number of items riding this envelope (the piggybacked summary is
    /// framing, not an item — counts see through the wrapper).
    pub fn items(&self) -> usize {
        match self {
            Envelope::TaskBatch(ts) | Envelope::Rehome(ts) => ts.len(),
            Envelope::Result(rs) => rs.len(),
            Envelope::State(_) => 1,
            Envelope::Piggybacked(inner, _) => inner.items(),
        }
    }

    /// Split a piggybacked envelope into its payload and the gossip
    /// summary that rode along; plain envelopes pass through unchanged.
    /// Receivers call this FIRST and feed the summary to their gossip
    /// handler, so a piggybacked ride is observationally a `State` arrival
    /// plus the inner delivery.
    pub fn split_gossip(self) -> (Envelope, Option<NeighborSummary>) {
        match self {
            Envelope::Piggybacked(inner, summary) => (*inner, Some(summary)),
            env => (env, None),
        }
    }

    /// The payload envelope with any piggybacked summary peeled off —
    /// the non-consuming sibling of [`Envelope::split_gossip`]. Never
    /// returns `Piggybacked` (the wrapper is not nested by contract).
    /// Telemetry's wire hooks classify sends/receives through this.
    pub fn payload(&self) -> &Envelope {
        match self {
            Envelope::Piggybacked(inner, _) => inner,
            env => env,
        }
    }

    /// Stable label of the payload kind for telemetry and logging (sees
    /// through piggybacking).
    pub fn kind_label(&self) -> &'static str {
        match self.payload() {
            Envelope::TaskBatch(_) => "task",
            Envelope::Result(_) => "result",
            Envelope::Rehome(_) => "rehome",
            Envelope::State(_) => "state",
            Envelope::Piggybacked(..) => unreachable!("payload() peels the wrapper"),
        }
    }

    /// Whether the (possibly wrapped) payload is a task batch — the
    /// message-count statistic and the realtime transport's accounting
    /// look through piggybacking.
    pub fn is_task_batch(&self) -> bool {
        match self {
            Envelope::TaskBatch(_) => true,
            Envelope::Piggybacked(inner, _) => inner.is_task_batch(),
            _ => false,
        }
    }

    /// The task batch inside this envelope, seeing through piggybacking.
    pub fn task_batch(&self) -> Option<&[Task]> {
        match self {
            Envelope::TaskBatch(ts) => Some(ts),
            Envelope::Piggybacked(inner, _) => inner.task_batch(),
            _ => None,
        }
    }

    /// Mutable view of the inner task batch (the drivers' encode step).
    pub fn task_batch_mut(&mut self) -> Option<&mut Vec<Task>> {
        match self {
            Envelope::TaskBatch(ts) => Some(ts),
            Envelope::Piggybacked(inner, _) => inner.task_batch_mut(),
            _ => None,
        }
    }

    /// THE wire charge — the one function both drivers and the core's
    /// byte counters consult (see the module-level contract).
    pub fn encoded_bytes(&self, meta: &ModelMeta) -> usize {
        match self {
            Envelope::TaskBatch(ts) | Envelope::Rehome(ts) => {
                ENVELOPE_HEADER_BYTES
                    + ts.iter().map(|t| task_item_bytes(meta, t)).sum::<usize>()
            }
            Envelope::Result(rs) => {
                ENVELOPE_HEADER_BYTES + rs.len() * RESULT_ITEM_BYTES
            }
            Envelope::State(s) => s.encoded_bytes(),
            Envelope::Piggybacked(inner, s) => {
                // The summary rides the inner frame: its encoding minus the
                // header it no longer needs (saturating — a summary never
                // encodes below one header, but keep the degenerate case
                // safe).
                inner.encoded_bytes(meta)
                    + s.encoded_bytes().saturating_sub(ENVELOPE_HEADER_BYTES)
            }
        }
    }

    /// What the same items would have cost as one-envelope-each messages
    /// (the seed's wiring). `encoded_bytes <= unbatched_bytes`, equal for
    /// singletons; the difference feeds the `wire_bytes_saved` counter.
    pub fn unbatched_bytes(&self, meta: &ModelMeta) -> usize {
        match self {
            Envelope::TaskBatch(ts) | Envelope::Rehome(ts) => ts
                .iter()
                .map(|t| ENVELOPE_HEADER_BYTES + task_item_bytes(meta, t))
                .sum(),
            Envelope::Result(rs) => rs.len() * RESULT_BYTES,
            Envelope::State(s) => s.encoded_bytes(),
            Envelope::Piggybacked(inner, s) => {
                inner.unbatched_bytes(meta) + s.encoded_bytes()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta::synthetic(vec![0.002, 0.003], vec![12288, 8192])
    }

    fn task(id: u64, stage: usize) -> Task {
        Task { stage, ..Task::initial(id, id as usize, None, 0.0) }
    }

    #[test]
    fn singleton_task_envelope_matches_seed_charge() {
        let m = meta();
        let env = Envelope::TaskBatch(vec![task(1, 1)]);
        assert_eq!(env.encoded_bytes(&m), 12288, "stage-1 tensor, seed-identical");
        let env = Envelope::TaskBatch(vec![task(1, 2)]);
        assert_eq!(env.encoded_bytes(&m), 8192);
        assert_eq!(env.unbatched_bytes(&m), env.encoded_bytes(&m));
    }

    #[test]
    fn batch_sheds_one_header_per_extra_task() {
        let m = meta();
        let env = Envelope::TaskBatch(vec![task(1, 2), task(2, 2), task(3, 2)]);
        assert_eq!(env.encoded_bytes(&m), 3 * 8192 - 2 * ENVELOPE_HEADER_BYTES);
        assert_eq!(env.unbatched_bytes(&m), 3 * 8192);
        assert_eq!(
            env.unbatched_bytes(&m) - env.encoded_bytes(&m),
            2 * ENVELOPE_HEADER_BYTES
        );
        assert_eq!(env.items(), 3);
    }

    #[test]
    fn result_envelopes_charge_seed_bytes_for_singletons() {
        let m = meta();
        let r = InferenceResult {
            sample: 0,
            exit_point: 1,
            prediction: 0,
            confidence: 0.9,
            admitted_at: 0.0,
            deadline: 1.0,
            exited_on: 1,
            source: 0,
            class: 0,
        };
        let env = Envelope::Result(vec![r]);
        assert_eq!(env.encoded_bytes(&m), RESULT_BYTES);
        let env = Envelope::Result(vec![r, r, r]);
        assert_eq!(
            env.encoded_bytes(&m),
            ENVELOPE_HEADER_BYTES + 3 * (RESULT_BYTES - ENVELOPE_HEADER_BYTES)
        );
        assert_eq!(env.unbatched_bytes(&m), 3 * RESULT_BYTES);
    }

    #[test]
    fn rehome_charges_like_task_batches() {
        let m = meta();
        let single = Envelope::Rehome(vec![task(1, 1)]);
        assert_eq!(single.encoded_bytes(&m), 12288);
        let pair = Envelope::Rehome(vec![task(1, 1), task(2, 1)]);
        assert_eq!(pair.encoded_bytes(&m), 2 * 12288 - ENVELOPE_HEADER_BYTES);
    }

    #[test]
    fn encoded_task_charges_the_ae_code_size() {
        let mut m = meta();
        m.ae = Some(crate::coordinator::worker::AeMeta {
            enc_cost_s: 0.001,
            dec_cost_s: 0.001,
            code_bytes: 2048,
        });
        let t = Task { encoded: true, ..task(1, 2) };
        assert_eq!(task_wire_bytes(&m, &t), 2048);
        let env = Envelope::TaskBatch(vec![t]);
        assert_eq!(env.encoded_bytes(&m), 2048);
    }

    #[test]
    fn state_envelopes_charge_the_summary_encoding() {
        let m = meta();
        let s = NeighborSummary::base(3, 0.01, 0.9);
        let bytes = s.encoded_bytes();
        let env = Envelope::State(s);
        assert_eq!(env.encoded_bytes(&m), bytes);
        assert_eq!(env.unbatched_bytes(&m), bytes);
        assert_eq!(env.items(), 1);
    }

    #[test]
    fn piggybacked_summary_shares_the_frame() {
        let m = meta();
        let s = NeighborSummary::base(3, 0.01, 0.9);
        let s_bytes = s.encoded_bytes();
        let inner = Envelope::TaskBatch(vec![task(1, 2)]);
        let inner_bytes = inner.encoded_bytes(&m);
        let inner_unbatched = inner.unbatched_bytes(&m);
        let env = Envelope::Piggybacked(Box::new(inner), s);
        // Charge = payload + summary minus the one header they now share.
        assert_eq!(
            env.encoded_bytes(&m),
            inner_bytes + s_bytes - ENVELOPE_HEADER_BYTES
        );
        // Cheaper than the two separate messages the seed wire would send.
        assert_eq!(env.unbatched_bytes(&m), inner_unbatched + s_bytes);
        assert_eq!(
            env.unbatched_bytes(&m) - env.encoded_bytes(&m),
            ENVELOPE_HEADER_BYTES
        );
        // Items / task-batch accessors see through the wrapper.
        assert_eq!(env.items(), 1);
        assert!(env.is_task_batch());
        assert_eq!(env.task_batch().unwrap().len(), 1);
    }

    #[test]
    fn split_gossip_roundtrip() {
        let m = meta();
        let s = NeighborSummary::base(5, 0.02, 0.8);
        let env =
            Envelope::Piggybacked(Box::new(Envelope::TaskBatch(vec![task(1, 1)])), s.clone());
        let (inner, gossip) = env.split_gossip();
        assert_eq!(gossip.as_ref().map(|g| g.input_len), Some(5));
        assert_eq!(inner.encoded_bytes(&m), 12288);
        assert!(matches!(inner, Envelope::TaskBatch(_)));
        // Plain envelopes pass through with no summary.
        let (plain, none) = Envelope::Result(vec![]).split_gossip();
        assert!(none.is_none());
        assert!(matches!(plain, Envelope::Result(_)));
        // Result piggybacks work too (any payload headed the right way).
        let env = Envelope::Piggybacked(Box::new(Envelope::Result(vec![])), s);
        assert!(!env.is_task_batch());
        assert!(env.task_batch().is_none());
    }
}
