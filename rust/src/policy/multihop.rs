//! Multi-hop offloading: push work toward remote under-loaded *regions*,
//! not just direct neighbors.
//!
//! The paper's Alg. 2 (and the ROADMAP follow-on it left open) offloads
//! one hop: a loaded worker whose direct neighbors are also loaded stalls
//! even when an idle region sits two hops away — the exact shape of the
//! `2-ring-bridge` topology, where ring A saturates while ring B idles
//! behind the bridge. This policy closes the gap with a small
//! distance-vector protocol over the existing gossip:
//!
//! * every summary carries a `region` table — the freshest known
//!   `(node, input_len, hops)` for nodes beyond the sender — which
//!   receivers merge (closer entries win; equal-hop entries refresh), so
//!   load information diffuses one gossip period per hop exactly like the
//!   adapted T_e does;
//! * `choose` first runs the paper's Alg. 2 scan over direct neighbors
//!   (same shuffle, same rule — one-hop behaviour is preserved when a
//!   direct target exists); when nobody accepts, it looks up the least
//!   loaded *remote* node it knows of and, if that node is meaningfully
//!   idler than every direct neighbor, hands the task to the
//!   [`crate::routing::RoutingTable`] next hop toward it.
//!
//! The relayed task arrives at the next hop as ordinary wire traffic: the
//! hop either computes it or — being itself loaded and running the same
//! policy — pushes it further toward the idle region. Work therefore
//! *diffuses* along shortest paths without any new message type, and a
//! stale region entry costs at most one misdirected hop.

use super::alg::OffloadRule;
use super::baseline::BaselineOffload;
use super::summary::{NeighborSummary, RegionLoad};
use super::{LocalState, OffloadCtx, OffloadPolicy};
use crate::util::rng::Pcg64;

/// Freshest knowledge about one remote node's load.
#[derive(Debug, Clone, Copy)]
struct Known {
    input_len: usize,
    hops: u8,
    heard_at: f64,
}

/// Region entries older than this are ignored as offload evidence (they
/// keep gossiping until refreshed, but a long-silent node may have drained
/// or filled long ago). Measured in seconds of driver time.
const STALE_S: f64 = 2.0;
/// Entries are not propagated further than this many hops — on the
/// paper-scale topologies (n <= 6) every node is reachable well within it.
const MAX_HOPS: u8 = 4;
/// A remote node must be at least this many tasks idler than both our
/// input backlog and the least-loaded direct neighbor before we commit a
/// task to a multi-hop journey.
const REMOTE_MARGIN: usize = 2;

/// Alg. 2 with a multi-hop fallback (see module docs). Routing is read
/// from [`OffloadCtx::next_hop`] at decision time (not copied at
/// construction), so a future churn-aware re-route is picked up for free.
#[derive(Debug)]
pub struct MultiHop {
    id: usize,
    /// Per-node freshest load knowledge (`None` = never heard of it).
    known: Vec<Option<Known>>,
    /// The one-hop scan is delegated to the baseline policy so the direct
    /// behaviour (and its RNG discipline) cannot drift from Alg. 2.
    direct: BaselineOffload,
}

impl MultiHop {
    pub fn new(id: usize, num_workers: usize) -> MultiHop {
        MultiHop {
            id,
            known: vec![None; num_workers],
            direct: BaselineOffload::new(OffloadRule::Alg2),
        }
    }

    fn merge(&mut self, node: usize, input_len: usize, hops: u8, now: f64) {
        if node == self.id || node >= self.known.len() || hops > MAX_HOPS {
            return;
        }
        let slot = &mut self.known[node];
        // Closer knowledge wins; equal-or-closer refreshes.
        let adopt = match *slot {
            Some(k) => hops <= k.hops || now - k.heard_at > STALE_S,
            None => true,
        };
        if adopt {
            *slot = Some(Known { input_len, hops, heard_at: now });
        }
    }

    /// The multi-hop fallback after Alg. 2's one-hop scan refused, for a
    /// coalescible run of `run_len` tasks (`1` = the classic single-task
    /// decision). A longer run raises both the journey and the local-wait
    /// estimates by the run's own service time, and the optimistic bump
    /// charges the remote for the whole batch, so one stale "idle" entry
    /// cannot absorb an unbounded coalesced flood.
    fn remote_fallback(&mut self, ctx: &OffloadCtx<'_>, run_len: usize) -> Option<usize> {
        let run = run_len.max(1);
        let direct_min =
            ctx.candidates.iter().map(|(_, s)| s.input_len).min().unwrap_or(usize::MAX);
        let best = self
            .known
            .iter()
            .enumerate()
            .filter_map(|(node, k)| k.map(|k| (node, k)))
            // Fresh knowledge about a node beyond the one-hop horizon
            // (hops < 2 means a direct neighbor Alg. 2 already saw) that
            // we can actually steer toward through an active neighbor.
            .filter(|&(node, k)| {
                k.hops >= 2
                    && ctx.now - k.heard_at <= STALE_S
                    && ctx
                        .next_hop
                        .get(node)
                        .copied()
                        .flatten()
                        .map(|hop| ctx.candidates.iter().any(|(m, _)| *m == hop))
                        .unwrap_or(false)
            })
            .min_by_key(|&(_, k)| k.input_len);
        let (remote, entry) = best?;
        let load = entry.input_len;
        // Pressure signal: the *input backlog*, not the output queue —
        // Alg. 2's `O_n > I_m` gate stalls precisely because O_n is capped
        // near T_O while the real overload piles up in I_n; the multi-hop
        // fallback exists to act on that backlog.
        if load + REMOTE_MARGIN > ctx.input_len || load + REMOTE_MARGIN > direct_min {
            return None;
        }
        let hop = ctx.next_hop[remote].expect("checked above");
        let (_, hop_summary) =
            ctx.candidates.iter().find(|(m, _)| *m == hop).expect("checked above");
        // The journey must still beat waiting here: estimate it as one
        // relay-link transfer per hop plus the destination's service of
        // its backlog and the run (gamma of the relay stands in for the
        // destination's — the region table does not gossip per-node Γ).
        let journey = entry.hops as f64 * hop_summary.d_nm_s
            + (load + run) as f64 * hop_summary.gamma_s;
        let local_wait = (ctx.input_len + run) as f64 * ctx.gamma_s;
        if journey < local_wait {
            // Optimistic bump until the next gossip refresh (the same
            // discipline the core applies to direct-neighbor views).
            if let Some(k) = self.known[remote].as_mut() {
                k.input_len += run;
            }
            Some(hop)
        } else {
            None
        }
    }
}

impl OffloadPolicy for MultiHop {
    fn name(&self) -> &'static str {
        "multi-hop"
    }

    fn observe(&mut self, from: usize, summary: &NeighborSummary, now: f64) {
        // The sender's own load is one hop away; its region table one more.
        self.merge(from, summary.input_len, 1, now);
        for &e in &summary.region {
            self.merge(e.node, e.input_len, e.hops.saturating_add(1), now);
        }
    }

    fn annotate(&mut self, summary: &mut NeighborSummary, local: &LocalState<'_>) {
        // Gossip everything fresh we know about nodes other than ourself
        // (receivers learn our own load from the base field).
        summary.region = self
            .known
            .iter()
            .enumerate()
            .filter_map(|(node, k)| {
                k.filter(|k| local.now - k.heard_at <= STALE_S && k.hops < MAX_HOPS).map(
                    |k| RegionLoad { node, input_len: k.input_len, hops: k.hops },
                )
            })
            .collect();
    }

    fn forget(&mut self, node: usize) {
        if let Some(slot) = self.known.get_mut(node) {
            *slot = None;
        }
    }

    fn choose(&mut self, ctx: &OffloadCtx<'_>, rng: &mut Pcg64) -> Option<usize> {
        // One-hop first: the paper's scan, verbatim. Only when no direct
        // neighbor accepts does the region table get a say.
        if let Some(target) = self.direct.choose(ctx, rng) {
            return Some(target);
        }
        self.remote_fallback(ctx, 1)
    }

    fn choose_coalesced(
        &mut self,
        ctx: &OffloadCtx<'_>,
        run_len: usize,
        rng: &mut Pcg64,
    ) -> Option<usize> {
        // The direct scan is batch-oblivious (Alg. 2 verbatim, same RNG
        // stream); the multi-hop fallback weighs the whole run.
        if let Some(target) = self.direct.choose(ctx, rng) {
            return Some(target);
        }
        self.remote_fallback(ctx, run_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::Task;

    /// line-4 routing row for node 0: everything right goes through 1.
    fn next_hop_0() -> Vec<Option<usize>> {
        vec![None, Some(1), Some(1), Some(1)]
    }

    fn summary(input_len: usize) -> NeighborSummary {
        let mut s = NeighborSummary::base(input_len, 0.01, 0.9);
        s.d_nm_s = 0.005;
        s
    }

    fn ctx<'a>(
        task: &'a Task,
        input_len: usize,
        output_len: usize,
        candidates: &'a [(usize, NeighborSummary)],
        next_hop: &'a [Option<usize>],
    ) -> OffloadCtx<'a> {
        OffloadCtx {
            now: 1.0,
            task,
            input_len,
            output_len,
            gamma_s: 0.01,
            candidates,
            next_hop,
        }
    }

    #[test]
    fn region_knowledge_diffuses_and_prefers_closer_entries() {
        let mut p = MultiHop::new(0, 4);
        let mut s = summary(3);
        s.region = vec![
            RegionLoad { node: 2, input_len: 7, hops: 1 },
            RegionLoad { node: 3, input_len: 0, hops: 2 },
        ];
        p.observe(1, &s, 1.0);
        assert_eq!(p.known[1].unwrap().input_len, 3);
        assert_eq!(p.known[1].unwrap().hops, 1);
        assert_eq!(p.known[2].unwrap().hops, 2);
        assert_eq!(p.known[3].unwrap().hops, 3);
        // A farther (staler-path) report of node 2 does not overwrite the
        // closer one...
        let mut far = summary(1);
        far.region = vec![RegionLoad { node: 2, input_len: 99, hops: 3 }];
        p.observe(1, &far, 1.5);
        assert_eq!(p.known[2].unwrap().input_len, 7, "closer entry wins");
        // ...until the closer one goes stale.
        p.observe(1, &far, 1.5 + STALE_S + 1.0);
        assert_eq!(p.known[2].unwrap().input_len, 99, "stale entries are replaced");
    }

    #[test]
    fn annotate_gossips_fresh_knowledge_only() {
        let mut p = MultiHop::new(0, 4);
        p.merge(2, 5, 1, 0.0);
        p.merge(3, 1, 2, 10.0);
        let q = crate::sched::Fifo::new();
        let local = LocalState {
            id: 0,
            now: 10.5,
            input_len: 0,
            output_len: 0,
            gamma_s: 0.01,
            input: &q,
            num_classes: 1,
        };
        let mut s = NeighborSummary::base(0, 0.01, 0.9);
        p.annotate(&mut s, &local);
        assert_eq!(s.region.len(), 1, "the entry from t=0 is stale at t=10.5");
        assert_eq!(s.region[0].node, 3);
        assert_eq!(s.encoded_bytes(), 32 + 8);
    }

    #[test]
    fn falls_back_to_pushing_toward_an_idle_remote_region() {
        let mut p = MultiHop::new(0, 4);
        // Direct neighbor 1 is drowning (Alg. 2's gate refuses: O_n = 5
        // <= I_m = 30) while the real overload — 40 tasks — sits in the
        // *input* queue; node 3 two hops out is idle.
        p.merge(3, 0, 2, 1.0);
        let task = Task::initial(1, 0, None, 0.0);
        let cands = vec![(1usize, summary(30))];
        let nh = next_hop_0();
        let got = p.choose(&ctx(&task, 40, 5, &cands, &nh), &mut Pcg64::new(1, 0));
        assert_eq!(got, Some(1), "task heads one hop toward idle node 3");
    }

    #[test]
    fn stays_put_when_the_remote_region_is_no_better() {
        let mut p = MultiHop::new(0, 4);
        p.merge(3, 45, 2, 1.0); // remote more loaded than our backlog
        let task = Task::initial(1, 0, None, 0.0);
        let cands = vec![(1usize, summary(30))];
        let nh = next_hop_0();
        let got = p.choose(&ctx(&task, 40, 5, &cands, &nh), &mut Pcg64::new(1, 0));
        assert_eq!(got, None);
    }

    #[test]
    fn forget_drops_churned_peers() {
        let mut p = MultiHop::new(0, 4);
        p.merge(3, 0, 2, 1.0);
        p.forget(3);
        let task = Task::initial(1, 0, None, 0.0);
        let cands = vec![(1usize, summary(30))];
        let nh = next_hop_0();
        assert_eq!(p.choose(&ctx(&task, 40, 5, &cands, &nh), &mut Pcg64::new(1, 0)), None);
    }
}
