//! Pluggable decision policies: *who exits, who offloads where, and how
//! the sources adapt* — the paper's Algorithms 1–4 as a trait surface.
//!
//! The seed hardwired Algs 1–4 as free functions called straight from
//! [`crate::coordinator::WorkerCore`], so every variant (deadline-aware
//! offloading, multi-hop offloading toward remote regions, alternative
//! admission controllers) meant editing the core. This module is the same
//! seam [`crate::sched::QueueDiscipline`] and [`crate::routing::Placement`]
//! already carved for queue order and data placement, applied to the
//! decision loop itself. The core consumes three boxed, config-selected
//! objects:
//!
//! * [`ExitPolicy`] — Alg. 1's seam: classifier confidence + threshold +
//!   queue state ([`ExitCtx`]) → [`ExitDecision`] for one finished task.
//! * [`OffloadPolicy`] — Alg. 2's seam: the head-of-line output task +
//!   the freshest [`NeighborSummary`] per neighbor ([`OffloadCtx`]) + the
//!   core's RNG → an offload target (or `None` to keep the task). The
//!   policy also *owns the gossip extension surface*: it annotates this
//!   worker's outgoing summaries ([`OffloadPolicy::annotate`]) and absorbs
//!   incoming ones ([`OffloadPolicy::observe`]).
//! * [`AdaptPolicy`] — Algs 3/4's seam: queue occupancy → μ and/or T_e
//!   updates at the admitting sources, replacing the two hardwired
//!   controllers.
//!
//! ## Trait contracts (what a policy may read, and determinism)
//!
//! Policies are **pure over their inputs plus their own state**: everything
//! a decision may depend on arrives in the context structs ([`ExitCtx`],
//! [`OffloadCtx`], [`LocalState`]) or through `observe` — a policy never
//! reaches into the core, the drivers, clocks, or global state. All
//! randomness comes from the `&mut Pcg64` handed into
//! [`OffloadPolicy::choose`] (the core's own per-worker stream, seeded
//! `(cfg.seed, 1000 + worker_id)`): a policy that draws from it consumes
//! the same stream the baseline consumed, so seeded runs stay reproducible
//! and the DES and realtime drivers make identical decision sequences for
//! identical event sequences. Policies must not block, sleep, or read
//! time beyond the `now` they are handed.
//!
//! `observe`/`annotate` are how summaries stay *extensible without wire
//! waste*: a policy only contributes the fields it actually consumes
//! (per-class occupancy, earliest-deadline slack, transitive region load),
//! and both drivers charge the link by [`NeighborSummary::encoded_bytes`]
//! — richer gossip costs more, paper-only gossip costs exactly the seed's
//! 32 bytes.
//!
//! ## Implementations
//!
//! * [`BaselineExit`] / [`BaselineOffload`] / [`BaselineAdapt`]
//!   ([`baseline`]) — bit-for-bit the pre-refactor Alg. 1/2/3/4 behaviour
//!   (property-tested against the free functions in [`alg`], including the
//!   RNG call sequence of the shuffled neighbor scan).
//! * [`DeadlineAware`] ([`deadline`]) — offloads the head-of-line task by
//!   *remaining slack vs. remote wait*, consuming the EDF deadlines
//!   stamped at admission and the gossiped `min_slack_s` field.
//! * [`MultiHop`] ([`multihop`]) — falls back from Alg. 2's one-hop scan
//!   to pushing work toward a remote under-loaded node through the
//!   [`crate::routing::RoutingTable`] next-hop row, steered by the
//!   transitive `region` load table the policy itself gossips.

pub mod alg;
mod baseline;
mod coalesce;
mod deadline;
mod multihop;
mod summary;

use anyhow::{bail, Result};

pub use alg::{
    alg1_decide, alg2_should_offload, offload_decide, AdaptConfig, ExitDecision,
    NeighborView, OffloadRule, RateController, ThresholdController,
};
pub use baseline::{BaselineAdapt, BaselineExit, BaselineOffload, LocalOnlyExit};
pub use coalesce::AdaptiveCoalesce;
pub use deadline::DeadlineAware;
pub use multihop::MultiHop;
pub use summary::{NeighborSummary, RegionLoad, BASE_SUMMARY_BYTES};

use crate::coordinator::task::Task;
use crate::sched::QueueDiscipline;
use crate::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// Decision contexts
// ---------------------------------------------------------------------------

/// Everything Alg. 1 (and any exit-policy variant) may read when deciding
/// what happens to a task whose stage just finished.
#[derive(Debug, Clone, Copy)]
pub struct ExitCtx {
    /// Classifier confidence C_k(d) at the exit point that ran.
    pub confidence: f32,
    /// Early-exit threshold T_e in effect at this worker (already
    /// `INFINITY` under `no_early_exit`).
    pub threshold: f32,
    /// The DNN output is final (last exit point, or DDI mode).
    pub is_final: bool,
    /// Live input-queue occupancy I_n.
    pub input_len: usize,
    /// Live output-queue occupancy O_n.
    pub output_len: usize,
    /// Output-queue threshold T_O of Alg. 1.
    pub t_o: usize,
    /// Driver time of the decision (virtual or wall seconds).
    pub now: f64,
    /// Traffic class of the task (stamped at admission).
    pub class: u8,
    /// Absolute completion deadline of the task.
    pub deadline: f64,
}

/// What an offload policy may read when picking a target for the
/// head-of-line output task.
#[derive(Debug)]
pub struct OffloadCtx<'a> {
    pub now: f64,
    /// The head-of-line output task the chosen target would receive.
    pub task: &'a Task,
    /// Live input-queue occupancy I_n.
    pub input_len: usize,
    /// Live output-queue occupancy O_n.
    pub output_len: usize,
    /// This worker's per-task compute-delay estimate Γ_n, seconds.
    pub gamma_s: f64,
    /// Active one-hop neighbors in canonical (topology) order, each with
    /// the freshest summary: the last gossiped one (with `d_nm_s` filled
    /// from the transfer estimator) or the optimistic default for peers
    /// never heard from.
    pub candidates: &'a [(usize, NeighborSummary)],
    /// This node's next-hop row (`next_hop[dest]`) from the run's routing
    /// table, for policies that steer beyond the one-hop horizon.
    pub next_hop: &'a [Option<usize>],
}

/// This worker's own state, handed to [`OffloadPolicy::annotate`] when an
/// outgoing gossip summary is built.
pub struct LocalState<'a> {
    pub id: usize,
    pub now: f64,
    pub input_len: usize,
    pub output_len: usize,
    pub gamma_s: f64,
    /// Read-only view of the input discipline (per-class occupancy,
    /// earliest deadline) for policies that gossip queue detail.
    pub input: &'a dyn QueueDiscipline,
    /// Number of traffic classes the run configures.
    pub num_classes: u8,
}

// ---------------------------------------------------------------------------
// The three traits
// ---------------------------------------------------------------------------

/// Alg. 1 seam: decide what happens to a task whose stage just computed.
pub trait ExitPolicy: Send + std::fmt::Debug {
    fn name(&self) -> &'static str;
    fn decide(&mut self, ctx: &ExitCtx) -> ExitDecision;
}

/// Alg. 2 seam: pick an offload target for the head-of-line output task,
/// and own the gossip fields the decision consumes.
pub trait OffloadPolicy: Send + std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// A neighbor's gossiped summary arrived: absorb whatever this policy
    /// tracks (region tables, slack views, ...). Called before the summary
    /// is stored as the neighbor's current view.
    fn observe(&mut self, _from: usize, _summary: &NeighborSummary, _now: f64) {}

    /// Contribute policy-specific fields to this worker's outgoing
    /// summary. The base fields are already filled; anything added here is
    /// charged on the wire by encoded size.
    fn annotate(&mut self, _summary: &mut NeighborSummary, _local: &LocalState<'_>) {}

    /// A peer churned out: drop any state tracked about it.
    fn forget(&mut self, _node: usize) {}

    /// Pick the neighbor to send the head-of-line task to, or `None` to
    /// keep it queued. `rng` is the core's seeded per-worker stream — the
    /// only randomness a policy may use.
    fn choose(&mut self, ctx: &OffloadCtx<'_>, rng: &mut Pcg64) -> Option<usize>;

    /// Like [`OffloadPolicy::choose`], but told the *coalescible run
    /// length*: `run_len >= 1` same-stage tasks (the head included) would
    /// ride one [`crate::net::Envelope`] if this offload happens, per the
    /// run's [`crate::sched::CoalesceMode`]. Policies that weigh batch
    /// size against slack or remote capacity override this; the default
    /// ignores the hint and delegates to `choose`, so `Baseline` consumes
    /// the seed's RNG stream bit for bit (`coalesce = off` always passes
    /// `run_len = 1`).
    fn choose_coalesced(
        &mut self,
        ctx: &OffloadCtx<'_>,
        run_len: usize,
        rng: &mut Pcg64,
    ) -> Option<usize> {
        let _ = run_len;
        self.choose(ctx, rng)
    }

    /// After [`OffloadPolicy::choose_coalesced`] accepted `target`: how
    /// many of the `run_len` coalescible tasks to actually drain into the
    /// envelope. The core clamps the answer to `[1, run_len]`; shipping
    /// fewer than the policy priced is always safe (a shorter run, never a
    /// longer one). The default takes the whole run — only
    /// [`crate::sched::CoalesceMode::Adaptive`] installs a sizing policy
    /// ([`AdaptiveCoalesce`]) that shrinks it on an idle medium.
    fn coalesce_take(&mut self, _ctx: &OffloadCtx<'_>, _target: usize, run_len: usize) -> usize {
        run_len
    }
}

/// Algs 3/4 seam: one adaptation step per tick at an admitting source.
pub trait AdaptPolicy: Send + std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// One step from the source's queue occupancy I_n + O_n.
    fn update(&mut self, queue_total: usize);

    /// Current interarrival time μ, if this policy adapts the rate.
    fn mu_s(&self) -> Option<f64>;

    /// Current early-exit threshold T_e, if this policy adapts it.
    fn t_e(&self) -> Option<f64>;
}

// ---------------------------------------------------------------------------
// Config surface
// ---------------------------------------------------------------------------

/// Which exit policy the run uses (TOML `[policy] exit`, CLI
/// `--exit-policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitKind {
    /// The paper's Alg. 1 (default).
    Alg1,
    /// Alg. 1 with the offload branch disabled: continuing tasks always
    /// stay local (ablation: what is offloading worth?).
    LocalOnly,
}

/// Which offload policy the run uses (TOML `[policy] offload` or the
/// legacy top-level `offload_policy`, CLI `--offload-policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadKind {
    /// The paper's Alg. 2 over a shuffled one-hop scan (default).
    Alg2,
    /// Alg. 2 without the probabilistic branch.
    Deterministic,
    /// Queue-length gate only.
    QueueOnly,
    /// Push to a random neighbor regardless of state.
    RoundRobin,
    /// Offload by remaining deadline slack vs. remote wait.
    DeadlineAware,
    /// Alg. 2 first, then push toward remote under-loaded regions through
    /// the next-hop table.
    MultiHop,
}

/// Which adaptation policy sources run (TOML `[policy] adapt`). The
/// admission mode decides *what* is adapted (μ vs. T_e); the kind decides
/// *how*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptKind {
    /// The paper's AIMD-style Algs 3/4 (the only kind today; the seam is
    /// what matters).
    Aimd,
}

/// The run's policy selection, consumed by `WorkerCore` at construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PolicyConfig {
    pub exit: ExitKind,
    pub offload: OffloadKind,
    pub adapt: AdaptKind,
}

impl Default for PolicyConfig {
    /// The paper's algorithms, exactly.
    fn default() -> PolicyConfig {
        PolicyConfig { exit: ExitKind::Alg1, offload: OffloadKind::Alg2, adapt: AdaptKind::Aimd }
    }
}

impl PolicyConfig {
    pub fn parse_exit(name: &str) -> Result<ExitKind> {
        Ok(match name {
            "alg1" => ExitKind::Alg1,
            "local-only" => ExitKind::LocalOnly,
            other => bail!("unknown exit policy {other:?} (alg1|local-only)"),
        })
    }

    pub fn parse_offload(name: &str) -> Result<OffloadKind> {
        Ok(match name {
            "alg2" => OffloadKind::Alg2,
            "deterministic" => OffloadKind::Deterministic,
            "queue-only" => OffloadKind::QueueOnly,
            "round-robin" => OffloadKind::RoundRobin,
            "deadline-aware" => OffloadKind::DeadlineAware,
            "multi-hop" => OffloadKind::MultiHop,
            other => bail!(
                "unknown offload policy {other:?} \
                 (alg2|deterministic|queue-only|round-robin|deadline-aware|multi-hop)"
            ),
        })
    }

    pub fn parse_adapt(name: &str) -> Result<AdaptKind> {
        Ok(match name {
            "aimd" => AdaptKind::Aimd,
            other => bail!("unknown adapt policy {other:?} (aimd)"),
        })
    }

    /// Build the exit policy object for one worker.
    pub fn build_exit(&self) -> Box<dyn ExitPolicy> {
        match self.exit {
            ExitKind::Alg1 => Box::new(BaselineExit),
            ExitKind::LocalOnly => Box::new(LocalOnlyExit),
        }
    }

    /// Build the offload policy object for worker `id`. `num_workers` is
    /// the topology size (multi-hop policies track per-node state);
    /// routing arrives per decision via [`OffloadCtx::next_hop`].
    pub fn build_offload(&self, id: usize, num_workers: usize) -> Box<dyn OffloadPolicy> {
        match self.offload {
            OffloadKind::Alg2 => Box::new(BaselineOffload::new(OffloadRule::Alg2)),
            OffloadKind::Deterministic => {
                Box::new(BaselineOffload::new(OffloadRule::Deterministic))
            }
            OffloadKind::QueueOnly => Box::new(BaselineOffload::new(OffloadRule::QueueOnly)),
            OffloadKind::RoundRobin => Box::new(BaselineOffload::new(OffloadRule::RoundRobin)),
            OffloadKind::DeadlineAware => Box::new(DeadlineAware::new()),
            OffloadKind::MultiHop => Box::new(MultiHop::new(id, num_workers)),
        }
    }

    /// Build the adaptation policy for an admitting source, per the run's
    /// admission mode (`None` for modes that adapt nothing).
    pub fn build_adapt(
        &self,
        admission: &crate::coordinator::config::AdmissionMode,
        adapt: AdaptConfig,
    ) -> Option<Box<dyn AdaptPolicy>> {
        use crate::coordinator::config::AdmissionMode;
        match (self.adapt, admission) {
            (AdaptKind::Aimd, AdmissionMode::AdaptiveRate { initial_mu_s, .. }) => {
                Some(Box::new(BaselineAdapt::rate(adapt, *initial_mu_s)))
            }
            (AdaptKind::Aimd, AdmissionMode::AdaptiveThreshold { initial_t_e, t_e_min, .. }) => {
                Some(Box::new(BaselineAdapt::threshold(
                    adapt,
                    *initial_t_e as f64,
                    *t_e_min as f64,
                )))
            }
            (AdaptKind::Aimd, AdmissionMode::Fixed { .. }) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_the_paper() {
        let p = PolicyConfig::default();
        assert_eq!(p.exit, ExitKind::Alg1);
        assert_eq!(p.offload, OffloadKind::Alg2);
        assert_eq!(p.adapt, AdaptKind::Aimd);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(PolicyConfig::parse_exit("alg1").unwrap(), ExitKind::Alg1);
        assert_eq!(PolicyConfig::parse_exit("local-only").unwrap(), ExitKind::LocalOnly);
        assert!(PolicyConfig::parse_exit("nope").is_err());
        for (name, kind) in [
            ("alg2", OffloadKind::Alg2),
            ("deterministic", OffloadKind::Deterministic),
            ("queue-only", OffloadKind::QueueOnly),
            ("round-robin", OffloadKind::RoundRobin),
            ("deadline-aware", OffloadKind::DeadlineAware),
            ("multi-hop", OffloadKind::MultiHop),
        ] {
            assert_eq!(PolicyConfig::parse_offload(name).unwrap(), kind);
        }
        assert!(PolicyConfig::parse_offload("warp").is_err());
        assert_eq!(PolicyConfig::parse_adapt("aimd").unwrap(), AdaptKind::Aimd);
        assert!(PolicyConfig::parse_adapt("pid").is_err());
    }

    #[test]
    fn builders_match_kinds() {
        let p = PolicyConfig::default();
        assert_eq!(p.build_exit().name(), "alg1");
        assert_eq!(p.build_offload(0, 2).name(), "alg2");
        let p = PolicyConfig {
            exit: ExitKind::LocalOnly,
            offload: OffloadKind::MultiHop,
            adapt: AdaptKind::Aimd,
        };
        assert_eq!(p.build_exit().name(), "local-only");
        assert_eq!(p.build_offload(0, 2).name(), "multi-hop");
    }
}
