//! Deadline-aware offloading: pick targets by *remaining slack vs. remote
//! wait*, not by queue length alone.
//!
//! Alg. 2 compares queue lengths and expected waits, but it is blind to
//! *when the task must be done*. With EDF deadlines stamped at admission
//! (`SchedConfig::class_deadline_s`), the head-of-line output task carries
//! an absolute deadline; this policy offloads it to the neighbor with the
//! smallest expected completion time — unconditionally when the local
//! backlog alone would blow the deadline, and only for a *clear* win when
//! the deadline is safe locally (a marginally-faster remote wastes the
//! wire). It consumes two gossip extensions it contributes itself:
//! per-class input occupancy (under deadline-ordered service, only
//! same-or-tighter classes queue ahead of our task, so the wait estimate
//! counts classes `<= task.class` instead of the whole queue) and the
//! neighbor's earliest-deadline slack (`min_slack_s`) — a neighbor
//! already missing its own deadlines is no rescue target.

use super::summary::NeighborSummary;
use super::{LocalState, OffloadCtx, OffloadPolicy};
use crate::util::rng::Pcg64;

/// When the deadline is safe locally, a remote must finish in under this
/// fraction of the local wait before the transfer is worth paying for.
const CLEAR_WIN: f64 = 0.5;

/// Tasks expected to be served before a class-`class` task at a neighbor:
/// with per-class occupancy gossiped, only same-or-higher-priority classes
/// count (deadline-ordered service); otherwise the whole queue.
fn queue_ahead(s: &NeighborSummary, class: u8) -> f64 {
    if s.per_class_input.is_empty() {
        s.input_len as f64
    } else {
        s.per_class_input.iter().take(class as usize + 1).map(|&c| c as f64).sum()
    }
}

/// Expected wait before the *last* of `run_len` tasks sent now would
/// finish at a neighbor: transfer + queued work ahead + the batch's own
/// service (`run_len = 1` is the classic single-task estimate).
fn remote_wait(s: &NeighborSummary, class: u8, run_len: usize) -> f64 {
    s.d_nm_s + (queue_ahead(s, class) + run_len as f64) * s.gamma_s
}

/// Offload the head-of-line task by deadline slack (see module docs).
/// Deterministic: never draws from the RNG, so seeded runs are identical
/// across drivers by construction.
#[derive(Debug, Default)]
pub struct DeadlineAware;

impl DeadlineAware {
    pub fn new() -> DeadlineAware {
        DeadlineAware
    }

    /// The slack-vs-wait decision for a coalescible run of `run_len`
    /// tasks. With `run_len = 1` this is exactly the single-task policy;
    /// a longer run raises both the local and the remote completion
    /// estimates by the batch's own service time, so a batch is only
    /// shipped where the whole run still finishes sooner.
    fn decide(&self, ctx: &OffloadCtx<'_>, run_len: usize) -> Option<usize> {
        let run = run_len.max(1) as f64;
        let slack = ctx.task.deadline - ctx.now;
        // Local completion estimate for the run's last element: the whole
        // input backlog is ahead of reclaimed output tasks, plus the run's
        // own service.
        let local_wait = (ctx.input_len as f64 + run) * ctx.gamma_s;

        // A neighbor already missing its own deadlines is overloaded
        // beyond rescue — dumping more urgent work there helps nobody.
        let (target, w) = ctx
            .candidates
            .iter()
            .filter(|(_, s)| !s.min_slack_s.is_some_and(|ms| ms < 0.0))
            .map(|(m, s)| (*m, remote_wait(s, ctx.task.class, run_len.max(1))))
            .min_by(|a, b| a.1.total_cmp(&b.1))?;

        // Never offload to a slower place; past that, urgency decides:
        // when the local backlog would blow the deadline, the fastest
        // neighbor is the task's best chance, no further questions. When
        // the deadline is safe locally, only a clear win justifies the
        // transfer — shaving a millisecond off a comfortable margin just
        // spends wire the overloaded paths need.
        if w >= local_wait {
            return None;
        }
        if local_wait > slack || w < CLEAR_WIN * local_wait {
            Some(target)
        } else {
            None
        }
    }
}

impl OffloadPolicy for DeadlineAware {
    fn name(&self) -> &'static str {
        "deadline-aware"
    }

    fn annotate(&mut self, summary: &mut NeighborSummary, local: &LocalState<'_>) {
        summary.per_class_input = (0..local.num_classes)
            .map(|c| local.input.class_len(c) as u32)
            .collect();
        summary.min_slack_s =
            Some(local.input.earliest_deadline().map_or(f64::INFINITY, |d| d - local.now));
    }

    fn choose(&mut self, ctx: &OffloadCtx<'_>, _rng: &mut Pcg64) -> Option<usize> {
        self.decide(ctx, 1)
    }

    fn choose_coalesced(
        &mut self,
        ctx: &OffloadCtx<'_>,
        run_len: usize,
        _rng: &mut Pcg64,
    ) -> Option<usize> {
        self.decide(ctx, run_len)
    }
}

#[cfg(test)]
mod tests {
    use super::super::NeighborSummary;
    use super::*;
    use crate::coordinator::task::Task;

    fn ctx<'a>(
        task: &'a Task,
        input_len: usize,
        candidates: &'a [(usize, NeighborSummary)],
    ) -> OffloadCtx<'a> {
        OffloadCtx {
            now: 0.0,
            task,
            input_len,
            output_len: 5,
            gamma_s: 0.01,
            candidates,
            next_hop: &[],
        }
    }

    fn summary(input_len: usize, gamma_s: f64, d: f64) -> NeighborSummary {
        let mut s = NeighborSummary::base(input_len, gamma_s, 0.9);
        s.d_nm_s = d;
        s
    }

    #[test]
    fn offloads_when_local_backlog_blows_the_deadline() {
        // Local: 50 tasks x 10 ms = 510 ms wait vs a 100 ms deadline.
        // Neighbor: idle, 5 ms away -> 15 ms completion. Must offload even
        // though the remote estimate alone would also fit a lazy gate.
        let task = Task { deadline: 0.1, ..Task::initial(1, 0, None, 0.0) };
        let cands = vec![(1usize, summary(0, 0.01, 0.005))];
        let got = DeadlineAware::new().choose(&ctx(&task, 50, &cands), &mut Pcg64::new(1, 0));
        assert_eq!(got, Some(1));
    }

    #[test]
    fn keeps_the_task_when_local_is_fastest() {
        // Empty local queue: 10 ms local vs 60 ms remote — stay.
        let task = Task { deadline: 1.0, ..Task::initial(1, 0, None, 0.0) };
        let cands = vec![(1usize, summary(5, 0.01, 0.0))];
        let got = DeadlineAware::new().choose(&ctx(&task, 0, &cands), &mut Pcg64::new(1, 0));
        assert_eq!(got, None);
    }

    #[test]
    fn keeps_the_task_when_remote_is_slower_despite_ample_slack() {
        // Local 30 ms (comfortably inside the 500 ms slack) vs remote
        // 45 ms: the remote never finishes sooner, so the wire is wasted.
        let task = Task { deadline: 0.5, ..Task::initial(1, 0, None, 0.0) };
        let cands = vec![(1usize, summary(3, 0.01, 0.005))]; // 45 ms remote
        let got = DeadlineAware::new().choose(&ctx(&task, 2, &cands), &mut Pcg64::new(1, 0));
        assert_eq!(got, None, "local 30 ms beats remote 45 ms");
    }

    #[test]
    fn safe_deadline_requires_a_clear_win() {
        // Slack 10 s — the deadline is in no danger locally (200 ms).
        let task = Task { deadline: 10.0, ..Task::initial(1, 0, None, 0.0) };
        // Remote 180 ms: faster, but marginal — keep the task.
        let cands = vec![(1usize, summary(17, 0.01, 0.0))];
        let got = DeadlineAware::new().choose(&ctx(&task, 19, &cands), &mut Pcg64::new(1, 0));
        assert_eq!(got, None, "a marginal win must not pay the wire");
        // Remote 60 ms: under half the local wait — worth the transfer.
        let cands = vec![(1usize, summary(5, 0.01, 0.0))];
        let got = DeadlineAware::new().choose(&ctx(&task, 19, &cands), &mut Pcg64::new(1, 0));
        assert_eq!(got, Some(1), "a clear win is taken even with ample slack");
    }

    #[test]
    fn remote_wait_counts_only_same_or_tighter_classes_when_gossiped() {
        // Neighbor holds 30 queued tasks, but only 2 are class <= 0: under
        // deadline-ordered service a class-0 task jumps the bulk backlog,
        // so the estimate must use the per-class view, not the raw length.
        let urgent = Task { class: 0, deadline: 0.1, ..Task::initial(1, 0, None, 0.0) };
        let mut s = summary(30, 0.01, 0.005);
        s.per_class_input = vec![2, 28];
        let cands = vec![(1usize, s)];
        // Raw length would say 315 ms remote vs 510 ms local wait — but the
        // class-aware estimate is 35 ms, an easy rescue.
        let got =
            DeadlineAware::new().choose(&ctx(&urgent, 50, &cands), &mut Pcg64::new(1, 0));
        assert_eq!(got, Some(1));
        // A class-1 task sees the whole queue ahead of it.
        assert!((queue_ahead(&cands[0].1, 1) - 30.0).abs() < 1e-9);
        assert!((queue_ahead(&cands[0].1, 0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn coalesced_run_raises_the_remote_bar() {
        // Single-task: remote 60 ms beats the clear-win bar against a
        // 200 ms local wait. A run of 12 pushes the remote estimate to
        // 170 ms — no longer a clear win for a safe deadline, so the
        // batch stays (and `choose` == `choose_coalesced(run_len = 1)`).
        let task = Task { deadline: 10.0, ..Task::initial(1, 0, None, 0.0) };
        let cands = vec![(1usize, summary(5, 0.01, 0.0))];
        let mut p = DeadlineAware::new();
        let mut rng = Pcg64::new(1, 0);
        let single = p.choose(&ctx(&task, 19, &cands), &mut rng);
        assert_eq!(single, p.choose_coalesced(&ctx(&task, 19, &cands), 1, &mut rng));
        assert_eq!(single, Some(1));
        let batched = p.choose_coalesced(&ctx(&task, 19, &cands), 12, &mut rng);
        assert_eq!(batched, None, "a long run must not chase a marginal remote win");
    }

    #[test]
    fn skips_neighbors_already_missing_deadlines() {
        let task = Task { deadline: 0.1, ..Task::initial(1, 0, None, 0.0) };
        let mut drowning = summary(0, 0.01, 0.005);
        drowning.min_slack_s = Some(-0.05);
        let mut ok = summary(2, 0.01, 0.005); // slower than the drowning one
        ok.min_slack_s = Some(1.0);
        let cands = vec![(1usize, drowning), (2usize, ok)];
        let got = DeadlineAware::new().choose(&ctx(&task, 50, &cands), &mut Pcg64::new(1, 0));
        assert_eq!(got, Some(2), "the drowning neighbor is not a rescue target");
    }

    #[test]
    fn annotates_slack_and_per_class_occupancy() {
        use crate::sched::QueueDiscipline;
        let mut q = crate::sched::Fifo::new();
        q.push(Task { class: 1, deadline: 0.7, ..Task::initial(1, 0, None, 0.0) });
        q.push(Task { class: 0, deadline: 0.3, ..Task::initial(2, 0, None, 0.0) });
        let local = LocalState {
            id: 0,
            now: 0.1,
            input_len: 2,
            output_len: 0,
            gamma_s: 0.01,
            input: &q,
            num_classes: 2,
        };
        let mut s = NeighborSummary::base(2, 0.01, 0.9);
        DeadlineAware::new().annotate(&mut s, &local);
        assert_eq!(s.per_class_input, vec![1, 1]);
        assert!((s.min_slack_s.unwrap() - 0.2).abs() < 1e-9, "earliest 0.3 at now 0.1");
        assert_eq!(s.encoded_bytes(), 32 + 8 + 8, "two classes + slack on the wire");
    }
}
