//! The paper's four algorithms as pure, driver-agnostic decision logic.
//!
//! Keeping Algs 1–4 free of threads, clocks, and I/O lets the exact same
//! code run under the discrete-event driver (benches, virtual time) and the
//! realtime threaded driver (examples, PJRT engine), and makes every branch
//! unit- and property-testable in isolation.
//!
//! These free functions are the *reference semantics* of the pluggable
//! [`super`] policy traits: [`super::BaselineExit`] and
//! [`super::BaselineOffload`] are required (and property-tested) to
//! reproduce `alg1_decide` / `alg2_should_offload` bit for bit, so the
//! trait seam can never drift from the paper's algorithms unnoticed.

use crate::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// Algorithm 1 — Inference and Early-Exit (the queue-placement decision)
// ---------------------------------------------------------------------------

/// Outcome of processing task τ_k at a worker (Alg. 1 lines 5–12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitDecision {
    /// C_k(d) > T_e^k: exit, return the classifier output to the source.
    Exit,
    /// Keep τ_{k+1}(d) locally (input queue empty, or output queue backed up).
    ContinueLocal,
    /// Queue τ_{k+1}(d) for offloading.
    ContinueOffload,
}

/// Alg. 1 lines 5–12: given confidence C_k(d) at exit k, the early-exit
/// threshold T_e^k, whether this was the final exit, and the worker's queue
/// state, decide what happens to data d.
///
/// * line 5: `confidence > threshold` → Exit (also forced at the last exit
///   point, where the DNN output is final by definition);
/// * line 8: input queue empty (local compute is starving) **or** output
///   queue above T_O (offload path is backed up) → keep τ_{k+1} local;
/// * otherwise → put τ_{k+1} in the output queue for offloading.
pub fn alg1_decide(
    confidence: f32,
    threshold: f32,
    is_final_exit: bool,
    input_len: usize,
    output_len: usize,
    t_o: usize,
) -> ExitDecision {
    if is_final_exit || confidence > threshold {
        return ExitDecision::Exit;
    }
    if input_len == 0 || output_len > t_o {
        ExitDecision::ContinueLocal
    } else {
        ExitDecision::ContinueOffload
    }
}

// ---------------------------------------------------------------------------
// Algorithm 2 — Offloading from worker n to neighbor m
// ---------------------------------------------------------------------------

/// What worker n knows about a one-hop neighbor m (gossiped state).
#[derive(Debug, Clone, Copy)]
pub struct NeighborView {
    /// Neighbor's input queue size I_m.
    pub input_len: usize,
    /// Neighbor's per-task compute delay Γ_m, seconds.
    pub gamma_s: f64,
    /// Measured transfer delay D_nm to this neighbor, seconds.
    pub d_nm_s: f64,
}

/// Alg. 2 for a single head-of-line task against one neighbor:
///
/// * gate (line 2/4): `O_n > I_m` — only offload toward someone less loaded;
/// * line 2-3: local wait `I_n·Γ_n` exceeds remote `D_nm + I_m·Γ_m` → offload;
/// * line 4-5: otherwise offload with probability
///   `min(I_n·Γ_n / (D_nm + I_m·Γ_m), 1)` — the probabilistic branch that
///   keeps utilizing resources when the two delays are comparable.
pub fn alg2_should_offload(
    output_len: usize,
    input_len: usize,
    gamma_n_s: f64,
    view: &NeighborView,
    rng: &mut Pcg64,
) -> bool {
    if output_len <= view.input_len {
        return false;
    }
    let local_wait = input_len as f64 * gamma_n_s;
    let remote_wait = view.d_nm_s + view.input_len as f64 * view.gamma_s;
    if local_wait > remote_wait {
        return true;
    }
    let p = if remote_wait <= 0.0 { 1.0 } else { (local_wait / remote_wait).min(1.0) };
    rng.chance(p)
}

/// Per-neighbor offload decision rule used by the baseline policy family
/// (ablation `abl-offload`, DESIGN.md §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadRule {
    /// The paper's Alg. 2 (deterministic + probabilistic branches).
    Alg2,
    /// Alg. 2 without line 5 (offload only when strictly faster) — shows
    /// why the probabilistic branch exists.
    Deterministic,
    /// Naive: offload to the first neighbor whenever O_n > I_m, ignoring
    /// delays entirely.
    QueueOnly,
    /// Round-robin to neighbors regardless of state (DDI-style push).
    RoundRobin,
}

/// Apply the selected offload rule for one candidate neighbor.
pub fn offload_decide(
    policy: OffloadRule,
    output_len: usize,
    input_len: usize,
    gamma_n_s: f64,
    view: &NeighborView,
    rng: &mut Pcg64,
) -> bool {
    match policy {
        OffloadRule::Alg2 => {
            alg2_should_offload(output_len, input_len, gamma_n_s, view, rng)
        }
        OffloadRule::Deterministic => {
            output_len > view.input_len
                && input_len as f64 * gamma_n_s
                    > view.d_nm_s + view.input_len as f64 * view.gamma_s
        }
        OffloadRule::QueueOnly => output_len > view.input_len,
        OffloadRule::RoundRobin => true,
    }
}

// ---------------------------------------------------------------------------
// Algorithm 3 — Data interarrival-time adaptation at the source
// ---------------------------------------------------------------------------

/// Shared AIMD-style constants of Algs 3 and 4 (paper §V: T_Q1=10, T_Q2=30,
/// α=0.2, β=0.1, ζ=0.2).
#[derive(Debug, Clone, Copy)]
pub struct AdaptConfig {
    pub t_q1: usize,
    pub t_q2: usize,
    pub alpha: f64,
    pub beta: f64,
    pub zeta: f64,
    /// Sleep duration s between adaptation steps, seconds.
    pub sleep_s: f64,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig { t_q1: 10, t_q2: 30, alpha: 0.2, beta: 0.1, zeta: 0.2, sleep_s: 0.5 }
    }
}

impl AdaptConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.t_q1 > self.t_q2 {
            return Err(format!("T_Q1 {} > T_Q2 {}", self.t_q1, self.t_q2));
        }
        for (name, v) in [("alpha", self.alpha), ("beta", self.beta), ("zeta", self.zeta)] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("{name} {v} outside (0,1)"));
            }
        }
        if self.alpha <= self.beta {
            return Err(format!("alpha {} must exceed beta {}", self.alpha, self.beta));
        }
        if self.sleep_s <= 0.0 {
            return Err("sleep_s must be positive".into());
        }
        Ok(())
    }
}

/// Alg. 3: adapts the data interarrival time μ from the source's queue
/// occupancy, TCP-Vegas style. Fixed accuracy (threshold), variable rate.
#[derive(Debug, Clone)]
pub struct RateController {
    cfg: AdaptConfig,
    mu_s: f64,
    mu_min_s: f64,
    mu_max_s: f64,
}

impl RateController {
    pub fn new(cfg: AdaptConfig, initial_mu_s: f64) -> RateController {
        // μ bounds keep the controller numerically sane: the paper leaves μ
        // unbounded, but a multiplicative-decrease rule can underflow once
        // queues saturate the measurement window.
        RateController { cfg, mu_s: initial_mu_s, mu_min_s: 1e-4, mu_max_s: 60.0 }
    }

    /// One adaptation step given the source's I_n + O_n; returns the new μ.
    /// The caller is responsible for sleeping `cfg.sleep_s` between calls
    /// (line "Sleep for s seconds" — virtual or real depending on driver).
    pub fn update(&mut self, queue_total: usize) -> f64 {
        let q = queue_total;
        if q < self.cfg.t_q1 {
            self.mu_s -= self.cfg.alpha * self.mu_s; // line 3: strong increase in rate
        } else if q > self.cfg.t_q1 && q < self.cfg.t_q2 {
            self.mu_s -= self.cfg.beta * self.mu_s; // line 5: gentle increase
        } else if q > self.cfg.t_q2 {
            self.mu_s += self.cfg.zeta * self.mu_s; // line 7: back off
        }
        // q == t_q1 or q == t_q2: no change (the paper's conditions are strict)
        self.mu_s = self.mu_s.clamp(self.mu_min_s, self.mu_max_s);
        self.mu_s
    }

    pub fn mu_s(&self) -> f64 {
        self.mu_s
    }

    /// Current data rate 1/μ (samples per second).
    pub fn rate_hz(&self) -> f64 {
        1.0 / self.mu_s
    }

    pub fn sleep_s(&self) -> f64 {
        self.cfg.sleep_s
    }
}

// ---------------------------------------------------------------------------
// Algorithm 4 — Early-exit threshold adaptation
// ---------------------------------------------------------------------------

/// Alg. 4: all arriving traffic must be admitted (Poisson at fixed mean
/// rate); the confidence threshold T_e — hence accuracy — adapts instead.
#[derive(Debug, Clone)]
pub struct ThresholdController {
    cfg: AdaptConfig,
    t_e: f64,
    t_e_min: f64,
}

impl ThresholdController {
    pub fn new(cfg: AdaptConfig, initial_t_e: f64, t_e_min: f64) -> ThresholdController {
        assert!(t_e_min > 0.0, "paper requires T_e^min > 0");
        ThresholdController { cfg, t_e: initial_t_e.clamp(t_e_min, 1.0), t_e_min }
    }

    /// One adaptation step from queue occupancy; returns the new T_e
    /// (applied to every exit point k — Alg. 4 line 9).
    pub fn update(&mut self, queue_total: usize) -> f64 {
        let q = queue_total;
        if q < self.cfg.t_q1 {
            self.t_e = (self.t_e + self.cfg.alpha * self.t_e).min(1.0); // line 3
        } else if q > self.cfg.t_q1 && q < self.cfg.t_q2 {
            self.t_e = (self.t_e + self.cfg.beta * self.t_e).min(1.0); // line 5
        } else if q > self.cfg.t_q2 {
            self.t_e = (self.t_e - self.cfg.zeta * self.t_e).max(self.t_e_min); // line 7
        }
        self.t_e
    }

    pub fn t_e(&self) -> f64 {
        self.t_e
    }

    pub fn sleep_s(&self) -> f64 {
        self.cfg.sleep_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- Alg. 1 decision table ------------------------------------------

    #[test]
    fn alg1_exits_on_confidence() {
        let d = alg1_decide(0.95, 0.9, false, 3, 0, 50);
        assert_eq!(d, ExitDecision::Exit);
    }

    #[test]
    fn alg1_threshold_is_strict() {
        // C == T_e does NOT exit (paper: "larger than")
        let d = alg1_decide(0.9, 0.9, false, 3, 0, 50);
        assert_ne!(d, ExitDecision::Exit);
    }

    #[test]
    fn alg1_final_exit_always_exits() {
        let d = alg1_decide(0.01, 0.99, true, 0, 0, 50);
        assert_eq!(d, ExitDecision::Exit);
    }

    #[test]
    fn alg1_empty_input_continues_local() {
        let d = alg1_decide(0.1, 0.9, false, 0, 10, 50);
        assert_eq!(d, ExitDecision::ContinueLocal);
    }

    #[test]
    fn alg1_backed_up_output_continues_local() {
        let d = alg1_decide(0.1, 0.9, false, 5, 51, 50);
        assert_eq!(d, ExitDecision::ContinueLocal);
    }

    #[test]
    fn alg1_otherwise_offloads() {
        let d = alg1_decide(0.1, 0.9, false, 5, 10, 50);
        assert_eq!(d, ExitDecision::ContinueOffload);
    }

    // ---- Alg. 2 ----------------------------------------------------------

    fn view(input_len: usize, gamma_s: f64, d_nm_s: f64) -> NeighborView {
        NeighborView { input_len, gamma_s, d_nm_s }
    }

    #[test]
    fn alg2_gate_requires_o_n_above_i_m() {
        let mut rng = Pcg64::new(1, 0);
        // O_n = 2 <= I_m = 5: never offload no matter how slow we are
        assert!(!alg2_should_offload(2, 100, 10.0, &view(5, 0.001, 0.001), &mut rng));
        // equality also refuses (strict >)
        assert!(!alg2_should_offload(5, 100, 10.0, &view(5, 0.001, 0.001), &mut rng));
    }

    #[test]
    fn alg2_deterministic_branch() {
        let mut rng = Pcg64::new(1, 0);
        // I_n*Γ_n = 10*1.0 = 10s  >  D + I_m*Γ_m = 0.1 + 1*0.5 = 0.6s
        assert!(alg2_should_offload(5, 10, 1.0, &view(1, 0.5, 0.1), &mut rng));
    }

    #[test]
    fn alg2_probabilistic_branch_statistics() {
        // local 0.5s vs remote 1.0s → p = 0.5
        let mut rng = Pcg64::new(2, 0);
        let n = 20_000;
        let hits = (0..n)
            .filter(|_| alg2_should_offload(5, 1, 0.5, &view(0, 0.5, 1.0), &mut rng))
            .count();
        let p = hits as f64 / n as f64;
        assert!((p - 0.5).abs() < 0.02, "p = {p}");
    }

    #[test]
    fn alg2_zero_remote_wait_offloads() {
        let mut rng = Pcg64::new(3, 0);
        assert!(alg2_should_offload(5, 0, 0.5, &view(0, 0.5, 0.0), &mut rng));
    }

    #[test]
    fn policy_variants_differ() {
        let mut rng = Pcg64::new(4, 0);
        let v = view(0, 0.5, 1.0); // remote slower than empty local
        // local wait = 0 → deterministic refuses, queue-only accepts
        assert!(!offload_decide(OffloadRule::Deterministic, 5, 0, 0.5, &v, &mut rng));
        assert!(offload_decide(OffloadRule::QueueOnly, 5, 0, 0.5, &v, &mut rng));
        assert!(offload_decide(OffloadRule::RoundRobin, 0, 0, 0.5, &v, &mut rng));
    }

    // ---- Alg. 3 ----------------------------------------------------------

    #[test]
    fn alg3_regions() {
        let cfg = AdaptConfig::default();
        let mut rc = RateController::new(cfg, 1.0);
        // q < T_Q1: μ -= α μ → 0.8
        assert!((rc.update(0) - 0.8).abs() < 1e-12);
        // T_Q1 < q < T_Q2: μ -= β μ → 0.72
        assert!((rc.update(15) - 0.72).abs() < 1e-12);
        // q > T_Q2: μ += ζ μ → 0.864
        assert!((rc.update(40) - 0.864).abs() < 1e-12);
        // boundary q == T_Q1: unchanged
        assert!((rc.update(10) - 0.864).abs() < 1e-12);
    }

    #[test]
    fn alg3_mu_stays_bounded() {
        let mut rc = RateController::new(AdaptConfig::default(), 1.0);
        for _ in 0..10_000 {
            rc.update(0);
        }
        assert!(rc.mu_s() >= 1e-4);
        for _ in 0..10_000 {
            rc.update(1000);
        }
        assert!(rc.mu_s() <= 60.0);
    }

    #[test]
    fn alg3_converges_to_equilibrium_band() {
        // Toy closed loop: service rate 20 Hz; queue integrates arrivals -
        // service. Alg. 3 should settle μ near 1/20 s.
        let mut rc = RateController::new(AdaptConfig::default(), 1.0);
        let mut queue = 0.0f64;
        let service_hz = 20.0;
        for _ in 0..400 {
            let mu = rc.mu_s();
            let dt = rc.sleep_s();
            queue = (queue + dt / mu - service_hz * dt).max(0.0);
            rc.update(queue.round() as usize);
        }
        let rate = rc.rate_hz();
        assert!(
            (10.0..40.0).contains(&rate),
            "rate {rate} did not settle near service 20 Hz"
        );
    }

    // ---- Alg. 4 ----------------------------------------------------------

    #[test]
    fn alg4_regions_and_caps() {
        let cfg = AdaptConfig::default();
        let mut tc = ThresholdController::new(cfg, 0.5, 0.05);
        // low occupancy: up by alpha
        assert!((tc.update(0) - 0.6).abs() < 1e-12);
        // mid: up by beta
        assert!((tc.update(15) - 0.66).abs() < 1e-12);
        // high: down by zeta
        assert!((tc.update(40) - 0.528).abs() < 1e-12);
        // cap at 1.0
        for _ in 0..100 {
            tc.update(0);
        }
        assert!(tc.t_e() <= 1.0);
        // floor at t_e_min
        for _ in 0..100 {
            tc.update(1000);
        }
        assert!((tc.t_e() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn adapt_config_validation() {
        assert!(AdaptConfig::default().validate().is_ok());
        let bad = AdaptConfig { t_q1: 50, ..AdaptConfig::default() };
        assert!(bad.validate().is_err());
        let bad = AdaptConfig { alpha: 0.1, beta: 0.2, ..AdaptConfig::default() };
        assert!(bad.validate().is_err());
        let bad = AdaptConfig { zeta: 1.5, ..AdaptConfig::default() };
        assert!(bad.validate().is_err());
    }
}
