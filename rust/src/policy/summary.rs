//! Extensible gossiped worker state — the wire form of "what worker n
//! knows about neighbor m".
//!
//! The paper's gossip carries exactly `{I_m, Γ_m}` (§IV.A). Policies need
//! more: deadline-aware offloading wants the neighbor's earliest-deadline
//! slack and per-class occupancy, multi-hop offloading wants a transitive
//! view of load *beyond* the one-hop horizon. [`NeighborSummary`] is the
//! open container both ride in: the base fields are always present (and
//! encode to the seed's fixed 32-byte state message), optional fields are
//! contributed by the run's [`super::OffloadPolicy`] via
//! [`super::OffloadPolicy::annotate`], and the wire charge is the *actual*
//! encoded size ([`NeighborSummary::encoded_bytes`]) — both drivers carry
//! gossip as a real transfer at that size (virtual link delay under DES,
//! wallclock framing under the realtime transport) and count it into
//! per-worker `gossip_bytes`, replacing the old constant-size, cost-free
//! accounting that under-charged any summary richer than the paper's.

/// Fixed wire size of the base fields (I_m + Γ_m + T_e + framing) —
/// identical to the seed's `STATE_BYTES`, so a run that gossips nothing
/// but the paper's state charges exactly what the seed charged.
pub const BASE_SUMMARY_BYTES: usize = 32;
/// Wire bytes per per-class occupancy entry (u32).
const PER_CLASS_ENTRY_BYTES: usize = 4;
/// Wire bytes for the earliest-deadline slack field (f64).
const SLACK_BYTES: usize = 8;
/// Wire bytes per transitive region-load entry (node u16 + load u32 +
/// hops u8 + pad).
const REGION_ENTRY_BYTES: usize = 8;
/// Wire bytes for the cluster heartbeat sequence number (u64). Only
/// charged when the control plane is on and stamps it — default runs
/// gossip exactly the seed's bytes.
const HEARTBEAT_BYTES: usize = 8;

/// One node's load as seen (possibly several hops away) by a gossiping
/// worker: the payload of the multi-hop region table.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RegionLoad {
    /// Topology node the entry describes.
    pub node: usize,
    /// That node's input-queue length when the entry was minted.
    pub input_len: usize,
    /// Gossip hops the entry has travelled (0 = the node itself minted it).
    pub hops: u8,
}

/// Gossiped neighbor state: the paper's base fields plus whatever the
/// run's policies contribute. `d_nm_s` is *receiver-local* (the transfer
/// estimate to the sender) and never travels the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct NeighborSummary {
    /// Neighbor's input queue size I_m.
    pub input_len: usize,
    /// Neighbor's per-task compute delay Γ_m, seconds.
    pub gamma_s: f64,
    /// Sender's current early-exit threshold T_e (Alg. 4 line 9 rides the
    /// same gossip in both drivers).
    pub t_e: f32,
    /// Measured transfer delay D_nm to this neighbor, seconds. Filled by
    /// the *receiver* from its own estimator — not encoded.
    pub d_nm_s: f64,
    /// Per-class input occupancy (empty unless a class-aware policy
    /// contributes it).
    pub per_class_input: Vec<u32>,
    /// Slack of the earliest deadline queued at the sender, seconds
    /// (negative = the sender is already missing deadlines). Contributed
    /// by deadline-aware policies.
    pub min_slack_s: Option<f64>,
    /// Transitively aggregated load of nodes *beyond* the sender, for
    /// multi-hop offloading. Entries describe nodes other than the sender
    /// (whose own load is `input_len`).
    pub region: Vec<RegionLoad>,
    /// Cluster heartbeat sequence number, stamped by the sender once per
    /// minted summary when the elastic control plane is enabled
    /// (`crate::cluster`). The receiver's health checker treats a strictly
    /// increasing beat as proof of life; `None` (the default) keeps the
    /// summary — and its wire charge — exactly at the seed's.
    pub beat: Option<u64>,
}

impl NeighborSummary {
    /// A summary carrying only the paper's base fields.
    pub fn base(input_len: usize, gamma_s: f64, t_e: f32) -> NeighborSummary {
        NeighborSummary {
            input_len,
            gamma_s,
            t_e,
            d_nm_s: 0.0,
            per_class_input: Vec::new(),
            min_slack_s: None,
            region: Vec::new(),
            beat: None,
        }
    }

    /// Actual encoded size on the wire. This is what both drivers charge:
    /// the realtime transport frames the link delay with it and the cores
    /// count it into `gossip_bytes`, so a policy that inflates the summary
    /// pays for the inflation instead of hiding behind a constant.
    pub fn encoded_bytes(&self) -> usize {
        BASE_SUMMARY_BYTES
            + self.per_class_input.len() * PER_CLASS_ENTRY_BYTES
            + self.min_slack_s.map_or(0, |_| SLACK_BYTES)
            + self.region.len() * REGION_ENTRY_BYTES
            + self.beat.map_or(0, |_| HEARTBEAT_BYTES)
    }

    /// Overwrite `self` with `src`, reusing the existing `Vec`
    /// allocations (the offload hot path refreshes a retained candidate
    /// buffer once per scan; a plain `clone` would re-allocate the
    /// per-class and region tables every time).
    pub fn copy_from(&mut self, src: &NeighborSummary) {
        self.input_len = src.input_len;
        self.gamma_s = src.gamma_s;
        self.t_e = src.t_e;
        self.d_nm_s = src.d_nm_s;
        self.per_class_input.clone_from(&src.per_class_input);
        self.min_slack_s = src.min_slack_s;
        self.region.clone_from(&src.region);
        self.beat = src.beat;
    }

    /// The base-field view the pure Alg. 2 functions consume.
    pub fn view(&self) -> super::alg::NeighborView {
        super::alg::NeighborView {
            input_len: self.input_len,
            gamma_s: self.gamma_s,
            d_nm_s: self.d_nm_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_summary_encodes_to_seed_state_bytes() {
        let s = NeighborSummary::base(5, 0.01, 0.9);
        assert_eq!(s.encoded_bytes(), 32, "paper-only gossip costs what the seed charged");
    }

    #[test]
    fn optional_fields_grow_the_wire_charge() {
        let mut s = NeighborSummary::base(5, 0.01, 0.9);
        s.per_class_input = vec![3, 2];
        assert_eq!(s.encoded_bytes(), 32 + 8);
        s.min_slack_s = Some(0.04);
        assert_eq!(s.encoded_bytes(), 32 + 8 + 8);
        s.region = vec![
            RegionLoad { node: 3, input_len: 0, hops: 1 },
            RegionLoad { node: 4, input_len: 7, hops: 2 },
        ];
        assert_eq!(s.encoded_bytes(), 32 + 8 + 8 + 16);
        s.beat = Some(12);
        assert_eq!(s.encoded_bytes(), 32 + 8 + 8 + 16 + 8, "heartbeat charges 8 B when stamped");
    }

    #[test]
    fn copy_from_mirrors_clone() {
        let mut src = NeighborSummary::base(5, 0.02, 0.8);
        src.d_nm_s = 0.004;
        src.per_class_input = vec![3, 2];
        src.min_slack_s = Some(0.1);
        src.region = vec![RegionLoad { node: 2, input_len: 9, hops: 1 }];
        src.beat = Some(3);
        let mut dst = NeighborSummary::base(0, 0.01, 0.9);
        dst.per_class_input = vec![7; 8]; // stale content must be replaced
        dst.copy_from(&src);
        assert_eq!(dst, src);
        // And copying a lean summary over a rich one trims it back.
        let lean = NeighborSummary::base(1, 0.03, 0.7);
        dst.copy_from(&lean);
        assert_eq!(dst, lean);
    }

    #[test]
    fn receiver_local_delay_is_not_charged() {
        let mut a = NeighborSummary::base(5, 0.01, 0.9);
        let bytes = a.encoded_bytes();
        a.d_nm_s = 0.25;
        assert_eq!(a.encoded_bytes(), bytes, "d_nm_s never travels the wire");
    }

    #[test]
    fn view_projects_base_fields() {
        let mut s = NeighborSummary::base(7, 0.02, 0.8);
        s.d_nm_s = 0.005;
        let v = s.view();
        assert_eq!(v.input_len, 7);
        assert!((v.gamma_s - 0.02).abs() < 1e-12);
        assert!((v.d_nm_s - 0.005).abs() < 1e-12);
    }
}
