//! Adaptive coalesced-run sizing — the [`crate::sched::CoalesceMode::Adaptive`]
//! policy object.
//!
//! Fixed-size coalescing (`coalesce = stage`, cap `coalesce_max`) is a
//! blunt knob: on an *idle* medium k singles pipeline — the first task is
//! already in flight while the k-th is still queued — so one long envelope
//! only adds head-of-line latency for 32·(k−1) saved header bytes. Under
//! *contention* the trade flips: every envelope pays a contention slot on
//! the shared medium, so k tasks in one frame cost one slot where k
//! singles cost k.
//!
//! [`AdaptiveCoalesce`] reads that regime off the run's own D_nm
//! estimator. Each offload decision sees the freshest per-neighbor
//! transfer estimate in [`OffloadCtx::candidates`]; the wrapper tracks the
//! best (smallest) delay it has ever observed per link — the link's
//! *uncontended floor* — and sizes the drained run by how far the current
//! estimate has inflated over that floor:
//!
//! * `pressure = d_nm / floor ≤ 1.25` — idle medium: ship singles;
//! * `pressure ≥ 3.0` — saturated: drain the whole priced run;
//! * in between: scale linearly.
//!
//! The wrapper decorates the run's configured [`OffloadPolicy`] (it
//! delegates every offload decision, gossip hook, and the RNG stream
//! untouched) and only implements the [`OffloadPolicy::coalesce_take`]
//! sizing seam, so it composes with any offload policy. Fully
//! deterministic: no RNG, state updates only from the candidate views the
//! decision itself was handed.

use super::{NeighborSummary, OffloadCtx, OffloadPolicy};
use crate::util::rng::Pcg64;

/// D_nm inflation at (or below) which the medium counts as idle and the
/// run ships as singles.
const PRESSURE_LO: f64 = 1.25;
/// D_nm inflation at (or above) which the whole priced run is drained.
const PRESSURE_HI: f64 = 3.0;

/// Decorator around the run's offload policy that sizes coalesced runs
/// from measured link contention (see module docs).
#[derive(Debug)]
pub struct AdaptiveCoalesce {
    inner: Box<dyn OffloadPolicy>,
    /// Best-observed (smallest) D_nm per topology node, seconds —
    /// `INFINITY` until a link has ever been measured.
    floor: Vec<f64>,
}

impl AdaptiveCoalesce {
    pub fn new(inner: Box<dyn OffloadPolicy>) -> AdaptiveCoalesce {
        AdaptiveCoalesce { inner, floor: Vec::new() }
    }

    fn note_floor(&mut self, node: usize, d_nm_s: f64) {
        if !(d_nm_s.is_finite() && d_nm_s > 0.0) {
            return;
        }
        if node >= self.floor.len() {
            self.floor.resize(node + 1, f64::INFINITY);
        }
        if d_nm_s < self.floor[node] {
            self.floor[node] = d_nm_s;
        }
    }

    /// Current D_nm inflation of the link to `target`, `None` until both
    /// a floor and a fresh estimate exist.
    fn pressure(&self, ctx: &OffloadCtx<'_>, target: usize) -> Option<f64> {
        let d = ctx
            .candidates
            .iter()
            .find(|(m, _)| *m == target)
            .map(|(_, s)| s.d_nm_s)?;
        let floor = self.floor.get(target).copied()?;
        if floor.is_finite() && floor > 0.0 && d.is_finite() && d > 0.0 {
            Some(d / floor)
        } else {
            None
        }
    }
}

impl OffloadPolicy for AdaptiveCoalesce {
    /// The offload decisions are the inner policy's; reports name those.
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn observe(&mut self, from: usize, summary: &NeighborSummary, now: f64) {
        self.inner.observe(from, summary, now);
    }

    fn annotate(&mut self, summary: &mut NeighborSummary, local: &super::LocalState<'_>) {
        self.inner.annotate(summary, local);
    }

    fn forget(&mut self, node: usize) {
        // A churned-out slot may be reused by a respawn with a different
        // link: its floor must not survive.
        if let Some(f) = self.floor.get_mut(node) {
            *f = f64::INFINITY;
        }
        self.inner.forget(node);
    }

    fn choose(&mut self, ctx: &OffloadCtx<'_>, rng: &mut Pcg64) -> Option<usize> {
        self.inner.choose(ctx, rng)
    }

    fn choose_coalesced(
        &mut self,
        ctx: &OffloadCtx<'_>,
        run_len: usize,
        rng: &mut Pcg64,
    ) -> Option<usize> {
        // The decision's candidate views are the only place D_nm is
        // visible to a policy: refresh the per-link floors here.
        for (m, s) in ctx.candidates {
            self.note_floor(*m, s.d_nm_s);
        }
        self.inner.choose_coalesced(ctx, run_len, rng)
    }

    fn coalesce_take(&mut self, ctx: &OffloadCtx<'_>, target: usize, run_len: usize) -> usize {
        if run_len <= 1 {
            return run_len;
        }
        match self.pressure(ctx, target) {
            // An unmeasured link gives no contention signal: behave like
            // plain `stage` coalescing rather than guessing idle.
            None => run_len,
            Some(p) => {
                let frac =
                    ((p - PRESSURE_LO) / (PRESSURE_HI - PRESSURE_LO)).clamp(0.0, 1.0);
                1 + (frac * (run_len - 1) as f64).round() as usize
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::Task;

    /// Inner stub: always offloads to node 1, counts delegated calls.
    #[derive(Debug, Default)]
    struct Stub {
        chooses: usize,
        observes: usize,
        forgets: usize,
    }

    impl OffloadPolicy for Stub {
        fn name(&self) -> &'static str {
            "stub"
        }
        fn observe(&mut self, _from: usize, _s: &NeighborSummary, _now: f64) {
            self.observes += 1;
        }
        fn forget(&mut self, _node: usize) {
            self.forgets += 1;
        }
        fn choose(&mut self, _ctx: &OffloadCtx<'_>, _rng: &mut Pcg64) -> Option<usize> {
            self.chooses += 1;
            Some(1)
        }
    }

    fn task() -> Task {
        Task::initial(0, 0, None, 0.0)
    }

    fn cand(d_nm_s: f64) -> Vec<(usize, NeighborSummary)> {
        let mut s = NeighborSummary::base(0, 0.01, 0.8);
        s.d_nm_s = d_nm_s;
        vec![(1, s)]
    }

    fn ctx<'a>(
        task: &'a Task,
        candidates: &'a [(usize, NeighborSummary)],
        next_hop: &'a [Option<usize>],
    ) -> OffloadCtx<'a> {
        OffloadCtx {
            now: 0.0,
            task,
            input_len: 0,
            output_len: 4,
            gamma_s: 0.01,
            candidates,
            next_hop,
        }
    }

    #[test]
    fn idle_medium_ships_singles() {
        let mut p = AdaptiveCoalesce::new(Box::<Stub>::default());
        let t = task();
        let hops = [None, Some(1)];
        let mut rng = Pcg64::new(7, 0);
        // First sight establishes the floor; the same value again means
        // pressure 1.0 — idle.
        let c = cand(0.004);
        assert_eq!(p.choose_coalesced(&ctx(&t, &c, &hops), 8, &mut rng), Some(1));
        assert_eq!(p.coalesce_take(&ctx(&t, &c, &hops), 1, 8), 1);
    }

    #[test]
    fn contended_medium_takes_the_whole_run() {
        let mut p = AdaptiveCoalesce::new(Box::<Stub>::default());
        let t = task();
        let hops = [None, Some(1)];
        let mut rng = Pcg64::new(7, 0);
        let idle = cand(0.004);
        let _ = p.choose_coalesced(&ctx(&t, &idle, &hops), 8, &mut rng);
        // 4x the floor: saturated.
        let busy = cand(0.016);
        let _ = p.choose_coalesced(&ctx(&t, &busy, &hops), 8, &mut rng);
        assert_eq!(p.coalesce_take(&ctx(&t, &busy, &hops), 1, 8), 8);
        // In between: strictly between singles and the full run, and
        // monotone in pressure.
        let mid = cand(0.008);
        let take_mid = p.coalesce_take(&ctx(&t, &mid, &hops), 1, 8);
        assert!((2..8).contains(&take_mid), "mid pressure take {take_mid}");
    }

    #[test]
    fn unmeasured_link_defaults_to_full_run() {
        let mut p = AdaptiveCoalesce::new(Box::<Stub>::default());
        let t = task();
        let hops = [None, Some(1)];
        let c = cand(0.004);
        // No floor yet (choose_coalesced never ran): no signal, full run.
        assert_eq!(p.coalesce_take(&ctx(&t, &c, &hops), 1, 6), 6);
        // Target absent from the candidate list: same.
        assert_eq!(p.coalesce_take(&ctx(&t, &c, &hops), 3, 6), 6);
    }

    #[test]
    fn forget_resets_the_floor_and_delegates() {
        let mut p = AdaptiveCoalesce::new(Box::<Stub>::default());
        let t = task();
        let hops = [None, Some(1)];
        let mut rng = Pcg64::new(7, 0);
        let idle = cand(0.001);
        let _ = p.choose_coalesced(&ctx(&t, &idle, &hops), 8, &mut rng);
        p.forget(1);
        // Floor gone: the old 0.001 no longer makes 0.004 look contended.
        let c = cand(0.004);
        let _ = p.choose_coalesced(&ctx(&t, &c, &hops), 8, &mut rng);
        assert_eq!(p.coalesce_take(&ctx(&t, &c, &hops), 1, 8), 1);
    }

    #[test]
    fn delegates_decisions_to_the_inner_policy() {
        let mut p = AdaptiveCoalesce::new(Box::<Stub>::default());
        let t = task();
        let hops = [None, Some(1)];
        let c = cand(0.004);
        let mut rng = Pcg64::new(7, 0);
        assert_eq!(p.name(), "stub");
        assert_eq!(p.choose(&ctx(&t, &c, &hops), &mut rng), Some(1));
        p.observe(1, &NeighborSummary::base(0, 0.01, 0.8), 0.0);
        // (delegation is observable through the decisions themselves;
        // the stub's counters are internal to it)
    }
}
