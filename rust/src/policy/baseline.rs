//! The baseline policy family: bit-for-bit the pre-refactor behaviour.
//!
//! [`BaselineExit`] is `alg1_decide`, [`BaselineOffload`] is the shuffled
//! one-hop Alg. 2 scan the core used to inline (same candidate order, same
//! shuffle, same per-neighbor rule, so the RNG stream advances identically
//! — property-tested in `tests/prop_coordinator.rs`), and
//! [`BaselineAdapt`] wraps the two AIMD controllers of Algs 3/4. They
//! gossip nothing beyond the paper's base fields, so their summaries
//! encode to exactly the seed's 32 bytes.

use super::alg::{
    alg1_decide, offload_decide, AdaptConfig, ExitDecision, OffloadRule, RateController,
    ThresholdController,
};
use super::{AdaptPolicy, ExitCtx, ExitPolicy, OffloadCtx, OffloadPolicy};
use crate::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// Exit
// ---------------------------------------------------------------------------

/// The paper's Alg. 1, verbatim.
#[derive(Debug, Default, Clone, Copy)]
pub struct BaselineExit;

impl ExitPolicy for BaselineExit {
    fn name(&self) -> &'static str {
        "alg1"
    }

    fn decide(&mut self, ctx: &ExitCtx) -> ExitDecision {
        alg1_decide(
            ctx.confidence,
            ctx.threshold,
            ctx.is_final,
            ctx.input_len,
            ctx.output_len,
            ctx.t_o,
        )
    }
}

/// Alg. 1 with the offload branch disabled: a continuing task always stays
/// local. Ablates what Alg. 2 is worth — with this policy the output queue
/// never fills and no task ever rides the wire.
#[derive(Debug, Default, Clone, Copy)]
pub struct LocalOnlyExit;

impl ExitPolicy for LocalOnlyExit {
    fn name(&self) -> &'static str {
        "local-only"
    }

    fn decide(&mut self, ctx: &ExitCtx) -> ExitDecision {
        match alg1_decide(
            ctx.confidence,
            ctx.threshold,
            ctx.is_final,
            ctx.input_len,
            ctx.output_len,
            ctx.t_o,
        ) {
            ExitDecision::Exit => ExitDecision::Exit,
            _ => ExitDecision::ContinueLocal,
        }
    }
}

// ---------------------------------------------------------------------------
// Offload
// ---------------------------------------------------------------------------

/// The pre-refactor offload scan: shuffle the active neighbors, walk them
/// in shuffled order, and send to the first one the per-neighbor rule
/// accepts. The shuffle and the rule's probabilistic branch draw from the
/// core's RNG in exactly the order the inlined code did.
#[derive(Debug)]
pub struct BaselineOffload {
    rule: OffloadRule,
    /// Scratch for the shuffled candidate indices (avoids an allocation
    /// per offload attempt — the benchmarked hot path).
    scan: Vec<usize>,
}

impl BaselineOffload {
    pub fn new(rule: OffloadRule) -> BaselineOffload {
        BaselineOffload { rule, scan: Vec::new() }
    }

    pub fn rule(&self) -> OffloadRule {
        self.rule
    }
}

impl OffloadPolicy for BaselineOffload {
    fn name(&self) -> &'static str {
        match self.rule {
            OffloadRule::Alg2 => "alg2",
            OffloadRule::Deterministic => "deterministic",
            OffloadRule::QueueOnly => "queue-only",
            OffloadRule::RoundRobin => "round-robin",
        }
    }

    fn choose(&mut self, ctx: &OffloadCtx<'_>, rng: &mut Pcg64) -> Option<usize> {
        self.scan.clear();
        self.scan.extend(0..ctx.candidates.len());
        rng.shuffle(&mut self.scan);
        for &i in &self.scan {
            let (m, summary) = &ctx.candidates[i];
            let go = offload_decide(
                self.rule,
                ctx.output_len,
                ctx.input_len,
                ctx.gamma_s,
                &summary.view(),
                rng,
            );
            if go {
                return Some(*m);
            }
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Adaptation
// ---------------------------------------------------------------------------

/// Algs 3/4 behind the [`AdaptPolicy`] seam: the admission mode decides
/// which of the two AIMD controllers a source runs.
#[derive(Debug)]
pub enum BaselineAdapt {
    /// Alg. 3: fixed threshold, adapt the interarrival time μ.
    Rate(RateController),
    /// Alg. 4: fixed arrivals, adapt the early-exit threshold T_e.
    Threshold(ThresholdController),
}

impl BaselineAdapt {
    pub fn rate(cfg: AdaptConfig, initial_mu_s: f64) -> BaselineAdapt {
        BaselineAdapt::Rate(RateController::new(cfg, initial_mu_s))
    }

    pub fn threshold(cfg: AdaptConfig, initial_t_e: f64, t_e_min: f64) -> BaselineAdapt {
        BaselineAdapt::Threshold(ThresholdController::new(cfg, initial_t_e, t_e_min))
    }
}

impl AdaptPolicy for BaselineAdapt {
    fn name(&self) -> &'static str {
        match self {
            BaselineAdapt::Rate(_) => "aimd-rate",
            BaselineAdapt::Threshold(_) => "aimd-threshold",
        }
    }

    fn update(&mut self, queue_total: usize) {
        match self {
            BaselineAdapt::Rate(rc) => {
                rc.update(queue_total);
            }
            BaselineAdapt::Threshold(tc) => {
                tc.update(queue_total);
            }
        }
    }

    fn mu_s(&self) -> Option<f64> {
        match self {
            BaselineAdapt::Rate(rc) => Some(rc.mu_s()),
            BaselineAdapt::Threshold(_) => None,
        }
    }

    fn t_e(&self) -> Option<f64> {
        match self {
            BaselineAdapt::Rate(_) => None,
            BaselineAdapt::Threshold(tc) => Some(tc.t_e()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::NeighborSummary;
    use super::*;
    use crate::coordinator::task::Task;

    fn ctx<'a>(
        task: &'a Task,
        output_len: usize,
        input_len: usize,
        candidates: &'a [(usize, NeighborSummary)],
        next_hop: &'a [Option<usize>],
    ) -> OffloadCtx<'a> {
        OffloadCtx {
            now: 0.0,
            task,
            input_len,
            output_len,
            gamma_s: 0.01,
            candidates,
            next_hop,
        }
    }

    #[test]
    fn exit_policies_agree_on_exits_and_differ_on_continuation() {
        let c = ExitCtx {
            confidence: 0.1,
            threshold: 0.9,
            is_final: false,
            input_len: 5,
            output_len: 3,
            t_o: 50,
            now: 0.0,
            class: 0,
            deadline: 1.0,
        };
        assert_eq!(BaselineExit.decide(&c), ExitDecision::ContinueOffload);
        assert_eq!(LocalOnlyExit.decide(&c), ExitDecision::ContinueLocal);
        let exit = ExitCtx { confidence: 0.95, ..c };
        assert_eq!(BaselineExit.decide(&exit), ExitDecision::Exit);
        assert_eq!(LocalOnlyExit.decide(&exit), ExitDecision::Exit);
    }

    #[test]
    fn baseline_offload_respects_the_gate() {
        let task = Task::initial(1, 0, None, 0.0);
        // Neighbor more loaded than our output queue: Alg. 2 refuses.
        let cands = vec![(1usize, NeighborSummary::base(50, 0.01, 0.9))];
        let mut p = BaselineOffload::new(OffloadRule::Alg2);
        let mut rng = Pcg64::new(1, 0);
        assert_eq!(p.choose(&ctx(&task, 3, 5, &cands, &[None, Some(1)]), &mut rng), None);
        // Idle neighbor, loaded local queue: deterministic branch fires.
        let cands = vec![(1usize, NeighborSummary::base(0, 0.01, 0.9))];
        assert_eq!(
            p.choose(&ctx(&task, 3, 50, &cands, &[None, Some(1)]), &mut rng),
            Some(1)
        );
    }

    #[test]
    fn round_robin_takes_any_candidate() {
        let task = Task::initial(1, 0, None, 0.0);
        let cands = vec![
            (1usize, NeighborSummary::base(99, 0.01, 0.9)),
            (2usize, NeighborSummary::base(99, 0.01, 0.9)),
        ];
        let mut p = BaselineOffload::new(OffloadRule::RoundRobin);
        let mut rng = Pcg64::new(1, 0);
        let got = p.choose(&ctx(&task, 0, 0, &cands, &[None, Some(1), Some(2)]), &mut rng);
        assert!(matches!(got, Some(1) | Some(2)));
    }

    #[test]
    fn baseline_offload_gossips_nothing_extra() {
        let mut p = BaselineOffload::new(OffloadRule::Alg2);
        let mut s = NeighborSummary::base(3, 0.01, 0.9);
        let q = crate::sched::Fifo::new();
        let local = super::super::LocalState {
            id: 0,
            now: 0.0,
            input_len: 3,
            output_len: 0,
            gamma_s: 0.01,
            input: &q,
            num_classes: 2,
        };
        p.annotate(&mut s, &local);
        assert_eq!(s.encoded_bytes(), 32, "baseline summaries stay at the seed size");
    }

    #[test]
    fn adapt_wraps_the_two_controllers() {
        let mut a = BaselineAdapt::rate(AdaptConfig::default(), 1.0);
        assert!(a.t_e().is_none());
        let mu0 = a.mu_s().unwrap();
        a.update(0); // under T_Q1: rate up, mu down
        assert!(a.mu_s().unwrap() < mu0);

        let mut a = BaselineAdapt::threshold(AdaptConfig::default(), 0.5, 0.05);
        assert!(a.mu_s().is_none());
        a.update(0);
        assert!((a.t_e().unwrap() - 0.6).abs() < 1e-12);
    }
}
