//! # mdi-exit
//!
//! Reproduction of **"Early-Exit meets Model-Distributed Inference at Edge
//! Networks"** (Colocrese, Koyuncu, Seferoglu, 2024) as a three-layer
//! Rust + JAX + Pallas system (AOT via XLA/PJRT).
//!
//! * L3 (this crate): the MDI-Exit coordinator — per-worker input/output
//!   queues, early-exit + offloading policies (Algs 1–2), and the two
//!   data-admission controllers (Algs 3–4) — over a simulated edge network.
//! * L2/L1 (`python/compile`, build-time only): multi-exit MobileNetV2-Lite
//!   and ResNet-Lite with Pallas kernels, AOT-lowered per stage to HLO text
//!   that the PJRT engine (`pjrt` feature) compiles and executes.
//!
//! The architecture is a single clock-agnostic state machine,
//! [`coordinator::WorkerCore`], that makes every admission/gossip/exit/
//! offload decision as explicit events-in/actions-out; two thin drivers — a
//! discrete-event simulator in virtual time and a realtime threaded runtime
//! on wallclock — map those actions onto their medium. The decisions
//! themselves are pluggable: the [`policy`] subsystem puts Algs 1–4 behind
//! `ExitPolicy` / `OffloadPolicy` / `AdaptPolicy` traits (plus extensible
//! gossip summaries), the same way [`sched`] makes queue order and
//! [`routing`] makes data placement a config choice. Everything that
//! crosses a link travels as a typed [`net::Envelope`] — batches are
//! first-class on the wire, and both drivers charge bytes through the one
//! shared [`net::Envelope::encoded_bytes`] contract. Runs are launched
//! through the [`coordinator::Run`] builder:
//!
//! ```ignore
//! let report = Run::builder()
//!     .config(cfg)
//!     .manifest(&manifest)
//!     .driver(Driver::Des)      // or Driver::Realtime
//!     .execute()?;
//! ```
//!
//! Start at [`coordinator`] for the algorithms, [`experiments`] for the
//! figure reproductions, and `examples/quickstart.rs` for a guided tour.
//!
//! The crate's written contracts (RNG-stream registry, clock purity,
//! wire-charge choke point, telemetry purity, panic budget) are
//! machine-checked by `cargo xtask lint` — see `rust/CONTRACTS.md`.

// The whole tree is safe code today; keep it that way.
#![forbid(unsafe_code)]

pub mod artifact;
pub mod cli;
#[cfg_attr(not(test), warn(clippy::unwrap_used))]
pub mod cluster;
// The panic-budget modules additionally carry clippy's unwrap lint in
// non-test code (xtask's `panic-budget` rule is the deny-by-default gate;
// the clippy warning catches sites in-editor before CI does).
#[cfg_attr(not(test), warn(clippy::unwrap_used))]
pub mod coordinator;
pub mod dataset;
pub mod experiments;
#[cfg_attr(not(test), warn(clippy::unwrap_used))]
pub mod net;
#[cfg_attr(not(test), warn(clippy::unwrap_used))]
pub mod policy;
pub mod routing;
pub mod runtime;
#[cfg_attr(not(test), warn(clippy::unwrap_used))]
pub mod sched;
pub mod simnet;
pub mod telemetry;
pub mod tensor;
pub mod testkit;
pub mod util;
pub mod workload;

/// Default artifacts directory (relative to the repo root), overridable via
/// the `MDI_ARTIFACTS` environment variable.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("MDI_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
