//! PJRT-backed engine: loads the AOT HLO text artifacts and executes them.
//!
//! This is the production request path: `HloModuleProto::from_text_file`
//! (text, not serialized proto — see /opt/xla-example/README.md on the
//! 64-bit-id incompatibility) → `XlaComputation` → `PjRtClient::compile`,
//! once per stage at startup; then `execute` per task with zero Python
//! anywhere.

use anyhow::{bail, Context, Result};

use super::{InferenceEngine, StageOutput};
use crate::artifact::{Manifest, ModelInfo};
use crate::tensor::Tensor;

/// One compiled model stage (task τ_k).
struct StageExe {
    exe: xla::PjRtLoadedExecutable,
    in_shape: Vec<usize>,
}

/// PJRT CPU engine holding every compiled stage of one model (plus the
/// optional autoencoder pair).
pub struct XlaEngine {
    stages: Vec<StageExe>,
    ae_enc: Option<StageExe>,
    ae_dec: Option<StageExe>,
    probs_dim: usize,
}

fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::new(dims, data))
}

fn compile_hlo(client: &xla::PjRtClient, path: &std::path::Path,
               in_shape: &[usize]) -> Result<StageExe> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))?;
    Ok(StageExe { exe, in_shape: in_shape.to_vec() })
}

impl StageExe {
    /// Execute on one input tensor; outputs are the AOT tuple elements.
    fn run(&self, input: &Tensor) -> Result<Vec<Tensor>> {
        if input.shape() != self.in_shape.as_slice() {
            bail!("input shape {:?} != expected {:?}", input.shape(), self.in_shape);
        }
        let lit = tensor_to_literal(input)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = result.to_tuple()?;
        parts.iter().map(literal_to_tensor).collect()
    }
}

impl XlaEngine {
    /// Compile every stage of `model` on a fresh PJRT CPU client.
    /// `with_ae` additionally compiles the autoencoder pair (resnetl).
    pub fn load(manifest: &Manifest, model: &str, with_ae: bool) -> Result<XlaEngine> {
        let info: &ModelInfo = manifest.model(model)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut stages = Vec::with_capacity(info.num_stages);
        for s in &info.stages {
            stages.push(compile_hlo(&client, &manifest.path(&s.hlo), &s.in_shape)?);
        }
        let (ae_enc, ae_dec) = if with_ae {
            let ae = info
                .ae
                .as_ref()
                .with_context(|| format!("model {model} has no autoencoder"))?;
            let raw_shape = info.stages[0].out_shape.clone();
            (
                Some(compile_hlo(&client, &manifest.path(&ae.enc_hlo), &raw_shape)?),
                Some(compile_hlo(&client, &manifest.path(&ae.dec_hlo), &ae.code_shape)?),
            )
        } else {
            (None, None)
        };
        Ok(XlaEngine {
            stages,
            ae_enc,
            ae_dec,
            probs_dim: info.stages[0].probs_dim,
        })
    }
}

impl InferenceEngine for XlaEngine {
    fn num_stages(&self) -> usize {
        self.stages.len()
    }

    fn run_stage(&self, k: usize, _sample: usize, features: Option<&Tensor>)
        -> Result<StageOutput> {
        if k == 0 || k > self.stages.len() {
            bail!("stage {k} out of range 1..={}", self.stages.len());
        }
        let input = features.context("XlaEngine needs a feature tensor")?;
        let outs = self.stages[k - 1].run(input)?;
        if outs.len() != 2 {
            bail!("stage {k} returned {} outputs, expected (features, probs)", outs.len());
        }
        let probs = &outs[1];
        if probs.numel() != self.probs_dim {
            bail!("probs dim {} != {}", probs.numel(), self.probs_dim);
        }
        Ok(StageOutput {
            confidence: probs.max(),
            prediction: probs.argmax() as u8,
            features: Some(outs[0].clone()),
        })
    }

    fn encode(&self, features: &Tensor) -> Result<Option<Tensor>> {
        match &self.ae_enc {
            None => Ok(None),
            Some(enc) => Ok(Some(enc.run(features)?.remove(0))),
        }
    }

    fn decode(&self, code: &Tensor) -> Result<Option<Tensor>> {
        match &self.ae_dec {
            None => Ok(None),
            Some(dec) => Ok(Some(dec.run(code)?.remove(0))),
        }
    }

    fn has_autoencoder(&self) -> bool {
        self.ae_enc.is_some()
    }
}
