//! Inference runtime: how a worker actually processes task τ_k.
//!
//! Two engines implement the same trait:
//!
//! * `xla_engine::XlaEngine` (behind the `pjrt` cargo feature) — the real
//!   path: loads the AOT-compiled HLO text artifacts, compiles them once on
//!   the PJRT CPU client, and executes stages on feature tensors. Used by
//!   the examples, the end-to-end integration tests, and the realtime
//!   driver when the feature is enabled.
//! * [`sim_engine::SimEngine`] — oracle replay: returns the *exact*
//!   confidence/prediction the trained model produces for each (sample,
//!   exit) from the build-time `exits_*.bin` table, without paying XLA
//!   compute. Used by the discrete-event driver so the figure benches can
//!   push tens of thousands of tasks through Algs 1–4 in virtual time.
//!
//! Both agree on the observable behaviour of the paper's system — the
//! integration suite cross-checks them on the same samples. Code that just
//! wants "the best engine this build has" calls [`default_engine`].

pub mod sim_engine;
#[cfg(feature = "pjrt")]
pub mod xla_engine;

use anyhow::Result;

use crate::artifact::Manifest;
use crate::tensor::Tensor;

/// What a worker learns from processing task τ_k (Alg. 1 lines 3–4).
#[derive(Debug, Clone)]
pub struct StageOutput {
    /// Feature tensor entering task τ_{k+1}. `None` from the oracle engine
    /// (the DES driver tracks payload sizes from the manifest instead) and
    /// for the final stage.
    pub features: Option<Tensor>,
    /// Confidence level C_k(d) — eq. (2): max of the exit-classifier softmax.
    pub confidence: f32,
    /// argmax of the exit classifier (the label sent back to the source).
    pub prediction: u8,
}

/// Uniform stage-execution interface for both engines.
///
/// `sample` is the dataset index d; `features` is the tensor entering the
/// stage (`None` on the oracle path). Stages are 1-based like the paper's
/// task indices.
///
/// Deliberately not `Send + Sync`: the `xla` crate's PJRT wrappers carry
/// raw pointers without thread-safety markers, so the realtime driver gives
/// each worker thread its own engine via an [`EngineFactory`] instead of
/// sharing one.
pub trait InferenceEngine {
    /// Number of tasks K the model is partitioned into.
    fn num_stages(&self) -> usize;

    /// Execute task τ_k. For k == 1 `features` is the raw image.
    fn run_stage(&self, k: usize, sample: usize, features: Option<&Tensor>)
        -> Result<StageOutput>;

    /// Execute stage k for a batch of samples with **one** engine call,
    /// returning one output per sample in order. The default loops
    /// [`InferenceEngine::run_stage`]; engines whose per-call dispatch
    /// dominates (cost emulation, PJRT program launch) override it so the
    /// fixed cost is paid once per batch — the whole point of the
    /// coordinator's batched `StartCompute`.
    fn run_stage_batch(
        &self,
        k: usize,
        samples: &[usize],
        features: &[Option<&Tensor>],
    ) -> Result<Vec<StageOutput>> {
        debug_assert_eq!(samples.len(), features.len());
        samples
            .iter()
            .zip(features)
            .map(|(&s, f)| self.run_stage(k, s, *f))
            .collect()
    }

    /// Autoencoder encode at the stage-1 boundary (paper §V). Only
    /// meaningful for models with an AE; `None` otherwise.
    fn encode(&self, _features: &Tensor) -> Result<Option<Tensor>> {
        Ok(None)
    }

    /// Autoencoder-encode a batch of same-stage feature tensors with
    /// **one** engine call, returning one code per input in order — the
    /// wire-side analogue of [`InferenceEngine::run_stage_batch`]: k
    /// tensors riding one coalesced envelope share a single AE forward
    /// (its fixed dispatch/compute is charged once per batch by the
    /// drivers), instead of paying k per-tensor encodes. The default
    /// loops [`InferenceEngine::encode`]; engines with a real batched AE
    /// forward override it.
    fn encode_batch(&self, features: &[&Tensor]) -> Result<Vec<Option<Tensor>>> {
        features.iter().map(|f| self.encode(f)).collect()
    }

    /// Autoencoder decode (inverse of [`InferenceEngine::encode`]).
    fn decode(&self, _code: &Tensor) -> Result<Option<Tensor>> {
        Ok(None)
    }

    /// Whether the AE path is available/enabled.
    fn has_autoencoder(&self) -> bool {
        false
    }
}

/// Per-thread engine constructor for the realtime driver: each worker
/// thread builds (and compiles) its own engine, mirroring how each Jetson
/// in the paper's testbed holds its own copy of its layers.
///
/// Note: as a bare alias this carries the `'static` object-lifetime
/// default, so it suits owned factories (`Box<EngineFactory>`); APIs that
/// accept *borrowed* factories (the `Run` builder's realtime path) spell
/// the `dyn Fn` type inline to get the reference-scoped lifetime instead.
pub type EngineFactory = dyn Fn(usize) -> Result<Box<dyn InferenceEngine>> + Send + Sync;

/// The best engine this build can offer for `model`: the PJRT-compiled HLO
/// stages when the `pjrt` feature is on, otherwise the oracle-replay engine
/// with wallclock cost emulation at the manifest's measured stage costs
/// (so realtime runs stay meaningful without an XLA toolchain).
pub fn default_engine(
    manifest: &Manifest,
    model: &str,
    use_ae: bool,
) -> Result<Box<dyn InferenceEngine>> {
    #[cfg(feature = "pjrt")]
    {
        Ok(Box::new(xla_engine::XlaEngine::load(manifest, model, use_ae)?))
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let info = manifest.model(model)?;
        let costs: Vec<f64> = info.stages.iter().map(|s| s.cost_ms / 1e3).collect();
        let eng = sim_engine::SimEngine::load(manifest, model, use_ae)?.with_costs(costs, 1.0);
        Ok(Box::new(eng))
    }
}
