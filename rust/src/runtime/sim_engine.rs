//! Oracle-replay engine: exact trained-model exit behaviour without XLA.
//!
//! The AOT pipeline evaluates the trained model on every held-out sample at
//! every exit point and ships the resulting (confidence, prediction) table
//! (`exits_<model>.bin`, and `exits_resnetl_ae.bin` for the AE-on-the-wire
//! variant). Replaying that table gives the discrete-event driver the same
//! observable behaviour as running the HLO — the paper's Algorithms 1–4
//! consume only C_k(d) and queue/delay state — at nanosecond cost, which is
//! what lets the figure benches sweep topologies × thresholds × rates.

use anyhow::{bail, Result};

use super::{InferenceEngine, StageOutput};
use crate::artifact::{Manifest, ModelInfo};
use crate::dataset::ExitTable;
use crate::tensor::Tensor;

/// Engine backed by the build-time exit-oracle table.
pub struct SimEngine {
    table: ExitTable,
    num_stages: usize,
    has_ae: bool,
    /// Optional wallclock compute emulation per stage (seconds). The DES
    /// driver charges stage costs in virtual time and leaves this empty;
    /// the realtime driver sets it so oracle replay occupies a worker
    /// thread for as long as the real HLO stage would.
    stage_cost_s: Vec<f64>,
}

impl SimEngine {
    /// Load from artifacts. `use_ae = true` selects the table evaluated with
    /// the autoencoder on the stage-1 boundary (resnetl only).
    pub fn load(manifest: &Manifest, model: &str, use_ae: bool) -> Result<SimEngine> {
        let info: &ModelInfo = manifest.model(model)?;
        let rel = if use_ae {
            match &info.ae {
                Some(ae) => ae.exits_bin_ae.clone(),
                None => bail!("model {model} has no autoencoder table"),
            }
        } else {
            info.exits_bin.clone()
        };
        let table = ExitTable::load(manifest.path(&rel))?;
        if table.num_exits != info.num_stages {
            bail!("exit table K={} != model stages {}", table.num_exits, info.num_stages);
        }
        Ok(SimEngine {
            table,
            num_stages: info.num_stages,
            has_ae: use_ae,
            stage_cost_s: Vec::new(),
        })
    }

    /// Emulate per-stage compute cost in wallclock (realtime driver): each
    /// `run_stage` busy-waits `manifest cost / scale` like the compiled HLO
    /// stage would occupy the thread. `scale` > 1 = faster device.
    pub fn with_costs(mut self, stage_cost_s: Vec<f64>, scale: f64) -> SimEngine {
        assert_eq!(stage_cost_s.len(), self.num_stages);
        assert!(scale > 0.0);
        self.stage_cost_s = stage_cost_s.iter().map(|c| c / scale).collect();
        self
    }

    /// Build directly from a table (unit tests, synthetic workloads).
    pub fn from_table(table: ExitTable, has_ae: bool) -> SimEngine {
        SimEngine {
            num_stages: table.num_exits,
            table,
            has_ae,
            stage_cost_s: Vec::new(),
        }
    }

    pub fn num_samples(&self) -> usize {
        self.table.n
    }
}

impl SimEngine {
    fn check_stage(&self, k: usize) -> Result<()> {
        if k == 0 || k > self.num_stages {
            bail!("stage {k} out of range 1..={}", self.num_stages);
        }
        Ok(())
    }

    fn check_sample(&self, sample: usize) -> Result<()> {
        if sample >= self.table.n {
            bail!("sample {sample} out of range {}", self.table.n);
        }
        Ok(())
    }

    /// Occupy the thread for the emulated cost of one stage *call*.
    fn emulate_cost(&self, k: usize) {
        if let Some(&cost) = self.stage_cost_s.get(k - 1) {
            // Spin rather than sleep: sub-millisecond stage costs are below
            // the scheduler's sleep granularity.
            let t0 = std::time::Instant::now();
            while t0.elapsed().as_secs_f64() < cost {
                std::hint::spin_loop();
            }
        }
    }

    fn replay(&self, k: usize, sample: usize) -> StageOutput {
        StageOutput {
            features: None,
            confidence: self.table.confidence(sample, k - 1),
            prediction: self.table.prediction(sample, k - 1),
        }
    }
}

impl InferenceEngine for SimEngine {
    fn num_stages(&self) -> usize {
        self.num_stages
    }

    fn run_stage(&self, k: usize, sample: usize, _features: Option<&Tensor>)
        -> Result<StageOutput> {
        self.check_stage(k)?;
        self.check_sample(sample)?;
        self.emulate_cost(k);
        Ok(self.replay(k, sample))
    }

    /// One batched forward: the emulated stage cost models the per-*call*
    /// dispatch (the compiled HLO launch the oracle stands in for), so a
    /// batch pays it once — table replay per element is nanoseconds. This
    /// is what makes batching show real wallclock wins on the realtime
    /// driver without an XLA toolchain.
    fn run_stage_batch(
        &self,
        k: usize,
        samples: &[usize],
        _features: &[Option<&Tensor>],
    ) -> Result<Vec<StageOutput>> {
        self.check_stage(k)?;
        for &s in samples {
            self.check_sample(s)?;
        }
        self.emulate_cost(k);
        Ok(samples.iter().map(|&s| self.replay(k, s)).collect())
    }

    fn has_autoencoder(&self) -> bool {
        self.has_ae
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> ExitTable {
        // 2 samples x 3 exits
        ExitTable::synthetic(
            2,
            3,
            vec![0.4, 0.6, 0.95, 0.2, 0.5, 0.9],
            vec![7, 7, 3, 1, 2, 2],
        )
    }

    #[test]
    fn replays_table_values() {
        let e = SimEngine::from_table(table(), false);
        assert_eq!(e.num_stages(), 3);
        let o = e.run_stage(3, 0, None).unwrap();
        assert!((o.confidence - 0.95).abs() < 1e-6);
        assert_eq!(o.prediction, 3);
        assert!(o.features.is_none());
        let o = e.run_stage(2, 1, None).unwrap();
        assert!((o.confidence - 0.5).abs() < 1e-6);
        assert_eq!(o.prediction, 2);
    }

    #[test]
    fn bounds_checked() {
        let e = SimEngine::from_table(table(), false);
        assert!(e.run_stage(0, 0, None).is_err());
        assert!(e.run_stage(4, 0, None).is_err());
        assert!(e.run_stage(1, 9, None).is_err());
    }

    #[test]
    fn with_costs_occupies_wallclock() {
        let e = SimEngine::from_table(table(), false).with_costs(vec![0.004, 0.0, 0.0], 2.0);
        let t0 = std::time::Instant::now();
        e.run_stage(1, 0, None).unwrap();
        assert!(t0.elapsed().as_secs_f64() >= 0.002); // 4ms / scale 2
        let t0 = std::time::Instant::now();
        e.run_stage(2, 0, None).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 0.002);
    }

    #[test]
    fn batch_replays_per_sample_and_pays_cost_once() {
        let e = SimEngine::from_table(table(), false).with_costs(vec![0.02, 0.0, 0.0], 1.0);
        let t0 = std::time::Instant::now();
        let outs = e.run_stage_batch(1, &[0, 1], &[None, None]).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(outs.len(), 2);
        assert!((outs[0].confidence - 0.4).abs() < 1e-6);
        assert!((outs[1].confidence - 0.2).abs() < 1e-6);
        assert!(dt >= 0.02, "cost paid at least once: {dt}");
        assert!(dt < 0.035, "cost paid once per batch, not per element: {dt}");
        assert!(e.run_stage_batch(1, &[0, 99], &[None, None]).is_err());
    }

    #[test]
    fn ae_flag() {
        assert!(!SimEngine::from_table(table(), false).has_autoencoder());
        assert!(SimEngine::from_table(table(), true).has_autoencoder());
    }
}
