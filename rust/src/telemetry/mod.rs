//! Telemetry: per-task trace spans, a sampled metrics registry, and a
//! flight recorder — one subsystem for both drivers.
//!
//! # Recorder contract
//!
//! A [`Recorder`] is installed per [`WorkerCore`](crate::coordinator::WorkerCore)
//! (`set_recorder`) and fed [`TelemetryEvent`]s from the core's
//! events-in/actions-out seam plus the drivers' wire hooks. The contract
//! that keeps both drivers equivalent and the DES bit-for-bit
//! deterministic:
//!
//! * **Clock-agnostic timestamps.** Every event carries the `now` the
//!   driver passed into the handler that produced it — virtual seconds on
//!   the DES [`VirtualClock`](crate::coordinator::VirtualClock), wallclock
//!   seconds on the realtime [`WallClock`](crate::coordinator::WallClock).
//!   A recorder never reads time itself.
//! * **Determinism.** Recording must not draw from any seeded RNG stream,
//!   mutate core state, or reorder events: a recorder observes, it never
//!   decides. Under the DES driver the same seed therefore yields the
//!   same event (and span) sequence with bit-identical timestamps,
//!   whether or not telemetry is enabled. Both halves of this contract
//!   (no clock reads, no RNG) are enforced as the `telemetry-purity` and
//!   `clock-purity` rules of `cargo xtask lint` — see `rust/CONTRACTS.md`.
//! * **Zero cost when off.** `WorkerCore.recorder` is `Option<Box<dyn
//!   Recorder>>`, `None` by default; every hook site is a single
//!   `is_some()` branch with event construction inside it. The metro
//!   bench asserts a [`NoopRecorder`] (events constructed, then
//!   discarded) stays within 2% of the recorder-free baseline.
//!
//! # Trace spans (`--trace out.json`)
//!
//! [`TelemetrySink`] pairs events into [`Span`]s — admit, queue-wait,
//! per-stage compute, per-hop wire legs (offload / re-home / result
//! relay / gossip), and the exit decision — and
//! [`TelemetryData::chrome_trace`] exports them as a Chrome trace-event
//! JSON array loadable in Perfetto (<https://ui.perfetto.dev>): one
//! *process* per worker (`pid` = worker id), one *track* per traffic
//! class (`tid` = class). Events are `"ph":"X"` complete events with
//! `ts`/`dur` in microseconds (instants have `dur: 0`), preceded by
//! `"ph":"M"` metadata naming each process and track; the exporter sorts
//! by start time so per-track timestamps are monotonic
//! ([`validate_chrome_trace`] checks both properties and is exercised by
//! unit tests). `args.task` is the task id in hex (task ids exceed 2^53,
//! so a JSON number would lose bits).
//!
//! # Metrics registry (`--metrics out.jsonl`, `[telemetry] interval`)
//!
//! On a fixed cadence both drivers call
//! `WorkerCore::on_metrics_tick`, which snapshots a [`CoreSample`]
//! (queue depth by class, controller μ/T_e, busy flag, cumulative
//! wire/processed counters) and hands it to the recorder; the sink merges
//! in its own event-derived counters (admitted, completed, on-time,
//! per-exit-point counts, a log-bucketed latency histogram, in-flight
//! envelopes, wire bytes/s) into one [`MetricsRow`] per worker per tick.
//! [`TelemetryData::metrics_jsonl`] emits one JSON object per line
//! (`"kind":"metrics"`), ordered by `(t_s, worker)`, followed by any
//! flight-recorder dumps (`"kind":"flight-dump"`). Counters are
//! *cumulative within the measurement window* (`now >= measure_from`,
//! matching `RunReport`'s warmup gating), so the folded final samples
//! reproduce the run's aggregates exactly: Σ over workers of the last
//! row's `admitted` / `completed` / `wire_bytes` equals
//! `RunReport.{admitted, completed, bytes_on_wire}` (asserted in tests).
//! The legacy source-only `TracePoint` timeline is derived from the same
//! `CoreSample` read, which keeps its JSON bit-compatible with the seed.
//!
//! # Flight recorder
//!
//! The sink keeps a bounded ring of the most recent events
//! (`flight_capacity`, default 64). An anomaly — task drop, engine batch
//! failure, deadline miss, churn re-home — snapshots the ring into a
//! [`FlightDump`] so the run report carries the context *leading up to*
//! the incident, not just the incident count. Dumps are capped (first
//! [`MAX_FLIGHT_DUMPS`]) to bound memory on pathological runs.

use std::collections::BTreeMap;
use std::collections::VecDeque;

use anyhow::{ensure, Result};

use crate::net::Envelope;
use crate::util::json::{obj, Json};

/// Upper bound on retained flight dumps per worker (first N anomalies).
pub const MAX_FLIGHT_DUMPS: usize = 32;

/// Log-bucket count for latency histograms.
pub const LATENCY_BUCKETS: usize = 32;

/// Lower edge of latency bucket 0 (seconds): 100 µs, doubling per bucket.
pub const LATENCY_BASE_S: f64 = 1e-4;

// ---------------------------------------------------------------------------
// Config
// ---------------------------------------------------------------------------

/// The `[telemetry]` section of an experiment config (and the `--trace` /
/// `--metrics` / `--metrics-interval` CLI flags). Everything defaults to
/// *off*: the default run has no recorder installed at all.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryConfig {
    /// Collect per-task trace spans (Chrome trace export).
    pub spans: bool,
    /// Sample the metrics registry every `interval_s` (JSONL export).
    pub metrics: bool,
    /// Metrics sampling cadence in seconds (virtual on DES, wall on rt).
    pub interval_s: f64,
    /// Flight-recorder ring size per worker; 0 disables anomaly dumps.
    pub flight_capacity: usize,
    /// Bench probe: install a [`NoopRecorder`] instead of a sink, so the
    /// metro bench can price the hook overhead with zero payload work.
    pub noop: bool,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            spans: false,
            metrics: false,
            interval_s: 0.25,
            flight_capacity: 64,
            noop: false,
        }
    }
}

impl TelemetryConfig {
    /// Whether the drivers should install a recorder at all.
    pub fn enabled(&self) -> bool {
        self.spans || self.metrics || self.noop
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.interval_s > 0.0 && self.interval_s.is_finite(),
            "telemetry.interval must be a positive number of seconds (got {})",
            self.interval_s
        );
        Ok(())
    }

    /// Build the recorder this config asks for (drivers call this once
    /// per worker when `enabled()`).
    pub fn build_recorder(&self, worker: usize, measure_from: f64) -> Box<dyn Recorder> {
        if self.noop {
            Box::new(NoopRecorder)
        } else {
            Box::new(TelemetrySink::new(worker, self.clone(), measure_from))
        }
    }
}

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// Which payload a wire leg carried (piggybacked gossip is folded into
/// its payload's kind — it shares the frame).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireKind {
    Task,
    Result,
    Rehome,
    Gossip,
}

impl WireKind {
    pub fn label(self) -> &'static str {
        match self {
            WireKind::Task => "task",
            WireKind::Result => "result",
            WireKind::Rehome => "rehome",
            WireKind::Gossip => "gossip",
        }
    }
}

/// Why work was lost (flight-recorder anomaly triggers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropReason {
    /// The engine failed a batch (`abort_compute`).
    EngineFailure,
    /// A result had no route to its admitting source.
    NoRoute,
}

impl DropReason {
    pub fn label(self) -> &'static str {
        match self {
            DropReason::EngineFailure => "engine-failure",
            DropReason::NoRoute => "no-route",
        }
    }
}

/// One structured observation from the core or a driver. Timestamps are
/// driver-passed `now` (see the module docs for the contract).
#[derive(Debug, Clone, PartialEq)]
pub enum TelemetryEvent {
    /// A source admitted a fresh task.
    Admit { t: f64, worker: usize, task: u64, class: u8 },
    /// A task entered this worker's input queue.
    Enqueue { t: f64, worker: usize, task: u64, class: u8, stage: usize },
    /// A batch element started compute (one event per element).
    ComputeStart { t: f64, worker: usize, task: u64, class: u8, stage: usize, batch: usize },
    /// A batch element finished compute.
    ComputeEnd { t: f64, worker: usize, task: u64, class: u8, stage: usize },
    /// Alg. 1 ruled on a finished element: exit here or continue.
    ExitDecision { t: f64, worker: usize, task: u64, class: u8, exit_point: usize, exited: bool },
    /// A completed inference reached its admitting source.
    Complete {
        t: f64,
        worker: usize,
        class: u8,
        exit_point: usize,
        on_time: bool,
        latency_s: f64,
    },
    /// An envelope left `from` toward one-hop neighbor `to` (recorded by
    /// the sending driver, which knows the transfer delay). Task batches
    /// and re-homes emit one event per task; results and gossip one per
    /// envelope (`task: 0`).
    WireSend {
        t: f64,
        from: usize,
        to: usize,
        task: u64,
        class: u8,
        kind: WireKind,
        bytes: usize,
        delay_s: f64,
    },
    /// An envelope arrived at `worker` (receiver-side hook).
    WireRecv { t: f64, worker: usize, from: usize, kind: WireKind, items: usize },
    /// This worker churned out and drained its backlog home.
    ChurnRehome { t: f64, worker: usize, drained: usize },
    /// Work was lost (with accounting) — see [`DropReason`].
    Drop { t: f64, worker: usize, task: u64, class: u8, count: usize, reason: DropReason },
    /// The elastic control plane resized the fleet: `worker` joined
    /// (spawned from parking) or left (load retirement / failover).
    /// `reason` is the [`ScaleReason`](crate::cluster::ScaleReason) label;
    /// `fleet` is the active-node count after the change. Retirements
    /// snapshot the flight ring — the events leading up to a shrink are
    /// exactly what post-hoc scaling analysis needs.
    Scale { t: f64, worker: usize, join: bool, reason: &'static str, fleet: usize },
    /// A metrics-cadence snapshot of the core's gauges and counters.
    MetricsTick(CoreSample),
}

impl TelemetryEvent {
    /// Event timestamp (the driver-passed `now` it was recorded at).
    pub fn t(&self) -> f64 {
        match self {
            TelemetryEvent::Admit { t, .. }
            | TelemetryEvent::Enqueue { t, .. }
            | TelemetryEvent::ComputeStart { t, .. }
            | TelemetryEvent::ComputeEnd { t, .. }
            | TelemetryEvent::ExitDecision { t, .. }
            | TelemetryEvent::Complete { t, .. }
            | TelemetryEvent::WireSend { t, .. }
            | TelemetryEvent::WireRecv { t, .. }
            | TelemetryEvent::ChurnRehome { t, .. }
            | TelemetryEvent::Drop { t, .. }
            | TelemetryEvent::Scale { t, .. } => *t,
            TelemetryEvent::MetricsTick(s) => s.t_s,
        }
    }

    fn json(&self) -> Json {
        match self {
            TelemetryEvent::Admit { t, worker, task, class } => obj(vec![
                ("ev", "admit".into()),
                ("t_s", (*t).into()),
                ("worker", (*worker).into()),
                ("task", format!("{task:#x}").into()),
                ("class", (*class as usize).into()),
            ]),
            TelemetryEvent::Enqueue { t, worker, task, class, stage } => obj(vec![
                ("ev", "enqueue".into()),
                ("t_s", (*t).into()),
                ("worker", (*worker).into()),
                ("task", format!("{task:#x}").into()),
                ("class", (*class as usize).into()),
                ("stage", (*stage).into()),
            ]),
            TelemetryEvent::ComputeStart { t, worker, task, class, stage, batch } => obj(vec![
                ("ev", "compute-start".into()),
                ("t_s", (*t).into()),
                ("worker", (*worker).into()),
                ("task", format!("{task:#x}").into()),
                ("class", (*class as usize).into()),
                ("stage", (*stage).into()),
                ("batch", (*batch).into()),
            ]),
            TelemetryEvent::ComputeEnd { t, worker, task, class, stage } => obj(vec![
                ("ev", "compute-end".into()),
                ("t_s", (*t).into()),
                ("worker", (*worker).into()),
                ("task", format!("{task:#x}").into()),
                ("class", (*class as usize).into()),
                ("stage", (*stage).into()),
            ]),
            TelemetryEvent::ExitDecision { t, worker, task, class, exit_point, exited } => {
                obj(vec![
                    ("ev", "exit-decision".into()),
                    ("t_s", (*t).into()),
                    ("worker", (*worker).into()),
                    ("task", format!("{task:#x}").into()),
                    ("class", (*class as usize).into()),
                    ("exit_point", (*exit_point).into()),
                    ("exited", (*exited).into()),
                ])
            }
            TelemetryEvent::Complete { t, worker, class, exit_point, on_time, latency_s } => {
                obj(vec![
                    ("ev", "complete".into()),
                    ("t_s", (*t).into()),
                    ("worker", (*worker).into()),
                    ("class", (*class as usize).into()),
                    ("exit_point", (*exit_point).into()),
                    ("on_time", (*on_time).into()),
                    ("latency_s", (*latency_s).into()),
                ])
            }
            TelemetryEvent::WireSend { t, from, to, task, class, kind, bytes, delay_s } => {
                obj(vec![
                    ("ev", "wire-send".into()),
                    ("t_s", (*t).into()),
                    ("from", (*from).into()),
                    ("to", (*to).into()),
                    ("task", format!("{task:#x}").into()),
                    ("class", (*class as usize).into()),
                    ("kind", kind.label().into()),
                    ("bytes", (*bytes).into()),
                    ("delay_s", (*delay_s).into()),
                ])
            }
            TelemetryEvent::WireRecv { t, worker, from, kind, items } => obj(vec![
                ("ev", "wire-recv".into()),
                ("t_s", (*t).into()),
                ("worker", (*worker).into()),
                ("from", (*from).into()),
                ("kind", kind.label().into()),
                ("items", (*items).into()),
            ]),
            TelemetryEvent::ChurnRehome { t, worker, drained } => obj(vec![
                ("ev", "churn-rehome".into()),
                ("t_s", (*t).into()),
                ("worker", (*worker).into()),
                ("drained", (*drained).into()),
            ]),
            TelemetryEvent::Drop { t, worker, task, class, count, reason } => obj(vec![
                ("ev", "drop".into()),
                ("t_s", (*t).into()),
                ("worker", (*worker).into()),
                ("task", format!("{task:#x}").into()),
                ("class", (*class as usize).into()),
                ("count", (*count).into()),
                ("reason", reason.label().into()),
            ]),
            TelemetryEvent::Scale { t, worker, join, reason, fleet } => obj(vec![
                ("ev", "scale".into()),
                ("t_s", (*t).into()),
                ("worker", (*worker).into()),
                ("join", (*join).into()),
                ("reason", (*reason).into()),
                ("fleet", (*fleet).into()),
            ]),
            TelemetryEvent::MetricsTick(s) => obj(vec![
                ("ev", "metrics-tick".into()),
                ("t_s", s.t_s.into()),
                ("worker", s.worker.into()),
            ]),
        }
    }
}

/// Pure snapshot of one worker's gauges and cumulative counters at an
/// instant — built by `WorkerCore::timeline_sample`. The legacy
/// `TracePoint` timeline reads `control`/`queue_total` from the same
/// snapshot, which is what keeps it bit-compatible with the seed.
#[derive(Debug, Clone, PartialEq)]
pub struct CoreSample {
    pub t_s: f64,
    pub worker: usize,
    /// Controller value: μ under Alg. 3, T_e otherwise.
    pub control: f64,
    pub t_e: f64,
    pub busy: bool,
    pub input_len: usize,
    pub output_len: usize,
    /// I_n + O_n (what the legacy `TracePoint.source_queue` reports).
    pub queue_total: usize,
    /// Input-queue occupancy per traffic class.
    pub class_depths: Vec<usize>,
    /// Cumulative in-window counters mirrored from `WorkerStats`.
    pub processed: u64,
    pub wire_bytes: u64,
    pub envelopes_sent: u64,
}

// ---------------------------------------------------------------------------
// Recorder trait
// ---------------------------------------------------------------------------

/// Observer for [`TelemetryEvent`]s. Default methods are no-ops, so an
/// impl overrides only what it needs; `Send` because realtime worker
/// threads own their recorder.
pub trait Recorder: Send {
    /// Observe one event. MUST NOT read clocks, draw RNG, or feed
    /// anything back into the core (see module docs).
    fn record(&mut self, _ev: &TelemetryEvent) {}

    /// Consume the recorder into its collected data at end of run.
    fn finish(self: Box<Self>) -> TelemetryData {
        TelemetryData::default()
    }
}

/// Discards everything — the zero-cost-when-off contract's bench probe.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopRecorder;

impl Recorder for NoopRecorder {}

// ---------------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Instant: a source admitted the task.
    Admit,
    /// Input-queue wait from enqueue to compute start.
    QueueWait,
    /// One stage of compute (batch elements share the interval).
    Compute,
    /// Instant: Alg. 1 exited here (`stage` = exit point).
    Exit,
    /// Instant: Alg. 1 continued (`stage` = exit point that declined).
    Continue,
    /// Wire leg carrying a task batch (offload or DDI forward).
    WireTask,
    /// Wire leg relaying results toward their source.
    WireResult,
    /// Wire leg re-homing displaced tasks.
    WireRehome,
    /// Wire leg carrying a dedicated gossip summary.
    WireGossip,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::QueueWait => "queue-wait",
            SpanKind::Compute => "compute",
            SpanKind::Exit => "exit",
            SpanKind::Continue => "continue",
            SpanKind::WireTask => "wire:task",
            SpanKind::WireResult => "wire:result",
            SpanKind::WireRehome => "wire:rehome",
            SpanKind::WireGossip => "wire:gossip",
        }
    }

    fn category(self) -> &'static str {
        match self {
            SpanKind::Admit => "admission",
            SpanKind::QueueWait => "queue",
            SpanKind::Compute => "compute",
            SpanKind::Exit | SpanKind::Continue => "decision",
            _ => "wire",
        }
    }
}

/// One interval (or instant, `t0 == t1`) in a task's life. `worker` maps
/// to the Chrome-trace `pid`, `class` to the `tid` track; wire spans live
/// on the *sender's* process with `peer` naming the receiver.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    pub worker: usize,
    pub class: u8,
    /// Task id (0 = not task-scoped: result/gossip envelopes).
    pub task: u64,
    /// Stage or exit point (0 = n/a).
    pub stage: usize,
    /// Wire peer (usize::MAX = n/a).
    pub peer: usize,
    pub t0: f64,
    pub t1: f64,
}

// ---------------------------------------------------------------------------
// Metrics rows, histograms, flight dumps
// ---------------------------------------------------------------------------

/// Log-bucketed histogram: bucket `i` covers
/// `[LATENCY_BASE_S * 2^i, LATENCY_BASE_S * 2^(i+1))`, clamped at the
/// ends — 100 µs to ~3.7 days in 32 buckets.
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    pub counts: Vec<u64>,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { counts: vec![0; LATENCY_BUCKETS] }
    }
}

impl LogHistogram {
    pub fn observe(&mut self, v_s: f64) {
        let idx = if v_s <= LATENCY_BASE_S {
            0
        } else {
            ((v_s / LATENCY_BASE_S).log2().floor() as i64)
                .clamp(0, LATENCY_BUCKETS as i64 - 1) as usize
        };
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

/// One sampled row of the per-worker time series: the core's gauges plus
/// the sink's event-derived counters, all cumulative within the
/// measurement window.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsRow {
    pub t_s: f64,
    pub worker: usize,
    pub control: f64,
    pub t_e: f64,
    pub busy: bool,
    pub input_len: usize,
    pub output_len: usize,
    pub class_depths: Vec<usize>,
    pub admitted: u64,
    pub completed: u64,
    pub on_time: u64,
    pub deadline_misses: u64,
    pub processed: u64,
    pub wire_bytes: u64,
    pub envelopes_sent: u64,
    /// Wire throughput over the last sampling interval (bytes/s).
    pub wire_bytes_per_s: f64,
    /// Envelopes this worker sent whose delivery is still in flight.
    pub envelopes_in_flight: usize,
    /// Cumulative exits decided at this worker, by exit point (index 0
    /// unused; grows on demand).
    pub exit_counts: Vec<u64>,
    /// Log-bucketed completion latency at this source (empty elsewhere).
    pub latency_hist: Vec<u64>,
}

impl MetricsRow {
    fn json(&self) -> Json {
        obj(vec![
            ("kind", "metrics".into()),
            ("t_s", self.t_s.into()),
            ("worker", self.worker.into()),
            ("control", self.control.into()),
            ("t_e", self.t_e.into()),
            ("busy", self.busy.into()),
            ("input_len", self.input_len.into()),
            ("output_len", self.output_len.into()),
            ("class_depths", self.class_depths.clone().into()),
            ("admitted", (self.admitted as i64).into()),
            ("completed", (self.completed as i64).into()),
            ("on_time", (self.on_time as i64).into()),
            ("deadline_misses", (self.deadline_misses as i64).into()),
            ("processed", (self.processed as i64).into()),
            ("wire_bytes", (self.wire_bytes as i64).into()),
            ("envelopes_sent", (self.envelopes_sent as i64).into()),
            ("wire_bytes_per_s", self.wire_bytes_per_s.into()),
            ("envelopes_in_flight", self.envelopes_in_flight.into()),
            (
                "exit_counts",
                Json::Arr(self.exit_counts.iter().map(|&c| (c as i64).into()).collect()),
            ),
            (
                "latency_hist",
                Json::Arr(self.latency_hist.iter().map(|&c| (c as i64).into()).collect()),
            ),
        ])
    }
}

/// The flight recorder's snapshot of the events preceding an anomaly.
#[derive(Debug, Clone, PartialEq)]
pub struct FlightDump {
    pub t_s: f64,
    pub worker: usize,
    pub reason: String,
    pub events: Vec<TelemetryEvent>,
}

impl FlightDump {
    fn json(&self) -> Json {
        obj(vec![
            ("kind", "flight-dump".into()),
            ("t_s", self.t_s.into()),
            ("worker", self.worker.into()),
            ("reason", self.reason.as_str().into()),
            ("events", Json::Arr(self.events.iter().map(|e| e.json()).collect())),
        ])
    }
}

// ---------------------------------------------------------------------------
// Collected data + exporters
// ---------------------------------------------------------------------------

/// Everything telemetry collected for a run: merged across workers by the
/// drivers, attached to `RunReport.telemetry` (never serialized into the
/// report's own JSON — the exporters below own the formats).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct TelemetryData {
    pub spans: Vec<Span>,
    pub metrics: Vec<MetricsRow>,
    pub dumps: Vec<FlightDump>,
}

impl TelemetryData {
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty() && self.metrics.is_empty() && self.dumps.is_empty()
    }

    /// Fold another worker's data in (order within a worker is preserved;
    /// exporters sort across workers where the format needs it).
    pub fn merge(&mut self, other: TelemetryData) {
        self.spans.extend(other.spans);
        self.metrics.extend(other.metrics);
        self.dumps.extend(other.dumps);
    }

    /// Export spans as a Chrome trace-event JSON array (Perfetto-loadable;
    /// see module docs for the layout).
    pub fn chrome_trace(&self) -> Json {
        let mut spans: Vec<&Span> = self.spans.iter().collect();
        spans.sort_by(|a, b| {
            a.t0.partial_cmp(&b.t0).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut workers: Vec<usize> = Vec::new();
        let mut tracks: Vec<(usize, u8)> = Vec::new();
        for s in &spans {
            if !workers.contains(&s.worker) {
                workers.push(s.worker);
            }
            if !tracks.contains(&(s.worker, s.class)) {
                tracks.push((s.worker, s.class));
            }
        }
        workers.sort_unstable();
        tracks.sort_unstable();
        let mut events: Vec<Json> = Vec::new();
        for w in workers {
            events.push(obj(vec![
                ("name", "process_name".into()),
                ("ph", "M".into()),
                ("pid", w.into()),
                ("args", obj(vec![("name", format!("worker {w}").into())])),
            ]));
        }
        for (w, c) in tracks {
            events.push(obj(vec![
                ("name", "thread_name".into()),
                ("ph", "M".into()),
                ("pid", w.into()),
                ("tid", (c as usize).into()),
                ("args", obj(vec![("name", format!("class {c}").into())])),
            ]));
        }
        for s in spans {
            let mut args = vec![("task", Json::Str(format!("{:#x}", s.task)))];
            if s.stage != 0 {
                args.push(("stage", s.stage.into()));
            }
            if s.peer != usize::MAX {
                args.push(("peer", s.peer.into()));
            }
            events.push(obj(vec![
                ("name", s.kind.name().into()),
                ("cat", s.kind.category().into()),
                ("ph", "X".into()),
                ("ts", (s.t0 * 1e6).into()),
                ("dur", ((s.t1 - s.t0) * 1e6).max(0.0).into()),
                ("pid", s.worker.into()),
                ("tid", (s.class as usize).into()),
                ("args", obj(args)),
            ]));
        }
        Json::Arr(events)
    }

    /// Export the metrics time series (plus flight dumps) as JSONL: one
    /// JSON object per line, rows ordered by `(t_s, worker)`.
    pub fn metrics_jsonl(&self) -> String {
        let mut rows: Vec<&MetricsRow> = self.metrics.iter().collect();
        rows.sort_by(|a, b| {
            a.t_s
                .partial_cmp(&b.t_s)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.worker.cmp(&b.worker))
        });
        let mut out = String::new();
        for r in rows {
            out.push_str(&r.json().to_string());
            out.push('\n');
        }
        for d in &self.dumps {
            out.push_str(&d.json().to_string());
            out.push('\n');
        }
        out
    }

    /// Fold each worker's *final* metrics row: Σ admitted, Σ completed,
    /// Σ wire_bytes — by construction equal to the `RunReport` aggregates
    /// (the identity the tests assert).
    pub fn folded_totals(&self) -> (u64, u64, u64) {
        let mut last: BTreeMap<usize, &MetricsRow> = BTreeMap::new();
        for r in &self.metrics {
            match last.get(&r.worker) {
                Some(prev) if prev.t_s > r.t_s => {}
                _ => {
                    last.insert(r.worker, r);
                }
            }
        }
        let mut admitted = 0;
        let mut completed = 0;
        let mut wire_bytes = 0;
        for r in last.values() {
            admitted += r.admitted;
            completed += r.completed;
            wire_bytes += r.wire_bytes;
        }
        (admitted, completed, wire_bytes)
    }
}

/// Check a value against the Chrome trace-event schema subset we emit:
/// a JSON array; every element an object with `name`/`ph`; `"X"` events
/// additionally carry numeric `ts`, non-negative `dur`, `pid`, `tid`;
/// and per-(pid, tid) track, `ts` is monotonically non-decreasing.
/// Returns the number of `"X"` events.
pub fn validate_chrome_trace(j: &Json) -> Result<usize, String> {
    let arr = j.as_arr().ok_or("trace is not a JSON array")?;
    let mut last_ts: BTreeMap<(i64, i64), f64> = BTreeMap::new();
    let mut complete = 0usize;
    for (i, ev) in arr.iter().enumerate() {
        ev.as_obj().ok_or_else(|| format!("event {i} is not an object"))?;
        ev.get("name").as_str().ok_or_else(|| format!("event {i} has no name"))?;
        let ph = ev.get("ph").as_str().ok_or_else(|| format!("event {i} has no ph"))?;
        if ph != "X" {
            continue;
        }
        let ts = ev.get("ts").as_f64().ok_or_else(|| format!("event {i}: ts not a number"))?;
        let dur =
            ev.get("dur").as_f64().ok_or_else(|| format!("event {i}: dur not a number"))?;
        if dur < 0.0 {
            return Err(format!("event {i}: negative dur {dur}"));
        }
        let pid =
            ev.get("pid").as_i64().ok_or_else(|| format!("event {i}: pid not an integer"))?;
        let tid =
            ev.get("tid").as_i64().ok_or_else(|| format!("event {i}: tid not an integer"))?;
        let slot = last_ts.entry((pid, tid)).or_insert(f64::NEG_INFINITY);
        if ts < *slot {
            return Err(format!(
                "event {i}: ts {ts} goes backwards on track ({pid},{tid}) (last {})",
                *slot
            ));
        }
        *slot = ts;
        complete += 1;
    }
    Ok(complete)
}

/// Emit the per-item [`TelemetryEvent::WireSend`] events for one outbound
/// envelope: one per task for task batches and re-homes, one per envelope
/// for results and gossip. Both drivers call this from their send path
/// (they know the transfer delay; the core does not).
pub fn wire_send_events(
    t: f64,
    from: usize,
    to: usize,
    env: &Envelope,
    bytes: usize,
    delay_s: f64,
    mut emit: impl FnMut(TelemetryEvent),
) {
    match env.payload() {
        Envelope::TaskBatch(tasks) | Envelope::Rehome(tasks) => {
            let kind = if matches!(env.payload(), Envelope::TaskBatch(_)) {
                WireKind::Task
            } else {
                WireKind::Rehome
            };
            for task in tasks {
                emit(TelemetryEvent::WireSend {
                    t,
                    from,
                    to,
                    task: task.id,
                    class: task.class,
                    kind,
                    bytes,
                    delay_s,
                });
            }
        }
        Envelope::Result(rs) => emit(TelemetryEvent::WireSend {
            t,
            from,
            to,
            task: 0,
            class: rs.first().map(|r| r.class).unwrap_or(0),
            kind: WireKind::Result,
            bytes,
            delay_s,
        }),
        Envelope::State(_) => emit(TelemetryEvent::WireSend {
            t,
            from,
            to,
            task: 0,
            class: 0,
            kind: WireKind::Gossip,
            bytes,
            delay_s,
        }),
        // `payload()` never returns the wrapper itself.
        Envelope::Piggybacked(..) => unreachable!("payload() peels Piggybacked"),
    }
}

/// The wire kind of an envelope's payload (sees through piggybacking).
pub fn wire_kind(env: &Envelope) -> WireKind {
    match env.payload() {
        Envelope::TaskBatch(_) => WireKind::Task,
        Envelope::Result(_) => WireKind::Result,
        Envelope::Rehome(_) => WireKind::Rehome,
        Envelope::State(_) => WireKind::Gossip,
        Envelope::Piggybacked(..) => unreachable!("payload() peels Piggybacked"),
    }
}

// ---------------------------------------------------------------------------
// The concrete sink
// ---------------------------------------------------------------------------

/// The default [`Recorder`]: pairs events into spans, folds counters into
/// metrics rows on every [`TelemetryEvent::MetricsTick`], and keeps the
/// flight ring. One sink per worker; drivers merge the finished
/// [`TelemetryData`].
pub struct TelemetrySink {
    worker: usize,
    cfg: TelemetryConfig,
    /// Warmup gate: counters only accumulate at `t >= measure_from`,
    /// matching `RunReport`'s windowing (spans and the flight ring are
    /// *not* gated — warmup context is exactly what anomaly forensics
    /// want).
    measure_from: f64,

    spans: Vec<Span>,
    metrics: Vec<MetricsRow>,

    /// Input-queue entry time per task (drained at compute start).
    enqueued_at: BTreeMap<u64, f64>,
    /// Start of the in-flight batch (single batch per worker at a time).
    compute_t0: f64,

    // Event-derived cumulative counters (in-window).
    admitted: u64,
    completed: u64,
    on_time: u64,
    deadline_misses: u64,
    exit_counts: Vec<u64>,
    latency: LogHistogram,
    /// Delivery deadlines of sent envelopes, pruned at each sample.
    inflight: VecDeque<f64>,
    /// Previous sample's (t, wire_bytes) for the bytes/s gauge.
    prev_sample: Option<(f64, u64)>,

    ring: VecDeque<TelemetryEvent>,
    dumps: Vec<FlightDump>,
}

impl TelemetrySink {
    pub fn new(worker: usize, cfg: TelemetryConfig, measure_from: f64) -> TelemetrySink {
        TelemetrySink {
            worker,
            cfg,
            measure_from,
            spans: Vec::new(),
            metrics: Vec::new(),
            enqueued_at: BTreeMap::new(),
            compute_t0: 0.0,
            admitted: 0,
            completed: 0,
            on_time: 0,
            deadline_misses: 0,
            exit_counts: Vec::new(),
            latency: LogHistogram::default(),
            inflight: VecDeque::new(),
            prev_sample: None,
            ring: VecDeque::new(),
            dumps: Vec::new(),
        }
    }

    fn in_window(&self, t: f64) -> bool {
        t >= self.measure_from
    }

    fn push_span(&mut self, span: Span) {
        if self.cfg.spans {
            self.spans.push(span);
        }
    }

    fn ring_push(&mut self, ev: &TelemetryEvent) {
        if self.cfg.flight_capacity == 0 {
            return;
        }
        if self.ring.len() >= self.cfg.flight_capacity {
            self.ring.pop_front();
        }
        self.ring.push_back(ev.clone());
    }

    /// Snapshot the ring into a dump (the anomaly event itself is the
    /// ring's most recent entry, since `record` rings before dispatch).
    fn anomaly(&mut self, t: f64, reason: String) {
        if self.cfg.flight_capacity == 0 || self.dumps.len() >= MAX_FLIGHT_DUMPS {
            return;
        }
        self.dumps.push(FlightDump {
            t_s: t,
            worker: self.worker,
            reason,
            events: self.ring.iter().cloned().collect(),
        });
    }

    fn bump_exit(&mut self, exit_point: usize) {
        if self.exit_counts.len() <= exit_point {
            self.exit_counts.resize(exit_point + 1, 0);
        }
        self.exit_counts[exit_point] += 1;
    }

    fn sample(&mut self, s: &CoreSample) {
        if !self.cfg.metrics {
            return;
        }
        while self.inflight.front().is_some_and(|&d| d <= s.t_s) {
            self.inflight.pop_front();
        }
        let wire_rate = match self.prev_sample {
            Some((t0, b0)) if s.t_s > t0 => {
                s.wire_bytes.saturating_sub(b0) as f64 / (s.t_s - t0)
            }
            _ => 0.0,
        };
        self.prev_sample = Some((s.t_s, s.wire_bytes));
        self.metrics.push(MetricsRow {
            t_s: s.t_s,
            worker: s.worker,
            control: s.control,
            t_e: s.t_e,
            busy: s.busy,
            input_len: s.input_len,
            output_len: s.output_len,
            class_depths: s.class_depths.clone(),
            admitted: self.admitted,
            completed: self.completed,
            on_time: self.on_time,
            deadline_misses: self.deadline_misses,
            processed: s.processed,
            wire_bytes: s.wire_bytes,
            envelopes_sent: s.envelopes_sent,
            wire_bytes_per_s: wire_rate,
            envelopes_in_flight: self.inflight.len(),
            exit_counts: self.exit_counts.clone(),
            latency_hist: self.latency.counts.clone(),
        });
    }
}

impl Recorder for TelemetrySink {
    fn record(&mut self, ev: &TelemetryEvent) {
        self.ring_push(ev);
        match *ev {
            TelemetryEvent::Admit { t, worker, task, class } => {
                if self.in_window(t) {
                    self.admitted += 1;
                }
                self.push_span(Span {
                    kind: SpanKind::Admit,
                    worker,
                    class,
                    task,
                    stage: 0,
                    peer: usize::MAX,
                    t0: t,
                    t1: t,
                });
            }
            TelemetryEvent::Enqueue { t, task, .. } => {
                if self.cfg.spans {
                    self.enqueued_at.insert(task, t);
                }
            }
            TelemetryEvent::ComputeStart { t, worker, task, class, .. } => {
                self.compute_t0 = t;
                if let Some(t_enq) = self.enqueued_at.remove(&task) {
                    self.push_span(Span {
                        kind: SpanKind::QueueWait,
                        worker,
                        class,
                        task,
                        stage: 0,
                        peer: usize::MAX,
                        t0: t_enq,
                        t1: t,
                    });
                }
            }
            TelemetryEvent::ComputeEnd { t, worker, task, class, stage } => {
                self.push_span(Span {
                    kind: SpanKind::Compute,
                    worker,
                    class,
                    task,
                    stage,
                    peer: usize::MAX,
                    t0: self.compute_t0.min(t),
                    t1: t,
                });
            }
            TelemetryEvent::ExitDecision { t, worker, task, class, exit_point, exited } => {
                if exited && self.in_window(t) {
                    self.bump_exit(exit_point);
                }
                self.push_span(Span {
                    kind: if exited { SpanKind::Exit } else { SpanKind::Continue },
                    worker,
                    class,
                    task,
                    stage: exit_point,
                    peer: usize::MAX,
                    t0: t,
                    t1: t,
                });
            }
            TelemetryEvent::Complete { t, on_time, latency_s, .. } => {
                if self.in_window(t) {
                    self.completed += 1;
                    if on_time {
                        self.on_time += 1;
                    } else {
                        self.deadline_misses += 1;
                    }
                    self.latency.observe(latency_s);
                }
                if !on_time {
                    self.anomaly(t, "deadline-miss".to_string());
                }
            }
            TelemetryEvent::WireSend { t, from, to, task, class, kind, delay_s, .. } => {
                self.inflight.push_back(t + delay_s);
                self.push_span(Span {
                    kind: match kind {
                        WireKind::Task => SpanKind::WireTask,
                        WireKind::Result => SpanKind::WireResult,
                        WireKind::Rehome => SpanKind::WireRehome,
                        WireKind::Gossip => SpanKind::WireGossip,
                    },
                    worker: from,
                    class,
                    task,
                    stage: 0,
                    peer: to,
                    t0: t,
                    t1: t + delay_s,
                });
            }
            TelemetryEvent::WireRecv { .. } => {}
            TelemetryEvent::ChurnRehome { t, drained, .. } => {
                self.anomaly(t, format!("churn-rehome ({drained} tasks drained)"));
            }
            TelemetryEvent::Drop { t, count, reason, .. } => {
                self.anomaly(t, format!("drop ({count} tasks, {})", reason.label()));
            }
            TelemetryEvent::Scale { t, worker, join, reason, fleet } => {
                // A shrink is the anomaly-shaped half: snapshot what led
                // up to losing a worker. Spawns just ride the ring.
                if !join {
                    self.anomaly(
                        t,
                        format!("scale ({reason}: worker {worker} retired, fleet {fleet})"),
                    );
                }
            }
            TelemetryEvent::MetricsTick(ref s) => {
                let s = s.clone();
                self.sample(&s);
            }
        }
    }

    fn finish(self: Box<Self>) -> TelemetryData {
        TelemetryData { spans: self.spans, metrics: self.metrics, dumps: self.dumps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink(spans: bool, metrics: bool, cap: usize) -> TelemetrySink {
        let cfg = TelemetryConfig {
            spans,
            metrics,
            interval_s: 0.25,
            flight_capacity: cap,
            noop: false,
        };
        TelemetrySink::new(0, cfg, 0.0)
    }

    fn admit(t: f64, task: u64) -> TelemetryEvent {
        TelemetryEvent::Admit { t, worker: 0, task, class: 0 }
    }

    #[test]
    fn histogram_buckets_are_logarithmic() {
        let mut h = LogHistogram::default();
        h.observe(0.0); // underflow -> bucket 0
        h.observe(1e-4);
        h.observe(2.5e-4); // bucket 1
        h.observe(1.0); // ~bucket 13
        h.observe(1e9); // overflow clamps to last bucket
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[1], 1);
        assert_eq!(h.counts[LATENCY_BUCKETS - 1], 1);
        assert_eq!(h.total(), 5);
    }

    #[test]
    fn sink_pairs_queue_wait_and_compute_spans() {
        let mut s = sink(true, false, 0);
        s.record(&TelemetryEvent::Enqueue { t: 1.0, worker: 0, task: 7, class: 2, stage: 1 });
        s.record(&TelemetryEvent::ComputeStart {
            t: 1.5,
            worker: 0,
            task: 7,
            class: 2,
            stage: 1,
            batch: 1,
        });
        s.record(&TelemetryEvent::ComputeEnd { t: 1.8, worker: 0, task: 7, class: 2, stage: 1 });
        s.record(&TelemetryEvent::ExitDecision {
            t: 1.8,
            worker: 0,
            task: 7,
            class: 2,
            exit_point: 1,
            exited: true,
        });
        let data = Box::new(s).finish();
        let kinds: Vec<SpanKind> = data.spans.iter().map(|s| s.kind).collect();
        assert_eq!(kinds, vec![SpanKind::QueueWait, SpanKind::Compute, SpanKind::Exit]);
        let qw = data.spans[0];
        assert_eq!((qw.t0, qw.t1), (1.0, 1.5));
        assert_eq!(qw.class, 2);
        let c = data.spans[1];
        assert_eq!((c.t0, c.t1), (1.5, 1.8));
        assert_eq!(c.stage, 1);
    }

    #[test]
    fn chrome_trace_is_valid_and_monotonic() {
        let mut s = sink(true, false, 0);
        for i in 0..20u64 {
            let t = i as f64 * 0.1;
            s.record(&TelemetryEvent::Enqueue {
                t,
                worker: 0,
                task: i,
                class: (i % 2) as u8,
                stage: 1,
            });
            s.record(&TelemetryEvent::ComputeStart {
                t: t + 0.01,
                worker: 0,
                task: i,
                class: (i % 2) as u8,
                stage: 1,
                batch: 1,
            });
            s.record(&TelemetryEvent::ComputeEnd {
                t: t + 0.03,
                worker: 0,
                task: i,
                class: (i % 2) as u8,
                stage: 1,
            });
        }
        s.record(&TelemetryEvent::WireSend {
            t: 0.5,
            from: 0,
            to: 1,
            task: 3,
            class: 1,
            kind: WireKind::Task,
            bytes: 1024,
            delay_s: 0.02,
        });
        let data = Box::new(s).finish();
        let trace = data.chrome_trace();
        let n = validate_chrome_trace(&trace).expect("schema-valid trace");
        assert_eq!(n, 41, "20 queue-waits + 20 computes + 1 wire leg");
        // Round-trips through the serializer too.
        let parsed = Json::parse(&trace.to_string()).expect("serialized trace parses");
        validate_chrome_trace(&parsed).expect("still valid after round-trip");
    }

    #[test]
    fn validator_rejects_backwards_track() {
        let j = Json::parse(
            r#"[{"name":"a","ph":"X","ts":5,"dur":1,"pid":0,"tid":0},
                {"name":"b","ph":"X","ts":4,"dur":1,"pid":0,"tid":0}]"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&j).is_err());
        let ok = Json::parse(
            r#"[{"name":"a","ph":"X","ts":5,"dur":1,"pid":0,"tid":0},
                {"name":"b","ph":"X","ts":4,"dur":1,"pid":0,"tid":1}]"#,
        )
        .unwrap();
        assert_eq!(validate_chrome_trace(&ok), Ok(2), "different track may restart");
    }

    #[test]
    fn flight_ring_is_bounded_and_dumps_on_anomaly() {
        let mut s = sink(false, false, 4);
        for i in 0..10 {
            s.record(&admit(i as f64, i));
        }
        assert_eq!(s.ring.len(), 4, "ring bounded at capacity");
        s.record(&TelemetryEvent::Drop {
            t: 10.0,
            worker: 0,
            task: 9,
            class: 0,
            count: 1,
            reason: DropReason::EngineFailure,
        });
        let data = Box::new(s).finish();
        assert_eq!(data.dumps.len(), 1);
        let d = &data.dumps[0];
        assert!(d.reason.contains("engine-failure"));
        // The dump holds the *preceding* events (the freshest ring slice),
        // ending with the drop itself.
        assert_eq!(d.events.len(), 4);
        assert!(matches!(d.events[0], TelemetryEvent::Admit { task: 7, .. }));
        assert!(matches!(d.events[3], TelemetryEvent::Drop { .. }));
        // JSONL export carries the dump.
        assert!(data.metrics_jsonl().contains("flight-dump"));
    }

    #[test]
    fn scale_retirement_dumps_the_flight_ring_but_spawn_does_not() {
        let mut s = sink(false, false, 4);
        s.record(&admit(1.0, 1));
        s.record(&TelemetryEvent::Scale {
            t: 2.0,
            worker: 3,
            join: true,
            reason: "load",
            fleet: 4,
        });
        assert!(s.dumps.is_empty(), "a spawn is not an anomaly");
        s.record(&TelemetryEvent::Scale {
            t: 3.0,
            worker: 2,
            join: false,
            reason: "failure",
            fleet: 3,
        });
        let data = Box::new(s).finish();
        assert_eq!(data.dumps.len(), 1);
        let d = &data.dumps[0];
        assert!(d.reason.contains("failure"), "{}", d.reason);
        assert!(d.reason.contains("worker 2"), "{}", d.reason);
        assert!(matches!(d.events.last(), Some(TelemetryEvent::Scale { join: false, .. })));
    }

    #[test]
    fn metrics_rows_fold_to_totals() {
        let mut s = sink(false, true, 0);
        for i in 0..5u64 {
            s.record(&admit(i as f64, i));
        }
        s.record(&TelemetryEvent::Complete {
            t: 6.0,
            worker: 0,
            class: 0,
            exit_point: 1,
            on_time: true,
            latency_s: 0.01,
        });
        let cs = CoreSample {
            t_s: 7.0,
            worker: 0,
            control: 0.5,
            t_e: 0.9,
            busy: false,
            input_len: 0,
            output_len: 0,
            queue_total: 0,
            class_depths: vec![0],
            processed: 5,
            wire_bytes: 1000,
            envelopes_sent: 2,
        };
        s.record(&TelemetryEvent::MetricsTick(cs));
        let data = Box::new(s).finish();
        assert_eq!(data.folded_totals(), (5, 1, 1000));
        let jsonl = data.metrics_jsonl();
        let row = Json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert_eq!(row.get("admitted").as_i64(), Some(5));
        assert_eq!(row.get("completed").as_i64(), Some(1));
        assert_eq!(row.get("wire_bytes").as_i64(), Some(1000));
    }

    #[test]
    fn warmup_gates_counters_but_not_spans() {
        let cfg = TelemetryConfig { spans: true, metrics: true, ..Default::default() };
        let mut s = TelemetrySink::new(0, cfg, 10.0);
        s.record(&admit(5.0, 1)); // warmup: span yes, counter no
        s.record(&admit(15.0, 2)); // in window: both
        assert_eq!(s.admitted, 1);
        let data = Box::new(s).finish();
        assert_eq!(data.spans.len(), 2);
    }

    #[test]
    fn noop_recorder_yields_empty_data() {
        let mut r = NoopRecorder;
        r.record(&admit(1.0, 1));
        let data = Box::new(r).finish();
        assert!(data.is_empty());
        assert_eq!(validate_chrome_trace(&data.chrome_trace()), Ok(0));
    }

    #[test]
    fn config_validation() {
        let mut cfg = TelemetryConfig::default();
        assert!(!cfg.enabled());
        assert!(cfg.validate().is_ok());
        cfg.metrics = true;
        assert!(cfg.enabled());
        cfg.interval_s = 0.0;
        assert!(cfg.validate().is_err());
    }
}
