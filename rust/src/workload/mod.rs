//! Traffic workload models: *when* sources admit data.
//!
//! The paper's testbed paces every source with a fixed interarrival time
//! (or the Alg. 3 controller's μ). Metro-scale experiments need richer
//! arrival processes — Poisson streams, flash crowds, diurnal load curves,
//! and recorded traces — without touching the admission state machine. An
//! [`ArrivalModel`] owns exactly one decision: given the pacing the
//! [`crate::coordinator::config::AdmissionMode`] would have used
//! (`base_dt_s`, the mean interarrival), produce the *actual* delay until
//! the next admission.
//!
//! ## Seeding / determinism contract
//!
//! * Every stochastic model draws from its own [`Pcg64`] stream,
//!   `(cfg.seed, `[`streams::ARRIVAL_STREAM_BASE`]` + source_id)` —
//!   disjoint from the worker-core decision streams
//!   ([`streams::WORKER_CORE_BASE`]` + id`), the DES link-jitter stream
//!   ([`streams::DES_LINK_JITTER`]), and the realtime `DelayNet` endpoint
//!   streams ([`streams::RT_LINK_JITTER_BASE`]` + id`) — all reserved in
//!   the central [`streams`] registry and enforced by `cargo xtask lint`.
//!   The k-th admission of source s therefore sees the same
//!   draw on BOTH drivers, which is what makes the cross-driver Poisson
//!   equivalence test possible: same seed ⇒ same per-source admission
//!   timeline, on the DES heap and on wallclock threads alike.
//! * [`ArrivalSpec::Legacy`] (the default) builds **no model at all** —
//!   `poll_admission` keeps the seed code path, including the
//!   `AdaptiveThreshold` mode's exponential draw from the *core's* RNG
//!   stream, so default configs reproduce seed behaviour bit for bit.
//! * Deterministic models (`Constant`, `Trace`) consume no randomness;
//!   rate-modulated models (`FlashCrowd`, `Diurnal`) consume exactly one
//!   draw per admission, so replacing one stochastic model with another
//!   never shifts any other stream.
//!
//! Models see `now` (the scheduled admission time) and may modulate their
//! rate with it; they never see the clock directly, so the same model
//! instance behaves identically in virtual and wall time.

use anyhow::{bail, Context, Result};

use crate::util::rng::{streams, Pcg64};

/// One source's arrival process. `next_dt` returns the delay until the
/// next admission given the admission mode's mean pacing `base_dt_s`
/// (already controller-adapted under Alg. 3) evaluated at time `now`.
/// The returned delay is *before* the placement's `rate_share` scaling —
/// the core applies that uniformly, so shares keep meaning "k× the
/// configured rate" under every model.
pub trait ArrivalModel: Send {
    fn name(&self) -> &'static str;
    fn next_dt(&mut self, now: f64, base_dt_s: f64) -> f64;
}

/// Declarative arrival-model choice (config-level; [`ArrivalSpec::build`]
/// turns it into a live model per source).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum ArrivalSpec {
    /// Seed behaviour: pacing comes from the admission mode alone
    /// (deterministic under `Fixed`/`AdaptiveRate`, the core-stream
    /// exponential under `AdaptiveThreshold`). Builds no model.
    #[default]
    Legacy,
    /// Deterministic pacing at exactly the mode's mean (`dt = base_dt`).
    /// Under `AdaptiveThreshold` this *removes* the seed's exponential
    /// jitter — the explicit constant-rate back-compat model.
    Constant,
    /// Homogeneous Poisson process at the mode's mean rate.
    Poisson,
    /// Poisson process whose rate ramps up to `peak_mult ×` the base rate
    /// and back down: linear up over [`at_s`, `at_s + ramp_s`], linear
    /// down over [`at_s + ramp_s`, `at_s + 2·ramp_s`].
    FlashCrowd { peak_mult: f64, at_s: f64, ramp_s: f64 },
    /// Poisson process with a sinusoidal rate profile:
    /// `rate × (1 + depth · sin(2π · now / period_s))`.
    Diurnal { period_s: f64, depth: f64 },
    /// Replay recorded interarrival gaps (seconds), cycling when the trace
    /// is exhausted. Ignores `base_dt_s` — the trace IS the rate.
    Trace { dts: Vec<f64> },
}

impl ArrivalSpec {
    /// Parse the CLI spelling: `legacy | constant | poisson | flash-crowd |
    /// diurnal | trace:PATH` (named models use default parameters; the
    /// `[workload]` TOML section sets the fine-grained knobs).
    pub fn parse_cli(s: &str) -> Result<ArrivalSpec> {
        if let Some(path) = s.strip_prefix("trace:") {
            return ArrivalSpec::trace_from_file(path);
        }
        Ok(match s {
            "legacy" => ArrivalSpec::Legacy,
            "constant" => ArrivalSpec::Constant,
            "poisson" => ArrivalSpec::Poisson,
            "flash-crowd" => {
                ArrivalSpec::FlashCrowd { peak_mult: 8.0, at_s: 30.0, ramp_s: 5.0 }
            }
            "diurnal" => ArrivalSpec::Diurnal { period_s: 60.0, depth: 0.5 },
            other => bail!(
                "unknown arrival model {other:?} \
                 (expected legacy|constant|poisson|flash-crowd|diurnal|trace:PATH)"
            ),
        })
    }

    /// Load a trace file: one interarrival gap (seconds) per line, `#`
    /// comments and blank lines ignored. Loaded eagerly so config parsing
    /// reports file errors and worker construction stays infallible.
    pub fn trace_from_file(path: &str) -> Result<ArrivalSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading arrival trace {path:?}"))?;
        let mut dts = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let dt: f64 = line
                .parse()
                .with_context(|| format!("{path}:{}: bad interarrival {line:?}", i + 1))?;
            dts.push(dt);
        }
        let spec = ArrivalSpec::Trace { dts };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            ArrivalSpec::Legacy | ArrivalSpec::Constant | ArrivalSpec::Poisson => {}
            ArrivalSpec::FlashCrowd { peak_mult, at_s, ramp_s } => {
                if !peak_mult.is_finite() || *peak_mult < 1.0 {
                    bail!("flash-crowd peak_mult must be >= 1, got {peak_mult}");
                }
                if !at_s.is_finite() || *at_s < 0.0 || !ramp_s.is_finite() || *ramp_s <= 0.0 {
                    bail!("flash-crowd needs at_s >= 0 and ramp_s > 0");
                }
            }
            ArrivalSpec::Diurnal { period_s, depth } => {
                if !period_s.is_finite() || *period_s <= 0.0 {
                    bail!("diurnal period_s must be positive, got {period_s}");
                }
                if !depth.is_finite() || !(0.0..1.0).contains(depth) {
                    bail!("diurnal depth must be in [0, 1), got {depth}");
                }
            }
            ArrivalSpec::Trace { dts } => {
                if dts.is_empty() {
                    bail!("arrival trace is empty");
                }
                if let Some(bad) = dts.iter().find(|d| !d.is_finite() || **d <= 0.0) {
                    bail!("arrival trace gaps must be positive and finite, got {bad}");
                }
            }
        }
        Ok(())
    }

    /// Instantiate the model for one source. `None` for [`Legacy`]
    /// (the core then keeps the seed pacing path untouched).
    ///
    /// [`Legacy`]: ArrivalSpec::Legacy
    pub fn build(&self, seed: u64, source: usize) -> Option<Box<dyn ArrivalModel>> {
        let rng = Pcg64::new(seed, streams::ARRIVAL_STREAM_BASE + source as u64);
        match self {
            ArrivalSpec::Legacy => None,
            ArrivalSpec::Constant => Some(Box::new(Constant)),
            ArrivalSpec::Poisson => Some(Box::new(Poisson { rng })),
            ArrivalSpec::FlashCrowd { peak_mult, at_s, ramp_s } => Some(Box::new(FlashCrowd {
                rng,
                peak_mult: *peak_mult,
                at_s: *at_s,
                ramp_s: *ramp_s,
            })),
            ArrivalSpec::Diurnal { period_s, depth } => {
                Some(Box::new(Diurnal { rng, period_s: *period_s, depth: *depth }))
            }
            ArrivalSpec::Trace { dts } => {
                Some(Box::new(TraceReplay { dts: dts.clone(), idx: 0 }))
            }
        }
    }
}

/// Workload description attached to [`crate::coordinator::ExperimentConfig`].
/// A struct (not a bare spec) so later growth — mobility, correlated
/// bursts — lands here without another config migration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WorkloadConfig {
    /// The arrival process every source runs unless overridden below.
    pub arrival: ArrivalSpec,
    /// Per-source overrides: `(source node, spec)` pairs, sorted by node
    /// (TOML `[workload.sources.N]`, CLI `--arrival-source N:SPEC,...`).
    /// Sources not listed share `arrival`. Entries for nodes that are not
    /// sources are harmless — only [`WorkloadConfig::spec_for`] calls from
    /// admitting cores ever read them.
    pub sources: Vec<(usize, ArrivalSpec)>,
}

impl WorkloadConfig {
    /// The arrival spec source `node` runs: its override if listed, the
    /// shared spec otherwise.
    pub fn spec_for(&self, node: usize) -> &ArrivalSpec {
        self.sources
            .iter()
            .find(|(n, _)| *n == node)
            .map(|(_, spec)| spec)
            .unwrap_or(&self.arrival)
    }

    pub fn validate(&self) -> Result<()> {
        self.arrival.validate()?;
        for (node, spec) in &self.sources {
            spec.validate()
                .map_err(|e| anyhow::anyhow!("workload.sources.{node}: {e}"))?;
        }
        let mut nodes: Vec<usize> = self.sources.iter().map(|(n, _)| *n).collect();
        nodes.sort_unstable();
        nodes.dedup();
        if nodes.len() != self.sources.len() {
            bail!("workload.sources lists a source twice");
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Models
// ---------------------------------------------------------------------------

struct Constant;

impl ArrivalModel for Constant {
    fn name(&self) -> &'static str {
        "constant"
    }
    fn next_dt(&mut self, _now: f64, base_dt_s: f64) -> f64 {
        base_dt_s
    }
}

struct Poisson {
    rng: Pcg64,
}

impl ArrivalModel for Poisson {
    fn name(&self) -> &'static str {
        "poisson"
    }
    fn next_dt(&mut self, _now: f64, base_dt_s: f64) -> f64 {
        self.rng.exponential(base_dt_s)
    }
}

/// Rate-modulated Poisson: each gap is exponential at the *instantaneous*
/// rate (a step-wise approximation of the nonhomogeneous process — exact
/// as gaps shrink relative to the ramp, and deterministic given the seed,
/// which is what the subsystem actually contracts).
struct FlashCrowd {
    rng: Pcg64,
    peak_mult: f64,
    at_s: f64,
    ramp_s: f64,
}

impl FlashCrowd {
    fn mult(&self, now: f64) -> f64 {
        let x = now - self.at_s;
        let up = self.ramp_s;
        if x <= 0.0 || x >= 2.0 * up {
            1.0
        } else if x < up {
            1.0 + (self.peak_mult - 1.0) * (x / up)
        } else {
            1.0 + (self.peak_mult - 1.0) * ((2.0 * up - x) / up)
        }
    }
}

impl ArrivalModel for FlashCrowd {
    fn name(&self) -> &'static str {
        "flash-crowd"
    }
    fn next_dt(&mut self, now: f64, base_dt_s: f64) -> f64 {
        self.rng.exponential(base_dt_s / self.mult(now))
    }
}

struct Diurnal {
    rng: Pcg64,
    period_s: f64,
    depth: f64,
}

impl ArrivalModel for Diurnal {
    fn name(&self) -> &'static str {
        "diurnal"
    }
    fn next_dt(&mut self, now: f64, base_dt_s: f64) -> f64 {
        let mult =
            1.0 + self.depth * (2.0 * std::f64::consts::PI * now / self.period_s).sin();
        self.rng.exponential(base_dt_s / mult)
    }
}

struct TraceReplay {
    dts: Vec<f64>,
    idx: usize,
}

impl ArrivalModel for TraceReplay {
    fn name(&self) -> &'static str {
        "trace"
    }
    fn next_dt(&mut self, _now: f64, _base_dt_s: f64) -> f64 {
        let dt = self.dts[self.idx % self.dts.len()];
        self.idx += 1;
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(spec: &ArrivalSpec, seed: u64, source: usize, n: usize) -> Vec<f64> {
        let mut m = spec.build(seed, source).expect("non-legacy spec builds");
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                let dt = m.next_dt(t, 0.02);
                t += dt;
                dt
            })
            .collect()
    }

    #[test]
    fn legacy_builds_no_model() {
        assert!(ArrivalSpec::Legacy.build(7, 0).is_none());
    }

    #[test]
    fn constant_returns_base_dt() {
        let dts = collect(&ArrivalSpec::Constant, 7, 0, 16);
        assert!(dts.iter().all(|&d| (d - 0.02).abs() < 1e-15), "{dts:?}");
    }

    #[test]
    fn poisson_is_seed_deterministic_and_source_separated() {
        let a = collect(&ArrivalSpec::Poisson, 7, 0, 64);
        let b = collect(&ArrivalSpec::Poisson, 7, 0, 64);
        let c = collect(&ArrivalSpec::Poisson, 7, 1, 64);
        let d = collect(&ArrivalSpec::Poisson, 8, 0, 64);
        assert_eq!(a, b, "same (seed, source) replays the same timeline");
        assert_ne!(a, c, "sources draw independent streams");
        assert_ne!(a, d, "different seeds diverge");
    }

    #[test]
    fn poisson_mean_matches_base_dt() {
        let dts = collect(&ArrivalSpec::Poisson, 3, 0, 50_000);
        let mean = dts.iter().sum::<f64>() / dts.len() as f64;
        assert!((mean - 0.02).abs() < 0.001, "mean {mean}");
        assert!(dts.iter().all(|&d| d >= 0.0));
    }

    #[test]
    fn flash_crowd_spikes_at_the_peak() {
        let spec = ArrivalSpec::FlashCrowd { peak_mult: 10.0, at_s: 10.0, ramp_s: 5.0 };
        let mut m = spec.build(1, 0).unwrap();
        let n = 5_000;
        let mean_at = |t: f64, m: &mut Box<dyn ArrivalModel>| {
            (0..n).map(|_| m.next_dt(t, 0.02)).sum::<f64>() / n as f64
        };
        let calm = mean_at(0.0, &mut m);
        let peak = mean_at(15.0, &mut m); // at_s + ramp_s = the crest
        let after = mean_at(60.0, &mut m);
        assert!(peak < calm / 5.0, "peak mean {peak} vs calm {calm}");
        assert!((after / calm).ln().abs() < 0.3, "rate recovers after the burst");
    }

    #[test]
    fn diurnal_modulates_by_phase() {
        let spec = ArrivalSpec::Diurnal { period_s: 40.0, depth: 0.8 };
        let mut m = spec.build(1, 0).unwrap();
        let n = 5_000;
        let mean_at = |t: f64, m: &mut Box<dyn ArrivalModel>| {
            (0..n).map(|_| m.next_dt(t, 0.02)).sum::<f64>() / n as f64
        };
        let crest = mean_at(10.0, &mut m); // sin = +1 → 1.8× rate
        let trough = mean_at(30.0, &mut m); // sin = −1 → 0.2× rate
        assert!(crest < trough / 3.0, "crest {crest} vs trough {trough}");
    }

    #[test]
    fn trace_cycles_and_ignores_base_dt() {
        let spec = ArrivalSpec::Trace { dts: vec![0.5, 0.25] };
        let mut m = spec.build(1, 0).unwrap();
        let got: Vec<f64> = (0..5).map(|_| m.next_dt(0.0, 123.0)).collect();
        assert_eq!(got, vec![0.5, 0.25, 0.5, 0.25, 0.5]);
    }

    #[test]
    fn parse_cli_names() {
        assert_eq!(ArrivalSpec::parse_cli("legacy").unwrap(), ArrivalSpec::Legacy);
        assert_eq!(ArrivalSpec::parse_cli("constant").unwrap(), ArrivalSpec::Constant);
        assert_eq!(ArrivalSpec::parse_cli("poisson").unwrap(), ArrivalSpec::Poisson);
        assert!(matches!(
            ArrivalSpec::parse_cli("flash-crowd").unwrap(),
            ArrivalSpec::FlashCrowd { .. }
        ));
        assert!(matches!(
            ArrivalSpec::parse_cli("diurnal").unwrap(),
            ArrivalSpec::Diurnal { .. }
        ));
        assert!(ArrivalSpec::parse_cli("warp-drive").is_err());
        assert!(ArrivalSpec::parse_cli("trace:/no/such/file").is_err());
    }

    #[test]
    fn trace_file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join("mdi_exit_arrival_trace_test.txt");
        std::fs::write(&path, "# recorded gaps\n0.5\n\n0.25\n0.125\n").unwrap();
        let spec = ArrivalSpec::trace_from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(spec, ArrivalSpec::Trace { dts: vec![0.5, 0.25, 0.125] });
        std::fs::write(&path, "0.5\n-1.0\n").unwrap();
        assert!(ArrivalSpec::trace_from_file(path.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(ArrivalSpec::FlashCrowd { peak_mult: 0.5, at_s: 0.0, ramp_s: 1.0 }
            .validate()
            .is_err());
        assert!(ArrivalSpec::FlashCrowd { peak_mult: 2.0, at_s: 0.0, ramp_s: 0.0 }
            .validate()
            .is_err());
        assert!(ArrivalSpec::Diurnal { period_s: 0.0, depth: 0.5 }.validate().is_err());
        assert!(ArrivalSpec::Diurnal { period_s: 10.0, depth: 1.0 }.validate().is_err());
        assert!(ArrivalSpec::Trace { dts: vec![] }.validate().is_err());
        assert!(ArrivalSpec::Trace { dts: vec![0.1, 0.0] }.validate().is_err());
        assert!(WorkloadConfig::default().validate().is_ok());
    }

    #[test]
    fn spec_for_prefers_the_override() {
        let cfg = WorkloadConfig {
            arrival: ArrivalSpec::Poisson,
            sources: vec![(3, ArrivalSpec::Constant)],
        };
        assert!(cfg.validate().is_ok());
        assert_eq!(*cfg.spec_for(3), ArrivalSpec::Constant);
        assert_eq!(*cfg.spec_for(0), ArrivalSpec::Poisson, "unlisted sources share");
    }

    #[test]
    fn per_source_validation_names_the_source() {
        let cfg = WorkloadConfig {
            arrival: ArrivalSpec::Legacy,
            sources: vec![(2, ArrivalSpec::Diurnal { period_s: 0.0, depth: 0.5 })],
        };
        let err = cfg.validate().expect_err("bad override").to_string();
        assert!(err.contains("workload.sources.2"), "{err}");
        let cfg = WorkloadConfig {
            arrival: ArrivalSpec::Legacy,
            sources: vec![(1, ArrivalSpec::Poisson), (1, ArrivalSpec::Constant)],
        };
        assert!(cfg.validate().is_err(), "duplicate source rejected");
    }
}
