//! Realtime threaded driver: the deployment shape of the system.
//!
//! One OS thread per worker (the paper's per-Jetson process), message
//! passing over `simnet::transport::DelayNet` (link delays enforced by a
//! delivery scheduler), and a per-thread [`crate::runtime::InferenceEngine`]
//! built by an engine factory — with the PJRT engine (`pjrt` feature) this
//! is the full production path: compiled HLO stages, zero Python.
//!
//! All decisions live in the shared [`super::worker::WorkerCore`]; this
//! driver maps the core's [`Action`]s onto the threaded medium: `Send`
//! becomes an endpoint send with real delivery delay, `StartCompute`
//! becomes a wallclock engine call whose measured duration feeds back into
//! `on_compute_done`. Only the clock ([`WallClock`] vs virtual) and the
//! transport differ from the DES driver.
//!
//! Churn schedules work here too (a payoff of the unified core): every
//! thread walks the same `cfg.churn` timeline against its own core, so a
//! leaving worker re-homes its queued tasks over the wire — hop by hop
//! along the routing table toward each task's admitting source — and its
//! peers stop offloading to it. Multi-source placements likewise: every
//! thread whose core says `is_source()` runs its own admission timeline
//! against the shared dataset, and the per-source tallies merge into one
//! report at join time. DDI mode likewise: the core already
//! round-robins whole images at the source, so the driver carries it with
//! no mode-specific code. `StartCompute` hands the thread a same-stage
//! *batch*; one `execute_batch` call runs it as one batched forward per
//! stage, so engines that amortize dispatch (cost emulation pays the stage
//! cost once per call) get real wallclock wins from batching.

use std::collections::VecDeque;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::config::ExperimentConfig;
use super::report::{ClassStats, RunReport};
use super::task::{InferenceResult, Task};
use super::worker::{
    execute_batch, Action, Clock, ModelMeta, TaskOrigin, WallClock, WorkerCore,
};
use crate::cluster::ScaleDecision;
use crate::dataset::Dataset;
use crate::log_info;
use crate::net::Envelope;
use crate::routing::{Role, RoutingTable};
use crate::runtime::InferenceEngine;
use crate::simnet::transport::{DelayNet, Endpoint};
use crate::simnet::{ChurnEvent, Topology};
use crate::telemetry::{self, TelemetryData, TelemetryEvent};
use crate::util::stats::Samples;

const IDLE_PARK: Duration = Duration::from_micros(200);

/// The shared scale bus: the controller thread appends every applied-for
/// [`ScaleDecision`] with its wallclock timestamp; every worker thread walks
/// the bus with a cursor (like the scripted churn timeline) and applies each
/// entry to its own core + routing. Single producer, append-only, so cursors
/// never miss or reorder entries.
type ScaleBus = Arc<Mutex<Vec<(f64, ScaleDecision)>>>;

fn lock_bus(bus: &ScaleBus) -> std::sync::MutexGuard<'_, Vec<(f64, ScaleDecision)>> {
    // A poisoned bus only means another thread panicked mid-push; the data
    // is still a well-formed prefix, so keep going rather than cascade.
    bus.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run the system with real threads + wallclock. `duration_s` of the config
/// is interpreted as wallclock seconds (keep it small in tests). Called via
/// [`super::run::Run`].
// Note: the factory type is spelled inline rather than via the
// `runtime::EngineFactory` alias — the alias carries the `'static`
// object-lifetime default from its definition site, which would reject the
// builder's borrow-scoped factories; inline, the lifetime elides to the
// reference's.
pub(super) fn run_realtime(
    cfg: &ExperimentConfig,
    factory: &(dyn Fn(usize) -> Result<Box<dyn InferenceEngine>> + Send + Sync),
    meta: &ModelMeta,
    dataset: &Dataset,
) -> Result<RunReport> {
    cfg.validate()?;
    let topo = Arc::new(
        Topology::named_seeded(&cfg.topology, cfg.link, cfg.seed)
            .with_context(|| format!("unknown topology {:?}", cfg.topology))?
            .with_churn(cfg.churn.clone()),
    );
    cfg.placement
        .validate(topo.n, &topo.churn)
        .context("placement does not fit the topology")?;
    let n = topo.n;
    // Routes are a property of the run, not of a worker: build them once
    // and share across all n threads (the per-core rebuild was O(n) full
    // shortest-path computations — prohibitive on metro-scale graphs).
    let routing = Arc::new(RoutingTable::build(&topo));
    // The fabric owns the run seed (per-endpoint jitter RNGs derive from
    // it) and the same shared-medium contention model the DES driver
    // applies, so link behaviour is reproducible per config seed and
    // consistent across drivers. Worker threads exchange the SAME
    // `net::Envelope` type the core emits — no driver-private mirror.
    let mut net: DelayNet<Envelope> =
        DelayNet::new(topo.clone(), cfg.seed, cfg.medium_contention);
    let mut endpoints: Vec<Option<Endpoint<Envelope>>> =
        (0..n).map(|i| Some(net.endpoint(i))).collect();

    let (stats_tx, stats_rx) =
        channel::<(usize, super::report::WorkerStats, SourceTally, Option<TelemetryData>)>();
    let t0 = Instant::now();
    let horizon = Duration::from_secs_f64(cfg.warmup_s + cfg.duration_s);
    // Elastic control plane: the initial parking set is a pure function of
    // the config, and the scale bus carries controller decisions to every
    // thread (and, after join, to the cost accounting below).
    let parked: Arc<Vec<usize>> = Arc::new(crate::cluster::initial_parked(
        cfg.cluster.enabled.then_some(cfg.cluster.initial_workers).flatten(),
        &cfg.placement.source_nodes(),
        n,
    ));
    let scale_bus: ScaleBus = Arc::new(Mutex::new(Vec::new()));

    std::thread::scope(|scope| -> Result<()> {
        for id in 0..n {
            let endpoint = endpoints[id].take().expect("endpoint taken once");
            let stats_tx = stats_tx.clone();
            let topo = topo.clone();
            let routing = routing.clone();
            let cfg = cfg.clone();
            let meta = meta.clone();
            let parked = parked.clone();
            let scale_bus = scale_bus.clone();
            scope.spawn(move || {
                let engine = match factory(id) {
                    Ok(e) => e,
                    Err(err) => {
                        log_info!("worker {id}: engine construction failed: {err:#}");
                        let _ = stats_tx.send((
                            id,
                            super::report::WorkerStats::default(),
                            SourceTally::default(),
                            None,
                        ));
                        return;
                    }
                };
                let mut churn: Vec<ChurnEvent> = cfg.churn.clone();
                churn.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
                let tally = SourceTally {
                    exit_histogram: vec![0; meta.num_stages],
                    per_class: (0..cfg.sched.num_classes.max(1))
                        .map(|_| ClassStats::new(meta.num_stages))
                        .collect(),
                    ..SourceTally::default()
                };
                let mut core =
                    WorkerCore::with_routing(id, &cfg, meta.clone(), &topo, &routing, dataset.n);
                if cfg.telemetry.enabled() {
                    core.set_recorder(cfg.telemetry.build_recorder(id, cfg.warmup_s));
                }
                let is_source = core.is_source();
                let mut w = RtWorker {
                    id,
                    cfg: &cfg,
                    meta: &meta,
                    core,
                    endpoint,
                    engine: engine.as_ref(),
                    dataset: is_source.then_some(dataset),
                    clock: WallClock::new(t0),
                    tally,
                    pending: None,
                    churn,
                    churn_idx: 0,
                    topo,
                    active: vec![true; n],
                    scale_bus,
                    scale_idx: 0,
                };
                w.park_initial(&parked);
                w.run(horizon);
                let id = w.id;
                let (stats, tally, tdata) = w.finish();
                let _ = stats_tx.send((id, stats, tally, tdata));
            });
        }
        Ok(())
    })?;
    drop(stats_tx);

    let mut report = RunReport::new(
        &cfg.model,
        &cfg.topology,
        "realtime",
        n,
        meta.num_stages,
        cfg.sched.num_classes as usize,
        &cfg.placement.source_nodes(),
    );
    report.duration_s = cfg.duration_s;
    // Every source thread carries its own tally home; the run totals are
    // the merge, and each tally verbatim is that source's per-source row.
    let lead = cfg.placement.sources[0].node;
    while let Ok((id, stats, tally, tdata)) = stats_rx.recv() {
        report.per_worker[id] = stats;
        if let Some(d) = tdata {
            report.telemetry.get_or_insert_with(TelemetryData::default).merge(d);
        }
        if !cfg.placement.is_source(id) {
            continue;
        }
        if let Some(ss) = report.per_source.iter_mut().find(|s| s.node == id) {
            ss.admitted = tally.admitted;
            ss.completed = tally.completed;
            ss.correct = tally.correct;
            ss.exit_histogram.clone_from(&tally.exit_histogram);
            ss.latency = tally.latency.clone();
        }
        report.admitted += tally.admitted;
        report.completed += tally.completed;
        report.correct += tally.correct;
        for (slot, &c) in report.exit_histogram.iter_mut().zip(&tally.exit_histogram) {
            *slot += c;
        }
        report.latency.absorb(&tally.latency);
        report.rehomed += tally.rehomed;
        for (rc, tc) in report.per_class.iter_mut().zip(&tally.per_class) {
            rc.absorb(tc);
        }
        if id == lead {
            report.final_mu_s = tally.final_mu_s;
            report.final_t_e = tally.final_t_e;
        }
    }
    let bus = lock_bus(&scale_bus);
    let (ups, downs, ws) = fleet_accounting(cfg, n, &parked, &bus);
    report.scale_ups = ups;
    report.scale_downs = downs;
    report.worker_seconds = ws;
    drop(bus);
    report.fold_worker_drops();
    report.fold_wire_totals();
    Ok(report)
}

/// Replay the fleet timeline (initial parking, scripted churn, scale-bus
/// entries) on the main thread after join, producing the scale counters and
/// the worker-seconds cost integral over the measured window. The bus is
/// single-producer and timestamped at publish, so the replay bills each
/// segment at the fleet size that ran it — same integral the DES driver
/// accumulates inline. A static n-node fleet lands on exactly
/// n x duration_s.
fn fleet_accounting(
    cfg: &ExperimentConfig,
    n: usize,
    parked: &[usize],
    bus: &[(f64, ScaleDecision)],
) -> (u64, u64, f64) {
    let mut active = vec![true; n];
    for &p in parked {
        active[p] = false;
    }
    // (t, worker, join, from_bus): scripted churn flips count toward the
    // integral but not the scale counters.
    let mut events: Vec<(f64, usize, bool, bool)> = Vec::new();
    for e in &cfg.churn {
        events.push((e.at_s, e.worker, e.join, false));
    }
    for (t, d) in bus {
        events.push((*t, d.worker, d.join, true));
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    let from0 = cfg.warmup_s;
    let end = cfg.warmup_s + cfg.duration_s;
    let (mut ups, mut downs) = (0u64, 0u64);
    let mut ws = 0.0f64;
    let mut last = 0.0f64;
    for (t, worker, join, from_bus) in events {
        let t_c = t.min(end);
        let lo = last.max(from0);
        if t_c > lo {
            ws += active.iter().filter(|&&a| a).count() as f64 * (t_c - lo);
        }
        last = last.max(t_c);
        // Stale entries (target already in the desired state) are skipped,
        // mirroring each thread's own guard.
        if active[worker] != join {
            active[worker] = join;
            if from_bus {
                if join {
                    ups += 1;
                } else {
                    downs += 1;
                }
            }
        }
    }
    let lo = last.max(from0);
    if end > lo {
        ws += active.iter().filter(|&&a| a).count() as f64 * (end - lo);
    }
    (ups, downs, ws)
}

/// Source-side accounting carried out of each source's worker thread.
#[derive(Default)]
struct SourceTally {
    admitted: u64,
    completed: u64,
    correct: u64,
    exit_histogram: Vec<u64>,
    latency: Samples,
    rehomed: u64,
    per_class: Vec<ClassStats>,
    final_mu_s: Option<f64>,
    final_t_e: Option<f64>,
}

struct RtWorker<'a> {
    id: usize,
    cfg: &'a ExperimentConfig,
    meta: &'a ModelMeta,
    core: WorkerCore,
    endpoint: Endpoint<Envelope>,
    engine: &'a dyn crate::runtime::InferenceEngine,
    dataset: Option<&'a Dataset>,
    clock: WallClock,
    tally: SourceTally,
    /// Same-stage batch handed out by a `StartCompute` action, executed
    /// one batch per loop iteration so admission/gossip/mailbox stay
    /// responsive.
    pending: Option<Vec<Task>>,
    churn: Vec<ChurnEvent>,
    churn_idx: usize,
    topo: Arc<Topology>,
    /// This thread's mirror of the fleet's join/leave state, fed by the
    /// scale bus and (cluster runs) the churn timeline; it drives the local
    /// routing rebuilds, so all threads converge on the same layout.
    active: Vec<bool>,
    scale_bus: ScaleBus,
    /// Cursor into the scale bus: entries before it are already applied.
    scale_idx: usize,
}

impl<'a> RtWorker<'a> {
    fn in_window(&self, now: f64) -> bool {
        now >= self.cfg.warmup_s
    }

    /// Apply the initial parking set before the loop starts: flip the
    /// parked nodes out on this thread's core and adopt the boot layout.
    /// Every thread runs this against the same set, so the fleet boots
    /// identically everywhere (mirrors the DES driver's pre-run parking).
    fn park_initial(&mut self, parked: &[usize]) {
        for &p in parked {
            self.active[p] = false;
            let acts = self.core.on_churn(0.0, p, false);
            self.dispatch(acts);
        }
        if !parked.is_empty() {
            self.relayout();
        }
    }

    fn run(&mut self, horizon: Duration) {
        let mut next_admit = 0.0f64;
        let mut next_adapt = self.cfg.adapt.sleep_s;
        let mut next_gossip = 0.0f64;
        let mut next_cluster = self.cfg.cluster.check_interval_s;
        // Metrics cadence: same `interval_s` the DES driver schedules; an
        // infinite first deadline disables the timer when metrics are off.
        let mut next_metrics = if self.cfg.telemetry.metrics {
            self.cfg.telemetry.interval_s
        } else {
            f64::INFINITY
        };
        while self.clock.now() < horizon.as_secs_f64() {
            let mut progressed = false;

            // 1. drain the mailbox
            while let Some(d) = self.endpoint.try_recv() {
                progressed = true;
                self.on_msg(d.from, d.msg);
            }

            let now = self.clock.now();

            // 2. churn timeline (every thread walks the shared schedule
            //    against its own core)
            while self.churn_idx < self.churn.len() && self.churn[self.churn_idx].at_s <= now {
                let e = self.churn[self.churn_idx];
                self.churn_idx += 1;
                if self.cfg.cluster.enabled {
                    // With the control plane on, scripted churn rides the
                    // same fleet-change path as scale decisions, so routing
                    // follows the live fleet on every thread.
                    if self.active[e.worker] != e.join {
                        self.apply_fleet_change(now, e.worker, e.join);
                    }
                } else {
                    let acts = self.core.on_churn(now, e.worker, e.join);
                    self.dispatch(acts);
                }
                progressed = true;
            }

            // 2b. elastic control plane: the controller core sweeps health
            //     + autoscaling on its cadence (decisions leave via the
            //     scale bus), and every thread drains the bus with its
            //     cursor, applying each decision to its own core/routing.
            if self.cfg.cluster.enabled {
                if self.core.runs_cluster_controller() && now >= next_cluster {
                    let acts = self.core.on_cluster_tick(now);
                    self.dispatch(acts);
                    next_cluster = now + self.cfg.cluster.check_interval_s;
                }
                loop {
                    let entry = lock_bus(&self.scale_bus).get(self.scale_idx).copied();
                    let Some((_, d)) = entry else { break };
                    self.scale_idx += 1;
                    self.apply_scale(now, d);
                    progressed = true;
                }
            }

            // 3. source duties: admission + adaptation. Admit *every* due
            // arrival, not one per loop iteration: when compute occupies
            // the thread for a while, capping admission at the loop rate
            // would silently under-admit relative to the configured rate
            // (the DES driver has no such cap), hiding overload from the
            // queues — and with it the backlog that batching and the
            // priority disciplines exist to manage. The clock is re-read
            // every iteration (not the `now` sampled above): bursty
            // arrival models (Poisson, flash crowd) can schedule several
            // admissions inside one drain, and a stale bound would defer
            // the tail of the burst by a full loop pass each — loop-rate
            // capping through the back door.
            while self.core.is_source() && self.clock.now() >= next_admit {
                // Stamp the task with its *scheduled* admission time, not
                // the post-catch-up `now`: that is when the DES driver
                // admits it, and using `now` would under-report latency
                // and shift EDF deadlines whenever compute blocked the
                // loop (coordinated omission).
                let at = next_admit;
                let (mut task, dt) = self.core.poll_admission(at);
                let ds = self.dataset.expect("source has the dataset");
                task.features = Some(ds.image(task.sample));
                if self.in_window(at) {
                    self.tally.admitted += 1;
                }
                let acts = self.core.on_task(now, task, TaskOrigin::Admitted);
                self.dispatch(acts);
                next_admit += dt;
                progressed = true;
            }
            if self.core.has_controller() && now >= next_adapt {
                let acts = self.core.on_adapt_tick(now);
                self.dispatch(acts);
                next_adapt = now + self.cfg.adapt.sleep_s;
            }

            // 4. gossip
            if now >= next_gossip {
                let acts = self.core.on_gossip_tick(now);
                self.dispatch(acts);
                next_gossip = now + self.cfg.gossip_interval_s;
            }

            // 4b. telemetry metrics sample (read-only on the core)
            if now >= next_metrics {
                self.core.on_metrics_tick(now);
                next_metrics = now + self.cfg.telemetry.interval_s;
            }

            // 5. run one batch through the engine (Alg. 1 on completion)
            if let Some(mut batch) = self.pending.take() {
                progressed = true;
                let started = Instant::now();
                match execute_batch(
                    self.engine,
                    self.cfg.mode,
                    self.meta.num_stages,
                    &mut batch,
                ) {
                    Ok(results) => {
                        let dur = started.elapsed().as_secs_f64();
                        let now = self.clock.now();
                        let acts = self.core.on_compute_done(now, batch, results, dur);
                        self.dispatch(acts);
                    }
                    Err(err) => {
                        log_info!(
                            "worker {}: stage {} failed: {err:#}",
                            self.id,
                            batch.first().map(|t| t.stage).unwrap_or(0)
                        );
                        let now = self.clock.now();
                        // Drop the batch *with accounting* (it shows up in
                        // the report's dropped counters) rather than
                        // re-homing: execute_batch may already have
                        // consumed the feature tensors, and a
                        // deterministically failing task would otherwise
                        // retry forever.
                        let acts = self.core.abort_compute(now, batch);
                        self.dispatch(acts);
                    }
                }
            }

            if !progressed {
                std::thread::park_timeout(IDLE_PARK);
            }
        }
        if self.core.is_source() {
            self.tally.final_mu_s = self.core.final_mu_s();
            self.tally.final_t_e = self.core.final_t_e();
        }
        // Closing metrics sample: the last row per worker carries the
        // full-window counters (mirrors the DES driver's finalize).
        if self.cfg.telemetry.metrics {
            let end = self.clock.now();
            self.core.on_metrics_tick(end);
        }
    }

    /// Map core actions onto the threaded medium.
    fn dispatch(&mut self, actions: Vec<Action>) {
        let mut q: VecDeque<Action> = actions.into();
        while let Some(a) = q.pop_front() {
            match a {
                Action::StartCompute { batch, .. } => {
                    debug_assert!(self.pending.is_none(), "core double-started compute");
                    self.pending = Some(batch);
                }
                Action::Send { to, env, needs_encode } => {
                    // Only task transfers feed the D_nm estimator — gossip
                    // and result messages are tiny and would bias Alg. 2's
                    // transfer-delay term (the DES driver does the same).
                    let mut env = env;
                    let is_task = env.is_task_batch();
                    if needs_encode {
                        // Shared with the DES driver: one batched encoder
                        // forward for the whole envelope, raw fallback per
                        // tensor (the charge function then prices the raw
                        // tensor), wire-counter reconciliation included.
                        // The forward count only matters to the DES
                        // driver's virtual cost charge.
                        let now = self.clock.now();
                        let _ = self.core.encode_for_wire(self.engine, now, &mut env);
                    }
                    // One shared charging function with the DES driver —
                    // sized after the AE step, framed once per envelope.
                    let bytes = env.encoded_bytes(self.meta);
                    let items = env.items();
                    // Wire legs are recorded by the sender — the only side
                    // that knows the delivery delay. The envelope is
                    // consumed by `send`, so cut the events first and
                    // stamp the sampled delay in once it is known.
                    let wire_events: Option<Vec<TelemetryEvent>> =
                        if self.core.has_recorder() {
                            let now = self.clock.now();
                            let mut evs = Vec::new();
                            telemetry::wire_send_events(
                                now, self.id, to, &env, bytes, 0.0,
                                |ev| evs.push(ev),
                            );
                            Some(evs)
                        } else {
                            None
                        };
                    // An Err means the fabric already shut down (end of
                    // run): drop the message, as the seed driver did.
                    if let Ok(delay) = self.endpoint.send(to, env, bytes) {
                        if is_task {
                            // Per-task amortized share, like the DES
                            // driver (and like Γ_n for batched compute).
                            self.core.note_transfer_delay(to, delay / items.max(1) as f64);
                        }
                        if let Some(evs) = wire_events {
                            for mut ev in evs {
                                if let TelemetryEvent::WireSend { delay_s, .. } = &mut ev {
                                    *delay_s = delay;
                                }
                                self.core.record_event(&ev);
                            }
                        }
                    }
                }
                Action::RecordResult { result } => self.record_result(result),
                Action::Scale(d) => {
                    // Only the controller core emits these; publishing on
                    // the bus (rather than applying directly) keeps one
                    // fleet-change path for every thread, controller
                    // included — it picks the entry up on its own cursor.
                    let now = self.clock.now();
                    lock_bus(&self.scale_bus).push((now, d));
                }
            }
        }
    }

    /// Apply one scale-bus entry to this thread. Stale decisions (the
    /// target already flipped, e.g. scripted churn raced the controller)
    /// are dropped, exactly as in the DES driver.
    fn apply_scale(&mut self, now: f64, d: ScaleDecision) {
        if self.active[d.worker] == d.join {
            return;
        }
        self.apply_fleet_change(now, d.worker, d.join);
        // The telemetry Scale mark is cut on the target's own thread so it
        // lands in that worker's recorder, like every other lifecycle event.
        if d.worker == self.id && self.core.has_recorder() {
            let fleet = self.active.iter().filter(|&&a| a).count();
            let ev = TelemetryEvent::Scale {
                t: now,
                worker: d.worker,
                join: d.join,
                reason: d.reason.label(),
                fleet,
            };
            self.core.record_event(&ev);
        }
    }

    /// The thread-local half of a fleet change: notify the core (in-flight
    /// batches finish where they are queued) and rebuild routing over the
    /// surviving fleet. Each thread rebuilds its own row; the build is
    /// deterministic in (topo, active), so all threads converge on the
    /// same layout without sharing the table.
    fn apply_fleet_change(&mut self, now: f64, worker: usize, join: bool) {
        self.active[worker] = join;
        let acts = self.core.on_churn(now, worker, join);
        self.dispatch(acts);
        self.relayout();
    }

    fn relayout(&mut self) {
        let routing = RoutingTable::build_active(&self.topo, &self.active);
        let role = Role::of(self.id, &self.cfg.placement, &routing);
        self.core.apply_relayout(routing.row(self.id), role);
    }

    fn on_msg(&mut self, from: usize, env: Envelope) {
        let now = self.clock.now();
        if self.core.has_recorder() {
            let ev = TelemetryEvent::WireRecv {
                t: now,
                worker: self.id,
                from,
                kind: telemetry::wire_kind(&env),
                items: env.items(),
            };
            self.core.record_event(&ev);
        }
        // Piggybacked gossip is unwrapped first — summary arrival, then
        // payload delivery, exactly as the DES driver orders them.
        let (env, gossip) = env.split_gossip();
        if let Some(summary) = gossip {
            let acts = self.core.on_gossip(now, from, summary);
            self.dispatch(acts);
        }
        let acts = match env {
            Envelope::TaskBatch(tasks) => {
                self.core.on_task_batch(now, tasks, TaskOrigin::Wire)
            }
            Envelope::Rehome(tasks) => {
                if tasks.first().is_some_and(|t| t.source == self.id) {
                    // Terminal delivery at the admitting source counts the
                    // displaced tasks as re-homed; relay hops do not.
                    self.tally.rehomed += tasks.len() as u64;
                }
                self.core.on_rehome(now, tasks)
            }
            Envelope::Result(rs) => self.core.on_result(now, rs),
            Envelope::State(summary) => self.core.on_gossip(now, from, summary),
            Envelope::Piggybacked(..) => unreachable!("split_gossip unwraps piggybacking"),
        };
        self.dispatch(acts);
    }

    fn record_result(&mut self, r: InferenceResult) {
        let now = self.clock.now();
        if !self.in_window(now) {
            return;
        }
        let ds = self.dataset.expect("source records results");
        self.tally.completed += 1;
        let correct = r.prediction == ds.label(r.sample);
        if correct {
            self.tally.correct += 1;
        }
        self.tally.exit_histogram[r.exit_point - 1] += 1;
        let latency = now - r.admitted_at;
        let on_time = now <= r.deadline;
        self.tally.latency.push(latency);
        // Same clamp rule as `RunReport::record_class`: out-of-range
        // classes fold into the last bucket.
        let i = (r.class as usize).min(self.tally.per_class.len().saturating_sub(1));
        if let Some(cs) = self.tally.per_class.get_mut(i) {
            cs.record(r.exit_point, correct, on_time, latency);
        }
    }

    fn finish(mut self) -> (super::report::WorkerStats, SourceTally, Option<TelemetryData>) {
        let data = self.core.take_recorder().map(|r| r.finish());
        (self.core.into_stats(), self.tally, data)
    }
}
