//! Realtime threaded driver: the deployment shape of the system.
//!
//! One OS thread per worker (the paper's per-Jetson process), message
//! passing over `simnet::transport::DelayNet` (link delays enforced by a
//! delivery scheduler), and a per-thread [`crate::runtime::InferenceEngine`]
//! built by an engine factory — with [`crate::runtime::xla_engine::XlaEngine`]
//! this is the full production path: compiled HLO stages executing on PJRT,
//! zero Python.
//!
//! The decision logic is the same `policy` module the DES driver uses;
//! only the clock (wallclock vs virtual) and the transport differ.
//!
//! Churn schedules are a DES-driver feature; the realtime driver runs a
//! fixed worker set (threads joining/leaving mid-run adds little beyond
//! what the DES churn tests already cover, at much higher flake risk).

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::config::{AdmissionMode, ExperimentConfig, Mode};
use super::policy::{
    self, ExitDecision, NeighborView, RateController, ThresholdController,
};
use super::queues::WorkerQueues;
use super::report::{RunReport, WorkerStats};
use super::sim::ModelMeta;
use super::task::{InferenceResult, Task};
use crate::dataset::Dataset;
use crate::log_info;

use crate::simnet::transport::{DelayNet, Endpoint};
use crate::simnet::Topology;
use crate::util::rng::Pcg64;
use crate::util::stats::{Ewma, Samples};

const RESULT_BYTES: usize = 64;
const STATE_BYTES: usize = 32;
const IDLE_PARK: Duration = Duration::from_micros(200);

/// Messages exchanged between worker threads.
enum NetMsg {
    Task(Task),
    Result(InferenceResult),
    /// Gossiped neighbor state (paper §IV.A: "periodically learns ... its
    /// input queue size I_m, per task computing delay Γ_m").
    State { input_len: usize, gamma_s: f64 },
}

/// Outcome of a realtime run (assembled from per-thread stats).
pub struct RtOutcome {
    pub report: RunReport,
}

/// Run the system with real threads + wallclock. `duration_s` of the config
/// is interpreted as wallclock seconds (keep it small in tests).
pub fn run_realtime<F>(
    cfg: &ExperimentConfig,
    factory: &F,
    meta: &ModelMeta,
    dataset: &Dataset,
) -> Result<RtOutcome>
where
    F: Fn(usize) -> Result<Box<dyn crate::runtime::InferenceEngine>> + Send + Sync,
{
    cfg.validate()?;
    anyhow::ensure!(cfg.mode == Mode::MdiExit, "realtime driver runs MDI-Exit mode");
    let topo = Arc::new(
        Topology::named(&cfg.topology, cfg.link)
            .with_context(|| format!("unknown topology {:?}", cfg.topology))?,
    );
    let n = topo.n;
    let mut net: DelayNet<NetMsg> = DelayNet::new(topo.clone(), cfg.seed);
    let mut endpoints: Vec<Endpoint<NetMsg>> = (0..n).map(|i| net.endpoint(i, cfg.seed)).collect();
    endpoints.reverse(); // pop() gives worker 0 first

    let (stats_tx, stats_rx) = channel::<(usize, WorkerStats, SourceTally)>();
    let t0 = Instant::now();
    let horizon = Duration::from_secs_f64(cfg.warmup_s + cfg.duration_s);

    std::thread::scope(|scope| -> Result<()> {
        for id in 0..n {
            let endpoint = endpoints.pop().expect("endpoint");
            let stats_tx = stats_tx.clone();
            let topo = topo.clone();
            let cfg = cfg.clone();
            let meta = meta.clone();
            scope.spawn(move || {
                let engine = match factory(id) {
                    Ok(e) => e,
                    Err(err) => {
                        log_info!("worker {id}: engine construction failed: {err:#}");
                        let _ = stats_tx.send((id, WorkerStats::default(), SourceTally::default()));
                        return;
                    }
                };
                let mut w = RtWorker {
                    id,
                    cfg: &cfg,
                    meta: &meta,
                    topo: &topo,
                    endpoint,
                    engine: engine.as_ref(),
                    dataset: if id == 0 { Some(dataset) } else { None },
                    queues: WorkerQueues::new(),
                    gamma: Ewma::new(0.2),
                    views: vec![None; topo.n],
                    d_est: (0..topo.n).map(|_| Ewma::new(0.2)).collect(),
                    rng: Pcg64::new(cfg.seed, 1000 + id as u64),
                    stats: WorkerStats::default(),
                    tally: SourceTally::default(),
                    t0,
                    measure_from: cfg.warmup_s,
                    next_task_id: (id as u64) << 48,
                    next_sample: 0,
                    rate_ctl: None,
                    thr_ctl: None,
                    t_e: 0.9,
                };
                w.init_controllers();
                w.run(horizon);
                let _ = stats_tx.send((w.id, w.stats, w.tally));
            });
        }
        Ok(())
    })?;
    drop(stats_tx);

    let mut report = RunReport::new(&cfg.model, &cfg.topology, "realtime", n, meta.num_stages);
    report.duration_s = cfg.duration_s;
    while let Ok((id, stats, tally)) = stats_rx.recv() {
        report.per_worker[id] = stats;
        if id == 0 {
            report.admitted = tally.admitted;
            report.completed = tally.completed;
            report.correct = tally.correct;
            report.exit_histogram = tally.exit_histogram;
            report.latency = tally.latency;
            report.final_mu_s = tally.final_mu_s;
            report.final_t_e = tally.final_t_e;
        }
    }
    if report.exit_histogram.is_empty() {
        report.exit_histogram = vec![0; meta.num_stages];
    }
    Ok(RtOutcome { report })
}

/// Source-side accounting carried out of the worker-0 thread.
#[derive(Default)]
struct SourceTally {
    admitted: u64,
    completed: u64,
    correct: u64,
    exit_histogram: Vec<u64>,
    latency: Samples,
    final_mu_s: Option<f64>,
    final_t_e: Option<f64>,
}

struct RtWorker<'a> {
    id: usize,
    cfg: &'a ExperimentConfig,
    meta: &'a ModelMeta,
    topo: &'a Topology,
    endpoint: Endpoint<NetMsg>,
    engine: &'a dyn crate::runtime::InferenceEngine,
    dataset: Option<&'a Dataset>,
    queues: WorkerQueues,
    gamma: Ewma,
    views: Vec<Option<NeighborView>>,
    d_est: Vec<Ewma>,
    rng: Pcg64,
    stats: WorkerStats,
    tally: SourceTally,
    t0: Instant,
    measure_from: f64,
    next_task_id: u64,
    next_sample: usize,
    rate_ctl: Option<RateController>,
    thr_ctl: Option<ThresholdController>,
    t_e: f32,
}

impl<'a> RtWorker<'a> {
    fn init_controllers(&mut self) {
        self.tally.exit_histogram = vec![0; self.meta.num_stages];
        match self.cfg.admission {
            AdmissionMode::AdaptiveRate { threshold, initial_mu_s } => {
                self.t_e = threshold;
                if self.id == 0 {
                    self.rate_ctl = Some(RateController::new(self.cfg.adapt, initial_mu_s));
                }
            }
            AdmissionMode::AdaptiveThreshold { initial_t_e, t_e_min, .. } => {
                self.t_e = initial_t_e;
                if self.id == 0 {
                    self.thr_ctl = Some(ThresholdController::new(
                        self.cfg.adapt,
                        initial_t_e as f64,
                        t_e_min as f64,
                    ));
                }
            }
            AdmissionMode::Fixed { threshold, .. } => self.t_e = threshold,
        }
    }

    fn now_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn in_window(&self) -> bool {
        self.now_s() >= self.measure_from
    }

    fn run(&mut self, horizon: Duration) {
        let mut next_admit = 0.0f64;
        let mut next_adapt = self.cfg.adapt.sleep_s;
        let mut next_gossip = 0.0f64;
        while self.t0.elapsed() < horizon {
            let mut progressed = false;

            // 1. drain the mailbox
            while let Some(d) = self.endpoint.try_recv() {
                progressed = true;
                self.on_msg(d.from, d.msg);
            }

            let now = self.now_s();

            // 2. source duties: admission + adaptation
            if self.id == 0 && now >= next_admit {
                self.admit(now);
                progressed = true;
                let dt = match self.cfg.admission {
                    AdmissionMode::AdaptiveRate { .. } => {
                        self.rate_ctl.as_ref().unwrap().mu_s()
                    }
                    AdmissionMode::AdaptiveThreshold { rate_hz, .. } => {
                        self.rng.exponential(1.0 / rate_hz)
                    }
                    AdmissionMode::Fixed { rate_hz, .. } => 1.0 / rate_hz,
                };
                next_admit = now + dt;
            }
            if self.id == 0 && now >= next_adapt {
                let q = self.queues.total_len();
                if let Some(rc) = self.rate_ctl.as_mut() {
                    rc.update(q);
                }
                if let Some(tc) = self.thr_ctl.as_mut() {
                    self.t_e = tc.update(q) as f32;
                }
                next_adapt = now + self.cfg.adapt.sleep_s;
            }

            // 3. gossip
            if now >= next_gossip {
                let state = NetMsg::State {
                    input_len: self.queues.input.len(),
                    gamma_s: self.gamma.get_or(0.01),
                };
                for m in self.endpoint.neighbors() {
                    let _ = self.endpoint.send(
                        m,
                        NetMsg::State {
                            input_len: match &state {
                                NetMsg::State { input_len, .. } => *input_len,
                                _ => unreachable!(),
                            },
                            gamma_s: self.gamma.get_or(0.01),
                        },
                        STATE_BYTES,
                    );
                }
                next_gossip = now + self.cfg.gossip_interval_s;
            }

            // 4. process one input task (Alg. 1)
            if let Some(task) = self.queues.input.pop() {
                progressed = true;
                self.process(task);
            }

            // 5. offload scan (Alg. 2)
            if self.try_offload() {
                progressed = true;
            }

            if !progressed {
                std::thread::park_timeout(IDLE_PARK);
            }
        }
        if self.id == 0 {
            self.tally.final_mu_s = self.rate_ctl.as_ref().map(|c| c.mu_s());
            self.tally.final_t_e = self.thr_ctl.as_ref().map(|c| c.t_e());
        }
    }

    fn admit(&mut self, now: f64) {
        let ds = self.dataset.expect("source has the dataset");
        let sample = self.next_sample;
        self.next_sample = (self.next_sample + 1) % ds.n;
        self.next_task_id += 1;
        let task = Task::initial(self.next_task_id, sample, Some(ds.image(sample)), now);
        if self.in_window() {
            self.tally.admitted += 1;
        }
        self.queues.input.push(task);
    }

    fn on_msg(&mut self, from: usize, msg: NetMsg) {
        match msg {
            NetMsg::Task(task) => {
                if self.in_window() {
                    self.stats.received += 1;
                }
                self.queues.input.push(task);
                self.stats.peak_input = self.stats.peak_input.max(self.queues.input.len());
            }
            NetMsg::Result(r) => self.record_result(r),
            NetMsg::State { input_len, gamma_s } => {
                let d = self.d_est[from].get_or(
                    self.topo
                        .link(self.id, from)
                        .map(|l| l.mean_delay_s(self.meta.stage_in_bytes[0]))
                        .unwrap_or(0.01),
                );
                self.views[from] = Some(NeighborView { input_len, gamma_s, d_nm_s: d });
            }
        }
    }

    fn process(&mut self, mut task: Task) {
        let started = Instant::now();
        // decode AE payloads before the stage (paper §V wire path)
        if task.encoded {
            if let Some(f) = task.features.take() {
                match self.engine.decode(&f) {
                    Ok(Some(dec)) => task.features = Some(dec),
                    _ => task.features = Some(f),
                }
            }
            task.encoded = false;
        }
        let out = match self.engine.run_stage(task.stage, task.sample, task.features.as_ref()) {
            Ok(o) => o,
            Err(err) => {
                log_info!("worker {}: stage {} failed: {err:#}", self.id, task.stage);
                return;
            }
        };
        let dur = started.elapsed().as_secs_f64();
        self.gamma.push(dur);
        if self.in_window() {
            self.stats.processed += 1;
            self.stats.busy_s += dur;
        }

        let is_final = task.stage >= self.meta.num_stages;
        let threshold = if self.cfg.no_early_exit { f32::INFINITY } else { self.t_e };
        let decision = policy::alg1_decide(
            out.confidence,
            threshold,
            is_final,
            self.queues.input.len(),
            self.queues.output.len(),
            self.cfg.t_o,
        );
        match decision {
            ExitDecision::Exit => {
                if self.in_window() {
                    self.stats.exits += 1;
                }
                let r = InferenceResult {
                    sample: task.sample,
                    exit_point: task.stage,
                    prediction: out.prediction,
                    confidence: out.confidence,
                    admitted_at: task.admitted_at,
                    exited_on: self.id,
                };
                if self.id == 0 {
                    self.record_result(r);
                } else {
                    let _ = self.endpoint.send(0, NetMsg::Result(r), RESULT_BYTES);
                }
            }
            ExitDecision::ContinueLocal => {
                self.next_task_id += 1;
                let succ = task.successor(self.next_task_id, out.features);
                self.queues.input.push(succ);
            }
            ExitDecision::ContinueOffload => {
                self.next_task_id += 1;
                let succ = task.successor(self.next_task_id, out.features);
                self.queues.output.push(succ);
            }
        }
        self.stats.peak_input = self.stats.peak_input.max(self.queues.input.len());
        self.stats.peak_output = self.stats.peak_output.max(self.queues.output.len());
    }

    fn try_offload(&mut self) -> bool {
        let mut any = false;
        loop {
            if self.queues.output.is_empty() {
                return any;
            }
            let mut neighbors = self.endpoint.neighbors();
            self.rng.shuffle(&mut neighbors);
            let mut sent = false;
            for m in neighbors {
                let view = self.views[m].unwrap_or(NeighborView {
                    input_len: 0,
                    gamma_s: 0.01,
                    d_nm_s: self.d_est[m].get_or(0.01),
                });
                let go = policy::offload_decide(
                    self.cfg.offload_policy,
                    self.queues.output.len(),
                    self.queues.input.len(),
                    self.gamma.get_or(0.01),
                    &view,
                    &mut self.rng,
                );
                if !go {
                    continue;
                }
                let mut t = self.queues.output.pop().unwrap();
                let mut bytes = self.meta.stage_in_bytes[t.stage - 1];
                // AE boundary: encode before the wire (stage-2 inputs only)
                if self.cfg.use_ae && t.stage == 2 && !t.encoded {
                    if let (Some(f), Some(ae)) = (t.features.take(), self.meta.ae.as_ref()) {
                        match self.engine.encode(&f) {
                            Ok(Some(code)) => {
                                t.features = Some(code);
                                t.encoded = true;
                                bytes = ae.code_bytes;
                            }
                            _ => t.features = Some(f),
                        }
                    }
                }
                t.hops += 1;
                match self.endpoint.send(m, NetMsg::Task(t), bytes) {
                    Ok(delay) => {
                        self.d_est[m].push(delay);
                        if let Some(v) = self.views[m].as_mut() {
                            v.input_len += 1;
                        }
                        if self.in_window() {
                            self.stats.offloaded_out += 1;
                        }
                        sent = true;
                        any = true;
                    }
                    Err(_) => return any,
                }
                break;
            }
            if !sent {
                // reclaim for local compute when starving (see sim.rs)
                if self.queues.input.is_empty() {
                    if let Some(t) = self.queues.output.pop() {
                        self.queues.input.push(t);
                        any = true;
                    }
                }
                return any;
            }
        }
    }

    fn record_result(&mut self, r: InferenceResult) {
        if !self.in_window() {
            return;
        }
        let ds = self.dataset.expect("source records results");
        self.tally.completed += 1;
        if r.prediction == ds.label(r.sample) {
            self.tally.correct += 1;
        }
        self.tally.exit_histogram[r.exit_point - 1] += 1;
        self.tally.latency.push(self.now_s() - r.admitted_at);
    }
}
