//! The paper's contribution: the MDI-Exit coordinator.
//!
//! * [`crate::policy`] (re-exported here as `policy`) — Algorithms 1–4 as
//!   pure decision logic *and* as the pluggable `ExitPolicy` /
//!   `OffloadPolicy` / `AdaptPolicy` trait surface the core consumes
//! * [`worker`] — the clock-agnostic [`WorkerCore`]: one events-in /
//!   actions-out state machine (queues, estimators, policies, stats)
//!   shared verbatim by both drivers
//! * [`task`], [`queues`] — τ_k(d) records and the I_n/O_n queue pair
//! * [`crate::net`] (re-exported as `Envelope` etc.) — the unified wire
//!   layer: every message both drivers carry is a typed envelope, batches
//!   are first-class on it, and byte charges come from one shared
//!   function
//! * [`config`], [`report`] — experiment descriptions and run reports
//! * [`crate::telemetry`] — observability over the same seam: when a run
//!   enables it, each core carries a [`crate::telemetry::Recorder`] that
//!   turns the events-in/actions-out flow into per-task trace spans
//!   (Chrome trace JSON, Perfetto-loadable), sampled metrics time-series,
//!   and a flight-recorder ring — identically on both drivers
//! * [`run`] — the [`Run`] builder façade: pick [`Driver::Des`] or
//!   [`Driver::Realtime`], everything else stays identical
//! * [`sim`] — discrete-event driver (virtual time; figure benches)
//! * `rt` — realtime threaded driver (wallclock; PJRT engine, examples),
//!   reached through [`Run`]
//!
//! The split mirrors what the paper claims: Algs 1–4 are medium-agnostic.
//! Drivers own clocks and transports; [`WorkerCore`] owns every decision,
//! so new scenarios (schedulers, workloads, queue disciplines) land once —
//! the [`crate::sched`] subsystem (queue disciplines, traffic classes,
//! batched compute) plugs in exactly there, configured per run via
//! [`config::ExperimentConfig::sched`]. Likewise *where* data enters and
//! results land: [`crate::routing`] turns source placement and next-hop
//! delivery into config ([`config::ExperimentConfig::placement`]), so one
//! or many sources on arbitrary multi-hop topologies run through the same
//! core on both drivers.

pub mod clock;
pub mod config;
pub mod equeue;
pub mod queues;
pub mod report;
mod rt;
pub mod run;
pub mod sim;
pub mod task;
pub mod worker;

/// The decision-policy subsystem (promoted out of the coordinator in the
/// policy-API redesign; re-exported so `coordinator::policy::...` paths
/// keep reading naturally).
pub use crate::policy;

pub use config::{AdmissionMode, ExperimentConfig, Mode};
pub use crate::policy::{
    AdaptConfig, AdaptKind, ExitKind, NeighborSummary, OffloadKind, PolicyConfig,
};
pub use equeue::{EventQueue, QueueKind};
pub use report::{ClassStats, RunReport, SourceStats, WorkerStats};
pub use run::{Driver, Run, RunBuilder};
pub use sim::{SampleStore, Simulation};
// Placement/routing surface (re-exported so run code reads naturally).
pub use crate::routing::{Placement, Role, RoutingTable, SourceSpec};
// The wire layer (re-exported so driver-adjacent code reads naturally).
pub use crate::net::{Envelope, ENVELOPE_HEADER_BYTES, RESULT_BYTES};
pub use worker::{
    encode_batch, execute_batch, Action, AeMeta, Clock, ModelMeta, TaskOrigin, VirtualClock,
    WallClock, WorkerCore,
};
