//! The paper's contribution: the MDI-Exit coordinator.
//!
//! * [`policy`] — Algorithms 1–4 as pure decision logic
//! * [`task`], [`queues`] — τ_k(d) records and the I_n/O_n queue pair
//! * [`config`], [`report`] — experiment descriptions and run reports
//! * [`sim`] — discrete-event driver (virtual time; figure benches)
//! * [`rt`] — realtime threaded driver (wallclock; PJRT engine, examples)

pub mod config;
pub mod policy;
pub mod queues;
pub mod report;
pub mod rt;
pub mod sim;
pub mod task;

pub use config::{AdmissionMode, ExperimentConfig, Mode};
pub use policy::{AdaptConfig, OffloadPolicy};
pub use report::RunReport;
pub use sim::{run_from_artifacts, ModelMeta, SampleStore, Simulation};
