//! Task and result records — the unit of work the paper calls τ_k(d).

use crate::tensor::Tensor;

/// Task τ_k(d): process the layers between exit point k-1 and k for data d.
#[derive(Debug, Clone)]
pub struct Task {
    /// Globally unique task id (diagnostics, loss/duplication checks).
    pub id: u64,
    /// Dataset index d of the originating sample.
    pub sample: usize,
    /// Which task (stage) this is, 1-based like the paper's τ indices.
    pub stage: usize,
    /// Node that admitted the sample. Results, re-homes, and per-source
    /// report counters all route/key off this; the admitting core stamps
    /// it (defaults to node 0, the classic single-source placement).
    pub source: usize,
    /// Feature tensor a_{λ_b^k}(d) entering the stage. `None` on the
    /// oracle (DES) path where the engine replays confidences by sample id.
    pub features: Option<Tensor>,
    /// Payload is an autoencoder code (must be decoded before processing).
    pub encoded: bool,
    /// Virtual/real time the sample was admitted at the source.
    pub admitted_at: f64,
    /// Offload hops so far (diagnostics; Fig. 5's transmission bottleneck).
    pub hops: u32,
    /// Traffic class stamped at admission (0 = highest priority). Class
    /// counters in the report and the `sched` disciplines key off it; the
    /// default single-class config leaves every task at 0.
    pub class: u8,
    /// Absolute completion deadline (admission time + the per-class budget
    /// in `SchedConfig`). Only deadline-aware disciplines read it.
    pub deadline: f64,
}

impl Task {
    /// First task τ_1(d) for a freshly admitted sample. Class/deadline are
    /// stamped by the admitting core from its `SchedConfig`.
    pub fn initial(id: u64, sample: usize, features: Option<Tensor>, now: f64) -> Task {
        Task {
            id,
            sample,
            stage: 1,
            source: 0,
            features,
            encoded: false,
            admitted_at: now,
            hops: 0,
            class: 0,
            deadline: f64::INFINITY,
        }
    }

    /// Total order of admission: admission time, ties broken by task id.
    /// This is THE replay order of the system — churn drains interleave
    /// both queues by it, and coalesced `net::Envelope` batches are
    /// sorted by it so receivers merge them through their discipline
    /// exactly as if the tasks had arrived one by one.
    pub fn admission_cmp(&self, other: &Task) -> std::cmp::Ordering {
        self.admitted_at.total_cmp(&other.admitted_at).then(self.id.cmp(&other.id))
    }

    /// Successor task τ_{k+1}(d) (Alg. 1 lines 9–11), reusing the data id
    /// and inheriting the admission-time class and deadline.
    pub fn successor(&self, id: u64, features: Option<Tensor>) -> Task {
        Task {
            id,
            sample: self.sample,
            stage: self.stage + 1,
            source: self.source,
            features,
            encoded: false,
            admitted_at: self.admitted_at,
            hops: self.hops,
            class: self.class,
            deadline: self.deadline,
        }
    }
}

/// What the source receives when some worker exits for data d
/// (Alg. 1 line 6: "send the output of the classifier b_l^k(d) to the source").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InferenceResult {
    pub sample: usize,
    /// Exit point that fired (1-based; K = full model, no early exit).
    pub exit_point: usize,
    pub prediction: u8,
    pub confidence: f32,
    /// Time the sample was admitted (for latency accounting).
    pub admitted_at: f64,
    /// Absolute completion deadline inherited from the task (admission
    /// time + per-class budget). Sources score on-time completion against
    /// it at delivery.
    pub deadline: f64,
    /// Worker that produced the exit.
    pub exited_on: usize,
    /// Source node that admitted the sample — the result's destination.
    /// Relays forward toward it hop by hop (`routing::RoutingTable`).
    pub source: usize,
    /// Traffic class of the originating task (per-class report counters).
    pub class: u8,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn successor_advances_stage_and_keeps_lineage() {
        let t = Task { class: 2, deadline: 4.5, source: 3, ..Task::initial(1, 42, None, 3.5) };
        assert_eq!((t.stage, t.sample, t.hops), (1, 42, 0));
        let s = t.successor(2, None);
        assert_eq!(s.stage, 2);
        assert_eq!(s.sample, 42);
        assert_eq!(s.admitted_at, 3.5);
        assert!(!s.encoded);
        assert_eq!(s.class, 2, "class is stamped once, at admission");
        assert_eq!(s.deadline, 4.5, "deadline travels with the data");
        assert_eq!(s.source, 3, "the admitting source travels with the data");
    }

    #[test]
    fn admission_cmp_orders_by_time_then_id() {
        let a = Task::initial(5, 0, None, 1.0);
        let b = Task::initial(2, 0, None, 2.0);
        let c = Task::initial(9, 0, None, 1.0);
        assert_eq!(a.admission_cmp(&b), std::cmp::Ordering::Less, "earlier admission first");
        assert_eq!(a.admission_cmp(&c), std::cmp::Ordering::Less, "ties break by id");
        assert_eq!(c.admission_cmp(&a), std::cmp::Ordering::Greater);
        assert_eq!(a.admission_cmp(&a), std::cmp::Ordering::Equal);
    }

    #[test]
    fn initial_task_defaults_to_class_zero_no_deadline() {
        let t = Task::initial(1, 0, None, 0.0);
        assert_eq!(t.class, 0);
        assert_eq!(t.source, 0, "classic placement unless the admitting core restamps");
        assert!(t.deadline.is_infinite());
    }
}
