//! Task and result records — the unit of work the paper calls τ_k(d).

use crate::tensor::Tensor;

/// Task τ_k(d): process the layers between exit point k-1 and k for data d.
#[derive(Debug, Clone)]
pub struct Task {
    /// Globally unique task id (diagnostics, loss/duplication checks).
    pub id: u64,
    /// Dataset index d of the originating sample.
    pub sample: usize,
    /// Which task (stage) this is, 1-based like the paper's τ indices.
    pub stage: usize,
    /// Feature tensor a_{λ_b^k}(d) entering the stage. `None` on the
    /// oracle (DES) path where the engine replays confidences by sample id.
    pub features: Option<Tensor>,
    /// Payload is an autoencoder code (must be decoded before processing).
    pub encoded: bool,
    /// Virtual/real time the sample was admitted at the source.
    pub admitted_at: f64,
    /// Offload hops so far (diagnostics; Fig. 5's transmission bottleneck).
    pub hops: u32,
}

impl Task {
    /// First task τ_1(d) for a freshly admitted sample.
    pub fn initial(id: u64, sample: usize, features: Option<Tensor>, now: f64) -> Task {
        Task { id, sample, stage: 1, features, encoded: false, admitted_at: now, hops: 0 }
    }

    /// Successor task τ_{k+1}(d) (Alg. 1 lines 9–11), reusing the data id.
    pub fn successor(&self, id: u64, features: Option<Tensor>) -> Task {
        Task {
            id,
            sample: self.sample,
            stage: self.stage + 1,
            features,
            encoded: false,
            admitted_at: self.admitted_at,
            hops: self.hops,
        }
    }
}

/// What the source receives when some worker exits for data d
/// (Alg. 1 line 6: "send the output of the classifier b_l^k(d) to the source").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InferenceResult {
    pub sample: usize,
    /// Exit point that fired (1-based; K = full model, no early exit).
    pub exit_point: usize,
    pub prediction: u8,
    pub confidence: f32,
    /// Time the sample was admitted (for latency accounting).
    pub admitted_at: f64,
    /// Worker that produced the exit.
    pub exited_on: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn successor_advances_stage_and_keeps_lineage() {
        let t = Task::initial(1, 42, None, 3.5);
        assert_eq!((t.stage, t.sample, t.hops), (1, 42, 0));
        let s = t.successor(2, None);
        assert_eq!(s.stage, 2);
        assert_eq!(s.sample, 42);
        assert_eq!(s.admitted_at, 3.5);
        assert!(!s.encoded);
    }
}
