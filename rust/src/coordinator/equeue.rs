//! Indexed event queues for the DES driver.
//!
//! The seed simulator kept its pending events in one `BinaryHeap`. That is
//! fine at testbed scale, but on metro-scale runs (1000-node generated
//! graphs, hundreds of admission timelines, ~100k pending transfers) every
//! push/pop pays `O(log n)` comparator hops through a heap that no longer
//! fits in cache — and the event loop is the whole simulator. This module
//! provides the classic DES answer, a **calendar queue** (a timing wheel
//! over virtual time with an overflow heap), behind a small
//! [`EventQueue`] facade so the simulation can select either structure at
//! run time and the two can be differentially tested against each other.
//!
//! ## Ordering contract (the part that matters)
//!
//! Both queue kinds pop in strictly ascending `(t, seq)` order, where
//! `seq` is the global push counter — i.e. FIFO among simultaneous
//! events. This is byte-for-byte the order the seed's `BinaryHeap` entry
//! comparator (`t.total_cmp` then `seq.cmp`) produced, so switching
//! structures cannot reorder a simulation: same config + seed ⇒ same
//! event sequence ⇒ same report. The regression test in `sim.rs` holds
//! both queues to that promise on a full run; the unit tests here fuzz it
//! on synthetic schedules.
//!
//! The calendar implementation assumes what a DES guarantees anyway:
//! events are pushed at or after the time of the last pop (the present).
//! Pushes slightly in the past are tolerated (clamped into the current
//! bucket) and still pop in correct `(t, seq)` order relative to
//! everything else in that bucket.

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;

/// Which queue structure the simulation drives its event loop with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueKind {
    /// Timing wheel + overflow heap (the metro-scale default).
    #[default]
    Calendar,
    /// The seed's plain binary heap (regression baseline).
    Baseline,
}

/// One pending event: fires at `t`, FIFO-tied by `seq`.
struct Entry<T> {
    t: f64,
    seq: u64,
    ev: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, o: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(o))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, o: &Self) -> CmpOrdering {
        // BinaryHeap is a max-heap: reverse for earliest-first.
        o.t.total_cmp(&self.t).then(o.seq.cmp(&self.seq))
    }
}

/// The seed's event store: a plain binary heap.
pub struct BaselineHeap<T> {
    heap: BinaryHeap<Entry<T>>,
}

impl<T> BaselineHeap<T> {
    pub fn new() -> Self {
        BaselineHeap { heap: BinaryHeap::new() }
    }

    pub fn push(&mut self, t: f64, seq: u64, ev: T) {
        self.heap.push(Entry { t, seq, ev });
    }

    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.t, e.ev))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<T> Default for BaselineHeap<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Calendar queue: a power-of-two ring of time buckets of fixed `width`,
/// indexed by absolute bucket id (`(t / width) & mask`), plus an overflow
/// heap for events beyond the wheel's horizon. Near-term events — the
/// overwhelming majority in a DES — cost O(1) amortized to insert and a
/// short in-bucket scan to pop; the wheel re-sizes itself (bucket count
/// *and* width, from an EWMA of observed pop gaps) when occupancy says the
/// geometry no longer fits the workload.
pub struct CalendarQueue<T> {
    buckets: Vec<Vec<Entry<T>>>,
    mask: u64,
    width: f64,
    /// Start time of the bucket the cursor is on (aligned to `width`).
    floor: f64,
    /// Absolute bucket id of the cursor (index = id & mask).
    cur_id: u64,
    /// Items currently in buckets (not counting overflow).
    in_buckets: usize,
    overflow: BinaryHeap<Entry<T>>,
    /// EWMA of gaps between consecutive pops; drives width adaptation.
    gap_ewma: f64,
    last_pop_t: Option<f64>,
}

const INITIAL_BUCKETS: usize = 1024;
const INITIAL_WIDTH: f64 = 1e-3;
const MIN_WIDTH: f64 = 1e-9;
const MAX_WIDTH: f64 = 10.0;

impl<T> CalendarQueue<T> {
    pub fn new() -> Self {
        CalendarQueue {
            buckets: (0..INITIAL_BUCKETS).map(|_| Vec::new()).collect(),
            mask: (INITIAL_BUCKETS - 1) as u64,
            width: INITIAL_WIDTH,
            floor: 0.0,
            cur_id: 0,
            in_buckets: 0,
            overflow: BinaryHeap::new(),
            gap_ewma: INITIAL_WIDTH,
            last_pop_t: None,
        }
    }

    fn horizon(&self) -> f64 {
        self.floor + self.buckets.len() as f64 * self.width
    }

    pub fn len(&self) -> usize {
        self.in_buckets + self.overflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn push(&mut self, t: f64, seq: u64, ev: T) {
        debug_assert!(t.is_finite(), "event time must be finite, got {t}");
        let entry = Entry { t, seq, ev };
        if t >= self.horizon() {
            self.overflow.push(entry);
            return;
        }
        // Clamp slightly-past events (and float-rounding stragglers) into
        // the current bucket; the in-bucket (t, seq) scan still pops them
        // in order. Never map behind the cursor — a bucket id < cur_id
        // would sit a full wheel revolution away.
        let id = ((t / self.width) as u64).max(self.cur_id);
        let idx = (id & self.mask) as usize;
        self.buckets[idx].push(entry);
        self.in_buckets += 1;
        if self.in_buckets > 3 * self.buckets.len() {
            self.rebuild(self.buckets.len() * 2);
        }
    }

    pub fn pop(&mut self) -> Option<(f64, T)> {
        loop {
            if self.in_buckets == 0 {
                // Nothing on the wheel: the next event (if any) is in
                // overflow — jump the cursor straight to its bucket.
                let min_t = self.overflow.peek()?.t;
                self.jump_to(min_t);
                self.drain_overflow();
                continue;
            }
            let idx = (self.cur_id & self.mask) as usize;
            if self.buckets[idx].is_empty() {
                self.advance();
                continue;
            }
            // Lazy width refit: a bulk prefill (or a workload whose event
            // spacing collapsed) can leave the width far wider than the
            // observed pop gaps, stuffing hundreds of events into each
            // bucket and turning every pop into a long scan. Once the gap
            // EWMA says a refit would at least halve the width, rebuild at
            // the same bucket count. Strictly-shrinking width (bounded by
            // `MIN_WIDTH`) guarantees this terminates.
            let target = (self.gap_ewma * 4.0).clamp(MIN_WIDTH, MAX_WIDTH);
            if self.buckets[idx].len() > 32 && target < 0.5 * self.width {
                self.rebuild(self.buckets.len());
                continue;
            }
            // In-bucket linear scan for the (t, seq) minimum. Buckets are
            // narrow by construction, so this stays a handful of items.
            let bucket = &mut self.buckets[idx];
            let mut best = 0;
            for i in 1..bucket.len() {
                let (a, b) = (&bucket[i], &bucket[best]);
                if a.t < b.t || (a.t == b.t && a.seq < b.seq) {
                    best = i;
                }
            }
            let e = bucket.swap_remove(best);
            self.in_buckets -= 1;
            if let Some(last) = self.last_pop_t {
                let gap = (e.t - last).max(0.0);
                self.gap_ewma = 0.9 * self.gap_ewma + 0.1 * gap;
            }
            self.last_pop_t = Some(e.t);
            return Some((e.t, e.ev));
        }
    }

    /// Move the cursor one bucket forward and pull any overflow events
    /// that the advanced horizon now covers. The floor is recomputed from
    /// `cur_id` (not accumulated) so it never drifts off the bucket grid.
    fn advance(&mut self) {
        self.cur_id += 1;
        self.floor = self.cur_id as f64 * self.width;
        self.drain_overflow();
    }

    /// Re-seat the cursor at the bucket containing time `t` (only called
    /// with every bucket empty, so no events are skipped).
    fn jump_to(&mut self, t: f64) {
        debug_assert_eq!(self.in_buckets, 0);
        let t = t.max(self.floor);
        self.cur_id = ((t / self.width) as u64).max(self.cur_id);
        self.floor = self.cur_id as f64 * self.width;
    }

    fn drain_overflow(&mut self) {
        let horizon = self.horizon();
        while self.overflow.peek().is_some_and(|e| e.t < horizon) {
            let e = self.overflow.pop().expect("peeked overflow entry");
            let id = ((e.t / self.width) as u64).max(self.cur_id);
            let idx = (id & self.mask) as usize;
            self.buckets[idx].push(e);
            self.in_buckets += 1;
        }
    }

    /// Re-size the wheel to `new_len` buckets (callers pass the current
    /// count for a pure width refit, or double it to grow) and re-fit the
    /// bucket width to the observed event spacing, then re-insert
    /// everything (including overflow — the re-fitted wheel may now cover
    /// it).
    fn rebuild(&mut self, new_len: usize) {
        let new_len = new_len.next_power_of_two();
        let new_width = (self.gap_ewma * 4.0).clamp(MIN_WIDTH, MAX_WIDTH);
        let mut pending: Vec<Entry<T>> =
            self.buckets.iter_mut().flat_map(std::mem::take).collect();
        pending.extend(std::mem::take(&mut self.overflow).into_vec());
        self.buckets = (0..new_len).map(|_| Vec::new()).collect();
        self.mask = (new_len - 1) as u64;
        self.width = new_width;
        self.cur_id = (self.floor / new_width) as u64;
        self.floor = self.cur_id as f64 * new_width;
        self.in_buckets = 0;
        for e in pending {
            // Re-insert without the grow check (we just grew).
            if e.t >= self.horizon() {
                self.overflow.push(e);
            } else {
                let id = ((e.t / self.width) as u64).max(self.cur_id);
                let idx = (id & self.mask) as usize;
                self.buckets[idx].push(e);
                self.in_buckets += 1;
            }
        }
    }
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Runtime-selectable event queue. Owns the global `seq` counter (so
/// callers just push `(t, event)`) and tracks the peak pending count for
/// the report's `peak_event_queue`.
pub struct EventQueue<T> {
    kind: QueueKind,
    baseline: BaselineHeap<T>,
    calendar: CalendarQueue<T>,
    seq: u64,
    peak: usize,
}

impl<T> EventQueue<T> {
    pub fn new(kind: QueueKind) -> Self {
        EventQueue {
            kind,
            baseline: BaselineHeap::new(),
            calendar: CalendarQueue::new(),
            seq: 0,
            peak: 0,
        }
    }

    pub fn kind(&self) -> QueueKind {
        self.kind
    }

    pub fn push(&mut self, t: f64, ev: T) {
        self.seq += 1;
        match self.kind {
            QueueKind::Baseline => self.baseline.push(t, self.seq, ev),
            QueueKind::Calendar => self.calendar.push(t, self.seq, ev),
        }
        self.peak = self.peak.max(self.len());
    }

    pub fn pop(&mut self) -> Option<(f64, T)> {
        match self.kind {
            QueueKind::Baseline => self.baseline.pop(),
            QueueKind::Calendar => self.calendar.pop(),
        }
    }

    pub fn len(&self) -> usize {
        match self.kind {
            QueueKind::Baseline => self.baseline.len(),
            QueueKind::Calendar => self.calendar.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Largest pending count ever observed (reported as
    /// `peak_event_queue`).
    pub fn peak_len(&self) -> usize {
        self.peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Drive both queue kinds through an identical DES-shaped schedule
    /// (pushes never go behind the current pop time) and compare the pop
    /// sequences element for element.
    fn differential(seed: u64, horizon_scale: f64) {
        let mut a = EventQueue::new(QueueKind::Baseline);
        let mut b = EventQueue::new(QueueKind::Calendar);
        let mut rng = Pcg64::new(seed, 0);
        let mut now = 0.0f64;
        let mut next_id = 0u64;
        for _ in 0..64 {
            for _ in 0..200 {
                // Mix of near-term and far-future events, plus exact ties.
                let dt = match rng.below(10) {
                    0 => 0.0,
                    1..=6 => rng.exponential(0.002),
                    7 | 8 => rng.exponential(0.5),
                    _ => rng.exponential(20.0) * horizon_scale,
                };
                a.push(now + dt, next_id);
                b.push(now + dt, next_id);
                next_id += 1;
            }
            for _ in 0..150 {
                let (ta, ea) = a.pop().unwrap();
                let (tb, eb) = b.pop().unwrap();
                assert_eq!((ta.to_bits(), ea), (tb.to_bits(), eb), "pop order diverged");
                assert!(ta >= now, "time went backwards");
                now = ta;
            }
        }
        // Drain both completely.
        loop {
            match (a.pop(), b.pop()) {
                (None, None) => break,
                (Some((ta, ea)), Some((tb, eb))) => {
                    assert_eq!((ta.to_bits(), ea), (tb.to_bits(), eb));
                    assert!(ta >= now);
                    now = ta;
                }
                other => panic!("length mismatch: {other:?}"),
            }
        }
        assert_eq!(a.len(), 0);
        assert_eq!(b.len(), 0);
    }

    // The differential and bulk tests push tens of thousands of events —
    // too slow under Miri; `simultaneous_events_pop_fifo` and the peak
    // tracker cover the pointer-heavy paths there.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn calendar_matches_baseline_order() {
        differential(7, 1.0);
        differential(42, 1.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn calendar_matches_baseline_with_deep_overflow() {
        // Far-future times exercise the overflow heap and cursor jumps.
        differential(3, 50.0);
    }

    #[test]
    fn simultaneous_events_pop_fifo() {
        for kind in [QueueKind::Baseline, QueueKind::Calendar] {
            let mut q = EventQueue::new(kind);
            for i in 0..100u64 {
                q.push(1.5, i);
            }
            q.push(0.5, 999);
            assert_eq!(q.pop(), Some((0.5, 999)), "{kind:?}");
            for i in 0..100u64 {
                assert_eq!(q.pop(), Some((1.5, i)), "{kind:?} FIFO among ties");
            }
            assert!(q.pop().is_none());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn rebuild_under_load_preserves_order() {
        // Push far more than 3×INITIAL_BUCKETS items at once to force at
        // least one rebuild, with a spread that also exercises overflow.
        let mut a = EventQueue::new(QueueKind::Baseline);
        let mut b = EventQueue::new(QueueKind::Calendar);
        let mut rng = Pcg64::new(11, 0);
        for i in 0..20_000u64 {
            let t = rng.f64() * 5.0 + if i % 97 == 0 { 5000.0 } else { 0.0 };
            a.push(t, i);
            b.push(t, i);
        }
        assert_eq!(a.len(), b.len());
        assert!(b.peak_len() >= 20_000);
        while let Some((ta, ea)) = a.pop() {
            let (tb, eb) = b.pop().unwrap();
            assert_eq!((ta.to_bits(), ea), (tb.to_bits(), eb));
        }
        assert!(b.pop().is_none());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn bulk_prefill_then_hold_stays_ordered_through_width_refits() {
        // A big prefill with no interleaved pops leaves the width fitted to
        // nothing; the first pops must trigger the lazy refit (possibly
        // several, strictly halving) without reordering a single event.
        let mut a = EventQueue::new(QueueKind::Baseline);
        let mut b = EventQueue::new(QueueKind::Calendar);
        let mut rng = Pcg64::new(5, 0);
        for i in 0..30_000u64 {
            let t = rng.exponential(1.0);
            a.push(t, i);
            b.push(t, i);
        }
        // Hold model: pop one, push its successor a mean-1s hold later.
        let mut now = 0.0;
        for i in 0..60_000u64 {
            let (ta, ea) = a.pop().unwrap();
            let (tb, eb) = b.pop().unwrap();
            assert_eq!((ta.to_bits(), ea), (tb.to_bits(), eb), "pop order diverged");
            assert!(ta >= now);
            now = ta;
            let t = now + rng.exponential(1.0);
            a.push(t, 30_000 + i);
            b.push(t, 30_000 + i);
        }
        while let Some((ta, ea)) = a.pop() {
            let (tb, eb) = b.pop().unwrap();
            assert_eq!((ta.to_bits(), ea), (tb.to_bits(), eb));
        }
        assert!(b.pop().is_none());
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut q: EventQueue<u32> = EventQueue::new(QueueKind::Calendar);
        for i in 0..10 {
            q.push(i as f64, i);
        }
        for _ in 0..10 {
            q.pop();
        }
        assert_eq!(q.peak_len(), 10);
        assert!(q.is_empty());
        q.push(100.0, 1);
        assert_eq!(q.peak_len(), 10, "peak is a high-water mark");
    }
}
