//! Discrete-event driver: runs the MDI-Exit system in virtual time.
//!
//! This is what the figure benches execute. All decisions live in the
//! shared [`super::worker::WorkerCore`]; this driver only owns the
//! *medium*: a virtual-clock event heap, link-delay sampling with
//! shared-medium contention, and report accounting. Each event advances
//! the [`VirtualClock`], feeds the owning core, and maps the returned
//! [`Action`]s back onto the heap:
//!
//! * `StartCompute` → a `ComputeDone` event after the (batch-amortized)
//!   estimated cost; the whole same-stage batch completes together;
//! * `Send` → a `Deliver` event after the sampled link delay. Every
//!   message is a [`crate::net::Envelope`] charged by the shared
//!   [`crate::net::Envelope::encoded_bytes`] contract — a coalesced
//!   `TaskBatch` crosses the link as ONE contended transfer (one base
//!   latency, one jitter draw, one contention slot) where per-task wiring
//!   paid k; gossip `State` envelopes are charged by their actual encoded
//!   summary size; result and re-home envelopes hop the topology link by
//!   link, each leg charged once per envelope, until they reach their
//!   admitting source;
//! * `RecordResult` → report bookkeeping (per traffic class and per
//!   source where the run configures more than one).
//!
//! Every source the run's `Placement` declares gets its own admission
//! timeline (and, per the admission mode, its own Alg. 3/4 controller).
//!
//! Engine-agnostic: with `SimEngine` (exit-oracle replay) a 60-virtual-
//! second topology run takes milliseconds; with the PJRT engine the same
//! driver pushes real feature tensors through the compiled HLO stages.

use anyhow::{bail, Context, Result};

use super::config::ExperimentConfig;
use super::equeue::{EventQueue, QueueKind};
use super::report::{RunReport, TracePoint};
use super::task::{InferenceResult, Task};
use super::worker::{
    execute_batch, Action, Clock, TaskOrigin, VirtualClock, WorkerCore,
};
use crate::cluster::ScaleDecision;
use crate::log_debug;
use crate::net::Envelope;
use crate::routing::{Role, RoutingTable};
use crate::runtime::InferenceEngine;
use crate::simnet::Topology;
use crate::telemetry::{self, TelemetryData, TelemetryEvent};
use crate::tensor::Tensor;
use crate::util::rng::{streams, Pcg64};

/// Trace sampling period (virtual seconds).
const TRACE_PERIOD_S: f64 = 0.25;
/// Hard ceiling on processed events — runaway-loop backstop.
const MAX_EVENTS: u64 = 200_000_000;

/// Sample access: labels always; image tensors only on the real-engine path.
pub struct SampleStore<'a> {
    pub labels: &'a [u8],
    pub images: Option<&'a crate::dataset::Dataset>,
}

impl<'a> SampleStore<'a> {
    pub fn len(&self) -> usize {
        self.labels.len()
    }
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
    fn image(&self, i: usize) -> Option<Tensor> {
        self.images.map(|d| d.image(i))
    }
}

// ---------------------------------------------------------------------------
// Event machinery
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum Event {
    /// One admission at `source` (each declared source runs its own
    /// admission timeline).
    Admit { source: usize },
    AdaptTick { source: usize },
    ComputeDone { worker: usize, batch: Vec<Task>, duration: f64 },
    /// A wire envelope in transit — the *same* [`Envelope`] type the core
    /// emits and the realtime transport carries; this driver keeps no
    /// private mirror of the payload enum (the old `Msg` duplication).
    Deliver { to: usize, from: usize, env: Envelope },
    GossipTick,
    TraceTick,
    /// Telemetry cadence: sample every core's gauges into its metrics
    /// timeline. Read-only — touches no RNG stream and emits no actions,
    /// so scheduling it cannot perturb the simulated system.
    MetricsTick,
    Churn { idx: usize },
    /// Elastic-control-plane cadence: run the controller core's health
    /// sweep + autoscaler step (`cfg.cluster.check_interval_s`).
    ClusterTick,
    /// A controller decision being applied: the target joins or leaves
    /// and the fleet re-layers. Scheduled at the decision's own `now` so
    /// it lands as its own event, after the emitting dispatch completes.
    Scale { d: ScaleDecision },
}

/// The simulation state. Construct with [`Simulation::new`], then
/// [`Simulation::run`] — or use [`super::run::Run`] which wraps both.
pub struct Simulation<'a> {
    cfg: ExperimentConfig,
    topo: Topology,
    meta: super::worker::ModelMeta,
    engine: &'a dyn InferenceEngine,
    store: SampleStore<'a>,

    queue: EventQueue<Event>,
    clock: VirtualClock,

    workers: Vec<WorkerCore>,
    /// Which nodes are in the active fleet (parked/churned-out nodes keep
    /// forwarding but neither compute nor receive offloads). Mirrors the
    /// cores' own join/leave state; the driver owns it because routing
    /// rebuilds and the worker-seconds cost integral are fleet-wide.
    active: Vec<bool>,
    /// Left edge of the un-accumulated worker-seconds interval.
    ws_last_t: f64,
    /// Concurrent transfers on the shared medium (WiFi contention model).
    active_transfers: usize,
    /// Jitter sampling for link delays (the cores own the decision RNGs).
    link_rng: Pcg64,

    report: RunReport,
    measure_from: f64,
    end_at: f64,
}

impl<'a> Simulation<'a> {
    pub fn new(
        cfg: ExperimentConfig,
        engine: &'a dyn InferenceEngine,
        meta: super::worker::ModelMeta,
        store: SampleStore<'a>,
    ) -> Result<Simulation<'a>> {
        cfg.validate()?;
        if store.is_empty() {
            bail!("empty sample store");
        }
        if meta.num_stages != engine.num_stages() {
            bail!("meta stages {} != engine stages {}", meta.num_stages, engine.num_stages());
        }
        if cfg.use_ae && meta.ae.is_none() {
            bail!("use_ae set but model has no autoencoder");
        }
        let topo = Topology::named_seeded(&cfg.topology, cfg.link, cfg.seed)
            .with_context(|| format!("unknown topology {:?}", cfg.topology))?
            .with_churn(cfg.churn.clone());
        cfg.placement
            .validate(topo.n, &topo.churn)
            .context("placement does not fit the topology")?;
        // One routing build shared by every core: per-worker rebuilds were
        // O(n) full Dijkstra sweeps each — quartic overall, minutes at
        // 1000 nodes.
        let routing = RoutingTable::build(&topo);
        let mut workers: Vec<WorkerCore> = (0..topo.n)
            .map(|i| WorkerCore::with_routing(i, &cfg, meta.clone(), &topo, &routing, store.len()))
            .collect();
        if cfg.telemetry.enabled() {
            for (i, w) in workers.iter_mut().enumerate() {
                w.set_recorder(cfg.telemetry.build_recorder(i, cfg.warmup_s));
            }
        }
        let report = RunReport::new(
            &cfg.model,
            &cfg.topology,
            &run_label(&cfg),
            topo.n,
            meta.num_stages,
            cfg.sched.num_classes as usize,
            &cfg.placement.source_nodes(),
        );
        let measure_from = cfg.warmup_s;
        let end_at = cfg.warmup_s + cfg.duration_s;
        let link_rng = Pcg64::new(cfg.seed, streams::DES_LINK_JITTER);
        let active = vec![true; topo.n];
        Ok(Simulation {
            cfg,
            topo,
            meta,
            engine,
            store,
            queue: EventQueue::new(QueueKind::default()),
            clock: VirtualClock::new(),
            workers,
            active,
            ws_last_t: 0.0,
            active_transfers: 0,
            link_rng,
            report,
            measure_from,
            end_at,
        })
    }

    /// Select the event-queue structure (the calendar queue is the
    /// default; [`QueueKind::Baseline`] is the seed heap, kept for
    /// regression testing and the metro bench's speedup comparison).
    pub fn with_queue_kind(mut self, kind: QueueKind) -> Self {
        self.queue = EventQueue::new(kind);
        self
    }

    fn now(&self) -> f64 {
        self.clock.now()
    }

    fn in_window(&self) -> bool {
        self.now() >= self.measure_from
    }

    fn push(&mut self, t: f64, ev: Event) {
        self.queue.push(t, ev);
    }

    /// Run to completion; returns the measured report.
    pub fn run(mut self) -> Result<RunReport> {
        if self.cfg.cluster.enabled {
            // Initial parking: under `initial_workers`, sources always
            // start active and the lowest-id non-sources fill the budget;
            // everyone else starts parked (radios on, compute off),
            // available for the autoscaler to wake.
            let parked = self.initial_parked();
            for &p in &parked {
                self.active[p] = false;
                for n in 0..self.topo.n {
                    let acts = self.workers[n].on_churn(0.0, p, false);
                    self.dispatch(n, acts)?;
                }
            }
            if !parked.is_empty() {
                self.relayout();
            }
            self.push(self.cfg.cluster.check_interval_s, Event::ClusterTick);
        }
        for source in self.cfg.placement.source_nodes() {
            self.push(0.0, Event::Admit { source });
            if self.workers[source].has_controller() {
                self.push(self.cfg.adapt.sleep_s, Event::AdaptTick { source });
            }
        }
        self.push(self.cfg.gossip_interval_s, Event::GossipTick);
        self.push(TRACE_PERIOD_S, Event::TraceTick);
        if self.cfg.telemetry.metrics {
            self.push(self.cfg.telemetry.interval_s, Event::MetricsTick);
        }
        let churn = self.topo.churn.clone();
        for (idx, e) in churn.iter().enumerate() {
            self.push(e.at_s, Event::Churn { idx });
        }

        let mut events: u64 = 0;
        while let Some((t, ev)) = self.queue.pop() {
            if t >= self.end_at {
                break;
            }
            self.clock.set(t);
            events += 1;
            if events > MAX_EVENTS {
                bail!("event budget exhausted (runaway simulation)");
            }
            match ev {
                Event::Admit { source } => self.on_admit(source)?,
                Event::AdaptTick { source } => self.on_adapt_tick(source)?,
                Event::ComputeDone { worker, batch, duration } => {
                    self.on_compute_done(worker, batch, duration)?
                }
                Event::Deliver { to, from, env } => self.on_deliver(to, from, env)?,
                Event::GossipTick => self.on_gossip_tick()?,
                Event::TraceTick => self.on_trace(),
                Event::MetricsTick => self.on_metrics_tick(),
                Event::Churn { idx } => self.on_churn(idx)?,
                Event::ClusterTick => self.on_cluster_tick()?,
                Event::Scale { d } => self.on_scale(d)?,
            }
        }
        self.report.sim_events = events;
        self.report.peak_event_queue = self.queue.peak_len();
        self.finalize()
    }

    // -- action dispatch ------------------------------------------------------

    /// Map core actions onto the virtual medium. Handlers return their
    /// complete action lists (consequences arrive as future events), so a
    /// straight walk suffices — no per-event worklist allocation.
    fn dispatch(&mut self, worker: usize, actions: Vec<Action>) -> Result<()> {
        let n = worker;
        for a in actions {
            let now = self.now();
            match a {
                Action::StartCompute { batch, est_cost_s } => {
                    self.push(
                        now + est_cost_s,
                        Event::ComputeDone { worker: n, batch, duration: est_cost_s },
                    );
                }
                Action::Send { to, env, needs_encode } => {
                    // One path for every envelope kind: run the AE step
                    // (task batches only), price the envelope with the
                    // shared `net` contract, and put it on the virtual
                    // medium as ONE contended transfer — a coalesced batch
                    // pays one base latency and one contention slot where
                    // k per-task messages paid k.
                    let mut env = env;
                    let mut enc_cost = 0.0;
                    if needs_encode {
                        // Shared with the realtime driver: one batched
                        // encoder forward for the whole envelope, raw
                        // fallback per tensor, wire-counter reconciliation
                        // when a fallback shipped raw.
                        let forwards =
                            self.workers[n].encode_for_wire(self.engine, now, &mut env);
                        enc_cost = forwards as f64 * self.enc_cost_s(n);
                    }
                    let bytes = env.encoded_bytes(&self.meta);
                    // Encoding costs compute on the sender; fold it into
                    // the send path (virtual time).
                    let delay = self.link_delay(n, to, bytes)? + enc_cost;
                    if let Some(tasks) = env.task_batch() {
                        // Only task transfers feed the D_nm estimator —
                        // gossip and result messages are tiny and would
                        // bias Alg. 2's transfer-delay term. D_nm is a
                        // *per-task* transfer estimate (Alg. 2 weighs it
                        // against per-task queue waits), so a coalesced
                        // envelope feeds the amortized share — exactly how
                        // Γ_n amortizes a batched compute measurement.
                        self.workers[n]
                            .note_transfer_delay(to, delay / tasks.len().max(1) as f64);
                    }
                    // Wire legs are recorded by the sender — the only side
                    // that knows the sampled delay (one span per task for
                    // task/re-home batches; one per envelope otherwise).
                    if self.workers[n].has_recorder() {
                        let w = &mut self.workers[n];
                        telemetry::wire_send_events(now, n, to, &env, bytes, delay, |ev| {
                            w.record_event(&ev)
                        });
                    }
                    self.active_transfers += 1;
                    self.push(now + delay, Event::Deliver { to, from: n, env });
                }
                Action::RecordResult { result } => self.record_result(result),
                Action::Scale(d) => {
                    self.push(now, Event::Scale { d });
                }
            }
        }
        Ok(())
    }

    /// AE encode cost in virtual time, scaled by the sender's speed.
    fn enc_cost_s(&self, n: usize) -> f64 {
        self.meta
            .ae
            .as_ref()
            .map(|ae| ae.enc_cost_s / self.workers[n].speed())
            .unwrap_or(0.0)
    }

    // -- event handlers -------------------------------------------------------

    fn on_admit(&mut self, source: usize) -> Result<()> {
        let now = self.now();
        let (mut task, dt) = self.workers[source].poll_admission(now);
        task.features = self.store.image(task.sample);
        if self.in_window() {
            self.report.record_admission(source);
        }
        let acts = self.workers[source].on_task(now, task, TaskOrigin::Admitted);
        self.dispatch(source, acts)?;
        self.push(now + dt, Event::Admit { source });
        Ok(())
    }

    fn on_adapt_tick(&mut self, source: usize) -> Result<()> {
        let now = self.now();
        let acts = self.workers[source].on_adapt_tick(now);
        self.dispatch(source, acts)?;
        self.push(now + self.cfg.adapt.sleep_s, Event::AdaptTick { source });
        Ok(())
    }

    fn on_compute_done(
        &mut self,
        worker: usize,
        mut batch: Vec<Task>,
        duration: f64,
    ) -> Result<()> {
        let results =
            execute_batch(self.engine, self.cfg.mode, self.meta.num_stages, &mut batch)?;
        let now = self.now();
        let acts = self.workers[worker].on_compute_done(now, batch, results, duration);
        self.dispatch(worker, acts)
    }

    fn on_deliver(&mut self, to: usize, from: usize, env: Envelope) -> Result<()> {
        // The transfer occupying the shared medium ends on delivery.
        self.active_transfers = self.active_transfers.saturating_sub(1);
        let now = self.now();
        if self.workers[to].has_recorder() {
            let ev = TelemetryEvent::WireRecv {
                t: now,
                worker: to,
                from,
                kind: telemetry::wire_kind(&env),
                items: env.items(),
            };
            self.workers[to].record_event(&ev);
        }
        // A piggybacked summary is a gossip arrival first, then the inner
        // delivery — same observable order as a State message followed by
        // the payload.
        let (env, gossip) = env.split_gossip();
        if let Some(summary) = gossip {
            let acts = self.workers[to].on_gossip(now, from, summary);
            self.dispatch(to, acts)?;
        }
        match env {
            Envelope::TaskBatch(tasks) => {
                let acts = self.workers[to].on_task_batch(now, tasks, TaskOrigin::Wire);
                self.dispatch(to, acts)
            }
            Envelope::Result(rs) => {
                let acts = self.workers[to].on_result(now, rs);
                self.dispatch(to, acts)
            }
            Envelope::Rehome(tasks) => {
                if tasks.first().is_some_and(|t| t.source == to) {
                    // The displaced tasks made it home: count them once,
                    // at terminal delivery (relay hops are not
                    // re-homings).
                    self.report.rehomed += tasks.len() as u64;
                }
                let acts = self.workers[to].on_rehome(now, tasks);
                self.dispatch(to, acts)
            }
            Envelope::State(summary) => {
                let acts = self.workers[to].on_gossip(now, from, summary);
                self.dispatch(to, acts)
            }
            Envelope::Piggybacked(..) => unreachable!("split_gossip unwraps piggybacking"),
        }
    }

    fn on_gossip_tick(&mut self) -> Result<()> {
        let now = self.now();
        for n in 0..self.topo.n {
            let acts = self.workers[n].on_gossip_tick(now);
            self.dispatch(n, acts)?;
        }
        self.push(now + self.cfg.gossip_interval_s, Event::GossipTick);
        Ok(())
    }

    fn on_trace(&mut self) {
        let now = self.now();
        // The trace follows the first declared source (multi-source runs
        // read per-source detail from `report.per_source` instead). The
        // point is cut from the same `timeline_sample` the telemetry
        // metrics use, so the two timelines can never disagree.
        let lead = self.cfg.placement.sources[0].node;
        let s = self.workers[lead].timeline_sample(now);
        self.report.trace.push(TracePoint {
            t_s: s.t_s,
            control: s.control,
            source_queue: s.queue_total,
        });
        self.push(now + TRACE_PERIOD_S, Event::TraceTick);
    }

    fn on_metrics_tick(&mut self) {
        let now = self.now();
        for n in 0..self.topo.n {
            self.workers[n].on_metrics_tick(now);
        }
        self.push(now + self.cfg.telemetry.interval_s, Event::MetricsTick);
    }

    fn on_churn(&mut self, idx: usize) -> Result<()> {
        let e = self.topo.churn[idx];
        let now = self.now();
        log_debug!("churn at {:.2}s: worker {} {}", now, e.worker,
                   if e.join { "joins" } else { "leaves" });
        if self.cfg.cluster.enabled {
            // With the control plane on, scripted churn goes through the
            // same fleet-change path the autoscaler uses, so routing and
            // cost accounting stay consistent with the live fleet.
            if self.active[e.worker] != e.join {
                self.apply_fleet_change(e.worker, e.join)?;
            }
            return Ok(());
        }
        // Seed behavior: per-core notification only, no re-layout. The
        // `active` mirror still tracks the flip so the worker-seconds
        // integral reflects the fleet that actually ran.
        self.accumulate_worker_seconds(now);
        self.active[e.worker] = e.join;
        for n in 0..self.topo.n {
            let acts = self.workers[n].on_churn(now, e.worker, e.join);
            self.dispatch(n, acts)?;
        }
        Ok(())
    }

    // -- elastic fleet control plane ------------------------------------------

    /// Controller cadence: let the controller source sweep health and the
    /// autoscaler, then reschedule. Non-controller nodes do nothing here, so
    /// the tick is cheap fleet-wide.
    fn on_cluster_tick(&mut self) -> Result<()> {
        let now = self.now();
        for n in 0..self.topo.n {
            if self.workers[n].runs_cluster_controller() {
                let acts = self.workers[n].on_cluster_tick(now);
                self.dispatch(n, acts)?;
            }
        }
        self.push(now + self.cfg.cluster.check_interval_s, Event::ClusterTick);
        Ok(())
    }

    /// Apply one scale decision. Stale decisions (the target already flipped,
    /// e.g. scripted churn raced the controller) are dropped silently —
    /// re-applying a join/leave would double-count and re-shuffle routing.
    fn on_scale(&mut self, d: ScaleDecision) -> Result<()> {
        if self.active[d.worker] == d.join {
            return Ok(());
        }
        self.apply_fleet_change(d.worker, d.join)?;
        if d.join {
            self.report.scale_ups += 1;
        } else {
            self.report.scale_downs += 1;
        }
        let now = self.now();
        let fleet = self.active.iter().filter(|&&a| a).count();
        if self.workers[d.worker].has_recorder() {
            let ev = TelemetryEvent::Scale {
                t: now,
                worker: d.worker,
                join: d.join,
                reason: d.reason.label(),
                fleet,
            };
            self.workers[d.worker].record_event(&ev);
        }
        Ok(())
    }

    /// The single fleet-mutation path: close the worker-seconds integral at
    /// the flip, notify every core (in-flight batches finish where they are
    /// queued), then rebuild routing and roles over the surviving fleet.
    fn apply_fleet_change(&mut self, worker: usize, join: bool) -> Result<()> {
        let now = self.now();
        self.accumulate_worker_seconds(now);
        self.active[worker] = join;
        for n in 0..self.topo.n {
            let acts = self.workers[n].on_churn(now, worker, join);
            self.dispatch(n, acts)?;
        }
        self.relayout();
        Ok(())
    }

    /// Rebuild the routing table over the currently-active fleet and hand
    /// every core its new next-hop row and role. Cores keep draining queues
    /// that the new layout no longer feeds — nothing in flight is dropped.
    fn relayout(&mut self) {
        let routing = RoutingTable::build_active(&self.topo, &self.active);
        for n in 0..self.topo.n {
            let role = Role::of(n, &self.cfg.placement, &routing);
            self.workers[n].apply_relayout(routing.row(n), role);
        }
    }

    /// Advance the worker-seconds cost integral to time `t`, clamped to the
    /// measured window. Called before every fleet flip and once at finalize,
    /// so each segment is billed at the fleet size that actually ran it.
    fn accumulate_worker_seconds(&mut self, t: f64) {
        let t = t.min(self.end_at);
        let from = self.ws_last_t.max(self.measure_from);
        if t > from {
            let active = self.active.iter().filter(|&&a| a).count();
            self.report.worker_seconds += active as f64 * (t - from);
        }
        self.ws_last_t = self.ws_last_t.max(t);
    }

    /// Nodes that start parked under `cluster.initial_workers` (shared
    /// boot-shape logic with the realtime driver).
    fn initial_parked(&self) -> Vec<usize> {
        crate::cluster::initial_parked(
            self.cfg.cluster.initial_workers,
            &self.cfg.placement.source_nodes(),
            self.topo.n,
        )
    }

    // -- accounting -----------------------------------------------------------

    fn record_result(&mut self, r: InferenceResult) {
        if !self.in_window() {
            return;
        }
        self.report.completed += 1;
        let label = self.store.labels[r.sample];
        let correct = r.prediction == label;
        if correct {
            self.report.correct += 1;
        }
        self.report.exit_histogram[r.exit_point - 1] += 1;
        let latency = self.now() - r.admitted_at;
        let on_time = self.now() <= r.deadline;
        self.report.latency.push(latency);
        self.report.record_class(r.class, r.exit_point, correct, on_time, latency);
        self.report.record_source(r.source, r.exit_point, correct, latency);
    }

    fn link_delay(&mut self, n: usize, m: usize, bytes: usize) -> Result<f64> {
        let Some(link) = self.topo.link(n, m).copied() else {
            bail!("no link {n} -> {m}");
        };
        // Shared-medium contention: concurrent transfers divide bandwidth.
        let slow = 1.0 + self.cfg.medium_contention * self.active_transfers as f64;
        let mut eff = link;
        eff.bandwidth_bps = link.bandwidth_bps / slow;
        Ok(eff.delay_s(bytes, &mut self.link_rng))
    }

    fn finalize(mut self) -> Result<RunReport> {
        // Close the worker-seconds integral at the window's end; a static
        // n-node fleet lands on exactly n x duration_s.
        self.accumulate_worker_seconds(self.end_at);
        // A closing metrics sample at the window's end: the last row per
        // worker then carries the full-window counters, which is what
        // `TelemetryData::folded_totals` checks against the report.
        if self.cfg.telemetry.metrics {
            let end = self.end_at;
            for n in 0..self.topo.n {
                self.workers[n].on_metrics_tick(end);
            }
        }
        let mut report = self.report;
        report.duration_s = self.cfg.duration_s;
        let lead = self.cfg.placement.sources[0].node;
        report.final_mu_s = self.workers[lead].final_mu_s();
        report.final_t_e = self.workers[lead].final_t_e();
        let mut data: Option<TelemetryData> = None;
        for (i, mut w) in self.workers.into_iter().enumerate() {
            if let Some(rec) = w.take_recorder() {
                data.get_or_insert_with(TelemetryData::default).merge(rec.finish());
            }
            report.per_worker[i] = w.into_stats();
        }
        report.telemetry = data;
        report.fold_worker_drops();
        report.fold_wire_totals();
        Ok(report)
    }
}

fn run_label(cfg: &ExperimentConfig) -> String {
    let ee = if cfg.no_early_exit { "No EE" } else { "MDI-Exit" };
    let mode = match cfg.mode {
        super::config::Mode::MdiExit => ee.to_string(),
        super::config::Mode::Ddi => "DDI".to_string(),
    };
    format!("{}, {}", cfg.topology, mode)
}

#[cfg(test)]
mod tests {
    use super::super::config::{AdmissionMode, Mode};
    use super::super::run::{Driver, Run};
    use super::super::worker::ModelMeta;
    use super::*;
    use crate::dataset::ExitTable;
    use crate::runtime::sim_engine::SimEngine;

    /// 8 samples x 2 exits: even samples are confident at exit 1 (correct),
    /// odd samples only at exit 2.
    fn engine_2stage() -> (SimEngine, Vec<u8>) {
        let n = 8;
        let mut conf = Vec::new();
        let mut pred = Vec::new();
        let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
        for i in 0..n {
            if i % 2 == 0 {
                conf.extend([0.97f32, 0.99]);
                pred.extend([labels[i], labels[i]]);
            } else {
                conf.extend([0.30f32, 0.95]);
                pred.extend([9 - labels[i], labels[i]]); // exit1 wrong
            }
        }
        (SimEngine::from_table(ExitTable::synthetic(n, 2, conf, pred), false), labels)
    }

    fn base_cfg(topology: &str) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::new(
            "tiny",
            topology,
            AdmissionMode::Fixed { rate_hz: 50.0, threshold: 0.9 },
        );
        cfg.duration_s = 20.0;
        cfg.warmup_s = 2.0;
        cfg
    }

    fn meta_2stage() -> ModelMeta {
        ModelMeta::synthetic(vec![0.002, 0.003], vec![12288, 8192])
    }

    fn run_des(cfg: ExperimentConfig, engine: &SimEngine, labels: &[u8]) -> RunReport {
        Run::builder()
            .config(cfg)
            .model(meta_2stage())
            .engine(engine)
            .labels(labels)
            .driver(Driver::Des)
            .execute()
            .unwrap()
    }

    #[test]
    fn local_early_exit_splits_by_confidence() {
        let (engine, labels) = engine_2stage();
        let r = run_des(base_cfg("local"), &engine, &labels);
        assert!(r.completed > 500, "completed {}", r.completed);
        // Half the stream exits at 1 (conf .97 > .9), half at 2.
        let f = r.exit_fractions();
        assert!((f[0] - 0.5).abs() < 0.05, "exit fractions {f:?}");
        // exit-1 samples correct, exit-2 samples correct => accuracy 1.0
        assert!((r.accuracy() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn no_early_exit_only_final() {
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("local");
        cfg.no_early_exit = true;
        let r = run_des(cfg, &engine, &labels);
        let f = r.exit_fractions();
        assert_eq!(f[0], 0.0, "no task may exit early: {f:?}");
        assert!(r.completed > 0);
    }

    #[test]
    fn distributed_offloads_and_completes() {
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        // overload one node so offloading must kick in
        cfg.admission = AdmissionMode::Fixed { rate_hz: 300.0, threshold: 0.9 };
        let r = run_des(cfg, &engine, &labels);
        assert!(r.task_transfers > 0, "expected offloading");
        assert!(r.completed > 1000, "completed {}", r.completed);
        assert!((r.accuracy() - 1.0).abs() < 1e-9);
        // workers 1 and 2 did real work
        assert!(r.per_worker[1].processed + r.per_worker[2].processed > 0);
    }

    #[test]
    fn adaptive_rate_tracks_capacity() {
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("local");
        cfg.admission = AdmissionMode::AdaptiveRate { threshold: 0.9, initial_mu_s: 1.0 };
        cfg.duration_s = 120.0;
        cfg.warmup_s = 30.0;
        let r = run_des(cfg, &engine, &labels);
        // capacity: mean cost/sample = 0.002 + 0.5*0.003 = 3.5ms → ~285 Hz.
        // Alg. 3 should push the admitted rate into the right decade and
        // the system should complete most of what it admits.
        let rate = r.admitted_rate_hz();
        assert!(rate > 100.0, "admitted rate {rate} too low");
        assert!(
            r.completed as f64 >= 0.7 * r.admitted as f64,
            "completed {} vs admitted {}",
            r.completed,
            r.admitted
        );
    }

    #[test]
    fn adaptive_threshold_degrades_under_load() {
        let (engine, labels) = engine_2stage();
        // Rate far beyond capacity: Alg. 4 must lower T_e toward the floor.
        let mut cfg = base_cfg("local");
        cfg.admission =
            AdmissionMode::AdaptiveThreshold { rate_hz: 2000.0, initial_t_e: 0.99, t_e_min: 0.05 };
        cfg.duration_s = 60.0;
        let r = run_des(cfg, &engine, &labels);
        let t_e = r.final_t_e.unwrap();
        assert!(t_e < 0.5, "threshold should fall under overload, got {t_e}");
        // with low T_e nearly everything exits at 1
        let f = r.exit_fractions();
        assert!(f[0] > 0.8, "exit fractions {f:?}");
    }

    #[test]
    fn churn_rehomes_tasks() {
        use crate::simnet::ChurnEvent;
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("2-node");
        // far beyond the 2-node capacity (~330 Hz for these costs) so the
        // leaving worker is guaranteed to hold queued tasks at churn time
        cfg.admission = AdmissionMode::Fixed { rate_hz: 900.0, threshold: 0.9 };
        cfg.duration_s = 30.0;
        cfg.churn = vec![ChurnEvent { at_s: 10.0, worker: 1, join: false }];
        let r = run_des(cfg, &engine, &labels);
        assert!(r.completed > 0);
        // After the leave, in-flight/queued tasks re-home instead of vanishing.
        assert!(r.rehomed > 0, "expected rehomed tasks on churn");
    }

    #[test]
    fn ddi_mode_uses_whole_model_and_final_exit() {
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        cfg.mode = Mode::Ddi;
        cfg.admission = AdmissionMode::Fixed { rate_hz: 100.0, threshold: 0.9 };
        let r = run_des(cfg, &engine, &labels);
        let f = r.exit_fractions();
        assert_eq!(f[0], 0.0, "DDI never exits early: {f:?}");
        assert!(r.completed > 0);
        // whole images travel: bytes include 12 KiB payloads
        assert!(r.bytes_on_wire > 0);
    }

    #[test]
    fn conservation_no_task_loss() {
        // Every admitted sample (before a settling margin) must eventually
        // produce exactly one result: count with a long drain window.
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        cfg.admission = AdmissionMode::Fixed { rate_hz: 100.0, threshold: 0.9 };
        cfg.duration_s = 40.0;
        cfg.warmup_s = 0.0;
        let r = run_des(cfg, &engine, &labels);
        // Under-loaded (100 Hz vs ~285 Hz capacity): everything admitted
        // except the in-flight tail must complete.
        assert!(
            r.admitted - r.completed < 20,
            "admitted {} completed {}",
            r.admitted,
            r.completed
        );
    }

    #[test]
    fn batched_compute_amortizes_cost() {
        use crate::sched::BatchPolicy;
        let (engine, labels) = engine_2stage();
        // Overload a single worker far past its unbatched capacity (~285 Hz
        // for these costs): batching amortizes the per-stage dispatch and
        // lifts completed throughput.
        let mut cfg = base_cfg("local");
        cfg.admission = AdmissionMode::Fixed { rate_hz: 2000.0, threshold: 0.9 };
        let unbatched = run_des(cfg.clone(), &engine, &labels);
        cfg.sched.batch = BatchPolicy::batched(8);
        let batched = run_des(cfg, &engine, &labels);
        assert!(
            batched.completed as f64 >= 1.3 * unbatched.completed as f64,
            "batched {} vs unbatched {}",
            batched.completed,
            unbatched.completed
        );
    }

    #[test]
    fn strict_priority_separates_class_latency_under_overload() {
        use crate::sched::DisciplineKind;
        let (engine, labels) = engine_2stage();
        // 480 Hz total = 240 Hz per class: class 0 alone fits the worker
        // (only stage-1 work — even samples exit at 1), class 1 overloads
        // the leftover capacity and queues up behind it.
        let mut cfg = base_cfg("local");
        cfg.admission = AdmissionMode::Fixed { rate_hz: 480.0, threshold: 0.9 };
        cfg.sched = cfg.sched.with_classes(2);
        cfg.sched.discipline = DisciplineKind::StrictPriority;
        let mut r = run_des(cfg, &engine, &labels);
        let (c0, c1) = {
            let [c0, c1] = &mut r.per_class[..] else { panic!("2 classes") };
            (c0.latency.p95(), c1.latency.p95())
        };
        assert!(r.per_class[0].completed > 100, "class 0 starved: {:?}", r.per_class);
        assert!(
            c0 < 0.5 * c1,
            "strict priority must keep class 0 fast under overload: p95 {c0} vs {c1}"
        );
    }

    #[test]
    fn multi_source_line_reports_per_source_and_conserves() {
        use crate::routing::Placement;
        let (engine, labels) = engine_2stage();
        // Two sources at the ends of a 4-node line, comfortably under
        // capacity: everything each source admits must come back to *it*,
        // with the oracle's 50/50 exit split per source.
        let mut cfg = base_cfg("line-4");
        cfg.admission = AdmissionMode::Fixed { rate_hz: 60.0, threshold: 0.9 };
        cfg.placement = Placement::multi(&[0, 3]);
        cfg.duration_s = 30.0;
        cfg.warmup_s = 2.0;
        let r = run_des(cfg, &engine, &labels);
        assert_eq!(r.per_source.len(), 2);
        let by_source_admitted: u64 = r.per_source.iter().map(|s| s.admitted).sum();
        let by_source_completed: u64 = r.per_source.iter().map(|s| s.completed).sum();
        assert_eq!(by_source_admitted, r.admitted, "per-source admissions conserve");
        assert_eq!(by_source_completed, r.completed, "per-source completions conserve");
        for s in &r.per_source {
            assert!(s.admitted > 1000, "source {} admitted {}", s.node, s.admitted);
            assert!(
                (s.admitted as i64 - s.completed as i64).abs() < 30,
                "source {}: admitted {} completed {} (in-flight tail only)",
                s.node,
                s.admitted,
                s.completed
            );
            let f = s.exit_fractions();
            assert!((f[0] - 0.5).abs() < 0.05, "source {} split {f:?}", s.node);
        }
        assert!((r.accuracy() - 1.0).abs() < 1e-9);
        // The JSON report carries the per-source rows.
        let mut r = r;
        let j = r.to_json();
        let sources = j.get("sources").as_arr().unwrap();
        assert_eq!(sources.len(), 2);
        assert_eq!(sources[1].get("node").as_i64(), Some(3));
        assert!(sources[1].get("completed").as_i64().unwrap() > 0);
    }

    /// 8 samples x 3 exits, stage-3-heavy costs: 3/4 of the stream rides
    /// to the final stage, which is 6x the cost of the others — so under
    /// overload, continuing work piles up two hops from the source. (A
    /// 2-stage model can never spread past one hop: only final-stage
    /// tasks offload, and they spawn no successors.)
    fn engine_3stage() -> (SimEngine, Vec<u8>, ModelMeta) {
        let n = 8;
        let mut conf = Vec::new();
        let mut pred = Vec::new();
        let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
        for i in 0..n {
            if i % 4 == 0 {
                conf.extend([0.97f32, 0.99, 1.0]);
            } else {
                conf.extend([0.30f32, 0.50, 0.95]);
            }
            pred.extend([labels[i]; 3]);
        }
        let engine = SimEngine::from_table(ExitTable::synthetic(n, 3, conf, pred), false);
        let meta =
            ModelMeta::synthetic(vec![0.001, 0.001, 0.006], vec![12288, 8192, 4096]);
        (engine, labels, meta)
    }

    #[test]
    fn churned_mid_line_backlog_rehomes_multi_hop() {
        use crate::simnet::ChurnEvent;
        let (engine, labels, meta) = engine_3stage();
        // Source at 0, worker 2 (two hops out) leaves while holding a
        // stage-3 backlog: that backlog must travel 2 → 1 → 0, showing up
        // as relays at worker 1 and re-homings at the source — the path
        // the old source-adjacency assumption could not express.
        let mut cfg = base_cfg("line-4");
        cfg.admission = AdmissionMode::Fixed { rate_hz: 900.0, threshold: 0.9 };
        cfg.duration_s = 30.0;
        cfg.warmup_s = 0.0;
        cfg.churn = vec![ChurnEvent { at_s: 10.0, worker: 2, join: false }];
        let r = Run::builder()
            .config(cfg)
            .model(meta)
            .engine(&engine)
            .labels(&labels)
            .driver(Driver::Des)
            .execute()
            .unwrap();
        assert!(r.rehomed > 0, "mid-line churn must re-home, not strand");
        assert!(
            r.per_worker[1].relayed > 0,
            "re-homes from worker 2 relay through worker 1: {:?}",
            r.per_worker.iter().map(|w| w.relayed).collect::<Vec<_>>()
        );
        assert!(r.completed > 0);
    }

    #[test]
    fn calendar_queue_reproduces_baseline_heap_run() {
        // The fast-path regression net: the same seed run under both event
        // queues must produce identical event counts and statistics —
        // event-order identity, observed end to end (offload decisions,
        // RNG draw order, byte charges all depend on it).
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        cfg.admission = AdmissionMode::Fixed { rate_hz: 300.0, threshold: 0.9 };
        let run = |kind: QueueKind| {
            let store = SampleStore { labels: &labels, images: None };
            Simulation::new(cfg.clone(), &engine, meta_2stage(), store)
                .unwrap()
                .with_queue_kind(kind)
                .run()
                .unwrap()
        };
        let a = run(QueueKind::Baseline);
        let b = run(QueueKind::Calendar);
        assert!(a.sim_events > 10_000, "run too small to mean anything");
        assert_eq!(a.sim_events, b.sim_events, "event counts diverged");
        assert_eq!(a.admitted, b.admitted);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.correct, b.correct);
        assert_eq!(a.exit_histogram, b.exit_histogram);
        assert_eq!(a.bytes_on_wire, b.bytes_on_wire, "byte charges diverged");
        assert_eq!(a.task_transfers, b.task_transfers);
        assert_eq!(
            a.latency.mean().to_bits(),
            b.latency.mean().to_bits(),
            "latencies must match to the bit"
        );
        assert!(b.peak_event_queue > 0);
    }

    #[test]
    fn poisson_workload_runs_and_alters_the_timeline() {
        use crate::workload::ArrivalSpec;
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        let legacy = run_des(cfg.clone(), &engine, &labels);
        cfg.workload.arrival = ArrivalSpec::Poisson;
        let poisson = run_des(cfg.clone(), &engine, &labels);
        // Same mean rate, different draw sequence: the counts land close
        // but not identical.
        assert_ne!(legacy.admitted, poisson.admitted);
        let ratio = poisson.admitted as f64 / legacy.admitted as f64;
        assert!((0.9..1.1).contains(&ratio), "mean rate preserved, ratio {ratio}");
        // Determinism: the Poisson run replays exactly.
        let again = run_des(cfg, &engine, &labels);
        assert_eq!(poisson.admitted, again.admitted);
        assert_eq!(poisson.completed, again.completed);
    }

    #[test]
    fn constant_arrival_reproduces_fixed_mode_timeline() {
        use crate::workload::ArrivalSpec;
        // Under `Fixed` admission the legacy pacing IS constant-rate, so
        // the explicit Constant model must reproduce it bit for bit.
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        let legacy = run_des(cfg.clone(), &engine, &labels);
        cfg.workload.arrival = ArrivalSpec::Constant;
        let constant = run_des(cfg, &engine, &labels);
        assert_eq!(legacy.admitted, constant.admitted);
        assert_eq!(legacy.completed, constant.completed);
        assert_eq!(legacy.bytes_on_wire, constant.bytes_on_wire);
        assert_eq!(
            legacy.latency.mean().to_bits(),
            constant.latency.mean().to_bits()
        );
    }

    #[test]
    fn gossip_piggyback_preserves_behavior_and_saves_bytes() {
        let (engine, labels) = engine_2stage();
        // Busy mesh: plenty of task/result envelopes for summaries to ride.
        let mut cfg = base_cfg("3-node-mesh");
        cfg.admission = AdmissionMode::Fixed { rate_hz: 300.0, threshold: 0.9 };
        let off = run_des(cfg.clone(), &engine, &labels);
        cfg.gossip_piggyback = true;
        let on = run_des(cfg, &engine, &labels);
        // Piggybacking must not break the system: the same work completes
        // (byte totals and RNG order shift, so counts are close, not
        // equal).
        assert!(on.completed > 0);
        let ratio = on.completed as f64 / off.completed as f64;
        assert!((0.9..1.1).contains(&ratio), "completion ratio {ratio}");
        assert!((on.accuracy() - off.accuracy()).abs() < 1e-9);
        // And it must actually save gossip wire bytes on a busy link.
        let gossip_off: u64 = off.per_worker.iter().map(|w| w.gossip_bytes).sum();
        let gossip_on: u64 = on.per_worker.iter().map(|w| w.gossip_bytes).sum();
        assert!(
            gossip_on < gossip_off,
            "piggybacked gossip {gossip_on} should undercut dedicated {gossip_off}"
        );
    }

    #[test]
    fn metro_topology_runs_end_to_end() {
        use crate::routing::Placement;
        use crate::workload::ArrivalSpec;
        // A generated 60-node geometric graph with 6 Poisson sources —
        // small enough for a unit test, structurally the metro bench.
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("random-geometric-60-0.2");
        cfg.admission = AdmissionMode::Fixed { rate_hz: 40.0, threshold: 0.9 };
        cfg.placement = Placement::multi(&[0, 10, 20, 30, 40, 50]);
        cfg.workload.arrival = ArrivalSpec::Poisson;
        cfg.duration_s = 10.0;
        cfg.warmup_s = 1.0;
        let r = run_des(cfg, &engine, &labels);
        assert!(r.completed > 1000, "completed {}", r.completed);
        assert_eq!(r.per_source.len(), 6);
        for s in &r.per_source {
            assert!(s.admitted > 100, "source {} admitted {}", s.node, s.admitted);
        }
    }

    #[test]
    fn rejects_bad_construction() {
        let (engine, labels) = engine_2stage();
        let cfg = base_cfg("not-a-topology");
        let store = SampleStore { labels: &labels, images: None };
        assert!(Simulation::new(cfg, &engine, meta_2stage(), store).is_err());

        // Placement that does not fit the topology.
        let mut cfg = base_cfg("2-node");
        cfg.placement = crate::routing::Placement::multi(&[0, 5]);
        let store = SampleStore { labels: &labels, images: None };
        assert!(Simulation::new(cfg, &engine, meta_2stage(), store).is_err());

        // Churn schedule that would retire every source (one of several
        // leaving is fine — the relaxed guard only requires coverage).
        let mut cfg = base_cfg("line-4");
        cfg.placement = crate::routing::Placement::multi(&[0, 3]);
        cfg.churn = vec![
            crate::simnet::ChurnEvent { at_s: 1.0, worker: 0, join: false },
            crate::simnet::ChurnEvent { at_s: 2.0, worker: 3, join: false },
        ];
        let store = SampleStore { labels: &labels, images: None };
        assert!(Simulation::new(cfg, &engine, meta_2stage(), store).is_err());

        let mut cfg = base_cfg("local");
        cfg.use_ae = true; // meta has no AE
        let store = SampleStore { labels: &labels, images: None };
        assert!(Simulation::new(cfg, &engine, meta_2stage(), store).is_err());

        let cfg = base_cfg("local");
        let store = SampleStore { labels: &[], images: None };
        assert!(Simulation::new(cfg, &engine, meta_2stage(), store).is_err());
    }

    #[test]
    fn cluster_off_keeps_static_fleet_accounting() {
        let (engine, labels) = engine_2stage();
        let r = run_des(base_cfg("3-node-mesh"), &engine, &labels);
        assert_eq!(r.scale_ups, 0);
        assert_eq!(r.scale_downs, 0);
        // A static 3-node fleet bills exactly 3 x duration.
        assert!(
            (r.worker_seconds - 3.0 * r.duration_s).abs() < 1e-6,
            "worker_seconds {} vs {}",
            r.worker_seconds,
            3.0 * r.duration_s
        );
    }

    #[test]
    fn cluster_autoscales_under_load_and_bills_the_live_fleet() {
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        // 600 Hz is ~2x a single node's capacity: starting from one active
        // node, the controller must wake the parked pair to keep up.
        cfg.admission = AdmissionMode::Fixed { rate_hz: 600.0, threshold: 0.9 };
        cfg.duration_s = 30.0;
        cfg.warmup_s = 0.0;
        cfg.cluster.enabled = true;
        cfg.cluster.initial_workers = Some(1);
        let r = run_des(cfg, &engine, &labels);
        assert!(r.scale_ups > 0, "overload must wake parked workers");
        assert!(r.completed > 1000, "completed {}", r.completed);
        // The fleet started at 1 of 3 nodes, so the cost integral must come
        // in under the static 3 x duration bill.
        assert!(
            r.worker_seconds < 3.0 * r.duration_s - 0.5,
            "worker_seconds {} should be below the static bill {}",
            r.worker_seconds,
            3.0 * r.duration_s
        );
    }

    #[test]
    fn cluster_runs_are_bit_for_bit_reproducible() {
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        cfg.admission = AdmissionMode::Fixed { rate_hz: 600.0, threshold: 0.9 };
        cfg.cluster.enabled = true;
        cfg.cluster.initial_workers = Some(1);
        let mut a = run_des(cfg.clone(), &engine, &labels);
        let mut b = run_des(cfg, &engine, &labels);
        assert_eq!(a.admitted, b.admitted);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.scale_ups, b.scale_ups);
        assert_eq!(a.scale_downs, b.scale_downs);
        assert_eq!(a.bytes_on_wire, b.bytes_on_wire);
        assert_eq!(a.latency.len(), b.latency.len());
        assert_eq!(a.latency.p95().to_bits(), b.latency.p95().to_bits());
        assert_eq!(a.worker_seconds.to_bits(), b.worker_seconds.to_bits());
    }

    #[test]
    fn cluster_reroutes_scripted_leave_and_respawns_under_load() {
        use crate::simnet::ChurnEvent;
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        // 3x a single node's capacity: every node holds queued tasks when
        // worker 1 leaves at t = 10, so its queue must re-home — and the
        // sustained overload then drives the controller to respawn it
        // (min_workers = 3 keeps the autoscaler from retiring anyone first,
        // so the scripted leave is never stale).
        cfg.admission = AdmissionMode::Fixed { rate_hz: 900.0, threshold: 0.9 };
        cfg.duration_s = 30.0;
        cfg.warmup_s = 0.0;
        cfg.churn = vec![ChurnEvent { at_s: 10.0, worker: 1, join: false }];
        cfg.cluster.enabled = true;
        cfg.cluster.min_workers = 3;
        let r = run_des(cfg, &engine, &labels);
        assert!(r.completed > 1000, "completed {}", r.completed);
        assert!(r.rehomed > 0, "queued tasks must re-home on the leave");
        assert!(r.scale_ups >= 1, "the control plane must heal the fleet");
        // Nothing is lost or duplicated across the re-layouts: every
        // completion landed at a source's per-source row.
        let by_source: u64 = r.per_source.iter().map(|s| s.completed).sum();
        assert_eq!(by_source, r.completed, "per-source completions conserve");
    }
}
