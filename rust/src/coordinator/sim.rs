//! Discrete-event driver: runs the MDI-Exit system in virtual time.
//!
//! This is what the figure benches execute. Workers are state machines;
//! compute completions, network deliveries, gossip, admission, and the
//! Alg. 3/4 adaptation ticks are events on a virtual-clock heap. The
//! decision logic is the *same* pure `policy` module the realtime threaded
//! driver uses — only the clock differs — so the benches measure the
//! paper's algorithms, not a re-implementation.
//!
//! Engine-agnostic: with `SimEngine` (exit-oracle replay) a 60-virtual-
//! second topology run takes milliseconds; with `XlaEngine` the same driver
//! pushes real feature tensors through the compiled HLO stages (used by the
//! end-to-end integration tests).

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;

use anyhow::{bail, Context, Result};

use super::config::{AdmissionMode, ExperimentConfig, Mode};
use super::policy::{
    self, ExitDecision, NeighborView, RateController, ThresholdController,
};
use super::queues::WorkerQueues;
use super::report::{RunReport, TracePoint, WorkerStats};
use super::task::{InferenceResult, Task};
use crate::artifact::ModelInfo;
use crate::log_debug;
use crate::runtime::InferenceEngine;
use crate::simnet::Topology;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use crate::util::stats::Ewma;

/// Bytes of an exit-result message (classifier output + header).
const RESULT_BYTES: usize = 64;
/// Trace sampling period (virtual seconds).
const TRACE_PERIOD_S: f64 = 0.25;
/// Hard ceiling on processed events — runaway-loop backstop.
const MAX_EVENTS: u64 = 200_000_000;

/// Compute/transfer metadata distilled from the manifest (so the DES inner
/// loop never touches JSON or paths).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub stage_cost_s: Vec<f64>,
    pub stage_in_bytes: Vec<usize>,
    pub num_stages: usize,
    pub ae: Option<AeMeta>,
}

#[derive(Debug, Clone)]
pub struct AeMeta {
    pub enc_cost_s: f64,
    pub dec_cost_s: f64,
    pub code_bytes: usize,
}

impl ModelMeta {
    pub fn from_manifest(info: &ModelInfo) -> ModelMeta {
        ModelMeta {
            stage_cost_s: info.stages.iter().map(|s| s.cost_ms / 1e3).collect(),
            stage_in_bytes: info.stages.iter().map(|s| s.in_bytes).collect(),
            num_stages: info.num_stages,
            ae: info.ae.as_ref().map(|ae| AeMeta {
                enc_cost_s: ae.enc_cost_ms / 1e3,
                dec_cost_s: ae.dec_cost_ms / 1e3,
                code_bytes: ae.code_bytes,
            }),
        }
    }

    /// Synthetic metadata for engine-free unit tests.
    pub fn synthetic(stage_cost_s: Vec<f64>, stage_in_bytes: Vec<usize>) -> ModelMeta {
        let n = stage_cost_s.len();
        assert_eq!(n, stage_in_bytes.len());
        ModelMeta { stage_cost_s, stage_in_bytes, num_stages: n, ae: None }
    }

    fn total_cost_s(&self) -> f64 {
        self.stage_cost_s.iter().sum()
    }
}

/// Sample access: labels always; image tensors only on the real-engine path.
pub struct SampleStore<'a> {
    pub labels: &'a [u8],
    pub images: Option<&'a crate::dataset::Dataset>,
}

impl<'a> SampleStore<'a> {
    pub fn len(&self) -> usize {
        self.labels.len()
    }
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
    fn image(&self, i: usize) -> Option<Tensor> {
        self.images.map(|d| d.image(i))
    }
}

// ---------------------------------------------------------------------------
// Event machinery
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum Msg {
    Task(Task),
    Result(InferenceResult),
}

#[derive(Debug)]
enum Event {
    Admit,
    AdaptTick,
    ComputeDone { worker: usize },
    Deliver { to: usize, from: usize, msg: Msg },
    GossipTick,
    TraceTick,
    Churn { idx: usize },
}

struct Entry {
    t: f64,
    seq: u64,
    ev: Event,
}

impl PartialEq for Entry {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, o: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(o))
    }
}
impl Ord for Entry {
    fn cmp(&self, o: &Self) -> CmpOrdering {
        // BinaryHeap is a max-heap: reverse for earliest-first.
        o.t.total_cmp(&self.t).then(o.seq.cmp(&self.seq))
    }
}

struct SimWorker {
    active: bool,
    queues: WorkerQueues,
    current: Option<Task>,
    busy_started: f64,
    busy_duration: f64,
    /// Per-task compute-delay estimate Γ_n (EWMA of measured durations).
    gamma: Ewma,
    /// What n believes about each other worker (gossip + optimism).
    views: Vec<Option<NeighborView>>,
    /// Measured transfer-delay estimate D_nm per neighbor.
    d_est: Vec<Ewma>,
    rng: Pcg64,
    stats: WorkerStats,
    speed: f64,
}

/// The simulation state. Construct with [`Simulation::new`], then [`Simulation::run`].
pub struct Simulation<'a> {
    cfg: ExperimentConfig,
    topo: Topology,
    meta: ModelMeta,
    engine: &'a dyn InferenceEngine,
    store: SampleStore<'a>,

    heap: BinaryHeap<Entry>,
    seq: u64,
    now: f64,
    next_task_id: u64,
    next_sample: usize,

    workers: Vec<SimWorker>,
    rate_ctl: Option<RateController>,
    thr_ctl: Option<ThresholdController>,
    /// Current global early-exit threshold T_e (Alg. 4 line 9 applies the
    /// adapted value to all exit points).
    t_e: f32,
    rng: Pcg64,
    /// Concurrent transfers on the shared medium (WiFi contention model).
    active_transfers: usize,
    ddi_next_target: usize,
    /// Precomputed adjacency (hot path: try_offload runs per event).
    neighbors: Vec<Vec<usize>>,
    /// Scratch buffer for the shuffled neighbor scan (avoids a Vec
    /// allocation per offload attempt — see EXPERIMENTS.md §Perf).
    scan_buf: Vec<usize>,

    report: RunReport,
    measure_from: f64,
    end_at: f64,
}

impl<'a> Simulation<'a> {
    pub fn new(
        cfg: ExperimentConfig,
        engine: &'a dyn InferenceEngine,
        meta: ModelMeta,
        store: SampleStore<'a>,
    ) -> Result<Simulation<'a>> {
        cfg.validate()?;
        if store.is_empty() {
            bail!("empty sample store");
        }
        if meta.num_stages != engine.num_stages() {
            bail!("meta stages {} != engine stages {}", meta.num_stages, engine.num_stages());
        }
        if cfg.use_ae && meta.ae.is_none() {
            bail!("use_ae set but model has no autoencoder");
        }
        let topo = Topology::named(&cfg.topology, cfg.link)
            .with_context(|| format!("unknown topology {:?}", cfg.topology))?
            .with_churn(cfg.churn.clone());
        let mut rng = Pcg64::new(cfg.seed, 0);
        let default_gamma = meta.total_cost_s() / meta.num_stages as f64;
        let workers = (0..topo.n)
            .map(|i| SimWorker {
                active: true,
                queues: WorkerQueues::new(),
                current: None,
                busy_started: 0.0,
                busy_duration: 0.0,
                gamma: {
                    let mut e = Ewma::new(0.2);
                    e.push(default_gamma / (topo.workers[i].speed * cfg.compute_scale));
                    e
                },
                views: vec![None; topo.n],
                d_est: (0..topo.n).map(|_| Ewma::new(0.2)).collect(),
                rng: rng.fork(i as u64 + 1),
                stats: WorkerStats::default(),
                speed: topo.workers[i].speed * cfg.compute_scale,
            })
            .collect();

        let (rate_ctl, thr_ctl, t_e) = match cfg.admission {
            AdmissionMode::AdaptiveRate { threshold, initial_mu_s } => {
                (Some(RateController::new(cfg.adapt, initial_mu_s)), None, threshold)
            }
            AdmissionMode::AdaptiveThreshold { initial_t_e, t_e_min, .. } => (
                None,
                Some(ThresholdController::new(cfg.adapt, initial_t_e as f64, t_e_min as f64)),
                initial_t_e,
            ),
            AdmissionMode::Fixed { threshold, .. } => (None, None, threshold),
        };

        let neighbors: Vec<Vec<usize>> = (0..topo.n).map(|n| topo.neighbors(n)).collect();
        let report = RunReport::new(
            &cfg.model,
            &cfg.topology,
            &run_label(&cfg),
            topo.n,
            meta.num_stages,
        );
        let measure_from = cfg.warmup_s;
        let end_at = cfg.warmup_s + cfg.duration_s;
        Ok(Simulation {
            cfg,
            topo,
            meta,
            engine,
            store,
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            next_task_id: 0,
            next_sample: 0,
            workers,
            rate_ctl,
            thr_ctl,
            t_e,
            rng,
            active_transfers: 0,
            ddi_next_target: 0,
            neighbors,
            scan_buf: Vec::new(),
            report,
            measure_from,
            end_at,
        })
    }

    fn push(&mut self, t: f64, ev: Event) {
        self.seq += 1;
        self.heap.push(Entry { t, seq: self.seq, ev });
    }

    /// Run to completion; returns the measured report.
    pub fn run(mut self) -> Result<RunReport> {
        self.push(0.0, Event::Admit);
        self.push(self.cfg.gossip_interval_s, Event::GossipTick);
        self.push(TRACE_PERIOD_S, Event::TraceTick);
        if self.rate_ctl.is_some() || self.thr_ctl.is_some() {
            self.push(self.cfg.adapt.sleep_s, Event::AdaptTick);
        }
        let churn = self.topo.churn.clone();
        for (idx, e) in churn.iter().enumerate() {
            self.push(e.at_s, Event::Churn { idx });
        }

        let mut events: u64 = 0;
        while let Some(Entry { t, ev, .. }) = self.heap.pop() {
            if t >= self.end_at {
                break;
            }
            self.now = t;
            events += 1;
            if events > MAX_EVENTS {
                bail!("event budget exhausted (runaway simulation)");
            }
            match ev {
                Event::Admit => self.on_admit()?,
                Event::AdaptTick => self.on_adapt_tick(),
                Event::ComputeDone { worker } => self.on_compute_done(worker)?,
                Event::Deliver { to, from, msg } => self.on_deliver(to, from, msg)?,
                Event::GossipTick => self.on_gossip(),
                Event::TraceTick => self.on_trace(),
                Event::Churn { idx } => self.on_churn(idx)?,
            }
        }
        self.finalize()
    }

    // -- admission ---------------------------------------------------------

    fn on_admit(&mut self) -> Result<()> {
        let sample = self.next_sample;
        self.next_sample = (self.next_sample + 1) % self.store.len();
        let id = self.next_id();
        let features = self.store.image(sample);
        let task = Task::initial(id, sample, features, self.now);
        if self.now >= self.measure_from {
            self.report.admitted += 1;
        }

        match self.cfg.mode {
            Mode::MdiExit => {
                self.workers[0].queues.input.push(task);
                self.try_start(0)?;
            }
            Mode::Ddi => {
                // Round-robin whole images across all active workers
                // (including the source). No partitioning, no early exits.
                let n = self.topo.n;
                let mut target = self.ddi_next_target % n;
                for _ in 0..n {
                    if self.workers[target].active
                        && (target == 0 || self.topo.is_connected_pair(0, target))
                    {
                        break;
                    }
                    target = (target + 1) % n;
                }
                self.ddi_next_target = target + 1;
                if target == 0 {
                    self.workers[0].queues.input.push(task);
                    self.try_start(0)?;
                } else {
                    let bytes = self.meta.stage_in_bytes[0];
                    self.transmit_task(0, target, task, bytes)?;
                }
            }
        }

        // Schedule the next arrival.
        let dt = match self.cfg.admission {
            AdmissionMode::AdaptiveRate { .. } => {
                self.rate_ctl.as_ref().expect("rate controller").mu_s()
            }
            AdmissionMode::AdaptiveThreshold { rate_hz, .. } => {
                self.rng.exponential(1.0 / rate_hz)
            }
            AdmissionMode::Fixed { rate_hz, .. } => 1.0 / rate_hz,
        };
        self.push(self.now + dt, Event::Admit);
        Ok(())
    }

    fn on_adapt_tick(&mut self) {
        let q = self.workers[0].queues.total_len();
        if let Some(rc) = self.rate_ctl.as_mut() {
            rc.update(q);
        }
        if let Some(tc) = self.thr_ctl.as_mut() {
            // Alg. 4 line 9: the adapted T_e applies to every exit point.
            self.t_e = tc.update(q) as f32;
        }
        self.push(self.now + self.cfg.adapt.sleep_s, Event::AdaptTick);
    }

    // -- compute -----------------------------------------------------------

    fn try_start(&mut self, n: usize) -> Result<()> {
        let w = &mut self.workers[n];
        if !w.active || w.current.is_some() || w.queues.input.is_empty() {
            return Ok(());
        }
        let task = w.queues.input.pop().unwrap();
        let mut cost = match self.cfg.mode {
            Mode::Ddi => self.meta.total_cost_s(),
            Mode::MdiExit => self.meta.stage_cost_s[task.stage - 1],
        };
        if task.encoded {
            cost += self.meta.ae.as_ref().map(|ae| ae.dec_cost_s).unwrap_or(0.0);
        }
        // ±3% lognormal-ish execution noise (thermal/DVFS variability).
        let noise = w.rng.normal(1.0, 0.03).clamp(0.7, 1.3);
        let duration = cost * noise / w.speed;
        w.busy_started = self.now;
        w.busy_duration = duration;
        w.current = Some(task);
        self.push(self.now + duration, Event::ComputeDone { worker: n });
        Ok(())
    }

    fn on_compute_done(&mut self, n: usize) -> Result<()> {
        let (task, duration) = {
            let w = &mut self.workers[n];
            let task = w.current.take().expect("compute done without task");
            if self.now >= self.measure_from {
                w.stats.busy_s += w.busy_duration;
                w.stats.processed += 1;
            }
            w.gamma.push(w.busy_duration);
            (task, w.busy_duration)
        };
        let _ = duration;

        // Run the stage(s) through the engine to observe C_k(d) (eq. 2).
        let (out, exit_point) = match self.cfg.mode {
            Mode::Ddi => {
                // whole model locally: chain every stage, exit at K
                let mut feats = task.features.clone();
                let mut out = None;
                for k in 1..=self.meta.num_stages {
                    let o = self.engine.run_stage(k, task.sample, feats.as_ref())?;
                    feats = o.features.clone();
                    out = Some(o);
                }
                (out.unwrap(), self.meta.num_stages)
            }
            Mode::MdiExit => {
                let mut feats = task.features.clone();
                if task.encoded {
                    if let Some(f) = &feats {
                        feats = self.engine.decode(f)?.or(feats);
                    }
                }
                let o = self.engine.run_stage(task.stage, task.sample, feats.as_ref())?;
                (o, task.stage)
            }
        };

        let is_final = exit_point >= self.meta.num_stages || self.cfg.mode == Mode::Ddi;
        let w = &self.workers[n];
        let threshold = if self.cfg.no_early_exit { f32::INFINITY } else { self.t_e };
        let decision = policy::alg1_decide(
            out.confidence,
            threshold,
            is_final,
            w.queues.input.len(),
            w.queues.output.len(),
            self.cfg.t_o,
        );

        match decision {
            ExitDecision::Exit => {
                self.workers[n].stats.exits += 1;
                let result = InferenceResult {
                    sample: task.sample,
                    exit_point,
                    prediction: out.prediction,
                    confidence: out.confidence,
                    admitted_at: task.admitted_at,
                    exited_on: n,
                };
                if n == 0 {
                    self.record_result(result);
                } else {
                    self.transmit_result(n, result)?;
                }
            }
            ExitDecision::ContinueLocal => {
                let id = self.next_id();
                let succ = task.successor(id, out.features);
                self.workers[n].queues.input.push(succ);
            }
            ExitDecision::ContinueOffload => {
                let id = self.next_id();
                let succ = task.successor(id, out.features);
                self.workers[n].queues.output.push(succ);
            }
        }

        self.try_offload(n)?;
        self.try_start(n)?;
        Ok(())
    }

    // -- offloading (Alg. 2) -------------------------------------------------

    fn try_offload(&mut self, n: usize) -> Result<()> {
        loop {
            if self.workers[n].queues.output.is_empty() || !self.workers[n].active {
                return Ok(());
            }
            let mut scan = std::mem::take(&mut self.scan_buf);
            scan.clear();
            scan.extend(self.neighbors[n].iter().copied()
                .filter(|&m| self.workers[m].active));
            self.workers[n].rng.shuffle(&mut scan);

            let mut sent = false;
            for m in scan.iter().copied() {
                let (o_len, i_len, gamma_n, view) = {
                    let w = &self.workers[n];
                    let view = w.views[m].unwrap_or_else(|| self.default_view(n, m));
                    (
                        w.queues.output.len(),
                        w.queues.input.len(),
                        w.gamma.get_or(0.01),
                        view,
                    )
                };
                let go = {
                    let w = &mut self.workers[n];
                    policy::offload_decide(
                        self.cfg.offload_policy,
                        o_len,
                        i_len,
                        gamma_n,
                        &view,
                        &mut w.rng,
                    )
                };
                if go {
                    let task = self.workers[n].queues.output.pop().unwrap();
                    let bytes = self.task_wire_bytes(&task);
                    let task = self.maybe_encode(n, task)?;
                    let bytes = if task.encoded {
                        self.meta.ae.as_ref().unwrap().code_bytes
                    } else {
                        bytes
                    };
                    self.transmit_task(n, m, task, bytes)?;
                    // optimistic view update until the next gossip refresh
                    if let Some(v) = self.workers[n].views[m].as_mut() {
                        v.input_len += 1;
                    }
                    sent = true;
                    break;
                }
            }
            self.scan_buf = scan;
            if !sent {
                // No neighbor accepted the head-of-line task. If local
                // compute is starving, reclaim it for the input queue
                // (prevents livelock; see DESIGN.md §6 — the paper's Alg. 2
                // spins, which a discrete simulation must not).
                let w = &mut self.workers[n];
                if w.current.is_none() && w.queues.input.is_empty() {
                    if let Some(t) = w.queues.output.pop() {
                        w.queues.input.push(t);
                        self.try_start(n)?;
                    }
                }
                return Ok(());
            }
        }
    }

    fn default_view(&self, n: usize, m: usize) -> NeighborView {
        let typical = self.meta.stage_in_bytes[self.meta.num_stages.min(2) - 1];
        let d = self.workers[n].d_est[m].get_or(
            self.topo
                .link(n, m)
                .map(|l| l.mean_delay_s(typical))
                .unwrap_or(0.01),
        );
        NeighborView {
            input_len: self.workers[m].queues.input.len(),
            gamma_s: self.workers[m].gamma.get_or(0.01),
            d_nm_s: d,
        }
    }

    /// Payload size of τ_k on the wire: the feature tensor entering stage k.
    fn task_wire_bytes(&self, task: &Task) -> usize {
        if task.encoded {
            return self.meta.ae.as_ref().map(|ae| ae.code_bytes).unwrap_or(0);
        }
        self.meta.stage_in_bytes[task.stage - 1]
    }

    /// Autoencoder at the stage-1 boundary: encode features before the wire
    /// (paper §V — only the first ResNet exit has an AE).
    fn maybe_encode(&mut self, n: usize, mut task: Task) -> Result<Task> {
        if !self.cfg.use_ae || task.encoded || task.stage != 2 {
            return Ok(task);
        }
        let Some(ae) = &self.meta.ae else { return Ok(task) };
        // Encoding costs compute on the sender; fold into the send path.
        let _enc_cost = ae.enc_cost_s / self.workers[n].speed;
        if let Some(f) = &task.features {
            if let Some(code) = self.engine.encode(f)? {
                task.features = Some(code);
            }
        }
        task.encoded = true;
        Ok(task)
    }

    fn link_delay(&mut self, n: usize, m: usize, bytes: usize) -> Result<f64> {
        let Some(link) = self.topo.link(n, m).copied() else {
            bail!("no link {n} -> {m}");
        };
        // Shared-medium contention: concurrent transfers divide bandwidth.
        let slow = 1.0 + self.cfg.medium_contention * self.active_transfers as f64;
        let mut eff = link;
        eff.bandwidth_bps = link.bandwidth_bps / slow;
        Ok(eff.delay_s(bytes, &mut self.workers[n].rng))
    }

    fn transmit_task(&mut self, n: usize, m: usize, task: Task, bytes: usize) -> Result<()> {
        let mut delay = self.link_delay(n, m, bytes)?;
        if task.encoded {
            if let Some(ae) = &self.meta.ae {
                delay += ae.enc_cost_s / self.workers[n].speed;
            }
        }
        self.workers[n].d_est[m].push(delay);
        if self.now >= self.measure_from {
            self.workers[n].stats.offloaded_out += 1;
            self.report.bytes_on_wire += bytes as u64;
            self.report.task_transfers += 1;
        }
        self.active_transfers += 1;
        let mut task = task;
        task.hops += 1;
        self.push(self.now + delay, Event::Deliver { to: m, from: n, msg: Msg::Task(task) });
        Ok(())
    }

    fn transmit_result(&mut self, n: usize, result: InferenceResult) -> Result<()> {
        // Results go back to the source (worker 0). All testbed topologies
        // are one hop from the source; a disconnected pair would indicate a
        // custom topology, where we charge a two-hop relay delay.
        let delay = if self.topo.is_connected_pair(n, 0) {
            self.link_delay(n, 0, RESULT_BYTES)?
        } else {
            let via = self.topo.neighbors(n).first().copied().context("isolated worker")?;
            self.link_delay(n, via, RESULT_BYTES)? * 2.0
        };
        if self.now >= self.measure_from {
            self.report.bytes_on_wire += RESULT_BYTES as u64;
        }
        self.active_transfers += 1;
        self.push(
            self.now + delay,
            Event::Deliver { to: 0, from: n, msg: Msg::Result(result) },
        );
        Ok(())
    }

    fn on_deliver(&mut self, to: usize, _from: usize, msg: Msg) -> Result<()> {
        // the transfer occupying the shared medium ends on delivery
        self.active_transfers = self.active_transfers.saturating_sub(1);
        match msg {
            Msg::Task(task) => {
                if !self.workers[to].active {
                    // Destination left while the task was in flight: the
                    // fabric re-homes it to the source so no data is lost.
                    self.report.rehomed += 1;
                    self.workers[0].queues.input.push(task);
                    self.try_start(0)?;
                    return Ok(());
                }
                if self.now >= self.measure_from {
                    self.workers[to].stats.received += 1;
                }
                self.workers[to].queues.input.push(task);
                self.try_start(to)?;
                self.try_offload(to)?;
            }
            Msg::Result(r) => {
                self.record_result(r);
            }
        }
        Ok(())
    }

    fn record_result(&mut self, r: InferenceResult) {
        if self.now < self.measure_from {
            return;
        }
        self.report.completed += 1;
        let label = self.store.labels[r.sample];
        if r.prediction == label {
            self.report.correct += 1;
        }
        self.report.exit_histogram[r.exit_point - 1] += 1;
        self.report.latency.push(self.now - r.admitted_at);
    }

    // -- periodic state ------------------------------------------------------

    fn on_gossip(&mut self) {
        for n in 0..self.topo.n {
            if !self.workers[n].active {
                continue;
            }
            for i in 0..self.neighbors[n].len() {
                let m = self.neighbors[n][i];
                if !self.workers[m].active {
                    self.workers[n].views[m] = None;
                    continue;
                }
                let view = self.default_view(n, m);
                self.workers[n].views[m] = Some(view);
            }
        }
        // Gossip may unblock offloading stalled on stale views.
        for n in 0..self.topo.n {
            if self.workers[n].active {
                let _ = self.try_offload(n);
            }
        }
        self.push(self.now + self.cfg.gossip_interval_s, Event::GossipTick);
    }

    fn on_trace(&mut self) {
        let control = self
            .rate_ctl
            .as_ref()
            .map(|rc| rc.mu_s())
            .or_else(|| self.thr_ctl.as_ref().map(|tc| tc.t_e()))
            .unwrap_or(self.t_e as f64);
        self.report.trace.push(TracePoint {
            t_s: self.now,
            control,
            source_queue: self.workers[0].queues.total_len(),
        });
        self.push(self.now + TRACE_PERIOD_S, Event::TraceTick);
    }

    fn on_churn(&mut self, idx: usize) -> Result<()> {
        let e = self.topo.churn[idx];
        log_debug!("churn at {:.2}s: worker {} {}", self.now, e.worker,
                   if e.join { "joins" } else { "leaves" });
        if e.join {
            self.workers[e.worker].active = true;
            self.try_start(e.worker)?;
        } else {
            self.workers[e.worker].active = false;
            // Re-home queued tasks to the source — no data loss on churn.
            let mut tasks = self.workers[e.worker].queues.input.drain_all();
            tasks.extend(self.workers[e.worker].queues.output.drain_all());
            self.report.rehomed += tasks.len() as u64;
            for t in tasks {
                self.workers[0].queues.input.push(t);
            }
            self.try_start(0)?;
        }
        Ok(())
    }

    fn next_id(&mut self) -> u64 {
        self.next_task_id += 1;
        self.next_task_id
    }

    fn finalize(mut self) -> Result<RunReport> {
        self.report.duration_s = self.cfg.duration_s;
        for (i, w) in self.workers.iter().enumerate() {
            self.report.per_worker[i].peak_input = w.queues.input.peak();
            self.report.per_worker[i].peak_output = w.queues.output.peak();
            let s = &w.stats;
            self.report.per_worker[i].processed = s.processed;
            self.report.per_worker[i].offloaded_out = s.offloaded_out;
            self.report.per_worker[i].received = s.received;
            self.report.per_worker[i].exits = s.exits;
            self.report.per_worker[i].busy_s = s.busy_s;
        }
        self.report.final_mu_s = self.rate_ctl.as_ref().map(|rc| rc.mu_s());
        self.report.final_t_e = self.thr_ctl.as_ref().map(|tc| tc.t_e());
        Ok(self.report)
    }
}

fn run_label(cfg: &ExperimentConfig) -> String {
    let ee = if cfg.no_early_exit { "No EE" } else { "MDI-Exit" };
    let mode = match cfg.mode {
        Mode::MdiExit => ee.to_string(),
        Mode::Ddi => "DDI".to_string(),
    };
    format!("{}, {}", cfg.topology, mode)
}

/// Convenience: run one experiment on the oracle engine using manifest
/// metadata (what benches and the CLI call).
pub fn run_from_artifacts(
    cfg: ExperimentConfig,
    manifest: &crate::artifact::Manifest,
) -> Result<RunReport> {
    let info = manifest.model(&cfg.model)?;
    let meta = ModelMeta::from_manifest(info);
    let engine =
        crate::runtime::sim_engine::SimEngine::load(manifest, &cfg.model, cfg.use_ae)?;
    let ds = crate::dataset::Dataset::load(manifest.path(&manifest.dataset.file))?;
    let store = SampleStore { labels: &ds.labels, images: None };
    Simulation::new(cfg, &engine, meta, store)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::ExitTable;
    use crate::runtime::sim_engine::SimEngine;

    /// 8 samples x 2 exits: even samples are confident at exit 1 (correct),
    /// odd samples only at exit 2.
    fn engine_2stage() -> (SimEngine, Vec<u8>) {
        let n = 8;
        let mut conf = Vec::new();
        let mut pred = Vec::new();
        let labels: Vec<u8> = (0..n as u8).map(|i| i % 10).collect();
        for i in 0..n {
            if i % 2 == 0 {
                conf.extend([0.97f32, 0.99]);
                pred.extend([labels[i], labels[i]]);
            } else {
                conf.extend([0.30f32, 0.95]);
                pred.extend([9 - labels[i], labels[i]]); // exit1 wrong
            }
        }
        (SimEngine::from_table(ExitTable::synthetic(n, 2, conf, pred), false), labels)
    }

    fn base_cfg(topology: &str) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::new(
            "tiny",
            topology,
            AdmissionMode::Fixed { rate_hz: 50.0, threshold: 0.9 },
        );
        cfg.duration_s = 20.0;
        cfg.warmup_s = 2.0;
        cfg
    }

    fn meta_2stage() -> ModelMeta {
        ModelMeta::synthetic(vec![0.002, 0.003], vec![12288, 8192])
    }

    #[test]
    fn local_early_exit_splits_by_confidence() {
        let (engine, labels) = engine_2stage();
        let cfg = base_cfg("local");
        let store = SampleStore { labels: &labels, images: None };
        let r = Simulation::new(cfg, &engine, meta_2stage(), store).unwrap().run().unwrap();
        assert!(r.completed > 500, "completed {}", r.completed);
        // Half the stream exits at 1 (conf .97 > .9), half at 2.
        let f = r.exit_fractions();
        assert!((f[0] - 0.5).abs() < 0.05, "exit fractions {f:?}");
        // exit-1 samples correct, exit-2 samples correct => accuracy 1.0
        assert!((r.accuracy() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn no_early_exit_only_final() {
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("local");
        cfg.no_early_exit = true;
        let store = SampleStore { labels: &labels, images: None };
        let r = Simulation::new(cfg, &engine, meta_2stage(), store).unwrap().run().unwrap();
        let f = r.exit_fractions();
        assert_eq!(f[0], 0.0, "no task may exit early: {f:?}");
        assert!(r.completed > 0);
    }

    #[test]
    fn distributed_offloads_and_completes() {
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        // overload one node so offloading must kick in
        cfg.admission = AdmissionMode::Fixed { rate_hz: 300.0, threshold: 0.9 };
        let store = SampleStore { labels: &labels, images: None };
        let r = Simulation::new(cfg, &engine, meta_2stage(), store).unwrap().run().unwrap();
        assert!(r.task_transfers > 0, "expected offloading");
        assert!(r.completed > 1000, "completed {}", r.completed);
        assert!((r.accuracy() - 1.0).abs() < 1e-9);
        // workers 1 and 2 did real work
        assert!(r.per_worker[1].processed + r.per_worker[2].processed > 0);
    }

    #[test]
    fn adaptive_rate_tracks_capacity() {
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("local");
        cfg.admission = AdmissionMode::AdaptiveRate { threshold: 0.9, initial_mu_s: 1.0 };
        cfg.duration_s = 120.0;
        cfg.warmup_s = 30.0;
        let store = SampleStore { labels: &labels, images: None };
        let r = Simulation::new(cfg, &engine, meta_2stage(), store).unwrap().run().unwrap();
        // capacity: mean cost/sample = 0.002 + 0.5*0.003 = 3.5ms → ~285 Hz.
        // Alg. 3 should push the admitted rate into the right decade and
        // the system should complete most of what it admits.
        let rate = r.admitted_rate_hz();
        assert!(rate > 100.0, "admitted rate {rate} too low");
        assert!(
            r.completed as f64 >= 0.7 * r.admitted as f64,
            "completed {} vs admitted {}",
            r.completed,
            r.admitted
        );
    }

    #[test]
    fn adaptive_threshold_degrades_under_load() {
        let (engine, labels) = engine_2stage();
        // Rate far beyond capacity: Alg. 4 must lower T_e toward the floor.
        let mut cfg = base_cfg("local");
        cfg.admission =
            AdmissionMode::AdaptiveThreshold { rate_hz: 2000.0, initial_t_e: 0.99, t_e_min: 0.05 };
        cfg.duration_s = 60.0;
        let store = SampleStore { labels: &labels, images: None };
        let r = Simulation::new(cfg, &engine, meta_2stage(), store).unwrap().run().unwrap();
        let t_e = r.final_t_e.unwrap();
        assert!(t_e < 0.5, "threshold should fall under overload, got {t_e}");
        // with low T_e nearly everything exits at 1
        let f = r.exit_fractions();
        assert!(f[0] > 0.8, "exit fractions {f:?}");
    }

    #[test]
    fn churn_rehomes_tasks() {
        use crate::simnet::ChurnEvent;
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("2-node");
        // far beyond the 2-node capacity (~330 Hz for these costs) so the
        // leaving worker is guaranteed to hold queued tasks at churn time
        cfg.admission = AdmissionMode::Fixed { rate_hz: 900.0, threshold: 0.9 };
        cfg.duration_s = 30.0;
        cfg.churn = vec![ChurnEvent { at_s: 10.0, worker: 1, join: false }];
        let store = SampleStore { labels: &labels, images: None };
        let meta = meta_2stage();
        let r = Simulation::new(cfg, &engine, meta, store).unwrap().run().unwrap();
        assert!(r.completed > 0);
        // After the leave, in-flight/queued tasks re-home instead of vanishing.
        assert!(r.rehomed > 0, "expected rehomed tasks on churn");
    }

    #[test]
    fn ddi_mode_uses_whole_model_and_final_exit() {
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        cfg.mode = Mode::Ddi;
        cfg.admission = AdmissionMode::Fixed { rate_hz: 100.0, threshold: 0.9 };
        let store = SampleStore { labels: &labels, images: None };
        let r = Simulation::new(cfg, &engine, meta_2stage(), store).unwrap().run().unwrap();
        let f = r.exit_fractions();
        assert_eq!(f[0], 0.0, "DDI never exits early: {f:?}");
        assert!(r.completed > 0);
        // whole images travel: bytes include 12 KiB payloads
        assert!(r.bytes_on_wire > 0);
    }

    #[test]
    fn conservation_no_task_loss() {
        // Every admitted sample (before a settling margin) must eventually
        // produce exactly one result: count with a long drain window.
        let (engine, labels) = engine_2stage();
        let mut cfg = base_cfg("3-node-mesh");
        cfg.admission = AdmissionMode::Fixed { rate_hz: 100.0, threshold: 0.9 };
        cfg.duration_s = 40.0;
        cfg.warmup_s = 0.0;
        let store = SampleStore { labels: &labels, images: None };
        let r = Simulation::new(cfg, &engine, meta_2stage(), store).unwrap().run().unwrap();
        // Under-loaded (100 Hz vs ~285 Hz capacity): everything admitted
        // except the in-flight tail must complete.
        assert!(
            r.admitted - r.completed < 20,
            "admitted {} completed {}",
            r.admitted,
            r.completed
        );
    }

    #[test]
    fn rejects_bad_construction() {
        let (engine, labels) = engine_2stage();
        let cfg = base_cfg("not-a-topology");
        let store = SampleStore { labels: &labels, images: None };
        assert!(Simulation::new(cfg, &engine, meta_2stage(), store).is_err());

        let mut cfg = base_cfg("local");
        cfg.use_ae = true; // meta has no AE
        let store = SampleStore { labels: &labels, images: None };
        assert!(Simulation::new(cfg, &engine, meta_2stage(), store).is_err());

        let cfg = base_cfg("local");
        let store = SampleStore { labels: &[], images: None };
        assert!(Simulation::new(cfg, &engine, meta_2stage(), store).is_err());
    }
}
