//! The clock-agnostic worker core — one state machine for both drivers.
//!
//! [`WorkerCore`] owns everything a worker *decides with*: the I_n/O_n
//! queue pair, the Γ_n/D_nm EWMA estimators, gossiped [`NeighborSummary`]s,
//! the Alg. 3/4 adaptation policy (source only), and the per-worker stats.
//! The decisions themselves are delegated to three boxed, config-selected
//! [`crate::policy`] objects — [`ExitPolicy`] (Alg. 1), [`OffloadPolicy`]
//! (Alg. 2 and its deadline-aware / multi-hop generalizations), and
//! [`AdaptPolicy`] (Algs 3/4) — so policy variants land in `crate::policy`
//! without touching this core. It is
//! driven by explicit events (`on_task`, `on_result`, `on_gossip`,
//! `on_compute_done`, `on_adapt_tick`, `on_churn`, `poll_admission`) and
//! answers with [`Action`]s — *what* should happen, never *how*:
//!
//! * `Send { to, env }` — put a typed [`Envelope`] on the wire (`to` is
//!   always a one-hop neighbor; multi-hop destinations are reached by
//!   forwarding along the run's [`crate::routing::RoutingTable`]).
//!   Batches are first-class: a same-stage run of tasks travels as ONE
//!   `TaskBatch` envelope when the run's
//!   [`crate::sched::SchedConfig::coalesce`] mode allows it, and the core
//!   counts every envelope into the per-worker wire counters
//!   (`wire_bytes`, `envelopes_sent`, `coalesced_tasks`,
//!   `wire_bytes_saved`) using the same
//!   [`crate::net::Envelope::encoded_bytes`] charge the drivers put on
//!   the medium;
//! * `StartCompute { batch, est_cost_s }` — run a same-stage batch of
//!   tasks through the engine (one batched forward per stage; batch size 1
//!   unless [`crate::sched::BatchPolicy`] says otherwise);
//! * `RecordResult { result }` — source-side accounting of a completed
//!   inference.
//!
//! Queue *order* is a policy: both queues sit behind boxed
//! [`crate::sched::QueueDiscipline`]s chosen by the run's
//! [`crate::sched::SchedConfig`] (FIFO, strict priority across traffic
//! classes, or EDF), and admission stamps each task's class and deadline.
//!
//! *Where* data enters and *where* results land is a policy too: the
//! run's [`crate::routing::Placement`] declares one or many source nodes,
//! each core derives its [`crate::routing::Role`] and next-hop row from
//! it, and every result / re-homed task / gossip-adopted T_e travels hop
//! by hop toward the admitting source — on any topology, on both drivers.
//!
//! The discrete-event driver ([`super::sim`]) maps these onto its
//! virtual-time heap; the realtime driver (`super::rt`) maps them onto
//! `DelayNet` sends and wallclock engine calls. Neither contains any
//! admission/gossip/exit/offload logic of its own, so every policy change
//! lands once. The core never reads time: drivers sample their [`Clock`]
//! and pass `now` into each event.

use super::config::{AdmissionMode, ExperimentConfig, Mode};
use super::queues::WorkerQueues;
use super::report::WorkerStats;
use super::task::{InferenceResult, Task};
use crate::artifact::ModelInfo;
use crate::cluster::{
    retire_candidate, spawn_candidate, Autoscaler, HealthChecker, ScaleDecision,
    ScaleDirection, ScaleReason, ScoreWeights,
};
use crate::net::Envelope;
use crate::policy::{
    AdaptPolicy, ExitCtx, ExitDecision, ExitPolicy, LocalState, NeighborSummary, OffloadCtx,
    OffloadPolicy,
};
use crate::net::ENVELOPE_HEADER_BYTES;
use crate::routing::{Role, RoutingTable};
use crate::runtime::{InferenceEngine, StageOutput};
use crate::sched::{CoalesceMode, QueueDiscipline};
use crate::simnet::Topology;
use crate::telemetry::{CoreSample, DropReason, Recorder, TelemetryEvent};
use crate::tensor::Tensor;
use crate::util::rng::{streams, Pcg64};
use crate::util::stats::Ewma;
use crate::workload::ArrivalModel;

// The wire layer owns all message sizing; re-exported here so existing
// `worker::RESULT_BYTES` call sites keep reading naturally.
pub use crate::net::RESULT_BYTES;

// The clock abstraction lives in `super::clock` (the one coordinator
// module allowed to touch `Instant` besides the realtime driver — the
// `clock-purity` lint enforces it); re-exported so `worker::Clock` call
// sites keep reading naturally.
pub use super::clock::{Clock, VirtualClock, WallClock};

// ---------------------------------------------------------------------------
// Model metadata
// ---------------------------------------------------------------------------

/// Compute/transfer metadata distilled from the manifest, so the decision
/// core and the DES inner loop never touch JSON or paths.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub stage_cost_s: Vec<f64>,
    pub stage_in_bytes: Vec<usize>,
    pub num_stages: usize,
    pub ae: Option<AeMeta>,
}

#[derive(Debug, Clone)]
pub struct AeMeta {
    pub enc_cost_s: f64,
    pub dec_cost_s: f64,
    pub code_bytes: usize,
}

impl ModelMeta {
    pub fn from_manifest(info: &ModelInfo) -> ModelMeta {
        ModelMeta {
            stage_cost_s: info.stages.iter().map(|s| s.cost_ms / 1e3).collect(),
            stage_in_bytes: info.stages.iter().map(|s| s.in_bytes).collect(),
            num_stages: info.num_stages,
            ae: info.ae.as_ref().map(|ae| AeMeta {
                enc_cost_s: ae.enc_cost_ms / 1e3,
                dec_cost_s: ae.dec_cost_ms / 1e3,
                code_bytes: ae.code_bytes,
            }),
        }
    }

    /// Synthetic metadata for engine-free unit tests.
    pub fn synthetic(stage_cost_s: Vec<f64>, stage_in_bytes: Vec<usize>) -> ModelMeta {
        let n = stage_cost_s.len();
        assert_eq!(n, stage_in_bytes.len());
        ModelMeta { stage_cost_s, stage_in_bytes, num_stages: n, ae: None }
    }

    pub fn total_cost_s(&self) -> f64 {
        self.stage_cost_s.iter().sum()
    }
}

// ---------------------------------------------------------------------------
// Events in, actions out
// ---------------------------------------------------------------------------

/// What a driver must make happen in its medium (virtual or real).
#[derive(Debug)]
pub enum Action {
    /// Transmit `env` to one-hop neighbor `to`. The driver obtains the
    /// wire charge from [`Envelope::encoded_bytes`] *after* any
    /// autoencoder step. `needs_encode` asks the driver to run the
    /// autoencoder on every feature tensor in a `TaskBatch` before the
    /// wire (the core already marked the tasks `encoded`; a failed encode
    /// flips a task back to raw and the shared charge function then
    /// prices the raw tensor).
    Send { to: usize, env: Envelope, needs_encode: bool },
    /// Run a same-stage batch of tasks through the engine (one batched
    /// forward per stage; see [`execute_batch`]). `est_cost_s` is the
    /// core's virtual cost estimate for the whole batch (amortized stage
    /// cost + AE decodes, ×noise, ÷speed) — the DES driver charges it as
    /// the compute delay; the realtime driver ignores it and measures real
    /// elapsed time. The batch is never empty.
    StartCompute { batch: Vec<Task>, est_cost_s: f64 },
    /// A completed inference reached its admitting source: record it.
    RecordResult { result: InferenceResult },
    /// The elastic control plane ordered a fleet change (controller node
    /// only — see [`crate::cluster`]). The driver applies it through the
    /// shared churn path (`on_churn` on every core, so a retiring worker
    /// re-homes its backlog), then re-layers: rebuild the routing table
    /// over the active fleet and hand every core its new next-hop row and
    /// role via [`WorkerCore::apply_relayout`].
    Scale(ScaleDecision),
}

/// One outbound consequence of a finished batch element, kept in batch
/// order so the wire sees exits and churn-displaced successors in exactly
/// the sequence the elements completed (at `coalesce = off` this
/// reproduces the seed's per-element emit order — and its RNG-draw order
/// in the DES driver — bit for bit).
#[derive(Debug)]
enum Outbound {
    Exit(InferenceResult),
    Displaced(Task),
}

/// How a task arrived at [`WorkerCore::on_task`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskOrigin {
    /// Freshly admitted at this worker (source only).
    Admitted,
    /// Delivered over the wire from a neighbor.
    Wire,
    /// Re-homed to the source after a worker left.
    Rehomed,
}

// ---------------------------------------------------------------------------
// Elastic control plane (per-core runtime state)
// ---------------------------------------------------------------------------

/// Control-plane state carried by every core when `cfg.cluster.enabled`
/// (absent otherwise — the default config builds none of this and stamps
/// no heartbeat, keeping the seed's wire accounting bit for bit).
struct ClusterState {
    /// Monotone heartbeat sequence, stamped into every minted summary.
    beat: u64,
    /// Controller-only loop state (the lowest-id source runs it).
    controller: Option<ControllerState>,
}

/// The closed loop the controller node runs each cluster tick: missed-beat
/// detection, composite scoring, and occupancy-driven scaling.
struct ControllerState {
    health: HealthChecker,
    scaler: Autoscaler,
    weights: ScoreWeights,
}

// ---------------------------------------------------------------------------
// The core
// ---------------------------------------------------------------------------

/// Per-worker decision state machine shared by both drivers.
pub struct WorkerCore {
    id: usize,
    cfg: ExperimentConfig,
    meta: ModelMeta,
    /// What the run's `Placement` makes of this worker: source or not,
    /// and which source it answers to.
    role: Role,
    /// `next_hop[dest]` — this node's row of the run's routing table
    /// (first hop of a shortest path; `None` = unreachable or self).
    next_hop: Vec<Option<usize>>,
    /// Admission pacing multiplier for this source (1.0 elsewhere).
    rate_share: f64,
    /// Effective compute speed (topology speed × cfg.compute_scale).
    speed: f64,
    neighbors: Vec<usize>,
    /// Mean link delay to each peer for a typical payload (default D_nm
    /// before the first measurement).
    link_default_delay: Vec<Option<f64>>,
    num_workers: usize,

    active: bool,
    peer_active: Vec<bool>,
    queues: WorkerQueues,
    /// A StartCompute is outstanding (cleared by `on_compute_done`).
    busy: bool,
    /// Per-task compute-delay estimate Γ_n (EWMA of measured durations).
    gamma: Ewma,
    /// What this worker believes about each peer (gossip + optimism).
    views: Vec<Option<NeighborSummary>>,
    /// Measured transfer-delay estimate D_nm per peer.
    d_est: Vec<Ewma>,
    rng: Pcg64,
    stats: WorkerStats,

    // Config-selected decision policies (`crate::policy`).
    exit_policy: Box<dyn ExitPolicy>,
    offload: Box<dyn OffloadPolicy>,
    /// Source-only Alg. 3/4 seam (inert `None` on other workers).
    adapt: Option<Box<dyn AdaptPolicy>>,
    /// Current early-exit threshold T_e (sources adapt it; others adopt
    /// their home source's value as it propagates hop by hop through
    /// gossip — Alg. 4 line 9, generalized to multi-hop graphs).
    t_e: f32,
    next_task_id: u64,
    next_sample: usize,
    num_samples: usize,
    ddi_next_target: usize,
    /// Round-robin traffic-class stamp for the next admission (source).
    next_class: u8,
    /// Per-class tasks lost to engine failures (`abort_compute`), merged
    /// with the disciplines' age-out drops in `into_stats`.
    failed_per_class: Vec<u64>,

    measure_from: f64,
    /// Scratch buffer for the resolved per-neighbor summaries handed to
    /// the offload policy (avoids a Vec allocation per offload attempt).
    cand_buf: Vec<(usize, NeighborSummary)>,

    /// Source-only arrival model from `cfg.workload` (`None` = legacy
    /// pacing, which reproduces seed timelines bit for bit). Stochastic
    /// models draw from their own per-source stream
    /// ([`streams::ARRIVAL_STREAM_BASE`]` + id`), never from
    /// `rng`, so enabling one perturbs no other draw order.
    arrival: Option<Box<dyn ArrivalModel>>,
    /// When each peer last received our summary by any means (dedicated
    /// `State` or piggyback). Only maintained when `cfg.gossip_piggyback`
    /// is on; used to suppress redundant gossip-tick sends.
    last_state_at: Vec<f64>,
    /// Elastic control plane (`None` unless `cfg.cluster.enabled`): every
    /// enabled node keeps a heartbeat counter; the controller node (the
    /// lowest-id source) additionally runs the health checker and the
    /// autoscaler and emits [`Action::Scale`] on its cluster ticks.
    cluster: Option<ClusterState>,
    /// Telemetry observer (`None` by default — the zero-cost-when-off
    /// contract: every hook is one `is_some()` branch, with event
    /// construction inside it). Installed by the drivers when the run's
    /// [`crate::telemetry::TelemetryConfig`] is enabled; must never feed
    /// decisions back into the core (see the `telemetry` module docs).
    recorder: Option<Box<dyn Recorder>>,
}

impl WorkerCore {
    /// Build worker `id`'s core. `num_samples` is only meaningful at
    /// sources (admission rotates through the sample store). Role and
    /// next hops derive from `cfg.placement` over the topology's routes.
    pub fn new(
        id: usize,
        cfg: &ExperimentConfig,
        meta: ModelMeta,
        topo: &Topology,
        num_samples: usize,
    ) -> WorkerCore {
        let routing = RoutingTable::build(topo);
        Self::with_routing(id, cfg, meta, topo, &routing, num_samples)
    }

    /// Like [`WorkerCore::new`], but with a pre-built routing table so a
    /// driver constructing `n` cores computes routes once instead of `n`
    /// times — the difference between O(n·E log n) and an O(n²·E log n)
    /// startup at metro scale.
    pub fn with_routing(
        id: usize,
        cfg: &ExperimentConfig,
        meta: ModelMeta,
        topo: &Topology,
        routing: &RoutingTable,
        num_samples: usize,
    ) -> WorkerCore {
        let n = topo.n;
        let role = Role::of(id, &cfg.placement, routing);
        let speed = topo.workers[id].speed * cfg.compute_scale;
        let neighbors = topo.neighbors(id);
        let typical = meta.stage_in_bytes[meta.num_stages.min(2) - 1];
        let link_default_delay =
            (0..n).map(|m| topo.link(id, m).map(|l| l.mean_delay_s(typical))).collect();
        let default_gamma = meta.total_cost_s() / meta.num_stages as f64;
        let mut gamma = Ewma::new(0.2);
        gamma.push(default_gamma / speed);

        let next_hop = routing.row(id);
        let exit_policy = cfg.policy.build_exit();
        let mut offload = cfg.policy.build_offload(id, n);
        if cfg.sched.coalesce == CoalesceMode::Adaptive {
            // Decorate the configured policy with the contention-driven
            // run-sizing seam; every offload decision still belongs to it.
            offload = Box::new(crate::policy::AdaptiveCoalesce::new(offload));
        }
        let adapt = if role.is_source {
            cfg.policy.build_adapt(&cfg.admission, cfg.adapt)
        } else {
            None
        };
        let t_e = match cfg.admission {
            AdmissionMode::AdaptiveRate { threshold, .. } => threshold,
            AdmissionMode::AdaptiveThreshold { initial_t_e, .. } => initial_t_e,
            AdmissionMode::Fixed { threshold, .. } => threshold,
        };
        let arrival =
            if role.is_source { cfg.workload.spec_for(id).build(cfg.seed, id) } else { None };
        let cluster = cfg.cluster.enabled.then(|| {
            // The controller is the lowest-id source: deterministic on any
            // placement, and a node every worker already routes results to.
            let is_controller =
                cfg.placement.source_nodes().iter().min() == Some(&id);
            ClusterState {
                beat: 0,
                controller: is_controller.then(|| ControllerState {
                    health: HealthChecker::new(
                        cfg.seed,
                        id,
                        cfg.gossip_interval_s,
                        cfg.cluster.timeout_beats,
                        cfg.cluster.jitter_frac,
                    ),
                    scaler: Autoscaler::new(&cfg.cluster),
                    weights: cfg.cluster.weights,
                }),
            }
        });

        WorkerCore {
            id,
            cfg: cfg.clone(),
            meta,
            role,
            next_hop,
            rate_share: cfg.placement.rate_share(id),
            speed,
            neighbors,
            link_default_delay,
            num_workers: n,
            active: true,
            peer_active: vec![true; n],
            queues: WorkerQueues::new(&cfg.sched, cfg.warmup_s),
            busy: false,
            gamma,
            views: vec![None; n],
            d_est: (0..n).map(|_| Ewma::new(0.2)).collect(),
            rng: Pcg64::new(cfg.seed, streams::WORKER_CORE_BASE + id as u64),
            stats: WorkerStats { offload_targets: vec![0; n], ..WorkerStats::default() },
            exit_policy,
            offload,
            adapt,
            t_e,
            next_task_id: 0,
            next_sample: 0,
            num_samples,
            ddi_next_target: 0,
            next_class: 0,
            failed_per_class: vec![0; cfg.sched.num_classes.max(1) as usize],
            measure_from: cfg.warmup_s,
            cand_buf: Vec::new(),
            arrival,
            cluster,
            last_state_at: vec![f64::NEG_INFINITY; n],
            recorder: None,
        }
    }

    // -- telemetry ----------------------------------------------------------

    /// Install a telemetry recorder (drivers, when the run traces).
    pub fn set_recorder(&mut self, recorder: Box<dyn Recorder>) {
        self.recorder = Some(recorder);
    }

    /// Remove and return the recorder (drivers, at end of run — call
    /// before `into_stats`).
    pub fn take_recorder(&mut self) -> Option<Box<dyn Recorder>> {
        self.recorder.take()
    }

    /// Whether a recorder is installed (drivers guard their own wire-hook
    /// event construction on this).
    pub fn has_recorder(&self) -> bool {
        self.recorder.is_some()
    }

    /// Forward a driver-constructed event (wire sends/receives, where
    /// only the driver knows the transfer delay) to the recorder.
    pub fn record_event(&mut self, ev: &TelemetryEvent) {
        if let Some(r) = self.recorder.as_deref_mut() {
            r.record(ev);
        }
    }

    /// Pure snapshot of this worker's gauges and cumulative counters.
    /// Shared read for the metrics registry AND the legacy source-only
    /// `TracePoint` timeline (`control`/`queue_total` here are exactly
    /// what `TracePoint.{control, source_queue}` report), so the seed
    /// trace stays bit-compatible while JSONL subsumes it.
    pub fn timeline_sample(&self, now: f64) -> CoreSample {
        CoreSample {
            t_s: now,
            worker: self.id,
            control: self.control_value(),
            t_e: self.t_e as f64,
            busy: self.busy,
            input_len: self.queues.input.len(),
            output_len: self.queues.output.len(),
            queue_total: self.queues.total_len(),
            class_depths: (0..self.cfg.sched.num_classes.max(1))
                .map(|c| self.queues.input.class_len(c))
                .collect(),
            processed: self.stats.processed,
            wire_bytes: self.stats.wire_bytes,
            envelopes_sent: self.stats.envelopes_sent,
        }
    }

    /// One metrics-cadence sample: snapshot the core and hand it to the
    /// recorder (no-op without one). Drivers call this on the run's
    /// `telemetry.interval_s` and once more at end of run, so the final
    /// row's cumulative counters equal the report aggregates.
    pub fn on_metrics_tick(&mut self, now: f64) {
        if self.recorder.is_none() {
            return;
        }
        let sample = self.timeline_sample(now);
        if let Some(r) = self.recorder.as_deref_mut() {
            r.record(&TelemetryEvent::MetricsTick(sample));
        }
    }

    // -- small accessors ----------------------------------------------------

    pub fn id(&self) -> usize {
        self.id
    }

    /// This worker's placement-derived role (source flag + home source).
    pub fn role(&self) -> Role {
        self.role
    }

    /// Whether this worker admits data (drivers use it to decide whether
    /// admission polling applies).
    pub fn is_source(&self) -> bool {
        self.role.is_source
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    pub fn speed(&self) -> f64 {
        self.speed
    }

    pub fn t_e(&self) -> f32 {
        self.t_e
    }

    pub fn input_len(&self) -> usize {
        self.queues.input.len()
    }

    pub fn output_len(&self) -> usize {
        self.queues.output.len()
    }

    /// Live input-queue occupancy of one traffic class (diagnostics; the
    /// per-class analogue of `input_len` for overload dashboards).
    pub fn input_class_len(&self, class: u8) -> usize {
        self.queues.input.class_len(class)
    }

    /// I_n + O_n — the occupancy signal Algs 3 and 4 consume.
    pub fn queue_total(&self) -> usize {
        self.queues.total_len()
    }

    /// Current controller value for traces: μ under Alg. 3, T_e otherwise.
    pub fn control_value(&self) -> f64 {
        self.adapt
            .as_ref()
            .and_then(|a| a.mu_s().or_else(|| a.t_e()))
            .unwrap_or(self.t_e as f64)
    }

    /// Whether this worker runs an Alg. 3/4 adaptation policy (drivers use
    /// it to decide if adaptation ticks need scheduling).
    pub fn has_controller(&self) -> bool {
        self.adapt.is_some()
    }

    pub fn final_mu_s(&self) -> Option<f64> {
        self.adapt.as_ref().and_then(|a| a.mu_s())
    }

    pub fn final_t_e(&self) -> Option<f64> {
        self.adapt.as_ref().and_then(|a| a.t_e())
    }

    /// Final per-worker stats (fills queue peaks, the service split, and
    /// the drop counters: discipline age-outs plus engine-failure losses).
    pub fn into_stats(mut self) -> WorkerStats {
        self.stats.peak_input = self.queues.input.peak();
        self.stats.peak_output = self.queues.output.peak();
        self.stats.served_per_class = self.queues.input.served_per_class().to_vec();
        let mut per_class = self.failed_per_class.clone();
        for q in [&self.queues.input, &self.queues.output] {
            for (c, &d) in q.dropped_per_class().iter().enumerate() {
                if let Some(slot) = per_class.get_mut(c) {
                    *slot += d;
                } else if let Some(last) = per_class.last_mut() {
                    *last += d; // out-of-range classes fold into the last
                }
            }
        }
        self.stats.dropped = per_class.iter().sum();
        self.stats.dropped_per_class = per_class;
        self.stats
    }

    fn in_window(&self, now: f64) -> bool {
        now >= self.measure_from
    }

    fn alloc_task_id(&mut self) -> u64 {
        self.next_task_id += 1;
        ((self.id as u64) << 48) | self.next_task_id
    }

    // -- admission (sources) -------------------------------------------------

    /// Sources only: admit the next sample. Returns the fresh task τ_1
    /// (features unset — the driver owns the sample store) with its
    /// admitting source, traffic class, and deadline stamped, and the
    /// delay until this source's next admission per the configured
    /// [`AdmissionMode`], scaled by the placement's per-source rate share.
    pub fn poll_admission(&mut self, now: f64) -> (Task, f64) {
        debug_assert!(self.role.is_source, "only sources admit data");
        let sample = self.next_sample;
        self.next_sample = (self.next_sample + 1) % self.num_samples.max(1);
        let id = self.alloc_task_id();
        let mut task = Task::initial(id, sample, None, now);
        task.source = self.id;
        task.class = self.next_class;
        task.deadline = now + self.cfg.sched.deadline_for(task.class);
        self.next_class = (self.next_class + 1) % self.cfg.sched.num_classes.max(1);
        if let Some(r) = self.recorder.as_deref_mut() {
            r.record(&TelemetryEvent::Admit {
                t: now,
                worker: self.id,
                task: id,
                class: task.class,
            });
        }
        let base_dt = match self.cfg.admission {
            AdmissionMode::AdaptiveRate { .. } => self
                .adapt
                .as_ref()
                .and_then(|a| a.mu_s())
                .expect("adaptive-rate source runs a rate-adapting policy"),
            AdmissionMode::AdaptiveThreshold { rate_hz, .. } => {
                if self.arrival.is_some() {
                    // The arrival model owns the stochasticity: hand it the
                    // mean gap and let it shape (and seed) the process.
                    1.0 / rate_hz
                } else {
                    // Legacy path — the exponential draw comes from the
                    // core's own stream, exactly as in the seed.
                    self.rng.exponential(1.0 / rate_hz)
                }
            }
            AdmissionMode::Fixed { rate_hz, .. } => 1.0 / rate_hz,
        };
        let dt = match self.arrival.as_mut() {
            Some(model) => model.next_dt(now, base_dt),
            None => base_dt,
        };
        (task, dt / self.rate_share)
    }

    // -- task arrival --------------------------------------------------------

    /// A task arrived: admitted locally, delivered over the wire, or
    /// re-homed. Queues it (or DDI-routes it at the source) and may start
    /// compute / offloading. Wire arrivals carrying several tasks go
    /// through [`WorkerCore::on_task_batch`]; this is the single-task
    /// entry (`on_task_batch` with one element behaves identically).
    pub fn on_task(&mut self, now: f64, task: Task, origin: TaskOrigin) -> Vec<Action> {
        if origin != TaskOrigin::Admitted {
            return self.on_task_batch(now, vec![task], origin);
        }
        let mut out = Vec::new();
        if self.cfg.mode == Mode::Ddi && self.role.is_source {
            // Round-robin whole images across all active workers
            // (including the source). No partitioning, no exits.
            let n = self.num_workers;
            let mut target = self.ddi_next_target % n;
            for _ in 0..n {
                let ok = if target == self.id {
                    self.active
                } else {
                    self.peer_active[target] && self.neighbors.contains(&target)
                };
                if ok {
                    break;
                }
                target = (target + 1) % n;
            }
            self.ddi_next_target = target + 1;
            if target != self.id {
                let mut task = task;
                task.hops += 1;
                if self.in_window(now) {
                    self.stats.offloaded_out += 1;
                    self.stats.offload_targets[target] += 1;
                }
                self.push_send(now, target, Envelope::TaskBatch(vec![task]), false, &mut out);
                return out;
            }
        }
        if let Some(r) = self.recorder.as_deref_mut() {
            r.record(&TelemetryEvent::Enqueue {
                t: now,
                worker: self.id,
                task: task.id,
                class: task.class,
                stage: task.stage,
            });
        }
        self.queues.input.push(task);
        if let Some(a) = self.maybe_start(now) {
            out.push(a);
        }
        out
    }

    /// One or more tasks arrived together — a wire `TaskBatch` envelope,
    /// or re-homed work reaching its source. Each task is merged through
    /// the input discipline's `push` in the envelope's (admission) order,
    /// so per-class queue accounting is exactly what k one-task arrivals
    /// would have produced; compute/offload scans run once for the whole
    /// batch.
    pub fn on_task_batch(&mut self, now: f64, tasks: Vec<Task>, origin: TaskOrigin) -> Vec<Action> {
        debug_assert!(origin != TaskOrigin::Admitted, "admission is one task at a time");
        let mut out = Vec::new();
        if origin == TaskOrigin::Wire && !self.active {
            // Arrived while this worker was gone: the fabric re-homes
            // everything to its admitting source (multi-hop if need be)
            // so no data is lost.
            self.rehome_all(now, tasks, &mut out);
            return out;
        }
        if origin == TaskOrigin::Wire && self.in_window(now) {
            self.stats.received += tasks.len() as u64;
        }
        for task in tasks {
            if let Some(r) = self.recorder.as_deref_mut() {
                r.record(&TelemetryEvent::Enqueue {
                    t: now,
                    worker: self.id,
                    task: task.id,
                    class: task.class,
                    stage: task.stage,
                });
            }
            self.queues.input.push(task);
        }
        if let Some(a) = self.maybe_start(now) {
            out.push(a);
        }
        if origin == TaskOrigin::Wire {
            self.try_offload(now, &mut out);
        }
        out
    }

    // -- compute -------------------------------------------------------------

    /// Pop the next same-stage batch off the input discipline and ask the
    /// driver to compute it, if idle. Batch size is 1 unless the run's
    /// [`crate::sched::BatchPolicy`] allows more; the batched stage cost
    /// amortizes per the policy's marginal-cost model.
    fn maybe_start(&mut self, now: f64) -> Option<Action> {
        if !self.active || self.busy || self.queues.input.is_empty() {
            return None;
        }
        let batch = self.cfg.sched.batch.form(self.queues.input.as_mut(), now);
        if batch.is_empty() {
            // A deadline-aware discipline aged out everything it held.
            return None;
        }
        let stage_cost = match self.cfg.mode {
            Mode::Ddi => self.meta.total_cost_s(),
            Mode::MdiExit => self.meta.stage_cost_s[batch[0].stage - 1],
        };
        let mut cost = self.cfg.sched.batch.batch_cost(stage_cost, batch.len());
        let dec_cost = self.meta.ae.as_ref().map(|ae| ae.dec_cost_s).unwrap_or(0.0);
        cost += dec_cost * batch.iter().filter(|t| t.encoded).count() as f64;
        // ±3% lognormal-ish execution noise (thermal/DVFS variability).
        // The telemetry hook sits AFTER this draw so recording never
        // perturbs the core's RNG stream (determinism contract).
        let noise = self.rng.normal(1.0, 0.03).clamp(0.7, 1.3);
        self.busy = true;
        if let Some(r) = self.recorder.as_deref_mut() {
            let k = batch.len();
            for t in &batch {
                r.record(&TelemetryEvent::ComputeStart {
                    t: now,
                    worker: self.id,
                    task: t.id,
                    class: t.class,
                    stage: t.stage,
                    batch: k,
                });
            }
        }
        Some(Action::StartCompute { batch, est_cost_s: cost * noise / self.speed })
    }

    /// The engine finished a batch: apply Alg. 1 to every element, then
    /// scan Alg. 2 and maybe start the next batch. `duration_s` is the
    /// measured (virtual or wall) compute time for the whole batch;
    /// `results` pairs each task's [`StageOutput`] with the exit point
    /// whose classifier ran, in batch order (see [`execute_batch`]).
    pub fn on_compute_done(
        &mut self,
        now: f64,
        batch: Vec<Task>,
        results: Vec<(StageOutput, usize)>,
        duration_s: f64,
    ) -> Vec<Action> {
        debug_assert_eq!(batch.len(), results.len(), "one result per batch element");
        self.busy = false;
        // Γ_n is a *per-task* compute-delay estimate (Alg. 2 compares it
        // against neighbor queues), so a batch feeds the amortized share.
        self.gamma.push(duration_s / batch.len().max(1) as f64);
        if self.in_window(now) {
            self.stats.processed += batch.len() as u64;
            self.stats.busy_s += duration_s;
        }

        let mut actions = Vec::new();
        // Exits and churn-displaced successors are collected in batch
        // order, then consecutive same-kind/same-source runs share an
        // envelope — a batch completion pays per *envelope*, not per
        // task, on every relay leg, while the wire still sees the
        // elements in completion order.
        let mut outbound: Vec<Outbound> = Vec::new();
        for (task, (out, exit_point)) in batch.into_iter().zip(results) {
            if let Some(r) = self.recorder.as_deref_mut() {
                r.record(&TelemetryEvent::ComputeEnd {
                    t: now,
                    worker: self.id,
                    task: task.id,
                    class: task.class,
                    stage: task.stage,
                });
            }
            let is_final = exit_point >= self.meta.num_stages || self.cfg.mode == Mode::Ddi;
            let threshold = if self.cfg.no_early_exit { f32::INFINITY } else { self.t_e };
            let decision = self.exit_policy.decide(&ExitCtx {
                confidence: out.confidence,
                threshold,
                is_final,
                input_len: self.queues.input.len(),
                output_len: self.queues.output.len(),
                t_o: self.cfg.t_o,
                now,
                class: task.class,
                deadline: task.deadline,
            });
            if let Some(r) = self.recorder.as_deref_mut() {
                r.record(&TelemetryEvent::ExitDecision {
                    t: now,
                    worker: self.id,
                    task: task.id,
                    class: task.class,
                    exit_point,
                    exited: decision == ExitDecision::Exit,
                });
            }
            match decision {
                ExitDecision::Exit => {
                    if self.in_window(now) {
                        self.stats.exits += 1;
                    }
                    outbound.push(Outbound::Exit(InferenceResult {
                        sample: task.sample,
                        exit_point,
                        prediction: out.prediction,
                        confidence: out.confidence,
                        admitted_at: task.admitted_at,
                        deadline: task.deadline,
                        exited_on: self.id,
                        source: task.source,
                        class: task.class,
                    }));
                }
                ExitDecision::ContinueLocal | ExitDecision::ContinueOffload => {
                    let id = self.alloc_task_id();
                    // Move (not clone) the feature tensor into the
                    // successor — this runs once per task-stage on the
                    // benchmarked hot path.
                    let succ = task.successor(id, out.features);
                    if !self.active {
                        // Completed while churned out: hand the successor
                        // back instead of stranding it on an inactive queue.
                        outbound.push(Outbound::Displaced(succ));
                    } else if decision == ExitDecision::ContinueLocal {
                        self.queues.input.push(succ);
                    } else {
                        self.queues.output.push(succ);
                    }
                }
            }
        }
        self.emit_outbound(now, outbound, &mut actions);

        self.try_offload(now, &mut actions);
        if let Some(a) = self.maybe_start(now) {
            actions.push(a);
        }
        actions
    }

    /// The driver could not run the engine (realtime engine error): clear
    /// the busy latch so the worker keeps draining its queue. The failed
    /// batch is dropped *with accounting* — re-homing it would retry a
    /// deterministically failing task forever (and `execute_batch` may
    /// already have consumed its feature tensors).
    pub fn abort_compute(&mut self, now: f64, failed: Vec<Task>) -> Vec<Action> {
        self.busy = false;
        if self.in_window(now) {
            let last = self.failed_per_class.len().saturating_sub(1);
            for t in &failed {
                self.failed_per_class[(t.class as usize).min(last)] += 1;
            }
        }
        if let Some(r) = self.recorder.as_deref_mut() {
            r.record(&TelemetryEvent::Drop {
                t: now,
                worker: self.id,
                task: failed.first().map(|t| t.id).unwrap_or(0),
                class: failed.first().map(|t| t.class).unwrap_or(0),
                count: failed.len(),
                reason: DropReason::EngineFailure,
            });
        }
        self.maybe_start(now).into_iter().collect()
    }

    // -- results and re-homes (multi-hop delivery) ---------------------------

    /// Items per coalesced result / re-home envelope: 1 under
    /// [`CoalesceMode::Off`] (the seed's one-message-per-item wire),
    /// otherwise the run's `coalesce_max`.
    fn coalesce_cap(&self) -> usize {
        match self.cfg.sched.coalesce {
            CoalesceMode::Off => 1,
            CoalesceMode::Stage | CoalesceMode::StageClass | CoalesceMode::Adaptive => {
                self.cfg.sched.coalesce_max.max(1)
            }
        }
    }

    /// Whether two items may share one coalesced envelope class-wise:
    /// under `stage-class` an envelope never mixes traffic classes (the
    /// mode's strict per-class isolation applies to results and re-homes
    /// too, not just task batches).
    fn same_envelope_class(&self, a: u8, b: u8) -> bool {
        self.cfg.sched.coalesce != CoalesceMode::StageClass || a == b
    }

    /// Emit a finished batch's outbound consequences in element order:
    /// local exits record in place, remote exits and displaced successors
    /// go one hop toward their source — consecutive same-kind runs headed
    /// to the same source (and class, under `stage-class`) share an
    /// envelope, a kind/source boundary flushes. With `coalesce = off`
    /// every item flushes immediately, reproducing the seed's per-element
    /// emit (and DES jitter-draw) order bit for bit.
    fn emit_outbound(&mut self, now: f64, items: Vec<Outbound>, out: &mut Vec<Action>) {
        let cap = self.coalesce_cap();
        let mut results: Vec<InferenceResult> = Vec::new();
        let mut rehomes: Vec<Task> = Vec::new();
        for item in items {
            match item {
                Outbound::Exit(r) => {
                    self.flush_rehomes(now, &mut rehomes, out);
                    if r.source == self.id {
                        self.flush_results(now, &mut results, out);
                        if let Some(rec) = self.recorder.as_deref_mut() {
                            rec.record(&TelemetryEvent::Complete {
                                t: now,
                                worker: self.id,
                                class: r.class,
                                exit_point: r.exit_point,
                                on_time: now <= r.deadline,
                                latency_s: now - r.admitted_at,
                            });
                        }
                        out.push(Action::RecordResult { result: r });
                    } else if results.last().is_some_and(|g| {
                        g.source == r.source && self.same_envelope_class(g.class, r.class)
                    }) && results.len() < cap
                    {
                        results.push(r);
                    } else {
                        self.flush_results(now, &mut results, out);
                        results.push(r);
                    }
                }
                Outbound::Displaced(t) => {
                    self.flush_results(now, &mut results, out);
                    if rehomes.last().is_some_and(|g| {
                        g.source == t.source && self.same_envelope_class(g.class, t.class)
                    }) && rehomes.len() < cap
                    {
                        rehomes.push(t);
                    } else {
                        self.flush_rehomes(now, &mut rehomes, out);
                        rehomes.push(t);
                    }
                }
            }
        }
        self.flush_results(now, &mut results, out);
        self.flush_rehomes(now, &mut rehomes, out);
    }

    /// Put each result where it belongs: record it if this worker is its
    /// admitting source, otherwise send it one hop closer — consecutive
    /// results headed to the same source (same class under `stage-class`)
    /// share one envelope (bounded by the coalesce cap). The routing
    /// table guarantees progress, so a result crosses at most n-1 links.
    fn deliver_results(
        &mut self,
        now: f64,
        results: Vec<InferenceResult>,
        out: &mut Vec<Action>,
    ) {
        let cap = self.coalesce_cap();
        let mut group: Vec<InferenceResult> = Vec::new();
        for r in results {
            if r.source == self.id {
                self.flush_results(now, &mut group, out);
                if let Some(rec) = self.recorder.as_deref_mut() {
                    rec.record(&TelemetryEvent::Complete {
                        t: now,
                        worker: self.id,
                        class: r.class,
                        exit_point: r.exit_point,
                        on_time: now <= r.deadline,
                        latency_s: now - r.admitted_at,
                    });
                }
                out.push(Action::RecordResult { result: r });
            } else if group.last().is_some_and(
                |g| g.source == r.source && self.same_envelope_class(g.class, r.class),
            ) && group.len() < cap
            {
                group.push(r);
            } else {
                self.flush_results(now, &mut group, out);
                group.push(r);
            }
        }
        self.flush_results(now, &mut group, out);
    }

    /// Send one same-source result group one hop closer to its source (or
    /// drop it *with accounting* when no route exists — only possible on
    /// a disconnected custom topology that placed work it cannot report).
    fn flush_results(
        &mut self,
        now: f64,
        group: &mut Vec<InferenceResult>,
        out: &mut Vec<Action>,
    ) {
        if group.is_empty() {
            return;
        }
        let results = std::mem::take(group);
        let source = results[0].source;
        match self.next_hop[source] {
            Some(hop) => {
                self.push_send(now, hop, Envelope::Result(results), false, out);
            }
            None => {
                if self.in_window(now) {
                    let last = self.failed_per_class.len().saturating_sub(1);
                    for r in &results {
                        self.failed_per_class[(r.class as usize).min(last)] += 1;
                    }
                }
                if let Some(rec) = self.recorder.as_deref_mut() {
                    rec.record(&TelemetryEvent::Drop {
                        t: now,
                        worker: self.id,
                        task: 0,
                        class: results.first().map(|r| r.class).unwrap_or(0),
                        count: results.len(),
                        reason: DropReason::NoRoute,
                    });
                }
                crate::log_debug!(
                    "worker {}: {} result(s) for unreachable source {} dropped",
                    self.id,
                    results.len(),
                    source
                );
            }
        }
    }

    /// A result envelope arrived (same-source by construction). Its
    /// admitting source records every item; every other worker relays the
    /// envelope one hop closer — one wire charge per leg, however many
    /// results ride it (this is what replaces the old DES-only
    /// "mis-delivered result" special case — relaying is a first-class,
    /// driver-agnostic behaviour).
    pub fn on_result(&mut self, now: f64, results: Vec<InferenceResult>) -> Vec<Action> {
        let mut out = Vec::new();
        let Some(first) = results.first() else {
            return out;
        };
        let forwards = first.source != self.id && self.next_hop[first.source].is_some();
        if forwards && self.in_window(now) {
            self.stats.relayed += 1;
        }
        self.deliver_results(now, results, &mut out);
        out
    }

    /// Route a same-source batch of displaced tasks back to its admitting
    /// source: one hop closer if remote, straight into the input queue if
    /// this worker *is* the source. The no-route fallback keeps the tasks
    /// queued locally rather than losing them (they replay when the
    /// worker rejoins).
    fn send_rehome_batch(&mut self, now: f64, tasks: Vec<Task>, out: &mut Vec<Action>) {
        let Some(first) = tasks.first() else {
            return;
        };
        debug_assert!(
            tasks.iter().all(|t| t.source == first.source),
            "re-home envelopes are same-source by construction"
        );
        let source = first.source;
        if source == self.id {
            for t in tasks {
                self.queues.input.push(t);
            }
            return;
        }
        match self.next_hop[source] {
            Some(hop) => self.push_send(now, hop, Envelope::Rehome(tasks), false, out),
            None => {
                for t in tasks {
                    self.queues.input.push(t);
                }
            }
        }
    }

    /// Send one pending same-source re-home group on its way (no-op when
    /// empty).
    fn flush_rehomes(&mut self, now: f64, group: &mut Vec<Task>, out: &mut Vec<Action>) {
        if !group.is_empty() {
            let flushed = std::mem::take(group);
            self.send_rehome_batch(now, flushed, out);
        }
    }

    /// Group a stream of displaced tasks (admission order, possibly mixed
    /// sources) into same-source (same-class under `stage-class`) re-home
    /// envelopes: consecutive matching tasks share an envelope, bounded
    /// by the coalesce cap — so a churned worker's backlog goes home one
    /// envelope per run instead of one message per task (`coalesce = off`
    /// keeps the seed's per-task wire).
    fn rehome_all(&mut self, now: f64, tasks: Vec<Task>, out: &mut Vec<Action>) {
        let cap = self.coalesce_cap();
        let mut group: Vec<Task> = Vec::new();
        for t in tasks {
            if group.last().is_some_and(
                |g| g.source == t.source && self.same_envelope_class(g.class, t.class),
            ) && group.len() < cap
            {
                group.push(t);
            } else {
                self.flush_rehomes(now, &mut group, out);
                group.push(t);
            }
        }
        self.flush_rehomes(now, &mut group, out);
    }

    /// A re-homing envelope arrived (same-source by construction): requeue
    /// everything if this worker is the admitting source, otherwise relay
    /// the envelope one hop closer. Relays happen even while churned out —
    /// the radio keeps forwarding; only *compute* stops (the fabric's
    /// no-data-loss guarantee).
    pub fn on_rehome(&mut self, now: f64, tasks: Vec<Task>) -> Vec<Action> {
        let Some(first) = tasks.first() else {
            return Vec::new();
        };
        if first.source == self.id {
            return self.on_task_batch(now, tasks, TaskOrigin::Rehomed);
        }
        if self.next_hop[first.source].is_some() && self.in_window(now) {
            self.stats.relayed += 1;
        }
        let mut out = Vec::new();
        self.send_rehome_batch(now, tasks, &mut out);
        out
    }

    /// The single choke point for outbound traffic: every envelope leaving
    /// this worker is charged here with the shared
    /// [`Envelope::encoded_bytes`] contract — the *same* number the
    /// drivers put on their medium — and folded into the per-worker wire
    /// counters (`wire_bytes`, `envelopes_sent`, `coalesced_tasks`,
    /// `wire_bytes_saved`).
    fn push_send(
        &mut self,
        now: f64,
        to: usize,
        env: Envelope,
        needs_encode: bool,
        out: &mut Vec<Action>,
    ) {
        let env = self.maybe_piggyback(now, to, env);
        if self.in_window(now) {
            let bytes = env.encoded_bytes(&self.meta);
            self.stats.wire_bytes += bytes as u64;
            if env.is_task_batch() {
                self.stats.envelopes_sent += 1;
            }
            let items = env.items();
            if items > 1 {
                self.stats.coalesced_tasks += (items - 1) as u64;
            }
            // Frame-sharing savings: batch coalescing (k−1 headers) plus a
            // piggybacked summary's shared header. Zero for plain
            // singletons, so the default path's accounting is unchanged.
            self.stats.wire_bytes_saved +=
                env.unbatched_bytes(&self.meta).saturating_sub(bytes) as u64;
        }
        out.push(Action::Send { to, env, needs_encode });
    }

    /// With `gossip_piggyback` on, ride a fresh [`NeighborSummary`] on a
    /// payload envelope already headed to `to` — the summary shares the
    /// payload's frame, so its marginal wire cost is its encoding minus
    /// one envelope header. `State` envelopes (already gossip) and
    /// already-wrapped envelopes pass through untouched.
    fn maybe_piggyback(&mut self, now: f64, to: usize, env: Envelope) -> Envelope {
        if !self.cfg.gossip_piggyback
            || matches!(env, Envelope::State(_) | Envelope::Piggybacked(..))
            || !self.active
            || !self.peer_active[to]
        {
            return env;
        }
        let summary = self.mint_summary(now);
        self.last_state_at[to] = now;
        if self.in_window(now) {
            self.stats.gossip_bytes +=
                summary.encoded_bytes().saturating_sub(ENVELOPE_HEADER_BYTES) as u64;
        }
        Envelope::Piggybacked(Box::new(env), summary)
    }

    // -- gossip --------------------------------------------------------------

    /// Periodic broadcast of this worker's state to its active neighbors.
    /// The summary carries the paper's base fields plus whatever the run's
    /// offload policy annotates; its *actual encoded size* is the wire
    /// charge on both drivers (virtual link delay under DES, realtime
    /// framing) and is counted into `gossip_bytes`.
    pub fn on_gossip_tick(&mut self, now: f64) -> Vec<Action> {
        if !self.active {
            return Vec::new();
        }
        let summary = self.mint_summary(now);
        let bytes = summary.encoded_bytes();
        let mut out = Vec::new();
        // Indexed loop (not `for &m in &self.neighbors`): the body needs
        // `&mut self` for `push_send` and the freshness stamps.
        let mut i = 0;
        while i < self.neighbors.len() {
            let m = self.neighbors[i];
            i += 1;
            if !self.peer_active[m] {
                continue;
            }
            if self.cfg.gossip_piggyback {
                // A summary already rode a payload to this peer within the
                // last half interval — skip the dedicated send. The half
                // margin keeps float rounding from starving the tick.
                if now - self.last_state_at[m] < 0.5 * self.cfg.gossip_interval_s {
                    continue;
                }
                self.last_state_at[m] = now;
            }
            if self.in_window(now) {
                self.stats.gossip_bytes += bytes as u64;
            }
            self.push_send(now, m, Envelope::State(summary.clone()), false, &mut out);
        }
        out
    }

    /// Mint this worker's current gossip summary: the paper's base fields
    /// plus whatever the run's offload policy annotates.
    fn mint_summary(&mut self, now: f64) -> NeighborSummary {
        let input_len = self.queues.input.len();
        let mut summary = NeighborSummary::base(input_len, self.gamma.get_or(0.01), self.t_e);
        if let Some(cl) = self.cluster.as_mut() {
            // Heartbeat: one fresh (strictly monotone) beat per minted
            // summary — piggybacked duplicates of an *old* summary can
            // never keep a dead sender alive at the checker.
            cl.beat += 1;
            summary.beat = Some(cl.beat);
        }
        self.offload.annotate(
            &mut summary,
            &LocalState {
                id: self.id,
                now,
                input_len,
                output_len: self.queues.output.len(),
                gamma_s: self.gamma.get_or(0.01),
                input: self.queues.input.as_ref(),
                num_classes: self.cfg.sched.num_classes,
            },
        );
        summary
    }

    /// A gossiped summary arrived from `from`: let the offload policy
    /// absorb its extension fields, refresh the view, and re-scan
    /// offloading (fresh views may unblock a stalled output queue).
    ///
    /// Threshold adoption (Alg. 4 line 9, "applies to every exit point")
    /// is multi-hop: a non-source adopts T_e from the neighbor that is its
    /// next hop toward its home source. That neighbor is strictly closer
    /// to the source and adopted the value the same way, so the adapted
    /// threshold ripples outward one gossip period per hop, with no echo
    /// loops — on a one-hop topology this degenerates to the paper's
    /// "adopt from the source" rule exactly.
    pub fn on_gossip(&mut self, now: f64, from: usize, summary: NeighborSummary) -> Vec<Action> {
        let mut summary = summary;
        summary.d_nm_s = self.d_est[from].get_or(self.link_default_delay[from].unwrap_or(0.01));
        if let Some(ctrl) = self.cluster.as_mut().and_then(|c| c.controller.as_mut()) {
            ctrl.health.observe(now, from, summary.beat);
        }
        self.offload.observe(from, &summary, now);
        if !self.role.is_source && self.next_hop[self.role.home_source] == Some(from) {
            self.t_e = summary.t_e;
        }
        self.views[from] = Some(summary);
        let mut out = Vec::new();
        self.try_offload(now, &mut out);
        out
    }

    // -- adaptation (source) --------------------------------------------------

    /// One Alg. 3/4 adaptation step from the source's queue occupancy. The
    /// driver schedules these every `cfg.adapt.sleep_s`.
    pub fn on_adapt_tick(&mut self, _now: f64) -> Vec<Action> {
        let q = self.queues.total_len();
        if let Some(a) = self.adapt.as_mut() {
            a.update(q);
            if let Some(t_e) = a.t_e() {
                self.t_e = t_e as f32;
            }
        }
        Vec::new()
    }

    // -- churn ---------------------------------------------------------------

    /// Worker `worker` joined/left at `now`. Every core sees every churn
    /// event: peers stop (or resume) being offload targets; the churned
    /// worker itself drains its queues back to the source.
    pub fn on_churn(&mut self, now: f64, worker: usize, join: bool) -> Vec<Action> {
        let mut out = Vec::new();
        if worker == self.id {
            self.active = join;
            if join {
                if let Some(a) = self.maybe_start(now) {
                    out.push(a);
                }
            } else {
                // Drain both queues in admission order so each source
                // replays its re-homed work deterministically (the drain
                // keeps peak/total_enqueued accounting intact — see
                // `QueueDiscipline::drain_all`). Every task routes to its
                // *own* admitting source via the next-hop table — a
                // mid-line worker's backlog travels multi-hop instead of
                // assuming the source is adjacent — and consecutive
                // same-source tasks share one re-home envelope when the
                // run coalesces.
                let drained = self.queues.drain_all_ordered();
                if let Some(r) = self.recorder.as_deref_mut() {
                    r.record(&TelemetryEvent::ChurnRehome {
                        t: now,
                        worker: self.id,
                        drained: drained.len(),
                    });
                }
                self.rehome_all(now, drained, &mut out);
            }
        } else {
            self.peer_active[worker] = join;
            if !join {
                self.views[worker] = None;
                self.offload.forget(worker);
                if let Some(ctrl) = self.cluster.as_mut().and_then(|c| c.controller.as_mut()) {
                    // The fleet retired this peer on purpose (scale-down or
                    // scripted churn): drop it from the missed-beat tracker
                    // so its silence is never read as a failure.
                    ctrl.health.forget(worker);
                }
            }
        }
        out
    }

    // -- elastic control plane (cluster ticks) -------------------------------

    /// Whether this core hosts the cluster controller loop — drivers
    /// schedule cluster ticks only where this is true (the lowest-id
    /// source, when `cfg.cluster.enabled`).
    pub fn runs_cluster_controller(&self) -> bool {
        self.cluster.as_ref().is_some_and(|c| c.controller.is_some())
    }

    /// One control-loop step on the controller node: sweep the health
    /// checker (failure-driven retirements bypass the cooldown but reset
    /// it) and, when the cooldown allows, make one load-driven scaling
    /// decision off aggregate occupancy — mean queued tasks per active
    /// worker over the gossip horizon (this node's own queues plus every
    /// active peer's gossiped input depth). Every decision leaves as an
    /// [`Action::Scale`]; the driver applies it through the shared churn +
    /// re-layer path. No-op on non-controller cores and while churned out.
    pub fn on_cluster_tick(&mut self, now: f64) -> Vec<Action> {
        let mut out = Vec::new();
        if !self.active {
            return out;
        }
        let Some(ctrl) = self.cluster.as_mut().and_then(|c| c.controller.as_mut()) else {
            return out;
        };
        // 1. Failure-driven retirement: peers newly past their (jittered)
        //    missed-beat deadline. Sources are never retired — admission
        //    must stay covered; a silent source is a topology problem the
        //    control plane cannot fix by unplugging it.
        let mut failed = false;
        for peer in ctrl.health.check(now) {
            if self.peer_active[peer] && !self.cfg.placement.is_source(peer) {
                failed = true;
                out.push(Action::Scale(ScaleDecision {
                    worker: peer,
                    join: false,
                    reason: ScaleReason::Failure,
                }));
            }
        }
        if failed {
            ctrl.scaler.note_failure(now);
        }
        // 2. Load-driven decision. Candidates are resolved first so the
        //    scaler only fires when a concrete target exists.
        let spawn = spawn_candidate(self.num_workers, |m| {
            m != self.id && !self.peer_active[m] && !self.cfg.placement.is_source(m)
        });
        let retire = retire_candidate(&ctrl.weights, &self.views, |m| {
            m != self.id
                && self.peer_active[m]
                && !self.cfg.placement.is_source(m)
                && !ctrl.health.is_dead(m)
        });
        let active_count = (0..self.num_workers)
            .filter(|&m| if m == self.id { self.active } else { self.peer_active[m] })
            .count();
        let mut queued = self.queues.total_len() as f64;
        for m in 0..self.num_workers {
            if m == self.id || !self.peer_active[m] {
                continue;
            }
            if let Some(v) = self.views[m].as_ref() {
                queued += v.input_len as f64;
            }
        }
        let occupancy = queued / active_count.max(1) as f64;
        let decision = ctrl.scaler.decide(
            now,
            occupancy,
            active_count,
            spawn.is_some(),
            retire.is_some(),
        );
        let target = match decision {
            Some(ScaleDirection::Up) => spawn.map(|m| (m, true)),
            Some(ScaleDirection::Down) => retire.map(|m| (m, false)),
            None => None,
        };
        if let Some((worker, join)) = target {
            out.push(Action::Scale(ScaleDecision { worker, join, reason: ScaleReason::Load }));
        }
        out
    }

    /// The fleet re-layered (a scale action or churn event was applied and
    /// the driver rebuilt routing over the active fleet): adopt the new
    /// next-hop row and placement role. In-flight tasks are untouched —
    /// they finish on the layout they started on, wherever they are
    /// queued; only traffic emitted after this call rides the new routes.
    pub fn apply_relayout(&mut self, next_hop: Vec<Option<usize>>, role: Role) {
        self.next_hop = next_hop;
        self.role = role;
    }

    // -- transfers -----------------------------------------------------------

    /// The driver measured (or sampled) the transfer delay of a send to
    /// `to`: feed the D_nm estimator.
    pub fn note_transfer_delay(&mut self, to: usize, delay_s: f64) {
        self.d_est[to].push(delay_s);
    }

    /// The driver's AE step shipped some payloads raw (encoder
    /// unavailable or failed), so the envelope left the worker larger
    /// than the code size counted at emit time: reconcile `wire_bytes`
    /// with the bytes actually charged to the medium. (`wire_bytes_saved`
    /// needs no correction — frame savings are payload-size-independent.)
    pub fn note_wire_recharge(&mut self, now: f64, extra_bytes: u64) {
        if self.in_window(now) {
            self.stats.wire_bytes += extra_bytes;
        }
    }

    /// The sender-side AE step both drivers run on a `needs_encode` send,
    /// in one place: batch-encode the envelope's tasks (one shared
    /// encoder forward — see [`encode_batch`]), then reconcile this
    /// worker's wire counter if a fallback shipped raw tensors (the core
    /// counted code bytes at emit time). Returns the number of encoder
    /// forward passes, which only the DES driver prices (`enc_cost_s`);
    /// non-task envelopes encode nothing and return 0.
    pub fn encode_for_wire(
        &mut self,
        engine: &dyn InferenceEngine,
        now: f64,
        env: &mut Envelope,
    ) -> usize {
        let pre = env.encoded_bytes(&self.meta);
        let forwards = match env.task_batch_mut() {
            Some(tasks) => encode_batch(engine, tasks),
            None => 0,
        };
        let post = env.encoded_bytes(&self.meta);
        if post > pre {
            self.note_wire_recharge(now, (post - pre) as u64);
        }
        forwards
    }

    /// Optimistic default for a peer never heard from (empty queue, fast
    /// compute, measured-or-default transfer delay).
    fn default_summary(&self, m: usize) -> NeighborSummary {
        let mut s = NeighborSummary::base(0, 0.01, self.t_e);
        s.d_nm_s = self.d_est[m].get_or(self.link_default_delay[m].unwrap_or(0.01));
        s
    }

    // -- offloading (the OffloadPolicy seam) -----------------------------------

    /// Offer the head-of-line output task to the run's offload policy,
    /// repeatedly, until it declines. When the policy accepts a target and
    /// the run coalesces ([`CoalesceMode`]), the whole same-stage (and,
    /// under `stage-class`, same-class) run behind the head — up to
    /// `coalesce_max` — is drained into ONE `TaskBatch` envelope, sorted
    /// into admission order for the receiver's discipline; the policy saw
    /// the coalescible run length up front via
    /// [`OffloadPolicy::choose_coalesced`]. Falls back to reclaiming the
    /// head task for local compute when starving (prevents livelock; the
    /// paper's Alg. 2 spins, which neither driver can afford).
    fn try_offload(&mut self, now: f64, out: &mut Vec<Action>) {
        let mut cand_ready = false;
        loop {
            if !self.active {
                return;
            }
            // Age out expired work first so the peeked head-of-line task
            // is the one a pop would actually serve.
            self.queues.output.expire(now);
            if self.queues.output.is_empty() {
                return;
            }
            // Resolve the freshest summary per active neighbor, in
            // canonical topology order (the policy owns any shuffling).
            // Once per call: across loop iterations the only view change
            // is our own optimistic bump, mirrored into the buffer below.
            // Retained slots are overwritten in place (`copy_from`), so
            // the benchmarked hot path stays allocation-free once the
            // buffer has grown to the neighbor count.
            if !cand_ready {
                let mut cand = std::mem::take(&mut self.cand_buf);
                let mut filled = 0;
                for &m in &self.neighbors {
                    if !self.peer_active[m] {
                        continue;
                    }
                    if filled < cand.len() {
                        cand[filled].0 = m;
                        match self.views[m].as_ref() {
                            Some(s) => cand[filled].1.copy_from(s),
                            None => {
                                let d = self.default_summary(m);
                                cand[filled].1.copy_from(&d);
                            }
                        }
                    } else {
                        let s = self.views[m]
                            .clone()
                            .unwrap_or_else(|| self.default_summary(m));
                        cand.push((m, s));
                    }
                    filled += 1;
                }
                cand.truncate(filled);
                self.cand_buf = cand;
                cand_ready = true;
            }

            // How many tasks one envelope to the chosen target would carry
            // (1 unless the run coalesces) — the policy weighs this run
            // length against slack/remote capacity before committing.
            let run_len = match self.cfg.sched.coalesce {
                CoalesceMode::Off => 1,
                mode => self
                    .queues
                    .output
                    .coalescible_run(
                        self.cfg.sched.coalesce_max,
                        mode == CoalesceMode::StageClass,
                    )
                    .max(1),
            };

            let chosen = {
                let task = self.queues.output.peek().expect("non-empty after expire");
                let ctx = OffloadCtx {
                    now,
                    task,
                    input_len: self.queues.input.len(),
                    output_len: self.queues.output.len(),
                    gamma_s: self.gamma.get_or(0.01),
                    candidates: &self.cand_buf,
                    next_hop: &self.next_hop,
                };
                match self.offload.choose_coalesced(&ctx, run_len, &mut self.rng) {
                    // The accepted target fixes the link; the sizing seam
                    // may now shrink the drained run (adaptive
                    // coalescing). Clamped: never longer than priced.
                    Some(m) => {
                        let take =
                            self.offload.coalesce_take(&ctx, m, run_len).clamp(1, run_len);
                        Some((m, take))
                    }
                    None => None,
                }
            };

            match chosen {
                Some((m, take)) => {
                    debug_assert!(
                        self.cand_buf.iter().any(|(c, _)| *c == m),
                        "policy chose {m}, not an active neighbor"
                    );
                    let head =
                        self.queues.output.pop_next(now).expect("peeked task still queued");
                    // AE boundary: encode before the wire (stage-2 inputs
                    // only, paper §V — only the first ResNet exit has an
                    // AE). Batches are same-stage, so the whole envelope
                    // shares the decision.
                    let needs_encode = self.cfg.use_ae
                        && head.stage == 2
                        && !head.encoded
                        && self.meta.ae.is_some();
                    let (stage, class) = (head.stage, head.class);
                    let mut batch = vec![head];
                    if self.cfg.sched.coalesce != CoalesceMode::Off {
                        // Drain the same-stage (same-class under
                        // stage-class) run behind the head into the same
                        // envelope — capped at `take`, the size the policy
                        // seam settled on (at most the `run_len` it
                        // priced; a conservative hint ships a shorter run,
                        // never a longer one). `expire` ran above, so
                        // peeks are truthful about what a pop returns.
                        while batch.len() < take {
                            let drain = self.queues.output.peek().is_some_and(|t| {
                                t.stage == stage
                                    && (!matches!(
                                        self.cfg.sched.coalesce,
                                        CoalesceMode::StageClass
                                    ) || t.class == class)
                            });
                            if !drain {
                                break;
                            }
                            let t = self
                                .queues
                                .output
                                .pop_next(now)
                                .expect("peeked task still queued");
                            batch.push(t);
                        }
                        // Receivers merge through their discipline in
                        // admission order (the net-layer batch contract).
                        batch.sort_by(Task::admission_cmp);
                    }
                    let k = batch.len();
                    for t in batch.iter_mut() {
                        if needs_encode {
                            t.encoded = true;
                        }
                        t.hops += 1;
                    }
                    if self.in_window(now) {
                        self.stats.offloaded_out += k as u64;
                        self.stats.offload_targets[m] += k as u64;
                    }
                    // Optimistic view update until the next gossip refresh
                    // (mirrored into the candidate buffer so the next loop
                    // iteration sees it without a rebuild; a never-gossiped
                    // default view is not bumped, exactly as before).
                    if let Some(v) = self.views[m].as_mut() {
                        v.input_len += k;
                        if let Some((_, s)) = self.cand_buf.iter_mut().find(|(c, _)| *c == m)
                        {
                            s.input_len += k;
                        }
                    }
                    self.push_send(now, m, Envelope::TaskBatch(batch), needs_encode, out);
                }
                None => {
                    // The policy kept the head-of-line task. If local
                    // compute is starving, reclaim it for the input queue.
                    if !self.busy && self.queues.input.is_empty() {
                        if let Some(t) = self.queues.output.pop_next(now) {
                            self.queues.input.push(t);
                            if let Some(a) = self.maybe_start(now) {
                                out.push(a);
                            }
                        }
                    }
                    return;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shared engine execution (driver-side helper)
// ---------------------------------------------------------------------------

/// Sender-side autoencoder step for an outgoing task batch, shared by
/// both drivers (`needs_encode` sends only). Every task the core marked
/// `encoded` rides **one** [`InferenceEngine::encode_batch`] forward —
/// the k same-stage tensors on a coalesced envelope share the encoder
/// pass instead of paying k per-tensor encodes. Per-item fallback is
/// unchanged: a tensor the engine declines (returns `None`, or the whole
/// call errors) ships raw and `encoded` flips back so the shared charge
/// function prices the raw tensor; on the oracle path (`features: None`)
/// encoding is virtual and the byte/cost accounting stands. Returns how
/// many encoder *forward passes* ran — 1 when anything (really or
/// virtually) encoded, else 0 — the count the DES driver charges
/// `enc_cost_s` for. (At batch size 1 this equals the old per-tensor
/// count, so un-coalesced runs are bit-for-bit unchanged.)
pub fn encode_batch(engine: &dyn InferenceEngine, tasks: &mut [Task]) -> usize {
    // Split the marked tasks: real tensors go through the batched
    // forward below; oracle-path tasks (no features) encode virtually.
    let mut virtual_cnt = 0usize;
    let mut real: Vec<(usize, Tensor)> = Vec::new();
    for (i, task) in tasks.iter_mut().enumerate() {
        if !task.encoded {
            continue;
        }
        match task.features.take() {
            Some(f) => real.push((i, f)),
            None => virtual_cnt += 1,
        }
    }
    let mut real_ok = 0usize;
    if !real.is_empty() {
        let refs: Vec<&Tensor> = real.iter().map(|(_, f)| f).collect();
        let codes = match engine.encode_batch(&refs) {
            // A whole-call error (or a length-confused engine) means no
            // tensor was coded: everyone ships raw.
            Ok(codes) if codes.len() == real.len() => codes,
            _ => vec![None; real.len()],
        };
        for ((i, f), code) in real.into_iter().zip(codes) {
            match code {
                Some(c) => {
                    tasks[i].features = Some(c);
                    real_ok += 1;
                }
                None => {
                    tasks[i].features = Some(f);
                    tasks[i].encoded = false;
                }
            }
        }
    }
    usize::from(virtual_cnt > 0 || real_ok > 0)
}

/// Run a same-stage batch through the engine the way both drivers must:
/// decode AE payloads first (per element), then either one batched forward
/// of stage τ_k (MDI-Exit) or the whole chain (DDI), via
/// [`InferenceEngine::run_stage_batch`] — one engine call per stage, not
/// one per task, which is what batching amortizes. Returns each element's
/// stage output paired with the exit point that fired, in batch order.
pub fn execute_batch(
    engine: &dyn InferenceEngine,
    mode: Mode,
    num_stages: usize,
    batch: &mut [Task],
) -> anyhow::Result<Vec<(StageOutput, usize)>> {
    anyhow::ensure!(!batch.is_empty(), "empty compute batch");
    for task in batch.iter_mut() {
        if task.encoded {
            if let Some(f) = task.features.take() {
                match engine.decode(&f)? {
                    Some(dec) => task.features = Some(dec),
                    None => task.features = Some(f),
                }
            }
            task.encoded = false;
        }
    }
    let samples: Vec<usize> = batch.iter().map(|t| t.sample).collect();
    match mode {
        Mode::Ddi => {
            // Whole model locally: chain every stage, exit at K.
            let mut feats: Vec<Option<Tensor>> =
                batch.iter_mut().map(|t| t.features.take()).collect();
            let mut outs: Option<Vec<StageOutput>> = None;
            for k in 1..=num_stages {
                let refs: Vec<Option<&Tensor>> = feats.iter().map(|f| f.as_ref()).collect();
                let o = engine.run_stage_batch(k, &samples, &refs)?;
                feats = o.iter().map(|s| s.features.clone()).collect();
                outs = Some(o);
            }
            let outs = outs.expect("model has at least one stage");
            Ok(outs.into_iter().map(|o| (o, num_stages)).collect())
        }
        Mode::MdiExit => {
            let stage = batch[0].stage;
            debug_assert!(
                batch.iter().all(|t| t.stage == stage),
                "compute batches are same-stage by construction"
            );
            let refs: Vec<Option<&Tensor>> =
                batch.iter().map(|t| t.features.as_ref()).collect();
            let outs = engine.run_stage_batch(stage, &samples, &refs)?;
            Ok(outs.into_iter().map(|o| (o, stage)).collect())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::LinkSpec;

    fn cfg_fixed(topology: &str, rate_hz: f64, threshold: f32) -> ExperimentConfig {
        ExperimentConfig::new("tiny", topology, AdmissionMode::Fixed { rate_hz, threshold })
    }

    fn meta2() -> ModelMeta {
        ModelMeta::synthetic(vec![0.002, 0.003], vec![12288, 8192])
    }

    fn topo(name: &str) -> Topology {
        Topology::named(name, LinkSpec::wifi()).unwrap()
    }

    fn core(id: usize, cfg: &ExperimentConfig, name: &str) -> WorkerCore {
        WorkerCore::new(id, cfg, meta2(), &topo(name), 8)
    }

    fn out(confidence: f32) -> StageOutput {
        StageOutput { features: None, confidence, prediction: 3 }
    }

    #[test]
    fn admission_rotates_samples_and_paces_fixed_rate() {
        let cfg = cfg_fixed("local", 50.0, 0.9);
        let mut w = core(0, &cfg, "local");
        let (t1, dt1) = w.poll_admission(0.0);
        let (t2, dt2) = w.poll_admission(dt1);
        assert_eq!(t1.sample, 0);
        assert_eq!(t2.sample, 1);
        assert_ne!(t1.id, t2.id);
        assert!((dt1 - 0.02).abs() < 1e-12);
        assert!((dt2 - 0.02).abs() < 1e-12);
    }

    #[test]
    fn admitted_task_starts_compute_with_stage_cost() {
        let cfg = cfg_fixed("local", 50.0, 0.9);
        let mut w = core(0, &cfg, "local");
        let (task, _) = w.poll_admission(0.0);
        let acts = w.on_task(0.0, task, TaskOrigin::Admitted);
        assert_eq!(acts.len(), 1);
        match &acts[0] {
            Action::StartCompute { batch, est_cost_s } => {
                assert_eq!(batch.len(), 1, "default policy is unbatched");
                assert_eq!(batch[0].stage, 1);
                // stage-1 cost 2 ms, ±3% noise, speed 1.0
                assert!((0.0012..0.0028).contains(est_cost_s), "est {est_cost_s}");
            }
            other => panic!("expected StartCompute, got {other:?}"),
        }
        // Busy: a second arrival queues instead of double-starting.
        let (t2, _) = w.poll_admission(0.1);
        let acts = w.on_task(0.1, t2, TaskOrigin::Admitted);
        assert!(acts.is_empty());
        assert_eq!(w.input_len(), 1);
    }

    #[test]
    fn confident_exit_records_at_source_and_sends_elsewhere() {
        let cfg = cfg_fixed("2-node", 50.0, 0.9);
        let mut src = core(0, &cfg, "2-node");
        let (task, _) = src.poll_admission(0.0);
        let started = src.on_task(0.0, task, TaskOrigin::Admitted);
        let Action::StartCompute { batch, .. } = started.into_iter().next().unwrap() else {
            panic!("no compute");
        };
        let acts = src.on_compute_done(0.01, batch, vec![(out(0.99), 1)], 0.002);
        assert!(matches!(acts[0], Action::RecordResult { .. }), "{acts:?}");

        let mut remote = core(1, &cfg, "2-node");
        let task = Task::initial(9, 0, None, 0.0);
        let started = remote.on_task(0.0, task, TaskOrigin::Wire);
        let Action::StartCompute { batch, .. } = started.into_iter().next().unwrap() else {
            panic!("no compute");
        };
        let acts = remote.on_compute_done(0.01, batch, vec![(out(0.99), 1)], 0.002);
        match &acts[0] {
            Action::Send { to: 0, env, .. } => {
                assert_eq!(env.encoded_bytes(&meta2()), RESULT_BYTES);
                match env {
                    Envelope::Result(rs) => {
                        assert_eq!(rs.len(), 1);
                        assert_eq!(rs[0].exited_on, 1);
                    }
                    other => panic!("expected a result envelope, got {other:?}"),
                }
            }
            other => panic!("expected result send, got {other:?}"),
        }
    }

    #[test]
    fn final_exit_fires_regardless_of_confidence() {
        let cfg = cfg_fixed("local", 50.0, 0.9);
        let mut w = core(0, &cfg, "local");
        let task = Task { stage: 2, ..Task::initial(1, 0, None, 0.0) };
        w.busy = true; // as if StartCompute had been issued
        let acts = w.on_compute_done(0.0, vec![task], vec![(out(0.01), 2)], 0.003);
        assert!(matches!(acts[0], Action::RecordResult { .. }));
    }

    #[test]
    fn low_confidence_with_busy_input_offloads_after_gossip() {
        let cfg = cfg_fixed("2-node", 50.0, 0.9);
        let mut w = core(0, &cfg, "2-node");
        // Two queued tasks keep the input non-empty so Alg. 1 picks the
        // output queue for the successor.
        for i in 0..3 {
            let (t, _) = w.poll_admission(i as f64 * 0.01);
            w.on_task(i as f64 * 0.01, t, TaskOrigin::Admitted);
        }
        let task = Task::initial(50, 0, None, 0.0);
        let acts = w.on_compute_done(0.05, vec![task], vec![(out(0.10), 1)], 0.002);
        // Successor went to the output queue; neighbor view is unknown so
        // the default (I_m = 0) applies: O_n = 1 > I_m = 0 opens the gate.
        let sent = acts.iter().any(|a| {
            matches!(a, Action::Send { to: 1, env: Envelope::TaskBatch(b), .. }
                     if b.len() == 1 && b[0].stage == 2)
        });
        assert!(sent, "expected a stage-2 task offload: {acts:?}");
    }

    #[test]
    fn gossip_gate_refuses_loaded_neighbors() {
        let cfg = cfg_fixed("2-node", 50.0, 0.9);
        let mut w = core(0, &cfg, "2-node");
        // Neighbor reports a long input queue: O_n = 1 <= I_m = 50 — the
        // Alg. 2 gate must stay closed.
        let _ = w.on_gossip(0.0, 1, NeighborSummary::base(50, 0.01, 0.9));
        for i in 0..3 {
            let (t, _) = w.poll_admission(i as f64 * 0.01);
            w.on_task(i as f64 * 0.01, t, TaskOrigin::Admitted);
        }
        let task = Task::initial(50, 0, None, 0.0);
        let acts = w.on_compute_done(0.05, vec![task], vec![(out(0.10), 1)], 0.002);
        let sent = acts
            .iter()
            .any(|a| matches!(a, Action::Send { env: Envelope::TaskBatch(_), .. }));
        assert!(!sent, "gate should refuse: {acts:?}");
        assert_eq!(w.output_len(), 1);
    }

    #[test]
    fn gossip_from_source_propagates_t_e() {
        let cfg = ExperimentConfig::new(
            "tiny",
            "2-node",
            AdmissionMode::AdaptiveThreshold { rate_hz: 10.0, initial_t_e: 0.9, t_e_min: 0.05 },
        );
        let mut w = WorkerCore::new(1, &cfg, meta2(), &topo("2-node"), 8);
        assert!((w.t_e() - 0.9).abs() < 1e-6);
        let _ = w.on_gossip(0.0, 0, NeighborSummary::base(0, 0.01, 0.42));
        assert!((w.t_e() - 0.42).abs() < 1e-6);
    }

    #[test]
    fn adapt_tick_moves_controllers() {
        let cfg = ExperimentConfig::new(
            "tiny",
            "local",
            AdmissionMode::AdaptiveRate { threshold: 0.9, initial_mu_s: 1.0 },
        );
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("local"), 8);
        let mu0 = w.control_value();
        let _ = w.on_adapt_tick(0.5); // empty queue: rate up, mu down
        assert!(w.control_value() < mu0);
        assert!(w.final_mu_s().is_some());
        assert!(w.final_t_e().is_none());
    }

    #[test]
    fn churn_leave_rehomes_queued_tasks_and_blocks_peers() {
        let cfg = cfg_fixed("2-node", 400.0, 0.9);
        let mut remote = core(1, &cfg, "2-node");
        for i in 0..4 {
            remote.on_task(0.0, Task::initial(i, 0, None, 0.0), TaskOrigin::Wire);
        }
        // One is computing; three are queued.
        assert_eq!(remote.input_len(), 3);
        let peak = 3; // three tasks were simultaneously queued
        let acts = remote.on_churn(1.0, 1, false);
        assert_eq!(acts.len(), 3);
        // Re-homing preserves admission order (ties broken by id here,
        // since every task was admitted at t=0) and travels the wire as a
        // routed Rehome payload toward the admitting source.
        let rehomed: Vec<u64> = acts
            .iter()
            .map(|a| match a {
                Action::Send { to: 0, env: Envelope::Rehome(ts), .. } => {
                    assert_eq!(ts.len(), 1, "coalesce = off keeps one task per envelope");
                    ts[0].id
                }
                other => panic!("expected routed Rehome send, got {other:?}"),
            })
            .collect();
        assert_eq!(rehomed, vec![1, 2, 3], "rehome must preserve arrival order");
        assert!(!remote.is_active());
        // Queue accounting survives the churn drain.
        let stats = remote.into_stats();
        assert_eq!(stats.peak_input, peak, "drain must not reset peak occupancy");
        let mut remote = core(1, &cfg, "2-node");
        for i in 0..4 {
            remote.on_task(0.0, Task::initial(i, 0, None, 0.0), TaskOrigin::Wire);
        }
        let _ = remote.on_churn(1.0, 1, false);
        // A late wire arrival also re-homes.
        let acts = remote.on_task(1.1, Task::initial(99, 0, None, 1.0), TaskOrigin::Wire);
        assert!(matches!(acts[0], Action::Send { to: 0, env: Envelope::Rehome(_), .. }));

        // The source hears about the leave and stops offloading to 1.
        let mut src = core(0, &cfg, "2-node");
        let _ = src.on_churn(1.0, 1, false);
        for i in 0..3 {
            let (t, _) = src.poll_admission(i as f64 * 0.001);
            src.on_task(i as f64 * 0.001, t, TaskOrigin::Admitted);
        }
        let task = Task::initial(50, 0, None, 0.0);
        let acts = src.on_compute_done(1.2, vec![task], vec![(out(0.1), 1)], 0.002);
        assert!(
            !acts
                .iter()
                .any(|a| matches!(a, Action::Send { env: Envelope::TaskBatch(_), .. })),
            "must not offload to a churned-out peer: {acts:?}"
        );
    }

    #[test]
    fn starving_worker_reclaims_output_head() {
        let cfg = cfg_fixed("local", 50.0, 0.9);
        let mut w = core(0, &cfg, "local");
        // Input empty, not busy, a task stuck in output with no neighbors:
        // the reclaim path must pull it back and start compute.
        let stuck = Task { stage: 2, ..Task::initial(1, 0, None, 0.0) };
        w.queues.output.push(stuck);
        let mut acts = Vec::new();
        w.try_offload(0.0, &mut acts);
        assert_eq!(w.output_len(), 0, "head-of-line task reclaimed");
        assert!(
            matches!(acts.as_slice(),
                     [Action::StartCompute { batch, .. }] if batch[0].stage == 2),
            "{acts:?}"
        );
    }

    #[test]
    fn gossip_tick_broadcasts_state_to_active_neighbors() {
        let cfg = cfg_fixed("3-node-mesh", 50.0, 0.9);
        let mut w = core(0, &cfg, "3-node-mesh");
        let acts = w.on_gossip_tick(0.0);
        assert_eq!(acts.len(), 2);
        for a in &acts {
            match a {
                Action::Send { env, .. } => {
                    // Baseline policies gossip only the paper's base
                    // fields: the charge is the seed's fixed 32 bytes,
                    // and the envelope charge IS the summary encoding.
                    let Envelope::State(s) = env else {
                        panic!("expected a state envelope, got {env:?}")
                    };
                    assert_eq!(env.encoded_bytes(&meta2()), s.encoded_bytes());
                    assert_eq!(s.encoded_bytes(), crate::policy::BASE_SUMMARY_BYTES);
                }
                other => panic!("expected state send, got {other:?}"),
            }
        }
        let _ = w.on_churn(0.0, 2, false);
        assert_eq!(w.on_gossip_tick(0.1).len(), 1);
    }

    #[test]
    fn gossip_bytes_are_charged_by_encoded_size() {
        // DeadlineAware annotates slack + per-class occupancy: the charge
        // must grow beyond the base 32 bytes and be counted per send.
        let mut cfg = cfg_fixed("3-node-mesh", 50.0, 0.9);
        cfg.warmup_s = 0.0;
        cfg.policy.offload = crate::policy::OffloadKind::DeadlineAware;
        cfg.sched = cfg.sched.with_classes(2);
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("3-node-mesh"), 8);
        let acts = w.on_gossip_tick(0.0);
        assert_eq!(acts.len(), 2);
        let per_msg = crate::policy::BASE_SUMMARY_BYTES + 2 * 4 + 8;
        for a in &acts {
            match a {
                Action::Send { env: Envelope::State(s), .. } => {
                    assert_eq!(s.encoded_bytes(), per_msg, "2 classes + slack on the wire");
                    assert_eq!(s.per_class_input.len(), 2);
                    assert!(s.min_slack_s.is_some());
                }
                other => panic!("expected state send, got {other:?}"),
            }
        }
        let stats = w.into_stats();
        assert_eq!(stats.gossip_bytes, (2 * per_msg) as u64);
    }

    #[test]
    fn ddi_source_round_robins_whole_images() {
        let mut cfg = cfg_fixed("3-node-mesh", 50.0, 0.9);
        cfg.mode = Mode::Ddi;
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("3-node-mesh"), 8);
        let mut targets = Vec::new();
        for i in 0..3 {
            let (t, _) = w.poll_admission(i as f64 * 0.02);
            let acts = w.on_task(i as f64 * 0.02, t, TaskOrigin::Admitted);
            match acts.first() {
                Some(Action::Send { to, env, .. }) => {
                    assert_eq!(
                        env.encoded_bytes(&meta2()),
                        12288,
                        "whole image on the wire"
                    );
                    targets.push(*to);
                }
                Some(Action::StartCompute { .. }) => targets.push(0),
                other => panic!("unexpected {other:?}"),
            }
        }
        targets.sort_unstable();
        assert_eq!(targets, vec![0, 1, 2], "round-robin covers all workers");
    }

    // -- scheduling subsystem through the core --------------------------------

    use crate::sched::{BatchPolicy, DisciplineKind};

    fn cfg_batched(max_batch: usize) -> ExperimentConfig {
        let mut cfg = cfg_fixed("local", 50.0, 0.9);
        cfg.sched.batch = BatchPolicy::batched(max_batch);
        cfg
    }

    #[test]
    fn admission_stamps_rotating_classes_and_deadlines() {
        let mut cfg = cfg_fixed("local", 50.0, 0.9);
        cfg.sched = cfg.sched.with_classes(3);
        cfg.sched.class_deadline_s = vec![0.1, 0.5, 2.0];
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("local"), 8);
        let (t0, _) = w.poll_admission(1.0);
        let (t1, _) = w.poll_admission(1.0);
        let (t2, _) = w.poll_admission(1.0);
        let (t3, _) = w.poll_admission(1.0);
        assert_eq!([t0.class, t1.class, t2.class, t3.class], [0, 1, 2, 0]);
        assert!((t0.deadline - 1.1).abs() < 1e-9);
        assert!((t1.deadline - 1.5).abs() < 1e-9);
        assert!((t2.deadline - 3.0).abs() < 1e-9);
    }

    #[test]
    fn queued_same_stage_tasks_start_as_one_batch() {
        let cfg = cfg_batched(4);
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("local"), 8);
        let (t, _) = w.poll_admission(0.0);
        let started = w.on_task(0.0, t, TaskOrigin::Admitted);
        let Action::StartCompute { batch, .. } = started.into_iter().next().unwrap() else {
            panic!("no compute");
        };
        assert_eq!(batch.len(), 1, "nothing else queued yet");
        // Three more stage-1 tasks arrive while busy.
        for i in 1..4 {
            let (t, _) = w.poll_admission(i as f64 * 0.01);
            assert!(w.on_task(i as f64 * 0.01, t, TaskOrigin::Admitted).is_empty());
        }
        assert_eq!(w.input_len(), 3);
        // Completing the head batch starts the rest as ONE batched forward
        // whose estimated cost is amortized (3 tasks ≪ 3x one-task cost).
        let acts = w.on_compute_done(0.05, batch, vec![(out(0.99), 1)], 0.002);
        let next = acts
            .iter()
            .find_map(|a| match a {
                Action::StartCompute { batch, est_cost_s } => Some((batch, *est_cost_s)),
                _ => None,
            })
            .expect("follow-up batch");
        assert_eq!(next.0.len(), 3, "same-stage run batched together");
        assert!(next.0.iter().all(|t| t.stage == 1));
        // stage-1 cost 2 ms: batch of 3 at marginal 0.25 => 1.5 x 2 ms,
        // ±3% noise — far below the 6 ms an unbatched trio would cost.
        assert!((0.0020..0.0045).contains(&next.1), "batched est {}", next.1);
        assert_eq!(w.input_len(), 0);
    }

    #[test]
    fn partial_batch_exits_split_between_results_and_successors() {
        let cfg = cfg_batched(4);
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("local"), 8);
        let batch: Vec<Task> = (0..3).map(|i| Task::initial(i, i as usize, None, 0.0)).collect();
        w.busy = true; // as if StartCompute had been issued for `batch`
        let results = vec![(out(0.99), 1), (out(0.10), 1), (out(0.95), 1)];
        let acts = w.on_compute_done(0.01, batch, results, 0.004);
        let exits =
            acts.iter().filter(|a| matches!(a, Action::RecordResult { .. })).count();
        assert_eq!(exits, 2, "confident elements exit: {acts:?}");
        // The low-confidence element continued to stage 2 (input was empty
        // at decision time so it stayed local) and is now computing.
        let started = acts.iter().any(|a| {
            matches!(a, Action::StartCompute { batch, .. }
                     if batch.len() == 1 && batch[0].stage == 2)
        });
        assert!(started, "successor continues at stage 2: {acts:?}");
    }

    #[test]
    fn mid_batch_churn_rehomes_continuing_elements() {
        let cfg = cfg_batched(4);
        let mut w = WorkerCore::new(1, &cfg, meta2(), &topo("2-node"), 8);
        let batch: Vec<Task> = (0..3).map(|i| Task::initial(i, i as usize, None, 0.0)).collect();
        w.busy = true;
        // The worker churns out while the batch is on the engine.
        let _ = w.on_churn(0.005, 1, false);
        assert!(!w.is_active());
        let results = vec![(out(0.99), 1), (out(0.10), 1), (out(0.20), 1)];
        let acts = w.on_compute_done(0.01, batch, results, 0.004);
        // The confident element still exits (the result is real work, sent
        // to the source); the continuing elements re-home instead of
        // stranding on an inactive queue.
        let sends = acts
            .iter()
            .filter(|a| matches!(a, Action::Send { env: Envelope::Result(_), .. }))
            .count();
        let rehomes = acts
            .iter()
            .filter(|a| matches!(a, Action::Send { env: Envelope::Rehome(_), .. }))
            .count();
        assert_eq!(sends, 1, "{acts:?}");
        assert_eq!(rehomes, 2, "{acts:?}");
        assert_eq!(w.input_len(), 0, "nothing queued on the inactive worker");
    }

    #[test]
    fn strict_priority_input_serves_class_zero_first() {
        let mut cfg = cfg_fixed("2-node", 50.0, 0.9);
        cfg.sched.discipline = DisciplineKind::StrictPriority;
        cfg.sched = cfg.sched.with_classes(2);
        let mut w = WorkerCore::new(1, &cfg, meta2(), &topo("2-node"), 8);
        w.busy = true; // hold the queue while traffic accumulates
        for (id, class) in [(1u64, 1u8), (2, 1), (3, 0)] {
            let t = Task { class, ..Task::initial(id, 0, None, 0.0) };
            assert!(w.on_task(0.0, t, TaskOrigin::Wire).is_empty());
        }
        assert_eq!(w.input_class_len(0), 1);
        assert_eq!(w.input_class_len(1), 2);
        let done = Task::initial(9, 0, None, 0.0);
        let acts = w.on_compute_done(0.01, vec![done], vec![(out(0.99), 1)], 0.002);
        let started = acts
            .iter()
            .find_map(|a| match a {
                Action::StartCompute { batch, .. } => Some(&batch[0]),
                _ => None,
            })
            .expect("next task starts");
        assert_eq!(started.class, 0, "class 0 jumps the two queued class-1 tasks");
        assert_eq!(started.id, 3);
    }

    #[test]
    fn edf_drop_late_counts_into_stats() {
        let mut cfg = cfg_fixed("local", 50.0, 0.9);
        cfg.warmup_s = 0.0; // drops are windowed like every other counter
        cfg.sched.discipline = DisciplineKind::Edf { drop_late: true };
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("local"), 8);
        w.busy = true;
        for id in 0..3 {
            let t = Task { deadline: 0.5, ..Task::initial(id, 0, None, 0.0) };
            assert!(w.on_task(0.0, t, TaskOrigin::Wire).is_empty());
        }
        // All three deadlines expired before the engine freed up: the pop
        // drains them as drops and nothing starts.
        let done = Task::initial(9, 0, None, 0.0);
        let acts = w.on_compute_done(1.0, vec![done], vec![(out(0.99), 1)], 0.002);
        assert!(
            !acts.iter().any(|a| matches!(a, Action::StartCompute { .. })),
            "{acts:?}"
        );
        let stats = w.into_stats();
        assert_eq!(stats.dropped, 3);
        assert_eq!(stats.dropped_per_class, vec![3]);
    }

    #[test]
    fn abort_compute_drops_failed_batch_with_accounting() {
        let mut cfg = cfg_batched(4);
        cfg.warmup_s = 0.0;
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("local"), 8);
        let batch: Vec<Task> = (0..3).map(|i| Task::initial(i, i as usize, None, 0.0)).collect();
        w.busy = true; // as if StartCompute had handed out `batch`
        let acts = w.abort_compute(0.01, batch);
        assert!(acts.is_empty(), "nothing queued to restart: {acts:?}");
        let stats = w.into_stats();
        assert_eq!(stats.dropped, 3, "failed batch is accounted, not lost silently");
        assert_eq!(stats.dropped_per_class, vec![3]);
    }

    // -- topology/routing API through the core --------------------------------

    use crate::routing::Placement;

    fn cfg_sources(topology: &str, sources: &[usize]) -> ExperimentConfig {
        let mut cfg = cfg_fixed(topology, 50.0, 0.9);
        cfg.warmup_s = 0.0;
        cfg.placement = Placement::multi(sources);
        cfg
    }

    #[test]
    fn placement_assigns_roles_and_stamps_admissions() {
        let cfg = cfg_sources("line-4", &[0, 3]);
        let w3 = WorkerCore::new(3, &cfg, meta2(), &topo("line-4"), 8);
        assert!(w3.is_source());
        assert_eq!(w3.role().home_source, 3);
        let w1 = WorkerCore::new(1, &cfg, meta2(), &topo("line-4"), 8);
        assert!(!w1.is_source());
        assert_eq!(w1.role().home_source, 0, "worker 1 is nearest the left source");
        let w2 = WorkerCore::new(2, &cfg, meta2(), &topo("line-4"), 8);
        assert_eq!(w2.role().home_source, 3, "worker 2 is nearest the right source");

        let mut w3 = w3;
        let (task, _) = w3.poll_admission(0.0);
        assert_eq!(task.source, 3, "tasks carry their admitting source");
    }

    #[test]
    fn exits_route_results_hop_by_hop_to_their_source() {
        let cfg = cfg_sources("line-4", &[0]);
        // Worker 2 exits a task admitted at 0: the result's first hop is 1.
        let mut w2 = WorkerCore::new(2, &cfg, meta2(), &topo("line-4"), 8);
        let task = Task::initial(7, 0, None, 0.0);
        w2.busy = true;
        let acts = w2.on_compute_done(0.01, vec![task], vec![(out(0.99), 1)], 0.002);
        let Action::Send { to, env, .. } = &acts[0] else {
            panic!("expected routed result send, got {acts:?}");
        };
        assert_eq!(*to, 1);
        assert_eq!(env.encoded_bytes(&meta2()), RESULT_BYTES);
        let Envelope::Result(rs) = env else {
            panic!("expected a result envelope, got {env:?}");
        };
        assert_eq!(rs.len(), 1);
        let r = rs[0];
        assert_eq!(r.source, 0);
        assert_eq!(r.exited_on, 2);

        // Worker 1 relays it one hop closer; worker 0 records it.
        let mut w1 = WorkerCore::new(1, &cfg, meta2(), &topo("line-4"), 8);
        let acts = w1.on_result(0.02, vec![r]);
        assert!(
            matches!(&acts[0], Action::Send { to: 0, env: Envelope::Result(_), .. }),
            "{acts:?}"
        );
        assert_eq!(w1.into_stats().relayed, 1, "relays are counted");
        let mut w0 = WorkerCore::new(0, &cfg, meta2(), &topo("line-4"), 8);
        let acts = w0.on_result(0.03, vec![r]);
        assert!(matches!(acts[0], Action::RecordResult { .. }), "{acts:?}");
        assert_eq!(w0.into_stats().relayed, 0, "terminal delivery is not a relay");
    }

    #[test]
    fn churned_mid_line_worker_rehomes_via_next_hop() {
        let cfg = cfg_sources("line-4", &[0]);
        // Worker 3 (two hops from the source) holds queued work and leaves:
        // every task must head to neighbor 2, not assume source adjacency.
        let mut w3 = WorkerCore::new(3, &cfg, meta2(), &topo("line-4"), 8);
        for i in 0..3 {
            w3.on_task(0.0, Task::initial(i, 0, None, 0.0), TaskOrigin::Wire);
        }
        let acts = w3.on_churn(1.0, 3, false);
        assert_eq!(acts.len(), 2, "one computing, two queued: {acts:?}");
        for a in &acts {
            assert!(
                matches!(a, Action::Send { to: 2, env: Envelope::Rehome(ts), .. }
                         if ts.len() == 1 && ts[0].source == 0),
                "rehome must route via worker 2: {a:?}"
            );
        }

        // The relay leg: worker 1 forwards toward 0; the source requeues
        // and immediately starts computing.
        let mut w1 = WorkerCore::new(1, &cfg, meta2(), &topo("line-4"), 8);
        let acts = w1.on_rehome(1.1, vec![Task::initial(9, 0, None, 0.0)]);
        assert!(
            matches!(acts[0], Action::Send { to: 0, env: Envelope::Rehome(_), .. }),
            "{acts:?}"
        );
        let mut w0 = WorkerCore::new(0, &cfg, meta2(), &topo("line-4"), 8);
        let acts = w0.on_rehome(1.2, vec![Task::initial(9, 0, None, 0.0)]);
        assert!(matches!(acts[0], Action::StartCompute { .. }), "{acts:?}");
        assert_eq!(w0.into_stats().relayed, 0);
    }

    #[test]
    fn t_e_adoption_follows_the_route_home() {
        let mut cfg = cfg_sources("line-4", &[0, 3]);
        cfg.admission =
            AdmissionMode::AdaptiveThreshold { rate_hz: 10.0, initial_t_e: 0.9, t_e_min: 0.05 };
        // Worker 2's home source is 3, so its next hop home *is* 3: gossip
        // from 1 (wrong direction) must not change T_e; gossip from 3 must.
        let mut w2 = WorkerCore::new(2, &cfg, meta2(), &topo("line-4"), 8);
        let _ = w2.on_gossip(0.0, 1, NeighborSummary::base(0, 0.01, 0.33));
        assert!((w2.t_e() - 0.9).abs() < 1e-6, "must not adopt from off-route gossip");
        let _ = w2.on_gossip(0.1, 3, NeighborSummary::base(0, 0.01, 0.42));
        assert!((w2.t_e() - 0.42).abs() < 1e-6, "adopts from the next hop home");

        // Sources keep their own controller's value.
        let mut w3 = WorkerCore::new(3, &cfg, meta2(), &topo("line-4"), 8);
        let _ = w3.on_gossip(0.0, 2, NeighborSummary::base(0, 0.01, 0.11));
        assert!((w3.t_e() - 0.9).abs() < 1e-6, "sources never adopt");
    }

    // -- cross-worker batch coalescing (the net::Envelope wire) ----------------

    /// Deterministic offload cfg: QueueOnly accepts whenever O_n > I_m
    /// (no RNG), warmup 0 so counters are live from t = 0.
    fn cfg_coalesce(mode: CoalesceMode) -> ExperimentConfig {
        let mut cfg = cfg_fixed("2-node", 50.0, 0.9);
        cfg.warmup_s = 0.0;
        cfg.policy.offload = crate::policy::OffloadKind::QueueOnly;
        cfg.sched.coalesce = mode;
        cfg.sched.coalesce_max = 8;
        cfg
    }

    fn stage2(id: u64, class: u8, admitted_at: f64) -> Task {
        Task { stage: 2, class, ..Task::initial(id, 0, None, admitted_at) }
    }

    #[test]
    fn coalesced_offload_drains_same_stage_run_into_one_envelope() {
        let cfg = cfg_coalesce(CoalesceMode::Stage);
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("2-node"), 8);
        w.busy = true; // keep the reclaim path out of the way
        for id in [2u64, 1, 3] {
            w.queues.output.push(stage2(id, 0, 0.0));
        }
        let mut acts = Vec::new();
        w.try_offload(0.0, &mut acts);
        assert_eq!(acts.len(), 1, "one envelope, not three: {acts:?}");
        match &acts[0] {
            Action::Send { to: 1, env, .. } => {
                let Envelope::TaskBatch(batch) = env else {
                    panic!("expected a task batch, got {env:?}")
                };
                let ids: Vec<u64> = batch.iter().map(|t| t.id).collect();
                assert_eq!(ids, vec![1, 2, 3], "batch travels in admission order");
                assert!(batch.iter().all(|t| t.stage == 2 && t.hops == 1));
                // One frame for three tasks: two frames saved.
                assert_eq!(
                    env.encoded_bytes(&meta2()),
                    3 * 8192 - 2 * crate::net::ENVELOPE_HEADER_BYTES
                );
            }
            other => panic!("expected a coalesced send, got {other:?}"),
        }
        assert_eq!(w.output_len(), 0);
        let stats = w.into_stats();
        assert_eq!(stats.offloaded_out, 3, "per-task offload accounting is kept");
        assert_eq!(stats.offload_targets[1], 3);
        assert_eq!(stats.envelopes_sent, 1);
        assert_eq!(stats.coalesced_tasks, 2);
        assert_eq!(
            stats.wire_bytes_saved,
            2 * crate::net::ENVELOPE_HEADER_BYTES as u64
        );
        assert_eq!(stats.wire_bytes, (3 * 8192 - 2 * crate::net::ENVELOPE_HEADER_BYTES) as u64);
    }

    #[test]
    fn stage_class_coalescing_stops_at_class_boundaries() {
        let cfg = cfg_coalesce(CoalesceMode::StageClass);
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("2-node"), 8);
        w.busy = true;
        w.queues.output.push(stage2(1, 0, 0.0));
        w.queues.output.push(stage2(2, 0, 0.1));
        w.queues.output.push(stage2(3, 1, 0.2));
        let mut acts = Vec::new();
        w.try_offload(0.0, &mut acts);
        // Two envelopes: the class-0 pair, then the class-1 singleton
        // (QueueOnly keeps accepting: O_n = 1 > I_m = 0).
        assert_eq!(acts.len(), 2, "{acts:?}");
        let sizes: Vec<usize> = acts
            .iter()
            .map(|a| match a {
                Action::Send { env: Envelope::TaskBatch(b), .. } => b.len(),
                other => panic!("expected task sends, got {other:?}"),
            })
            .collect();
        assert_eq!(sizes, vec![2, 1], "one envelope per class run");
        let stats = w.into_stats();
        assert_eq!(stats.envelopes_sent, 2);
        assert_eq!(stats.coalesced_tasks, 1);
    }

    #[test]
    fn coalesce_off_keeps_one_task_per_envelope() {
        let cfg = cfg_coalesce(CoalesceMode::Off);
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("2-node"), 8);
        w.busy = true;
        for id in 1..=3u64 {
            w.queues.output.push(stage2(id, 0, 0.0));
        }
        let mut acts = Vec::new();
        w.try_offload(0.0, &mut acts);
        assert_eq!(acts.len(), 3, "seed wire: one message per task: {acts:?}");
        for a in &acts {
            match a {
                Action::Send { env, .. } => {
                    assert_eq!(env.items(), 1);
                    assert_eq!(env.encoded_bytes(&meta2()), 8192, "seed byte charge");
                }
                other => panic!("expected task sends, got {other:?}"),
            }
        }
        let stats = w.into_stats();
        assert_eq!(stats.envelopes_sent, 3);
        assert_eq!(stats.coalesced_tasks, 0);
        assert_eq!(stats.wire_bytes_saved, 0);
    }

    #[test]
    fn receiver_merges_batch_through_discipline_in_envelope_order() {
        let mut cfg = cfg_fixed("2-node", 50.0, 0.9);
        cfg.warmup_s = 0.0;
        cfg.sched.discipline = DisciplineKind::StrictPriority;
        cfg.sched = cfg.sched.with_classes(2);
        let mut w = WorkerCore::new(1, &cfg, meta2(), &topo("2-node"), 8);
        w.busy = true; // hold the queue so the merge is observable
        let batch = vec![stage2(1, 1, 0.0), stage2(2, 0, 0.1), stage2(3, 1, 0.2)];
        let acts = w.on_task_batch(0.0, batch, TaskOrigin::Wire);
        assert!(acts.is_empty(), "busy worker only queues: {acts:?}");
        assert_eq!(w.input_len(), 3);
        assert_eq!(w.input_class_len(0), 1);
        assert_eq!(w.input_class_len(1), 2);
        // The class-0 element jumps the lane exactly as three one-task
        // arrivals would have arranged it.
        let done = Task::initial(9, 0, None, 0.0);
        let acts = w.on_compute_done(0.01, vec![done], vec![(out(0.99), 1)], 0.002);
        let started = acts
            .iter()
            .find_map(|a| match a {
                Action::StartCompute { batch, .. } => Some(&batch[0]),
                _ => None,
            })
            .expect("next task starts");
        assert_eq!((started.id, started.class), (2, 0));
        let stats = w.into_stats();
        assert_eq!(stats.received, 3, "each batched task counts as received");
    }

    #[test]
    fn churn_rehome_coalesces_same_source_runs() {
        let mut cfg = cfg_coalesce(CoalesceMode::Stage);
        cfg.warmup_s = 0.0;
        let mut w = WorkerCore::new(1, &cfg, meta2(), &topo("2-node"), 8);
        for i in 0..4 {
            w.on_task(0.0, Task::initial(i, 0, None, 0.0), TaskOrigin::Wire);
        }
        // One computing, three queued; all share source 0, so the drain
        // goes home as ONE re-home envelope.
        let acts = w.on_churn(1.0, 1, false);
        assert_eq!(acts.len(), 1, "{acts:?}");
        match &acts[0] {
            Action::Send { to: 0, env: Envelope::Rehome(ts), .. } => {
                let ids: Vec<u64> = ts.iter().map(|t| t.id).collect();
                assert_eq!(ids, vec![1, 2, 3], "admission order inside the envelope");
            }
            other => panic!("expected one coalesced rehome, got {other:?}"),
        }
    }

    #[test]
    fn stage_class_rehome_envelopes_stay_class_pure() {
        let mut cfg = cfg_coalesce(CoalesceMode::StageClass);
        cfg.sched = cfg.sched.with_classes(2);
        let mut w = WorkerCore::new(1, &cfg, meta2(), &topo("2-node"), 8);
        w.busy = true; // hold the queue so the whole backlog drains at churn
        for (id, class) in [(1u64, 0u8), (2, 0), (3, 1)] {
            let t = Task { class, ..Task::initial(id, 0, None, 0.0) };
            w.on_task(0.0, t, TaskOrigin::Wire);
        }
        let acts = w.on_churn(1.0, 1, false);
        let sizes: Vec<usize> = acts
            .iter()
            .map(|a| match a {
                Action::Send { env: Envelope::Rehome(ts), .. } => ts.len(),
                other => panic!("expected rehome sends, got {other:?}"),
            })
            .collect();
        assert_eq!(sizes, vec![2, 1], "stage-class envelopes never mix classes");
    }

    #[test]
    fn rate_share_scales_admission_pacing() {
        let mut cfg = cfg_fixed("2-node", 50.0, 0.9);
        cfg.placement = Placement {
            sources: vec![crate::routing::SourceSpec { node: 0, rate_share: 2.0 }],
        };
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("2-node"), 8);
        let (_, dt) = w.poll_admission(0.0);
        // Fixed 50 Hz at share 2.0 paces at 100 Hz.
        assert!((dt - 0.01).abs() < 1e-12, "dt {dt}");
    }

    // -- elastic control plane through the core --------------------------------

    fn cfg_cluster(topology: &str) -> ExperimentConfig {
        let mut cfg = cfg_fixed(topology, 50.0, 0.9);
        cfg.warmup_s = 0.0;
        cfg.cluster.enabled = true;
        cfg
    }

    #[test]
    fn cluster_off_builds_no_state_and_stamps_no_beat() {
        let cfg = cfg_fixed("2-node", 50.0, 0.9);
        let mut w = core(0, &cfg, "2-node");
        assert!(!w.runs_cluster_controller());
        let acts = w.on_gossip_tick(0.0);
        let Some(Action::Send { env: Envelope::State(s), .. }) = acts.first() else {
            panic!("expected state send: {acts:?}");
        };
        assert_eq!(s.beat, None, "default config keeps the seed wire");
        assert_eq!(s.encoded_bytes(), crate::policy::BASE_SUMMARY_BYTES);
        assert!(w.on_cluster_tick(1.0).is_empty(), "no controller, no decisions");
    }

    #[test]
    fn cluster_beats_ride_gossip_and_silence_retires_a_peer() {
        let cfg = cfg_cluster("3-node-mesh");
        let mut w0 = core(0, &cfg, "3-node-mesh");
        let mut w1 = core(1, &cfg, "3-node-mesh");
        let mut w2 = core(2, &cfg, "3-node-mesh");
        assert!(w0.runs_cluster_controller(), "lowest-id source hosts the loop");
        assert!(!w1.runs_cluster_controller());
        // Minted summaries carry monotone beats, charged +8 B on the wire.
        let acts = w1.on_gossip_tick(0.0);
        let Some(Action::Send { env: Envelope::State(s1), .. }) = acts.first() else {
            panic!("expected state send: {acts:?}");
        };
        assert_eq!(s1.beat, Some(1));
        assert_eq!(s1.encoded_bytes(), crate::policy::BASE_SUMMARY_BYTES + 8);
        let _ = w0.on_gossip(0.0, 1, s1.clone());
        let acts = w2.on_gossip_tick(0.0);
        let Some(Action::Send { env: Envelope::State(s2), .. }) = acts.first() else {
            panic!("expected state send: {acts:?}");
        };
        let _ = w0.on_gossip(0.0, 2, s2.clone());
        // Hold occupancy in the deadband so only the health path can fire
        // (3 tasks / 3 active = 1.0, between 0.5 and 3.0).
        for i in 0..3 {
            w0.queues.input.push(Task::initial(i, 0, None, 0.0));
        }
        // Worker 1 keeps beating; worker 2 goes silent past its deadline
        // (gossip 0.1 s × 3 beats × jitter ≤ 1.2 → at most 0.36 s).
        let acts = w1.on_gossip_tick(0.3);
        let Some(Action::Send { env: Envelope::State(s1), .. }) = acts.first() else {
            panic!("expected state send: {acts:?}");
        };
        let _ = w0.on_gossip(0.3, 1, s1.clone());
        let acts = w0.on_cluster_tick(0.5);
        assert_eq!(acts.len(), 1, "{acts:?}");
        let Action::Scale(d) = &acts[0] else { panic!("{acts:?}") };
        assert_eq!((d.worker, d.join), (2, false), "the silent peer is retired");
        assert_eq!(d.reason, ScaleReason::Failure);
        // The driver applies the retirement; the failover resets the
        // cooldown, so the next tick inside it stays quiet.
        let _ = w0.on_churn(0.5, 2, false);
        assert!(w0.on_cluster_tick(1.0).is_empty(), "cooldown after failover");
    }

    #[test]
    fn cluster_tick_scales_up_under_load_and_down_when_idle() {
        let cfg = cfg_cluster("3-node-mesh");
        let mut w0 = core(0, &cfg, "3-node-mesh");
        // Park worker 2: the run starts with a fleet of two.
        let _ = w0.on_churn(0.0, 2, false);
        // Occupancy (7 local + 1 gossiped) / 2 active = 4.0 ≥ 3.0: grow.
        for i in 0..7 {
            w0.queues.input.push(Task::initial(i, 0, None, 0.0));
        }
        let mut s = NeighborSummary::base(1, 0.002, 0.9);
        s.beat = Some(1);
        let _ = w0.on_gossip(0.0, 1, s);
        let acts = w0.on_cluster_tick(0.1);
        assert_eq!(acts.len(), 1, "{acts:?}");
        let Action::Scale(d) = &acts[0] else { panic!("{acts:?}") };
        assert_eq!((d.worker, d.join), (2, true), "wakes the lowest parked id");
        assert_eq!(d.reason, ScaleReason::Load);
        assert!(w0.on_cluster_tick(0.2).is_empty(), "cooldown blocks thrash");

        // Idle fleet: the worst-scored (slowest) worker is retired.
        let mut w0 = core(0, &cfg, "3-node-mesh");
        let mut lean = NeighborSummary::base(0, 0.002, 0.9);
        lean.beat = Some(1);
        let mut slow = NeighborSummary::base(0, 0.050, 0.9);
        slow.beat = Some(1);
        let _ = w0.on_gossip(0.0, 1, lean);
        let _ = w0.on_gossip(0.0, 2, slow);
        let acts = w0.on_cluster_tick(0.1);
        assert_eq!(acts.len(), 1, "{acts:?}");
        let Action::Scale(d) = &acts[0] else { panic!("{acts:?}") };
        assert_eq!((d.worker, d.join), (2, false), "highest composite cost retires");
        assert_eq!(d.reason, ScaleReason::Load);
    }

    #[test]
    fn cluster_never_scales_a_source_and_sleeps_while_churned_out() {
        let cfg = cfg_cluster("2-node");
        let mut w0 = core(0, &cfg, "2-node");
        // Worker 1 is the only non-source; make IT the source instead so
        // nothing is eligible for retirement.
        let mut cfg2 = cfg_cluster("2-node");
        cfg2.placement = Placement::multi(&[0, 1]);
        let mut both = WorkerCore::new(0, &cfg2, meta2(), &topo("2-node"), 8);
        let mut s = NeighborSummary::base(0, 0.002, 0.9);
        s.beat = Some(1);
        let _ = both.on_gossip(0.0, 1, s.clone());
        // Idle (occ 0 ≤ 0.5) but every node is a source: nothing retires,
        // and a silent source is never failure-retired either.
        assert!(both.on_cluster_tick(0.1).is_empty());
        assert!(both.on_cluster_tick(5.0).is_empty(), "sources never retire");
        // A churned-out controller makes no decisions.
        let _ = w0.on_gossip(0.0, 1, s);
        let _ = w0.on_churn(0.05, 0, false);
        assert!(w0.on_cluster_tick(5.0).is_empty());
    }

    #[test]
    fn relayout_adopts_new_routes_and_role() {
        let cfg = cfg_sources("3-node-mesh", &[0]);
        let mut w2 = WorkerCore::new(2, &cfg, meta2(), &topo("3-node-mesh"), 8);
        w2.busy = true;
        let acts =
            w2.on_compute_done(0.01, vec![Task::initial(1, 0, None, 0.0)], vec![(out(0.99), 1)], 0.002);
        assert!(
            matches!(&acts[0], Action::Send { to: 0, env: Envelope::Result(_), .. }),
            "mesh default routes results direct: {acts:?}"
        );
        // Re-layer with a detour row (as the driver would after a fleet
        // change): subsequent results ride the new route.
        let routing = RoutingTable::build(&topo("3-node-mesh"));
        let role = Role::of(2, &cfg.placement, &routing);
        let mut row = routing.row(2);
        row[0] = Some(1);
        w2.apply_relayout(row, role);
        w2.busy = true;
        let acts =
            w2.on_compute_done(0.02, vec![Task::initial(2, 0, None, 0.0)], vec![(out(0.99), 1)], 0.002);
        assert!(
            matches!(&acts[0], Action::Send { to: 1, env: Envelope::Result(_), .. }),
            "re-layered route via 1: {acts:?}"
        );
    }

    // ---- batched AE encode & wire recharge (PR 10) ------------------------

    use crate::dataset::ExitTable;
    use crate::testkit::TensorEngine;

    fn meta_ae() -> ModelMeta {
        let mut m = meta2();
        m.ae = Some(AeMeta { enc_cost_s: 0.001, dec_cost_s: 0.001, code_bytes: 2048 });
        m
    }

    fn tensor_engine() -> TensorEngine {
        TensorEngine::new(ExitTable::synthetic(4, 2, vec![0.9; 8], vec![1; 8]), 16, 4)
    }

    /// A stage-2 task marked for encoding, carrying the engine's real
    /// feature tensor for `sample` (or none, for the oracle path).
    fn ae_task(eng: &TensorEngine, sample: usize, real: bool) -> Task {
        let features = real.then(|| eng.features_for(sample));
        Task {
            stage: 2,
            encoded: true,
            ..Task::initial(sample as u64, sample, features, 0.0)
        }
    }

    #[test]
    fn batched_ae_matches_k_singles_and_charges_fewer_bytes() {
        let m = meta_ae();
        let k = 3usize;
        let eng = tensor_engine();
        let mut batch: Vec<Task> = (0..k).map(|s| ae_task(&eng, s, true)).collect();
        assert_eq!(encode_batch(&eng, &mut batch), 1, "one priced forward for the run");
        assert_eq!(eng.batch_forwards(), 1, "k tensors share one encoder pass");

        // The same tensors encoded one by one (a fresh engine) must yield
        // identical codes — hence identical per-task reconstruction error.
        let solo = tensor_engine();
        let mut singles: Vec<Task> = (0..k).map(|s| ae_task(&solo, s, true)).collect();
        let mut forwards = 0;
        for t in singles.iter_mut() {
            forwards += encode_batch(&solo, std::slice::from_mut(t));
        }
        assert_eq!(forwards, k, "k un-coalesced sends pay k forwards");
        for (b, s) in batch.iter().zip(&singles) {
            assert!(b.encoded && s.encoded);
            let code = b.features.as_ref().unwrap();
            assert_eq!(code, s.features.as_ref().unwrap(), "batched code == single code");
            let orig = eng.features_for(b.sample);
            let rec = eng.decode(code).unwrap().unwrap();
            let err: f32 = orig
                .data()
                .iter()
                .zip(rec.data())
                .map(|(a, r)| (a - r) * (a - r))
                .sum();
            assert!(err > 0.0, "pooling is lossy, so the error is measurable");
        }

        // One coalesced envelope of k codes undercuts k singletons by
        // exactly the shed frames.
        let coalesced = Envelope::TaskBatch(batch).encoded_bytes(&m);
        let separate: usize = singles
            .into_iter()
            .map(|t| Envelope::TaskBatch(vec![t]).encoded_bytes(&m))
            .sum();
        assert_eq!(separate, k * 2048, "a singleton charges the AE code size");
        assert_eq!(separate - coalesced, (k - 1) * ENVELOPE_HEADER_BYTES);
    }

    #[test]
    fn encode_for_wire_recharges_declined_tensors_raw() {
        let mut cfg = cfg_fixed("2-node", 50.0, 0.9);
        cfg.warmup_s = 0.0;
        cfg.use_ae = true;
        let m = meta_ae();
        let mut w = WorkerCore::new(0, &cfg, meta_ae(), &topo("2-node"), 8);
        let eng = tensor_engine().declining([1]);
        let tasks: Vec<Task> = (0..3).map(|s| ae_task(&eng, s, true)).collect();
        let mut env = Envelope::TaskBatch(tasks);
        let pre = env.encoded_bytes(&m);
        assert_eq!(w.encode_for_wire(&eng, 0.5, &mut env), 1);
        let post = env.encoded_bytes(&m);
        // The declined middle tensor ships raw: its item charge grows from
        // the code size to the full stage-2 activation.
        assert_eq!(post - pre, 8192 - 2048);
        let batch = env.task_batch().unwrap();
        assert!(batch[0].encoded && batch[2].encoded, "the others stay coded");
        assert!(!batch[1].encoded, "declined tensor flips raw");
        let raw = batch[1].features.as_ref().unwrap();
        assert_eq!(raw.data()[0], 1.0, "raw payload travels intact");
        assert_eq!(raw.numel(), 16, "full tensor, not a code");
        assert_eq!(
            w.into_stats().wire_bytes,
            (8192 - 2048) as u64,
            "the sender re-charges exactly the fallback delta"
        );
    }

    #[test]
    fn encoder_error_ships_the_whole_batch_raw() {
        let mut cfg = cfg_fixed("2-node", 50.0, 0.9);
        cfg.warmup_s = 0.0;
        let m = meta_ae();
        let mut w = WorkerCore::new(0, &cfg, meta_ae(), &topo("2-node"), 8);
        let eng = tensor_engine().erroring();
        let tasks: Vec<Task> = (0..2).map(|s| ae_task(&eng, s, true)).collect();
        let mut env = Envelope::TaskBatch(tasks);
        let pre = env.encoded_bytes(&m);
        assert_eq!(w.encode_for_wire(&eng, 0.5, &mut env), 0, "no forward completed");
        assert_eq!(env.encoded_bytes(&m) - pre, 2 * (8192 - 2048));
        assert!(env
            .task_batch()
            .unwrap()
            .iter()
            .all(|t| !t.encoded && t.features.is_some()));
        assert_eq!(w.into_stats().wire_bytes, 2 * (8192 - 2048) as u64);
    }

    #[test]
    fn virtual_encodes_price_one_forward_and_never_recharge() {
        let mut cfg = cfg_fixed("2-node", 50.0, 0.9);
        cfg.warmup_s = 0.0;
        let m = meta_ae();
        let mut w = WorkerCore::new(0, &cfg, meta_ae(), &topo("2-node"), 8);
        // Oracle path (SimEngine-style): no tensors, the encode is virtual.
        let eng = tensor_engine();
        let tasks: Vec<Task> = (0..2).map(|s| ae_task(&eng, s, false)).collect();
        let mut env = Envelope::TaskBatch(tasks);
        let pre = env.encoded_bytes(&m);
        assert_eq!(w.encode_for_wire(&eng, 0.5, &mut env), 1, "still one priced forward");
        assert_eq!(eng.batch_forwards(), 0, "but no real encoder call runs");
        assert_eq!(env.encoded_bytes(&m), pre, "code-size charge stands");
        assert!(env.task_batch().unwrap().iter().all(|t| t.encoded));
        assert_eq!(w.into_stats().wire_bytes, 0, "nothing to recharge");
    }

    #[test]
    fn adaptive_coalescing_singles_when_idle_and_drains_under_pressure() {
        let cfg = cfg_coalesce(CoalesceMode::Adaptive);
        let mut w = WorkerCore::new(0, &cfg, meta2(), &topo("2-node"), 8);
        w.busy = true;
        // One measured transfer fixes the link's uncontended floor; the
        // D_nm estimate equals it, so the medium reads as idle.
        w.note_transfer_delay(1, 0.001);
        for id in [1u64, 2, 3] {
            w.queues.output.push(stage2(id, 0, 0.0));
        }
        let mut acts = Vec::new();
        w.try_offload(0.0, &mut acts);
        assert_eq!(acts.len(), 3, "idle medium pipelines singles: {acts:?}");
        assert!(acts.iter().all(|a| matches!(
            a,
            Action::Send { env: Envelope::TaskBatch(b), .. } if b.len() == 1
        )));
        // Inflate the estimate far past the floor: a saturated medium
        // flips the same queue state to one deep coalesced run.
        for _ in 0..50 {
            w.note_transfer_delay(1, 0.02);
        }
        for id in [4u64, 5, 6] {
            w.queues.output.push(stage2(id, 0, 0.0));
        }
        let mut acts = Vec::new();
        w.try_offload(0.0, &mut acts);
        assert_eq!(acts.len(), 1, "contended medium coalesces: {acts:?}");
        match &acts[0] {
            Action::Send { env: Envelope::TaskBatch(batch), .. } => assert_eq!(batch.len(), 3),
            other => panic!("expected one coalesced send, got {other:?}"),
        }
    }
}
