//! Run reports: everything a figure/bench needs from one experiment run.

use crate::util::json::{obj, Json};
use crate::util::stats::Samples;

/// Per-worker accounting.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub processed: u64,
    pub offloaded_out: u64,
    pub received: u64,
    pub exits: u64,
    /// Result/re-home messages this worker forwarded one hop closer to
    /// their admitting source (multi-hop routing activity).
    pub relayed: u64,
    pub peak_input: usize,
    pub peak_output: usize,
    /// Virtual/real seconds spent computing (utilization numerator).
    pub busy_s: f64,
    /// Tasks this worker's queue disciplines discarded (EDF `drop_late`).
    pub dropped: u64,
    /// The same drops broken down by traffic class.
    pub dropped_per_class: Vec<u64>,
    /// Where this worker's offloads went: one slot per topology node (the
    /// per-policy offload-target histogram — how Alg. 2 vs. the
    /// deadline-aware / multi-hop policies actually spread work).
    pub offload_targets: Vec<u64>,
    /// Gossip bytes this worker put on the wire, charged by the *actual*
    /// encoded summary size (policies that annotate extra fields pay here).
    pub gossip_bytes: u64,
    /// Tasks the input discipline served per class (weighted-fair
    /// disciplines report their realized split; empty otherwise).
    pub served_per_class: Vec<u64>,
    /// Every byte this worker put on the wire (task batches, results,
    /// re-homes, gossip), charged by `net::Envelope::encoded_bytes` — the
    /// same number the drivers feed their medium. Run-level
    /// `bytes_on_wire` is the sum of these.
    pub wire_bytes: u64,
    /// Task-carrying envelopes this worker sent (offloads + DDI routing).
    /// With `coalesce = off` this equals the per-task offload count; with
    /// coalescing on, fewer envelopes carry the same tasks.
    pub envelopes_sent: u64,
    /// Tasks that rode an envelope behind another task (the k-1 extras of
    /// every k-task batch, across task/result/re-home envelopes).
    pub coalesced_tasks: u64,
    /// Wire bytes avoided by sharing envelope frames (sum over envelopes
    /// of `unbatched_bytes - encoded_bytes`).
    pub wire_bytes_saved: u64,
}

/// Per-traffic-class accounting (populated when the run configures more
/// than one class; single-class runs carry one entry equal to the totals).
#[derive(Debug, Clone)]
pub struct ClassStats {
    /// Results of this class returned to the source during the window.
    pub completed: u64,
    pub correct: u64,
    /// Results of this class delivered before their stamped deadline
    /// (deadline-aware policy/bench surface).
    pub on_time: u64,
    /// Results per exit point (1-based; index 0 = exit 1).
    pub exit_histogram: Vec<u64>,
    pub latency: Samples,
    /// Tasks of this class discarded by deadline-aware disciplines.
    pub dropped: u64,
}

impl ClassStats {
    pub fn new(num_exits: usize) -> ClassStats {
        ClassStats {
            completed: 0,
            correct: 0,
            on_time: 0,
            exit_histogram: vec![0; num_exits],
            latency: Samples::new(),
            dropped: 0,
        }
    }

    /// Fold one completed result of this class into the counters.
    pub fn record(&mut self, exit_point: usize, correct: bool, on_time: bool, latency_s: f64) {
        self.completed += 1;
        if correct {
            self.correct += 1;
        }
        if on_time {
            self.on_time += 1;
        }
        if let Some(slot) = self.exit_histogram.get_mut(exit_point - 1) {
            *slot += 1;
        }
        self.latency.push(latency_s);
    }

    /// Fraction of this class's completions that met their deadline.
    pub fn on_time_rate(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.on_time as f64 / self.completed as f64
    }

    /// Fraction of this class's results that exited at each point.
    pub fn exit_fractions(&self) -> Vec<f64> {
        let total: u64 = self.exit_histogram.iter().sum();
        if total == 0 {
            return vec![0.0; self.exit_histogram.len()];
        }
        self.exit_histogram.iter().map(|&c| c as f64 / total as f64).collect()
    }

    /// Fold another class tally into this one (merging per-source tallies
    /// from the realtime driver's source threads).
    pub fn absorb(&mut self, other: &ClassStats) {
        self.completed += other.completed;
        self.correct += other.correct;
        self.on_time += other.on_time;
        for (slot, &c) in self.exit_histogram.iter_mut().zip(&other.exit_histogram) {
            *slot += c;
        }
        self.latency.absorb(&other.latency);
        self.dropped += other.dropped;
    }
}

/// Per-source accounting: what one admitting node pushed into the system
/// and got back (populated for every source the run's `Placement`
/// declares; classic single-source runs carry one entry equal to the
/// totals).
#[derive(Debug, Clone)]
pub struct SourceStats {
    /// Topology node this source sits on.
    pub node: usize,
    /// Samples this source admitted during the window.
    pub admitted: u64,
    /// Results delivered back to this source during the window.
    pub completed: u64,
    pub correct: u64,
    /// This source's results per exit point (1-based; index 0 = exit 1).
    pub exit_histogram: Vec<u64>,
    pub latency: Samples,
}

impl SourceStats {
    pub fn new(node: usize, num_exits: usize) -> SourceStats {
        SourceStats {
            node,
            admitted: 0,
            completed: 0,
            correct: 0,
            exit_histogram: vec![0; num_exits],
            latency: Samples::new(),
        }
    }

    /// Fold one result delivered to this source into the counters.
    pub fn record(&mut self, exit_point: usize, correct: bool, latency_s: f64) {
        self.completed += 1;
        if correct {
            self.correct += 1;
        }
        if let Some(slot) = self.exit_histogram.get_mut(exit_point - 1) {
            *slot += 1;
        }
        self.latency.push(latency_s);
    }

    pub fn accuracy(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.correct as f64 / self.completed as f64
    }

    /// Fraction of this source's results that exited at each point.
    pub fn exit_fractions(&self) -> Vec<f64> {
        let total: u64 = self.exit_histogram.iter().sum();
        if total == 0 {
            return vec![0.0; self.exit_histogram.len()];
        }
        self.exit_histogram.iter().map(|&c| c as f64 / total as f64).collect()
    }
}

/// A sampled point of the controller/queue timeline.
#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    pub t_s: f64,
    /// Current interarrival μ (Alg. 3 runs) or threshold T_e (Alg. 4 runs).
    pub control: f64,
    pub source_queue: usize,
}

/// Everything measured during the post-warmup window of one run.
#[derive(Debug)]
pub struct RunReport {
    pub model: String,
    pub topology: String,
    pub label: String,
    pub duration_s: f64,
    /// Samples admitted at the source during the window.
    pub admitted: u64,
    /// Inference results returned to the source during the window.
    pub completed: u64,
    pub correct: u64,
    /// Results per exit point (1-based; index 0 = exit 1).
    pub exit_histogram: Vec<u64>,
    pub latency: Samples,
    pub per_worker: Vec<WorkerStats>,
    pub bytes_on_wire: u64,
    pub task_transfers: u64,
    /// Tasks re-homed to the source because a worker left mid-run.
    pub rehomed: u64,
    /// Tasks discarded by deadline-aware disciplines (sum over workers).
    pub dropped: u64,
    /// Per-traffic-class counters (one entry per configured class).
    pub per_class: Vec<ClassStats>,
    /// Per-source counters, in the placement's declaration order.
    pub per_source: Vec<SourceStats>,
    /// Final controller values.
    pub final_mu_s: Option<f64>,
    pub final_t_e: Option<f64>,
    /// Fleet changes ordered by the elastic control plane (spawns /
    /// retirements actually applied — stale decisions don't count).
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Active-fleet cost: ∫ active-node-count dt over the measured
    /// window (node-seconds). A static n-node fleet reports
    /// `n × duration_s`; an autoscaled fleet reports what it actually
    /// kept awake — the cost axis of the cluster ablation bench.
    pub worker_seconds: f64,
    /// Events processed by the DES event loop (0 on the realtime driver).
    pub sim_events: u64,
    /// High-water mark of the DES event queue (0 on the realtime driver).
    pub peak_event_queue: usize,
    pub trace: Vec<TracePoint>,
    /// Spans / metrics rows / flight dumps collected when the run enabled
    /// telemetry (`None` otherwise). Not serialized by `to_json` — the CLI
    /// exports it to its own files (`--trace`, `--metrics`).
    pub telemetry: Option<crate::telemetry::TelemetryData>,
}

impl RunReport {
    pub fn new(model: &str, topology: &str, label: &str, n_workers: usize,
               num_exits: usize, num_classes: usize, source_nodes: &[usize]) -> RunReport {
        RunReport {
            model: model.to_string(),
            topology: topology.to_string(),
            label: label.to_string(),
            duration_s: 0.0,
            admitted: 0,
            completed: 0,
            correct: 0,
            exit_histogram: vec![0; num_exits],
            latency: Samples::new(),
            per_worker: vec![WorkerStats::default(); n_workers],
            bytes_on_wire: 0,
            task_transfers: 0,
            rehomed: 0,
            dropped: 0,
            per_class: vec![ClassStats::new(num_exits); num_classes.max(1)],
            per_source: source_nodes
                .iter()
                .map(|&node| SourceStats::new(node, num_exits))
                .collect(),
            final_mu_s: None,
            final_t_e: None,
            scale_ups: 0,
            scale_downs: 0,
            worker_seconds: 0.0,
            sim_events: 0,
            peak_event_queue: 0,
            trace: Vec::new(),
            telemetry: None,
        }
    }

    /// Fold one completed result into its class's counters (drivers call
    /// this next to their total accounting).
    pub fn record_class(&mut self, class: u8, exit_point: usize, correct: bool,
                        on_time: bool, latency_s: f64) {
        // Out-of-range classes fold into the last bucket, mirroring how
        // `StrictPriority` clamps lanes.
        let i = (class as usize).min(self.per_class.len().saturating_sub(1));
        if let Some(cs) = self.per_class.get_mut(i) {
            cs.record(exit_point, correct, on_time, latency_s);
        }
    }

    /// Fold one completed result into its admitting source's counters
    /// (no-op for sources the placement does not declare — cannot happen
    /// on a validated run).
    pub fn record_source(&mut self, source: usize, exit_point: usize, correct: bool,
                         latency_s: f64) {
        if let Some(ss) = self.per_source.iter_mut().find(|s| s.node == source) {
            ss.record(exit_point, correct, latency_s);
        }
    }

    /// Count one admission at `source`.
    pub fn record_admission(&mut self, source: usize) {
        self.admitted += 1;
        if let Some(ss) = self.per_source.iter_mut().find(|s| s.node == source) {
            ss.admitted += 1;
        }
    }

    /// Derive the run-level wire totals from the per-worker envelope
    /// counters (call after `per_worker` is filled; idempotent). Both
    /// drivers go through this, so `bytes_on_wire` / `task_transfers`
    /// have one definition: the sum of what every core charged through
    /// `net::Envelope::encoded_bytes`.
    pub fn fold_wire_totals(&mut self) {
        self.bytes_on_wire = self.per_worker.iter().map(|w| w.wire_bytes).sum();
        self.task_transfers = self.per_worker.iter().map(|w| w.envelopes_sent).sum();
    }

    /// Task-carrying envelopes the run put on the wire (sum over workers).
    pub fn envelopes_sent(&self) -> u64 {
        self.per_worker.iter().map(|w| w.envelopes_sent).sum()
    }

    /// Tasks that shared an envelope with another task (sum over workers).
    pub fn coalesced_tasks(&self) -> u64 {
        self.per_worker.iter().map(|w| w.coalesced_tasks).sum()
    }

    /// Wire bytes avoided by envelope sharing (sum over workers).
    pub fn wire_bytes_saved(&self) -> u64 {
        self.per_worker.iter().map(|w| w.wire_bytes_saved).sum()
    }

    /// Aggregate the per-worker discipline drops into the per-class and
    /// total counters (call once, after `per_worker` is filled).
    pub fn fold_worker_drops(&mut self) {
        self.dropped = 0;
        for cs in &mut self.per_class {
            cs.dropped = 0;
        }
        let drops: Vec<(usize, u64)> = self
            .per_worker
            .iter()
            .flat_map(|w| w.dropped_per_class.iter().enumerate().map(|(c, &d)| (c, d)))
            .collect();
        for (c, d) in drops {
            self.dropped += d;
            let i = c.min(self.per_class.len().saturating_sub(1));
            if let Some(cs) = self.per_class.get_mut(i) {
                cs.dropped += d;
            }
        }
    }

    /// Classification accuracy over completed results.
    pub fn accuracy(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.correct as f64 / self.completed as f64
    }

    /// Completed inference throughput (the paper's achieved "data rate").
    pub fn throughput_hz(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / self.duration_s
    }

    /// Admission rate at the source.
    pub fn admitted_rate_hz(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.admitted as f64 / self.duration_s
    }

    /// Fraction of results that exited at each point.
    pub fn exit_fractions(&self) -> Vec<f64> {
        let total: u64 = self.exit_histogram.iter().sum();
        if total == 0 {
            return vec![0.0; self.exit_histogram.len()];
        }
        self.exit_histogram.iter().map(|&c| c as f64 / total as f64).collect()
    }

    /// Total gossip bytes the run put on the wire (sum of the per-worker
    /// encoded-size charges).
    pub fn gossip_bytes(&self) -> u64 {
        self.per_worker.iter().map(|w| w.gossip_bytes).sum()
    }

    pub fn to_json(&mut self) -> Json {
        let workers: Vec<Json> = self
            .per_worker
            .iter()
            .map(|w| {
                obj(vec![
                    ("processed", (w.processed as i64).into()),
                    ("offloaded_out", (w.offloaded_out as i64).into()),
                    ("received", (w.received as i64).into()),
                    ("exits", (w.exits as i64).into()),
                    ("relayed", (w.relayed as i64).into()),
                    ("peak_input", w.peak_input.into()),
                    ("peak_output", w.peak_output.into()),
                    ("busy_s", w.busy_s.into()),
                    ("dropped", (w.dropped as i64).into()),
                    ("offload_targets",
                     Json::Arr(w.offload_targets.iter().map(|&n| (n as i64).into()).collect())),
                    ("gossip_bytes", (w.gossip_bytes as i64).into()),
                    ("served_per_class",
                     Json::Arr(w.served_per_class.iter().map(|&n| (n as i64).into()).collect())),
                    ("wire_bytes", (w.wire_bytes as i64).into()),
                    ("envelopes_sent", (w.envelopes_sent as i64).into()),
                    ("coalesced_tasks", (w.coalesced_tasks as i64).into()),
                    ("wire_bytes_saved", (w.wire_bytes_saved as i64).into()),
                ])
            })
            .collect();
        let classes: Vec<Json> = self
            .per_class
            .iter_mut()
            .map(|c| {
                let (p50, p95) = (c.latency.p50(), c.latency.p95());
                let acc = if c.completed > 0 {
                    c.correct as f64 / c.completed as f64
                } else {
                    0.0
                };
                let on_time_rate = c.on_time_rate();
                obj(vec![
                    ("completed", (c.completed as i64).into()),
                    ("accuracy", acc.into()),
                    ("on_time", (c.on_time as i64).into()),
                    ("on_time_rate", on_time_rate.into()),
                    ("latency_p50_s", p50.into()),
                    ("latency_p95_s", p95.into()),
                    ("exit_histogram",
                     Json::Arr(c.exit_histogram.iter().map(|&n| (n as i64).into()).collect())),
                    ("dropped", (c.dropped as i64).into()),
                ])
            })
            .collect();
        let duration_s = self.duration_s;
        let sources: Vec<Json> = self
            .per_source
            .iter_mut()
            .map(|s| {
                let (p50, p95) = (s.latency.p50(), s.latency.p95());
                let acc = s.accuracy();
                let tput = if duration_s > 0.0 {
                    s.completed as f64 / duration_s
                } else {
                    0.0
                };
                obj(vec![
                    ("node", s.node.into()),
                    ("admitted", (s.admitted as i64).into()),
                    ("completed", (s.completed as i64).into()),
                    ("throughput_hz", tput.into()),
                    ("accuracy", acc.into()),
                    ("latency_p50_s", p50.into()),
                    ("latency_p95_s", p95.into()),
                    ("exit_histogram",
                     Json::Arr(s.exit_histogram.iter().map(|&n| (n as i64).into()).collect())),
                ])
            })
            .collect();
        let (p50, p95, p99, mean) = (
            self.latency.p50(),
            self.latency.p95(),
            self.latency.p99(),
            self.latency.mean(),
        );
        obj(vec![
            ("model", self.model.as_str().into()),
            ("topology", self.topology.as_str().into()),
            ("label", self.label.as_str().into()),
            ("duration_s", self.duration_s.into()),
            ("admitted", (self.admitted as i64).into()),
            ("completed", (self.completed as i64).into()),
            ("accuracy", self.accuracy().into()),
            ("throughput_hz", self.throughput_hz().into()),
            ("admitted_rate_hz", self.admitted_rate_hz().into()),
            ("latency_mean_s", mean.into()),
            ("latency_p50_s", p50.into()),
            ("latency_p95_s", p95.into()),
            ("latency_p99_s", p99.into()),
            ("exit_histogram",
             Json::Arr(self.exit_histogram.iter().map(|&c| (c as i64).into()).collect())),
            ("bytes_on_wire", (self.bytes_on_wire as i64).into()),
            ("gossip_bytes", (self.gossip_bytes() as i64).into()),
            ("task_transfers", (self.task_transfers as i64).into()),
            ("envelopes_sent", (self.envelopes_sent() as i64).into()),
            ("coalesced_tasks", (self.coalesced_tasks() as i64).into()),
            ("wire_bytes_saved", (self.wire_bytes_saved() as i64).into()),
            ("rehomed", (self.rehomed as i64).into()),
            ("dropped", (self.dropped as i64).into()),
            ("scale_ups", (self.scale_ups as i64).into()),
            ("scale_downs", (self.scale_downs as i64).into()),
            ("worker_seconds", self.worker_seconds.into()),
            ("sim_events", (self.sim_events as i64).into()),
            ("peak_event_queue", (self.peak_event_queue as i64).into()),
            ("final_mu_s", self.final_mu_s.map(Json::from).unwrap_or(Json::Null)),
            ("final_t_e", self.final_t_e.map(Json::from).unwrap_or(Json::Null)),
            ("classes", Json::Arr(classes)),
            ("sources", Json::Arr(sources)),
            ("workers", Json::Arr(workers)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_metrics() {
        let mut r = RunReport::new("m", "t", "lbl", 2, 3, 1, &[0]);
        r.duration_s = 10.0;
        r.admitted = 100;
        r.completed = 80;
        r.correct = 60;
        r.exit_histogram = vec![40, 20, 20];
        assert!((r.accuracy() - 0.75).abs() < 1e-12);
        assert!((r.throughput_hz() - 8.0).abs() < 1e-12);
        assert!((r.admitted_rate_hz() - 10.0).abs() < 1e-12);
        let f = r.exit_fractions();
        assert!((f[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_report_is_finite() {
        let mut r = RunReport::new("m", "t", "lbl", 1, 2, 1, &[0]);
        assert_eq!(r.accuracy(), 0.0);
        assert_eq!(r.throughput_hz(), 0.0);
        assert_eq!(r.exit_fractions(), vec![0.0, 0.0]);
        let j = r.to_json();
        assert_eq!(j.get("completed").as_i64(), Some(0));
    }

    #[test]
    fn json_shape() {
        let mut r = RunReport::new("mob", "2-node", "fig3", 2, 5, 1, &[0]);
        r.duration_s = 5.0;
        r.completed = 1;
        r.correct = 1;
        r.latency.push(0.125);
        r.final_mu_s = Some(0.05);
        let j = r.to_json();
        assert_eq!(j.get("model").as_str(), Some("mob"));
        assert_eq!(j.get("workers").as_arr().unwrap().len(), 2);
        assert_eq!(j.get("classes").as_arr().unwrap().len(), 1);
        assert!((j.get("latency_p50_s").as_f64().unwrap() - 0.125).abs() < 1e-9);
        assert!((j.get("final_mu_s").as_f64().unwrap() - 0.05).abs() < 1e-12);
        assert!(j.get("final_t_e").is_null());
    }

    #[test]
    fn per_class_counters_accumulate() {
        let mut r = RunReport::new("m", "t", "lbl", 1, 2, 2, &[0]);
        r.record_class(0, 1, true, true, 0.010);
        r.record_class(0, 2, false, false, 0.030);
        r.record_class(1, 2, true, true, 0.200);
        // out-of-range classes clamp into the last bucket
        r.record_class(7, 1, true, true, 0.100);
        assert_eq!(r.per_class[0].completed, 2);
        assert_eq!(r.per_class[0].correct, 1);
        assert_eq!(r.per_class[0].on_time, 1);
        assert!((r.per_class[0].on_time_rate() - 0.5).abs() < 1e-12);
        assert_eq!(r.per_class[0].exit_histogram, vec![1, 1]);
        assert_eq!(r.per_class[1].completed, 2);
        let f = r.per_class[0].exit_fractions();
        assert!((f[0] - 0.5).abs() < 1e-12);
        assert!((r.per_class[1].latency.p95() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn per_source_counters_accumulate_and_serialize() {
        let mut r = RunReport::new("m", "line-4", "lbl", 4, 2, 1, &[0, 3]);
        r.duration_s = 10.0;
        r.record_admission(0);
        r.record_admission(3);
        r.record_admission(3);
        r.record_source(0, 1, true, 0.010);
        r.record_source(3, 2, false, 0.050);
        r.record_source(3, 1, true, 0.020);
        assert_eq!(r.admitted, 3);
        assert_eq!(r.per_source[0].admitted, 1);
        assert_eq!(r.per_source[1].admitted, 2);
        assert_eq!(r.per_source[1].completed, 2);
        assert_eq!(r.per_source[1].correct, 1);
        assert_eq!(r.per_source[1].exit_histogram, vec![1, 1]);
        assert!((r.per_source[0].accuracy() - 1.0).abs() < 1e-12);
        // Unknown source node: ignored, not misattributed.
        r.record_source(2, 1, true, 0.010);
        assert_eq!(r.per_source[0].completed + r.per_source[1].completed, 3);
        let j = r.to_json();
        let sources = j.get("sources").as_arr().unwrap();
        assert_eq!(sources.len(), 2);
        assert_eq!(sources[0].get("node").as_i64(), Some(0));
        assert_eq!(sources[1].get("node").as_i64(), Some(3));
        assert_eq!(sources[1].get("completed").as_i64(), Some(2));
        assert!((sources[1].get("accuracy").as_f64().unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn class_stats_absorb_merges_tallies() {
        let mut a = ClassStats::new(2);
        a.record(1, true, true, 0.010);
        let mut b = ClassStats::new(2);
        b.record(2, false, false, 0.030);
        b.record(1, true, true, 0.020);
        a.absorb(&b);
        assert_eq!(a.completed, 3);
        assert_eq!(a.correct, 2);
        assert_eq!(a.on_time, 2);
        assert_eq!(a.exit_histogram, vec![2, 1]);
        assert_eq!(a.latency.len(), 3);
    }

    #[test]
    fn wire_totals_fold_from_worker_envelope_counters() {
        let mut r = RunReport::new("m", "t", "lbl", 2, 2, 1, &[0]);
        r.per_worker[0].wire_bytes = 1000;
        r.per_worker[0].envelopes_sent = 3;
        r.per_worker[0].coalesced_tasks = 2;
        r.per_worker[0].wire_bytes_saved = 64;
        r.per_worker[1].wire_bytes = 500;
        r.per_worker[1].envelopes_sent = 1;
        r.fold_wire_totals();
        assert_eq!(r.bytes_on_wire, 1500);
        assert_eq!(r.task_transfers, 4);
        assert_eq!(r.envelopes_sent(), 4);
        assert_eq!(r.coalesced_tasks(), 2);
        assert_eq!(r.wire_bytes_saved(), 64);
        // idempotent
        r.fold_wire_totals();
        assert_eq!(r.bytes_on_wire, 1500);
        let j = r.to_json();
        assert_eq!(j.get("coalesced_tasks").as_i64(), Some(2));
        assert_eq!(j.get("envelopes_sent").as_i64(), Some(4));
        assert_eq!(j.get("wire_bytes_saved").as_i64(), Some(64));
        let w0 = &j.get("workers").as_arr().unwrap()[0];
        assert_eq!(w0.get("envelopes_sent").as_i64(), Some(3));
        assert_eq!(w0.get("wire_bytes").as_i64(), Some(1000));
    }

    #[test]
    fn worker_drops_fold_into_classes_and_total() {
        let mut r = RunReport::new("m", "t", "lbl", 2, 2, 2, &[0]);
        r.per_worker[0].dropped = 3;
        r.per_worker[0].dropped_per_class = vec![1, 2];
        r.per_worker[1].dropped = 2;
        r.per_worker[1].dropped_per_class = vec![0, 2];
        r.fold_worker_drops();
        assert_eq!(r.dropped, 5);
        assert_eq!(r.per_class[0].dropped, 1);
        assert_eq!(r.per_class[1].dropped, 4);
        // idempotent: folding again must not double-count
        r.fold_worker_drops();
        assert_eq!(r.dropped, 5);
    }
}
