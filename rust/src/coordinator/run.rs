//! The public run façade: one builder for both drivers.
//!
//! ```ignore
//! // DES sweep from artifacts (what the figure benches do):
//! let report = Run::builder()
//!     .config(cfg)
//!     .manifest(&manifest)
//!     .execute()?;
//!
//! // Realtime threads on a per-worker engine factory:
//! let report = Run::builder()
//!     .config(cfg)
//!     .model(meta)
//!     .engine_factory(|worker| Ok(Box::new(make_engine(worker)?) as _))
//!     .dataset(&ds)
//!     .driver(Driver::Realtime)
//!     .execute()?;
//!
//! // Engine-free unit run (synthetic oracle + labels only):
//! let report = Run::builder()
//!     .config(cfg)
//!     .model(ModelMeta::synthetic(costs, bytes))
//!     .engine(&sim_engine)
//!     .labels(&labels)
//!     .execute()?;
//! ```
//!
//! Everything unspecified is derived from the manifest: the model metadata
//! from `cfg.model`, the oracle [`SimEngine`](crate::runtime::sim_engine::SimEngine)
//! as the engine (with wallclock cost emulation on the realtime driver),
//! and the held-out dataset as the sample store. Both drivers execute the
//! same [`super::worker::WorkerCore`]; picking [`Driver::Des`] or
//! [`Driver::Realtime`] only changes the clock and the transport.
//!
//! Observability flows through the same façade: set
//! [`ExperimentConfig::telemetry`] (`[telemetry]` TOML, `--trace` /
//! `--metrics` CLI) and the returned [`RunReport::telemetry`] carries the
//! per-task spans, metrics time-series, and flight-recorder dumps that
//! both drivers collected through their cores' recorders.

use anyhow::{Context, Result};

use super::config::ExperimentConfig;
use super::report::RunReport;
use super::rt;
use super::sim::{SampleStore, Simulation};
use super::worker::ModelMeta;
use crate::artifact::Manifest;
use crate::dataset::Dataset;
use crate::routing::Placement;
use crate::runtime::{sim_engine::SimEngine, InferenceEngine};

/// Which execution medium carries the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Driver {
    /// Discrete-event simulation in virtual time (default; milliseconds of
    /// wallclock per virtual minute on the oracle engine).
    #[default]
    Des,
    /// One OS thread per worker, wallclock time, delay-enforcing transport.
    /// `cfg.duration_s` is real seconds — keep it small in tests.
    Realtime,
}

type FactoryBox<'a> =
    Box<dyn Fn(usize) -> Result<Box<dyn InferenceEngine>> + Send + Sync + 'a>;

/// Entry point: [`Run::builder`].
pub struct Run;

impl Run {
    pub fn builder<'a>() -> RunBuilder<'a> {
        RunBuilder {
            cfg: None,
            meta: None,
            manifest: None,
            engine: None,
            factory: None,
            dataset: None,
            labels: None,
            images: None,
            placement: None,
            driver: Driver::Des,
        }
    }
}

/// Accumulates the pieces of a run; see the module docs for recipes.
pub struct RunBuilder<'a> {
    cfg: Option<ExperimentConfig>,
    meta: Option<ModelMeta>,
    manifest: Option<&'a Manifest>,
    engine: Option<&'a dyn InferenceEngine>,
    factory: Option<FactoryBox<'a>>,
    dataset: Option<&'a Dataset>,
    labels: Option<&'a [u8]>,
    images: Option<&'a Dataset>,
    placement: Option<Placement>,
    driver: Driver,
}

impl<'a> RunBuilder<'a> {
    /// The experiment description (required).
    pub fn config(mut self, cfg: ExperimentConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// Artifact manifest to derive defaults from: model metadata, oracle
    /// engine, dataset.
    pub fn manifest(mut self, manifest: &'a Manifest) -> Self {
        self.manifest = Some(manifest);
        self
    }

    /// Explicit model metadata (otherwise derived from the manifest).
    pub fn model(mut self, meta: ModelMeta) -> Self {
        self.meta = Some(meta);
        self
    }

    /// Explicit shared engine (DES driver only — the realtime driver needs
    /// a per-thread factory because engines are deliberately not `Send`).
    pub fn engine(mut self, engine: &'a dyn InferenceEngine) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Per-worker engine constructor. The realtime driver calls it once per
    /// worker thread; the DES driver calls it once (worker 0) and shares.
    pub fn engine_factory<F>(mut self, factory: F) -> Self
    where
        F: Fn(usize) -> Result<Box<dyn InferenceEngine>> + Send + Sync + 'a,
    {
        self.factory = Some(Box::new(factory));
        self
    }

    /// Full labelled dataset (realtime driver admission / DES real-engine
    /// path; otherwise loaded from the manifest).
    pub fn dataset(mut self, dataset: &'a Dataset) -> Self {
        self.dataset = Some(dataset);
        self
    }

    /// Labels-only sample store for engine-free DES runs (the oracle
    /// replays confidences by sample id; no image tensors needed).
    pub fn labels(mut self, labels: &'a [u8]) -> Self {
        self.labels = Some(labels);
        self
    }

    /// Image source for DES runs on a real engine.
    pub fn images(mut self, images: &'a Dataset) -> Self {
        self.images = Some(images);
        self
    }

    /// Override the config's source placement (who admits data, where).
    /// Sugar for mutating `cfg.placement` before `.config(...)` — handy
    /// when sweeping placements over one base config.
    pub fn placement(mut self, placement: Placement) -> Self {
        self.placement = Some(placement);
        self
    }

    pub fn driver(mut self, driver: Driver) -> Self {
        self.driver = driver;
        self
    }

    /// Resolve defaults and run to completion.
    pub fn execute(self) -> Result<RunReport> {
        let mut cfg = self.cfg.context("Run::builder(): .config(...) is required")?;
        if let Some(p) = self.placement {
            cfg.placement = p;
        }
        let meta = match self.meta {
            Some(m) => m,
            None => {
                let manifest = self
                    .manifest
                    .context("Run::builder(): need .model(meta) or .manifest(...)")?;
                ModelMeta::from_manifest(manifest.model(&cfg.model)?)
            }
        };

        // Dataset: explicit, or loaded from the manifest when a driver
        // needs one and only labels were not provided.
        let owned_dataset: Option<Dataset> = match (self.dataset, self.driver, self.labels) {
            (Some(_), _, _) => None,
            (None, Driver::Realtime, _) | (None, Driver::Des, None) => {
                let manifest = self.manifest.context(
                    "Run::builder(): need .dataset(...)/.labels(...) or .manifest(...)",
                )?;
                Some(Dataset::load(manifest.path(&manifest.dataset.file))?)
            }
            (None, Driver::Des, Some(_)) => None,
        };
        let dataset: Option<&Dataset> = self.dataset.or(owned_dataset.as_ref());

        match self.driver {
            Driver::Des => {
                anyhow::ensure!(
                    self.engine.is_none() || self.factory.is_none(),
                    "Run::builder(): .engine(...) and .engine_factory(...) are \
                     mutually exclusive — the DES driver would silently ignore \
                     the factory"
                );
                let store = SampleStore {
                    labels: match self.labels {
                        Some(l) => l,
                        None => &dataset.expect("resolved above").labels,
                    },
                    // An explicitly supplied dataset carries its images
                    // (real-engine path); a manifest-derived one stays
                    // labels-only, as the oracle engine never reads tensors.
                    images: self.images.or(self.dataset),
                };
                // Engine: explicit ref, factory product, or the oracle.
                let from_factory: Option<Box<dyn InferenceEngine>> =
                    match (&self.engine, &self.factory) {
                        (None, Some(f)) => Some(f(0)?),
                        _ => None,
                    };
                let owned_engine: Option<SimEngine> =
                    if self.engine.is_none() && from_factory.is_none() {
                        let manifest = self.manifest.context(
                            "Run::builder(): need .engine(...)/.engine_factory(...) \
                             or .manifest(...)",
                        )?;
                        Some(SimEngine::load(manifest, &cfg.model, cfg.use_ae)?)
                    } else {
                        None
                    };
                let engine: &dyn InferenceEngine = match (&self.engine, &from_factory) {
                    (Some(e), _) => *e,
                    (None, Some(b)) => b.as_ref(),
                    (None, None) => owned_engine.as_ref().expect("resolved above"),
                };
                Simulation::new(cfg, engine, meta, store)?.run()
            }
            Driver::Realtime => {
                anyhow::ensure!(
                    self.engine.is_none(),
                    "Run::builder(): .engine(...) cannot drive the realtime driver \
                     (engines are not Send; each worker thread needs its own) — \
                     use .engine_factory(...) instead"
                );
                anyhow::ensure!(
                    self.labels.is_none() && self.images.is_none(),
                    "Run::builder(): .labels(...)/.images(...) are DES-only — the \
                     realtime driver admits from a full .dataset(...)"
                );
                let dataset = dataset.expect("resolved above");
                match self.factory {
                    Some(f) => rt::run_realtime(&cfg, &f, &meta, dataset),
                    None => {
                        // Default: the best engine this build offers (PJRT
                        // stages under the `pjrt` feature, oracle replay
                        // with wallclock cost emulation otherwise).
                        let manifest = self.manifest.context(
                            "Run::builder(): realtime needs .engine_factory(...) \
                             or .manifest(...)",
                        )?;
                        let model = cfg.model.clone();
                        let use_ae = cfg.use_ae;
                        let f = move |_worker: usize| -> Result<Box<dyn InferenceEngine>> {
                            crate::runtime::default_engine(manifest, &model, use_ae)
                        };
                        rt::run_realtime(&cfg, &f, &meta, dataset)
                    }
                }
            }
        }
    }
}
